"""Kernel-level microbenchmark: dense vs masked vs gather-BSR matmul on CPU
wall-clock across densities, at the BERT projection shape (768x768) and the
FFN shape (3072x768). Shows where the sparse path's crossover density sits
on this backend -- the kernel-level version of Table 1.

Output CSV: name,us_per_call,derived  (derived = speedup vs dense)
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sparsity import prune_to_sparsity
from repro.kernels import pack_bsr
from repro.kernels.ops import bsr_linear

SHAPES = [("proj_768", 768, 768), ("ffn_3072", 3072, 768)]
DENSITIES = (1.0, 0.5, 0.2, 0.1, 0.05)
M, TILE = 384, (32, 32)


def _time(fn, *args, reps=5):
    jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def run(emit=print):
    rng = np.random.RandomState(0)
    out = []
    for name, n, k in SHAPES:
        x = jnp.asarray(rng.randn(M, k).astype(np.float32))
        w = jnp.asarray(rng.randn(n, k).astype(np.float32))
        dense = jax.jit(lambda x_, w_: x_ @ w_.T)
        t_dense = _time(dense, x, w)
        emit(f"kernel/{name}_dense,{t_dense*1e6:.1f},1.000")
        for d in DENSITIES:
            pruned, _ = prune_to_sparsity(w, TILE, 1.0 - d)
            pk = pack_bsr(np.asarray(pruned), TILE)
            for backend in ("gather", "rowpack"):
                sparse = jax.jit(lambda x_, data, _pk=pk, _b=backend:
                                 bsr_linear(x_, data, _pk, _b))
                t_s = _time(sparse, x, pk.data)
                emit(f"kernel/{name}_{backend}_d{int(d*100):03d},"
                     f"{t_s*1e6:.1f},{t_dense/t_s:.3f}")
                out.append((name, backend, d, t_dense, t_s))
    return out


if __name__ == "__main__":
    run()
