"""Kernel-level microbenchmark: dense vs sparse backends on CPU wall-clock
across densities, at the BERT projection shape (768x768) and the FFN shape
(3072x768). Shows where each sparse path's crossover density sits on this
backend -- the kernel-level version of Table 1.

Backends swept (see src/repro/kernels/ops.py and docs/PERF.md):
  * gather  -- one gather per stored tile (pure-XLA baseline);
  * rowpack -- row-grouped batched matmul, data scattered per call;
  * plan    -- precomputed RowPackPlan, data stored row-grouped offline
               (the serving path of repro/serving/export.py).

Besides the default (32, 32) kernel tile, the sweep includes the paper's
32x1 linear sparsity block at serving densities.

Output CSV: name,us_per_call,derived  (derived = speedup vs dense); the same
records are persisted to BENCH_kernels.json at the repo root (section
"kernel") so future PRs have a perf trajectory to compare against.

Every cell also runs the serving autotuner (kernels/autotune.py,
``ServingSpec backend='auto'``) over the same candidate set and records
whether its pick lands within 5% of the cell's measured best -- the
"measure, don't assume" check of the Sparsity Roofline argument, persisted
as the "autotune" section. With REPRO_AUTOTUNE_STUB=1 (CI) the pick comes
from the deterministic proxy instead of wall clocks; the section then
records mode="stub" and the 5% flag is informational only.

Run:  PYTHONPATH=src python benchmarks/kernel_bench.py [--smoke] [--no-json]
"""
from __future__ import annotations

import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sparsity import prune_to_sparsity
from repro.kernels import pack_bsr
from repro.kernels.autotune import choose_backend
from repro.kernels.exec_plan import (pack_plan_data, plan_for_pack,
                                     plan_linear, plan_linear_pallas)
from repro.kernels.flash_decode import flash_decode
from repro.kernels.ops import bsr_linear
from repro.models.attention import decode_attention
from repro.runtime.bench_io import update_bench_json

SHAPES = [("proj_768", 768, 768), ("ffn_3072", 3072, 768)]
DENSITIES = (1.0, 0.5, 0.2, 0.1, 0.05)
M = 384
SQUARE_TILE = (32, 32)
LINEAR_TILE = (32, 1)          # the paper's end-to-end CPU-optimal block
LINEAR_DENSITIES = (0.2, 0.1)  # serving regime only (nnzt is large at 32x1)
BACKENDS = ("gather", "rowpack", "plan")


def _time_group(fns_args, reps=7):
    """Paired timing: interleave the reps of all contestants round-robin so
    machine drift (shared cores, thermal) hits every arm equally -- backend
    *ordering* is then trustworthy even when absolute times wander. Returns
    ``(mins, scores)``: min-of-reps per contestant (scheduler noise on a
    shared box is one-sided: it only slows a run down, so the minimum
    approximates the quiet-machine time) and the median paired ratio vs
    the first contestant (each round's arms see the same machine state --
    the drift-robust *ordering* statistic, same one the autotuner ranks
    by)."""
    for fn, args in fns_args:
        jax.block_until_ready(fn(*args))        # compile + warm
    ts = [[] for _ in fns_args]
    for _ in range(reps):
        for i, (fn, args) in enumerate(fns_args):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            ts[i].append(time.perf_counter() - t0)
    anchor = np.asarray(ts[0], np.float64)
    scores = [float(np.median(np.asarray(t, np.float64) / anchor))
              for t in ts]
    return [float(np.min(t)) for t in ts], scores


def _sparse_fn(pk, backend):
    """Jitted callable + its data argument for one (pattern, backend)."""
    if backend == "plan":
        plan = plan_for_pack(pk)
        data = pack_plan_data(plan, pk.data)
        return jax.jit(lambda x_, d_, _p=plan: plan_linear(x_, d_, _p)), data
    return (jax.jit(lambda x_, d_, _pk=pk, _b=backend:
                    bsr_linear(x_, d_, _pk, _b)), pk.data)


def run(emit=print, smoke=False, write_json=True, reps=7):
    """Sweep backends; returns the record list written to BENCH_kernels.json.

    ``smoke`` restricts to one serving density at the default tile with
    fewer reps -- the ~30 s CI smoke of scripts/check.sh.
    """
    rng = np.random.RandomState(0)
    if smoke:
        sweeps = [(SQUARE_TILE, (0.2,))]
        reps = min(reps, 3)
    else:
        sweeps = [(SQUARE_TILE, DENSITIES), (LINEAR_TILE, LINEAR_DENSITIES)]
    records = []
    auto_records = []
    for name, n, k in SHAPES:
        x = jnp.asarray(rng.randn(M, k).astype(np.float32))
        w = jnp.asarray(rng.randn(n, k).astype(np.float32))
        dense = jax.jit(lambda x_, w_: x_ @ w_.T)
        for tile, densities in sweeps:
            tile_tag = "" if tile == SQUARE_TILE else \
                f"_t{tile[0]}x{tile[1]}"
            # at the 32x1 tile nnzt explodes and the gather path would
            # materialize an (nnzt, M, bn) product (~0.7 GB at the FFN
            # shape) -- exactly the docs/PERF.md point about aggregating
            # small sparsity blocks into kernel tiles; sweep the
            # row-grouped backends only there
            backends = BACKENDS if tile == SQUARE_TILE else \
                ("rowpack", "plan")
            for d in densities:
                pruned, _ = prune_to_sparsity(w, tile, 1.0 - d)
                pk = pack_bsr(np.asarray(pruned), tile)
                # the dense baseline joins every group so each recorded
                # speedup_vs_dense is a *paired* measurement (machine drift
                # between groups cannot skew the ratio)
                arms = [("dense", dense, w)]
                arms += [(backend,) + _sparse_fn(pk, backend)
                         for backend in backends]
                # serving-density arms are fast: buy extra reps there so the
                # min-of-reps ordering is stable against scheduler noise
                # (the shared box needs ~30 paired reps to resolve <10% gaps)
                d_reps = reps if d > 0.2 or smoke else max(reps, 31)
                times, scores = _time_group([(fn, (x, data))
                                             for _, fn, data in arms],
                                            reps=d_reps)
                t_dense = times[0]
                for (backend, _, _), t_s in zip(arms, times):
                    emit(f"kernel/{name}_{backend}{tile_tag}"
                         f"_d{int(d*100):03d},{t_s*1e6:.1f},"
                         f"{t_dense/t_s:.3f}")
                    records.append({
                        "shape": name, "n": n, "k": k, "m": M,
                        "backend": backend, "tile": list(tile),
                        "density": d, "us": round(t_s * 1e6, 1),
                        "speedup_vs_dense": round(t_dense / t_s, 3)})
                # autotuner cross-check over this cell's candidate set:
                # its independent pick must land within 5% of the paired
                # measurement's best arm (stub mode: deterministic proxy).
                # Both sides use the same rep discipline AND the same
                # drift-robust ordering statistic (median paired ratio);
                # residual disagreement is then pure session-to-session
                # drift on genuine near-ties
                by_arm = {nm: t for (nm, _, _), t in zip(arms, times)}
                by_score = {nm: s for (nm, _, _), s in zip(arms, scores)}
                choice = choose_backend(
                    pk, m=M, candidates=tuple(by_arm), reps=d_reps)
                best = min(by_score, key=by_score.get)
                auto_records.append({
                    "shape": name, "tile": list(tile), "density": d,
                    "chosen": choice.backend, "best_measured": best,
                    "chosen_us": round(by_arm[choice.backend] * 1e6, 1),
                    "best_us": round(by_arm[best] * 1e6, 1),
                    "chosen_score": round(by_score[choice.backend], 4),
                    "best_score": round(by_score[best], 4),
                    "within_5pct": bool(by_score[choice.backend]
                                        <= 1.05 * by_score[best]),
                    "cache_hit": choice.cache_hit, "mode": choice.mode})
    n_ok = sum(r["within_5pct"] for r in auto_records)
    emit(f"# autotune: {n_ok}/{len(auto_records)} cells within 5% of best "
         f"fixed backend [{auto_records[0]['mode'] if auto_records else '-'}]")
    if write_json:
        # the smoke subset must not clobber the full sweep's trajectory
        section = "kernel_smoke" if smoke else "kernel"
        path = update_bench_json(section, records)
        update_bench_json("autotune_smoke" if smoke else "autotune",
                          auto_records)
        emit(f"# wrote {len(records)} records to {path} [{section}]")
    return records


def run_plan_bsr(emit=print, smoke=False, write_json=True, reps=7):
    """Plan-layout arms head to head: the XLA composition ('plan') vs the
    compiled plan-consuming Pallas kernel ('plan_pallas').

    Off-TPU the Pallas arm executes in interpret mode -- a correctness
    vehicle, not a serving path (docs/PERF.md) -- so it only runs in the
    smoke sweep at a tiny shape there; the recorded cells keep the two
    arms' trajectories comparable on TPU where both compile. Section
    schema matches the engine benches ({"results": {arm: [cells]}}) so
    scripts/bench_guard.py tracks it warn-only by ``rate`` (rows/s)."""
    rng = np.random.RandomState(0)
    on_tpu = jax.default_backend() == "tpu"
    if smoke:
        cells = [("proj_256", 256, 256, 64, 0.2)]
        reps = min(reps, 3)
    else:
        cells = [("proj_768", 768, 768, M, d) for d in (0.5, 0.2, 0.1)]
    results = {"plan": [], "plan_pallas": []}
    for name, n, k, m, d in cells:
        x = jnp.asarray(rng.randn(m, k).astype(np.float32))
        w = jnp.asarray(rng.randn(n, k).astype(np.float32))
        pruned, _ = prune_to_sparsity(w, SQUARE_TILE, 1.0 - d)
        pk = pack_bsr(np.asarray(pruned), SQUARE_TILE)
        plan = plan_for_pack(pk)
        data = pack_plan_data(plan, pk.data)
        arms = [("plan", jax.jit(
            lambda x_, d_, _p=plan: plan_linear(x_, d_, _p)))]
        if on_tpu or smoke:
            arms.append(("plan_pallas", jax.jit(
                lambda x_, d_, _p=plan: plan_linear_pallas(x_, d_, _p))))
        times, _ = _time_group([(fn, (x, data)) for _, fn in arms],
                               reps=reps)
        for (arm, _), t_s in zip(arms, times):
            cell = {"cell": f"{name}_d{int(d * 100):03d}", "density": d,
                    "m": m, "us": round(t_s * 1e6, 1),
                    "rate": round(m / t_s, 1)}
            results[arm].append(cell)
            emit(f"plan_bsr/{name}_{arm}_d{int(d * 100):03d},"
                 f"{t_s * 1e6:.1f},{m / t_s:.0f}")
    if write_json:
        section = "plan_bsr_smoke" if smoke else "plan_bsr"
        path = update_bench_json(section, {"results": results,
                                           "device": jax.default_backend()})
        emit(f"# wrote plan_bsr cells to {path} [{section}]")
    return results


def run_flash_decode(emit=print, smoke=False, write_json=True, reps=7):
    """Decode-attention arms over a context-length x split-K sweep: the
    materialized-softmax XLA path vs the split-K flash kernel
    (kernels/flash_decode.py). Off-TPU the flash arm is interpret-mode
    (smoke-only, tiny contexts); tokens_per_s = batch tokens emitted per
    decode step -- the metric bench_guard tracks warn-only."""
    rng = np.random.RandomState(0)
    on_tpu = jax.default_backend() == "tpu"
    b, hq, hkv, d = 8, 8, 4, 64
    if smoke:
        sweep = [(128, 1), (128, 2)]
        reps = min(reps, 3)
    else:
        sweep = [(t, s) for t in (256, 1024, 4096) for s in (1, 4, 8)
                 if s <= t // 128]
    results = {"xla": [], "flash": []}
    for t, split in sweep:
        q = jnp.asarray(rng.randn(b, 1, hq, d).astype(np.float32))
        k = jnp.asarray(rng.randn(b, t, hkv, d).astype(np.float32))
        v = jnp.asarray(rng.randn(b, t, hkv, d).astype(np.float32))
        kvp = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))
        pos = jnp.full((b,), t - 1, jnp.int32)
        arms = [("xla", jax.jit(lambda *a: decode_attention(*a)))]
        if on_tpu or smoke:
            arms.append(("flash", jax.jit(
                lambda *a, _s=split: flash_decode(*a, kv_split=_s))))
        times, _ = _time_group([(fn, (q, k, v, kvp, pos)) for _, fn in arms],
                               reps=reps)
        for (arm, _), t_s in zip(arms, times):
            if arm == "xla" and split > 1:
                continue            # the XLA arm has no split axis
            results[arm].append({
                "cell": f"t{t}_s{split if arm == 'flash' else 1}",
                "context": t, "kv_split": split if arm == "flash" else 1,
                "us": round(t_s * 1e6, 1),
                "tokens_per_s": round(b / t_s, 1)})
            emit(f"flash_decode/{arm}_t{t}_s{split},{t_s * 1e6:.1f},"
                 f"{b / t_s:.0f}")
    if write_json:
        section = "flash_decode_smoke" if smoke else "flash_decode"
        path = update_bench_json(section, {"results": results,
                                           "device": jax.default_backend()})
        emit(f"# wrote flash_decode cells to {path} [{section}]")
    return results


if __name__ == "__main__":
    smoke = "--smoke" in sys.argv
    write_json = "--no-json" not in sys.argv
    run(smoke=smoke, write_json=write_json)
    run_plan_bsr(smoke=smoke, write_json=write_json)
    run_flash_decode(smoke=smoke, write_json=write_json)
