"""Paper Table 2 analogue: task quality vs sparsity ratio.

No GLUE/SQuAD data offline, so the proxy task is synthetic masked-LM on a
structured token stream (zipfian unigram + copy patterns): train a reduced
BERT dense, then prune to 50% / 80% block sparsity (32x1 blocks, the paper's
regularization shape) with brief finetuning, and report MLM loss + masked
accuracy for each arm. The claim being reproduced is the TREND (small quality
drop at 50%, modest at 80%), not absolute GLUE numbers.

Output CSV: name,us_per_call,derived  (us=finetune step time, derived=metric)
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.core.pruner import apply_masks, oneshot_prune
from repro.core.sparsity import SparsityConfig
from repro.launch.steps import cross_entropy
from repro.models import bert as bert_mod
from repro.models import init_model
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state

MASK_ID = 3
_TARGETS = ("attn/wq", "attn/wk", "attn/wv", "attn/wo", "ffn/wi", "ffn/wo")


def _mlm_batch(rng, cfg, b=8, s=64):
    base = rng.zipf(1.5, size=(b, s)) % (cfg.vocab_size - 4) + 4
    # copy structure: second half repeats first half (learnable signal)
    base[:, s // 2:] = base[:, : s // 2]
    mask = rng.rand(b, s) < 0.15
    tokens = np.where(mask, MASK_ID, base)
    return (jnp.asarray(tokens.astype(np.int32)),
            jnp.asarray(base.astype(np.int32)), jnp.asarray(mask))


def _mlm_loss(params, cfg, tokens, labels, mask):
    logits = bert_mod.forward(params, cfg, tokens)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    per_tok = lse - gold
    m = mask.astype(jnp.float32)
    loss = jnp.sum(per_tok * m) / jnp.maximum(jnp.sum(m), 1.0)
    acc = jnp.sum((jnp.argmax(logits, -1) == labels) * m) / \
        jnp.maximum(jnp.sum(m), 1.0)
    return loss, acc


def _train(params, cfg, steps, rng, masks=None, sp=None, lr=3e-4):
    opt_cfg = AdamWConfig(peak_lr=lr, warmup_steps=10, total_steps=steps,
                          weight_decay=0.0)
    opt = init_opt_state(params, opt_cfg)

    @jax.jit
    def step(p, o, tokens, labels, mask):
        (l, acc), g = jax.value_and_grad(
            lambda p_: _mlm_loss(p_, cfg, tokens, labels, mask),
            has_aux=True)(p)
        p2, o2, _ = adamw_update(g, o, p, opt_cfg)
        return p2, o2, l

    t_step = None
    for i in range(steps):
        tokens, labels, mask = _mlm_batch(rng, cfg)
        t0 = time.perf_counter()
        params, opt, loss = step(params, opt, tokens, labels, mask)
        jax.block_until_ready(loss)
        t_step = time.perf_counter() - t0
        if masks is not None:
            params = apply_masks(params, masks, sp)
    return params, float(loss), t_step


def run(pretrain_steps=150, finetune_steps=60, emit=print):
    cfg = dataclasses.replace(get_config("bert_base", smoke=True),
                              n_layers=4, d_model=128, n_heads=4,
                              n_kv_heads=4, head_dim=32, d_ff=512,
                              vocab_size=1024)
    rng = np.random.RandomState(0)
    params = init_model(jax.random.PRNGKey(0), cfg)
    params, _, t_step = _train(params, cfg, pretrain_steps, rng)

    def evaluate(p):
        ls, accs = [], []
        erng = np.random.RandomState(999)
        for _ in range(8):
            tokens, labels, mask = _mlm_batch(erng, cfg)
            l, a = jax.jit(lambda p_, t, y, m: _mlm_loss(p_, cfg, t, y, m)
                           )(p, tokens, labels, mask)
            ls.append(float(l))
            accs.append(float(a))
        return float(np.mean(ls)), float(np.mean(accs))

    l_dense, a_dense = evaluate(params)
    emit(f"table2/dense_mlm_acc,{t_step*1e6:.0f},{a_dense:.4f}")
    emit(f"table2/dense_mlm_loss,{t_step*1e6:.0f},{l_dense:.4f}")
    results = {"dense": (l_dense, a_dense)}

    for ratio in (0.5, 0.8):
        sp = SparsityConfig(block_shape=(32, 1), sparsity=ratio,
                            targets=_TARGETS)
        pruned, masks = oneshot_prune(params, sp)
        tuned, _, t_ft = _train(pruned, cfg, finetune_steps,
                                np.random.RandomState(1), masks=masks, sp=sp,
                                lr=1e-4)
        l, a = evaluate(tuned)
        results[f"{int(ratio*100)}%"] = (l, a)
        emit(f"table2/sparse{int(ratio*100)}_mlm_acc,{t_ft*1e6:.0f},{a:.4f}")
        emit(f"table2/sparse{int(ratio*100)}_mlm_loss,{t_ft*1e6:.0f},{l:.4f}")
    return results


if __name__ == "__main__":
    run()
