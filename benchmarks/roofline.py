"""Consolidate results/dryrun/*.json into the §Roofline table.

Per (arch x shape x mesh): the three roofline terms (seconds), the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPS, roofline fraction, and memory footprint.
Emits CSV rows and (with --md) the markdown table for EXPERIMENTS.md.
"""
from __future__ import annotations

import glob
import json
import os
import sys

RESULTS = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "results", "dryrun")


def load(results_dir=RESULTS):
    cells = []
    for path in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def run(emit=print, results_dir=RESULTS):
    cells = load(results_dir)
    for c in cells:
        tag = f"{c['arch']}/{c['shape']}/{c['mesh']}"
        if c["status"] != "OK":
            emit(f"roofline/{tag},0,{c['status']}")
            continue
        r = c["roofline"]
        t_dom = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        emit(f"roofline/{tag},{t_dom*1e6:.0f},{r['roofline_fraction']:.4f}")
    return cells


def markdown(results_dir=RESULTS, mesh_filter="16x16"):
    rows = ["| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | "
            "bottleneck | useful/HLO | roofline frac | HBM GB/dev |",
            "|---|---|---|---|---|---|---|---|---|"]
    for c in load(results_dir):
        if c["mesh"] != mesh_filter:
            continue
        if c["status"] == "SKIP":
            rows.append(f"| {c['arch']} | {c['shape']} | - | - | - | "
                        f"SKIP: {c['reason']} | - | - | - |")
            continue
        if c["status"] != "OK":
            rows.append(f"| {c['arch']} | {c['shape']} | FAIL |||||||")
            continue
        r = c["roofline"]
        peak_gb = c["memory"]["peak_bytes"] / 1e9
        rows.append(
            f"| {c['arch']} | {c['shape']} | {r['t_compute_s']:.4f} | "
            f"{r['t_memory_s']:.4f} | {r['t_collective_s']:.4f} | "
            f"**{r['bottleneck']}** | {min(r['useful_flop_ratio'],99):.3f} | "
            f"{r['roofline_fraction']:.3f} | {peak_gb:.1f} |")
    return "\n".join(rows)


if __name__ == "__main__":
    if "--md" in sys.argv:
        mesh = sys.argv[sys.argv.index("--md") + 1] if \
            len(sys.argv) > sys.argv.index("--md") + 1 else "16x16"
        print(markdown(mesh_filter=mesh))
    else:
        run()
