"""Benchmark harness: one function per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV.  Select subsets:
  PYTHONPATH=src python -m benchmarks.run [table1] [table2] [fig2]
                                           [kernel] [roofline]
(no args = all).
"""
from __future__ import annotations

import sys


def main() -> None:
    want = set(sys.argv[1:]) or {"kernel", "table1", "table2", "fig2",
                                 "roofline"}
    print("name,us_per_call,derived")
    if "kernel" in want:
        from benchmarks.kernel_bench import run as kernel_run
        kernel_run()
    if "table1" in want:
        import os
        cached = os.path.join("results", "table1.csv")
        if os.path.exists(cached) and os.path.getsize(cached) > 0 and \
                "--fresh" not in sys.argv:
            # the full sweep takes ~1h on 1 CPU core; re-emit the recorded
            # measurements (rerun with --fresh to re-measure)
            with open(cached) as f:
                for line in f:
                    if line.strip() and not line.startswith("name,"):
                        print(line.strip())
        else:
            from benchmarks.table1_block_sweep import run as t1_run
            t1_run()
    if "table2" in want:
        from benchmarks.table2_accuracy import run as t2_run
        t2_run()
    if "fig2" in want:
        from benchmarks.fig2_block_perf import run as f2_run
        f2_run()
    if "roofline" in want:
        from benchmarks.roofline import run as roof_run
        roof_run()


if __name__ == "__main__":
    main()
