"""Paper Figure 2 analogue: performance vs block shape, plus the reuse
mechanism the paper hypothesizes (unique intra-block pattern cardinality).

Consumes table1 results when available (same process) or re-derives the
mechanism metrics standalone: for each block shape, at 80% sparsity,
  * packed tile density (compute actually executed by the BSR path)
  * unique intra-block pattern count / #blocks (TVM-scheduler reuse proxy)

Output CSV: name,us_per_call,derived
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.core.pattern_reuse import count_unique_intrablock_patterns
from repro.core.pruner import oneshot_prune
from repro.core.sparsity import SparsityConfig
from repro.kernels import pack_bsr
from repro.models import init_model

from benchmarks.table1_block_sweep import BLOCK_SHAPES, SPARSITY, _TARGETS


def run(emit=print):
    cfg = get_config("bert_base")
    params = init_model(jax.random.PRNGKey(0), cfg)
    w_ref = None
    out = []
    for name, bs in BLOCK_SHAPES:
        sp = SparsityConfig(block_shape=bs, sparsity=SPARSITY,
                            targets=_TARGETS)
        pruned, _ = oneshot_prune(params, sp)
        w = np.asarray(pruned["layers"][0]["attn"]["wq"]["w"], np.float32)
        tile = bs if bs != (1, 1) else (32, 32)
        pk = pack_bsr(w, tile)
        n_blocks = (w.shape[0] // bs[0]) * (w.shape[1] // bs[1])
        uniq = count_unique_intrablock_patterns(w, bs) / n_blocks
        emit(f"fig2/density_{name},0,{pk.density:.4f}")
        emit(f"fig2/unique_pattern_frac_{name},0,{uniq:.4f}")
        out.append((name, pk.density, uniq))
    return out


if __name__ == "__main__":
    run()
