"""Serving-engine throughput benchmark: tokens/s vs request concurrency.

Runs the continuous-batching engine (repro/serving/engine.py) over a
BERT-sized decoder-only LM (12L x 768d, the paper's model size moved into
the decode regime) at 1 / 4 / 16 request slots, sparse (80% block-pruned,
plan backend) against dense (same weights, no BSR support -- the paper's
negative control). Each cell submits 2x slots requests of mixed prompt
lengths, so admission, bucketed prefill, slot recycling and the batched
ragged decode all exercise on the hot path.

What to expect (docs/PERF.md records measured numbers): tokens/s grows
with slot count for both arms -- one batched decode step amortizes weight
traffic over all active slots -- and the sparse arm tracks or beats dense
once the per-step matmuls dominate scheduling overhead.

Results are persisted to BENCH_serving.json at the repo root (sections
"engine" / "engine_smoke") via repro.runtime.bench_io, keeping the perf
trajectory machine-readable across PRs.

Run:  PYTHONPATH=src python benchmarks/serving_bench.py [--smoke] [--no-json]
"""
from __future__ import annotations

import os
import sys
import time

import jax
import numpy as np

from repro.configs.base import LayerKind, ModelConfig
from repro.models import init_model
from repro.runtime.bench_io import repo_root, update_bench_json
from repro.serving import ServingSpec, prepare_servable

SLOT_COUNTS = (1, 4, 16)
SPARSITY = 0.8
TILE = (64, 64)


def bench_path() -> str:
    return os.path.join(repo_root(), "BENCH_serving.json")


def _bert_sized_lm(smoke: bool) -> ModelConfig:
    if smoke:
        return ModelConfig(
            arch="serving-bench-smoke", family="dense",
            n_layers=4, d_model=256, n_heads=4, n_kv_heads=4, head_dim=64,
            d_ff=1024, vocab_size=4096,
            pattern=(LayerKind("attn", "dense"),), dtype="float32")
    return ModelConfig(
        arch="serving-bench-bert-lm", family="dense",
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
        d_ff=3072, vocab_size=30522,
        pattern=(LayerKind("attn", "dense"),), dtype="float32")


def _run_cell(servable, slots, *, prompt_len, max_new, cache_len, rng,
              reps=2):
    """One (backend, concurrency) cell: warm the jit caches with a
    single-request run, then time a 2x-slots request burst ``reps`` times
    and keep the fastest (scheduler noise on the shared box is one-sided --
    it only slows a run down -- so min-of-reps approximates the
    quiet-machine time, same discipline as kernel_bench)."""
    warm = servable.engine(max_slots=slots, cache_len=cache_len)
    warm.submit(rng.randint(0, servable.cfg.vocab_size, (prompt_len,)),
                max_new_tokens=2)
    warm.run()

    best = None
    for _ in range(reps):
        eng = servable.engine(max_slots=slots, cache_len=cache_len)
        # same bucket as the warmup (prompt lengths vary under one power of
        # two) so the timed runs pay zero compilation
        lens = [max(2, prompt_len - (i % 4)) for i in range(2 * slots)]
        reqs = [eng.submit(rng.randint(0, servable.cfg.vocab_size, (L,)),
                           max_new_tokens=max_new) for L in lens]
        t0 = time.perf_counter()
        eng.run()
        dt = time.perf_counter() - t0
        assert all(r.done for r in reqs)
        if best is None or dt < best[0]:
            best = (dt, eng, len(reqs))
    dt, eng, n_reqs = best
    toks = eng.stats.tokens_generated
    return {"slots": slots, "requests": n_reqs, "tokens": toks,
            "seconds": round(dt, 4), "tokens_per_s": round(toks / dt, 2),
            "decode_steps": eng.stats.steps,
            "mean_occupancy": round(eng.stats.mean_occupancy, 2),
            "prefill_buckets": dict(eng.stats.bucket_hits)}


def run(emit=print, smoke=False, write_json=True):
    cfg = _bert_sized_lm(smoke)
    prompt_len = 8 if smoke else 16
    max_new = 8 if smoke else 32
    cache_len = 64 if smoke else 128
    rng = np.random.RandomState(0)

    emit(f"initializing {cfg.arch} ({cfg.n_layers}L x {cfg.d_model}d)...")
    params = init_model(jax.random.PRNGKey(0), cfg)
    # tied masks: one pattern shared by all layers of a scan-stacked group,
    # so the group's union pack stays at the target density (independent
    # per-layer masks would union to ~1 - (1-d)^L tile density)
    arms = {
        "sparse": prepare_servable(params, cfg, ServingSpec(
            tile=TILE, sparsity=SPARSITY, prune="tied",
            targets=("attn/wq", "attn/wk", "attn/wv", "attn/wo"),
            backend="plan")),
        "dense": prepare_servable(params, cfg, ServingSpec(
            tile=TILE, sparsity=SPARSITY, prune="tied",
            targets=("attn/wq", "attn/wk", "attn/wv", "attn/wo"),
            backend="dense")),
    }
    emit(f"sparse export: density="
         f"{arms['sparse'].stats()['density']:.2f} (target {SPARSITY:.0%} "
         f"pruned @ {TILE[0]}x{TILE[1]})")

    results = {name: [] for name in arms}
    emit(f"{'arm':8s} {'slots':>5s} {'tokens':>7s} {'sec':>8s} "
         f"{'tok/s':>8s} {'occupancy':>9s}")
    for slots in SLOT_COUNTS:
        for name, servable in arms.items():
            cell = _run_cell(servable, slots, prompt_len=prompt_len,
                             max_new=max_new, cache_len=cache_len, rng=rng,
                             reps=1 if smoke else 2)
            results[name].append(cell)
            emit(f"{name:8s} {cell['slots']:5d} {cell['tokens']:7d} "
                 f"{cell['seconds']:8.3f} {cell['tokens_per_s']:8.1f} "
                 f"{cell['mean_occupancy']:9.2f}")

    scaling = {name: round(cells[-1]["tokens_per_s"] /
                           cells[0]["tokens_per_s"], 2)
               for name, cells in results.items()}
    emit(f"throughput scaling {SLOT_COUNTS[0]} -> {SLOT_COUNTS[-1]} slots: "
         + ", ".join(f"{k} {v}x" for k, v in scaling.items()))

    if write_json:
        section = "engine_smoke" if smoke else "engine"
        path = update_bench_json(section, {
            "model": cfg.arch,
            "layers": cfg.n_layers, "d_model": cfg.d_model,
            "sparsity": SPARSITY, "tile": list(TILE),
            "prompt_len": prompt_len, "max_new_tokens": max_new,
            "slot_counts": list(SLOT_COUNTS),
            "results": results,
            "throughput_scaling": scaling,
        }, path=bench_path())
        emit(f"wrote {section} section to {path}")
    return results


if __name__ == "__main__":
    run(smoke="--smoke" in sys.argv, write_json="--no-json" not in sys.argv)
