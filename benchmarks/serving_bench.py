"""Serving-engine throughput benchmark: tokens/s vs request concurrency.

Runs the continuous-batching engine (repro/serving/engine.py) over a
BERT-sized decoder-only LM (12L x 768d, the paper's model size moved into
the decode regime) at 1 / 4 / 16 request slots, sparse (80% block-pruned,
plan backend) against dense (same weights, no BSR support -- the paper's
negative control). Each cell submits 2x slots requests of mixed prompt
lengths, so admission, bucketed prefill, slot recycling and the batched
ragged decode all exercise on the hot path.

Two sections are produced:

  * "engine" -- the per-step loop (sync_every=1): one host round-trip per
    token, the PR-3 baseline. Each cell now also reports the wall-clock
    breakdown (prefill vs decode vs host-sync seconds, engine stats).
  * "engine_fused" -- the fused-window loop: a ``--sync-every`` sweep at
    the highest concurrency, where K decode steps run inside one jitted
    scan and the host syncs once per window (models.api.decode_many).
    The per-step baseline showed ~200 ms/step on this box with the device
    busy for a fraction of it -- host dispatch, the overhead regime the
    CPU sparse-serving literature says to engineer away (arXiv:2306.16601).

An "engine_chaos" section measures the request-lifecycle robustness
layer's overhead: the same fused workload through a bare engine vs one
with deadlines, a bounded queue, a watchdog and a chaos registry armed --
lifecycle enforcement happens at window-sync points only, so the two arms
should match to noise (docs/PERF.md §Engine robustness overhead).

A "sharded" section sweeps the mesh path (``--mesh 1,2,8``): the
same engine workload served tensor-parallel over a ``(1, S)`` device mesh
(spec ``mesh_shape``), reporting tok/s plus per-device pack and cache
bytes -- the partitioning evidence. Mesh sizes the process cannot host
(fewer visible devices) are skipped with a note; on CPU run under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``. Host-platform
"devices" share one socket, so the sharded tok/s measure partitioning
OVERHEAD, not interconnect speedups (docs/PERF.md).

Results are persisted to BENCH_serving.json at the repo root via
repro.runtime.bench_io, keeping the perf trajectory machine-readable
across PRs; scripts/check.sh warns when a fresh smoke regresses >20%
against the committed numbers (scripts/bench_guard.py).

An "open_loop" section (``run_open_loop``) measures SLO latency under
seeded Poisson arrivals at fixed offered QPS: the full SLO scheduler
(chunked prefill + token budget + decode priority + queue-delay
shedding, SchedSpec) against the serve-everyone monolithic-prefill
baseline on the same arrival trace, reporting p50/p95/p99 TTFT and
per-token latency over completed requests plus shed counts
(docs/PERF.md §Open-loop serving).

Run:  PYTHONPATH=src python benchmarks/serving_bench.py
          [--smoke] [--no-json] [--skip-baseline] [--sync-every 1,4,8,16]
          [--mesh 1,2,8] [--qps 4,8,16] [run_* selector ...]
"""
from __future__ import annotations

import os
import sys
import time

import jax
import numpy as np

from repro.configs.base import LayerKind, ModelConfig
from repro.models import init_model
from repro.runtime.bench_io import repo_root, update_bench_json
from repro.serving import ServingSpec, prepare_servable

SLOT_COUNTS = (1, 4, 16)
SPARSITY = 0.8
TILE = (64, 64)
SYNC_SWEEP = (1, 4, 8, 16)
SYNC_SWEEP_SMOKE = (1, 4)
MESH_SWEEP = (1, 2, 8)


def bench_path() -> str:
    return os.path.join(repo_root(), "BENCH_serving.json")


def _bert_sized_lm(smoke: bool) -> ModelConfig:
    if smoke:
        return ModelConfig(
            arch="serving-bench-smoke", family="dense",
            n_layers=4, d_model=256, n_heads=4, n_kv_heads=4, head_dim=64,
            d_ff=1024, vocab_size=4096,
            pattern=(LayerKind("attn", "dense"),), dtype="float32")
    return ModelConfig(
        arch="serving-bench-bert-lm", family="dense",
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
        d_ff=3072, vocab_size=30522,
        pattern=(LayerKind("attn", "dense"),), dtype="float32")


def _run_cell(servable, slots, *, prompt_len, max_new, cache_len, rng,
              reps=2, sync_every=1, engine_kw=None, submit_kw=None):
    """One (backend, concurrency, sync_every) cell: warm the jit caches
    with a single-request run at the same window length (so every fused-K
    executable the timed run needs is already traced), then time a
    2x-slots request burst ``reps`` times and keep the fastest (scheduler
    noise on the shared box is one-sided -- it only slows a run down -- so
    min-of-reps approximates the quiet-machine time, same discipline as
    kernel_bench). ``engine_kw`` / ``submit_kw`` forward robustness knobs
    (deadlines, bounded queue, watchdog, chaos) for the engine_chaos
    section."""
    engine_kw = engine_kw or {}
    submit_kw = submit_kw or {}
    warm = servable.engine(max_slots=slots, cache_len=cache_len,
                           sync_every=sync_every, **engine_kw)
    warm.submit(rng.randint(0, servable.cfg.vocab_size, (prompt_len,)),
                max_new_tokens=max_new, **submit_kw)
    warm.run()
    warm.close()

    best = None
    for _ in range(reps):
        eng = servable.engine(max_slots=slots, cache_len=cache_len,
                              sync_every=sync_every, **engine_kw)
        # same bucket as the warmup (prompt lengths vary under one power of
        # two) so the timed runs pay zero compilation
        lens = [max(2, prompt_len - (i % 4)) for i in range(2 * slots)]
        reqs = [eng.submit(rng.randint(0, servable.cfg.vocab_size, (L,)),
                           max_new_tokens=max_new, **submit_kw)
                for L in lens]
        t0 = time.perf_counter()
        eng.run()
        dt = time.perf_counter() - t0
        eng.close()
        assert all(r.done for r in reqs)
        if best is None or dt < best[0]:
            best = (dt, eng, len(reqs))
    dt, eng, n_reqs = best
    toks = eng.stats.tokens_generated
    st = eng.stats
    cell = {"slots": slots, "requests": n_reqs, "tokens": toks,
            "seconds": round(dt, 4), "tokens_per_s": round(toks / dt, 2),
            "sync_every": sync_every,
            "decode_steps": st.steps, "windows": st.windows,
            "mean_occupancy": round(st.mean_occupancy, 2),
            "prefill_buckets": dict(st.bucket_hits),
            # wall-clock breakdown (seconds, engine-measured): prompt
            # prefill, decode windows (device call -> outputs on host),
            # host-side sync (token drain + callbacks + slot recycling)
            "breakdown": {
                "prefill_s": round(st.prefill_s, 4),
                "decode_s": round(st.decode_s, 4),
                "sync_s": round(st.sync_s, 4),
                "decode_ms_per_step": round(
                    1e3 * st.decode_s / max(st.steps, 1), 2),
                "sync_ms_per_window": round(
                    1e3 * st.sync_s / max(st.windows, 1), 2),
            }}
    # the timed engine rides along so callers can read post-run state
    # (e.g. run_sharded's per-device cache bytes) without building another
    return eng, cell


def _bench_params(smoke: bool):
    return {"prompt_len": 8 if smoke else 16,
            "max_new": 8 if smoke else 32,
            "cache_len": 64 if smoke else 128}


#: the paper's targets: attention AND the FC projections, where most of
#: the decode FLOPs live (ffn export for lm families landed with the
#: fused-decode PR; both arms prune identically -- dense is the
#: no-format-support negative control over the SAME pruned weights)
TARGETS = ("attn/wq", "attn/wk", "attn/wv", "attn/wo",
           "ffn/wi", "ffn/wg", "ffn/wo")


def _build_arms(cfg, emit):
    emit(f"initializing {cfg.arch} ({cfg.n_layers}L x {cfg.d_model}d)...")
    params = init_model(jax.random.PRNGKey(0), cfg)
    # tied masks: one pattern shared by all layers of a scan-stacked group,
    # so the group's union pack stays at the target density (independent
    # per-layer masks would union to ~1 - (1-d)^L tile density)
    arms = {
        "sparse": prepare_servable(params, cfg, ServingSpec(
            tile=TILE, sparsity=SPARSITY, prune="tied", targets=TARGETS,
            backend="plan")),
        "dense": prepare_servable(params, cfg, ServingSpec(
            tile=TILE, sparsity=SPARSITY, prune="tied", targets=TARGETS,
            backend="dense")),
    }
    emit(f"sparse export: density="
         f"{arms['sparse'].stats()['density']:.2f} (target {SPARSITY:.0%} "
         f"pruned @ {TILE[0]}x{TILE[1]})")
    return arms


def run(emit=print, smoke=False, write_json=True, arms=None):
    cfg = _bert_sized_lm(smoke)
    bp = _bench_params(smoke)
    prompt_len, max_new, cache_len = (bp["prompt_len"], bp["max_new"],
                                      bp["cache_len"])
    rng = np.random.RandomState(0)
    arms = arms or _build_arms(cfg, emit)

    results = {name: [] for name in arms}
    emit(f"{'arm':8s} {'slots':>5s} {'tokens':>7s} {'sec':>8s} "
         f"{'tok/s':>8s} {'occupancy':>9s}")
    for slots in SLOT_COUNTS:
        for name, servable in arms.items():
            _, cell = _run_cell(servable, slots, prompt_len=prompt_len,
                                max_new=max_new, cache_len=cache_len,
                                rng=rng, reps=1 if smoke else 2)
            results[name].append(cell)
            emit(f"{name:8s} {cell['slots']:5d} {cell['tokens']:7d} "
                 f"{cell['seconds']:8.3f} {cell['tokens_per_s']:8.1f} "
                 f"{cell['mean_occupancy']:9.2f}")

    scaling = {name: round(cells[-1]["tokens_per_s"] /
                           cells[0]["tokens_per_s"], 2)
               for name, cells in results.items()}
    emit(f"throughput scaling {SLOT_COUNTS[0]} -> {SLOT_COUNTS[-1]} slots: "
         + ", ".join(f"{k} {v}x" for k, v in scaling.items()))

    if write_json:
        section = "engine_smoke" if smoke else "engine"
        path = update_bench_json(section, {
            "model": cfg.arch,
            "layers": cfg.n_layers, "d_model": cfg.d_model,
            "sparsity": SPARSITY, "tile": list(TILE),
            "prompt_len": prompt_len, "max_new_tokens": max_new,
            "slot_counts": list(SLOT_COUNTS),
            "results": results,
            "throughput_scaling": scaling,
        }, path=bench_path())
        emit(f"wrote {section} section to {path}")
    return results


def run_fused(emit=print, smoke=False, write_json=True, sync_sweep=None,
              arms=None):
    """The tentpole measurement: tokens/s vs ``sync_every`` at the highest
    concurrency, fused windows against the per-step loop (sync_every=1 in
    the same sweep doubles as the paired baseline). Reports the wall-clock
    breakdown per cell so the host-dispatch share is visible."""
    cfg = _bert_sized_lm(smoke)
    bp = _bench_params(smoke)
    slots = 4 if smoke else SLOT_COUNTS[-1]
    sweep = tuple(sync_sweep or (SYNC_SWEEP_SMOKE if smoke else SYNC_SWEEP))
    rng = np.random.RandomState(1)
    arms = arms or _build_arms(cfg, emit)

    results = {name: [] for name in arms}
    emit(f"{'arm':8s} {'sync':>5s} {'tokens':>7s} {'sec':>8s} "
         f"{'tok/s':>8s} {'dec ms/step':>12s}")
    for sync_every in sweep:
        for name, servable in arms.items():
            _, cell = _run_cell(servable, slots, rng=rng,
                                prompt_len=bp["prompt_len"],
                                max_new=bp["max_new"],
                                cache_len=bp["cache_len"],
                                reps=1 if smoke else 2,
                                sync_every=sync_every)
            results[name].append(cell)
            emit(f"{name:8s} {sync_every:5d} {cell['tokens']:7d} "
                 f"{cell['seconds']:8.3f} {cell['tokens_per_s']:8.1f} "
                 f"{cell['breakdown']['decode_ms_per_step']:12.2f}")

    # "vs per-step" only means something when the sweep actually contains
    # the per-step arm (sync_every == 1); a custom sweep without it gets
    # no speedup record rather than a mislabeled ratio in the trajectory
    def _speedup(cells):
        base = [c for c in cells if c["sync_every"] == 1]
        if not base:
            return None
        return round(max(c["tokens_per_s"] for c in cells) /
                     base[0]["tokens_per_s"], 2)
    speedup = {name: _speedup(cells) for name, cells in results.items()}
    if all(v is not None for v in speedup.values()):
        emit("fused speedup vs per-step (best cell / sync-1 cell): "
             + ", ".join(f"{k} {v}x" for k, v in speedup.items()))
    else:
        emit("(no sync_every=1 arm in sweep: per-step speedup not recorded)")

    if write_json:
        section = "engine_fused_smoke" if smoke else "engine_fused"
        path = update_bench_json(section, {
            "model": cfg.arch,
            "layers": cfg.n_layers, "d_model": cfg.d_model,
            "sparsity": SPARSITY, "tile": list(TILE),
            "prompt_len": bp["prompt_len"], "max_new_tokens": bp["max_new"],
            "slots": slots, "sync_every_sweep": list(sweep),
            "results": results,
            "fused_speedup_vs_per_step": speedup,
        }, path=bench_path())
        emit(f"wrote {section} section to {path}")
    return results


def _tp_lm(smoke: bool) -> ModelConfig:
    """A decoder whose projections divide an 8-wide model axis at the
    sharded tile (wqkv block rows, ffn rows/cols, kv heads all % 8 == 0)."""
    if smoke:
        return ModelConfig(
            arch="serving-bench-tp-smoke", family="dense",
            n_layers=4, d_model=256, n_heads=8, n_kv_heads=8, head_dim=32,
            d_ff=1024, vocab_size=4096,
            pattern=(LayerKind("attn", "dense"),), dtype="float32")
    return ModelConfig(
        arch="serving-bench-tp", family="dense",
        n_layers=12, d_model=512, n_heads=8, n_kv_heads=8, head_dim=64,
        d_ff=2048, vocab_size=30522,
        pattern=(LayerKind("attn", "dense"),), dtype="float32")


def _per_device_bytes(tree) -> int:
    leaves = jax.tree_util.tree_leaves(tree)
    return int(sum(
        np.prod(x.sharding.shard_shape(x.shape)) * x.dtype.itemsize
        if hasattr(x, "sharding") else x.nbytes for x in leaves))


def run_sharded(emit=print, smoke=False, write_json=True, mesh_sweep=None):
    """The mesh sweep: the fused-engine workload served over (1, S) meshes.
    Emits tok/s + per-device pack/cache bytes per mesh size -- the
    evidence that TP export actually partitions state. Host-platform
    meshes measure partitioning overhead, not interconnects."""
    cfg = _tp_lm(smoke)
    bp = _bench_params(smoke)
    tile = (32, 32) if smoke else (64, 64)
    slots = 4 if smoke else 8
    sweep = tuple(mesh_sweep or MESH_SWEEP)
    rng = np.random.RandomState(2)

    emit(f"initializing {cfg.arch} ({cfg.n_layers}L x {cfg.d_model}d), "
         f"mesh sweep {sweep} ({jax.device_count()} devices visible)...")
    params = init_model(jax.random.PRNGKey(0), cfg)
    results = {}
    skipped = []
    emit(f"{'mesh':>6s} {'tokens':>7s} {'sec':>8s} {'tok/s':>8s} "
         f"{'pack/dev':>10s} {'cache/dev':>10s}")
    for s in sweep:
        if s > jax.device_count():
            skipped.append(s)
            continue
        spec = ServingSpec(
            tile=tile, sparsity=SPARSITY, prune="tied", targets=TARGETS,
            backend="plan",
            mesh_shape=(1, s) if s > 1 else None, partition="tp")
        servable = prepare_servable(params, cfg, spec)
        eng, cell = _run_cell(servable, slots, prompt_len=bp["prompt_len"],
                              max_new=bp["max_new"],
                              cache_len=bp["cache_len"],
                              rng=rng, reps=1 if smoke else 2, sync_every=4)
        _, cell["pack_bytes_per_device"] = servable.pack_bytes()
        cell["cache_bytes_per_device"] = _per_device_bytes(eng.cache)
        st = servable.stats()
        if "sharding" in st:
            cell["sharded_packs"] = st["sharding"]["sharded_packs"]
        results[f"tp{s}"] = [cell]
        emit(f"{'tp' + str(s):>6s} {cell['tokens']:7d} "
             f"{cell['seconds']:8.3f} {cell['tokens_per_s']:8.1f} "
             f"{cell['pack_bytes_per_device']:10d} "
             f"{cell['cache_bytes_per_device']:10d}")
    for s in skipped:
        emit(f"(mesh tp{s} skipped: needs {s} devices, "
             f"{jax.device_count()} visible -- set XLA_FLAGS="
             f"--xla_force_host_platform_device_count={max(sweep)})")

    if write_json and results:
        section = "sharded_smoke" if smoke else "sharded"
        path = update_bench_json(section, {
            "model": cfg.arch, "layers": cfg.n_layers,
            "d_model": cfg.d_model, "sparsity": SPARSITY,
            "tile": list(tile), "slots": slots,
            "mesh_sweep": list(sweep), "skipped": skipped,
            "devices_visible": jax.device_count(),
            "results": results,
        }, path=bench_path())
        emit(f"wrote {section} section to {path}")
    return results


def run_chaos(emit=print, smoke=False, write_json=True, arms=None):
    """The lifecycle-overhead cell: the fused-engine workload served twice
    over the SAME sparse servable -- once through a bare engine
    ("baseline") and once with the whole robustness layer armed
    ("lifecycle": bounded queue, per-request deadlines + priorities, a
    watchdog thread, and an attached-but-unarmed chaos registry). The
    deadline/cancel sweep and queue accounting run at window-sync points
    only, so the two arms should measure the same tok/s to noise
    (docs/PERF.md); bench_guard tracks the cell warn-only so a future PR
    that accidentally puts lifecycle checks on the per-token path shows up
    in the trajectory."""
    from repro.runtime.chaos import ChaosInjector
    cfg = _bert_sized_lm(smoke)
    bp = _bench_params(smoke)
    slots = 4 if smoke else SLOT_COUNTS[-1]
    sync_every = 4
    rng = np.random.RandomState(3)
    arms = arms or _build_arms(cfg, emit)
    servable = arms["sparse"]

    cells = {
        "baseline": ({}, {}),
        "lifecycle": ({"max_queue": 4 * slots, "overflow": "reject",
                       "watchdog_timeout_s": 60.0,
                       "chaos": ChaosInjector()},
                      {"deadline_s": 600.0, "priority": 1}),
    }
    results = {}
    emit(f"{'arm':10s} {'tokens':>7s} {'sec':>8s} {'tok/s':>8s}")
    for name, (engine_kw, submit_kw) in cells.items():
        _, cell = _run_cell(servable, slots, prompt_len=bp["prompt_len"],
                            max_new=bp["max_new"],
                            cache_len=bp["cache_len"], rng=rng,
                            reps=1 if smoke else 2, sync_every=sync_every,
                            engine_kw=engine_kw, submit_kw=submit_kw)
        results[name] = [cell]
        emit(f"{name:10s} {cell['tokens']:7d} {cell['seconds']:8.3f} "
             f"{cell['tokens_per_s']:8.1f}")
    overhead = round(
        results["baseline"][0]["tokens_per_s"] /
        results["lifecycle"][0]["tokens_per_s"] - 1.0, 4)
    emit(f"lifecycle overhead vs baseline: {overhead:+.2%} "
         f"(sync-point enforcement: expected ~0)")

    if write_json:
        section = "engine_chaos_smoke" if smoke else "engine_chaos"
        path = update_bench_json(section, {
            "model": cfg.arch, "layers": cfg.n_layers,
            "d_model": cfg.d_model, "sparsity": SPARSITY,
            "tile": list(TILE), "slots": slots, "sync_every": sync_every,
            "prompt_len": bp["prompt_len"], "max_new_tokens": bp["max_new"],
            "results": results,
            "lifecycle_overhead": overhead,
        }, path=bench_path())
        emit(f"wrote {section} section to {path}")
    return results


def run_kv_memory(emit=print, smoke=False, write_json=True, arms=None):
    """The paged-KV memory cells (docs/API.md §Paged KV + prefix cache):

      * per-request KV bytes -- dense reserves a full ``cache_len`` slot
        per request; paged reserves ``ceil((len + max_new) / page_size)``
        pages, so short requests stop paying for the worst case.
      * max concurrent requests at a FIXED KV byte budget -- the dense
        engine's whole cache allocation is taken as the budget, a paged
        pool of exactly that many bytes serves a mixed-length burst, and
        the peak concurrently-active count is measured (not derived).
      * shared-system-prompt workload -- every request repeats one system
        prompt; the radix prefix cache turns the repeats into page reuse.
        Reports the prefix-hit rate and the measured mean/p50 TTFT against
        the dense arm (same requests, full prefill each).

    All cells ride the same servable; the engine's ``kv_layout`` kwarg
    picks the layout so both arms share weights, packs and jit caches."""
    cfg = _bert_sized_lm(smoke)
    bp = _bench_params(smoke)
    cache_len, max_new = bp["cache_len"], bp["max_new"]
    slots = 4 if smoke else 8
    rng = np.random.RandomState(4)
    arms = arms or _build_arms(cfg, emit)
    servable = arms["sparse"]
    V = cfg.vocab_size

    def fresh(layout, **kw):
        return servable.engine(max_slots=slots, cache_len=cache_len,
                               sync_every=4, kv_layout=layout, **kw)

    # -- cell 1: per-request KV bytes -----------------------------------
    eng_d = fresh("dense")
    eng_p = fresh("paged")
    kv_d, kv_p = eng_d.kv_stats(), eng_p.kv_stats()
    ps = kv_p["page_size"]
    from repro.serving.paging import pages_needed
    mixed_lens = [max(2, int(L)) for L in
                  np.linspace(4, cache_len - max_new, 8)]
    per_req_paged = [pages_needed(L + max_new, ps) * kv_p["bytes_per_page"]
                     for L in mixed_lens]
    bytes_cell = {
        "dense_bytes_per_request": kv_d["kv_bytes_per_slot"],
        "paged_bytes_per_request_mixed": per_req_paged,
        "paged_mean_bytes_per_request": int(np.mean(per_req_paged)),
        "page_size": ps, "bytes_per_page": kv_p["bytes_per_page"],
        "mixed_prompt_lens": mixed_lens, "max_new_tokens": max_new,
    }
    emit(f"KV bytes/request: dense {kv_d['kv_bytes_per_slot']}, paged "
         f"mean {bytes_cell['paged_mean_bytes_per_request']} over mixed "
         f"lens {mixed_lens[0]}..{mixed_lens[-1]}")
    eng_d.close(), eng_p.close()

    # -- cell 2: max concurrency at the dense engine's byte budget -------
    budget = kv_d["kv_bytes_total"]
    pool_pages = max(1, budget // kv_p["bytes_per_page"])
    eng = servable.engine(max_slots=4 * slots, cache_len=cache_len,
                          sync_every=4, kv_layout="paged",
                          kv_pool_pages=pool_pages, max_queue=None)
    burst, peak = [], 0
    for i in range(4 * slots):
        L = mixed_lens[i % len(mixed_lens)]
        burst.append(eng.submit(rng.randint(0, V, (L,)),
                                max_new_tokens=max_new))
    while eng.step():
        peak = max(peak, eng.n_active)
    assert all(r.done for r in burst)
    concurrency_cell = {
        "kv_byte_budget": budget, "pool_pages": pool_pages,
        "dense_max_concurrent": slots,      # budget / full-slot bytes
        "paged_peak_concurrent": peak,
        "paged_peak_pages_used": eng.kv_stats()["peak_pages_used"],
    }
    emit(f"max concurrent @ {budget} KV bytes: dense {slots}, "
         f"paged {peak} (peak pages {concurrency_cell['paged_peak_pages_used']}"
         f"/{pool_pages})")
    eng.close()

    # -- cell 3: shared-system-prompt workload ---------------------------
    # exactly `slots` requests: all admit in the first schedule pass, so
    # TTFT measures admission (prefill) latency, not queue wait behind
    # decode throughput -- the decode tax shows in tokens_per_s instead
    system = rng.randint(0, V, (cache_len // 2,)).tolist()
    tails = [rng.randint(0, V, (3,)).tolist() for _ in range(slots)]
    results = {}
    for name in ("dense", "paged"):
        # warm the jit caches off-clock: two shared-prefix requests so the
        # paged arm compiles BOTH admission paths (full prefill + insert,
        # then match + restore + suffix prefill at the tail bucket)
        warm = fresh(name)
        warm.submit(system + tails[0], max_new_tokens=max_new)
        warm.submit(system + tails[1], max_new_tokens=max_new)
        warm.run()
        warm.close()
        eng = fresh(name, max_queue=None)
        first_tok = {}
        t0 = time.perf_counter()
        reqs = [eng.submit(system + tail, max_new_tokens=max_new,
                           on_token=lambda rid, tok: first_tok.setdefault(
                               rid, time.perf_counter() - t0))
                for tail in tails]
        eng.run()
        dt = time.perf_counter() - t0
        assert all(r.done for r in reqs)
        st = eng.stats
        kv = eng.kv_stats()
        ttfts = sorted(first_tok[r.req_id] for r in reqs)
        prompt_tokens = sum(len(system) + len(t) for t in tails)
        results[name] = [{
            "slots": slots, "requests": len(reqs), "sync_every": 4,
            "tokens": st.tokens_generated, "seconds": round(dt, 4),
            "tokens_per_s": round(st.tokens_generated / dt, 2),
            "prompt_tokens": prompt_tokens,
            "prefilled_tokens": kv["prefilled_tokens"],
            "prefix_hit_tokens": kv["prefix_hit_tokens"],
            "prefix_hit_rate": round(
                kv["prefix_hit_tokens"] / prompt_tokens, 4),
            "prefill_s": round(st.prefill_s, 4),
            # the paged decode tax (per-step page gather) lives here
            "decode_ms_per_step": round(
                1e3 * st.decode_s / max(st.steps, 1), 2),
            "ttft_mean_ms": round(1e3 * float(np.mean(ttfts)), 2),
            "ttft_p50_ms": round(1e3 * ttfts[len(ttfts) // 2], 2),
        }]
        c = results[name][0]
        emit(f"{name:8s} shared-prompt: hit rate {c['prefix_hit_rate']:.0%} "
             f"ttft mean {c['ttft_mean_ms']:.1f} ms  "
             f"prefilled {c['prefilled_tokens']}/{prompt_tokens} tok  "
             f"{c['tokens_per_s']:.1f} tok/s")
    ttft_reduction = round(
        1.0 - results["paged"][0]["ttft_mean_ms"] /
        results["dense"][0]["ttft_mean_ms"], 4)
    emit(f"prefix sharing TTFT reduction vs dense: {ttft_reduction:+.2%}")

    if write_json:
        section = "kv_memory_smoke" if smoke else "kv_memory"
        path = update_bench_json(section, {
            "model": cfg.arch, "layers": cfg.n_layers,
            "d_model": cfg.d_model, "sparsity": SPARSITY,
            "tile": list(TILE), "cache_len": cache_len,
            "max_new_tokens": max_new,
            "bytes_per_request": bytes_cell,
            "fixed_budget_concurrency": concurrency_cell,
            "results": results,
            "ttft_reduction_vs_dense": ttft_reduction,
        }, path=bench_path())
        emit(f"wrote {section} section to {path}")
    return results


def _latency_pcts(xs_s):
    """p50/p95/p99 of a latency sample, reported in milliseconds."""
    if not xs_s:
        return {"p50_ms": None, "p95_ms": None, "p99_ms": None}
    a = np.asarray(sorted(xs_s), dtype=np.float64)
    return {f"p{q}_ms": round(1e3 * float(np.percentile(a, q)), 2)
            for q in (50, 95, 99)}


def run_open_loop(emit=print, smoke=False, write_json=True, arms=None,
                  qps_sweep=None):
    """Open-loop SLO measurement (docs/PERF.md §Open-loop serving): seeded
    Poisson arrivals at a fixed offered QPS over a mixed-length workload
    (rare near-cache-sized prompts inside interactive traffic), submitted
    on their own clock -- arrivals do NOT wait for the engine, so queueing
    delay is measured rather than hidden (closed-loop benches self-throttle
    to the engine's pace and can't see head-of-line blocking at all).

    Two arms share one servable and the identical arrival/length trace:

      * "baseline" -- the PR-8 engine (monolithic prefill, FIFO+priority
        admission, serve-everyone): a long prompt's prefill occupies the
        whole scheduling pass, and under overload the queue grows without
        bound -- every request is eventually served, arbitrarily late.
      * "sched"    -- the full SLO feature set: SchedSpec(max_chunk,
        token_budget, decode_priority, max_queue_delay_s). Long prefills
        are sliced across windows with new arrivals admitted BETWEEN
        slices, running decodes keep a reserved token share, and when the
        estimated backlog drain time exceeds the queue-delay SLO the
        engine SHEDS (lowest-priority, newest-first) instead of serving
        everyone late.

    Reports TTFT (first token relative to the request's OFFERED arrival
    time, queue wait included) and per-token decode latency (TPOT) as
    p50/p95/p99 per offered QPS over the COMPLETED requests, with shed
    counts alongside -- the goodput framing: under overload an SLO-aware
    engine refuses work it cannot serve in time, so its percentiles cover
    fewer, faster requests BY DESIGN (the shed column is the other half
    of that trade; at stable load nothing sheds and the populations are
    identical). The p95-TTFT delta between arms is the SLO evidence the
    acceptance gate reads. Host-platform numbers characterize SCHEDULER
    behavior (relative arm-to-arm deltas), not hardware serving
    latency."""
    from repro.serving import SchedSpec
    cfg = _bert_sized_lm(smoke)
    slots = 8
    sync_every = 4
    cache_len = 512
    max_new = 8
    # interactive traffic with an occasional huge prompt: every 25th
    # request carries a near-cache-sized prompt (the tail-latency story is
    # the MANY shorts being protected from the RARE long, so the long
    # fraction is kept low enough that overall p95 reads the short
    # population; per-class percentiles are reported either way). The
    # smoke sweep sits in the moderate-to-deep overload regime where SLO
    # scheduling has something to do -- at stable load (the full sweep's
    # 8 qps cell) the arms are at parity by design.
    short_len, long_len, long_every = 8, 448, 25
    n_requests = 60 if smoke else 120
    sweep = tuple(qps_sweep or ((24.0, 40.0) if smoke
                                else (8.0, 24.0, 40.0)))
    sched = SchedSpec(max_chunk=128, token_budget=256, decode_priority=True,
                      max_queue_delay_s=0.25)
    arms = arms or _build_arms(cfg, emit)
    servable = arms["sparse"]
    V = cfg.vocab_size

    def fresh(use_sched):
        return servable.engine(max_slots=slots, cache_len=cache_len,
                               sync_every=sync_every, max_queue=None,
                               sched=sched if use_sched else None)

    # warm both arms' jit caches off-clock: one long + one short prompt
    # covers the monolithic buckets (128, 8) and the chunk buckets (16, 8)
    wrng = np.random.RandomState(9)
    for use_sched in (False, True):
        warm = fresh(use_sched)
        warm.submit(wrng.randint(0, V, (long_len,)), max_new_tokens=max_new)
        warm.submit(wrng.randint(0, V, (short_len,)), max_new_tokens=max_new)
        warm.run()
        warm.close()

    results = {"baseline": [], "sched": []}
    improvement = {}
    emit(f"{'arm':9s} {'qps':>5s} {'done':>5s} {'shed':>5s} "
         f"{'ttft p50':>9s} {'ttft p95':>9s} {'tpot p95':>9s} "
         f"{'tok/s':>7s}")
    for qps in sweep:
        # one seeded trace per QPS, replayed identically by both arms
        trace_rng = np.random.RandomState(int(qps * 1000) + 17)
        arrivals = np.cumsum(trace_rng.exponential(1.0 / qps, n_requests))
        lens = [long_len if (i + 1) % long_every == 0 else short_len
                for i in range(n_requests)]
        prompts = [trace_rng.randint(0, V, (int(L),)) for L in lens]
        for arm in ("baseline", "sched"):
            eng = fresh(arm == "sched")
            reqs = []
            t0 = time.monotonic()
            i = 0
            while i < n_requests:
                now = time.monotonic() - t0
                if arrivals[i] <= now:
                    reqs.append(eng.submit(prompts[i],
                                           max_new_tokens=max_new))
                    i += 1
                    continue
                if not eng.step():      # idle: sleep until the next arrival
                    time.sleep(min(arrivals[i] - now, 0.02))
            eng.run()                   # drain the tail
            assert all(r.finished for r in reqs)
            # latency percentiles cover COMPLETED requests (goodput);
            # shed/deadline counts in the cell are the other half
            served = [(r, arr, L) for r, arr, L
                      in zip(reqs, arrivals, lens) if r.status == "done"]
            ttfts = [r.first_token_at - (t0 + arr) for r, arr, _ in served]
            tpots = [(r.finished_at - r.first_token_at) /
                     (len(r.tokens) - 1)
                     for r, _, _ in served if len(r.tokens) > 1]
            st = eng.stats
            wall = max(r.finished_at for r, _, _ in served) - t0
            cell = {"arm": arm, "qps": qps, "requests": n_requests,
                    "completed": st.completed, "shed": st.shed,
                    "deadline_misses": st.deadline_misses,
                    "prefill_chunks": st.prefill_chunks,
                    "tokens_per_s": round(st.tokens_generated / wall, 2),
                    "ttft": _latency_pcts(ttfts),
                    "ttft_short": _latency_pcts(
                        [t for t, (_, _, L) in zip(ttfts, served)
                         if L == short_len]),
                    "ttft_long": _latency_pcts(
                        [t for t, (_, _, L) in zip(ttfts, served)
                         if L == long_len]),
                    "tpot": _latency_pcts(tpots)}
            results[arm].append(cell)
            emit(f"{arm:9s} {qps:5.1f} {cell['completed']:5d} "
                 f"{cell['shed']:5d} "
                 f"{cell['ttft']['p50_ms']:9.1f} "
                 f"{cell['ttft']['p95_ms']:9.1f} "
                 f"{cell['tpot']['p95_ms']:9.1f} "
                 f"{cell['tokens_per_s']:7.1f}")
            eng.close()
        base_p95 = results["baseline"][-1]["ttft"]["p95_ms"]
        sched_p95 = results["sched"][-1]["ttft"]["p95_ms"]
        improvement[str(qps)] = round(base_p95 - sched_p95, 2)
        emit(f"  p95 TTFT delta @ {qps} qps: "
             f"{improvement[str(qps)]:+.1f} ms (positive = sched wins)")

    if write_json:
        section = "open_loop_smoke" if smoke else "open_loop"
        path = update_bench_json(section, {
            "model": cfg.arch, "layers": cfg.n_layers,
            "d_model": cfg.d_model, "sparsity": SPARSITY,
            "tile": list(TILE), "slots": slots, "sync_every": sync_every,
            "cache_len": cache_len, "max_new_tokens": max_new,
            "short_len": short_len, "long_len": long_len,
            "long_every": long_every, "requests_per_cell": n_requests,
            "qps_sweep": list(sweep),
            "sched": {"max_chunk": sched.max_chunk,
                      "token_budget": sched.token_budget,
                      "decode_priority": sched.decode_priority,
                      "max_queue_delay_s": sched.max_queue_delay_s},
            "results": results,
            "p95_ttft_improvement_ms": improvement,
        }, path=bench_path())
        emit(f"wrote {section} section to {path}")
    return results


def run_quant_error(emit=print, smoke=False, write_json=True, arms=None):
    """The quantized-pack cells (docs/API.md §Quantized sparse packs): the
    fp32 plan arm against the SAME pruned weights exported with
    ``pack_quant='int8'`` (per-block absmax scales, dequant fused into the
    plan matmul). Three numbers matter:

      * pack bytes -- fp32 vs int8+scales, total and per device; the
        acceptance gate wants >= 3x smaller (int8 is 4x on values, the
        scale stream gives a little back).
      * fidelity -- max abs logit delta, teacher-forced next-token
        agreement (identical context per position, the standard metric)
        and free-running engine greedy agreement on identical prompts
        (both arms at temperature 0, same seeds), alongside the model's
        own top-2 logit margins. The >= 99% gate holds on the
        config-registry models (tests/test_quant_packs.py, gemma3);
        THIS model is random-init, so its margins sit at the quant
        noise floor and the agreement here reads against
        `logit_margins` (docs/PERF.md §Quantized packs). bench_guard
        warns if agreement or the delta drifts.
      * throughput -- tok/s per arm through the fused engine loop, so the
        dequant-fused path's cost (or win) is on the record next to the
        bytes it saves.
    """
    cfg = _bert_sized_lm(smoke)
    bp = _bench_params(smoke)
    slots = 4 if smoke else SLOT_COUNTS[-1]
    sync_every = 4
    rng = np.random.RandomState(5)
    arms = arms or _build_arms(cfg, emit)
    fp32 = arms["sparse"]
    emit("exporting int8 arm (same pruned weights, pack_quant='int8')...")
    # init_model is deterministic: PRNGKey(0) reproduces _build_arms'
    # weights exactly, so both arms prune to the identical pattern
    int8 = prepare_servable(
        init_model(jax.random.PRNGKey(0), cfg), cfg,
        ServingSpec(tile=TILE, sparsity=SPARSITY, prune="tied",
                    targets=TARGETS, backend="plan", pack_quant="int8"))

    # -- fidelity: teacher-forced next-token agreement over a prompt
    # batch (both arms see the IDENTICAL context at every position --
    # the standard quantization-fidelity metric; free-running decode
    # cascades a single flip into every later token) plus the raw max
    # logit delta and the model's own top-2 logit margins, so the
    # agreement number can be read against the decision margins it is
    # up against (random-init logits are near-tied by construction;
    # docs/PERF.md §Quantized packs)
    import jax.numpy as jnp
    toks = np.random.RandomState(6).randint(0, cfg.vocab_size, (8, 24))
    y32 = np.asarray(fp32.forward(jnp.asarray(toks)))
    y8 = np.asarray(int8.forward(jnp.asarray(toks)))
    max_delta = float(np.abs(y32 - y8).max())
    a32, a8 = y32.argmax(-1), y8.argmax(-1)
    tf_agreement = float((a32 == a8).mean())
    top2 = np.sort(y32, -1)
    gaps = top2[..., -1] - top2[..., -2]
    margin_stats = {"top2_gap_median": round(float(np.median(gaps)), 5),
                    "top2_gap_p10": round(float(np.percentile(gaps, 10)),
                                          5)}

    def greedy_tokens(servable):
        eng = servable.engine(max_slots=slots, cache_len=bp["cache_len"],
                              sync_every=sync_every, temperature=0.0)
        prng = np.random.RandomState(7)
        lens = [max(2, bp["prompt_len"] - (i % 4))
                for i in range(2 * slots)]
        reqs = [eng.submit(prng.randint(0, cfg.vocab_size, (L,)),
                           max_new_tokens=bp["max_new"]) for L in lens]
        eng.run()
        assert all(r.done for r in reqs)
        out = [list(r.tokens) for r in reqs]
        eng.close()
        return out

    t32, t8 = greedy_tokens(fp32), greedy_tokens(int8)
    matched = sum(a == b for s32, s8 in zip(t32, t8)
                  for a, b in zip(s32, s8))
    total = sum(len(s) for s in t32)
    fr_agreement = matched / max(total, 1)

    # -- bytes: fp32 vs int8+scales, total and per device ----------------
    b32_total, b32_dev = fp32.pack_bytes()
    b8_total, b8_dev = int8.pack_bytes()
    qs = int8.quant_stats() or {}
    bytes_cell = {
        "fp32_pack_bytes": b32_total, "fp32_pack_bytes_per_device": b32_dev,
        "int8_pack_bytes": b8_total, "int8_pack_bytes_per_device": b8_dev,
        "bytes_ratio": round(b32_total / max(b8_total, 1), 3),
        "compression_ratio": qs.get("compression_ratio"),
        "granularities": qs.get("granularities"),
        "max_abs_quant_err": qs.get("max_abs_err"),
    }
    emit(f"pack bytes: fp32 {b32_total}, int8 {b8_total} "
         f"({bytes_cell['bytes_ratio']}x smaller); "
         f"max |logit delta| {max_delta:.4g} "
         f"(model top-2 gap median {margin_stats['top2_gap_median']})")
    emit(f"greedy agreement: teacher-forced {tf_agreement:.2%}, "
         f"free-running engine {fr_agreement:.2%} "
         f"({matched}/{total} tokens)")

    # -- throughput: both arms through the fused engine loop -------------
    results = {}
    emit(f"{'arm':10s} {'tokens':>7s} {'sec':>8s} {'tok/s':>8s}")
    for name, servable in (("fp32_plan", fp32), ("int8_plan", int8)):
        _, cell = _run_cell(servable, slots, prompt_len=bp["prompt_len"],
                            max_new=bp["max_new"],
                            cache_len=bp["cache_len"], rng=rng,
                            reps=1 if smoke else 2, sync_every=sync_every)
        results[name] = [cell]
        emit(f"{name:10s} {cell['tokens']:7d} {cell['seconds']:8.3f} "
             f"{cell['tokens_per_s']:8.1f}")

    if write_json:
        section = "quant_error_smoke" if smoke else "quant_error"
        path = update_bench_json(section, {
            "model": cfg.arch, "layers": cfg.n_layers,
            "d_model": cfg.d_model, "sparsity": SPARSITY,
            "tile": list(TILE), "slots": slots, "sync_every": sync_every,
            "prompt_len": bp["prompt_len"], "max_new_tokens": bp["max_new"],
            "pack_quant": "int8",
            "pack_bytes": bytes_cell,
            "max_abs_logit_delta": round(max_delta, 6),
            "greedy_token_agreement": round(tf_agreement, 6),
            "engine_greedy_agreement": round(fr_agreement, 6),
            "logit_margins": margin_stats,
            "results": results,
        }, path=bench_path())
        emit(f"wrote {section} section to {path}")
    return results


#: positional selectors: `serving_bench.py --smoke run_open_loop` runs just
#: that section; no selector keeps the historical run-everything behavior
SELECTORS = ("run", "run_fused", "run_chaos", "run_kv_memory",
             "run_sharded", "run_open_loop", "run_quant_error")


def main(argv):
    smoke = "--smoke" in argv
    write_json = "--no-json" not in argv
    sweep = None
    if "--sync-every" in argv:
        sweep = tuple(int(v) for v in
                      argv[argv.index("--sync-every") + 1].split(","))
    mesh_sweep = None
    if "--mesh" in argv:
        mesh_sweep = tuple(int(v) for v in
                           argv[argv.index("--mesh") + 1].split(","))
    qps_sweep = None
    if "--qps" in argv:
        qps_sweep = tuple(float(v) for v in
                          argv[argv.index("--qps") + 1].split(","))
    chosen = [a for a in argv if a in SELECTORS]
    if "--skip-baseline" in argv and "run" in chosen:
        chosen.remove("run")

    def want(name):
        return name in chosen if chosen else True

    arms = None
    if any(want(n) for n in SELECTORS if n != "run_sharded"):
        arms = _build_arms(_bert_sized_lm(smoke), print)
    if want("run") and "--skip-baseline" not in argv:
        run(smoke=smoke, write_json=write_json, arms=arms)
    if want("run_fused"):
        run_fused(smoke=smoke, write_json=write_json, sync_sweep=sweep,
                  arms=arms)
    if want("run_chaos"):
        run_chaos(smoke=smoke, write_json=write_json, arms=arms)
    if want("run_kv_memory"):
        run_kv_memory(smoke=smoke, write_json=write_json, arms=arms)
    if want("run_open_loop"):
        run_open_loop(smoke=smoke, write_json=write_json, arms=arms,
                      qps_sweep=qps_sweep)
    if want("run_quant_error"):
        run_quant_error(smoke=smoke, write_json=write_json, arms=arms)
    if want("run_sharded"):
        run_sharded(smoke=smoke, write_json=write_json,
                    mesh_sweep=mesh_sweep)


if __name__ == "__main__":
    main(sys.argv[1:])
