"""Paper Table 1 analogue: BERT_BASE CPU inference time vs sparsity structure.

Arms (mapping in DESIGN.md §2):
  eager      -- un-jitted jax.numpy          (PyTorch/TF row)
  xla_dense  -- jit dense                    (stock-TVM dense row)
  xla_masked -- jit, pruned weights, dense execution
                                             (stock TVM + sparse model row:
                                              the negative control)
  xla_bsr    -- jit, BSR-packed execution via the gather sparse path
                                             (TVM+ row)

Sweeps the paper's 14 block shapes at 80% sparsity on the full BERT_BASE
(L=12, H=768, seq 384, batch 1 -- the paper's SQuAD serving shape).
Irregular (1x1) sparsity is packed at the kernel's (32,32) tile granularity;
its packed density stays ~1.0, mechanically reproducing the paper's finding
that fine-grained sparsity yields no speedup without structure.

Output CSV: name,us_per_call,derived   (derived = ratio vs xla_dense)
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.core.pattern_reuse import count_unique_intrablock_patterns
from repro.core.sparsity import SparsityConfig
from repro.core.pruner import oneshot_prune
from repro.models import bert as bert_mod
from repro.models import init_model
from repro.serving import ServingSpec, prepare_servable

SEQ, BATCH, SPARSITY = 384, 1, 0.8
BLOCK_SHAPES = [
    ("irregular_1x1", (1, 1)),
    ("l1_1x4", (1, 4)), ("l1_1x8", (1, 8)), ("l1_1x16", (1, 16)),
    ("l1_1x32", (1, 32)), ("l1_1x64", (1, 64)), ("l1_1x128", (1, 128)),
    ("l1_1x256", (1, 256)), ("l1_1x384", (1, 384)),
    ("sq_4x4", (4, 4)), ("sq_8x8", (8, 8)), ("sq_16x16", (16, 16)),
    ("sq_32x32", (32, 32)), ("sq_64x64", (64, 64)),
    # beyond-paper: the XLA/TPU backend-tile optimum (EXPERIMENTS.md §Perf)
    ("sq_128x128", (128, 128)),
]
_TARGETS = ("attn/wq", "attn/wk", "attn/wv", "attn/wo", "ffn/wi", "ffn/wo")


def _time(fn, *args, reps=3, warmup=1):
    """Adaptive: configs slower than 5 s/run are measured once (noise is
    irrelevant at 10-50x slowdowns; budget matters on 1 CPU core)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    jax.block_until_ready(fn(*args))
    first = time.perf_counter() - t0
    if first > 5.0 or reps <= 1:
        return first, 0.0
    ts = [first]
    for _ in range(reps - 1):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.mean(ts)), float(np.std(ts))


def run(reps=3, emit=lambda s: print(s, flush=True)):
    cfg = get_config("bert_base")
    params = init_model(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(np.random.RandomState(0).randint(
        0, cfg.vocab_size, (BATCH, SEQ)))

    rows = []
    # -- dense baselines ---------------------------------------------------
    t_eager, _ = _time(lambda: bert_mod.forward(params, cfg, toks), reps=1)
    dense_fn = jax.jit(lambda p, t: bert_mod.forward(p, cfg, t))
    t_dense, s_dense = _time(dense_fn, params, toks, reps=reps)
    rows.append(("table1/eager_dense", t_eager, 1.0))
    rows.append(("table1/xla_dense", t_dense, 1.0))
    emit(f"table1/eager_dense,{t_eager*1e6:.0f},{t_eager/t_dense:.3f}")
    emit(f"table1/xla_dense,{t_dense*1e6:.0f},1.000")

    for name, bs in BLOCK_SHAPES:
        sp = SparsityConfig(block_shape=bs, sparsity=SPARSITY,
                            targets=_TARGETS)
        pruned, _ = oneshot_prune(params, sp)
        # negative control: pruned weights, dense execution
        t_masked, _ = _time(dense_fn, pruned, toks, reps=reps)
        # TVM+ analogue: BSR execution via the serving facade; kernel tile ==
        # sparsity block, except irregular which is packed at (32,32)
        tile = bs if bs != (1, 1) else (32, 32)
        servable = prepare_servable(
            pruned, cfg, ServingSpec(tile=tile, prune="none",
                                     cross_layer_union=False))
        density = servable.stats()["density"]
        t_bsr, s_bsr = _time(servable.forward, toks, reps=reps)
        ratio = t_bsr / t_dense
        uniq = count_unique_intrablock_patterns(
            np.asarray(pruned["layers"][0]["attn"]["wq"]["w"]), bs)
        emit(f"table1/masked_{name},{t_masked*1e6:.0f},"
             f"{t_masked/t_dense:.3f}")
        emit(f"table1/bsr_{name},{t_bsr*1e6:.0f},{ratio:.3f}")
        rows.append((name, t_masked, t_bsr, ratio, density, uniq))
    return rows


if __name__ == "__main__":
    run()
