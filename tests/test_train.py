"""Integration: training loss goes down, checkpoint/restart is exact,
injected failures recover, elastic restore re-shards, straggler rebalance,
gradient compression numerics."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import CheckpointStore
from repro.configs.registry import get_config
from repro.core.sparsity import SparsityConfig, actual_sparsity
from repro.data.pipeline import DataConfig, DataPipeline
from repro.launch.train import TrainConfig, Trainer
from repro.optim.adamw import AdamWConfig
from repro.optim.compression import (CompressionConfig, compress,
                                     decompress, init_error_buffers)
from repro.runtime.fault_tolerance import (FaultInjector,
                                           FaultToleranceConfig,
                                           StragglerMonitor)

single_mesh = lambda: jax.make_mesh((1, 1), ("data", "model"))


def _tcfg(tmp, **kw):
    return TrainConfig(
        n_steps=kw.pop("n_steps", 12), ckpt_dir=str(tmp),
        opt=AdamWConfig(peak_lr=5e-3, warmup_steps=2, total_steps=50,
                        weight_decay=0.0),
        ft=FaultToleranceConfig(checkpoint_every=4, max_restarts=3),
        log_every=1, **kw)


def _dcfg(cfg):
    return DataConfig(seq_len=32, global_batch=4, vocab_size=cfg.vocab_size)


def test_loss_decreases(tmp_path):
    cfg = get_config("deepseek_7b", smoke=True)
    tr = Trainer(cfg, _tcfg(tmp_path, n_steps=20), single_mesh(), _dcfg(cfg))
    _, hist = tr.fit(resume=False)
    first = np.mean([l for _, l in hist[:3]])
    last = np.mean([l for _, l in hist[-3:]])
    assert last < first, hist


def test_failure_recovery_resumes_from_checkpoint(tmp_path):
    cfg = get_config("deepseek_7b", smoke=True)
    inj = FaultInjector(fail_at_steps=(6, 9))
    tr = Trainer(cfg, _tcfg(tmp_path), single_mesh(), _dcfg(cfg),
                 fault_injector=inj)
    state, hist = tr.fit(resume=False)
    assert inj.fired == {6, 9}
    assert int(state["opt"]["step"]) == 12    # completed despite failures


def test_restart_reproduces_uninterrupted_run(tmp_path):
    """Deterministic data + restore-on-failure => same final loss as a
    clean run (exactly-once step semantics)."""
    cfg = get_config("deepseek_7b", smoke=True)
    t1 = Trainer(cfg, _tcfg(tmp_path / "a"), single_mesh(), _dcfg(cfg))
    s1, h1 = t1.fit(resume=False)
    inj = FaultInjector(fail_at_steps=(7,))
    t2 = Trainer(cfg, _tcfg(tmp_path / "b"), single_mesh(), _dcfg(cfg),
                 fault_injector=inj)
    s2, h2 = t2.fit(resume=False)
    l1 = jax.tree_util.tree_leaves(s1["params"])
    l2 = jax.tree_util.tree_leaves(s2["params"])
    for a, b in zip(l1, l2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_checkpoint_elastic_restore(tmp_path):
    """Save under one mesh, restore under a different device layout."""
    cfg = get_config("deepseek_7b", smoke=True)
    tr = Trainer(cfg, _tcfg(tmp_path, n_steps=4), single_mesh(), _dcfg(cfg))
    state, _ = tr.fit(resume=False)
    store = CheckpointStore(str(tmp_path))
    like = {"params": state["params"], "opt": state["opt"], "masks": None}
    restored = store.restore(like)
    for a, b in zip(jax.tree_util.tree_leaves(state["params"]),
                    jax.tree_util.tree_leaves(restored["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_gradual_pruning_during_training(tmp_path):
    sp = SparsityConfig(block_shape=(8, 8), sparsity=0.75,
                        targets=("attn/wq", "attn/wk", "attn/wv", "attn/wo"),
                        start_step=0, end_step=8)
    cfg = dataclasses.replace(get_config("deepseek_7b", smoke=True),
                              sparsity=sp)
    tr = Trainer(cfg, _tcfg(tmp_path, n_steps=12, prune=True), single_mesh(),
                 _dcfg(cfg))
    state, hist = tr.fit(resume=False)
    w = state["params"]["blocks"][0]["attn"]["wq"]["w"][0]
    got = float(actual_sparsity(w, (8, 8)))
    assert got >= 0.70, got


def test_data_pipeline_determinism_and_sharding():
    cfg = DataConfig(seq_len=16, global_batch=8, vocab_size=100, n_hosts=1)
    p = DataPipeline(cfg)
    b5a = p.batch_at(5)
    b5b = p.batch_at(5)
    np.testing.assert_array_equal(b5a["tokens"], b5b["tokens"])
    p.close()
    # host sharding partitions the batch
    c0 = DataConfig(seq_len=16, global_batch=8, vocab_size=100, n_hosts=2,
                    host_id=0)
    assert c0.host_batch == 4


def test_straggler_monitor_rebalances():
    mon = StragglerMonitor(4, FaultToleranceConfig(straggler_threshold=1.4))
    for _ in range(5):
        mon.observe({0: 1.0, 1: 1.0, 2: 1.0, 3: 3.0})
    assert mon.stragglers() == [3]
    mb = mon.rebalance(np.array([4, 4, 4, 4]))
    assert mb.sum() == 16 and mb[3] == 3


class TestCompression:
    def test_roundtrip_identity_at_full_density(self):
        rng = np.random.RandomState(0)
        g = jnp.asarray(rng.randn(64, 256).astype(np.float32))
        ccfg = CompressionConfig(block_shape=(8, 128), density=1.0,
                                 min_size=0)
        err0 = jnp.zeros_like(g)
        vals, idx, err = compress(g, err0, ccfg)
        back = decompress(vals, idx, g.shape, ccfg)
        np.testing.assert_allclose(np.asarray(back), np.asarray(g),
                                   rtol=1e-6)
        assert float(jnp.abs(err).max()) == 0.0

    def test_error_feedback_conserves_signal(self):
        """compressed + error == original (nothing lost, only deferred)."""
        rng = np.random.RandomState(1)
        g = jnp.asarray(rng.randn(64, 256).astype(np.float32))
        ccfg = CompressionConfig(block_shape=(8, 128), density=0.25,
                                 min_size=0)
        vals, idx, err = compress(g, jnp.zeros_like(g), ccfg)
        back = decompress(vals, idx, g.shape, ccfg)
        np.testing.assert_allclose(np.asarray(back + err), np.asarray(g),
                                   rtol=1e-5, atol=1e-6)

    def test_compressed_allreduce_under_shard_map(self):
        from repro.optim.compression import make_compressed_sync
        mesh = jax.make_mesh((1,), ("data",))
        ccfg = CompressionConfig(block_shape=(8, 128), density=1.0,
                                 min_size=0)
        rng = np.random.RandomState(2)
        g = jnp.asarray(rng.randn(16, 256).astype(np.float32))
        err = jnp.zeros_like(g)
        sync = make_compressed_sync(mesh, ("data",), ccfg)
        out, new_err = sync(g, err)
        np.testing.assert_allclose(np.asarray(out), np.asarray(g), rtol=1e-5)


def test_group_lasso_prox_induces_sparsity_without_pruning(tmp_path):
    """Paper Eq. 1 mechanism: the group-lasso prox term ALONE (no magnitude
    pruning) drives whole blocks to exact zero during training."""
    # block norm at init ~ 0.02*8 = 0.16; per-step shrink = lr * lambda,
    # so lambda = 3.0 crosses the weakest blocks well inside 40 steps
    sp = SparsityConfig(block_shape=(8, 8), sparsity=0.0, lambda_reg=3.0,
                        targets=("attn/wq", "attn/wk", "attn/wv", "attn/wo"))
    cfg = dataclasses.replace(get_config("deepseek_7b", smoke=True),
                              sparsity=sp)
    tr = Trainer(cfg, _tcfg(tmp_path, n_steps=40), single_mesh(), _dcfg(cfg))
    state, _ = tr.fit(resume=False)
    w = state["params"]["blocks"][0]["attn"]["wq"]["w"][0]
    got = float(actual_sparsity(w, (8, 8)))
    assert got > 0.10, f"prox produced no block sparsity ({got})"


def test_elastic_restore_across_mesh_shapes(tmp_path):
    """Save on a (1,1) mesh; restore + step on a different layout in a
    subprocess with 4 fake devices (scale-up restart)."""
    import os
    import subprocess
    import sys
    cfg = get_config("deepseek_7b", smoke=True)
    tr = Trainer(cfg, _tcfg(tmp_path, n_steps=4), single_mesh(), _dcfg(cfg))
    tr.fit(resume=False)
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    code = f"""
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=4'
import jax, numpy as np
from repro.configs.registry import get_config
from repro.data.pipeline import DataConfig
from repro.launch.train import TrainConfig, Trainer
from repro.optim.adamw import AdamWConfig
from repro.runtime.fault_tolerance import FaultToleranceConfig
cfg = get_config('deepseek_7b', smoke=True)
mesh = jax.make_mesh((2, 2), ('data', 'model'))
tcfg = TrainConfig(n_steps=6, ckpt_dir={str(tmp_path)!r},
                   opt=AdamWConfig(peak_lr=5e-3, warmup_steps=2,
                                   total_steps=50, weight_decay=0.0),
                   ft=FaultToleranceConfig(checkpoint_every=100), log_every=1)
dcfg = DataConfig(seq_len=32, global_batch=4, vocab_size=cfg.vocab_size)
tr = Trainer(cfg, tcfg, mesh, dcfg)
state, hist = tr.fit(resume=True)   # restores the (1,1)-mesh checkpoint
assert int(state['opt']['step']) == 6, int(state['opt']['step'])
print('ELASTIC OK', hist[-1])
"""
    env = dict(os.environ, PYTHONPATH=os.path.join(repo, "src"))
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "ELASTIC OK" in r.stdout
