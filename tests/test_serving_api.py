"""The unified serving facade (repro.serving): prepare_servable parity vs
dense-pruned forward for bert AND an lm config (fused + union on/off),
tied_prune as a first-class recipe, stats() instrumentation, and the
save -> load_servable round-trip serving without re-running export."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.core import SparsityConfig
from repro.core.pruner import oneshot_prune, tie_group, tied_prune
from repro.models import init_model, model_forward
from repro.serving import ServingSpec, load_servable, prepare_servable

RNG = np.random.RandomState(0)
TARGETS = ("attn/wq", "attn/wk", "attn/wv", "attn/wo", "ffn/wi", "ffn/wo")


@pytest.fixture(scope="module")
def bert():
    cfg = get_config("bert_base", smoke=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(RNG.randint(0, cfg.vocab_size, (2, 32)))
    return cfg, params, toks


@pytest.fixture(scope="module")
def lm():
    cfg = get_config("deepseek_7b", smoke=True)
    params = init_model(jax.random.PRNGKey(1), cfg)
    toks = jnp.asarray(RNG.randint(0, cfg.vocab_size, (2, 32)))
    return cfg, params, toks


# --------------------------------------------------------------------------
# tied_prune (promoted into core.pruner)
# --------------------------------------------------------------------------

def test_tie_group_wildcards_layer_indices():
    assert tie_group("layers/[3]/attn/wq/w") == "layers/*/attn/wq/w"
    assert tie_group("layers/[3]/attn/wq/w") == tie_group("layers/[7]/attn/wq/w")


def test_tied_prune_shares_masks_across_layers(bert):
    cfg, params, _ = bert
    sp = SparsityConfig(block_shape=(16, 16), sparsity=0.75, targets=TARGETS)
    pruned, masks = tied_prune(params, sp)
    m0 = masks["layers"][0]["attn"]["wq"]["w"]
    m1 = masks["layers"][1]["attn"]["wq"]["w"]
    assert m0 is not None and bool(jnp.all(m0 == m1))
    # tied sparsity hits the target like oneshot does
    kept = float(jnp.mean(m0))
    assert abs((1.0 - kept) - sp.sparsity) < 0.1
    # untargeted leaves keep no mask
    assert masks["embed"]["w"] is None


def test_tied_prune_matches_oneshot_sparsity_level(lm):
    cfg, params, _ = lm
    sp = SparsityConfig(block_shape=(16, 16), sparsity=0.7)
    pruned, masks = tied_prune(params, sp)
    n_masked = sum(m is not None for m in jax.tree_util.tree_leaves(
        masks, is_leaf=lambda x: x is None))
    assert n_masked > 0


# --------------------------------------------------------------------------
# prepare_servable parity (bert + lm, fused/union on and off)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("fuse,union", [(True, True), (True, False),
                                        (False, True), (False, False)])
def test_bert_servable_matches_dense_pruned(bert, fuse, union):
    cfg, params, toks = bert
    spec = ServingSpec(tile=(16, 16), sparsity=0.75, prune="tied",
                       targets=TARGETS, fuse_qkv=fuse,
                       cross_layer_union=union)
    servable = prepare_servable(params, cfg, spec)
    pruned, _ = tied_prune(params, spec.sparsity_config())
    dense, _ = model_forward(pruned, cfg, {"tokens": toks})
    got = servable.forward(toks)
    np.testing.assert_allclose(np.asarray(got), np.asarray(dense),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("fuse", [True, False])
def test_lm_servable_matches_dense_pruned(lm, fuse):
    cfg, params, toks = lm
    spec = ServingSpec(tile=(16, 16), sparsity=0.7, prune="oneshot",
                       targets=("attn/wq", "attn/wk", "attn/wv", "attn/wo"),
                       fuse_qkv=fuse)
    servable = prepare_servable(params, cfg, spec)
    assert servable.packs, "no projections exported"
    pruned, _ = oneshot_prune(params, spec.sparsity_config())
    dense, _ = model_forward(pruned, cfg, {"tokens": toks})
    got = servable.forward(toks)
    np.testing.assert_allclose(np.asarray(got), np.asarray(dense),
                               rtol=1e-4, atol=1e-4)


def test_bsr_backend_matches_plan_backend(bert):
    cfg, params, toks = bert
    mk = lambda backend: prepare_servable(
        params, cfg, ServingSpec(tile=(16, 16), sparsity=0.75, prune="tied",
                                 targets=TARGETS, backend=backend))
    np.testing.assert_allclose(np.asarray(mk("plan").forward(toks)),
                               np.asarray(mk("bsr").forward(toks)),
                               rtol=1e-4, atol=1e-4)


def test_lm_ffn_export_packs_only_pruned_projections(lm):
    """FFN export for lm families (the paper's FC targets): pruned wi/wg/wo
    get packed and serve with parity; an attention-only prune recipe packs
    NO ffn projections (packing an unpruned weight is pure loss)."""
    cfg, params, toks = lm
    ffn_spec = ServingSpec(tile=(16, 16), sparsity=0.7, prune="oneshot",
                           targets=("attn/wq", "attn/wk", "attn/wv",
                                    "attn/wo", "ffn/wi", "ffn/wg", "ffn/wo"))
    servable = prepare_servable(params, cfg, ffn_spec)
    ffn_packs = [k for k in servable.packs if "/ffn/" in k]
    assert ffn_packs, "pruned FFN projections must be exported"
    pruned, _ = oneshot_prune(params, ffn_spec.sparsity_config())
    dense, _ = model_forward(pruned, cfg, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(servable.forward(toks)),
                               np.asarray(dense), rtol=1e-4, atol=1e-4)
    # decode path consumes the ffn packs too
    cache = servable.init_cache(2, 16)
    logits, _ = servable.decode_step(cache, toks[:, :1], 0)
    assert logits.shape == (2, 1, cfg.vocab_size)

    attn_only = prepare_servable(params, cfg, ServingSpec(
        tile=(16, 16), sparsity=0.7, prune="oneshot",
        targets=("attn/wq", "attn/wk", "attn/wv", "attn/wo")))
    assert not [k for k in attn_only.packs if "/ffn/" in k]


def test_lm_servable_decode_step(lm):
    cfg, params, toks = lm
    servable = prepare_servable(
        params, cfg, ServingSpec(tile=(16, 16), sparsity=0.7, prune="oneshot",
                                 targets=("attn/wq", "attn/wk", "attn/wv")))
    cache = servable.init_cache(2, 16)
    logits, cache = servable.decode_step(cache, toks[:, :1], 0)
    assert logits.shape == (2, 1, cfg.vocab_size)


def test_bert_servable_has_no_decode(bert):
    cfg, params, _ = bert
    servable = prepare_servable(params, cfg, ServingSpec(tile=(16, 16)))
    with pytest.raises(ValueError):
        servable.init_cache(1, 8)


# --------------------------------------------------------------------------
# instrumentation
# --------------------------------------------------------------------------

def test_stats_reports_registry_reuse_and_union(bert):
    cfg, params, toks = bert
    spec = ServingSpec(tile=(16, 16), sparsity=0.75, prune="tied",
                       targets=TARGETS, cross_layer_union=True)
    st = prepare_servable(params, cfg, spec).stats()
    n_groups = st["unique_patterns"]              # wqkv, attn/wo, ffn/wi, wo
    assert st["packed_projections"] == cfg.n_layers * n_groups
    # cross-layer union: every layer after the first hits the registry
    assert st["registry"]["misses"] == n_groups
    assert st["registry"]["hits"] == (cfg.n_layers - 1) * n_groups
    assert st["registry"]["reuse_rate"] > 0
    # tied masks -> the union adds zero padding
    assert st["union_overhead"] == pytest.approx(1.0)
    assert 0 < st["density"] < 0.45
    assert st["padded_flop_ratio"] >= 1.0


def test_unique_patterns_counted_by_fingerprint_on_bsr_backend(bert,
                                                               tmp_path):
    """Tied masks + per-layer bsr packs: uniqueness must dedupe by pattern
    fingerprint (not object identity), and survive a save/load unchanged."""
    cfg, params, _ = bert
    spec = ServingSpec(tile=(16, 16), sparsity=0.75, prune="tied",
                       targets=TARGETS, backend="bsr",
                       cross_layer_union=False)
    servable = prepare_servable(params, cfg, spec)
    st = servable.stats()
    assert st["packed_projections"] == cfg.n_layers * st["unique_patterns"]
    servable.save(str(tmp_path))
    assert load_servable(str(tmp_path)).stats()["unique_patterns"] \
        == st["unique_patterns"]


def test_spec_validation():
    with pytest.raises(ValueError):
        ServingSpec(prune="magic")
    with pytest.raises(ValueError):
        ServingSpec(backend="cuda")
    with pytest.raises(ValueError):
        ServingSpec(dtype="int4")


def test_spec_dtype_casts_packed_values_only(bert):
    cfg, params, _ = bert
    servable = prepare_servable(
        params, cfg, ServingSpec(tile=(16, 16), sparsity=0.75,
                                 targets=TARGETS, dtype="bfloat16"))
    assert servable.params["layers"][0]["attn"]["wqkv"]["w"].dtype == jnp.bfloat16
    assert servable.params["embed"]["w"].dtype == jnp.float32


# --------------------------------------------------------------------------
# persistence: save -> load_servable serves without re-running export
# --------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["plan", "bsr"])
def test_save_load_roundtrip(bert, tmp_path, backend):
    cfg, params, toks = bert
    spec = ServingSpec(tile=(16, 16), sparsity=0.75, prune="tied",
                       targets=TARGETS, backend=backend)
    servable = prepare_servable(params, cfg, spec)
    want = servable.forward(toks)
    servable.save(str(tmp_path))

    loaded = load_servable(str(tmp_path))
    got = loaded.forward(toks)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # config/spec survive the trip
    assert loaded.cfg == cfg
    assert loaded.spec == spec
    # pattern sharing survives: one object per unique pattern, and the
    # build-time reuse counters stay inspectable
    st = loaded.stats()
    assert st["unique_patterns"] == servable.stats()["unique_patterns"]
    assert st["registry_at_save"] == servable.stats()["registry"]
    if backend == "plan":
        # the load pays one plan build per unique pattern, never per scope
        assert st["registry"]["misses"] == st["unique_patterns"]


def test_load_servable_lm_decode_roundtrip(lm, tmp_path):
    cfg, params, toks = lm
    servable = prepare_servable(
        params, cfg, ServingSpec(tile=(16, 16), sparsity=0.7, prune="oneshot",
                                 targets=("attn/wq", "attn/wk", "attn/wv",
                                          "attn/wo")))
    want, _ = servable.decode_step(servable.init_cache(2, 16), toks[:, :1], 0)
    servable.save(str(tmp_path))
    loaded = load_servable(str(tmp_path))
    got, _ = loaded.decode_step(loaded.init_cache(2, 16), toks[:, :1], 0)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
