"""Loop-aware HLO cost model: exact match vs XLA on loop-free graphs,
trip-count scaling on loops, collective accounting under SPMD."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.hlo_cost import HloCostModel, parse_module


def _compile(fn, *specs, **jit_kw):
    return jax.jit(fn, **jit_kw).lower(*specs).compile()


def test_matches_xla_on_loop_free_graph():
    def fn(a, b):
        return jnp.tanh(a @ b) @ b
    c = _compile(fn, jax.ShapeDtypeStruct((256, 512), jnp.float32),
                 jax.ShapeDtypeStruct((512, 512), jnp.float32))
    ours = HloCostModel(c.as_text()).total()
    xla = c.cost_analysis()
    if isinstance(xla, list):   # older JAX returns one dict per partition
        xla = xla[0]
    assert abs(ours.flops / xla["flops"] - 1) < 0.02
    assert abs(ours.bytes / xla["bytes accessed"] - 1) < 0.05


def test_scales_with_trip_count():
    def make(n):
        def fn(h):
            out, _ = jax.lax.scan(lambda h, _: (jnp.tanh(h @ h), None), h,
                                  None, length=n)
            return out
        c = _compile(fn, jax.ShapeDtypeStruct((128, 128), jnp.float32))
        return HloCostModel(c.as_text()).total().flops
    f3, f12 = make(3), make(12)
    assert abs(f12 / f3 - 4.0) < 0.1
    # absolute: one body dot = 2*128^3
    assert abs(f3 / (3 * 2 * 128 ** 3) - 1) < 0.1


def test_nested_loops_multiply():
    def fn(h):
        def outer(h, _):
            def inner(h, _):
                return jnp.tanh(h @ h), None
            h, _ = jax.lax.scan(inner, h, None, length=5)
            return h, None
        out, _ = jax.lax.scan(outer, h, None, length=3)
        return out
    c = _compile(fn, jax.ShapeDtypeStruct((64, 64), jnp.float32))
    flops = HloCostModel(c.as_text()).total().flops
    assert abs(flops / (15 * 2 * 64 ** 3) - 1) < 0.1


def test_collectives_counted_with_trip_multiplier():
    mesh = jax.make_mesh((1,), ("d",))
    sh = NamedSharding(mesh, P("d"))

    def fn(x):
        def body(c, _):
            s = jax.lax.with_sharding_constraint(c, sh)
            return jnp.tanh(s @ s.T @ s), None
        out, _ = jax.lax.scan(body, x, None, length=4)
        return jnp.sum(out)
    c = _compile(fn, jax.ShapeDtypeStruct((8, 8), jnp.float32))
    m = HloCostModel(c.as_text())
    # on a 1-device mesh there may be no collectives; the parse must at
    # least succeed and produce finite totals
    t = m.total()
    assert np.isfinite(t.flops) and np.isfinite(t.bytes)


def test_parser_handles_tuple_types_with_index_comments():
    text = """HloModule m, is_scheduled=true

%body (p: (s32[], f32[4,4])) -> (s32[], f32[4,4]) {
  %p = (s32[], /*index=1*/ f32[4,4]{1,0}) parameter(0)
  %g0 = s32[] get-tuple-element(%p), index=0
  %g1 = f32[4,4]{1,0} get-tuple-element(%p), index=1
  %c1 = s32[] constant(1)
  %a = s32[] add(%g0, %c1)
  %d = f32[4,4]{1,0} dot(%g1, %g1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %t = (s32[], /*index=1*/ f32[4,4]{1,0}) tuple(%a, %d)
}

%cond (p2: (s32[], f32[4,4])) -> pred[] {
  %p2 = (s32[], /*index=1*/ f32[4,4]{1,0}) parameter(0)
  %g = s32[] get-tuple-element(%p2), index=0
  %c = s32[] constant(10)
  ROOT %lt = pred[] compare(%g, %c), direction=LT
}

ENTRY %main (x: f32[4,4]) -> f32[4,4] {
  %x = f32[4,4]{1,0} parameter(0)
  %z = s32[] constant(0)
  %tup = (s32[], /*index=1*/ f32[4,4]{1,0}) tuple(%z, %x)
  %w = (s32[], /*index=1*/ f32[4,4]{1,0}) while(%tup), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
  ROOT %out = f32[4,4]{1,0} get-tuple-element(%w), index=1
}
"""
    m = HloCostModel(text)
    comps, entry = parse_module(text)
    assert entry == "main"
    t = m.total()
    dot_flops = 10 * 2 * 4 * 4 * 4          # 10 trips x dot(4x4x4)
    assert dot_flops <= t.flops <= dot_flops + 10 * 4  # + add/compare per trip
