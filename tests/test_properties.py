"""Hypothesis property tests on system invariants."""
import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed (pip install -r requirements-dev.txt)")

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core import (actual_sparsity, bsr_to_dense, dense_to_bsr,
                        group_prox, prune_to_sparsity, topk_block_mask)
from repro.core.bsr import row_ids_from_indptr
from repro.kernels import pack_bsr
from repro.kernels import ref as kref

_settings = dict(max_examples=25, deadline=None)


@st.composite
def _sparse_matrix(draw):
    bh = draw(st.sampled_from([1, 4, 8]))
    bw = draw(st.sampled_from([1, 8, 16]))
    nbr = draw(st.integers(1, 6))
    nbc = draw(st.integers(1, 6))
    seed = draw(st.integers(0, 2**31 - 1))
    density = draw(st.floats(0.0, 1.0))
    rng = np.random.RandomState(seed)
    w = rng.randn(nbr * bh, nbc * bw).astype(np.float32)
    mask = rng.rand(nbr, nbc) < density
    return w * np.kron(mask, np.ones((bh, bw), np.float32)), (bh, bw)


@given(_sparse_matrix())
@settings(**_settings)
def test_bsr_roundtrip(args):
    w, bs = args
    m = dense_to_bsr(w, bs)
    np.testing.assert_allclose(np.asarray(bsr_to_dense(m)), w)


@given(_sparse_matrix())
@settings(**_settings)
def test_row_ids_inverse_of_indptr(args):
    w, bs = args
    m = dense_to_bsr(w, bs)
    rows = np.asarray(row_ids_from_indptr(m.indptr, m.nnzb))
    indptr = np.asarray(m.indptr)
    for j, r in enumerate(rows):
        assert indptr[r] <= j < indptr[r + 1]


@given(_sparse_matrix(), st.integers(0, 2**31 - 1))
@settings(**_settings)
def test_gather_matmul_equals_dense(args, seed):
    w, bs = args
    m = dense_to_bsr(w, bs)
    rng = np.random.RandomState(seed)
    x = rng.randn(4, w.shape[1]).astype(np.float32)
    got = np.asarray(kref.bsr_matmul_gather(jnp.asarray(x), m))
    np.testing.assert_allclose(got, x @ w.T, rtol=1e-4, atol=1e-4)


@given(st.integers(0, 2**31 - 1), st.floats(0.05, 0.95))
@settings(**_settings)
def test_prune_sparsity_monotone_in_target(seed, s):
    rng = np.random.RandomState(seed)
    w = jnp.asarray(rng.randn(64, 64).astype(np.float32))
    lo, _ = prune_to_sparsity(w, (8, 8), s / 2)
    hi, _ = prune_to_sparsity(w, (8, 8), s)
    assert float(actual_sparsity(hi, (8, 8))) >= \
        float(actual_sparsity(lo, (8, 8))) - 1e-6
    # pruned support of hi is a subset of lo's zeros' complement
    lo_nz = np.asarray(lo) != 0
    hi_nz = np.asarray(hi) != 0
    assert np.all(lo_nz | ~hi_nz)


@given(st.integers(0, 2**31 - 1), st.floats(0.01, 2.0))
@settings(**_settings)
def test_group_prox_nonexpansive(seed, t):
    rng = np.random.RandomState(seed)
    w = jnp.asarray(rng.randn(32, 32).astype(np.float32))
    out = group_prox(w, (8, 8), t)
    assert float(jnp.linalg.norm(out)) <= float(jnp.linalg.norm(w)) + 1e-5


@given(_sparse_matrix())
@settings(max_examples=15, deadline=None)
def test_pack_covers_every_row_and_col(args):
    w, bs = args
    # pack at the same tile shape (pad shape to tile grid first)
    pk = pack_bsr(w, bs)
    rows = set(pk.row_id[: pk.nnzt].tolist())
    cols = set(pk.col_id.tolist())
    assert rows == set(range(pk.n_brows))
    assert cols.issuperset(set()) and all(c < pk.n_bcols for c in cols)
    # transpose pattern covers every block-col as a row
    t_rows = set(pk.t_row_id()[:-1].tolist())
    assert t_rows == set(range(pk.n_bcols))
