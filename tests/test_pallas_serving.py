"""Compiled Pallas serving kernels (interpret-mode parity, PR 8).

Two kernel families against their XLA references, both in interpret mode
(the correctness oracle off-TPU, the compiled path on TPU):

  * plan-consuming BSR matmul (kernels/bsr_matmul.plan_dds + the
    exec_plan.plan_linear_pallas custom_vjp): the RowPackPlan's spill
    schedule drives the Pallas grid, so the kernel streams row-grouped
    values with no per-call scatter. Parity vs plan_linear, fwd + bwd,
    including spill-schedule edge rows and fused-QKV packs;
  * split-K flash decode (kernels/flash_decode): online-softmax decode
    attention vs the materialized decode_attention reference across
    window/global configs and split factors, plus the paged variant --
    which must be BIT-exact vs the same flash kernel run over the
    paged_view dense reassembly (same split boundaries, same op order).

Plus: the autotune stub ranks the new candidates sanely, the
'plan_pallas' serving backend round-trips end to end, and the servable
decode-kernel switch ('xla' vs 'flash') preserves greedy tokens.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.bsr_matmul import pack_bsr
from repro.kernels.exec_plan import (PlanChoice, build_plan, pack_plan_data,
                                     plan_fused_linear, plan_kernel_sequence,
                                     plan_linear, plan_linear_pallas,
                                     unpack_plan_data)
from repro.kernels.flash_decode import (decode_kernel_override,
                                        default_kv_split, flash_decode,
                                        paged_flash_decode,
                                        resolved_decode_kernel)
from repro.models.attention import decode_attention
from repro.models.common import paged_view

RNG_SEED = 0


def _sparse_weight(rng, n, k, tile, density):
    w = rng.randn(n, k).astype(np.float32)
    mask = rng.rand(n // tile[0], k // tile[1]) < density
    return w * np.kron(mask, np.ones(tile, np.float32))


def _plan_pack(rng, n, k, tile, density, pad_tiles=0):
    w = _sparse_weight(rng, n, k, tile, density)
    pk = pack_bsr(w, tile)      # pack_bsr may force coverage tiles
    if pad_tiles:
        pk = pack_bsr(w, tile, nnzt=pk.real_nnzt + pad_tiles)
    plan = build_plan(pk)
    return w, plan, pack_plan_data(plan, pk.data)


# --------------------------------------------------------------------------
# plan-consuming Pallas BSR matmul vs plan_linear
# --------------------------------------------------------------------------

@pytest.mark.parametrize("density,pad_tiles", [(0.4, 0), (0.15, 0), (0.4, 5)])
def test_plan_pallas_matches_plan_fwd_bwd(density, pad_tiles):
    """Forward <= 1e-5 and relative grad parity vs plan_linear, including
    padded slots (whose grads must stay exactly zero)."""
    rng = np.random.RandomState(1)
    n, k, m, tile = 96, 128, 24, (16, 32)
    _, plan, data_rp = _plan_pack(rng, n, k, tile, density, pad_tiles)
    x = jnp.asarray(rng.randn(m, k).astype(np.float32))

    y_ref = plan_linear(x, data_rp, plan)
    y_pal = plan_linear_pallas(x, data_rp, plan)
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               atol=1e-5)

    loss = lambda fn: jax.grad(
        lambda x_, d_: jnp.sum(fn(x_, d_, plan) ** 2), argnums=(0, 1))
    gx_r, gd_r = loss(plan_linear)(x, data_rp)
    gx_p, gd_p = loss(plan_linear_pallas)(x, data_rp)
    np.testing.assert_allclose(np.asarray(gx_p), np.asarray(gx_r),
                               rtol=2e-5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gd_p), np.asarray(gd_r),
                               rtol=2e-5, atol=1e-4)
    # padding slots (slot_mask False) never receive gradient
    dead = ~np.asarray(plan.slot_mask)
    assert np.all(np.asarray(gd_p)[dead] == 0.0)


def test_plan_pallas_spill_schedule_edge_rows():
    """A deliberately skewed pattern (one hot row spilling over several
    vrows, some near-empty rows) exercises the write-on-row-change
    protocol across spill boundaries."""
    rng = np.random.RandomState(2)
    n, k, tile = 128, 1024, (16, 64)
    w = np.zeros((n, k), np.float32)
    # hot block row 0 owns every column tile; rows 1..7 one tile each on
    # the diagonal -- the skew the adaptive capacity heuristic spills
    w[:16, :] = rng.randn(16, k)
    for i in range(1, 8):
        w[16 * i: 16 * (i + 1), 64 * i: 64 * (i + 1)] = \
            0.1 * rng.randn(16, 64)
    pk = pack_bsr(w, tile)
    plan = build_plan(pk)
    assert plan.col_idx.shape[0] > n // tile[0], "pattern did not spill"
    seqs = plan_kernel_sequence(plan)
    rows = np.asarray(seqs[0][:-1])
    assert np.all(np.diff(rows) >= 0), "kernel visitation must be row-sorted"
    data_rp = pack_plan_data(plan, pk.data)
    x = jnp.asarray(rng.randn(20, k).astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(plan_linear_pallas(x, data_rp, plan)),
        np.asarray(plan_linear(x, data_rp, plan)), rtol=1e-5, atol=1e-4)


def test_plan_pallas_fused_qkv_pack():
    """Fused-QKV-shaped pack (three N segments concatenated) through the
    batched plan_matmul_pallas entry, leading dims preserved."""
    from repro.kernels.exec_plan import plan_matmul_pallas, plan_matmul
    rng = np.random.RandomState(3)
    k, tile = 64, (16, 16)
    segs = [_sparse_weight(rng, 48, k, tile, 0.5) for _ in range(3)]
    w = np.concatenate(segs, axis=0)              # (144, 64) fused
    pk = pack_bsr(w, tile)
    plan = build_plan(pk)
    data_rp = pack_plan_data(plan, pk.data)
    x = jnp.asarray(rng.randn(2, 5, k).astype(np.float32))
    y_ref = plan_matmul(x, data_rp, plan)
    y_pal = plan_matmul_pallas(x, data_rp, plan)
    assert y_pal.shape == (2, 5, 144)
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               atol=1e-5)


def test_plan_pallas_bias_act_epilogue():
    """The fused bias/activation epilogue matches applying them after the
    XLA plan path."""
    rng = np.random.RandomState(4)
    n, k, m, tile = 64, 96, 16, (16, 16)
    _, plan, data_rp = _plan_pack(rng, n, k, tile, 0.5)
    x = jnp.asarray(rng.randn(m, k).astype(np.float32))
    b = jnp.asarray(rng.randn(n).astype(np.float32))
    for act, fn in [("relu", jax.nn.relu), ("gelu", jax.nn.gelu),
                    ("silu", jax.nn.silu), (None, lambda v: v)]:
        y_ref = fn(plan_linear(x, data_rp, plan) + b)
        y_pal = plan_fused_linear(x, data_rp, plan, bias=b, act=act)
        np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                                   atol=1e-5, err_msg=str(act))


def test_plan_data_roundtrip_through_pallas_grad():
    """unpack_plan_data of the pallas ddata equals the packed-layout grads
    of the XLA path -- the two layouts stay interchangeable."""
    rng = np.random.RandomState(5)
    _, plan, data_rp = _plan_pack(rng, 64, 64, (16, 16), 0.5)
    x = jnp.asarray(rng.randn(8, 64).astype(np.float32))
    g = jax.grad(lambda d: jnp.sum(plan_linear_pallas(x, d, plan) ** 2))(
        data_rp)
    g_ref = jax.grad(lambda d: jnp.sum(plan_linear(x, d, plan) ** 2))(
        data_rp)
    np.testing.assert_allclose(np.asarray(unpack_plan_data(plan, g)),
                               np.asarray(unpack_plan_data(plan, g_ref)),
                               rtol=2e-5, atol=1e-4)


# --------------------------------------------------------------------------
# split-K flash decode vs decode_attention
# --------------------------------------------------------------------------

def _decode_case(rng, b, t, hq, hkv, d, ragged=True):
    q = jnp.asarray(rng.randn(b, 1, hq, d).astype(np.float32))
    k = jnp.asarray(rng.randn(b, t, hkv, d).astype(np.float32))
    v = jnp.asarray(rng.randn(b, t, hkv, d).astype(np.float32))
    kvp = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))
    if ragged:
        pos = jnp.asarray(rng.randint(0, t, size=b), jnp.int32)
        pos = pos.at[0].set(t - 1)
        if b > 1:
            pos = pos.at[1].set(-1)        # inactive slot
    else:
        pos = jnp.full((b,), t - 1, jnp.int32)
    return q, k, v, kvp, pos


@pytest.mark.parametrize("window", [0, 8])
@pytest.mark.parametrize("kv_split", [1, 2, 4])
def test_flash_decode_matches_xla(window, kv_split):
    rng = np.random.RandomState(6)
    b, t, hq, hkv, d = 3, 32, 8, 4, 16
    q, k, v, kvp, pos = _decode_case(rng, b, t, hq, hkv, d)
    out_ref = decode_attention(q, k, v, kvp, pos, window=window)
    out_fl = flash_decode(q, k, v, kvp, pos, window=window,
                          kv_split=kv_split)
    active = np.asarray(pos) >= 0
    np.testing.assert_allclose(np.asarray(out_fl)[active],
                               np.asarray(out_ref)[active], atol=1e-5)


def test_flash_decode_mha_and_scalar_pos():
    """hq == hkv (no grouping) and scalar pos / 1-D kv_positions inputs."""
    rng = np.random.RandomState(7)
    b, t, h, d = 2, 24, 4, 8
    q = jnp.asarray(rng.randn(b, 1, h, d).astype(np.float32))
    k = jnp.asarray(rng.randn(b, t, h, d).astype(np.float32))
    v = jnp.asarray(rng.randn(b, t, h, d).astype(np.float32))
    kvp = jnp.arange(t, dtype=jnp.int32)             # shared 1-D map
    out_ref = decode_attention(q, k, v, kvp, t - 1)
    out_fl = flash_decode(q, k, v, kvp, t - 1)
    np.testing.assert_allclose(np.asarray(out_fl), np.asarray(out_ref),
                               atol=1e-5)


def test_paged_flash_decode_bit_exact_vs_dense_view():
    """The paged kernel gathers KV pages in place; over the same page
    geometry it must be BIT-exact vs the flash kernel run on the
    paged_view dense reassembly with matching split boundaries."""
    rng = np.random.RandomState(8)
    b, npg, ps, hkv, hq, d = 2, 4, 8, 2, 4, 16
    n_pages = b * npg + 3
    kp = jnp.asarray(rng.randn(n_pages, ps, hkv, d).astype(np.float32))
    vp = jnp.asarray(rng.randn(n_pages, ps, hkv, d).astype(np.float32))
    # slot 0: 3 mapped pages + 1 hole; slot 1: 2 mapped pages
    table = jnp.asarray([[2, 5, 7, -1], [1, 9, -1, -1]], jnp.int32)
    pos_map = np.full((b, npg * ps), -1, np.int32)
    pos_map[0, : 3 * ps] = np.arange(3 * ps)
    pos_map[1, : 2 * ps] = np.arange(2 * ps)
    pos_map = jnp.asarray(pos_map)
    pos = jnp.asarray([3 * ps - 1, ps + 3], jnp.int32)
    q = jnp.asarray(rng.randn(b, 1, hq, d).astype(np.float32))

    out_paged = paged_flash_decode(q, kp, vp, table, pos_map, pos)
    k_view = paged_view(kp, table, pos_map)
    v_view = paged_view(vp, table, pos_map)
    out_view = flash_decode(q, k_view, v_view, pos_map, pos, kv_split=npg)
    assert np.array_equal(np.asarray(out_paged), np.asarray(out_view)), \
        "paged flash decode must be bit-exact vs the dense-view flash path"
    # and allclose vs the XLA reference over the same view
    out_ref = decode_attention(q, k_view, v_view, pos_map, pos)
    np.testing.assert_allclose(np.asarray(out_paged), np.asarray(out_ref),
                               atol=1e-5)


def test_paged_flash_decode_ignores_stale_pages():
    """Garbage in unmapped/recycled pages never leaks: only pos_map decides
    visibility."""
    rng = np.random.RandomState(9)
    b, npg, ps, hkv, hq, d = 1, 2, 4, 2, 2, 8
    kp = jnp.asarray(rng.randn(6, ps, hkv, d).astype(np.float32))
    vp = jnp.asarray(rng.randn(6, ps, hkv, d).astype(np.float32))
    table = jnp.asarray([[3, -1]], jnp.int32)
    pos_map = np.full((b, npg * ps), -1, np.int32)
    pos_map[0, :ps] = np.arange(ps)
    pos_map = jnp.asarray(pos_map)
    pos = jnp.asarray([ps - 1], jnp.int32)
    q = jnp.asarray(rng.randn(b, 1, hq, d).astype(np.float32))
    base = paged_flash_decode(q, kp, vp, table, pos_map, pos)
    # poison every page except the mapped one
    kp2 = kp.at[0].set(1e6).at[1].set(1e6).at[2].set(1e6).at[4].set(1e6)
    vp2 = vp.at[0].set(1e6).at[5].set(-1e6)
    out = paged_flash_decode(q, kp2, vp2, table, pos_map, pos)
    assert np.array_equal(np.asarray(base), np.asarray(out))


def test_default_kv_split_and_override():
    assert default_kv_split(64) == 1
    assert default_kv_split(512) == 4
    assert default_kv_split(4096) == 8
    assert resolved_decode_kernel() in ("xla", "flash")
    with decode_kernel_override("flash"):
        assert resolved_decode_kernel() == "flash"
        with decode_kernel_override("xla"):
            assert resolved_decode_kernel() == "xla"   # innermost wins
    prev = os.environ.get("REPRO_DECODE_KERNEL")
    try:
        os.environ["REPRO_DECODE_KERNEL"] = "flash"
        assert resolved_decode_kernel() == "flash"
    finally:
        if prev is None:
            os.environ.pop("REPRO_DECODE_KERNEL", None)
        else:
            os.environ["REPRO_DECODE_KERNEL"] = prev


# --------------------------------------------------------------------------
# autotune integration
# --------------------------------------------------------------------------

def test_autotune_stub_ranks_new_candidates():
    from repro.kernels.autotune import (CANDIDATES, DECODE_CANDIDATES,
                                        INTERPRET_ONLY, decode_stub_costs,
                                        stub_costs)
    rng = np.random.RandomState(10)
    w = _sparse_weight(rng, 64, 64, (16, 16), 0.5)
    pk = pack_bsr(w, (16, 16))
    costs = stub_costs(pk, 128, CANDIDATES)
    assert set(costs) == set(CANDIDATES)
    # plan_pallas skips padded-slot work: strictly cheaper than the
    # flat-stream pallas kernel in the proxy
    assert costs["plan_pallas"] < costs["pallas"]
    if jax.default_backend() != "tpu":
        assert min(costs, key=costs.get) not in INTERPRET_ONLY
    dc = decode_stub_costs(b=4, t=256, hq=8, hkv=4, d=64, kv_split=2)
    assert set(dc) == set(DECODE_CANDIDATES)
    if jax.default_backend() != "tpu":
        assert min(dc, key=dc.get) == "xla"


def test_choose_decode_kernel_stub(tmp_path, monkeypatch):
    from repro.kernels.autotune import AutotuneCache, choose_decode_kernel
    cache = AutotuneCache(str(tmp_path / "at.json"))
    c = choose_decode_kernel(b=4, t=128, hq=4, hkv=2, d=16, stub=True,
                             cache=cache)
    assert c.backend in ("xla", "flash")
    assert not c.cache_hit and c.mode == "stub"
    c2 = choose_decode_kernel(b=4, t=128, hq=4, hkv=2, d=16, stub=True,
                              cache=cache)
    assert c2.cache_hit and c2.backend == c.backend
    if jax.default_backend() != "tpu":
        assert c.backend == "xla"
    # frozen timer exercises the wall-clock branch deterministically
    timer = lambda name, fn, args: {"xla": 2.0, "flash": 1.0}[name]
    c3 = choose_decode_kernel(b=2, t=32, hq=4, hkv=2, d=8, stub=False,
                              cache=cache, timer=timer)
    assert c3.backend == "flash" and c3.mode == "wallclock"
    # attention-free shapes (pure-SSM configs have n_kv_heads=0) must be
    # rejected up front, not ZeroDivide inside the measurement
    with pytest.raises(ValueError, match="attention-free"):
        choose_decode_kernel(b=2, t=32, hq=0, hkv=0, d=0, cache=cache)


# --------------------------------------------------------------------------
# serving integration (slow: full prepare_servable pipelines)
# --------------------------------------------------------------------------

_ATTN = ("attn/wq", "attn/wk", "attn/wv", "attn/wo")


def _smoke_setup():
    from repro.configs.registry import get_config
    from repro.models import api as model_api
    cfg = get_config("gemma3_4b", smoke=True)
    params = model_api.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.mark.slow
def test_plan_pallas_backend_end_to_end(tmp_path):
    """ServingSpec(backend='plan_pallas') forward-parity vs 'plan' and
    save/load round-trip (packs rebuilt as PlanChoice)."""
    from repro.serving.servable import load_servable, prepare_servable
    from repro.serving.spec import ServingSpec
    cfg, params = _smoke_setup()
    rng = np.random.RandomState(11)
    batch = {"tokens": jnp.asarray(
        rng.randint(0, cfg.vocab_size, (2, 12)))}
    mk = lambda backend: ServingSpec(tile=(16, 16), sparsity=0.5,
                                     prune="oneshot", targets=_ATTN,
                                     backend=backend)
    sv_plan = prepare_servable(params, cfg, mk("plan"))
    sv_pp = prepare_servable(params, cfg, mk("plan_pallas"))
    assert all(isinstance(pk, PlanChoice) for pk in sv_pp.packs.values())
    y_plan = np.asarray(sv_plan.forward(batch))
    y_pp = np.asarray(sv_pp.forward(batch))
    np.testing.assert_allclose(y_pp, y_plan, atol=1e-4)

    path = str(tmp_path / "sv")
    sv_pp.save(path)
    sv2 = load_servable(path)
    assert sv2.spec.backend == "plan_pallas"
    assert all(isinstance(pk, PlanChoice) for pk in sv2.packs.values())
    np.testing.assert_array_equal(np.asarray(sv2.forward(batch)), y_pp)


@pytest.mark.slow
def test_servable_decode_kernel_flash_parity():
    """decode_kernel='flash' vs 'xla' servables agree on logits (allclose)
    and on greedy tokens over a short decode."""
    from repro.serving.servable import prepare_servable
    from repro.serving.spec import ServingSpec
    cfg, params = _smoke_setup()
    mk = lambda dk: ServingSpec(tile=(16, 16), sparsity=0.5,
                                prune="oneshot", targets=_ATTN,
                                backend="plan", decode_kernel=dk)
    sv_x = prepare_servable(params, cfg, mk("xla"))
    sv_f = prepare_servable(params, cfg, mk("flash"))
    assert sv_x.decode_kernel_kind() == "xla"
    assert sv_f.decode_kernel_kind() == "flash"
    cx = sv_x.init_cache(2, 32)
    cf = sv_f.init_cache(2, 32)
    tx = tf = jnp.asarray([[3], [7]], jnp.int32)
    for step in range(4):
        p = jnp.full((2,), step, jnp.int32)
        lx, cx = sv_x.decode_step(cx, tx, p)
        lf, cf = sv_f.decode_step(cf, tf, p)
        np.testing.assert_allclose(np.asarray(lf), np.asarray(lx),
                                   atol=1e-4)
        tx = jnp.argmax(lx[:, 0], -1)[:, None].astype(jnp.int32)
        tf = jnp.argmax(lf[:, 0], -1)[:, None].astype(jnp.int32)
        np.testing.assert_array_equal(np.asarray(tx), np.asarray(tf))


@pytest.mark.slow
def test_env_override_wins_over_spec(monkeypatch):
    from repro.serving.servable import prepare_servable
    from repro.serving.spec import ServingSpec
    cfg, params = _smoke_setup()
    monkeypatch.setenv("REPRO_DECODE_KERNEL", "xla")
    sv = prepare_servable(params, cfg, ServingSpec(
        tile=(16, 16), sparsity=0.5, prune="oneshot", targets=_ATTN,
        backend="plan", decode_kernel="flash"))
    assert sv.decode_kernel_kind() == "xla"
