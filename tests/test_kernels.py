"""Per-kernel correctness: shape/dtype sweeps vs the pure-jnp oracle
(interpret=True executes the Pallas kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bsr import dense_to_bsr
from repro.kernels import (bsr_linear, dds, dds_t, masked_matmul, pack_bsr,
                           sddmm)
from repro.kernels import ref as kref


def _sparse_weight(rng, n, k, tile, density):
    w = rng.randn(n, k).astype(np.float32)
    mask = rng.rand(n // tile[0], k // tile[1]) < density
    return w * np.kron(mask, np.ones(tile, np.float32)), mask


SHAPES = [
    # (M, N, K, tile, density, bm)
    (32, 128, 128, (32, 64), 0.4, 16),
    (64, 256, 128, (64, 128), 0.25, 32),
    (100, 128, 384, (32, 128), 0.5, 32),     # M not tile-aligned
    (16, 512, 256, (128, 128), 0.1, 16),     # very sparse
    (8, 64, 64, (64, 64), 1.0, 8),           # fully dense pattern
]


@pytest.mark.parametrize("m,n,k,tile,density,bm", SHAPES)
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_dds_matches_ref(m, n, k, tile, density, bm, dtype):
    rng = np.random.RandomState(0)
    wd, _ = _sparse_weight(rng, n, k, tile, density)
    x = rng.randn(m, k).astype(np.float32)
    pk = pack_bsr(wd, tile)
    xj = jnp.asarray(x, dtype=dtype)
    pk_t = pack_bsr(wd.astype(np.float32), tile)
    pk_t = pk_t.__class__(pk_t.data.astype(dtype), pk_t.row_id, pk_t.col_id,
                          pk_t.t_perm, pk_t.real_nnzt, pk_t.shape, pk_t.tile)
    y = dds(xj, pk_t, bm=bm)
    ref = x @ wd.T
    tol = 1e-3 if dtype == np.float32 else 2e-1
    np.testing.assert_allclose(np.asarray(y, np.float32), ref,
                               atol=tol * max(1.0, np.abs(ref).max()))


@pytest.mark.parametrize("m,n,k,tile,density,bm", SHAPES)
def test_dds_t_matches_ref(m, n, k, tile, density, bm):
    rng = np.random.RandomState(1)
    wd, _ = _sparse_weight(rng, n, k, tile, density)
    dy = rng.randn(m, n).astype(np.float32)
    pk = pack_bsr(wd, tile)
    dx = dds_t(jnp.asarray(dy), pk, bm=bm)
    np.testing.assert_allclose(np.asarray(dx), dy @ wd, atol=1e-3)


@pytest.mark.parametrize("m,n,k,tile,density,bm", SHAPES[:3])
def test_sddmm_matches_ref(m, n, k, tile, density, bm):
    rng = np.random.RandomState(2)
    wd, _ = _sparse_weight(rng, n, k, tile, density)
    dy = rng.randn(m, n).astype(np.float32)
    x = rng.randn(m, k).astype(np.float32)
    pk = pack_bsr(wd, tile)
    g = sddmm(jnp.asarray(dy), jnp.asarray(x), pk, bm=bm)
    core = dense_to_bsr(wd, tile)
    # compare via densified gradients (handles block-order differences)
    from repro.core.bsr import BSR, bsr_to_dense, row_ids_from_indptr
    dense_ref = (dy.T @ x)
    tile_mask = np.kron(
        np.any(wd.reshape(n // tile[0], tile[0], k // tile[1], tile[1]) != 0,
               axis=(1, 3)), np.ones(tile, bool))
    # rebuild dense from kernel output
    got = np.zeros((n, k), np.float32)
    rows = pk.row_id[: pk.nnzt]
    cols = pk.col_id
    for j in range(pk.real_nnzt):
        r, c = rows[j], cols[j]
        got[r * tile[0]:(r + 1) * tile[0],
            c * tile[1]:(c + 1) * tile[1]] = np.asarray(g[j])
    np.testing.assert_allclose(got[tile_mask].ravel(),
                               dense_ref[tile_mask].ravel(), rtol=1e-3,
                               atol=1e-2)


@pytest.mark.parametrize("m,n,k,tile,density,bm", SHAPES[:4])
def test_masked_matmul(m, n, k, tile, density, bm):
    rng = np.random.RandomState(3)
    wd, mask = _sparse_weight(rng, n, k, tile, density)
    x = rng.randn(m, k).astype(np.float32)
    y = masked_matmul(jnp.asarray(x), jnp.asarray(wd), jnp.asarray(mask),
                      tile=tile, bm=bm)
    np.testing.assert_allclose(np.asarray(y), x @ wd.T, atol=1e-3)


@pytest.mark.parametrize("backend", ["gather", "ref", "pallas"])
def test_bsr_linear_grads(backend):
    rng = np.random.RandomState(4)
    n, k, m, tile = 128, 256, 32, (64, 128)
    wd, _ = _sparse_weight(rng, n, k, tile, 0.5)
    x = jnp.asarray(rng.randn(m, k).astype(np.float32))
    pk = pack_bsr(wd, tile)

    def loss(x_, d_):
        return jnp.sum(bsr_linear(x_, d_, pk, backend) ** 2)

    gx, gd = jax.grad(loss, argnums=(0, 1))(x, pk.data)
    gx_ref = jax.grad(lambda x_: jnp.sum((x_ @ jnp.asarray(wd).T) ** 2))(x)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(gx_ref), rtol=1e-3,
                               atol=1e-2)
    # gradient w.r.t. padding blocks must be exactly zero
    pad = ~np.asarray(pk.pad_mask())
    if pad.any():
        assert float(jnp.abs(gd[jnp.asarray(pad)]).max()) == 0.0


def test_gather_path_flops_scale_with_density():
    """The sparse-compute path must do less work at higher sparsity
    (counted via jaxpr dot shapes)."""
    rng = np.random.RandomState(5)
    n = k = m = 256
    tile = (64, 64)
    outs = {}
    for density in (1.0, 0.25):
        wd, _ = _sparse_weight(rng, n, k, tile, density)
        core = dense_to_bsr(wd, tile)
        outs[density] = core.nnzb
    assert outs[0.25] < outs[1.0] * 0.5
