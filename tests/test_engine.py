"""Continuous-batching engine: batched ragged-slot decode equals sequential
per-request decode for every decode-capable mixer family (attention, MLA,
SSM, RG-LRU hybrid, enc-dec audio), slot recycling hygiene (a freed slot
serves the next request exactly like a fresh cache), and the ragged-pos
per-row causal/window mask semantics underneath it all."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LayerKind, ModelConfig
from repro.configs.registry import get_config
from repro.models import api as model_api
from repro.models import init_model
from repro.models.attention import decode_attention, full_attention
from repro.serving import ServingSpec, prepare_servable

RNG = np.random.RandomState(0)

ATTN_TARGETS = ("attn/wq", "attn/wk", "attn/wv", "attn/wo")


def _mla_dense_cfg():
    """MLA mixer + dense FFN: isolates the absorbed-latent decode path from
    MoE's batch-composition-dependent capacity drops."""
    return ModelConfig(
        arch="mla-dense-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=512,
        kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
        pattern=(LayerKind("mla", "dense"),), dtype="float32")


def _servable(cfg, seed=1, sparsity=0.5):
    params = init_model(jax.random.PRNGKey(seed), cfg)
    return prepare_servable(params, cfg, ServingSpec(
        tile=(16, 16), sparsity=sparsity, prune="oneshot",
        targets=ATTN_TARGETS))


def _sequential(servable, prompt, max_new, cache_len, frames=None):
    """B=1 reference: per-request prefill through the decode path, then
    greedy generation -- what the engine must reproduce under batching."""
    cache = servable.init_cache(1, cache_len, frames=frames)
    logits = None
    for t, tok in enumerate(prompt):
        logits, cache = servable.decode_step(
            cache, jnp.asarray([[tok]], jnp.int32), jnp.int32(t))
    toks, logs = [], []
    pos = len(prompt)
    cur = int(np.argmax(np.asarray(logits[0, 0])))
    toks.append(cur)
    logs.append(np.asarray(logits[0, 0], np.float32))
    while len(toks) < max_new:
        logits, cache = servable.decode_step(
            cache, jnp.asarray([[cur]], jnp.int32), jnp.int32(pos))
        pos += 1
        cur = int(np.argmax(np.asarray(logits[0, 0])))
        toks.append(cur)
        logs.append(np.asarray(logits[0, 0], np.float32))
    return toks, logs


# --------------------------------------------------------------------------
# ragged-pos mask semantics (the primitive under the engine)
# --------------------------------------------------------------------------

def test_ragged_pos_masks_match_per_row_reference():
    """decode_attention with a (B,T) pos_map + (B,) pos == per-row full
    attention at each row's own position (causal AND windowed)."""
    b, s, hq, hkv, d = 3, 24, 2, 1, 16
    q_all = RNG.randn(b, s, hq, d).astype(np.float32)
    k_all = RNG.randn(b, s, hkv, d).astype(np.float32)
    v_all = RNG.randn(b, s, hkv, d).astype(np.float32)
    pos = np.array([5, 17, 11], np.int32)       # ragged per-slot positions
    for window in (0, 8):
        t = s
        kc = jnp.asarray(k_all)
        vc = jnp.asarray(v_all)
        pm = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
        q = jnp.asarray(q_all[np.arange(b), pos])[:, None]
        got = decode_attention(q, kc, vc, pm, jnp.asarray(pos), window=window)
        for i in range(b):
            ref = full_attention(jnp.asarray(q_all[i:i + 1, pos[i]:pos[i] + 1]),
                                 jnp.asarray(k_all[i:i + 1, :pos[i] + 1]),
                                 jnp.asarray(v_all[i:i + 1, :pos[i] + 1]),
                                 causal=True, window=window,
                                 q_offset=int(pos[i]))
            np.testing.assert_allclose(np.asarray(got[i]), np.asarray(ref[0]),
                                       atol=1e-5,
                                       err_msg=f"row={i} window={window}")


def test_inactive_rows_leave_cache_untouched():
    """pos = -1 rows (free slots / prefill padding) must not write KV or
    advance recurrent state, for every mixer kind."""
    cfg = get_config("recurrentgemma_9b", smoke=True)   # rglru + local attn
    params = init_model(jax.random.PRNGKey(0), cfg)
    cache = model_api.init_cache(params, cfg, 2, 32)
    tok = jnp.asarray(RNG.randint(0, cfg.vocab_size, (2, 1)))
    # row 0 active at pos 0, row 1 inactive
    _, cache1 = model_api.decode_step(params, cache, cfg, tok,
                                      jnp.asarray([0, -1], jnp.int32))
    row1_before = model_api.read_slot(cache, cfg, 1)
    row1_after = model_api.read_slot(cache1, cfg, 1)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        row1_before, row1_after)
    # ...and the active row did write something
    row0_delta = jax.tree_util.tree_reduce(
        lambda acc, x: acc + float(jnp.abs(x.astype(jnp.float32)).sum()),
        jax.tree_util.tree_map(
            lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32),
            model_api.read_slot(cache1, cfg, 0),
            model_api.read_slot(cache, cfg, 0)), 0.0)
    assert row0_delta > 0


def test_scalar_pos_broadcast_back_compat():
    """The single-request convention (scalar pos) still decodes exactly."""
    cfg = get_config("deepseek_7b", smoke=True)
    params = init_model(jax.random.PRNGKey(1), cfg)
    b, s = 2, 16
    toks = RNG.randint(0, cfg.vocab_size, (b, s))
    fwd, _ = model_api.model_forward(params, cfg,
                                     {"tokens": jnp.asarray(toks)})
    c_scalar = model_api.init_cache(params, cfg, b, s)
    c_vector = model_api.init_cache(params, cfg, b, s)
    for t in range(s):
        tok = jnp.asarray(toks[:, t:t + 1])
        lg_s, c_scalar = model_api.decode_step(params, c_scalar, cfg, tok,
                                               jnp.int32(t))
        lg_v, c_vector = model_api.decode_step(
            params, c_vector, cfg, tok, jnp.full((b,), t, jnp.int32))
        np.testing.assert_array_equal(np.asarray(lg_s), np.asarray(lg_v))
        np.testing.assert_allclose(np.asarray(lg_s[:, 0]),
                                   np.asarray(fwd[:, t]), atol=2e-5)


@pytest.mark.parametrize("arch", ["deepseek_7b", "mamba2_780m",
                                  "recurrentgemma_9b"])
def test_one_pass_prefill_matches_sequential(arch):
    """prefill_cache (one forward pass, bulk cache writes, bucket padding,
    ring wrap for windowed layers) == token-by-token decode prefill."""
    cfg = get_config(arch, smoke=True)
    params = init_model(jax.random.PRNGKey(2), cfg)
    L, bucket, cache_len = 41, 64, 64       # > the 32-token smoke windows
    toks = RNG.randint(0, cfg.vocab_size, (1, L))
    padded = np.zeros((1, bucket), np.int64)
    padded[:, :L] = toks
    cache_ref = model_api.init_cache(params, cfg, 1, cache_len)
    for t in range(L):
        lg_ref, cache_ref = model_api.decode_step(
            params, cache_ref, cfg, jnp.asarray(toks[:, t:t + 1]),
            jnp.int32(t))
    cache_pf = model_api.init_cache(params, cfg, 1, cache_len)
    lg_pf, cache_pf = model_api.prefill_cache(
        params, cache_pf, cfg, jnp.asarray(padded), jnp.int32(L))
    np.testing.assert_allclose(np.asarray(lg_pf[:, L - 1]),
                               np.asarray(lg_ref[:, 0]), atol=1e-5)
    # both caches must continue identically
    cur = int(np.argmax(np.asarray(lg_ref[0, 0])))
    for t in range(L, L + 6):
        tok = jnp.asarray([[cur]], jnp.int32)
        lg_r, cache_ref = model_api.decode_step(params, cache_ref, cfg, tok,
                                                jnp.int32(t))
        lg_p, cache_pf = model_api.decode_step(params, cache_pf, cfg, tok,
                                               jnp.int32(t))
        np.testing.assert_allclose(np.asarray(lg_p), np.asarray(lg_r),
                                   atol=1e-5)
        cur = int(np.argmax(np.asarray(lg_r[0, 0])))


# --------------------------------------------------------------------------
# engine batched decode == sequential per-request decode, per family
# --------------------------------------------------------------------------

@pytest.mark.parametrize("arch,mixer", [
    ("deepseek_7b", "attention"),
    ("mamba2_780m", "ssm"),
    ("recurrentgemma_9b", "rglru+local"),
])
def test_engine_matches_sequential(arch, mixer):
    cfg = get_config(arch, smoke=True)
    servable = _servable(cfg)
    prompts = [RNG.randint(0, cfg.vocab_size, (L,)).tolist()
               for L in (3, 11, 7, 5)]          # mixed lengths, all co-active
    eng = servable.engine(max_slots=4, cache_len=64, collect_logits=True)
    handles = [eng.submit(p, max_new_tokens=6) for p in prompts]
    eng.run()
    for h, p in zip(handles, prompts):
        want_toks, want_logs = _sequential(servable, p, 6, 64)
        assert h.done and h.tokens == want_toks, mixer
        for got, want in zip(h.step_logits, want_logs):
            np.testing.assert_allclose(got, want, atol=1e-5)


def test_engine_matches_sequential_mla():
    cfg = _mla_dense_cfg()
    servable = _servable(cfg)
    prompts = [RNG.randint(0, cfg.vocab_size, (L,)).tolist()
               for L in (4, 9, 13)]
    eng = servable.engine(max_slots=3, cache_len=64, collect_logits=True)
    handles = [eng.submit(p, max_new_tokens=5) for p in prompts]
    eng.run()
    for h, p in zip(handles, prompts):
        want_toks, want_logs = _sequential(servable, p, 5, 64)
        assert h.tokens == want_toks
        for got, want in zip(h.step_logits, want_logs):
            np.testing.assert_allclose(got, want, atol=1e-5)


def test_engine_matches_sequential_moe_high_capacity():
    """MoE routes over the whole batch, so parity needs drop-free capacity
    (the engine's documented caveat); with headroom, routing is per-token
    and batched == sequential."""
    cfg = dataclasses.replace(get_config("deepseek_v2_lite_16b", smoke=True),
                              capacity_factor=64.0)
    servable = _servable(cfg)
    prompts = [RNG.randint(0, cfg.vocab_size, (L,)).tolist() for L in (3, 8)]
    eng = servable.engine(max_slots=2, cache_len=32, collect_logits=True)
    handles = [eng.submit(p, max_new_tokens=4) for p in prompts]
    eng.run()
    for h, p in zip(handles, prompts):
        want_toks, want_logs = _sequential(servable, p, 4, 32)
        assert h.tokens == want_toks
        for got, want in zip(h.step_logits, want_logs):
            np.testing.assert_allclose(got, want, atol=1e-5)


def test_engine_matches_sequential_audio():
    cfg = get_config("whisper_base", smoke=True)
    params = init_model(jax.random.PRNGKey(3), cfg)
    servable = prepare_servable(params, cfg, ServingSpec(tile=(16, 16)))
    frames = [RNG.randn(cfg.n_audio_ctx, cfg.d_model).astype(np.float32)
              for _ in range(3)]
    prompts = [RNG.randint(0, cfg.vocab_size, (L,)).tolist()
               for L in (2, 6, 4)]
    eng = servable.engine(max_slots=3, cache_len=32, collect_logits=True)
    handles = [eng.submit(p, max_new_tokens=4, frames=f)
               for p, f in zip(prompts, frames)]
    eng.run()
    for h, p, f in zip(handles, prompts, frames):
        want_toks, want_logs = _sequential(servable, p, 4, 32,
                                           frames=jnp.asarray(f)[None])
        assert h.tokens == want_toks
        for got, want in zip(h.step_logits, want_logs):
            np.testing.assert_allclose(got, want, atol=1e-5)


# --------------------------------------------------------------------------
# slot lifecycle
# --------------------------------------------------------------------------

def test_slot_recycling_is_hygienic():
    """More requests than slots: recycled slots must serve their second
    request exactly like a fresh engine would (no state leak)."""
    cfg = get_config("recurrentgemma_9b", smoke=True)
    servable = _servable(cfg)
    prompts = [RNG.randint(0, cfg.vocab_size, (L,)).tolist()
               for L in (3, 9, 5, 12, 4, 7)]
    eng = servable.engine(max_slots=2, cache_len=64, collect_logits=True)
    handles = [eng.submit(p, max_new_tokens=5) for p in prompts]
    eng.run()
    assert all(h.done for h in handles)
    for h, p in zip(handles, prompts):
        want_toks, want_logs = _sequential(servable, p, 5, 64)
        assert h.tokens == want_toks
        for got, want in zip(h.step_logits, want_logs):
            np.testing.assert_allclose(got, want, atol=1e-5)
    assert eng.stats.completed == len(prompts)
    assert eng.stats.prefills == len(prompts)


def test_freed_slot_equals_fresh_cache():
    """free_slot zeroes attention KV AND recurrent state: slot state after
    free == slot state of a never-used cache."""
    cfg = get_config("recurrentgemma_9b", smoke=True)
    params = init_model(jax.random.PRNGKey(4), cfg)
    cache = model_api.init_cache(params, cfg, 3, 32)
    fresh = model_api.init_cache(params, cfg, 3, 32)
    tok = jnp.asarray(RNG.randint(0, cfg.vocab_size, (3, 1)))
    for t in range(4):
        _, cache = model_api.decode_step(params, cache, cfg, tok,
                                         jnp.full((3,), t, jnp.int32))
    cache = model_api.free_slot(cache, cfg, 1)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        model_api.read_slot(cache, cfg, 1),
        model_api.read_slot(fresh, cfg, 1))


def test_engine_callbacks_and_eos():
    cfg = get_config("deepseek_7b", smoke=True)
    servable = _servable(cfg)
    seen = []
    done = []
    eng = servable.engine(max_slots=2, cache_len=64)
    h = eng.submit(RNG.randint(0, cfg.vocab_size, (4,)).tolist(),
                   max_new_tokens=8,
                   on_token=lambda rid, tok: seen.append((rid, tok)),
                   on_done=lambda rid, toks: done.append((rid, toks)))
    eng.run()
    assert [t for _, t in seen] == h.tokens
    assert done == [(h.req_id, h.tokens)]
    # eos stops early: replay with eos set to the first emitted token
    eng2 = servable.engine(max_slots=2, cache_len=64)
    h2 = eng2.submit(list(h.prompt), max_new_tokens=8, eos_id=h.tokens[0])
    eng2.run()
    assert h2.tokens == h.tokens[:1]


def test_engine_rejects_bad_requests():
    """Invalid requests are rejected AT SUBMISSION with a structured
    FailureReason (never a late prefill/decode crash); submit() does not
    raise for request-level problems."""
    cfg = get_config("deepseek_7b", smoke=True)
    servable = _servable(cfg)
    eng = servable.engine(max_slots=1, cache_len=16)
    h = eng.submit([], max_new_tokens=4)
    assert h.status == "failed" and not h.done
    assert h.failure.code == "rejected" and "empty" in h.failure.message
    h = eng.submit([1, 2, 3], max_new_tokens=16)    # overflows cache_len
    assert h.status == "failed"
    assert h.failure.code == "rejected"
    assert "cache_len" in h.failure.message
    h = eng.submit([1, 2, 3], max_new_tokens=0)
    assert h.failure.code == "rejected"
    assert eng.stats.rejected == 3 and eng.stats.failed == 3
    # rejected handles still drain through run() (queue conservation) and
    # a valid follow-up request is unaffected
    ok = eng.submit([1, 2, 3], max_new_tokens=4)
    done = eng.run()
    assert {r.req_id for r in done} == {0, 1, 2, ok.req_id}
    assert ok.done and len(ok.tokens) == 4
    bert = get_config("bert_base", smoke=True)
    bert_servable = prepare_servable(init_model(jax.random.PRNGKey(0), bert),
                                     bert, ServingSpec(tile=(16, 16)))
    with pytest.raises(ValueError):
        bert_servable.engine(max_slots=2)


def test_registry_thread_safety():
    """Concurrent admissions share one plan build per pattern (satellite:
    lock around PatternRegistry lookup/insert)."""
    import threading
    from repro.core.pattern_reuse import PatternRegistry

    reg = PatternRegistry()
    built = []

    def builder():
        built.append(1)
        return object()

    def worker():
        for _ in range(200):
            reg.cached("k", builder)

    threads = [threading.Thread(target=worker) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(built) == 1
    assert reg.stats.misses == 1
    assert reg.stats.hits == 8 * 200 - 1
