"""Autotuned backend selection (kernels/autotune.py, backend='auto'):
frozen-timer argmin + persistence, stub-mode determinism, cache-key
separation, spec-level wiring with per-group stats, and serialization of
the choice/masked pack kinds."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import get_config
from repro.core.sparsity import prune_to_sparsity
from repro.kernels import autotune
from repro.kernels.autotune import (AutotuneCache, BackendChoice, MaskedPack,
                                    choose_backend, dense_from_pack,
                                    masked_pack_from, stub_costs)
from repro.kernels.bsr_matmul import pack_bsr
from repro.models import init_model
from repro.serving import ServingSpec, load_servable, prepare_servable
from repro.serving.serialize import (packs_from_arrays, packs_to_arrays,
                                     pattern_key)

RNG = np.random.RandomState(0)

ATTN_TARGETS = ("attn/wq", "attn/wk", "attn/wv", "attn/wo")


def _pack(n=64, k=48, tile=(16, 16), sparsity=0.5, seed=0):
    rng = np.random.RandomState(seed)
    w = jnp.asarray(rng.randn(n, k).astype(np.float32))
    pruned, _ = prune_to_sparsity(w, tile, sparsity)
    return pack_bsr(np.asarray(pruned), tile)


# --------------------------------------------------------------------------
# chooser mechanics
# --------------------------------------------------------------------------

def test_frozen_timer_picks_argmin_and_persists(tmp_path):
    """With an injected frozen clock, the chooser is exact argmin; the
    winner is persisted and a FRESH cache instance over the same file
    (a stand-in for a second process) answers from disk."""
    pk = _pack()
    frozen = {"dense": 5.0, "gather": 3.0, "rowpack": 4.0, "plan": 1.0}
    cache = AutotuneCache(str(tmp_path / "at.json"))
    c = choose_backend(pk, m=32, candidates=tuple(frozen), cache=cache,
                       stub=False, timer=lambda name, fn, args: frozen[name])
    assert c.backend == "plan" and not c.cache_hit
    assert cache.stats.misses == 1

    cache2 = AutotuneCache(str(tmp_path / "at.json"))    # "new process"
    c2 = choose_backend(pk, m=32, candidates=tuple(frozen), cache=cache2,
                        stub=False,
                        timer=lambda name, fn, args: 1.0 / 0.0)  # never runs
    assert c2.backend == "plan" and c2.cache_hit
    assert cache2.stats.hits == 1


def test_cache_key_separates_pattern_m_and_mode(tmp_path):
    cache = AutotuneCache(str(tmp_path / "at.json"))
    pk1, pk2 = _pack(seed=0), _pack(seed=1)
    t = lambda name, fn, args: {"dense": 1.0, "plan": 2.0}[name]
    a = choose_backend(pk1, m=32, candidates=("dense", "plan"), cache=cache,
                       stub=False, timer=t)
    b = choose_backend(pk2, m=32, candidates=("dense", "plan"), cache=cache,
                       stub=False, timer=t)
    c = choose_backend(pk1, m=64, candidates=("dense", "plan"), cache=cache,
                       stub=False, timer=t)
    d = choose_backend(pk1, m=32, candidates=("dense", "plan"), cache=cache,
                       stub=True)
    assert len({a.key, b.key, c.key, d.key}) == 4
    assert cache.stats.hits == 0 and cache.stats.misses == 4


def test_cache_key_includes_device_count_and_shard(tmp_path):
    """The mesh-serving key fix: device count is always in the key, and a
    sharded measurement (n_shards, axis) never answers for a different
    shard config -- or for the unsharded pattern."""
    cache = AutotuneCache(str(tmp_path / "at.json"))
    pk = _pack(n=128, k=128)
    base = choose_backend(pk, m=32, candidates=("dense", "plan"),
                          cache=cache, stub=True)
    assert f":d{jax.device_count()}" in base.key
    variants = [choose_backend(pk, m=32, candidates=("dense", "plan"),
                               cache=cache, stub=True, shard=s)
                for s in [(4, "out"), (8, "out"), (4, "in")]]
    keys = {base.key} | {v.key for v in variants}
    assert len(keys) == 4
    assert cache.stats.hits == 0 and cache.stats.misses == 4
    # an indivisible shard config serves replicated -> keyed unsharded
    odd = choose_backend(pk, m=32, candidates=("dense", "plan"),
                         cache=cache, stub=True, shard=(3, "out"))
    assert odd.key == base.key and odd.cache_hit


def test_single_argument_chooser_still_works_unsharded(monkeypatch):
    """Pre-mesh contract: a backend_chooser taking only (pack) keeps
    working for unsharded exports -- shard= is passed only to choosers of
    packs that actually shard."""
    monkeypatch.setenv("REPRO_AUTOTUNE_STUB", "1")
    from repro.serving.export import export_params
    cfg = get_config("deepseek_7b", smoke=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    calls = []

    def chooser(pack):                      # no shard kwarg
        calls.append(pack.shape)
        return choose_backend(pack, m=32, candidates=("dense", "plan"),
                              stub=True)
    _, packs, _ = export_params(params, cfg, tile=(16, 16),
                                backend_chooser=chooser)
    assert calls                            # chooser actually consulted


def test_cache_v1_file_invalidates_without_crash(tmp_path):
    """Migration contract: an old-format cache file is read as empty (its
    winners were keyed without device/shard fields), the chooser re-tunes,
    and the file is rewritten at the current version."""
    import json
    path = tmp_path / "at.json"
    path.write_text(json.dumps(
        {"version": 1, "entries": {"stalekey": {"backend": "gather"}}}))
    cache = AutotuneCache(str(path))
    pk = _pack()
    c = choose_backend(pk, m=32, candidates=("dense", "plan"), cache=cache,
                       stub=True)
    assert not c.cache_hit                 # nothing answered from v1
    doc = json.loads(path.read_text())
    assert doc["version"] == autotune.CACHE_VERSION
    assert c.key in doc["entries"] and "stalekey" not in doc["entries"]
    # corrupt file: same contract, no crash
    path.write_text("{not json")
    cache2 = AutotuneCache(str(path))
    c2 = choose_backend(pk, m=32, candidates=("dense", "plan"), cache=cache2,
                        stub=True)
    assert not c2.cache_hit


@pytest.mark.parametrize("payload", [
    b"",                                              # empty file
    b"\x00\x9c\xffgarbage\x81",                       # binary garbage
    b"[1, 2, 3]",                                     # JSON, wrong shape
    b'"just a string"',
    b'{"version": 2, "entries": [1, 2]}',             # entries not a dict
    b'{"version": 2, "entries": {"k": "notadict"}}',  # record not a dict
    b'{"version": 2, "entries": {"k": {"backend": "dense"}',  # truncated
])
def test_cache_corrupt_file_reads_empty_and_is_rewritten(tmp_path, payload):
    """Robustness contract: ANY unparseable/malformed winner cache reads as
    empty (worst case: re-measure), and the next put() rewrites the file as
    valid current-version JSON -- never a crash, never a poisoned read."""
    import json
    path = tmp_path / "at.json"
    path.write_bytes(payload)
    cache = AutotuneCache(str(path))
    assert cache.get("anything") is None            # no crash, a miss
    cache.put("k2", {"backend": "plan"})            # rewrite heals the file
    doc = json.loads(path.read_text())
    assert doc["version"] == autotune.CACHE_VERSION
    assert doc["entries"]["k2"] == {"backend": "plan"}
    # well-formed sibling entries survive a merge; malformed ones are
    # dropped rather than re-persisted
    assert all(isinstance(v, dict) for v in doc["entries"].values())
    fresh = AutotuneCache(str(path))
    assert fresh.get("k2") == {"backend": "plan"}


def test_cache_corrupt_file_end_to_end_choose(tmp_path):
    """choose_backend over a corrupt cache file: tunes from scratch,
    persists, and a second chooser over the healed file gets a hit."""
    path = tmp_path / "at.json"
    path.write_bytes(b"\x89PNG not a json file at all")
    pk = _pack()
    c1 = choose_backend(pk, m=32, candidates=("dense", "plan"),
                        cache=AutotuneCache(str(path)), stub=True)
    assert not c1.cache_hit
    c2 = choose_backend(pk, m=32, candidates=("dense", "plan"),
                        cache=AutotuneCache(str(path)), stub=True)
    assert c2.cache_hit and c2.backend == c1.backend


def test_stub_mode_is_deterministic(tmp_path):
    pk = _pack()
    costs1 = stub_costs(pk, 128, autotune.CANDIDATES)
    costs2 = stub_costs(pk, 128, autotune.CANDIDATES)
    assert costs1 == costs2
    assert set(costs1) == set(autotune.CANDIDATES)
    c1 = choose_backend(pk, m=128, cache=AutotuneCache(
        str(tmp_path / "a.json")), stub=True)
    c2 = choose_backend(pk, m=128, cache=AutotuneCache(
        str(tmp_path / "b.json")), stub=True)
    assert c1.backend == c2.backend and c1.mode == "stub"
    if jax.default_backend() != "tpu":
        # interpret-mode arms must never win the proxy off-TPU
        assert c1.backend not in autotune.INTERPRET_ONLY


def test_wallclock_measure_small_pattern():
    """Real (tiny) wall-clock path: positive times per candidate plus the
    drift-robust paired-ratio ranking scores (anchor scores 1.0 exactly:
    it is its own round-mate)."""
    pk = _pack(n=32, k=32, tile=(16, 16))
    times, scores = autotune.measure(
        pk, 8, ("dense", "gather", "rowpack", "plan"), reps=2)
    assert all(t > 0 for t in times.values()) and len(times) == 4
    assert scores["dense"] == 1.0
    assert all(s > 0 for s in scores.values()) and len(scores) == 4


# --------------------------------------------------------------------------
# spec-level wiring (stub mode: deterministic in CI)
# --------------------------------------------------------------------------

def _auto_spec():
    return ServingSpec(tile=(16, 16), sparsity=0.5, prune="oneshot",
                       targets=ATTN_TARGETS, backend="auto", autotune_m=64)


def test_backend_auto_end_to_end(tmp_path, monkeypatch):
    """backend='auto' serves with forward/decode parity vs the plan
    backend, reports the chosen backend per layer group in stats(), and a
    second prepare (same cache file) counts cache hits."""
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "at.json"))
    monkeypatch.setenv("REPRO_AUTOTUNE_STUB", "1")
    cfg = get_config("deepseek_7b", smoke=True)
    params = init_model(jax.random.PRNGKey(1), cfg)
    sv_auto = prepare_servable(params, cfg, _auto_spec())
    sv_plan = prepare_servable(params, cfg, ServingSpec(
        tile=(16, 16), sparsity=0.5, prune="oneshot", targets=ATTN_TARGETS,
        backend="plan"))
    toks = jnp.asarray(RNG.randint(0, cfg.vocab_size, (2, 8)))
    np.testing.assert_allclose(np.asarray(sv_auto.forward(toks)),
                               np.asarray(sv_plan.forward(toks)), atol=1e-5)
    st = sv_auto.stats()
    assert st["backend"] == "auto"
    auto = st["autotune"]
    assert auto["mode"] == "stub" and auto["backends"]
    assert all(b in autotune.CANDIDATES for b in auto["backends"].values())
    assert auto["cache_misses"] == len(auto["backends"])

    sv2 = prepare_servable(params, cfg, _auto_spec())
    auto2 = sv2.stats()["autotune"]
    assert auto2["cache_hits"] == len(auto2["backends"])
    assert auto2["backends"] == auto["backends"]


def test_backend_auto_save_load(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "at.json"))
    monkeypatch.setenv("REPRO_AUTOTUNE_STUB", "1")
    cfg = get_config("deepseek_7b", smoke=True)
    params = init_model(jax.random.PRNGKey(2), cfg)
    sv = prepare_servable(params, cfg, _auto_spec())
    toks = jnp.asarray(RNG.randint(0, cfg.vocab_size, (1, 6)))
    want = np.asarray(sv.forward(toks))
    sv.save(str(tmp_path / "ckpt"))
    sv2 = load_servable(str(tmp_path / "ckpt"))
    np.testing.assert_allclose(np.asarray(sv2.forward(toks)), want,
                               atol=1e-6)
    assert sv2.stats()["autotune"]["backends"] == \
        sv.stats()["autotune"]["backends"]


# --------------------------------------------------------------------------
# choice/masked pack kinds: serve parity + serialization round-trip
# --------------------------------------------------------------------------

def test_choice_and_masked_packs_roundtrip():
    pk = _pack()
    packs = {"a/wq": BackendChoice(pk, "gather"),
             "b/wq": BackendChoice(pk, "rowpack"),
             "c/wq": masked_pack_from(pk)}
    # same pattern pinned to different backends must NOT dedupe together
    assert len({pattern_key(p) for p in packs.values()}) == 3
    arrays, meta = packs_to_arrays(packs)
    restored = packs_from_arrays(meta, arrays)
    assert restored["a/wq"].backend == "gather"
    assert restored["b/wq"].backend == "rowpack"
    np.testing.assert_array_equal(restored["c/wq"].tile_mask,
                                  packs["c/wq"].tile_mask)
    for key in packs:
        assert pattern_key(restored[key]) == pattern_key(packs[key])


def test_masked_and_choice_linear_parity():
    from repro.models.common import linear
    pk = _pack()
    x = jnp.asarray(RNG.randn(4, 48).astype(np.float32))
    ref = x @ jnp.asarray(dense_from_pack(pk)).T
    for pack, w in [
            (BackendChoice(pk, "gather"), pk.data),
            (BackendChoice(pk, "rowpack"), pk.data),
            (masked_pack_from(pk), jnp.asarray(dense_from_pack(pk)))]:
        got = linear({"w": w}, x, pack)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=1e-4)
