"""Core sparsity library: BSR format (vs scipy), pruning, regularizers,
pattern reuse."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
import scipy.sparse as sp

from repro.core import (BSR, PatternRegistry, SparsityConfig, actual_sparsity,
                        apply_block_mask, block_norms, bsr_to_dense,
                        count_unique_intrablock_patterns, dense_to_bsr,
                        group_penalty, group_prox, l1_prox, oneshot_prune,
                        pattern_fingerprint, pattern_similarity,
                        prune_to_sparsity, topk_block_mask, tree_group_penalty)
from repro.core.pruner import (apply_masks, cubic_sparsity, init_masks,
                               update_masks)


def _sparse(rng, n, k, bs, density):
    w = rng.randn(n, k).astype(np.float32)
    mask = rng.rand(n // bs[0], k // bs[1]) < density
    return w * np.kron(mask, np.ones(bs, np.float32))


class TestBSRFormat:
    def test_roundtrip_matches_scipy(self):
        rng = np.random.RandomState(0)
        for bs in [(1, 32), (32, 1), (8, 16), (64, 64)]:
            w = _sparse(rng, 128, 256, bs, 0.3)
            ours = dense_to_bsr(w, bs)
            theirs = sp.bsr_matrix(w, blocksize=bs)
            theirs.eliminate_zeros()
            np.testing.assert_allclose(np.asarray(bsr_to_dense(ours)), w)
            assert ours.nnzb >= theirs.nnz / (bs[0] * bs[1]) or True
            # indptr/indices semantics match scipy's
            dense_from_scipy = theirs.toarray()
            np.testing.assert_allclose(np.asarray(bsr_to_dense(ours)),
                                       dense_from_scipy)

    def test_padding_is_harmless(self):
        rng = np.random.RandomState(1)
        w = _sparse(rng, 64, 64, (16, 16), 0.4)
        tight = dense_to_bsr(w, (16, 16))
        padded = dense_to_bsr(w, (16, 16), nnzb=tight.nnzb + 5)
        np.testing.assert_allclose(np.asarray(bsr_to_dense(tight)),
                                   np.asarray(bsr_to_dense(padded)))

    def test_fingerprint_distinguishes_patterns(self):
        rng = np.random.RandomState(2)
        a = dense_to_bsr(_sparse(rng, 64, 64, (16, 16), 0.4), (16, 16))
        b = dense_to_bsr(_sparse(rng, 64, 64, (16, 16), 0.4), (16, 16))
        a2 = BSR(a.data * 2.0, a.indices, a.indptr, a.shape, a.block_shape)
        assert pattern_fingerprint(a) == pattern_fingerprint(a2)  # values differ
        if a.nnzb != b.nnzb or not np.array_equal(np.asarray(a.indices),
                                                  np.asarray(b.indices)):
            assert pattern_fingerprint(a) != pattern_fingerprint(b)


class TestPruning:
    def test_prune_hits_target_ratio(self):
        rng = np.random.RandomState(3)
        w = jnp.asarray(rng.randn(128, 128).astype(np.float32))
        for s in (0.5, 0.8):
            pw, mask = prune_to_sparsity(w, (32, 1), s)
            assert abs(float(actual_sparsity(pw, (32, 1))) - s) < 0.02

    def test_prune_keeps_largest_blocks(self):
        w = np.ones((8, 8), np.float32)
        w[:4] *= 10.0
        pw, mask = prune_to_sparsity(jnp.asarray(w), (4, 4), 0.5)
        np.testing.assert_array_equal(np.asarray(mask),
                                      [[True, True], [False, False]])

    def test_cubic_schedule_monotone(self):
        cfg = SparsityConfig(sparsity=0.8, start_step=0, end_step=100)
        vals = [float(cubic_sparsity(jnp.asarray(s), cfg))
                for s in range(0, 110, 10)]
        assert all(b >= a - 1e-6 for a, b in zip(vals, vals[1:]))
        assert abs(vals[-1] - 0.8) < 1e-6

    def test_mask_lifecycle(self):
        rng = np.random.RandomState(4)
        params = {"attn": {"wq": {"w": jnp.asarray(
            rng.randn(64, 64).astype(np.float32))}}}
        cfg = SparsityConfig(block_shape=(8, 8), sparsity=0.75,
                             targets=("attn/wq",), start_step=0, end_step=1)
        masks = init_masks(params, cfg)
        masks = update_masks(params, masks, jnp.asarray(5), cfg)
        pruned = apply_masks(params, masks, cfg)
        got = float(actual_sparsity(pruned["attn"]["wq"]["w"], (8, 8)))
        assert got >= 0.70


class TestRegularizer:
    def test_group_prox_zeroes_small_blocks(self):
        rng = np.random.RandomState(5)
        w = jnp.asarray(rng.randn(32, 32).astype(np.float32)) * 0.01
        out = group_prox(w, (8, 8), thresh=1.0)
        assert float(jnp.abs(out).max()) == 0.0

    def test_group_prox_shrinks_norm(self):
        rng = np.random.RandomState(6)
        w = jnp.asarray(rng.randn(32, 32).astype(np.float32))
        out = group_prox(w, (8, 8), thresh=0.5)
        nb, na = block_norms(w, (8, 8)), block_norms(out, (8, 8))
        assert np.all(np.asarray(na) <= np.asarray(nb) + 1e-6)
        np.testing.assert_allclose(np.asarray(na)[np.asarray(na) > 0],
                                   np.asarray(nb)[np.asarray(na) > 0] - 0.5,
                                   rtol=1e-5)

    def test_l1_prox(self):
        w = jnp.asarray([-2.0, -0.1, 0.1, 2.0])
        np.testing.assert_allclose(np.asarray(l1_prox(w, 0.5)),
                                   [-1.5, 0.0, 0.0, 1.5])

    def test_penalty_p1_equals_l1(self):
        rng = np.random.RandomState(7)
        w = jnp.asarray(rng.randn(32, 32).astype(np.float32))
        assert abs(float(group_penalty(w, (8, 8), 1))
                   - float(jnp.sum(jnp.abs(w)))) < 1e-3


class TestPatternReuse:
    def test_registry_hits_for_identical_patterns(self):
        rng = np.random.RandomState(8)
        w = _sparse(rng, 64, 64, (16, 16), 0.4)
        a = dense_to_bsr(w, (16, 16))
        b = BSR(a.data * 3.0, a.indices, a.indptr, a.shape, a.block_shape)
        reg = PatternRegistry()
        fn = lambda m: bsr_to_dense(m).sum()
        reg.specialize(fn, a)
        reg.specialize(fn, b)       # same structure -> reuse
        assert reg.stats.hits == 1 and reg.stats.misses == 1
        assert reg.n_unique_patterns() == 1

    def test_small_blocks_have_fewer_intrablock_patterns(self):
        """Paper §4 mechanism: pattern cardinality grows with block size."""
        rng = np.random.RandomState(9)
        w = rng.randn(256, 256).astype(np.float32)
        w[np.abs(w) < 1.0] = 0.0
        c_small = count_unique_intrablock_patterns(w, (1, 4))
        c_big = count_unique_intrablock_patterns(w, (16, 16))
        # normalize by number of blocks
        n_small = (256 * 256) // 4
        n_big = (256 * 256) // 256
        assert c_small / n_small < 1.0          # heavy reuse at small blocks
        assert c_big / n_big > 0.9              # ~every big block unique

    def test_pattern_similarity(self):
        rng = np.random.RandomState(10)
        w = _sparse(rng, 64, 64, (16, 16), 0.5)
        a = dense_to_bsr(w, (16, 16))
        assert pattern_similarity(a, a) == 1.0
