"""Exec-plan layer: RowPackPlan parity with the rowpack backend (fwd + bwd,
incl. padded nnzt), fused-QKV parity with unfused dispatch, cross-layer
union export parity, and plan-registry reuse accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import PatternRegistry, SparsityConfig
from repro.core.pruner import oneshot_prune
from repro.configs.registry import get_config
from repro.kernels import pack_bsr
from repro.kernels.exec_plan import (build_plan, pack_plan_data,
                                     plan_for_pack, plan_linear, plan_matmul,
                                     unpack_plan_data)
from repro.kernels.ops import bsr_linear
from repro.models import bert as bert_mod
from repro.models import init_model

RNG = np.random.RandomState(0)
_TARGETS = ("attn/wq", "attn/wk", "attn/wv", "attn/wo", "ffn/wi", "ffn/wo")


def _sparse_weight(rng, n, k, tile, density):
    w = rng.randn(n, k).astype(np.float32)
    mask = rng.rand(n // tile[0], k // tile[1]) < density
    return w * np.kron(mask, np.ones(tile, np.float32))


# --------------------------------------------------------------------------
# RowPackPlan vs the rowpack backend
# --------------------------------------------------------------------------

@pytest.mark.parametrize("pad_tiles", [0, 7])
def test_plan_matches_rowpack_fwd_bwd(pad_tiles):
    """Plan forward/backward == rowpack backend, including the padded-nnzt
    case (real_nnzt < nnzt): padding carries zero data and zero grads."""
    rng = np.random.RandomState(1)
    n, k, m, tile = 128, 256, 32, (32, 64)
    w = _sparse_weight(rng, n, k, tile, 0.4)
    x = jnp.asarray(rng.randn(m, k).astype(np.float32))
    real = int(np.any(
        w.reshape(n // tile[0], tile[0], k // tile[1], tile[1]) != 0,
        axis=(1, 3)).sum())
    pk = pack_bsr(w, tile, nnzt=real + pad_tiles)
    assert pk.real_nnzt == real and pk.nnzt == real + pad_tiles

    plan = build_plan(pk)
    data_rp = pack_plan_data(plan, pk.data)
    y_plan = plan_linear(x, data_rp, plan)
    y_rp = bsr_linear(x, pk.data, pk, "rowpack")
    # spill scheduling may reassociate the per-row sums -> allclose, not ==
    np.testing.assert_allclose(np.asarray(y_plan), np.asarray(y_rp),
                               rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(y_plan),
                               np.asarray(x) @ w.T, rtol=1e-4, atol=1e-4)

    gx_p, gd_p = jax.grad(
        lambda x_, d_: jnp.sum(plan_linear(x_, d_, plan) ** 2),
        argnums=(0, 1))(x, data_rp)
    gx_r, gd_r = jax.grad(
        lambda x_, d_: jnp.sum(bsr_linear(x_, d_, pk, "rowpack") ** 2),
        argnums=(0, 1))(x, pk.data)
    np.testing.assert_allclose(np.asarray(gx_p), np.asarray(gx_r),
                               rtol=1e-4, atol=1e-3)
    # value grads agree on real tiles after inverting the row-grouping
    np.testing.assert_allclose(np.asarray(unpack_plan_data(plan, gd_p)),
                               np.asarray(gd_r[:real]), rtol=1e-4, atol=1e-3)
    # padding (slots and tiles) must stay exactly dead
    dead = np.asarray(jnp.where(
        jnp.asarray(plan.slot_mask)[:, :, None, None], 0.0, gd_p))
    assert float(np.abs(dead).max()) == 0.0
    if pad_tiles:
        assert float(jnp.abs(gd_r[real:]).max()) == 0.0


def test_plan_spill_schedule_correct():
    """A deliberately skewed pattern (one dense row, rest sparse) forces the
    offline scheduler to spill: V > R, fewer padded slots than rowpack's
    fixed max-P layout, and the segment-sum path stays exact."""
    rng = np.random.RandomState(7)
    n, k, m, tile = 256, 512, 24, (32, 32)
    w = np.zeros((n, k), np.float32)
    w[:32] = rng.randn(32, k)                       # row 0: all 16 tiles
    mask = rng.rand(n // 32, k // 32) < 0.15        # other rows: sparse
    mask[0] = True
    w2 = rng.randn(n, k).astype(np.float32) * np.kron(
        mask, np.ones(tile, np.float32))
    w2[:32] = w[:32]
    pk = pack_bsr(w2, tile)
    plan = build_plan(pk)
    assert plan.spilled and plan.n_vrows > plan.n_brows
    counts_max = 16                                 # rowpack pads all rows to
    assert plan.n_vrows * plan.p_max < pk.n_brows * counts_max
    x = jnp.asarray(rng.randn(m, k).astype(np.float32))
    y = plan_linear(x, pack_plan_data(plan, pk.data), plan)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x) @ w2.T,
                               rtol=1e-4, atol=1e-4)
    gx, gd = jax.grad(
        lambda x_, d_: jnp.sum(plan_linear(x_, d_, plan) ** 2),
        argnums=(0, 1))(x, pack_plan_data(plan, pk.data))
    gx_ref = jax.grad(
        lambda x_: jnp.sum((x_ @ jnp.asarray(w2).T) ** 2))(x)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(gx_ref),
                               rtol=1e-3, atol=1e-2)
    dead = np.asarray(jnp.where(
        jnp.asarray(plan.slot_mask)[:, :, None, None], 0.0, gd))
    assert float(np.abs(dead).max()) == 0.0


def test_plan_matmul_batched_leading_dims():
    rng = np.random.RandomState(2)
    n, k, tile = 64, 64, (16, 16)
    w = _sparse_weight(rng, n, k, tile, 0.5)
    pk = pack_bsr(w, tile)
    plan = build_plan(pk)
    data_rp = pack_plan_data(plan, pk.data)
    x = jnp.asarray(rng.randn(2, 5, k).astype(np.float32))
    y = plan_matmul(x, data_rp, plan)
    assert y.shape == (2, 5, n)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(x) @ w.T, rtol=1e-4, atol=1e-4)


def test_plan_registry_reuse_and_fingerprint():
    """Identical patterns -> one plan (hit); plan hash/eq by fingerprint so
    jit caches key on the pattern, not the object identity."""
    rng = np.random.RandomState(3)
    tile = (16, 16)
    w = _sparse_weight(rng, 64, 64, tile, 0.5)
    reg = PatternRegistry()
    p1 = plan_for_pack(pack_bsr(w, tile), registry=reg)
    p2 = plan_for_pack(pack_bsr(w, tile), registry=reg)
    assert p1 is p2
    assert reg.stats.misses == 1 and reg.stats.hits == 1
    assert build_plan(pack_bsr(w, tile)) == p1       # eq via fingerprint
    assert hash(build_plan(pack_bsr(w, tile))) == hash(p1)
    w2 = _sparse_weight(rng, 64, 64, tile, 0.5)
    p3 = plan_for_pack(pack_bsr(w2, tile), registry=reg)
    assert p3 is not p1 and reg.stats.misses == 2


# --------------------------------------------------------------------------
# fused QKV dispatch
# --------------------------------------------------------------------------

def test_fused_qkv_matches_three_unfused_calls():
    """One fused (3N, K) BSR matmul == three unfused bsr_linear calls."""
    rng = np.random.RandomState(4)
    n, k, m, tile = 64, 128, 16, (16, 16)
    ws = [_sparse_weight(rng, n, k, tile, 0.4) for _ in range(3)]
    x = jnp.asarray(rng.randn(m, k).astype(np.float32))
    outs = []
    for w in ws:
        pk = pack_bsr(w, tile)
        outs.append(bsr_linear(x, pk.data, pk, "rowpack"))
    unfused = jnp.concatenate(outs, axis=1)

    pk_f = pack_bsr(np.concatenate(ws, axis=0), tile)
    plan = build_plan(pk_f)
    fused = plan_linear(x, pack_plan_data(plan, pk_f.data), plan)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(unfused),
                               rtol=1e-5, atol=1e-5)


def _pruned_smoke_bert(sparsity=0.75, tile=(16, 16)):
    cfg = get_config("bert_base", smoke=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    sp = SparsityConfig(block_shape=tile, sparsity=sparsity, targets=_TARGETS)
    pruned, _ = oneshot_prune(params, sp)
    return cfg, pruned


def test_bert_fused_export_matches_unfused():
    from repro.serving.export import export_bert_sparse
    cfg, pruned = _pruned_smoke_bert()
    toks = jnp.asarray(RNG.randint(0, cfg.vocab_size, (2, 24)))
    p_f, packs_f = export_bert_sparse(pruned, cfg, tile=(16, 16),
                                      fuse_qkv=True)
    p_u, packs_u = export_bert_sparse(pruned, cfg, tile=(16, 16),
                                      fuse_qkv=False)
    assert any(key.endswith("/wqkv") for key in packs_f)
    assert all(not key.endswith("/wqkv") for key in packs_u)
    out_f = bert_mod.forward(p_f, cfg, toks, packs=packs_f)
    out_u = bert_mod.forward(p_u, cfg, toks, packs=packs_u)
    np.testing.assert_allclose(np.asarray(out_f), np.asarray(out_u),
                               rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------------------
# cross-layer union export
# --------------------------------------------------------------------------

def test_bert_union_export_matches_per_layer():
    """Unioned export logits == per-layer export logits; all layers share
    one specialization per projection group (L-1 hits each)."""
    from repro.serving.export import export_bert_sparse
    cfg, pruned = _pruned_smoke_bert()
    toks = jnp.asarray(RNG.randint(0, cfg.vocab_size, (2, 24)))
    reg = PatternRegistry()
    p_un, packs_un = export_bert_sparse(pruned, cfg, tile=(16, 16),
                                        cross_layer_union=True, registry=reg)
    p_pl, packs_pl = export_bert_sparse(pruned, cfg, tile=(16, 16),
                                        cross_layer_union=False)
    out_un = bert_mod.forward(p_un, cfg, toks, packs=packs_un)
    out_pl = bert_mod.forward(p_pl, cfg, toks, packs=packs_pl)
    np.testing.assert_allclose(np.asarray(out_un), np.asarray(out_pl),
                               rtol=1e-4, atol=1e-4)

    n_groups = 4                                # wqkv, attn/wo, ffn/wi, ffn/wo
    assert len(packs_un) == cfg.n_layers * n_groups
    assert len({p.fingerprint for p in packs_un.values()}) == n_groups
    assert reg.stats.misses == n_groups
    assert reg.stats.hits == (cfg.n_layers - 1) * n_groups
