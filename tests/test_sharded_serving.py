"""Mesh-first serving (spec.mesh_shape): sharded BSR export, sharded slot
caches, tensor-parallel sparse decode.

The parity contract: a servable prepared with ``mesh_shape=(1, 8)`` must
reproduce the single-device servable's logits (<= 1e-5) and greedy tokens
for every decode-capable family, while its plan packs and slot caches
physically partition across the mesh (per-device bytes shrink ~n_shards
fold where divisibility permits).

Multi-device tests need a forced host-platform mesh:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m pytest tests/test_sharded_serving.py

(the ci.yml `devices: 8` matrix leg runs exactly this; under the default
single-device run these tests skip). The pure-kernel ShardedPlan tests and
spec validation run everywhere.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LayerKind, ModelConfig
from repro.configs.registry import get_config
from repro.core.sparsity import prune_to_sparsity
from repro.kernels import exec_plan as xp
from repro.kernels.bsr_matmul import pack_bsr
from repro.kernels.exec_plan import ShardedPlan
from repro.core.pattern_reuse import PatternRegistry
from repro.models import init_model
from repro.serving import ServingSpec, load_servable, prepare_servable

needs8 = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")

ALL_TARGETS = ("attn/wq", "attn/wk", "attn/wv", "attn/wo",
               "ffn/wi", "ffn/wg", "ffn/wo")
ATTN_TARGETS = ("attn/wq", "attn/wk", "attn/wv", "attn/wo")


def _tp_cfg():
    """Dense LM whose projections divide an 8-wide model axis at tile 32:
    wqkv (768, 256) -> 24 block rows, wo 8 block cols, ffn 32/8."""
    return ModelConfig(
        arch="tp-smoke", family="dense", n_layers=4, d_model=256,
        n_heads=8, n_kv_heads=8, head_dim=32, d_ff=1024, vocab_size=1024,
        pattern=(LayerKind("attn", "dense"),), dtype="float32")


def _tp_spec(**kw):
    return ServingSpec(tile=(32, 32), sparsity=0.7, prune="tied",
                       targets=ALL_TARGETS, **kw)


@pytest.fixture(scope="module")
def tp_pair():
    """(params, single-device servable, 8-way TP servable) over _tp_cfg."""
    if jax.device_count() < 8:
        pytest.skip("needs 8 devices")
    cfg = _tp_cfg()
    params = init_model(jax.random.PRNGKey(1), cfg)
    sv1 = prepare_servable(params, cfg, _tp_spec())
    sv8 = prepare_servable(params, cfg,
                           _tp_spec(mesh_shape=(1, 8), partition="tp"))
    return params, sv1, sv8


def _run_engine(sv, prompts, *, slots=4, cache_len=64, sync_every=4,
                max_new=8, frames=None):
    eng = sv.engine(max_slots=slots, cache_len=cache_len,
                    sync_every=sync_every)
    if frames is not None:
        hs = [eng.submit(p, max_new_tokens=max_new, frames=f)
              for p, f in zip(prompts, frames)]
    else:
        hs = [eng.submit(p, max_new_tokens=max_new) for p in prompts]
    eng.run()
    assert all(h.done for h in hs)
    return [h.tokens for h in hs], eng


# --------------------------------------------------------------------------
# kernel level: ShardedPlan == dense reference (any device count)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("axis,n_shards", [("out", 4), ("in", 4),
                                           ("out", 8), ("in", 8)])
def test_sharded_plan_matches_dense(axis, n_shards):
    rng = np.random.RandomState(0)
    w = rng.randn(128, 128).astype(np.float32)
    pruned, _ = prune_to_sparsity(jnp.asarray(w), (16, 16), 0.6)
    w = np.asarray(pruned)
    pack = pack_bsr(w, (16, 16))
    plan = xp.build_sharded_plan(pack, n_shards, axis)
    assert plan.n_vrows % n_shards == 0
    assert plan.spilled                    # partials always fold
    assert len(plan.shard_fingerprints) == n_shards
    data = xp.pack_plan_data(plan, pack.data)
    x = rng.randn(5, 128).astype(np.float32)
    y = np.asarray(xp.plan_linear(jnp.asarray(x), data, plan))
    np.testing.assert_allclose(y, x @ w.T, atol=1e-4)


def test_sharded_plan_registry_reuse_per_shard():
    """Identical patterns reuse per-shard layouts; shard_stats exposes the
    per-shard hit/miss accounting."""
    rng = np.random.RandomState(1)
    w = rng.randn(64, 64).astype(np.float32)
    pruned, _ = prune_to_sparsity(jnp.asarray(w), (16, 16), 0.5)
    pack = pack_bsr(np.asarray(pruned), (16, 16))
    reg, st = PatternRegistry(), {}
    xp.build_sharded_plan(pack, 4, "out", registry=reg, shard_stats=st)
    first = {s: dict(v) for s, v in st.items()}
    xp.build_sharded_plan(pack, 4, "out", registry=reg, shard_stats=st)
    # second build: every shard answers from the registry (shards with
    # coincidentally identical sub-patterns may even hit on the first)
    assert set(st) == {0, 1, 2, 3}
    assert all(v["hits"] + v["misses"] == 2 for v in st.values())
    assert all(st[s]["hits"] == first[s]["hits"] + 1 for s in st)


@pytest.mark.parametrize("axis,n_shards", [("out", 4), ("in", 4)])
def test_identical_shard_patterns_share_layouts_correctly(axis, n_shards):
    """Regression: shards whose LOCAL sub-patterns coincide (regular
    patterns -- GQA fused qkv hit this) must share a position-independent
    cached layout; the shared layout is re-offset to each shard's global
    rows/cols at assembly."""
    tile = (16, 16)
    blk = np.random.RandomState(0).rand(2, 2) < 0.7
    mask = np.kron(np.ones((4, 2), bool), blk)   # every shard looks alike
    w = np.random.RandomState(1).randn(128, 64).astype(np.float32)
    w *= np.kron(mask, np.ones(tile, np.float32))
    pack = pack_bsr(w, tile)
    reg = PatternRegistry()
    plan = xp.build_sharded_plan(pack, n_shards, axis, registry=reg)
    assert reg.stats.hits > 0              # layouts actually shared
    data = xp.pack_plan_data(plan, pack.data)
    x = np.random.RandomState(2).randn(3, 64).astype(np.float32)
    y = np.asarray(xp.plan_linear(jnp.asarray(x), data, plan))
    np.testing.assert_allclose(y, x @ w.T, atol=1e-4)


def test_indivisible_pattern_raises_and_predicate():
    rng = np.random.RandomState(2)
    w = rng.randn(48, 48).astype(np.float32)   # 3 block rows at tile 16
    pack = pack_bsr(w, (16, 16))
    assert not xp.shard_divisible(pack, 8, "out")
    with pytest.raises(ValueError):
        xp.build_sharded_plan(pack, 8, "out")


def test_spec_validation():
    with pytest.raises(ValueError):
        ServingSpec(partition="nope")
    with pytest.raises(ValueError):            # tp mesh needs data == 1
        ServingSpec(mesh_shape=(2, 4), partition="tp")
    with pytest.raises(ValueError):            # bsr has no sharded layout
        ServingSpec(mesh_shape=(1, 8), partition="tp", backend="bsr")
    spec = ServingSpec(mesh_shape=(2, 4), partition="tp+dp")
    assert spec.model_shards == 4 and spec.data_shards == 2
    rt = ServingSpec.from_dict(spec.to_dict())
    assert rt == spec and rt.mesh_shape == (2, 4)


# --------------------------------------------------------------------------
# export + placement (8-device mesh)
# --------------------------------------------------------------------------

@needs8
def test_sharded_export_shards_packs_and_bytes(tp_pair):
    """Every projection of the divisible config exports as a ShardedPlan
    and per-device pack bytes come out <= 1/4 (here exactly 1/8) of the
    unsharded total -- the acceptance bar of the mesh refactor."""
    _, sv1, sv8 = tp_pair
    assert sv8.packs and all(isinstance(p, ShardedPlan)
                             for p in sv8.packs.values())
    axes = {k.rsplit("/", 1)[1]: p.shard_axis for k, p in sv8.packs.items()}
    assert axes["wqkv"] == "out" and axes["wo"] == "in"
    st = sv8.stats()["sharding"]
    assert st["n_shards"] == 8 and st["sharded_packs"] == len(sv8.packs)
    assert st["pack_bytes_per_device"] <= st["pack_bytes_total"] / 4
    # physical placement: the vrow axis of every packed leaf is split 8-way
    leaf = sv8.params["blocks"][0]["attn"]["wqkv"]["w"]
    shard_shape = leaf.sharding.shard_shape(leaf.shape)
    assert shard_shape[1] == leaf.shape[1] // 8
    # per-shard registry accounting was collected at export
    assert set(st["per_shard_registry"]) == {str(s) for s in range(8)}
    assert all(v["misses"] >= 1 for v in st["per_shard_registry"].values())


@needs8
def test_forward_prefill_decode_many_parity(tp_pair):
    _, sv1, sv8 = tp_pair
    cfg = sv1.cfg
    rng = np.random.RandomState(0)
    toks = rng.randint(0, cfg.vocab_size, (2, 8))
    np.testing.assert_allclose(np.asarray(sv1.forward(toks)),
                               np.asarray(sv8.forward(toks)), atol=1e-5)
    t0 = jnp.asarray(toks[:, :1])
    pos = jnp.zeros((2,), jnp.int32)
    t1, v1, _ = sv1.decode_many(sv1.init_cache(2, 32), t0, pos, 6)
    t8, v8, _ = sv8.decode_many(sv8.init_cache(2, 32), t0, pos, 6)
    assert np.array_equal(np.asarray(t1), np.asarray(t8))
    assert np.array_equal(np.asarray(v1), np.asarray(v8))


@needs8
@pytest.mark.parametrize("partition,mesh_shape", [
    ("tp", (1, 8)), ("dp", (8, 1)), ("tp+dp", (2, 4))])
def test_engine_parity_all_partitions(tp_pair, partition, mesh_shape):
    """Sharded engine == single-device engine, token for token, for every
    partition mode -- admission, bucketed prefill, fused windows, slot
    recycling all on the sharded cache."""
    params, sv1, sv8 = tp_pair
    cfg = sv1.cfg
    sv = (sv8 if partition == "tp" else prepare_servable(
        params, cfg, _tp_spec(mesh_shape=mesh_shape, partition=partition)))
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab_size, (3 + 2 * i,)).tolist()
               for i in range(6)]
    ref, _ = _run_engine(sv1, prompts, slots=8)
    out, eng = _run_engine(sv, prompts, slots=8)
    assert out == ref
    if partition != "tp":       # slots shard over "data"
        leaf = eng.cache["blocks"][0]["mix"]["k"]
        shard = leaf.sharding.shard_shape(leaf.shape)
        assert shard[1] == leaf.shape[1] // mesh_shape[0]


@needs8
def test_sharded_cache_lifecycle_never_gathers(tp_pair):
    """write/free/decode keep every cache leaf's sharding -- lifecycle ops
    are in-place sharded scatters, not host round-trips."""
    _, _, sv8 = tp_pair
    eng = sv8.engine(max_slots=4, cache_len=64, sync_every=4)
    before = jax.tree_util.tree_map(lambda x: x.sharding, eng.cache)
    rng = np.random.RandomState(0)
    hs = [eng.submit(rng.randint(0, sv8.cfg.vocab_size, (5,)).tolist(),
                     max_new_tokens=6) for _ in range(6)]
    eng.run()
    assert all(h.done for h in hs)
    after = jax.tree_util.tree_map(lambda x: x.sharding, eng.cache)
    assert before == after
    # heads genuinely split over the model axis (8 kv heads / 8 devices)
    leaf = eng.cache["blocks"][0]["mix"]["k"]
    assert leaf.sharding.shard_shape(leaf.shape)[3] == 1


@needs8
def test_sharded_slot_recycling_is_hygienic(tp_pair):
    """A recycled slot of a sharded cache serves the same tokens as a
    fresh engine -- free_slot zeroing works shard-local."""
    _, _, sv8 = tp_pair
    rng = np.random.RandomState(3)
    prompts = [rng.randint(0, sv8.cfg.vocab_size, (4 + i,)).tolist()
               for i in range(4)]
    # 2 slots, 4 requests: slots 0/1 recycle for requests 2/3
    recycled, _ = _run_engine(sv8, prompts, slots=2)
    fresh = [_run_engine(sv8, [p], slots=1)[0][0] for p in prompts]
    assert recycled == fresh


# --------------------------------------------------------------------------
# family matrix: TP decode == single-device decode for every
# decode-capable family (divisibility falls back to replicated packs; the
# mesh path itself must stay exact either way)
# --------------------------------------------------------------------------

@needs8
@pytest.mark.parametrize("arch", ["deepseek_7b", "chatglm3_6b",
                                  "mamba2_780m", "recurrentgemma_9b"])
def test_family_engine_parity_tp8(arch):
    cfg = get_config(arch, smoke=True)
    params = init_model(jax.random.PRNGKey(1), cfg)
    spec = dict(tile=(16, 16), sparsity=0.5, prune="oneshot",
                targets=ATTN_TARGETS)
    sv1 = prepare_servable(params, cfg, ServingSpec(**spec))
    sv8 = prepare_servable(params, cfg, ServingSpec(
        **spec, mesh_shape=(1, 8), partition="tp"))
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab_size, (3 + 2 * i,)).tolist()
               for i in range(4)]
    ref, _ = _run_engine(sv1, prompts, slots=2, max_new=6)
    out, _ = _run_engine(sv8, prompts, slots=2, max_new=6)
    assert out == ref


@needs8
def test_family_engine_parity_moe_tp8():
    cfg = dataclasses.replace(get_config("deepseek_v2_lite_16b", smoke=True),
                              capacity_factor=8.0)
    params = init_model(jax.random.PRNGKey(1), cfg)
    spec = dict(tile=(16, 16), sparsity=0.5, prune="oneshot",
                targets=ATTN_TARGETS)
    sv1 = prepare_servable(params, cfg, ServingSpec(**spec))
    sv8 = prepare_servable(params, cfg, ServingSpec(
        **spec, mesh_shape=(1, 8), partition="tp"))
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab_size, (4 + i,)).tolist()
               for i in range(3)]
    ref, _ = _run_engine(sv1, prompts, slots=2, max_new=5)
    out, _ = _run_engine(sv8, prompts, slots=2, max_new=5)
    assert out == ref


@needs8
def test_family_engine_parity_mla_tp8():
    cfg = ModelConfig(
        arch="mla-tp-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=512,
        kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
        pattern=(LayerKind("mla", "dense"),), dtype="float32")
    params = init_model(jax.random.PRNGKey(1), cfg)
    spec = dict(tile=(16, 16), sparsity=0.5, prune="oneshot",
                targets=ATTN_TARGETS)
    sv1 = prepare_servable(params, cfg, ServingSpec(**spec))
    sv8 = prepare_servable(params, cfg, ServingSpec(
        **spec, mesh_shape=(1, 8), partition="tp"))
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab_size, (4 + i,)).tolist()
               for i in range(3)]
    ref, _ = _run_engine(sv1, prompts, slots=2, max_new=5)
    out, _ = _run_engine(sv8, prompts, slots=2, max_new=5)
    assert out == ref


@needs8
def test_bert_forward_parity_tp():
    """Encoder-only family: cross-layer-unioned packs shard over a 4-wide
    model axis (12 block rows divide 4, not 8) and batched forward stays
    within tolerance."""
    cfg = get_config("bert_base", smoke=True)
    params = init_model(jax.random.PRNGKey(1), cfg)
    spec = dict(tile=(16, 16), sparsity=0.5, prune="tied",
                cross_layer_union=True)
    sv1 = prepare_servable(params, cfg, ServingSpec(**spec))
    sv4 = prepare_servable(params, cfg, ServingSpec(
        **spec, mesh_shape=(1, 4), partition="tp"))
    assert any(isinstance(p, ShardedPlan) for p in sv4.packs.values())
    toks = np.random.RandomState(0).randint(0, cfg.vocab_size, (4, 16))
    np.testing.assert_allclose(np.asarray(sv1.forward(toks)),
                               np.asarray(sv4.forward(toks)), atol=1e-5)


@needs8
def test_family_engine_parity_audio_tp8():
    """Audio (enc-dec) has no packs route: the mesh path serves it dense
    with GSPMD-sharded params and a sharded slot cache."""
    cfg = get_config("whisper_base", smoke=True)
    params = init_model(jax.random.PRNGKey(1), cfg)
    spec = dict(tile=(16, 16), sparsity=0.5, prune="none")
    sv1 = prepare_servable(params, cfg, ServingSpec(**spec))
    sv8 = prepare_servable(params, cfg, ServingSpec(
        **spec, mesh_shape=(1, 8), partition="tp"))
    rng = np.random.RandomState(0)
    frames = [rng.randn(cfg.n_audio_ctx, cfg.d_model).astype(np.float32)
              for _ in range(2)]
    prompts = [[1], [1, 2]]
    ref, _ = _run_engine(sv1, prompts, slots=2, max_new=4, frames=frames)
    out, _ = _run_engine(sv8, prompts, slots=2, max_new=4, frames=frames)
    assert out == ref


# --------------------------------------------------------------------------
# persistence
# --------------------------------------------------------------------------

@needs8
def test_save_load_roundtrip_sharded(tp_pair, tmp_path):
    """Shard-partitioned packs survive save/load: kinds, shard metadata,
    per-shard fingerprints, placement, and numerics."""
    _, _, sv8 = tp_pair
    sv8.save(str(tmp_path / "sv"))
    lv = load_servable(str(tmp_path / "sv"))
    assert lv.mesh is not None
    assert set(lv.packs) == set(sv8.packs)
    for key, pk in sv8.packs.items():
        lp = lv.packs[key]
        assert isinstance(lp, ShardedPlan)
        assert lp.n_shards == pk.n_shards
        assert lp.shard_axis == pk.shard_axis
        assert lp.shard_fingerprints == pk.shard_fingerprints
    toks = np.random.RandomState(0).randint(0, sv8.cfg.vocab_size, (2, 8))
    np.testing.assert_allclose(np.asarray(sv8.forward(toks)),
                               np.asarray(lv.forward(toks)), atol=1e-6)
    leaf = lv.params["blocks"][0]["attn"]["wqkv"]["w"]
    assert leaf.sharding.shard_shape(leaf.shape)[1] == leaf.shape[1] // 8
