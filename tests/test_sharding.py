"""Sharding rules: valid divisibility-aware specs; small-mesh end-to-end
pjit execution; subprocess dry-run smoke (own XLA_FLAGS, 16 fake devices)."""
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.registry import get_config
from repro.launch.mesh import make_mesh
from repro.launch.sharding import (batch_shardings, cache_shardings,
                                   param_shardings, spec_for_cache,
                                   spec_for_param)
from repro.launch.specs import cache_specs, input_specs, params_specs

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _mesh():
    return make_mesh((1, 1), ("data", "model"))


class TestSpecRules:
    def test_column_vs_row_parallel(self):
        mesh = make_mesh((1, 1), ("data", "model"))
        # a 16x16-divisible fake weight
        assert spec_for_param("blocks/0/attn/wq/w", (16, 4096, 4096), mesh) \
            == P(None, "model", "data")
        assert spec_for_param("blocks/0/attn/wo/w", (16, 4096, 4096), mesh) \
            == P(None, "data", "model")

    def test_indivisible_dims_replicate(self):
        # abstract mesh: spec rules shouldn't need real devices
        try:   # modern signature: (axis_sizes, axis_names)
            wide = jax.sharding.AbstractMesh((1, 16), ("data", "model"))
        except TypeError:   # older JAX: one tuple of (name, size) pairs
            wide = jax.sharding.AbstractMesh((("data", 1), ("model", 16)))
        spec = spec_for_param("blocks/0/attn/wk/w", (2, 100, 4096), wide)
        assert spec[1] is None     # 100 % 16 != 0 -> replicated
        assert spec[2] == "data"   # in-dim divisible by data axis -> FSDP
        spec2 = spec_for_param("blocks/0/attn/wq/w", (2, 4096, 4096), wide)
        assert spec2 == P(None, "model", "data")

    def test_expert_weights_get_ep(self):
        mesh = make_mesh((1, 1), ("data", "model"))
        spec = spec_for_param("blocks/0/ffn/wi", (16, 128, 4096, 1536), mesh)
        assert spec == P(None, "model", "data", None)

    def test_cache_specs_avoid_head_dim(self):
        mesh = make_mesh((1, 1), ("data", "model"))
        spec = spec_for_cache("blocks/0/mix/k", (16, 128, 32768, 4, 256),
                              mesh)
        assert spec[4] is None     # head_dim never sharded over model

    def test_all_archs_all_shapes_specs_build(self):
        mesh = _mesh()
        for arch in ("qwen3_moe_235b_a22b", "whisper_base", "mamba2_780m",
                     "pixtral_12b"):
            cfg = get_config(arch, smoke=True)
            p = params_specs(cfg)
            sh = param_shardings(p, mesh)
            assert jax.tree_util.tree_structure(sh) == \
                jax.tree_util.tree_structure(p)


def test_pjit_train_step_runs_on_mesh():
    """End-to-end sharded execution on the (1,1) CPU mesh."""
    from repro.launch.steps import make_train_step
    from repro.models import init_model
    from repro.optim.adamw import AdamWConfig, init_opt_state
    cfg = get_config("deepseek_7b", smoke=True)
    mesh = _mesh()
    rng = np.random.RandomState(0)
    with mesh:
        params = init_model(jax.random.PRNGKey(0), cfg)
        opt_cfg = AdamWConfig()
        opt = init_opt_state(params, opt_cfg)
        p_sh = param_shardings(jax.eval_shape(lambda: params), mesh)
        batch = {"tokens": rng.randint(0, cfg.vocab_size, (2, 16)),
                 "labels": rng.randint(0, cfg.vocab_size, (2, 16))}
        step = jax.jit(make_train_step(cfg, opt_cfg), in_shardings=(p_sh, None, None))
        p2, o2, m = step(params, opt, batch)
        assert np.isfinite(float(m["loss"]))


@pytest.mark.slow
def test_dryrun_subprocess_smoke(tmp_path):
    """Real dryrun.py entry point with its own XLA_FLAGS in a subprocess
    (16 fake devices via DRYRUN_DEVICES; prod-mesh shape shrunk by env)."""
    env = dict(os.environ, DRYRUN_DEVICES="16",
               PYTHONPATH=os.path.join(REPO, "src"))
    code = (
        "import os\n"
        "os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=16'\n"
        "import jax\n"
        "from repro.configs.registry import get_config\n"
        "from repro.launch.dryrun import lower_cell\n"
        "mesh = jax.make_mesh((4,4),('data','model'))\n"
        "c = lower_cell(get_config('whisper_base', smoke=True), 'train_4k', mesh)\n"
        "compiled = c.compile()\n"
        "print('MEM', compiled.memory_analysis() is not None)\n"
        "print('COST', bool(compiled.cost_analysis()))\n"
    )
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "MEM True" in out.stdout and "COST True" in out.stdout
