"""The paper's end-to-end flow: train/prune -> export BSR -> sparse serving
equals dense-pruned serving; pattern registry reuse across layers."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.core import PatternRegistry, SparsityConfig
from repro.core.pruner import oneshot_prune
from repro.models import bert as bert_mod
from repro.models import init_model, model_forward
from repro.serving.export import (export_bert_sparse, export_lm_sparse,
                                  pack_stacked)

RNG = np.random.RandomState(0)


def _pruned_bert(sparsity=0.75, tile=(16, 16)):
    cfg = get_config("bert_base", smoke=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    sp = SparsityConfig(block_shape=tile, sparsity=sparsity,
                        targets=("attn/wq", "attn/wk", "attn/wv", "attn/wo",
                                 "ffn/wi", "ffn/wo"))
    pruned, _ = oneshot_prune(params, sp)
    return cfg, pruned


def test_bert_sparse_serving_matches_dense():
    cfg, pruned = _pruned_bert()
    toks = jnp.asarray(RNG.randint(0, cfg.vocab_size, (2, 32)))
    dense_logits = bert_mod.forward(pruned, cfg, toks)
    sparse_params, packs = export_bert_sparse(pruned, cfg, tile=(16, 16))
    sparse_logits = bert_mod.forward(sparse_params, cfg, toks, packs=packs)
    np.testing.assert_allclose(np.asarray(sparse_logits),
                               np.asarray(dense_logits), rtol=1e-3, atol=1e-3)


def test_bert_sparse_actually_sparse():
    cfg, pruned = _pruned_bert(sparsity=0.8)
    _, packs = export_bert_sparse(pruned, cfg, tile=(16, 16))
    densities = [p.density for p in packs.values()]
    assert np.mean(densities) < 0.45, densities


def test_lm_sparse_serving_matches_dense():
    cfg = get_config("deepseek_7b", smoke=True)
    params = init_model(jax.random.PRNGKey(1), cfg)
    sp = SparsityConfig(block_shape=(16, 16), sparsity=0.7)
    pruned, _ = oneshot_prune(params, sp)
    toks = jnp.asarray(RNG.randint(0, cfg.vocab_size, (2, 32)))
    dense_logits, _ = model_forward(pruned, cfg, {"tokens": toks})
    sparse_params, packs, stats = export_lm_sparse(pruned, cfg, tile=(16, 16))
    assert packs, "no projections exported"
    sparse_logits, _ = model_forward(sparse_params, cfg, {"tokens": toks},
                                     packs=packs)
    np.testing.assert_allclose(np.asarray(sparse_logits),
                               np.asarray(dense_logits), rtol=1e-3, atol=1e-3)


def test_pack_stacked_union_semantics():
    l, n, k, tile = 3, 64, 64, (16, 16)
    w = RNG.randn(l, n, k).astype(np.float32)
    # different pattern per layer
    for i in range(l):
        mask = RNG.rand(n // 16, k // 16) < 0.4
        w[i] *= np.kron(mask, np.ones(tile, np.float32))
    pack, data, stats = pack_stacked(w, tile)
    assert data.shape[0] == l
    assert stats["union_nnzt"] >= stats["mean_layer_nnzt"]
    # densify layer 1 from the pack and compare
    from repro.kernels.bsr_matmul import KernelBSR
    from repro.kernels.ops import bsr_matmul
    x = jnp.asarray(RNG.randn(8, k).astype(np.float32))
    for i in range(l):
        kb = KernelBSR(jnp.asarray(data[i]), pack.row_id, pack.col_id,
                       pack.t_perm, pack.real_nnzt, pack.shape, pack.tile)
        y = bsr_matmul(x, kb, "gather")
        np.testing.assert_allclose(np.asarray(y), np.asarray(x) @ w[i].T,
                                   rtol=1e-4, atol=1e-4)


def test_pattern_registry_reuses_across_layers():
    """Identical per-layer patterns (paper's small-block regime) compile
    once -- the TVM task-dedup analogue."""
    from repro.core.bsr import dense_to_bsr, bsr_to_dense
    reg = PatternRegistry()
    base_mask = RNG.rand(4, 4) < 0.5
    fn = lambda m: bsr_to_dense(m).sum()
    for layer in range(6):
        w = RNG.randn(64, 64).astype(np.float32) * \
            np.kron(base_mask, np.ones((16, 16), np.float32))
        reg.specialize(fn, dense_to_bsr(w, (16, 16)))
    assert reg.n_unique_patterns() == 1
    assert reg.stats.hits == 5 and reg.stats.misses == 1
