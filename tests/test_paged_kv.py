"""Paged KV cache + radix prefix sharing (serving/paging.py,
serving/prefix_cache.py, the engine's kv_layout='paged' path).

The parity contract: a paged engine is BIT-EXACT against the dense-slot
engine for greedy decode on every decode-capable attention/MLA family --
the page pool is pure storage relayout (paged_view reassembles the exact
dense cache array, zeros where unmapped), so the decode einsums are
unchanged. On top of that storage the host-side allocator must never leak
or double-free a page under any lifecycle path (done / cancel / deadline /
preempt / pool exhaustion / chaos), and prefix sharing must reuse pages
copy-on-write without one request's decode ever touching another's state.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LayerKind, ModelConfig
from repro.models import init_model
from repro.models.common import (PagedLayout, paged_bulk_write,
                                 paged_row_write, paged_view)
from repro.runtime import chaos as chaos_mod
from repro.serving import ServingSpec, prepare_servable
from repro.serving.engine import FailureReason, ServingEngine
from repro.serving.paging import PagePool, PagePoolExhausted, pages_needed
from repro.serving.prefix_cache import PrefixCache

needs8 = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")

ATTN_TARGETS = ("attn/wq", "attn/wk", "attn/wv", "attn/wo")


def _attn_cfg():
    return ModelConfig(
        arch="paged-attn-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=512,
        pattern=(LayerKind("attn", "dense"),), dtype="float32")


def _mla_cfg():
    return ModelConfig(
        arch="paged-mla-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=512,
        kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
        pattern=(LayerKind("mla", "dense"),), dtype="float32")


def _windowed_cfg():
    """Mixed local+global attention: windowed layers stay slot-dense,
    global layers page -- the partially-paged cache tree."""
    return ModelConfig(
        arch="paged-window-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=512,
        pattern=(LayerKind("attn", "dense", window=16),
                 LayerKind("attn", "dense")), dtype="float32")


CFGS = {"attn": _attn_cfg, "mla": _mla_cfg, "windowed": _windowed_cfg}


def _servables(cfg, page_size=8):
    params = init_model(jax.random.PRNGKey(1), cfg)
    mk = lambda **kw: prepare_servable(params, cfg, ServingSpec(
        tile=(16, 16), sparsity=0.5, prune="oneshot",
        targets=ATTN_TARGETS, **kw))
    return mk(), mk(kv_layout="paged", kv_page_size=page_size)


@pytest.fixture(scope="module", params=sorted(CFGS))
def pair(request):
    dense, paged = _servables(CFGS[request.param]())
    return request.param, dense, paged


def _drain(sv, prompts, max_new=8, **kw):
    kw.setdefault("max_slots", 4)
    kw.setdefault("cache_len", 64)
    eng = ServingEngine(sv, max_queue=16, **kw)
    reqs = [eng.submit(list(p), max_new_tokens=max_new) for p in prompts]
    eng.run()
    return eng, reqs


# --------------------------------------------------------------------------
# host allocator + radix tree units
# --------------------------------------------------------------------------

def test_pages_needed():
    assert pages_needed(0, 8) == 0
    assert pages_needed(1, 8) == 1
    assert pages_needed(8, 8) == 1
    assert pages_needed(9, 8) == 2


def test_pool_alloc_release_refcount():
    pool = PagePool(4, 8)
    a = pool.alloc(2)
    assert a == [0, 1] and pool.free_count == 2    # deterministic low-first
    pool.retain(a)                                  # second reference
    pool.release(a)
    assert pool.used_count == 2                     # still held once
    pool.release(a)
    assert pool.free_count == 4 and pool.peak_used == 2
    with pytest.raises(ValueError):
        pool.release([0])                           # double release
    with pytest.raises(PagePoolExhausted):
        pool.alloc(5)
    assert pool.free_count == 4                     # failed alloc: no effect
    pool.check()


def test_prefix_cache_match_insert_evict():
    pool = PagePool(8, 4)
    pc = PrefixCache(pool, 4)
    toks = list(range(12))                          # 3 complete chunks
    pages = pool.alloc(3)
    assert pc.insert(toks, pages) == 3
    got = pc.match(toks)                            # retains for the caller
    assert got == pages
    assert pc.hit_tokens == 12
    assert pc.match(toks, limit=9) == pages[:2]     # cap -> whole chunks only
    assert pc.match([99, 98]) == []
    for p in (got + pages[:2]):
        pool.release([p])                           # caller refs back
    pool.release(pages)                             # allocator's own refs
    assert pool.used_count == 3                     # tree still holds 3
    assert pc.evict(3) == 3
    assert pool.free_count == 8
    pool.check()


# --------------------------------------------------------------------------
# device primitives: JAX -1-index semantics are load-bearing
# --------------------------------------------------------------------------

def test_paged_row_write_drops_invalid():
    pool = jnp.zeros((3, 4, 2), jnp.float32)
    table = jnp.asarray([[1, -1]], jnp.int32)       # page 1 mapped, rest not
    val = jnp.ones((1, 2), jnp.float32)
    # pos 6 -> chunk 1 -> table[-1] = unmapped: the write must DROP, not
    # wrap to the last page (jnp's negative-index gather would)
    out = paged_row_write(pool, table, jnp.asarray([6]), val,
                          jnp.asarray([True]))
    assert float(jnp.abs(out).sum()) == 0.0
    out = paged_row_write(pool, table, jnp.asarray([2]), val,
                          jnp.asarray([True]))      # chunk 0 -> page 1 row 2
    assert float(out[1, 2].sum()) == 2.0 and float(jnp.abs(out).sum()) == 2.0
    out = paged_row_write(pool, table, jnp.asarray([2]), val,
                          jnp.asarray([False]))     # inactive slot: dropped
    assert float(jnp.abs(out).sum()) == 0.0


def test_paged_view_zeroes_unmapped():
    layout = PagedLayout(page_size=4, n_pages=3)
    pool = jnp.full((3, 4, 2), 7.0, jnp.float32)    # stale NaN-able junk
    table = jnp.asarray([[2, -1]], jnp.int32)
    pos_map = jnp.asarray([[0, 1, 2, -1, -1, -1, -1, -1]], jnp.int32)
    view = paged_view(pool, table, pos_map)
    assert view.shape == (1, 8, 2)
    np.testing.assert_array_equal(np.asarray(view[0, :3]), 7.0)
    np.testing.assert_array_equal(np.asarray(view[0, 3:]), 0.0)


def test_paged_bulk_write_roundtrip():
    vals = jnp.arange(32, dtype=jnp.float32).reshape(16, 2)
    pool = jnp.zeros((4, 4, 2), jnp.float32)
    row = jnp.asarray([3, 1, -1, -1], jnp.int32)    # vals past page 2 drop
    pool = paged_bulk_write(pool, row, vals)
    table = row[None]
    pos_map = jnp.full((1, 16), -1, jnp.int32).at[0, :8].set(jnp.arange(8))
    view = paged_view(pool, table, pos_map)
    np.testing.assert_array_equal(np.asarray(view[0, :8]),
                                  np.asarray(vals[:8]))
    np.testing.assert_array_equal(np.asarray(view[0, 8:]), 0.0)


# --------------------------------------------------------------------------
# engine parity: paged decode is bit-exact vs the dense-slot oracle
# --------------------------------------------------------------------------

PROMPTS = [[1, 2, 3, 4, 5], [7, 8, 9], list(range(10, 31)), [40, 41]]


def test_paged_engine_bitexact(pair):
    name, dense, paged = pair
    eng_d, res_d = _drain(dense, PROMPTS)
    eng_p, res_p = _drain(paged, PROMPTS)
    if name == "windowed":
        # only the global layer pages; windowed layers stay slot-dense
        assert eng_p.kv_layout == "paged"
    for rd, rp in zip(res_d, res_p):
        assert rd.status == rp.status == "done"
        assert rd.tokens == rp.tokens, (name, rd.tokens, rp.tokens)
    kv = eng_p.kv_stats()
    assert kv["layout"] == "paged" and kv["peak_pages_used"] > 0
    assert eng_d.kv_stats()["layout"] == "dense"
    eng_p.verify_invariants()


def test_env_var_selects_layout(monkeypatch):
    dense, _ = _servables(_attn_cfg())
    monkeypatch.setenv("REPRO_KV_LAYOUT", "paged")
    eng = ServingEngine(dense, max_slots=2, cache_len=64)
    assert eng.kv_layout == "paged"     # env overrides the dense spec
    monkeypatch.setenv("REPRO_KV_LAYOUT", "bogus")
    with pytest.raises(ValueError):
        ServingEngine(dense, max_slots=2, cache_len=64)


def test_spec_rejects_paged_dp():
    with pytest.raises(ValueError):
        ServingSpec(kv_layout="paged", mesh_shape=(2, 1), partition="dp")


# --------------------------------------------------------------------------
# prefix sharing: CoW reuse, divergence, containment
# --------------------------------------------------------------------------

def test_shared_prefix_bitexact_and_diverges():
    dense, paged = _servables(_attn_cfg())
    shared = list(range(1, 33))                     # 4 full pages
    prompts = [shared + [100, 101, 102], shared + [200, 201]]
    eng_p, res_p = _drain(paged, prompts)
    eng_d, res_d = _drain(dense, prompts)
    for rp, rd in zip(res_p, res_d):
        assert rp.tokens == rd.tokens               # CoW: each one exact
    assert res_p[0].tokens != res_p[1].tokens       # ...and they diverged
    kv = eng_p.kv_stats()
    assert kv["prefix_hit_tokens"] >= 32            # second request shared
    assert kv["prefilled_tokens"] < sum(len(p) for p in prompts)
    eng_p.verify_invariants()


def test_shared_prefix_pages_survive_corrupt_slot():
    """corrupt_slot on one sharer NaN-fills only PRIVATE pages: the other
    sharer (and the prefix cache) must keep decoding finite."""
    _, paged = _servables(_attn_cfg())
    eng = ServingEngine(paged, max_slots=2, cache_len=64, sync_every=1)
    shared = list(range(1, 17))
    a = eng.submit(shared + [100], max_new_tokens=12)
    b = eng.submit(shared + [200], max_new_tokens=12)
    eng.step()                                      # both admitted
    assert a.slot >= 0 and b.slot >= 0
    eng.corrupt_slot(a.slot)
    eng.run()
    assert a.status == "failed"
    assert a.failure.code == FailureReason.NONFINITE_LOGITS
    assert b.status == "done" and len(b.tokens) == 12
    eng.verify_invariants()


# --------------------------------------------------------------------------
# lifecycle hygiene: no leaks under any terminal path
# --------------------------------------------------------------------------

def _pool_balance(eng):
    """Pages not free must all be prefix-cache-owned once idle."""
    return eng._pool.n_pages - eng._pool.free_count - \
        eng._prefix_cache.cached_pages


def test_slot_recycle_no_page_leaks():
    _, paged = _servables(_attn_cfg())
    eng = ServingEngine(paged, max_slots=2, cache_len=64, max_queue=32)
    for wave in range(3):                           # reuse slots 3x over
        reqs = [eng.submit([wave * 7 + t for t in range(1, 6)],
                           max_new_tokens=5) for _ in range(4)]
        eng.run()
        assert all(r.status == "done" for r in reqs)
    assert _pool_balance(eng) == 0
    eng.verify_invariants()


def test_refcounts_under_cancel_deadline_preempt():
    _, paged = _servables(_attn_cfg())
    # pool sized so the preempted victim's retained pages and the
    # preemptor's reservation coexist (default 1-slot pool cannot)
    eng = ServingEngine(paged, max_slots=1, cache_len=64, sync_every=1,
                        max_queue=16, kv_pool_pages=16)
    a = eng.submit(list(range(1, 9)), max_new_tokens=30)
    eng.step()
    b = eng.submit([50, 51, 52], max_new_tokens=30, priority=5)
    eng.step()                                      # preempts a (retained)
    assert a.status == "queued" and a.n_preempted == 1
    assert eng.stats.preemptions == 1
    eng.verify_invariants()                         # saved pages refcounted
    eng.cancel(b)
    c = eng.submit([60, 61], max_new_tokens=2, deadline_s=0.0)
    eng.step()                                      # b cancels, c expires
    assert b.status == "cancelled"
    assert c.status == "failed"
    assert c.failure.code == FailureReason.DEADLINE
    eng.run()                                       # a resumes and finishes
    assert a.status == "done" and len(a.tokens) == 30
    assert eng.stats.page_resumes >= 1
    assert _pool_balance(eng) == 0
    eng.verify_invariants()


def test_preempt_resume_is_cheaper_and_bitexact():
    dense, paged = _servables(_attn_cfg())
    outs = {}
    for tag, sv in (("dense", dense), ("paged", paged)):
        eng = ServingEngine(sv, max_slots=1, cache_len=64, sync_every=2,
                            max_queue=16)
        a = eng.submit(list(range(1, 9)), max_new_tokens=20)
        for _ in range(2):
            eng.step()
        b = eng.submit([20, 21, 22, 23], max_new_tokens=4, priority=10)
        eng.run()
        assert a.status == "done" and b.status == "done"
        outs[tag] = (a.tokens, b.tokens, eng.stats.prefilled_tokens,
                     eng.stats.page_resumes)
    assert outs["dense"][:2] == outs["paged"][:2]   # bit-exact resume
    assert outs["paged"][3] >= 1                    # via page retention...
    assert outs["paged"][2] < outs["dense"][2]      # ...with no re-prefill


# --------------------------------------------------------------------------
# pool exhaustion: backpressure, never a crash
# --------------------------------------------------------------------------

def test_exhaustion_parks_until_pages_free():
    _, paged = _servables(_attn_cfg())
    eng = ServingEngine(paged, max_slots=4, cache_len=64, max_queue=16,
                        kv_pool_pages=3)            # 24 tokens of pool
    x = eng.submit([1, 2, 3, 4], max_new_tokens=4)  # 1 page
    y = eng.submit(list(range(1, 17)), max_new_tokens=8)   # all 3 pages
    eng.run()
    assert x.status == "done" and y.status == "done"
    assert _pool_balance(eng) == 0
    eng.verify_invariants()


def test_exhaustion_fails_oversized_request_when_idle():
    _, paged = _servables(_attn_cfg())
    eng = ServingEngine(paged, max_slots=2, cache_len=64, kv_pool_pages=2)
    r = eng.submit(list(range(1, 25)), max_new_tokens=8)   # needs 4 > 2
    eng.run()
    assert r.status == "failed"
    assert r.failure.code == FailureReason.KV_PAGES
    assert eng._pool.free_count == 2                # nothing leaked
    eng.verify_invariants()


def test_chaos_page_alloc_fault_sheds_per_policy():
    _, paged = _servables(_attn_cfg())
    chaos = chaos_mod.ChaosInjector()
    eng = ServingEngine(paged, max_slots=2, cache_len=64, chaos=chaos)
    chaos.inject(chaos_mod.SITE_PAGE_ALLOC, at=1,
                 exc=PagePoolExhausted(1, 0))
    a = eng.submit([1, 2, 3], max_new_tokens=4)     # hits injected exhaustion
    b = eng.submit([5, 6, 7], max_new_tokens=4)
    eng.run()
    # no active work existed -> the faulted admission fails structurally,
    # the next one proceeds
    assert a.status == "failed"
    assert a.failure.code == FailureReason.KV_PAGES
    assert b.status == "done"
    assert chaos.fired(chaos_mod.SITE_PAGE_ALLOC) == 1
    assert _pool_balance(eng) == 0
    eng.verify_invariants()


# --------------------------------------------------------------------------
# tensor-parallel paged pool
# --------------------------------------------------------------------------

@needs8
def test_tp_paged_pool_bitexact():
    cfg = ModelConfig(
        arch="paged-tp-smoke", family="dense", n_layers=2, d_model=256,
        n_heads=8, n_kv_heads=8, head_dim=32, d_ff=512, vocab_size=512,
        pattern=(LayerKind("attn", "dense"),), dtype="float32")
    params = init_model(jax.random.PRNGKey(3), cfg)
    mk = lambda **kw: prepare_servable(params, cfg, ServingSpec(
        tile=(32, 32), sparsity=0.5, prune="oneshot",
        targets=ATTN_TARGETS, kv_layout="paged", kv_page_size=8, **kw))
    ref = mk()
    tp = mk(mesh_shape=(1, 8), partition="tp")
    eng_r, res_r = _drain(ref, PROMPTS[:2], max_slots=2)
    eng_t, res_t = _drain(tp, PROMPTS[:2], max_slots=2)
    for rr, rt in zip(res_r, res_t):
        assert rr.status == rt.status == "done"
        assert rr.tokens == rt.tokens
    # pool leaves shard kv-heads over "model", never the page axis
    leaves = jax.tree_util.tree_leaves_with_path(eng_t.cache)
    pool_leaves = [(p, x) for p, x in leaves
                   if str(getattr(p[-1], "key", "")).endswith("_pages")]
    assert pool_leaves
    for path, leaf in pool_leaves:
        spec = leaf.sharding.spec
        assert spec[0] is None                      # page axis replicated
        assert "model" in tuple(spec)               # heads sharded
    eng_t.verify_invariants()
