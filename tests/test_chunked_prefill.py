"""Chunked prefill parity: the SLO scheduler's sliced prompt admission
(engine ``sched=SchedSpec(max_chunk=...)``) must be BIT-EXACT against the
legacy one-shot admission for greedy decode on every decode-capable mixer
family, on dense AND paged KV.

Why parity holds: each chunk runs through ``models.api.prefill_suffix``
with positions ``start + arange(c)`` against the slot's already-resident
state -- dense rings attend-before-write over the concatenated (ring view
+ fresh chunk) K/V with exact-zero masked terms, MLA scatters latents then
expands, SSM/RG-LRU seed their inter-chunk scans with the slot's carried
state and real conv history. The einsum structure matches the one-shot
path, so greedy token streams are equal and logits agree to float32
tolerance (the PR 7 "numerically-equal-not-bitwise" contract).
"""
import jax
import numpy as np
import pytest

from repro.configs.base import LayerKind, ModelConfig
from repro.configs.registry import get_config
from repro.models import init_model
from repro.serving import SchedSpec, ServingSpec, prepare_servable

needs8 = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")

RNG = np.random.RandomState(7)

ATTN_TARGETS = ("attn/wq", "attn/wk", "attn/wv", "attn/wo")


def _attn_cfg():
    return ModelConfig(
        arch="chunk-attn-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=512,
        pattern=(LayerKind("attn", "dense"),), dtype="float32")


def _mla_cfg():
    return ModelConfig(
        arch="chunk-mla-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=512,
        kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
        pattern=(LayerKind("mla", "dense"),), dtype="float32")


def _windowed_cfg():
    """Mixed local+global attention: the chunk path must respect the ring
    hazard (attend-before-write) on the windowed layers."""
    return ModelConfig(
        arch="chunk-window-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=512,
        pattern=(LayerKind("attn", "dense", window=16),
                 LayerKind("attn", "dense")), dtype="float32")


CFGS = {
    "attn": _attn_cfg,
    "mla": _mla_cfg,
    "windowed": _windowed_cfg,
    # hybrid recurrent families: chunk continuation seeds the SSD scan /
    # RG-LRU recurrence with the slot's carried state + conv history
    "hybrid-ssm": lambda: get_config("mamba2_780m", smoke=True),
    "hybrid-rglru": lambda: get_config("recurrentgemma_9b", smoke=True),
}

SCHED = SchedSpec(max_chunk=8, token_budget=16)

# mixed lengths: shorter than one chunk, multi-chunk, chunk-boundary exact
PROMPTS_LENS = (5, 23, 3, 37)


def _servable(cfg, **kw):
    params = init_model(jax.random.PRNGKey(1), cfg)
    return prepare_servable(params, cfg, ServingSpec(
        tile=(16, 16), sparsity=0.5, prune="oneshot",
        targets=ATTN_TARGETS, **kw))


def _prompts(cfg, lens=PROMPTS_LENS):
    rng = np.random.RandomState(3)
    return [rng.randint(0, cfg.vocab_size, (n,)).tolist() for n in lens]


def _drain(sv, prompts, max_new=6, **kw):
    kw.setdefault("max_slots", 4)
    kw.setdefault("cache_len", 64)
    eng = sv.engine(max_queue=16, **kw)
    reqs = [eng.submit(list(p), max_new_tokens=max_new) for p in prompts]
    eng.run()
    return eng, reqs


def _assert_parity(base_reqs, sched_reqs, tag):
    for rb, rs in zip(base_reqs, sched_reqs):
        assert rb.status == rs.status == "done", (
            tag, rb.status, rs.status, rs.failure)
        assert rb.tokens == rs.tokens, (tag, rb.tokens, rs.tokens)


@pytest.mark.parametrize("family", sorted(CFGS))
def test_chunked_equals_oneshot_dense(family):
    cfg = CFGS[family]()
    sv = _servable(cfg)
    prompts = _prompts(cfg)
    _, base = _drain(sv, prompts)
    eng, chunked = _drain(sv, prompts, sched=SCHED)
    assert eng._chunking
    assert eng.stats.prefill_chunks > len(prompts)  # real multi-chunk work
    _assert_parity(base, chunked, family)
    eng.verify_invariants()


@pytest.mark.parametrize("family", ["attn", "mla"])
def test_chunked_equals_oneshot_paged(family):
    cfg = CFGS[family]()
    sv = _servable(cfg, kv_layout="paged", kv_page_size=8)
    prompts = _prompts(cfg)
    _, base = _drain(sv, prompts)
    eng, chunked = _drain(sv, prompts, sched=SCHED)
    assert eng.kv_layout == "paged" and eng._chunking
    _assert_parity(base, chunked, family + "+paged")
    assert eng.kv_stats()["peak_pages_used"] > 0
    eng.verify_invariants()


def test_chunk_boundary_on_window_edge():
    """Chunk boundaries landing exactly on the attention window edge (and
    on ring-wrap points) must not perturb the stream: prompt length ==
    k * window with max_chunk == window."""
    cfg = _windowed_cfg()                       # window = 16
    sv = _servable(cfg)
    prompts = _prompts(cfg, lens=(32, 16, 48))  # exact multiples of 16
    _, base = _drain(sv, prompts, max_new=8)
    eng, chunked = _drain(sv, prompts, max_new=8,
                          sched=SchedSpec(max_chunk=16, token_budget=16))
    _assert_parity(base, chunked, "window-edge")
    eng.verify_invariants()


def test_chunked_prefill_shares_prefix_pages():
    """The chunked admission path keeps the paged engine's prefix sharing:
    a completed request's full prompt pages publish at (chunked) prefill
    completion, and a later sharer serves its prefix from them -- matched
    at slot claim time, before any chunk runs. (Two requests admitted in
    the SAME window cannot share: publication happens at completion.)"""
    cfg = _attn_cfg()
    sv = _servable(cfg, kv_layout="paged", kv_page_size=8)
    shared = list(range(1, 33))
    prompts = [shared + [100, 101, 102], shared + [200, 201]]
    base_eng = sv.engine(max_slots=4, cache_len=64, max_queue=16)
    base = [base_eng.submit(p, max_new_tokens=6) for p in prompts]
    base_eng.run()
    eng = sv.engine(max_slots=4, cache_len=64, max_queue=16, sched=SCHED)
    first = eng.submit(prompts[0], max_new_tokens=6)
    eng.run()                                   # publish prompt pages
    second = eng.submit(prompts[1], max_new_tokens=6)
    eng.run()                                   # prefix hit via pages
    _assert_parity(base, [first, second], "prefix+chunk")
    assert eng.stats.prefix_hit_tokens >= 32
    assert eng.stats.prefilled_tokens < sum(len(p) for p in prompts)
    eng.verify_invariants()


def test_preempt_resume_of_half_prefilled_request():
    """A request preempted MID-PREFILL (slot held, pos still -1) restarts
    its prefill from scratch on re-admission and finishes with the exact
    greedy stream -- and never retains pages (retention requires generated
    tokens)."""
    cfg = _attn_cfg()
    sv = _servable(cfg)
    prompt = _prompts(cfg, lens=(40,))[0]
    # budget 4/window: the long prompt needs many windows to prefill
    eng = sv.engine(max_slots=1, cache_len=64, max_queue=16,
                    sched=SchedSpec(max_chunk=4, token_budget=4))
    a = eng.submit(prompt, max_new_tokens=6)
    for _ in range(3):
        eng.step()
    assert a.status == "active" and 0 < a.prefill_pos < a.prefill_target
    assert eng._pos[a.slot] == -1               # admitted but not decoding
    b = eng.submit([9, 8, 7], max_new_tokens=4, priority=10)
    eng.run()
    assert a.status == "done" and b.status == "done"
    assert a.n_preempted >= 1
    # oracle: the same request one-shot
    _, base = _drain(sv, [prompt])
    assert a.tokens == base[0].tokens
    eng.verify_invariants()


def test_budget_prevents_head_of_line_blocking():
    """With a token budget, a short prompt submitted behind a long one
    starts decoding before the long prefill completes (no HOL blocking);
    the legacy scheduler prefills the long prompt monolithically first."""
    cfg = _attn_cfg()
    sv = _servable(cfg)
    long_p = _prompts(cfg, lens=(48,))[0]
    eng = sv.engine(max_slots=2, cache_len=64, max_queue=16, sync_every=2,
                    sched=SchedSpec(max_chunk=8, token_budget=8,
                                    decode_priority=True))
    first_done_order = []
    a = eng.submit(long_p, max_new_tokens=4,
                   on_done=lambda rid, t: first_done_order.append("long"))
    b = eng.submit([5, 6, 7], max_new_tokens=4,
                   on_done=lambda rid, t: first_done_order.append("short"))
    eng.run()
    assert a.status == b.status == "done"
    assert first_done_order[0] == "short"
    # the short request got tokens while the long prefill was in flight
    assert b.first_token_at < a.first_token_at
    eng.verify_invariants()


def test_chunking_gate_falls_back_for_moe():
    """MoE routing is batch-global: the engine must silently fall back to
    one-shot admission (sched's other knobs stay live)."""
    cfg = get_config("qwen3_moe_235b_a22b", smoke=True)
    sv = _servable(cfg)
    eng = sv.engine(max_slots=2, cache_len=64, sched=SCHED)
    assert not eng._chunking
    r = eng.submit(list(range(1, 12)), max_new_tokens=4)
    eng.run()
    assert r.status == "done" and eng.stats.prefill_chunks == 0


def test_sched_spec_roundtrip_via_serving_spec():
    spec = ServingSpec(tile=(16, 16), sparsity=0.5, prune="oneshot",
                       targets=ATTN_TARGETS,
                       sched=SchedSpec(max_chunk=32, token_budget=64,
                                       fast_fail=True))
    back = ServingSpec.from_dict(spec.to_dict())
    assert back.sched == spec.sched
    cfg = _attn_cfg()
    params = init_model(jax.random.PRNGKey(1), cfg)
    sv = prepare_servable(params, cfg, spec)
    eng = sv.engine(max_slots=2, cache_len=64)
    assert eng.sched == spec.sched and eng._chunking    # spec-level default
    eng2 = sv.engine(max_slots=2, cache_len=64,
                     sched=SchedSpec(max_chunk=0))
    assert not eng2._chunking                           # kwarg overrides


@needs8
def test_chunked_parity_tp8():
    """Chunked prefill through the mesh suffix jit (out_shardings pinned)
    matches the unsharded stream bit-exactly."""
    cfg = ModelConfig(
        arch="chunk-tp-smoke", family="dense", n_layers=2, d_model=256,
        n_heads=8, n_kv_heads=8, head_dim=32, d_ff=512, vocab_size=512,
        pattern=(LayerKind("attn", "dense"),), dtype="float32")
    params = init_model(jax.random.PRNGKey(3), cfg)
    mk = lambda **kw: prepare_servable(params, cfg, ServingSpec(
        tile=(32, 32), sparsity=0.5, prune="oneshot",
        targets=ATTN_TARGETS, **kw))
    ref = mk()
    tp = mk(mesh_shape=(1, 8), partition="tp")
    prompts = _prompts(cfg, lens=(23, 5))
    _, base = _drain(ref, prompts, max_slots=2)
    eng, chunked = _drain(tp, prompts, max_slots=2, sched=SCHED)
    assert eng._chunking and eng.mesh is not None
    _assert_parity(base, chunked, "tp8")
    eng.verify_invariants()
