"""Per-arch smoke tests (reduced configs, one forward/train step, shape +
finiteness) and model-math equivalences (flash==full, local==masked-full,
SSD chunked==recurrence, decode==forward)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, get_config
from repro.launch.steps import make_train_step
from repro.models import decode_step, init_cache, init_model, model_forward
from repro.models.attention import (decode_attention, flash_attention,
                                    full_attention, local_attention)
from repro.optim.adamw import AdamWConfig, init_opt_state

RNG = np.random.RandomState(0)


def _batch(cfg, b=2, s=32, labels=True):
    out = {"tokens": jnp.asarray(RNG.randint(0, cfg.vocab_size, (b, s)))}
    if labels:
        out["labels"] = jnp.asarray(RNG.randint(0, cfg.vocab_size, (b, s)))
    if cfg.family == "audio":
        out["frames"] = jnp.asarray(
            RNG.randn(b, cfg.n_audio_ctx, cfg.d_model).astype(np.float32))
    if cfg.family == "vlm":
        out["mm_embeds"] = jnp.asarray(
            RNG.randn(b, cfg.n_patches, cfg.d_model).astype(np.float32))
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_forward(arch):
    cfg = get_config(arch, smoke=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    logits, aux = model_forward(params, cfg, batch)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ["deepseek_7b", "qwen3_moe_235b_a22b",
                                  "mamba2_780m", "recurrentgemma_9b",
                                  "bert_base"])
def test_arch_smoke_train_step(arch):
    cfg = get_config(arch, smoke=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    opt_cfg = AdamWConfig(peak_lr=1e-3, warmup_steps=1, total_steps=10)
    opt = init_opt_state(params, opt_cfg)
    step = jax.jit(make_train_step(cfg, opt_cfg))
    batch = _batch(cfg)
    params2, opt2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually moved
    delta = jax.tree_util.tree_reduce(
        lambda a, l: a + float(jnp.abs(l).sum()),
        jax.tree_util.tree_map(jnp.subtract, params2, params), 0.0)
    assert delta > 0


@pytest.mark.parametrize("arch", ["deepseek_7b", "gemma3_4b",
                                  "mamba2_780m", "recurrentgemma_9b"])
def test_decode_matches_forward(arch):
    cfg = get_config(arch, smoke=True)
    params = init_model(jax.random.PRNGKey(1), cfg)
    b, s = 2, 40
    toks = RNG.randint(0, cfg.vocab_size, (b, s))
    logits_fwd, _ = model_forward(params, cfg, {"tokens": jnp.asarray(toks)})
    cache = init_cache(params, cfg, b, s)
    step = jax.jit(lambda c, tok, pos: decode_step(params, c, cfg, tok, pos))
    errs = []
    for t in range(s):
        lg, cache = step(cache, jnp.asarray(toks[:, t:t + 1]), jnp.int32(t))
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - logits_fwd[:, t]))))
    assert max(errs) < 2e-3, max(errs)


class TestAttentionEquivalence:
    def _qkv(self, b=2, s=256, hq=4, hkv=2, d=32):
        q = jnp.asarray(RNG.randn(b, s, hq, d).astype(np.float32))
        k = jnp.asarray(RNG.randn(b, s, hkv, d).astype(np.float32))
        v = jnp.asarray(RNG.randn(b, s, hkv, d).astype(np.float32))
        return q, k, v

    def test_flash_equals_full_causal(self):
        q, k, v = self._qkv()
        a = full_attention(q, k, v, causal=True)
        f = flash_attention(q, k, v, causal=True, q_chunk=64, kv_chunk=64)
        np.testing.assert_allclose(np.asarray(f), np.asarray(a), atol=2e-5)

    def test_flash_equals_full_windowed(self):
        q, k, v = self._qkv()
        a = full_attention(q, k, v, causal=True, window=96)
        f = flash_attention(q, k, v, causal=True, window=96,
                            q_chunk=64, kv_chunk=32)
        np.testing.assert_allclose(np.asarray(f), np.asarray(a), atol=2e-5)

    def test_local_equals_full_windowed(self):
        q, k, v = self._qkv(s=256)
        for w in (32, 64, 128):
            a = full_attention(q, k, v, causal=True, window=w)
            l = local_attention(q, k, v, window=w)
            np.testing.assert_allclose(np.asarray(l), np.asarray(a),
                                       atol=2e-5, err_msg=f"window={w}")

    def test_flash_noncausal(self):
        q, k, v = self._qkv()
        a = full_attention(q, k, v, causal=False)
        f = flash_attention(q, k, v, causal=False, q_chunk=64, kv_chunk=64)
        np.testing.assert_allclose(np.asarray(f), np.asarray(a), atol=2e-5)

    def test_decode_ring_cache_matches_window(self):
        """Ring-buffered local cache == full recompute with window mask."""
        b, s, hq, hkv, d, w = 1, 48, 2, 1, 16, 16
        q_all = RNG.randn(b, s, hq, d).astype(np.float32)
        k_all = RNG.randn(b, s, hkv, d).astype(np.float32)
        v_all = RNG.randn(b, s, hkv, d).astype(np.float32)
        kc = jnp.zeros((b, w, hkv, d))
        vc = jnp.zeros((b, w, hkv, d))
        pm = jnp.full((w,), -1, jnp.int32)
        dec = jax.jit(lambda q, kc, vc, pm, t: decode_attention(
            q, kc, vc, pm, t, window=w))
        for t in range(s):
            slot = t % w
            kc = kc.at[:, slot].set(k_all[:, t])
            vc = vc.at[:, slot].set(v_all[:, t])
            pm = pm.at[slot].set(t)
            got = dec(jnp.asarray(q_all[:, t:t + 1]), kc, vc, pm, t)
            ref = full_attention(jnp.asarray(q_all[:, t:t + 1]),
                                 jnp.asarray(k_all[:, :t + 1]),
                                 jnp.asarray(v_all[:, :t + 1]),
                                 causal=True, window=w, q_offset=t)
            np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                       atol=1e-5, err_msg=f"t={t}")


def test_ssd_chunked_equals_recurrence():
    """Chunked SSD == step-by-step linear recurrence."""
    from repro.models.ssm import _ssd_chunked
    b, s, h, p, n = 1, 64, 2, 4, 8
    x = RNG.randn(b, s, h, p).astype(np.float32)
    dt = np.abs(RNG.randn(b, s, h)).astype(np.float32) * 0.5
    a_neg = -np.abs(RNG.randn(h)).astype(np.float32)
    da = dt * a_neg[None, None, :]
    bm = RNG.randn(b, s, n).astype(np.float32)
    cm = RNG.randn(b, s, n).astype(np.float32)
    y = np.asarray(_ssd_chunked(jnp.asarray(x), jnp.asarray(dt),
                                jnp.asarray(da), jnp.asarray(bm),
                                jnp.asarray(cm), chunk=16))
    # reference recurrence
    state = np.zeros((b, h, p, n), np.float32)
    ref = np.zeros_like(y)
    for t in range(s):
        decay = np.exp(da[:, t])[..., None, None]
        upd = np.einsum("bh,bhp,bn->bhpn", dt[:, t], x[:, t], bm[:, t])
        state = state * decay + upd
        ref[:, t] = np.einsum("bhpn,bn->bhp", state, cm[:, t])
    np.testing.assert_allclose(y, ref, rtol=1e-3, atol=1e-3)


def test_moe_capacity_drops_only_overflow():
    import dataclasses
    cfg = dataclasses.replace(get_config("qwen3_moe_235b_a22b", smoke=True),
                              capacity_factor=64.0)
    from repro.models.moe import apply_moe, init_moe
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(RNG.randn(2, 16, cfg.d_model).astype(np.float32))
    y, aux = apply_moe(p, x, cfg)
    assert y.shape == x.shape and bool(jnp.all(jnp.isfinite(y)))
    # with huge capacity, permutation-invariance: shuffling tokens shuffles y
    perm = RNG.permutation(16)
    y2, _ = apply_moe(p, x[:, perm], cfg)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y[:, perm]),
                               rtol=2e-3, atol=2e-3)


def test_int8_kv_cache_decode_close_to_exact():
    """Quantized decode cache (capacity fix, §Perf iter 5): logits within
    ~1% relative of the full-precision forward."""
    import dataclasses
    cfg0 = get_config("deepseek_7b", smoke=True)
    cfgq = dataclasses.replace(cfg0, kv_cache_quant=True)
    params = init_model(jax.random.PRNGKey(0), cfg0)
    b, s = 2, 32
    toks = RNG.randint(0, cfg0.vocab_size, (b, s))
    logits_fwd, _ = model_forward(params, cfg0, {"tokens": jnp.asarray(toks)})
    cache = init_cache(params, cfgq, b, s)
    step = jax.jit(lambda c, tok, pos: decode_step(params, c, cfgq, tok, pos))
    errs = []
    for t in range(s):
        lg, cache = step(cache, jnp.asarray(toks[:, t:t + 1]), jnp.int32(t))
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - logits_fwd[:, t]))))
    rel = max(errs) / float(jnp.abs(logits_fwd).max())
    assert rel < 0.02, rel
