"""Chaos suite: deterministic fault injection across the serving stack
(repro/runtime/chaos.py) and the invariants that must survive every fault
class:

  * no leaked or cross-contaminated slots (free + active always partition
    the slot space; a recycled slot behaves like a fresh cache);
  * queue conservation -- every submit() ends in EXACTLY ONE of
    done / failed / cancelled / shed;
  * blast-radius containment -- requests not targeted by a fault finish
    with BIT-IDENTICAL token streams to an uninjected reference run
    (per-slot compute is batch-row independent);
  * the engine stays serving after every fault (a fresh request completes
    with reference tokens).

Plus the loader robustness satellite: a truncated/corrupt ``packs.npz``
raises :class:`ServableLoadError` naming the offending leaf, and the
``servable.load_packs`` chaos site can corrupt the artifact a load is
about to trust.
"""
import zipfile

import jax
import numpy as np
import pytest

from repro.configs.base import LayerKind, ModelConfig
from repro.models import init_model
from repro.runtime.chaos import (SITE_ALLOC, SITE_LOAD_PACKS, SITE_PREFILL,
                                 SITE_SYNC, SITE_TRAIN_STEP, SITE_WINDOW,
                                 ChaosInjector, FaultInjector, Watchdog,
                                 poison_slot, straggle)
from repro.serving import (FailureReason, ServableLoadError, ServingSpec,
                           TERMINAL_STATES, load_servable, prepare_servable)

ATTN_TARGETS = ("attn/wq", "attn/wk", "attn/wv", "attn/wo")


def _cfg():
    return ModelConfig(
        arch="chaos-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=256,
        pattern=(LayerKind("attn", "dense"),), dtype="float32")


@pytest.fixture(scope="module")
def servable():
    cfg = _cfg()
    params = init_model(jax.random.PRNGKey(1), cfg)
    return prepare_servable(params, cfg, ServingSpec(
        tile=(16, 16), sparsity=0.5, prune="oneshot", targets=ATTN_TARGETS))


def _prompts(n):
    rng = np.random.RandomState(3)
    return [rng.randint(0, 256, (rng.randint(4, 9),)).tolist()
            for _ in range(n)]


def _reference_tokens(servable, prompts, max_new=6, sync_every=3):
    eng = servable.engine(max_slots=2, cache_len=64, sync_every=sync_every)
    hs = [eng.submit(p, max_new_tokens=max_new) for p in prompts]
    eng.run()
    assert all(h.done for h in hs)
    return [list(h.tokens) for h in hs]


# --------------------------------------------------------------------------
# injector + watchdog mechanics
# --------------------------------------------------------------------------

def test_injector_fires_deterministically_on_nth_hit():
    chaos = ChaosInjector()
    chaos.inject("site.a", at=2, exc=RuntimeError("boom"))
    chaos.fire("site.a")                    # hit 1: armed but not at N
    with pytest.raises(RuntimeError, match="boom"):
        chaos.fire("site.a")                # hit 2: fires
    chaos.fire("site.a")                    # hit 3: spent
    assert chaos.count("site.a") == 3
    assert chaos.fired("site.a") == 1
    assert [e.occurrence for e in chaos.log] == [2]


def test_injector_action_sees_ctx_and_times_window():
    chaos = ChaosInjector()
    seen = []
    chaos.inject("site.b", at=2, times=2, action=lambda ctx: seen.append(
        ctx["payload"]))
    for i in range(5):
        chaos.fire("site.b", payload=i)
    assert seen == [1, 2]                   # hits 2 and 3 (0-indexed payload)
    assert chaos.fired("site.b") == 2


def test_fault_injector_shim_raises_once_per_step():
    inj = FaultInjector(fail_at_steps=[3])
    for step in (1, 2):
        inj.maybe_fail(step)
    with pytest.raises(RuntimeError, match="step 3"):
        inj.maybe_fail(3)
    inj.maybe_fail(3)                       # replayed step: fires once only
    assert inj.chaos.count(SITE_TRAIN_STEP) == 4


def test_watchdog_detects_and_fires_once_per_section():
    import time
    events = []
    dog = Watchdog(0.03, on_stall=lambda label, s: events.append(label),
                   poll_s=0.005)
    try:
        dog.arm("slow")
        time.sleep(0.12)
        assert dog.disarm() > 0.03
        dog.arm("fast")
        elapsed = dog.disarm()
        assert elapsed < 0.03
        time.sleep(0.03)                    # disarmed: nothing fires
        assert events == ["slow"]
        assert len(dog.stalls) == 1 and dog.stalls[0][0] == "slow"
    finally:
        dog.close()


# --------------------------------------------------------------------------
# engine fault classes (parametrized against an uninjected reference)
# --------------------------------------------------------------------------

def _arm(chaos, fault):
    """Arm one named fault class; returns the FailureReason code the
    TARGETED request must fail with (None = no request should fail)."""
    if fault == "alloc":
        chaos.inject(SITE_ALLOC, at=2, exc=MemoryError("no slot memory"))
        return FailureReason.PREFILL_ERROR
    if fault == "prefill":
        chaos.inject(SITE_PREFILL, at=2, exc=RuntimeError("bad prefill"))
        return FailureReason.PREFILL_ERROR
    if fault == "nan-window":
        chaos.inject(SITE_WINDOW, at=2, action=poison_slot())
        return FailureReason.NONFINITE_LOGITS
    if fault == "window-error":
        chaos.inject(SITE_WINDOW, at=2, exc=RuntimeError("device lost"))
        return FailureReason.ENGINE_ERROR
    if fault == "straggler":
        chaos.inject(SITE_SYNC, at=1, action=straggle(0.05))
        return None
    raise AssertionError(fault)


@pytest.mark.parametrize(
    "fault", ["alloc", "prefill", "nan-window", "window-error", "straggler"])
def test_engine_survives_fault_class(servable, fault):
    """Every fault class: structured failures for targeted requests only,
    bit-identical tokens for everyone else, no slot leaks, queue conserved,
    engine reusable."""
    prompts = _prompts(4)
    ref = _reference_tokens(servable, prompts)
    chaos = ChaosInjector()
    code = _arm(chaos, fault)
    eng = servable.engine(max_slots=2, cache_len=64, sync_every=3,
                          chaos=chaos)
    hs = [eng.submit(p, max_new_tokens=6) for p in prompts]
    eng.run()

    # queue conservation: every submit reached exactly one terminal state
    for h in hs:
        assert h.status in TERMINAL_STATES
    failed = [h for h in hs if h.status == "failed"]
    if code is None:
        assert not failed
    else:
        assert failed, f"fault {fault!r} never failed a request"
        for h in failed:
            assert h.failure is not None and h.failure.code == code
        assert chaos.fired() >= 1
    # blast radius: untargeted requests match the uninjected run exactly
    for h, want in zip(hs, ref):
        if h.status == "done":
            assert h.tokens == want, (fault, h.req_id)
    # window-error fails only the requests in flight at that window;
    # later admissions (the queue at the time) must still complete
    if fault == "window-error":
        assert len(failed) <= 2 and sum(h.done for h in hs) >= 2
    # no leaked / duplicated slots
    eng.verify_invariants()
    assert eng.n_free == eng.max_slots and eng.n_active == 0
    assert (eng.stats.completed + eng.stats.failed + eng.stats.cancelled
            + eng.stats.shed == len(hs))

    # the engine keeps serving after the fault: fresh submissions (one of
    # them over a previously-faulted slot) reproduce the reference
    again = [eng.submit(p, max_new_tokens=6) for p in prompts[:2]]
    eng.run()
    for h, want in zip(again, ref[:2]):
        assert h.done and h.tokens == want


def test_slot_hygiene_under_mid_step_exception(servable):
    """A failure mid-step() leaks nothing: freed == fresh, and the SAME
    engine serves the failed request's prompt to reference tokens."""
    prompts = _prompts(2)
    ref = _reference_tokens(servable, prompts)
    chaos = ChaosInjector()
    chaos.inject(SITE_PREFILL, at=1, exc=RuntimeError("first admission"))
    eng = servable.engine(max_slots=2, cache_len=64, sync_every=3,
                          chaos=chaos)
    hs = [eng.submit(p, max_new_tokens=6) for p in prompts]
    eng.run()
    assert hs[0].status == "failed"
    assert hs[0].failure.code == FailureReason.PREFILL_ERROR
    assert hs[1].done and hs[1].tokens == ref[1]
    eng.verify_invariants()
    assert eng.n_free == eng.max_slots
    retry = eng.submit(prompts[0], max_new_tokens=6)
    eng.run()
    assert retry.done and retry.tokens == ref[0]
    assert eng.stats.prefills == 2          # failed admission never counted


# --------------------------------------------------------------------------
# loader robustness (ServableLoadError satellite)
# --------------------------------------------------------------------------

def _saved(servable, tmp_path):
    path = str(tmp_path / "sv")
    servable.save(path)
    return path


def test_load_servable_truncated_packs(servable, tmp_path):
    path = _saved(servable, tmp_path)
    npz = tmp_path / "sv" / "step_000000000" / "packs.npz"
    raw = npz.read_bytes()
    npz.write_bytes(raw[: len(raw) // 3])
    with pytest.raises(ServableLoadError, match="packs.npz"):
        load_servable(path)


def test_load_servable_missing_leaf_is_named(servable, tmp_path):
    path = _saved(servable, tmp_path)
    npz = tmp_path / "sv" / "step_000000000" / "packs.npz"
    with np.load(npz) as f:
        arrays = {k: f[k] for k in f.files}
    victim = sorted(k for k in arrays if k.endswith("_col_idx"))[0]
    del arrays[victim]
    np.savez(npz, **arrays)
    with pytest.raises(ServableLoadError, match=victim):
        load_servable(path)


def test_load_servable_corrupt_leaf_is_named(servable, tmp_path):
    """Bit-flip one member's compressed payload in place: np.load opens
    fine (lazy decompression), but reading that leaf must surface a
    ServableLoadError naming it -- not a zlib traceback."""
    path = _saved(servable, tmp_path)
    npz = tmp_path / "sv" / "step_000000000" / "packs.npz"
    with zipfile.ZipFile(npz) as z:
        victim = sorted(n for n in z.namelist() if "col_idx" in n)[0]
        info = z.getinfo(victim)
    raw = bytearray(npz.read_bytes())
    # corrupt bytes inside the member's data area (past its local header)
    start = info.header_offset + 80
    for i in range(start, min(start + 64, len(raw))):
        raw[i] ^= 0xFF
    npz.write_bytes(bytes(raw))
    with pytest.raises(ServableLoadError,
                       match=victim.removesuffix(".npy")):
        load_servable(path)


def test_load_servable_chaos_site_corrupts_bytes(servable, tmp_path):
    """The servable.load_packs site fires with the archive path BEFORE the
    bytes are trusted; a chaos action that corrupts them there must yield
    a structured load error, not a crash deeper in the codec."""
    path = _saved(servable, tmp_path)
    chaos = ChaosInjector()

    def corrupt(ctx):
        with open(ctx["path"], "r+b") as f:
            f.truncate(16)
    chaos.inject(SITE_LOAD_PACKS, at=1, action=corrupt)
    with pytest.raises(ServableLoadError):
        load_servable(path, chaos=chaos)
    assert chaos.fired(SITE_LOAD_PACKS) == 1


def test_load_servable_missing_meta(tmp_path):
    with pytest.raises(ServableLoadError, match="meta"):
        load_servable(str(tmp_path / "nothing-here"))


# --------------------------------------------------------------------------
# sharded (TP) lifecycle: the robustness layer over a mesh engine
# --------------------------------------------------------------------------

needs8 = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")

ALL_TARGETS = ("attn/wq", "attn/wk", "attn/wv", "attn/wo",
               "ffn/wi", "ffn/wg", "ffn/wo")


def _tp_cfg():
    return ModelConfig(
        arch="tp-chaos-smoke", family="dense", n_layers=2, d_model=256,
        n_heads=8, n_kv_heads=8, head_dim=32, d_ff=1024, vocab_size=1024,
        pattern=(LayerKind("attn", "dense"),), dtype="float32")


@needs8
def test_tp_engine_lifecycle_and_quarantine():
    """Deadline / cancel / preemption / backpressure / NaN-quarantine all
    hold on the tensor-parallel sharded path (mesh_shape=(1, 8)), with the
    unaffected slots bit-identical to an uninjected sharded run."""
    import time
    cfg = _tp_cfg()
    params = init_model(jax.random.PRNGKey(1), cfg)
    sv = prepare_servable(params, cfg, ServingSpec(
        tile=(32, 32), sparsity=0.7, prune="tied", targets=ALL_TARGETS,
        mesh_shape=(1, 8), partition="tp"))
    rng = np.random.RandomState(5)
    prompts = [rng.randint(0, 1024, (rng.randint(4, 8),)).tolist()
               for _ in range(3)]

    ref_eng = sv.engine(max_slots=3, cache_len=64, sync_every=2)
    refs = [ref_eng.submit(p, max_new_tokens=6) for p in prompts]
    ref_eng.run()
    assert all(h.done for h in refs)
    ref = [list(h.tokens) for h in refs]

    # NaN quarantine on the sharded cache
    eng = sv.engine(max_slots=3, cache_len=64, sync_every=2)
    hs = [eng.submit(p, max_new_tokens=6) for p in prompts]
    eng.step()
    eng.corrupt_slot(hs[1].slot)
    eng.run()
    assert hs[1].status == "failed"
    assert hs[1].failure.code == FailureReason.NONFINITE_LOGITS
    assert hs[0].done and hs[0].tokens == ref[0]
    assert hs[2].done and hs[2].tokens == ref[2]
    eng.verify_invariants()
    retry = eng.submit(prompts[1], max_new_tokens=6)
    eng.run()
    assert retry.done and retry.tokens == ref[1]

    # deadline + cancel + preemption + backpressure on one sharded engine
    eng2 = sv.engine(max_slots=1, cache_len=64, sync_every=2,
                     max_queue=2, overflow="reject")
    victim = eng2.submit(prompts[0], max_new_tokens=6, priority=0)
    eng2.step()
    assert victim.status == "active"
    vip = eng2.submit(prompts[1], max_new_tokens=6, priority=5)
    late = eng2.submit(prompts[2], max_new_tokens=6, deadline_s=0.0)
    shed = eng2.submit(prompts[2], max_new_tokens=6)
    assert shed.status == "shed"
    time.sleep(0.005)
    eng2.step()                             # preempt victim, admit vip
    assert victim.n_preempted == 1
    cancelled = eng2.submit(prompts[2], max_new_tokens=6)
    assert eng2.cancel(cancelled)
    eng2.run()
    assert vip.done and vip.tokens == ref[1]
    assert victim.done and victim.tokens == ref[0]   # resume == unpreempted
    assert late.status == "failed"
    assert late.failure.code == FailureReason.DEADLINE
    assert cancelled.status == "cancelled"
    eng2.verify_invariants()
    assert (eng2.stats.completed + eng2.stats.failed + eng2.stats.cancelled
            + eng2.stats.shed == 5)
