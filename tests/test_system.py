"""End-to-end behaviour of the paper's system: the integrated
prune -> compile(pack/specialize) -> execute flow and its co-design claims,
at test scale.

The paper's three findings, re-validated structurally:
  1. sparsity alone (dense execution of pruned weights) does NOT reduce
     compute -- only the BSR-aware path does;
  2. block-aligned sparsity maps to fewer stored tiles than irregular
     sparsity at the same ratio (the mechanism behind Table 1);
  3. task/pattern reuse grows as blocks shrink (the scheduler interaction).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.core import (SparsityConfig, count_unique_intrablock_patterns,
                        dense_to_bsr, prune_to_sparsity)
from repro.kernels import pack_bsr
from repro.models import bert as bert_mod
from repro.models import init_model
from repro.serving.export import export_bert_sparse

RNG = np.random.RandomState(7)


def test_finding1_bsr_support_required_for_compute_reduction():
    """80%-pruned weights: dense matmul flops unchanged; gather-BSR flops
    scale with density (counted via stored blocks)."""
    n = k = 512
    tile = (32, 32)
    w = RNG.randn(n, k).astype(np.float32)
    pruned, _ = prune_to_sparsity(jnp.asarray(w), tile, 0.8)
    dense_blocks = (n // tile[0]) * (k // tile[1])
    m = dense_to_bsr(np.asarray(pruned), tile)
    # dense execution touches all blocks; BSR touches ~20%
    assert m.nnzb <= dense_blocks * 0.25
    # and the pruned-dense matmul is numerically identical to BSR execution
    x = RNG.randn(8, k).astype(np.float32)
    from repro.kernels.ref import bsr_matmul_gather
    np.testing.assert_allclose(
        np.asarray(bsr_matmul_gather(jnp.asarray(x), m)),
        x @ np.asarray(pruned).T, rtol=1e-4, atol=1e-4)


def test_finding2_structured_beats_irregular_at_same_ratio():
    """Same 80% *element* sparsity: block-structured pruning yields far
    fewer stored kernel tiles than irregular pruning."""
    n = k = 512
    kernel_tile = (64, 64)
    w = RNG.randn(n, k).astype(np.float32)
    # irregular: zero 80% of elements
    flat = np.abs(w).ravel()
    thresh = np.quantile(flat, 0.8)
    irregular = np.where(np.abs(w) > thresh, w, 0.0)
    # structured: zero 80% of (32,32) blocks
    structured, _ = prune_to_sparsity(jnp.asarray(w), (64, 64), 0.8)
    pk_irr = pack_bsr(irregular, kernel_tile)
    pk_str = pack_bsr(np.asarray(structured), kernel_tile)
    assert pk_str.real_nnzt < 0.35 * pk_irr.real_nnzt, \
        (pk_str.real_nnzt, pk_irr.real_nnzt)


def test_finding3_pattern_reuse_grows_as_blocks_shrink():
    w = RNG.randn(256, 256).astype(np.float32)
    pruned, _ = prune_to_sparsity(jnp.asarray(w), (4, 4), 0.8)
    w = np.asarray(pruned)
    small = count_unique_intrablock_patterns(w, (4, 4)) / ((256 * 256) / 16)
    large = count_unique_intrablock_patterns(w, (64, 64)) / ((256 * 256) / 4096)
    assert small < large    # unique-pattern fraction rises with block size


def test_end_to_end_prune_export_serve():
    """The full paper flow on BERT: regularize->prune->export->serve."""
    from repro.core.pruner import oneshot_prune
    cfg = get_config("bert_base", smoke=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    sp = SparsityConfig(block_shape=(16, 16), sparsity=0.8,
                        targets=("attn/wq", "attn/wk", "attn/wv", "attn/wo",
                                 "ffn/wi", "ffn/wo"))
    pruned, masks = oneshot_prune(params, sp)
    sparse_params, packs = export_bert_sparse(pruned, cfg, tile=(16, 16))
    toks = jnp.asarray(RNG.randint(0, cfg.vocab_size, (2, 24)))
    got = bert_mod.forward(sparse_params, cfg, toks, packs=packs)
    want = bert_mod.forward(pruned, cfg, toks)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-3, atol=1e-3)
    mean_density = float(np.mean([p.density for p in packs.values()]))
    assert mean_density < 0.35
