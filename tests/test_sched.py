"""SLO scheduler robustness: deadline fast-fail at admission, graceful
overload shedding, watchdog stall snapshots, and the two open-loop chaos
sites (``engine.arrival_burst``, ``engine.prefill_chunk``).

Invariants under test (the PR 8 conservation contract, extended):

  * every submit() -- including re-entrant burst submissions fired from a
    chaos action INSIDE submit() -- reaches exactly one terminal state;
  * fast-fail and shedding happen BEFORE a prefill slot is consumed, from
    measured rates only (a cold engine never guesses);
  * a chunk fault fails only the targeted request; co-resident slots stay
    bit-identical to an uninjected run and the engine keeps serving.
"""
import time

import jax
import numpy as np
import pytest

from repro.configs.base import LayerKind, ModelConfig
from repro.models import init_model
from repro.runtime.chaos import (SITE_ARRIVAL_BURST, SITE_PREFILL_CHUNK,
                                 SITE_SYNC, ChaosInjector, straggle)
from repro.serving import (FailureReason, SchedSpec, ServingSpec,
                           TERMINAL_STATES, prepare_servable)

ATTN_TARGETS = ("attn/wq", "attn/wk", "attn/wv", "attn/wo")


def _cfg():
    return ModelConfig(
        arch="sched-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=256,
        pattern=(LayerKind("attn", "dense"),), dtype="float32")


@pytest.fixture(scope="module")
def servable():
    cfg = _cfg()
    params = init_model(jax.random.PRNGKey(1), cfg)
    return prepare_servable(params, cfg, ServingSpec(
        tile=(16, 16), sparsity=0.5, prune="oneshot", targets=ATTN_TARGETS))


def _prompts(n, lo=4, hi=9):
    rng = np.random.RandomState(3)
    return [rng.randint(0, 256, (rng.randint(lo, hi),)).tolist()
            for _ in range(n)]


def _warm(eng, prompt):
    """Run one request to completion so the engine has MEASURED
    prefill/decode rates (estimation refuses to guess before that)."""
    h = eng.submit(list(prompt), max_new_tokens=4)
    eng.run()
    assert h.done
    return h


def _pin_rates(eng, tok_per_s=1000.0):
    """Pin the measured-rate buckets to a known throughput so service
    estimates are deterministic in assertions (1 token == 1 step == 1ms)."""
    eng.stats.prefill_s = eng.stats.prefilled_tokens / tok_per_s
    eng.stats.decode_s = eng.stats.steps / tok_per_s


# --------------------------------------------------------------------------
# deadline fast-fail at admission
# --------------------------------------------------------------------------

def test_expired_deadline_fails_at_submission(servable):
    eng = servable.engine(max_slots=2, cache_len=64)
    h = eng.submit(_prompts(1)[0], max_new_tokens=4, deadline_s=-0.001)
    assert h.status == "failed"
    assert h.failure.code == FailureReason.DEADLINE
    assert "at submission" in h.failure.message
    assert eng.stats.deadline_misses == 1
    assert eng.n_active == 0 and eng.stats.prefills == 0  # never got a slot


def test_fast_fail_projects_from_measured_rates(servable):
    sched = SchedSpec(fast_fail=True)
    eng = servable.engine(max_slots=2, cache_len=64, sched=sched)
    prompt = list(range(1, 9))

    # cold engine: no measured rates, estimation must refuse to guess --
    # a tight-but-unexpired deadline is NOT fast-failed
    cold = eng.submit(prompt, max_new_tokens=4, deadline_s=30.0)
    assert cold.status == "queued"
    eng.run()
    assert cold.done

    _pin_rates(eng)                         # 1000 tok/s -> est ~0.012s
    doomed = eng.submit(prompt, max_new_tokens=4, deadline_s=0.001)
    assert doomed.status == "failed"
    assert doomed.failure.code == FailureReason.DEADLINE
    assert "projected" in doomed.failure.message
    ok = eng.submit(prompt, max_new_tokens=4, deadline_s=30.0)
    eng.run()
    assert ok.done
    assert eng.stats.deadline_misses == 1
    eng.verify_invariants()


# --------------------------------------------------------------------------
# graceful overload shedding
# --------------------------------------------------------------------------

def test_overload_sheds_lowest_priority_newest_first(servable):
    """With estimated queue delay over the bound, the LOWEST-priority
    NEWEST request is shed (status 'shed', OVERLOAD reason); higher SLO
    tiers keep their place even when they arrived later."""
    eng = servable.engine(max_slots=1, cache_len=64, sync_every=2,
                          sched=SchedSpec(max_queue_delay_s=0.020))
    prompt = list(range(1, 9))              # 8 tokens
    _warm(eng, prompt)
    _pin_rates(eng)                         # blocker est 0.016 < bound

    # hold the only slot so submissions queue up
    blocker = eng.submit(prompt, max_new_tokens=8)
    eng.step()
    assert blocker.status == "active"
    _pin_rates(eng)                         # re-pin: step() moved the rates

    a = eng.submit(prompt, max_new_tokens=4, priority=0)
    assert a.status == "queued"             # backlog 0.012 <= 0.020
    b = eng.submit(prompt, max_new_tokens=4, priority=5)
    assert b.status == "queued"             # survived: higher tier...
    assert a.status == "shed"               # ...the p0 request was shed
    assert a.failure.code == FailureReason.OVERLOAD
    assert "max_queue_delay_s" in a.failure.message
    c = eng.submit(prompt, max_new_tokens=4, priority=0)
    assert c.status == "shed"               # newest lowest tier sheds itself
    assert b.status == "queued"
    eng.run()
    assert blocker.done and b.done
    assert eng.stats.shed == 2
    eng.verify_invariants()
    for h in (blocker, a, b, c):
        assert h.status in TERMINAL_STATES


def test_cold_engine_never_sheds_at_submission(servable):
    """No measured rates -> no estimate -> submission-time shedding must
    not trigger no matter how tight the bound (estimation never guesses).
    Once the first completion measures real rates, the absurd bound DOES
    shed the backlog -- and every request still reaches exactly one
    terminal state."""
    eng = servable.engine(max_slots=1, cache_len=64,
                          sched=SchedSpec(max_queue_delay_s=1e-9))
    hs = [eng.submit(p, max_new_tokens=4) for p in _prompts(4)]
    assert all(h.status in ("queued", "active") for h in hs)
    eng.run()
    for h in hs:
        assert h.status in TERMINAL_STATES
    assert hs[0].done                       # the first admission completed
    shed = [h for h in hs if h.status == "shed"]
    assert shed and all(h.failure.code == FailureReason.OVERLOAD
                        for h in shed)
    eng.verify_invariants()


# --------------------------------------------------------------------------
# watchdog stall snapshot
# --------------------------------------------------------------------------

def test_watchdog_snapshot_in_stats_dict(servable):
    """A stalled window promotes queue/active state into
    stats_dict()['watchdog'] (and still forwards to the user callback)."""
    chaos = ChaosInjector()
    chaos.inject(SITE_SYNC, at=1, action=straggle(0.08))
    seen = []
    eng = servable.engine(max_slots=1, cache_len=64, max_queue=8,
                          watchdog_timeout_s=0.02, chaos=chaos,
                          on_stall=lambda label, s: seen.append(label))
    try:
        hs = [eng.submit(p, max_new_tokens=4) for p in _prompts(3)]
        eng.run()
        assert all(h.done for h in hs)
        assert eng.stats.watchdog_stalls >= 1
        assert seen and seen[0] == "decode-window"
        snap = eng.stats_dict()["watchdog"]
        assert snap["site"] == "decode-window"
        assert snap["elapsed_s"] > 0.02
        # the straggling sync point still had work in the system (the sync
        # fires after the window's emits, so the decoder itself may already
        # be finalized -- but the max_slots=1 backlog is still queued)
        assert snap["n_active"] + snap["n_queued"] >= 1
        for row in snap["active"] + snap["queued"]:
            assert {"req_id", "status", "prefill_pos", "prefill_target",
                    "n_generated", "age_s"} <= set(row)
    finally:
        eng.close()


# --------------------------------------------------------------------------
# chaos sites: engine.arrival_burst / engine.prefill_chunk
# --------------------------------------------------------------------------

def test_arrival_burst_action_conserves_every_submission(servable):
    """A chaos action that re-entrantly submits a burst from INSIDE
    submit(): every request -- original and burst -- reaches exactly one
    terminal state (at=1, times=1: nested fires don't re-trigger)."""
    chaos = ChaosInjector()
    burst = []

    def storm(ctx):
        eng = ctx["engine"]
        burst.extend(eng.submit([7, 7, 7], max_new_tokens=3)
                     for _ in range(5))
    chaos.inject(SITE_ARRIVAL_BURST, at=1, action=storm)
    eng = servable.engine(max_slots=2, cache_len=64, max_queue=4,
                          overflow="reject", chaos=chaos)
    hs = [eng.submit(p, max_new_tokens=4) for p in _prompts(3)]
    eng.run()
    assert chaos.fired(SITE_ARRIVAL_BURST) == 1
    all_reqs = burst + hs
    assert len(all_reqs) == 8
    for h in all_reqs:
        assert h.status in TERMINAL_STATES, h.req_id
    # the burst overflowed max_queue=4: some shed, the rest completed
    assert any(h.status == "shed" for h in burst)
    assert (eng.stats.completed + eng.stats.failed + eng.stats.cancelled
            + eng.stats.shed == len(all_reqs))
    eng.verify_invariants()


def test_arrival_burst_exception_sheds_only_that_submission(servable):
    chaos = ChaosInjector()
    chaos.inject(SITE_ARRIVAL_BURST, at=2, exc=RuntimeError("ingest down"))
    eng = servable.engine(max_slots=2, cache_len=64, chaos=chaos)
    prompts = _prompts(3)
    hs = [eng.submit(p, max_new_tokens=4) for p in prompts]
    assert hs[1].status == "shed"
    assert hs[1].failure.code == FailureReason.OVERLOAD
    assert "ingest" in hs[1].failure.message
    eng.run()
    assert hs[0].done and hs[2].done
    eng.verify_invariants()


def test_prefill_chunk_fault_contains_blast_radius(servable):
    """An exception raised at a chunk dispatch fails ONLY that request
    (PREFILL_ERROR, slot + state released); the co-resident request's
    stream is bit-identical to an uninjected chunked run, and the same
    engine serves the faulted prompt afterwards."""
    sched = SchedSpec(max_chunk=8, token_budget=32)
    long_p = _prompts(1, lo=24, hi=25)[0]   # needs multiple chunks
    short_p = _prompts(1)[0]

    ref_eng = servable.engine(max_slots=2, cache_len=64, sched=sched)
    refs = [ref_eng.submit(p, max_new_tokens=5) for p in (long_p, short_p)]
    ref_eng.run()
    assert all(h.done for h in refs)

    chaos = ChaosInjector()
    chaos.inject(SITE_PREFILL_CHUNK, at=2, exc=RuntimeError("chunk lost"))
    eng = servable.engine(max_slots=2, cache_len=64, sched=sched,
                          chaos=chaos)
    hs = [eng.submit(p, max_new_tokens=5) for p in (long_p, short_p)]
    eng.run()
    # the long prompt's second chunk faulted
    failed = [h for h in hs if h.status == "failed"]
    assert len(failed) == 1
    assert failed[0].failure.code == FailureReason.PREFILL_ERROR
    survivor = hs[0] if hs[1] is failed[0] else hs[1]
    want = refs[0] if hs[1] is failed[0] else refs[1]
    assert survivor.done and survivor.tokens == want.tokens
    eng.verify_invariants()
    assert eng.n_free == eng.max_slots and eng.n_active == 0

    retry = eng.submit(failed[0].prompt.tolist(), max_new_tokens=5)
    eng.run()
    ref_retry = refs[0] if failed[0] is hs[0] else refs[1]
    assert retry.done and retry.tokens == ref_retry.tokens
    assert eng.stats.prefill_chunks > 0
    eng.verify_invariants()


def test_prefill_chunk_straggler_trips_chunk_watchdog(servable):
    """straggle() at the chunk site stalls the armed 'prefill-chunk'
    section; the watchdog snapshot shows the mid-prefill row."""
    chaos = ChaosInjector()
    chaos.inject(SITE_PREFILL_CHUNK, at=2, action=straggle(0.08))
    eng = servable.engine(max_slots=1, cache_len=64,
                          watchdog_timeout_s=0.02, chaos=chaos,
                          sched=SchedSpec(max_chunk=8, token_budget=8))
    try:
        h = eng.submit(list(range(1, 25)), max_new_tokens=4)
        eng.run()
        assert h.done
        assert eng.stats.watchdog_stalls >= 1
        snap = eng.stats_dict()["watchdog"]
        assert snap["site"] == "prefill-chunk"
        assert any(r["prefill_target"] > 0 for r in snap["active"])
    finally:
        eng.close()
