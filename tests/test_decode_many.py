"""Fused multi-token decode (models.api.decode_many + engine sync_every):
the K-step on-device loop must reproduce the per-step loop exactly --
token-for-token -- for every decode-capable mixer family, through mixed
prompt lengths, EOS mid-window, slot recycling at sync boundaries, and
seeded temperature/top-k sampling (same base key => identical tokens
between fused and unfused paths; models/sampling.py)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LayerKind, ModelConfig
from repro.configs.registry import get_config
from repro.models import api as model_api
from repro.models import init_model
from repro.models.sampling import sample_tokens
from repro.serving import ServingSpec, prepare_servable

RNG = np.random.RandomState(0)

ATTN_TARGETS = ("attn/wq", "attn/wk", "attn/wv", "attn/wo")


def _servable(cfg, seed=1, sparsity=0.5):
    params = init_model(jax.random.PRNGKey(seed), cfg)
    return prepare_servable(params, cfg, ServingSpec(
        tile=(16, 16), sparsity=sparsity, prune="oneshot",
        targets=ATTN_TARGETS))


def _mla_dense_cfg():
    return ModelConfig(
        arch="mla-dense-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=512,
        kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
        pattern=(LayerKind("mla", "dense"),), dtype="float32")


def _run_engine(servable, prompts, max_new, sync_every, *, cache_len=64,
                max_slots=None, frames=None, **engine_kw):
    eng = servable.engine(max_slots=max_slots or len(prompts),
                          cache_len=cache_len, sync_every=sync_every,
                          **engine_kw)
    handles = [eng.submit(p, max_new_tokens=max_new,
                          frames=None if frames is None else frames[i])
               for i, p in enumerate(prompts)]
    eng.run()
    assert all(h.done for h in handles)
    return eng, handles


# --------------------------------------------------------------------------
# fused == per-step, per family (mixed lengths + recycling at sync points)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["deepseek_7b", "mamba2_780m",
                                  "recurrentgemma_9b"])
def test_fused_matches_per_step(arch):
    """6 mixed-length requests through 2 slots: admission, fused windows,
    mid-window completion, slot recycling at sync boundaries -- all token
    streams must equal the per-step engine's."""
    cfg = get_config(arch, smoke=True)
    servable = _servable(cfg)
    prompts = [RNG.randint(0, cfg.vocab_size, (L,)).tolist()
               for L in (3, 11, 7, 5, 9, 4)]
    _, ref = _run_engine(servable, prompts, 6, 1, max_slots=2)
    eng, got = _run_engine(servable, prompts, 6, 4, max_slots=2)
    for h_ref, h_got in zip(ref, got):
        assert h_got.tokens == h_ref.tokens
    # the fused engine really fused: fewer dispatches than decode steps
    assert eng.stats.windows < eng.stats.steps


def test_fused_matches_per_step_mla():
    cfg = _mla_dense_cfg()
    servable = _servable(cfg)
    prompts = [RNG.randint(0, cfg.vocab_size, (L,)).tolist()
               for L in (4, 9, 13)]
    _, ref = _run_engine(servable, prompts, 5, 1)
    _, got = _run_engine(servable, prompts, 5, 8)
    for h_ref, h_got in zip(ref, got):
        assert h_got.tokens == h_ref.tokens


def test_fused_matches_per_step_moe_high_capacity():
    cfg = dataclasses.replace(get_config("deepseek_v2_lite_16b", smoke=True),
                              capacity_factor=64.0)
    servable = _servable(cfg)
    prompts = [RNG.randint(0, cfg.vocab_size, (L,)).tolist() for L in (3, 8)]
    _, ref = _run_engine(servable, prompts, 4, 1, cache_len=32)
    _, got = _run_engine(servable, prompts, 4, 4, cache_len=32)
    for h_ref, h_got in zip(ref, got):
        assert h_got.tokens == h_ref.tokens


def test_fused_matches_per_step_audio():
    cfg = get_config("whisper_base", smoke=True)
    params = init_model(jax.random.PRNGKey(3), cfg)
    servable = prepare_servable(params, cfg, ServingSpec(tile=(16, 16)))
    frames = [RNG.randn(cfg.n_audio_ctx, cfg.d_model).astype(np.float32)
              for _ in range(3)]
    prompts = [RNG.randint(0, cfg.vocab_size, (L,)).tolist()
               for L in (2, 6, 4)]
    _, ref = _run_engine(servable, prompts, 4, 1, cache_len=32,
                         frames=frames)
    _, got = _run_engine(servable, prompts, 4, 4, cache_len=32,
                         frames=frames)
    for h_ref, h_got in zip(ref, got):
        assert h_got.tokens == h_ref.tokens


def test_eos_mid_window():
    """EOS sampled inside a fused window must stop that slot exactly there
    (emitted tokens cut at the EOS token) while co-resident slots run on."""
    cfg = get_config("deepseek_7b", smoke=True)
    servable = _servable(cfg)
    prompts = [RNG.randint(0, cfg.vocab_size, (L,)).tolist() for L in (3, 7)]
    _, ref = _run_engine(servable, prompts, 8, 1)
    eos = ref[0].tokens[2]      # forces a stop 3 tokens in, mid-window
    eng1 = servable.engine(max_slots=2, cache_len=64, sync_every=1)
    a1 = eng1.submit(prompts[0], max_new_tokens=8, eos_id=eos)
    b1 = eng1.submit(prompts[1], max_new_tokens=8)
    eng1.run()
    eng8 = servable.engine(max_slots=2, cache_len=64, sync_every=8)
    a8 = eng8.submit(prompts[0], max_new_tokens=8, eos_id=eos)
    b8 = eng8.submit(prompts[1], max_new_tokens=8)
    eng8.run()
    assert a8.tokens == a1.tokens and a8.tokens[-1] == eos
    assert len(a8.tokens) <= 4
    assert b8.tokens == b1.tokens and len(b8.tokens) == 8


# --------------------------------------------------------------------------
# model-level decode_many == decode_step loop (cache state included)
# --------------------------------------------------------------------------

def test_decode_many_equals_step_loop():
    cfg = get_config("deepseek_7b", smoke=True)
    params = init_model(jax.random.PRNGKey(5), cfg)
    b, k_steps = 3, 5
    tok0 = jnp.asarray(RNG.randint(0, cfg.vocab_size, (b, 1)), jnp.int32)
    pos0 = jnp.asarray([0, 3, -1], jnp.int32)   # mixed progress + inactive

    cache_a = model_api.init_cache(params, cfg, b, 32)
    cache_b = model_api.init_cache(params, cfg, b, 32)
    toks, valid, state = model_api.decode_many(
        params, cache_a, cfg, tok0, pos0, k_steps)

    tok, pos = tok0, pos0
    ref_toks = []
    for _ in range(k_steps):
        logits, cache_b = model_api.decode_step(params, cache_b, cfg, tok,
                                                pos)
        nxt = jnp.argmax(logits[:, 0, :], axis=-1).astype(jnp.int32)
        active = pos >= 0
        nxt = jnp.where(active, nxt, 0)
        ref_toks.append(np.asarray(nxt))
        pos = jnp.where(active, pos + 1, pos)
        tok = jnp.where(active, nxt, tok[:, 0])[:, None]

    np.testing.assert_array_equal(np.asarray(toks), np.stack(ref_toks))
    np.testing.assert_array_equal(np.asarray(valid),
                                  np.stack([[True, True, False]] * k_steps))
    # carried caches must be state-identical: one more step agrees <= 1e-5
    lg_a, _ = model_api.decode_step(params, state["cache"], cfg,
                                    state["token"], state["pos"])
    lg_b, _ = model_api.decode_step(params, cache_b, cfg, tok, pos)
    np.testing.assert_allclose(np.asarray(lg_a[:2]), np.asarray(lg_b[:2]),
                               atol=1e-5)


def test_decode_many_remaining_budget():
    """A slot whose budget runs out mid-window self-deactivates: exactly
    ``remaining`` tokens valid, pos -1 afterwards."""
    cfg = get_config("deepseek_7b", smoke=True)
    params = init_model(jax.random.PRNGKey(6), cfg)
    cache = model_api.init_cache(params, cfg, 2, 32)
    tok0 = jnp.asarray(RNG.randint(0, cfg.vocab_size, (2, 1)), jnp.int32)
    toks, valid, state = model_api.decode_many(
        params, cache, cfg, tok0, jnp.asarray([0, 0], jnp.int32), 6,
        remaining=jnp.asarray([2, 8], jnp.int32))
    v = np.asarray(valid)
    assert v[:, 0].sum() == 2 and v[:, 1].sum() == 6
    assert np.asarray(state["pos"])[0] == -1        # budget spent -> inactive
    assert np.asarray(state["pos"])[1] > 0          # budget left -> still live
    assert np.asarray(state["remaining"])[1] == 2


# --------------------------------------------------------------------------
# seeded sampling parity
# --------------------------------------------------------------------------

@pytest.mark.parametrize("temperature,top_k", [(0.7, 0), (1.0, 5)])
def test_seeded_sampling_parity(temperature, top_k):
    """Same base seed => identical sampled continuations between the fused
    and per-step engines (slot+position-keyed PRNG), and a different seed
    actually changes them."""
    cfg = get_config("deepseek_7b", smoke=True)
    servable = _servable(cfg)
    prompts = [RNG.randint(0, cfg.vocab_size, (L,)).tolist()
               for L in (3, 11, 7)]
    kw = dict(temperature=temperature, top_k=top_k)
    _, ref = _run_engine(servable, prompts, 6, 1, seed=7, **kw)
    _, got = _run_engine(servable, prompts, 6, 4, seed=7, **kw)
    for h_ref, h_got in zip(ref, got):
        assert h_got.tokens == h_ref.tokens
    _, other = _run_engine(servable, prompts, 6, 4, seed=8, **kw)
    assert any(a.tokens != b.tokens for a, b in zip(got, other))


def test_servable_decode_many_public_api():
    """The non-donating Servable.decode_many: same contract as the model
    API, usable without an engine (docs/API.md)."""
    cfg = get_config("deepseek_7b", smoke=True)
    servable = _servable(cfg)
    cache = servable.init_cache(2, 32)
    tok = jnp.asarray(RNG.randint(0, cfg.vocab_size, (2, 1)), jnp.int32)
    toks, valid, state = servable.decode_many(
        cache, tok, jnp.asarray([0, 0], jnp.int32), 4)
    assert toks.shape == (4, 2) and bool(np.all(np.asarray(valid)))
    # the input cache was not donated: still usable
    logits, _ = servable.decode_step(cache, tok, jnp.int32(0))
    assert logits.shape == (2, 1, cfg.vocab_size)


def test_sample_tokens_greedy_and_topk():
    logits = jnp.asarray(RNG.randn(4, 32).astype(np.float32))
    pos = jnp.asarray([0, 1, 2, 3], jnp.int32)
    key = jax.random.PRNGKey(0)
    greedy = sample_tokens(logits, key, pos, temperature=0.0)
    np.testing.assert_array_equal(np.asarray(greedy),
                                  np.argmax(np.asarray(logits), axis=-1))
    # top-k samples must come from the k largest entries per row
    k = 3
    sampled = np.asarray(sample_tokens(logits, key, pos, temperature=1.0,
                                       top_k=k))
    topk = np.argsort(np.asarray(logits), axis=-1)[:, -k:]
    for i in range(4):
        assert sampled[i] in topk[i]
