# NOTE: no XLA_FLAGS here on purpose -- smoke tests and benches must see the
# real single CPU device; only launch/dryrun.py (separate process) fakes 512.
import pytest


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running (subprocess compiles)")
