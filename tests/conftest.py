# NOTE: no XLA_FLAGS here on purpose -- by default smoke tests and benches
# see the real single CPU device; only launch/dryrun.py (separate process)
# fakes 512. The ci.yml `devices: 8` matrix leg exports
# XLA_FLAGS=--xla_force_host_platform_device_count=8 for the WHOLE run so
# the mesh-path tests (tests/test_sharded_serving.py) execute multi-device;
# under the plain run those tests skip.
import pytest


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running (subprocess compiles)")
