"""GPipe-style pipeline parallelism: subprocess with 4 fake devices."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

CODE = """
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=4'
import jax, jax.numpy as jnp, numpy as np
from repro.launch.pipeline import pipeline_apply

mesh = jax.make_mesh((4,), ('pipe',))
rng = np.random.RandomState(0)
S, MB, D = 4, 8, 16
ws = jnp.asarray(rng.randn(S, D, D).astype(np.float32) * 0.3)
xs = jnp.asarray(rng.randn(6, MB, D).astype(np.float32))  # 6 microbatches

def layer_fn(p, x):
    return jnp.tanh(x @ p['w'])

out = pipeline_apply(layer_fn, {'w': ws}, xs, mesh, axis='pipe')
# reference: sequential through all 4 stages
ref = xs
for i in range(S):
    ref = jnp.tanh(ref @ ws[i])
err = float(jnp.max(jnp.abs(out - ref)))
print('ERR', err)
assert err < 1e-5, err
print('PIPELINE OK')
"""


@pytest.mark.slow
def test_pipeline_matches_sequential():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run([sys.executable, "-c", CODE], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "PIPELINE OK" in r.stdout
