"""Request-lifecycle robustness of the serving engine (docs/API.md §Engine
robustness): structured submission rejection, per-request deadlines,
cancellation, priority preemption with prefill-resume, bounded-queue
backpressure policies, non-finite quarantine, and the stuck-window
watchdog -- all enforced at window-sync points so the fused decode window
stays one jitted scan.

The cross-cutting invariant, asserted throughout: every submit() ends in
EXACTLY ONE terminal state (done / failed / cancelled / shed), no slot
leaks, and the failure of one request never perturbs the token streams of
co-resident requests (per-slot compute is batch-row independent, so
'unaffected' means bit-identical, not approximately equal).
"""
import time

import jax
import numpy as np
import pytest

from repro.configs.base import LayerKind, ModelConfig
from repro.models import init_model
from repro.runtime import chaos as chaos_mod
from repro.serving import (FailureReason, ServingSpec, TERMINAL_STATES,
                           prepare_servable)

RNG = np.random.RandomState(7)

ATTN_TARGETS = ("attn/wq", "attn/wk", "attn/wv", "attn/wo")


def _cfg():
    return ModelConfig(
        arch="lifecycle-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=256,
        pattern=(LayerKind("attn", "dense"),), dtype="float32")


@pytest.fixture(scope="module")
def servable():
    cfg = _cfg()
    params = init_model(jax.random.PRNGKey(1), cfg)
    return prepare_servable(params, cfg, ServingSpec(
        tile=(16, 16), sparsity=0.5, prune="oneshot", targets=ATTN_TARGETS))


def _prompts(n, lo=4, hi=10):
    rng = np.random.RandomState(11)
    return [rng.randint(0, 256, (rng.randint(lo, hi),)).tolist()
            for _ in range(n)]


def _reference(servable, prompts, max_new=8, **kw):
    """Uninjected greedy token streams, one engine per call (fresh slots)."""
    eng = servable.engine(max_slots=len(prompts), cache_len=64, **kw)
    hs = [eng.submit(p, max_new_tokens=max_new) for p in prompts]
    eng.run()
    assert all(h.done for h in hs)
    return [list(h.tokens) for h in hs]


def _assert_conserved(eng, handles):
    """Queue conservation + slot hygiene after a drain."""
    for h in handles:
        assert h.status in TERMINAL_STATES, (h.req_id, h.status)
    assert eng.n_active == 0 and eng.n_queued == 0
    assert eng.n_free == eng.max_slots
    eng.verify_invariants()
    st = eng.stats
    assert (st.completed + st.failed + st.cancelled + st.shed
            == len(handles))


# --------------------------------------------------------------------------
# deadlines + cancellation (sync-point enforcement)
# --------------------------------------------------------------------------

def test_deadline_expires_queued_request(servable):
    """An already-expired deadline fails the request before admission --
    it never occupies a slot."""
    eng = servable.engine(max_slots=1, cache_len=64, sync_every=4)
    good = eng.submit(_prompts(1)[0], max_new_tokens=4)
    late = eng.submit(_prompts(2)[1], max_new_tokens=4, deadline_s=0.0)
    time.sleep(0.005)
    eng.run()
    assert good.done
    assert late.status == "failed"
    assert late.failure.code == FailureReason.DEADLINE
    assert late.tokens == [] and late.n_generated == 0
    assert eng.stats.deadline_misses == 1
    _assert_conserved(eng, [good, late])


def test_deadline_expires_active_request_between_windows(servable):
    """Deadline enforcement on an IN-FLIGHT request happens at the next
    window-sync point: tokens generated so far stay on the handle, the
    slot frees, co-resident requests are untouched (fused sync_every>1)."""
    [ref] = _reference(servable, _prompts(1), max_new=12)
    eng = servable.engine(max_slots=2, cache_len=64, sync_every=3)
    other = eng.submit(_prompts(1)[0], max_new_tokens=12)
    doomed = eng.submit(_prompts(2)[1], max_new_tokens=12, deadline_s=60.0)
    assert eng.step()                       # admit both + one fused window
    assert doomed.status == "active" and doomed.n_generated > 0
    partial = list(doomed.tokens)
    doomed.deadline_at = time.monotonic() - 1.0     # force expiry
    eng.run()
    assert doomed.status == "failed"
    assert doomed.failure.code == FailureReason.DEADLINE
    assert doomed.tokens[:len(partial)] == partial
    assert other.done and other.tokens == ref      # bit-identical neighbor
    _assert_conserved(eng, [other, doomed])


def test_cancel_queued_and_active(servable):
    [ref] = _reference(servable, _prompts(1), max_new=10)
    eng = servable.engine(max_slots=1, cache_len=64, sync_every=2)
    running = eng.submit(_prompts(1)[0], max_new_tokens=10)
    queued = eng.submit(_prompts(2)[1], max_new_tokens=10)
    # queued: cancels immediately, before ever holding a slot
    assert eng.cancel(queued)
    assert queued.status == "cancelled" and queued.slot == -1
    assert queued.failure.code == FailureReason.CANCELLED
    # active: flagged now, honored at the next sync point
    assert eng.step()
    got = running.n_generated
    assert running.status == "active" and got > 0
    assert eng.cancel(running)
    assert running.status == "active"       # not yet -- sync-point action
    eng.step()
    assert running.status == "cancelled"
    assert running.tokens == ref[:len(running.tokens)]  # kept partial output
    assert len(running.tokens) >= got
    # terminal handles cannot be re-cancelled
    assert not eng.cancel(running) and not eng.cancel(queued)
    assert eng.stats.cancelled == 2
    _assert_conserved(eng, [running, queued])
    # the engine is still serving after cancellations
    again = eng.submit(_prompts(1)[0], max_new_tokens=10)
    eng.run()
    assert again.done and again.tokens == ref


# --------------------------------------------------------------------------
# priority + preemption
# --------------------------------------------------------------------------

def test_priority_orders_admission(servable):
    """Higher priority admits first; FIFO within a class."""
    order = []
    eng = servable.engine(max_slots=1, cache_len=64, sync_every=2)
    hs = [eng.submit(p, max_new_tokens=3, priority=pr,
                     on_done=lambda rid, toks: order.append(rid))
          for p, pr in zip(_prompts(4), (0, 1, 0, 1))]
    eng.run()
    assert all(h.done for h in hs)
    assert order == [hs[1].req_id, hs[3].req_id, hs[0].req_id, hs[2].req_id]


def test_preemption_resumes_via_prefill(servable):
    """A strictly-higher-priority submission evicts the low-priority
    in-flight request; the victim resumes by prefilling prompt + generated
    tokens and its final greedy stream is EXACTLY the uninterrupted one."""
    prompts = _prompts(2)
    [ref_victim, ref_vip] = [_reference(servable, [p], max_new=10)[0]
                             for p in prompts]
    eng = servable.engine(max_slots=1, cache_len=64, sync_every=2)
    victim = eng.submit(prompts[0], max_new_tokens=10, priority=0)
    eng.step()                              # admit + 1 window (2 tokens)
    assert victim.status == "active" and 0 < victim.n_generated < 10
    vip = eng.submit(prompts[1], max_new_tokens=10, priority=5)
    eng.step()                              # sync point: preempt + admit vip
    assert vip.status == "active"
    assert victim.status == "queued" and victim.slot == -1
    assert victim.n_preempted == 1
    eng.verify_invariants()
    eng.run()
    assert vip.done and vip.tokens == ref_vip
    assert victim.done and victim.tokens == ref_victim
    assert eng.stats.preemptions == 1
    _assert_conserved(eng, [victim, vip])


def test_paged_preemption_resumes_without_reprefill(servable):
    """Under kv_layout='paged' a preempted victim's pages stay allocated
    (refcount held in _saved_pages), so re-admission re-attaches the page
    table and decodes on -- the SAME tokens as the dense resume-by-prefill
    path, but with strictly fewer prefilled tokens and page_resumes > 0."""
    cfg = _cfg()
    params = init_model(jax.random.PRNGKey(1), cfg)
    paged_sv = prepare_servable(params, cfg, ServingSpec(
        tile=(16, 16), sparsity=0.5, prune="oneshot", targets=ATTN_TARGETS,
        kv_layout="paged", kv_page_size=8))
    prompts = _prompts(2)

    def interrupted(sv, layout, pool_pages=None):
        # the explicit kwarg outranks REPRO_KV_LAYOUT: the dense comparator
        # must stay dense even on the env-parametrized paged CI leg
        kw = {} if pool_pages is None else {"kv_pool_pages": pool_pages}
        eng = sv.engine(max_slots=1, cache_len=64, sync_every=2,
                        kv_layout=layout, **kw)
        victim = eng.submit(prompts[0], max_new_tokens=10, priority=0)
        eng.step()
        vip = eng.submit(prompts[1], max_new_tokens=10, priority=5)
        eng.run()
        assert victim.done and vip.done
        assert eng.stats.preemptions == 1
        eng.verify_invariants()
        return eng, victim, vip

    eng_d, vd, pd = interrupted(servable, "dense")
    eng_p, vp, pp = interrupted(paged_sv, "paged", pool_pages=16)
    assert vp.tokens == vd.tokens and pp.tokens == pd.tokens
    assert eng_p.stats.page_resumes == 1
    assert eng_d.stats.page_resumes == 0
    # dense re-prefills prompt + generated tokens; paged re-prefills NOTHING
    assert eng_p.stats.prefilled_tokens < eng_d.stats.prefilled_tokens
    assert eng_p.stats.prefilled_tokens == sum(len(p) for p in prompts)


def test_equal_priority_never_preempts(servable):
    eng = servable.engine(max_slots=1, cache_len=64, sync_every=2)
    first = eng.submit(_prompts(1)[0], max_new_tokens=6, priority=3)
    eng.step()
    second = eng.submit(_prompts(2)[1], max_new_tokens=6, priority=3)
    eng.run()
    assert first.done and second.done
    assert eng.stats.preemptions == 0
    assert first.n_preempted == 0


# --------------------------------------------------------------------------
# bounded queue + backpressure policies
# --------------------------------------------------------------------------

def test_overflow_reject_sheds_new_submission(servable):
    eng = servable.engine(max_slots=1, cache_len=64, max_queue=2,
                          overflow="reject")
    hs = [eng.submit(p, max_new_tokens=3) for p in _prompts(4)]
    # cap 2, no steps in between: hs[0]/hs[1] fill the queue, both later
    # submissions are shed at the door (the queued traffic is untouched)
    assert [h.status for h in hs] == ["queued", "queued", "shed", "shed"]
    assert hs[2].failure.code == FailureReason.QUEUE_FULL
    eng.run()
    assert [h.done for h in hs] == [True, True, False, False]
    assert eng.stats.shed == 2
    _assert_conserved(eng, hs)


def test_overflow_shed_oldest_keeps_fresh_traffic(servable):
    eng = servable.engine(max_slots=1, cache_len=64, max_queue=2,
                          overflow="shed-oldest")
    hs = [eng.submit(p, max_new_tokens=3) for p in _prompts(4)]
    # cap 2, no steps in between: each of hs[2]/hs[3] sheds the OLDEST
    # queued request to make room -- stale traffic loses to fresh traffic
    assert [h.status for h in hs] == ["shed", "shed", "queued", "queued"]
    assert hs[0].failure.code == FailureReason.QUEUE_FULL
    eng.run()
    assert [h.done for h in hs] == [False, False, True, True]
    assert eng.stats.shed == 2
    _assert_conserved(eng, hs)


def test_overflow_block_drains_instead_of_shedding(servable):
    eng = servable.engine(max_slots=1, cache_len=64, max_queue=1,
                          overflow="block", sync_every=2)
    hs = [eng.submit(p, max_new_tokens=3) for p in _prompts(4)]
    eng.run()
    assert all(h.done for h in hs)
    assert eng.stats.shed == 0 and eng.stats.rejected == 0
    _assert_conserved(eng, hs)


def test_overflow_policy_validated():
    cfg = _cfg()
    sv = prepare_servable(init_model(jax.random.PRNGKey(1), cfg), cfg,
                          ServingSpec(tile=(16, 16), sparsity=0.5,
                                      prune="oneshot",
                                      targets=ATTN_TARGETS))
    with pytest.raises(ValueError):
        sv.engine(max_slots=1, overflow="drop-all")
    with pytest.raises(ValueError):
        sv.engine(max_slots=1, max_queue=0)


# --------------------------------------------------------------------------
# non-finite quarantine
# --------------------------------------------------------------------------

@pytest.mark.parametrize("sync_every", [1, 4])
def test_nonfinite_quarantine_isolates_one_slot(servable, sync_every):
    """NaN-poisoning one slot's cache fails exactly that request with a
    structured reason; co-resident requests finish BIT-IDENTICAL to an
    uninjected run, and the quarantined slot recycles cleanly."""
    prompts = _prompts(3)
    ref = _reference(servable, prompts, max_new=8, sync_every=sync_every)
    eng = servable.engine(max_slots=3, cache_len=64, sync_every=sync_every)
    hs = [eng.submit(p, max_new_tokens=8) for p in prompts]
    eng.step()                              # admit all three + first window
    victim = hs[1]
    assert victim.status == "active"
    eng.corrupt_slot(victim.slot)
    eng.run()
    assert victim.status == "failed"
    assert victim.failure.code == FailureReason.NONFINITE_LOGITS
    assert hs[0].done and hs[0].tokens == ref[0]
    assert hs[2].done and hs[2].tokens == ref[2]
    _assert_conserved(eng, hs)
    # freed == fresh: a new request over the quarantined slot reproduces
    # the fresh-engine reference exactly
    again = eng.submit(prompts[1], max_new_tokens=8)
    eng.run()
    assert again.done and again.tokens == ref[1]


def test_prefill_failure_is_isolated_to_its_request(servable):
    """An admission/prefill blow-up fails ONLY its own request with a
    structured reason; the slot is restored and the engine keeps
    serving."""
    chaos = chaos_mod.ChaosInjector()
    eng = servable.engine(max_slots=2, cache_len=64, sync_every=2,
                          chaos=chaos)
    # inject an exception-based prefill failure for the 2nd admission
    chaos.inject(chaos_mod.SITE_PREFILL, at=2,
                 exc=RuntimeError("injected prefill blow-up"))
    ok = eng.submit(_prompts(1)[0], max_new_tokens=4)
    bad = eng.submit(_prompts(2)[1], max_new_tokens=4)
    eng.run()
    assert ok.done
    assert bad.status == "failed"
    assert bad.failure.code == FailureReason.PREFILL_ERROR
    assert "injected prefill blow-up" in bad.failure.message
    _assert_conserved(eng, [ok, bad])


# --------------------------------------------------------------------------
# watchdog
# --------------------------------------------------------------------------

def test_watchdog_detects_straggler_window(servable):
    """An artificial straggler sync (chaos ``straggle``) trips the armed
    watchdog; the request still completes (detection-only)."""
    stalls = []
    chaos = chaos_mod.ChaosInjector()
    chaos.inject(chaos_mod.SITE_SYNC, at=1,
                 action=chaos_mod.straggle(0.25))
    eng = servable.engine(max_slots=1, cache_len=64, sync_every=2,
                          watchdog_timeout_s=0.05, chaos=chaos,
                          on_stall=lambda label, s: stalls.append((label, s)))
    try:
        h = eng.submit(_prompts(1)[0], max_new_tokens=4)
        eng.run()
        assert h.done
        assert eng.stats.watchdog_stalls >= 1
        assert stalls and stalls[0][0] == "decode-window"
        assert stalls[0][1] > 0.05
    finally:
        eng.close()


def test_watchdog_quiet_on_healthy_engine(servable):
    eng = servable.engine(max_slots=2, cache_len=64, sync_every=2,
                          watchdog_timeout_s=30.0)
    try:
        hs = [eng.submit(p, max_new_tokens=4) for p in _prompts(3)]
        eng.run()
        assert all(h.done for h in hs)
        assert eng.stats.watchdog_stalls == 0
    finally:
        eng.close()
