"""Quantized sparse packs (spec.pack_quant, kernels/exec_plan.py quant
section, bsr_matmul.plan_dds_q): int8/fp8 block values + per-block (or
per-row-group) fp32 absmax scales, dequant fused into the plan matmul.

Covers the quantize/dequantize round-trip bounds per block shape (the
32x1 skinny-tile row-scale fallback and a 16x64 spill edge included),
plan vs plan_q8 forward parity, the fused-QKV export + the Pallas
kernel's bias/act epilogue, serialize round-trips (old-codec files load
unchanged), autotune cache-key separation by pack_quant and value dtype,
TP-sharded quantized packs (8-device leg), and greedy-decode token
agreement on the gemma3 smoke config.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LayerKind, ModelConfig
from repro.configs.registry import get_config
from repro.core.sparsity import prune_to_sparsity
from repro.kernels import exec_plan as xp
from repro.kernels.autotune import AutotuneCache, choose_backend
from repro.kernels.bsr_matmul import pack_bsr
from repro.kernels.exec_plan import (QuantPlan, ShardedPlan,
                                     dequantize_plan_values, fp8_dtype,
                                     quant_granularity, quantize_for_plan,
                                     quantize_plan_values)
from repro.models import init_model
from repro.serving import ServingSpec, load_servable, prepare_servable
from repro.serving.serialize import packs_from_arrays, packs_to_arrays

needs8 = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")

ATTN_TARGETS = ("attn/wq", "attn/wk", "attn/wv", "attn/wo")


def _pack(n=64, k=64, tile=(16, 16), sparsity=0.5, seed=0):
    rng = np.random.RandomState(seed)
    w = jnp.asarray(rng.randn(n, k).astype(np.float32))
    pruned, _ = prune_to_sparsity(w, tile, sparsity)
    return pack_bsr(np.asarray(pruned), tile)


def _quant_arm(pack, qdtype="int8"):
    plan = xp.plan_for_pack(pack)
    qp, params = quantize_for_plan(plan, pack.data, qdtype)
    return plan, qp, params


# --------------------------------------------------------------------------
# quantize/dequantize round-trip bounds
# --------------------------------------------------------------------------

@pytest.mark.parametrize("tile,n,k", [
    ((16, 16), 64, 64),      # square tile below the block threshold
    ((32, 1), 64, 16),       # skinny tile -> row-group scales
    ((16, 64), 64, 128),     # wide tile >= 128 elems -> block scales
    ((128, 128), 256, 256),  # the serving default
])
def test_round_trip_error_bound(tile, n, k):
    """|w - dequant(quant(w))| <= scale/2 per element (int8 symmetric
    midpoint), under both scale granularities."""
    pack = _pack(n=n, k=k, tile=tile, sparsity=0.5)
    plan = xp.plan_for_pack(pack)
    data_rp = xp.pack_plan_data(plan, pack.data)
    gran = quant_granularity(tile)
    assert gran == ("block" if tile[0] * tile[1] >= 128 else "row")
    q, s = quantize_plan_values(data_rp, "int8", gran)
    assert q.dtype == jnp.int8
    assert s.shape == (data_rp.shape[0],
                       data_rp.shape[1] if gran == "block" else 1)
    rt = dequantize_plan_values(q, s)
    bound = np.broadcast_to(np.asarray(s)[..., None, None] / 2 + 1e-7,
                            rt.shape)
    assert np.all(np.abs(np.asarray(rt) - np.asarray(data_rp)) <= bound)


def test_row_granularity_spill_edge():
    """A (16, 64) pattern dense enough to spill still round-trips: the
    virtual-row split happens before quantization, so scales follow
    vrows, not brows."""
    pack = _pack(n=32, k=256, tile=(16, 64), sparsity=0.1, seed=3)
    plan = xp.plan_for_pack(pack)
    data_rp = xp.pack_plan_data(plan, pack.data)
    q, s = quantize_plan_values(data_rp, "int8", quant_granularity((16, 64)))
    assert s.shape[0] == plan.n_vrows
    rt = dequantize_plan_values(q, s)
    np.testing.assert_allclose(np.asarray(rt), np.asarray(data_rp),
                               atol=float(np.asarray(s).max()) / 2 + 1e-7)


def test_zero_blocks_quantize_exact():
    """All-zero groups get scale 1.0 -> dequant is exactly zero (no NaNs
    from 0/0, no drift on padding slots)."""
    data_rp = jnp.zeros((3, 2, 16, 16))
    q, s = quantize_plan_values(data_rp, "int8", "block")
    assert np.all(np.asarray(s) == 1.0)
    assert np.all(np.asarray(dequantize_plan_values(q, s)) == 0.0)


def test_fp8_gated_on_jax_support():
    data_rp = jnp.ones((2, 2, 16, 16))
    if fp8_dtype() is None:
        with pytest.raises(NotImplementedError):
            quantize_plan_values(data_rp, "fp8", "block")
    else:
        q, s = quantize_plan_values(data_rp, "fp8", "block")
        assert q.dtype == fp8_dtype()
        np.testing.assert_allclose(np.asarray(dequantize_plan_values(q, s)),
                                   np.asarray(data_rp), rtol=0.07)


# --------------------------------------------------------------------------
# forward parity: plan vs plan_q8 (XLA) and the Pallas kernel
# --------------------------------------------------------------------------

@pytest.mark.parametrize("tile,sparsity", [((16, 16), 0.5),
                                           ((16, 64), 0.1),
                                           ((32, 1), 0.5)])
def test_plan_q_linear_matches_dequant_reference(tile, sparsity):
    """The fused path equals gather-matmul over explicitly dequantized
    weights to float tolerance -- fusion changes where the scale is
    applied, never the math."""
    pack = _pack(n=64, k=128, tile=tile, sparsity=sparsity, seed=1)
    plan, qp, params = _quant_arm(pack)
    x = jnp.asarray(np.random.RandomState(2).randn(8, 128).astype(np.float32))
    got = xp.plan_q_linear(x, params["w"], params["scale"], plan)
    ref = xp.plan_linear(x, dequantize_plan_values(params["w"],
                                                   params["scale"]), plan)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


def test_plan_q_pallas_matches_xla():
    pack = _pack(n=64, k=128, tile=(16, 16), sparsity=0.4, seed=4)
    plan, qp, params = _quant_arm(pack)
    x = jnp.asarray(np.random.RandomState(5).randn(16, 128)
                    .astype(np.float32))
    got = xp.plan_q_linear_pallas(x, params["w"], params["scale"], plan)
    want = xp.plan_q_linear(x, params["w"], params["scale"], plan)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


def test_plan_q_pallas_fused_epilogue():
    """bias + relu ride the Pallas kernel's row-change epilogue exactly as
    in the fp32 plan kernel."""
    pack = _pack(n=64, k=64, tile=(16, 16), sparsity=0.4, seed=6)
    plan, qp, params = _quant_arm(pack)
    x = jnp.asarray(np.random.RandomState(7).randn(8, 64).astype(np.float32))
    bias = jnp.asarray(np.random.RandomState(8).randn(64).astype(np.float32))
    got = xp.plan_q_linear_pallas(x, params["w"], params["scale"], plan,
                                  bias=bias, act="relu")
    want = jax.nn.relu(xp.plan_q_linear(x, params["w"], params["scale"],
                                        plan) + bias)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


def test_plan_q_backward_dx():
    """grad flows through x (engine probe path); quantized weights and
    scales are constants."""
    pack = _pack(n=32, k=64, tile=(16, 16), sparsity=0.5, seed=9)
    plan, qp, params = _quant_arm(pack)
    x = jnp.asarray(np.random.RandomState(10).randn(4, 64)
                    .astype(np.float32))

    def f(xx):
        return jnp.sum(xp.plan_q_linear(xx, params["w"], params["scale"],
                                        plan) ** 2)

    def f_ref(xx):
        return jnp.sum(xp.plan_linear(
            xx, dequantize_plan_values(params["w"], params["scale"]),
            plan) ** 2)

    np.testing.assert_allclose(np.asarray(jax.grad(f)(x)),
                               np.asarray(jax.grad(f_ref)(x)),
                               atol=1e-3, rtol=1e-3)


# --------------------------------------------------------------------------
# spec-level export: forward parity, fused QKV, stats
# --------------------------------------------------------------------------

def _servable_pair(cfg, params, **spec_kw):
    base = dict(tile=(16, 16), sparsity=0.5, prune="oneshot",
                targets=ATTN_TARGETS, **spec_kw)
    return (prepare_servable(params, cfg, ServingSpec(backend="plan",
                                                      **base)),
            prepare_servable(params, cfg, ServingSpec(
                backend="plan", pack_quant="int8", **base)))


def test_export_quant_forward_parity_and_stats():
    cfg = get_config("deepseek_7b", smoke=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    sv32, sv8 = _servable_pair(cfg, params)
    assert any(isinstance(p, QuantPlan) for p in sv8.packs.values())
    toks = jnp.asarray(np.random.RandomState(0).randint(
        0, cfg.vocab_size, (2, 8)))
    y32 = np.asarray(sv32.forward(toks))
    y8 = np.asarray(sv8.forward(toks))
    assert np.argmax(y32[:, -1], -1).tolist() == \
        np.argmax(y8[:, -1], -1).tolist()
    qs = sv8.quant_stats()
    assert qs["pack_quant"] == "int8" and qs["quantized_packs"] > 0
    # the acceptance bar: int8 + scales cut pack bytes >= 3x vs fp32
    assert qs["compression_ratio"] >= 3.0
    assert qs["max_abs_err"] >= 0 and qs["max_rel_err"] < 0.05
    assert "quant" in sv8.stats()
    assert sv32.quant_stats() is None and "quant" not in sv32.stats()


def test_export_quant_fused_qkv():
    """fuse_qkv concatenates wq/wk/wv into ONE pack; quantization applies
    to the fused plan and the slicing epilogue is untouched."""
    cfg = get_config("deepseek_7b", smoke=True)
    params = init_model(jax.random.PRNGKey(1), cfg)
    sv32, sv8 = _servable_pair(cfg, params, fuse_qkv=True)
    fused_q = [k for k, p in sv8.packs.items()
               if isinstance(p, QuantPlan) and "wqkv" in k]
    assert fused_q, f"no fused quantized pack in {list(sv8.packs)}"
    toks = jnp.asarray(np.random.RandomState(1).randint(
        0, cfg.vocab_size, (2, 6)))
    np.testing.assert_allclose(np.asarray(sv32.forward(toks)),
                               np.asarray(sv8.forward(toks)),
                               atol=0.1, rtol=0.1)


def test_spec_rejects_quant_on_unquantizable_backend():
    with pytest.raises(ValueError):
        ServingSpec(backend="bsr", pack_quant="int8")
    with pytest.raises(ValueError):
        ServingSpec(pack_quant="int4")


def test_engine_greedy_agreement_gemma3():
    """The acceptance gate: >= 99% greedy token agreement vs fp32 packs
    over a full engine run on the gemma3 smoke config."""
    cfg = get_config("gemma3_4b", smoke=True)
    params = init_model(jax.random.PRNGKey(2), cfg)
    sv32, sv8 = _servable_pair(cfg, params)

    def greedy(sv):
        eng = sv.engine(max_slots=4, cache_len=64, sync_every=4,
                        temperature=0.0)
        prng = np.random.RandomState(7)
        reqs = [eng.submit(list(prng.randint(1, cfg.vocab_size,
                                             (3 + 2 * i,))),
                           max_new_tokens=8) for i in range(8)]
        eng.run()
        out = [list(r.tokens) for r in reqs]
        eng.close()
        return out

    a, b = greedy(sv32), greedy(sv8)
    total = sum(len(s) for s in a)
    matched = sum(x == y for s1, s2 in zip(a, b) for x, y in zip(s1, s2))
    assert matched / total >= 0.99
    assert "quant" in sv8.engine(max_slots=1, cache_len=32).stats_dict()


# --------------------------------------------------------------------------
# serialization
# --------------------------------------------------------------------------

def test_quant_pack_array_round_trip():
    pack = _pack(n=64, k=64, tile=(16, 16), sparsity=0.5, seed=11)
    plan, qp, params = _quant_arm(pack)
    packs = {"blocks/g0/attn/wq": qp}
    arrays, meta = packs_to_arrays(packs)
    assert any(m["kind"] == "quant_plan" for m in meta["patterns"])
    back = packs_from_arrays(meta, arrays)
    qp2 = back["blocks/g0/attn/wq"]
    assert isinstance(qp2, QuantPlan)
    assert qp2.fingerprint == qp.fingerprint
    assert qp2.qdtype == "int8" and qp2.granularity == qp.granularity


def test_quant_pattern_dedup():
    """Two packs over the same pattern share ONE set of plan arrays."""
    pack = _pack(n=64, k=64, tile=(16, 16), sparsity=0.5, seed=12)
    plan, qp, _ = _quant_arm(pack)
    arrays, meta = packs_to_arrays({"a": qp, "b": qp})
    fp_arrays = [k for k in arrays if k.endswith("plan_fingerprint")]
    assert len(fp_arrays) == 1
    assert len(meta["patterns"]) == 1 and len(meta["keys"]) == 2


def test_save_load_quant_servable(tmp_path):
    cfg = get_config("deepseek_7b", smoke=True)
    params = init_model(jax.random.PRNGKey(3), cfg)
    _, sv8 = _servable_pair(cfg, params)
    toks = jnp.asarray(np.random.RandomState(3).randint(
        0, cfg.vocab_size, (1, 6)))
    want = np.asarray(sv8.forward(toks))
    sv8.save(str(tmp_path / "ckpt"))
    sv2 = load_servable(str(tmp_path / "ckpt"))
    assert any(isinstance(p, QuantPlan) for p in sv2.packs.values())
    np.testing.assert_allclose(np.asarray(sv2.forward(toks)), want,
                               atol=1e-6)
    assert sv2.quant_stats()["pack_quant"] == "int8"


def test_old_codec_files_load_unchanged(tmp_path):
    """A servable saved WITHOUT quantization writes no quant_plan records
    and loads byte-identically -- the codec addition is purely additive
    (a pre-quant file can never contain the new kind, so the old-file
    path IS the fp32 path)."""
    cfg = get_config("deepseek_7b", smoke=True)
    params = init_model(jax.random.PRNGKey(4), cfg)
    sv32, _ = _servable_pair(cfg, params)
    arrays, meta = packs_to_arrays(sv32.packs)
    kinds = {m["kind"] for m in meta["patterns"]}
    assert "quant_plan" not in kinds
    # the exact (arrays, meta) an old-codec writer produced round-trips
    # through the new reader with fingerprints intact
    back = packs_from_arrays(json.loads(json.dumps(meta)), arrays)
    assert {k: p.fingerprint for k, p in back.items()} == \
        {k: p.fingerprint for k, p in sv32.packs.items()}
    sv32.save(str(tmp_path / "ckpt32"))
    toks = jnp.asarray(np.random.RandomState(4).randint(
        0, cfg.vocab_size, (1, 6)))
    want = np.asarray(sv32.forward(toks))
    sv2 = load_servable(str(tmp_path / "ckpt32"))
    np.testing.assert_allclose(np.asarray(sv2.forward(toks)), want,
                               atol=1e-6)
    assert sv2.quant_stats() is None


# --------------------------------------------------------------------------
# autotune: quant candidates + cache-key separation
# --------------------------------------------------------------------------

def test_choose_backend_key_separates_quant(tmp_path):
    """quant='none' and quant='int8' are DIFFERENT cache keys over the
    same pattern: the int8 entry carries the plan_q8 candidates, the fp32
    entry never sees them (the key bugfix this PR)."""
    pack = _pack(n=128, k=128, tile=(16, 16), sparsity=0.8, seed=13)
    cache = AutotuneCache(str(tmp_path / "at.json"))
    c0 = choose_backend(pack, m=64, cache=cache, stub=True)
    c1 = choose_backend(pack, m=64, cache=cache, stub=True, quant="int8")
    assert c0.key != c1.key
    assert ":qnone:" in c0.key and ":qint8:" in c1.key
    assert "plan_q8" in c1.costs and "plan_q8" not in c0.costs
    # both answer from cache on re-ask, each under its own key
    assert choose_backend(pack, m=64, cache=cache, stub=True).cache_hit
    assert choose_backend(pack, m=64, cache=cache, stub=True,
                          quant="int8").cache_hit


def test_choose_backend_key_separates_value_dtype(tmp_path):
    """The value dtype is part of the key: a bf16 pack never reuses the
    fp32 pack's winner (their traffic differs 2x)."""
    pack32 = _pack(n=64, k=64, tile=(16, 16), sparsity=0.5, seed=14)
    pack16 = dataclasses.replace(
        pack32, data=jnp.asarray(pack32.data, jnp.bfloat16))
    cache = AutotuneCache(str(tmp_path / "at.json"))
    k32 = choose_backend(pack32, m=64, cache=cache, stub=True).key
    k16 = choose_backend(pack16, m=64, cache=cache, stub=True).key
    assert k32 != k16 and ":wfloat32:" in k32 and ":wbfloat16:" in k16


def test_stub_prefers_quant_at_high_sparsity():
    """Same geometry, quantized arm prices 4x less value traffic -> the
    stub proxy picks plan_q8 over plan whenever traffic matters."""
    from repro.kernels.autotune import stub_costs
    pack = _pack(n=256, k=256, tile=(16, 16), sparsity=0.8, seed=15)
    costs = stub_costs(pack, 64, ("plan", "plan_q8"))
    assert costs["plan_q8"] < costs["plan"]


def test_auto_backend_with_quant_serves(tmp_path, monkeypatch):
    """backend='auto' + pack_quant='int8' end to end: the chooser sees
    the quant candidates, and whatever wins serves with parity vs the
    pinned plan_q8 export."""
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "at.json"))
    monkeypatch.setenv("REPRO_AUTOTUNE_STUB", "1")
    cfg = get_config("deepseek_7b", smoke=True)
    params = init_model(jax.random.PRNGKey(5), cfg)
    base = dict(tile=(16, 16), sparsity=0.5, prune="oneshot",
                targets=ATTN_TARGETS)
    sv_auto = prepare_servable(params, cfg, ServingSpec(
        backend="auto", pack_quant="int8", autotune_m=64, **base))
    sv_pin = prepare_servable(params, cfg, ServingSpec(
        backend="plan", pack_quant="int8", **base))
    toks = jnp.asarray(np.random.RandomState(5).randint(
        0, cfg.vocab_size, (2, 8)))
    np.testing.assert_allclose(np.asarray(sv_auto.forward(toks)),
                               np.asarray(sv_pin.forward(toks)),
                               atol=0.05, rtol=0.05)
    auto = sv_auto.stats()["autotune"]
    assert auto["backends"]
    assert all(b in ("dense", "gather", "rowpack", "plan", "pallas",
                     "masked", "plan_pallas", "plan_q8", "plan_pallas_q8")
               for b in auto["backends"].values())


# --------------------------------------------------------------------------
# TP: sharded quantized packs (8-device leg)
# --------------------------------------------------------------------------

def _tp_cfg():
    return ModelConfig(
        arch="tp-quant-smoke", family="dense", n_layers=2, d_model=256,
        n_heads=8, n_kv_heads=8, head_dim=32, d_ff=1024, vocab_size=512,
        pattern=(LayerKind("attn", "dense"),), dtype="float32")


ALL_TARGETS = ("attn/wq", "attn/wk", "attn/wv", "attn/wo",
               "ffn/wi", "ffn/wg", "ffn/wo")


@needs8
def test_sharded_quant_packs_parity_and_bytes():
    cfg = _tp_cfg()
    params = init_model(jax.random.PRNGKey(6), cfg)
    base = dict(tile=(32, 32), sparsity=0.5, prune="tied",
                targets=ALL_TARGETS, mesh_shape=(1, 8), partition="tp")
    sv32 = prepare_servable(params, cfg, ServingSpec(backend="plan",
                                                     **base))
    sv8 = prepare_servable(params, cfg, ServingSpec(
        backend="plan", pack_quant="int8", **base))
    sharded_q = [p for p in sv8.packs.values()
                 if isinstance(p, QuantPlan)
                 and isinstance(p.plan, ShardedPlan)]
    assert sharded_q, "no sharded quantized packs"
    toks = jnp.asarray(np.random.RandomState(6).randint(
        0, cfg.vocab_size, (2, 8)))
    y32 = np.asarray(sv32.forward(toks))
    y8 = np.asarray(sv8.forward(toks))
    assert np.argmax(y32[:, -1], -1).tolist() == \
        np.argmax(y8[:, -1], -1).tolist()
    qs = sv8.quant_stats()
    assert qs["compression_ratio"] >= 3.0
    assert qs["quant_bytes_per_device"] < qs["quant_bytes_total"]


@needs8
def test_sharded_quant_save_load(tmp_path):
    cfg = _tp_cfg()
    params = init_model(jax.random.PRNGKey(7), cfg)
    sv = prepare_servable(params, cfg, ServingSpec(
        tile=(32, 32), sparsity=0.5, prune="tied", targets=ALL_TARGETS,
        mesh_shape=(1, 8), partition="tp", backend="plan",
        pack_quant="int8"))
    toks = jnp.asarray(np.random.RandomState(7).randint(
        0, cfg.vocab_size, (1, 6)))
    want = np.asarray(sv.forward(toks))
    sv.save(str(tmp_path / "ckpt"))
    sv2 = load_servable(str(tmp_path / "ckpt"))
    assert any(isinstance(p, QuantPlan)
               and isinstance(p.plan, ShardedPlan)
               for p in sv2.packs.values())
    np.testing.assert_allclose(np.asarray(sv2.forward(toks)), want,
                               atol=1e-5)
