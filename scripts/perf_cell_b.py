"""A/B: qwen3-moe prefill_32k at 512 chips, train-style vs inference-mode
param sharding. Writes results/perf_cell_b.json."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json, sys, time
sys.path.insert(0, "src")
import jax
from repro.configs.registry import get_config
from repro.launch import hlo_analysis as ha
from repro.launch import hlo_cost
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import batch_shardings, param_shardings
from repro.launch.specs import input_specs
from repro.launch.steps import make_prefill_step
from repro.configs.base import SHAPES

cfg = get_config("qwen3_moe_235b_a22b")
mesh = make_production_mesh()
specs = input_specs(cfg, "prefill_32k")
b_sh = batch_shardings(specs["batch"], mesh)
step = make_prefill_step(cfg)
out = {}
for label, mode in (("before", "train"), ("after", "inference")):
    p_sh = param_shardings(specs["params"], mesh, mode=mode)
    t0 = time.time()
    with mesh:
        compiled = jax.jit(step, in_shardings=(p_sh, b_sh)).lower(
            specs["params"], specs["batch"]).compile()
    la = hlo_cost.analyze(compiled.as_text())
    n_params = ha.count_params(specs["params"])
    n_exp = ha.count_expert_params(specs["params"])
    mf = ha.model_flops_estimate(cfg, SHAPES["prefill_32k"], n_params, n_exp,
                                 "prefill")
    roof = ha.Roofline(la["flops"], la["bytes"], la["coll"]["total"], 256, mf)
    mem = compiled.memory_analysis()
    out[label] = {**roof.to_dict(),
                  "arg_bytes": getattr(mem, "argument_size_in_bytes", 0),
                  "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
                  "compile_s": round(time.time() - t0, 1)}
    print(label, {k: round(v, 3) if isinstance(v, float) else v
                  for k, v in out[label].items() if k.startswith(("t_", "bo"))},
          flush=True)
os.makedirs("results", exist_ok=True)
json.dump({"before": out["before"], "after": out["after"]},
          open("results/perf_cell_b.json", "w"), indent=1)
