#!/usr/bin/env bash
# Tier-1 gate + kernel-bench smoke (~30 s): what every PR must keep green.
#
#   bash scripts/check.sh
#
# 1. the repo's tier-1 test command (ROADMAP.md);
# 2. a smoke run of the kernel microbenchmark, refreshing the
#    "kernel_smoke" section of BENCH_kernels.json so perf regressions are
#    visible in-diff (the full "kernel" sweep is a manual
#    `python benchmarks/kernel_bench.py` run);
# 3. a smoke run of the serving-engine benchmark, refreshing the
#    "engine_smoke" section of BENCH_serving.json (full sweep:
#    `python benchmarks/serving_bench.py`).
#
# The smokes run even when tests fail (a handful of seed-era failures are
# known; see CHANGES.md) -- the script exits nonzero if any step did.
set -uo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

status=0

python -m pytest -x -q || status=$?

python benchmarks/kernel_bench.py --smoke || status=$?

python benchmarks/serving_bench.py --smoke || status=$?

exit $status
