#!/usr/bin/env bash
# Tier-1 gate + kernel-bench smoke (~30 s): what every PR must keep green.
#
#   bash scripts/check.sh
#
# 1. the repo's tier-1 test command (ROADMAP.md);
# 2. a smoke run of the kernel microbenchmark, refreshing the
#    "kernel_smoke" section of BENCH_kernels.json so perf regressions are
#    visible in-diff (the full "kernel" sweep is a manual
#    `python benchmarks/kernel_bench.py` run);
# 3. a smoke run of the serving-engine benchmark (per-step baseline +
#    fused sync_every sweep), refreshing the "engine_smoke" /
#    "engine_fused_smoke" sections of BENCH_serving.json (full sweep:
#    `python benchmarks/serving_bench.py`);
# 4. the bench regression guard: compares the fresh smoke tokens/s against
#    the committed BENCH_serving.json baseline and WARNS (never fails) on
#    a >20% drop -- visible in CI logs without blocking on machine noise.
#
# The smokes run even when tests fail (a handful of seed-era failures are
# known; see CHANGES.md) -- the script exits nonzero if any step did.
set -uo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

status=0

python -m pytest -x -q || status=$?

# keep the committed serving numbers aside as the regression baseline
bench_baseline="$(mktemp)"
cp BENCH_serving.json "$bench_baseline" 2>/dev/null || true

python benchmarks/kernel_bench.py --smoke || status=$?

python benchmarks/serving_bench.py --smoke || status=$?

# warn-only guard: >20% tokens/s drop vs the committed baseline
if [ -s "$bench_baseline" ]; then
    python scripts/bench_guard.py "$bench_baseline" BENCH_serving.json || status=$?
fi
rm -f "$bench_baseline"

exit $status
