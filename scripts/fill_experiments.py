"""Fill EXPERIMENTS.md placeholders from measured artifacts.

  <!-- TABLE1 -->    <- results/table1.csv (markdown table)
  <!-- TABLE2 -->    <- results/table2.csv
  <!-- ROOFLINE -->  <- results/dryrun/*.json via benchmarks.roofline
  <!-- CELL_B -->    <- results/perf_cell_b.json (A/B numbers)
  <!-- CELL_C -->    <- before/after sweep JSONs for chatglm3 train

Usage: PYTHONPATH=src python scripts/fill_experiments.py
"""
import json
import os
import re
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def table1_md():
    path = os.path.join(REPO, "results", "table1.csv")
    if not os.path.exists(path):
        return "*(results/table1.csv missing — run benchmarks.run table1)*"
    rows = {}
    order = []
    for line in open(path):
        line = line.strip()
        if not line or line.startswith("name,"):
            continue
        name, us, derived = line.split(",")
        key = name.split("/")[1]
        rows[key] = (float(us) / 1e3, float(derived))
        order.append(key)
    out = ["| config | ms | ratio vs xla_dense |", "|---|---|---|"]
    for key in order:
        ms, r = rows[key]
        mark = " **<- backend optimum**" if key == "bsr_sq_128x128" else ""
        out.append(f"| {key} | {ms:.0f} | {r:.3f}{mark} |")
    return "\n".join(out)


def table2_md():
    path = os.path.join(REPO, "results", "table2.csv")
    if not os.path.exists(path):
        # fall back to extracting from the recorded bench output
        bench = os.path.join(REPO, "bench_output.txt")
        if os.path.exists(bench):
            rows = [l.strip() for l in open(bench)
                    if l.startswith("table2/")]
            if rows:
                with open(path, "w") as f:
                    f.write("\n".join(rows) + "\n")
    if not os.path.exists(path):
        return "*(results/table2.csv missing — run benchmarks.run table2)*"
    out = ["| arm | metric | value |", "|---|---|---|"]
    for line in open(path):
        line = line.strip()
        if not line or line.startswith("name,"):
            continue
        name, us, derived = line.split(",")
        arm, metric = name.split("/")[1].rsplit("_mlm_", 1)
        out.append(f"| {arm} | mlm_{metric} | {float(derived):.4f} |")
    return "\n".join(out)


def cell_b_md():
    path = os.path.join(REPO, "results", "perf_cell_b.json")
    if not os.path.exists(path):
        return "*(pending)*"
    d = json.load(open(path))
    a, b = d["before"], d["after"]
    return (
        "Baseline (paper-era FSDP-style inference sharding): "
        f"t_coll **{a['t_collective_s']:.1f}s**, t_mem {a['t_memory_s']:.1f}s, "
        f"t_comp {a['t_compute_s']:.1f}s — collective-bound by per-layer "
        "weight all-gathers over the data axis.\n\n"
        "Change: TP-only inference params + 2-D (E x f) expert sharding "
        "(`sharding.py mode=\"inference\"`) — weights never gathered; expert "
        "partial sums all-reduce instead.\n\n"
        f"After: t_coll **{b['t_collective_s']:.1f}s** "
        f"({a['t_collective_s']/max(b['t_collective_s'],1e-9):.1f}x down), "
        f"t_mem {b['t_memory_s']:.1f}s, t_comp {b['t_compute_s']:.1f}s; "
        f"bottleneck: {a['bottleneck']} -> {b['bottleneck']}; roofline "
        f"fraction {a['roofline_fraction']:.3f} -> "
        f"{b['roofline_fraction']:.3f}. **CONFIRMED** — applied as the "
        "default for all prefill/decode cells in the final roofline table."
    )


def cell_c_md():
    bpath = os.path.join(REPO, "results", "perf_cell_c_before.json")
    apath = os.path.join(REPO, "results", "dryrun",
                         "chatglm3_6b__train_4k__pod.json")
    if not (os.path.exists(bpath) and os.path.exists(apath)):
        return "*(pending)*"
    a = json.load(open(bpath))["roofline"]
    b = json.load(open(apath))["roofline"]
    return (
        f"Baseline (scan-autodiff flash): t_mem **{a['t_memory_s']:.1f}s** "
        f"(dominant), t_comp {a['t_compute_s']:.1f}s, t_coll "
        f"{a['t_collective_s']:.1f}s; useful/HLO {a['useful_flop_ratio']:.3f}."
        "\n\nChange: flash custom-VJP (§Perf iter 2) + bf16 tiles (iter 3)."
        f"\n\nAfter: t_mem **{b['t_memory_s']:.1f}s** "
        f"({a['t_memory_s']/max(b['t_memory_s'],1e-9):.2f}x down), t_comp "
        f"{b['t_compute_s']:.1f}s, t_coll {b['t_collective_s']:.1f}s; "
        f"useful/HLO {b['useful_flop_ratio']:.3f}; roofline fraction "
        f"{a['roofline_fraction']:.4f} -> {b['roofline_fraction']:.4f}. "
        "Residual gap: XLA-level flash still round-trips score tiles through "
        "HBM at fusion boundaries — the designed next step is the VMEM-"
        "resident Pallas flash kernel (TPU-only; not measurable in this "
        "container)."
    )


def main():
    from benchmarks.roofline import markdown
    path = os.path.join(REPO, "EXPERIMENTS.md")
    text = open(path).read()
    text = text.replace("<!-- TABLE1 -->", table1_md())
    text = text.replace("<!-- TABLE2 -->", table2_md())
    text = text.replace("<!-- ROOFLINE -->", markdown(mesh_filter="16x16"))
    text = text.replace("<!-- CELL_B -->", cell_b_md())
    text = text.replace("<!-- CELL_C -->", cell_c_md())
    open(path, "w").write(text)
    print("EXPERIMENTS.md filled")


if __name__ == "__main__":
    main()
