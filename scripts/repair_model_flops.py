"""Recompute model_flops / ratios in existing dryrun JSONs after the
count_expert_params fix (no recompilation: HLO-derived terms are unchanged).
"""
import glob
import json
import os
import sys

sys.path.insert(0, "src")

from repro.configs.base import SHAPES
from repro.configs.registry import get_config
from repro.launch import hlo_analysis as ha
from repro.launch.specs import params_specs

cache = {}
for path in sorted(glob.glob(sys.argv[1] if len(sys.argv) > 1
                             else "results/dryrun/*.json")):
    cell = json.load(open(path))
    if cell.get("status") != "OK":
        continue
    arch = cell["arch"]
    if arch not in cache:
        cfg = get_config(arch)
        p = params_specs(cfg)
        cache[arch] = (cfg, ha.count_params(p), ha.count_expert_params(p))
    cfg, n_params, n_expert = cache[arch]
    shape = SHAPES[cell["shape"]]
    mf = ha.model_flops_estimate(cfg, shape, n_params, n_expert, shape.kind)
    r = cell["roofline"]
    roof = ha.Roofline(r["flops_per_dev"], r["hbm_bytes_per_dev"],
                       r["coll_bytes_per_dev"], r["n_devices"], mf)
    cell["n_params"], cell["n_expert_params"] = n_params, n_expert
    cell["roofline"] = roof.to_dict()
    json.dump(cell, open(path, "w"), indent=1)
    print(f"{os.path.basename(path):55s} useful={roof.useful_flop_ratio:.3f} "
          f"frac={roof.roofline_fraction:.4f}")
