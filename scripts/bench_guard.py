"""Bench regression guard: warn when fresh serving throughput regresses.

Compares the tokens/s of matching cells between a baseline BENCH_serving
json (the committed numbers, copied aside before the smoke refresh) and a
freshly written one. A drop larger than the threshold prints a WARNING per
cell; the exit code stays 0 (warn, don't fail -- the reference box is
shared and noisy; the warning makes the regression visible in CI logs and
in-diff without blocking on machine weather). ``--strict`` flips warnings
into a nonzero exit for local use.

Usage:
    python scripts/bench_guard.py BASELINE.json FRESH.json \
        [--threshold 0.2] [--strict]
"""
from __future__ import annotations

import json
import sys

#: the sharded cells are new this PR and host-platform meshes are extra
#: noisy (one socket pretending to be 8 devices) -- they stay warn-only
#: like everything else here
#: engine_chaos tracks the lifecycle-overhead cell (baseline vs
#: robustness-armed engine over the same servable) -- warn-only, so a PR
#: that moves lifecycle checks onto the per-token path surfaces here
#: kv_memory tracks the shared-system-prompt workload (dense vs paged
#: prefix-sharing arms) by tok/s -- warn-only like the rest; its byte and
#: concurrency cells are informational (no tok/s, so compare() skips them)
#: flash_decode tracks the decode-attention kernels (xla vs split-K flash)
#: by tokens_per_s over a context x split sweep; plan_bsr tracks the
#: plan-layout matmul arms (XLA composition vs the plan-consuming Pallas
#: kernel) by rate (rows/s) -- both warn-only like everything else here,
#: keyed per cell tag (kernel_bench.py)
#: quant_error tracks the fp32-plan vs int8-plan arms (same pruned
#: weights, pack_quant='int8') by tok/s; its fidelity scalars (greedy
#: token agreement, max abs logit delta) get their own direction-aware
#: pass below -- all warn-only, so a PR that degrades quantized decode
#: fidelity or throughput shows up in the trajectory without blocking
SECTIONS = ("engine_smoke", "engine", "engine_fused_smoke", "engine_fused",
            "engine_chaos_smoke", "engine_chaos",
            "kv_memory_smoke", "kv_memory",
            "sharded_smoke", "sharded",
            "flash_decode_smoke", "flash_decode",
            "plan_bsr_smoke", "plan_bsr",
            "quant_error_smoke", "quant_error")

#: open_loop cells carry LATENCY percentiles (lower is better, the
#: opposite direction from every throughput section above): p95 TTFT and
#: p95 per-token latency per (arm, offered qps). Warn-only like the rest
#: -- open-loop tails on a shared box are the noisiest numbers in the
#: file, so the threshold only flags step-change regressions
LATENCY_SECTIONS = ("open_loop_smoke", "open_loop")

#: quant fidelity scalars live at the section's top level, one number
#: each, with opposite regression directions: agreement is
#: higher-is-better (a drop warns, like throughput), the logit delta is
#: lower-is-better (a rise warns, like latency)
QUANT_SECTIONS = ("quant_error_smoke", "quant_error")
QUANT_HIGHER_BETTER = ("greedy_token_agreement",)
QUANT_LOWER_BETTER = ("max_abs_logit_delta",)


def _cells(section_payload):
    """-> {(arm, cell key, sync_every): rate}. Engine sections key by
    ``slots`` and carry ``tokens_per_s``; kernel sections key by ``cell``
    and carry ``tokens_per_s`` or ``rate`` -- one positive-is-faster
    number either way."""
    out = {}
    for arm, cells in (section_payload.get("results") or {}).items():
        for cell in cells:
            key = (arm, cell.get("slots", cell.get("cell")),
                   cell.get("sync_every", 1))
            out[key] = cell.get("tokens_per_s", cell.get("rate"))
    return out


def _latency_cells(section_payload):
    """-> {(arm, qps, metric): ms} for the open_loop sections; lower is
    better. Cells whose percentile is None (e.g. everything shed at an
    extreme qps) are skipped."""
    out = {}
    for arm, cells in (section_payload.get("results") or {}).items():
        for cell in cells:
            for metric, group in (("ttft_p95", "ttft"), ("tpot_p95", "tpot")):
                ms = (cell.get(group) or {}).get("p95_ms")
                if ms:
                    out[(arm, cell.get("qps"), metric)] = ms
    return out


def compare(baseline: dict, fresh: dict, threshold: float = 0.2):
    """-> list of (section, cell key, baseline, fresh, unit). Throughput
    sections regress when the fresh rate drops by more than ``threshold``;
    open_loop latency sections regress when the fresh p95 RISES by more
    than ``threshold`` (direction inverted: latency, lower is better)."""
    regressions = []
    for section in SECTIONS:
        if section not in baseline or section not in fresh:
            continue
        base_cells = _cells(baseline[section])
        fresh_cells = _cells(fresh[section])
        for key, base_tps in base_cells.items():
            new_tps = fresh_cells.get(key)
            if not base_tps or not new_tps:
                continue
            if new_tps < (1.0 - threshold) * base_tps:
                regressions.append((section, key, base_tps, new_tps,
                                    "tok/s"))
    for section in LATENCY_SECTIONS:
        if section not in baseline or section not in fresh:
            continue
        base_cells = _latency_cells(baseline[section])
        fresh_cells = _latency_cells(fresh[section])
        for key, base_ms in base_cells.items():
            new_ms = fresh_cells.get(key)
            if not base_ms or not new_ms:
                continue
            if new_ms > (1.0 + threshold) * base_ms:
                regressions.append((section, key, base_ms, new_ms, "ms"))
    for section in QUANT_SECTIONS:
        if section not in baseline or section not in fresh:
            continue
        for metric in QUANT_HIGHER_BETTER + QUANT_LOWER_BETTER:
            base_v = baseline[section].get(metric)
            new_v = fresh[section].get(metric)
            if base_v is None or new_v is None or not base_v:
                continue
            worse = (new_v < (1.0 - threshold) * base_v
                     if metric in QUANT_HIGHER_BETTER
                     else new_v > (1.0 + threshold) * base_v)
            if worse:
                regressions.append((section, (metric, None, None),
                                    base_v, new_v, "quant"))
    return regressions


def main(argv):
    threshold = 0.2
    argv = list(argv)
    if "--threshold" in argv:
        i = argv.index("--threshold")
        threshold = float(argv[i + 1])
        del argv[i:i + 2]           # value must not read as a positional
    args = [a for a in argv if not a.startswith("--")]
    if len(args) != 2:
        print(__doc__)
        return 2
    try:
        with open(args[0]) as f:
            baseline = json.load(f)
        with open(args[1]) as f:
            fresh = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"bench_guard: cannot compare ({e}); skipping")
        return 0
    regressions = compare(baseline, fresh, threshold)
    for section, key, base_v, new_v, unit in regressions:
        arm, mid, tail = key
        if unit == "quant":
            desc = f"{arm}"
        elif unit == "ms":
            desc = f"{arm} qps={mid} {tail}"
        else:
            desc = f"{arm} slots={mid} sync_every={tail}"
        print(f"WARNING: bench regression in {section}: {desc}: "
              f"{base_v:.4g} -> {new_v:.4g} {unit} "
              f"({100 * (new_v / base_v - 1):+.0f}%)")
    if not regressions:
        print(f"bench_guard: no >{threshold:.0%} regression "
              f"(throughput or open-loop latency)")
    return 1 if (regressions and "--strict" in argv) else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
