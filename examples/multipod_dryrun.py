"""Multi-pod dry-run example: lower+compile one (arch x shape) cell on the
2x16x16 = 512-chip production mesh and print its roofline terms.

Run:  PYTHONPATH=src python examples/multipod_dryrun.py \
          [--arch gemma3_4b] [--shape train_4k] [--singlepod]
(This is a thin wrapper over `python -m repro.launch.dryrun`; the heavy
lifting, including the XLA_FLAGS device faking, lives there.)
"""
import argparse
import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3_4b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--singlepod", action="store_true")
    args = ap.parse_args()

    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", args.arch,
           "--shape", args.shape, "--out", "results/dryrun"]
    if not args.singlepod:
        cmd.append("--multipod")
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    subprocess.run(cmd, env=env, cwd=REPO, check=True)

    tag = "pod" if args.singlepod else "multipod"
    path = os.path.join(REPO, "results", "dryrun",
                        f"{args.arch.replace('-', '_')}__{args.shape}__{tag}.json")
    with open(path) as f:
        cell = json.load(f)
    print(json.dumps({k: v for k, v in cell.items() if k != "trace"},
                     indent=1))


if __name__ == "__main__":
    main()
