"""Fault-tolerant sparse training driver: a decoder LM trained with gradual
block pruning + group-lasso prox, an injected mid-run failure, automatic
checkpoint restore, and a final handoff to the serving facade
(``prepare_servable`` with ``prune='none'``: the trained masks ARE the
sparsity) -- the whole substrate in one run.

Run:  PYTHONPATH=src python examples/train_lm_sparse.py [--steps 60]
"""
import argparse
import dataclasses
import logging
import tempfile

import jax

from repro.configs.registry import get_config
from repro.core.pruner import sparsity_report
from repro.core.sparsity import SparsityConfig
from repro.data.pipeline import DataConfig
from repro.launch.train import TrainConfig, Trainer
from repro.optim.adamw import AdamWConfig
from repro.serving import ServingSpec, prepare_servable
from repro.runtime.fault_tolerance import FaultInjector, FaultToleranceConfig

logging.basicConfig(level=logging.INFO,
                    format="%(asctime)s %(name)s %(message)s")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--arch", default="deepseek_7b")
    args = ap.parse_args()

    sp = SparsityConfig(block_shape=(16, 16), sparsity=0.7,
                        lambda_reg=1e-4, start_step=10,
                        end_step=max(args.steps - 10, 11))
    cfg = dataclasses.replace(get_config(args.arch, smoke=True), sparsity=sp)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ckpt = tempfile.mkdtemp(prefix="repro_lm_")

    tcfg = TrainConfig(
        n_steps=args.steps, ckpt_dir=ckpt, prune=True, log_every=10,
        opt=AdamWConfig(peak_lr=3e-3, warmup_steps=10,
                        total_steps=args.steps, weight_decay=0.0),
        ft=FaultToleranceConfig(checkpoint_every=15, max_restarts=3))
    data = DataConfig(seq_len=64, global_batch=8, vocab_size=cfg.vocab_size)

    injector = FaultInjector(fail_at_steps=(args.steps // 2,))
    trainer = Trainer(cfg, tcfg, mesh, data, fault_injector=injector)
    state, history = trainer.fit(resume=False)

    print("\nloss curve:", [f"{s}:{l:.3f}" for s, l in history])
    print("injected failures survived:", sorted(injector.fired))
    rep = sparsity_report(state["params"], sp)
    print("final attention block sparsity:",
          {k.split('/')[-2]: round(v, 2) for k, v in list(rep.items())[:4]})

    servable = prepare_servable(state["params"], cfg,
                                ServingSpec(tile=(16, 16), prune="none"))
    st = servable.stats()
    print(f"BSR export: {st['packed_projections']} weights, mean density "
          f"{st['density']:.2f}, union overhead {st['union_overhead']:.2f}")


if __name__ == "__main__":
    main()
