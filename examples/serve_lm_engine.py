"""Continuous-batching LM serving demo: the request-level deployment story.

Builds a sparse Servable for a decoder-only LM, constructs the
continuous-batching engine (``servable.engine(...)``), and pushes a burst of
requests with mixed prompt lengths through a handful of request slots --
more requests than slots, so admission, bucketed prefill, ragged batched
decode, and slot recycling all run. Tokens stream per request through the
``on_token`` callback while the engine batches every active request into ONE
jitted decode *window* -- ``--sync-every`` fused steps between host syncs
(docs/API.md §Engine; ``--sync-every 1`` shows the per-step loop the fused
path replaced). ``--temperature``/``--top-k``/``--seed`` switch greedy
decoding to on-device seeded sampling.

``--tp N`` serves the same workload tensor-parallel over a ``(1, N)``
device mesh (spec ``mesh_shape``): BSR plan packs shard by output block
rows / input block cols, the slot cache shards its KV heads, and
``stats()`` reports the per-shard pack bytes and registry accounting. On
CPU, expose fake devices first:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/serve_lm_engine.py --tp 8

Compare with examples/serve_bert_sparse.py (batched *encoder* serving):
this demo is the decode-side counterpart the paper's runtime argument
ultimately cares about -- concurrency without per-request graphs.

``--kv-layout paged`` serves the same burst from a paged KV pool with
radix prefix sharing; add ``--shared-prefix 32`` to give every request
one shared system prompt and watch ``stats_dict()['kv']`` report pool
utilization and the prompt tokens served from shared pages instead of
prefill (docs/API.md §Paged KV + prefix cache).

``--pack-quant int8`` serves the same packs with int8 block values +
per-block fp32 scales, dequant fused into the plan matmul
(docs/API.md §Quantized sparse packs). The demo prints a pack-bytes
scorecard -- fp32-equivalent vs quantized, per device under ``--tp N``
-- next to the tok/s line, so the memory/fidelity trade is visible in
one run.

Run:  PYTHONPATH=src python examples/serve_lm_engine.py
          [--arch deepseek_7b] [--slots 4] [--requests 10] [--max-new 12]
          [--sync-every 8] [--temperature 0.8] [--top-k 40] [--tp N]
          [--kv-layout paged] [--kv-page-size 16] [--shared-prefix 32]
          [--pack-quant int8]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs.registry import get_config
from repro.models import init_model
from repro.serving import ServingSpec, prepare_servable


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek_7b",
                    help="any decode-capable arch (smoke config is used)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--sparsity", type=float, default=0.7)
    ap.add_argument("--sync-every", type=int, default=8,
                    help="fused decode window length K (1 = per-step loop)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy; >0 samples on device")
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel shards: serve over a (1, N) mesh "
                         "(needs N visible devices; on CPU set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N)")
    ap.add_argument("--kv-layout", default="dense",
                    choices=("dense", "paged"),
                    help="KV storage: 'paged' = page-pool KV + radix "
                         "prefix sharing (docs/API.md §Paged KV)")
    ap.add_argument("--kv-page-size", type=int, default=16)
    ap.add_argument("--shared-prefix", type=int, default=0, metavar="N",
                    help="prepend one shared N-token system prompt to every "
                         "request -- with --kv-layout paged the prefix cache "
                         "serves the repeats from shared pages")
    ap.add_argument("--pack-quant", default="none",
                    choices=("none", "int8", "fp8"),
                    help="store pack values quantized with per-block "
                         "scales, dequant fused into the plan matmul "
                         "(docs/API.md §Quantized sparse packs)")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    print(f"initializing {cfg.arch} ({cfg.family})...")
    params = init_model(jax.random.PRNGKey(0), cfg)
    servable = prepare_servable(params, cfg, ServingSpec(
        tile=(16, 16), sparsity=args.sparsity, prune="oneshot",
        targets=("attn/wq", "attn/wk", "attn/wv", "attn/wo"),
        mesh_shape=(1, args.tp) if args.tp > 1 else None, partition="tp",
        kv_layout=args.kv_layout, kv_page_size=args.kv_page_size,
        pack_quant=args.pack_quant))
    st = servable.stats()
    print(f"sparse export: {st['packed_projections']} packed projections, "
          f"density {st['density']:.2f}" if st["density"] is not None
          else "no packed projections (dense serving)")
    if args.tp > 1:
        sh = st["sharding"]
        print(f"tensor-parallel: mesh (1, {args.tp}), "
              f"{sh['sharded_packs']}/{st['packed_projections']} packs "
              f"sharded, pack bytes/device "
              f"{sh['pack_bytes_per_device']}/{sh['pack_bytes_total']} "
              f"(total)")
        hits = {s: f"{v['hits']}h/{v['misses']}m"
                for s, v in sorted(sh["per_shard_registry"].items())}
        print(f"per-shard registry (layout reuse across layers): {hits}")
    qs = servable.quant_stats()
    if qs:
        print(f"pack-bytes scorecard ({qs['qdtype']}, "
              f"{'/'.join(sorted(qs['granularities']))} scales):")
        print(f"  fp32-equivalent: {qs['fp32_equiv_bytes_total']:>10d} B "
              f"total, {qs['fp32_equiv_bytes_per_device']:>10d} B/device")
        print(f"  quantized:       {qs['quant_bytes_total']:>10d} B "
              f"total, {qs['quant_bytes_per_device']:>10d} B/device "
              f"(incl. {qs['scale_bytes_total']} B scales)")
        print(f"  compression {qs['compression_ratio']:.2f}x, worst "
              f"quant err {qs['max_abs_err']:.2e} abs / "
              f"{qs['max_rel_err']:.2e} rel")

    engine = servable.engine(max_slots=args.slots, cache_len=128,
                             sync_every=args.sync_every,
                             temperature=args.temperature,
                             top_k=args.top_k, seed=args.seed)
    rng = np.random.RandomState(0)

    streams = {}

    def on_token(rid, tok):
        streams.setdefault(rid, []).append(tok)

    def on_done(rid, toks):
        print(f"  request {rid}: done, {len(toks)} tokens -> {toks[:8]}"
              f"{'...' if len(toks) > 8 else ''}")

    system = rng.randint(0, cfg.vocab_size,
                         (args.shared_prefix,)).tolist()
    print(f"submitting {args.requests} requests "
          f"(prompts 3..18 tokens"
          + (f" after a shared {len(system)}-token system prompt"
             if system else "")
          + f") into {args.slots} slots...")
    handles = []
    for i in range(args.requests):
        prompt = system + rng.randint(
            0, cfg.vocab_size, (3 + (5 * i) % 16,)).tolist()
        handles.append(engine.submit(prompt, max_new_tokens=args.max_new,
                                     on_token=on_token, on_done=on_done))

    t0 = time.perf_counter()
    engine.run()
    dt = time.perf_counter() - t0

    s = engine.stats
    assert all(h.done for h in handles)
    assert all(streams[h.req_id] == h.tokens for h in handles)
    print(f"served {s.completed} requests / {s.tokens_generated} tokens in "
          f"{dt:.2f}s ({s.tokens_generated / dt:.1f} tok/s)")
    print(f"{s.steps} decode steps in {s.windows} fused windows "
          f"(sync_every={args.sync_every}), mean occupancy "
          f"{s.mean_occupancy:.2f}/{args.slots} slots, prefill buckets "
          f"{dict(s.bucket_hits)}")
    print(f"wall-clock breakdown: prefill {s.prefill_s:.2f}s, decode "
          f"{s.decode_s:.2f}s, host-sync {s.sync_s:.2f}s")
    kv = engine.stats_dict()["kv"]
    if kv["layout"] == "paged":
        print(f"kv pool: {kv['pages_used']}/{kv['n_pages']} pages used "
              f"(peak {kv['peak_pages_used']}, "
              f"page_size {kv['page_size']}, "
              f"utilization {kv['utilization']:.1%}), "
              f"{kv['kv_bytes_used']}/{kv['kv_bytes_total']} bytes")
        print(f"prefix sharing: {kv['prefix_hit_tokens']} prompt tokens "
              f"served from shared pages, {kv['prefilled_tokens']} "
              f"prefilled, {kv['prefix_cached_pages']} pages cached, "
              f"{kv['page_resumes']} page-retained resumes")
    else:
        print(f"kv (dense slots): {kv['kv_bytes_total']} bytes total, "
              f"{kv['kv_bytes_per_slot']} per slot")


if __name__ == "__main__":
    main()
