"""End-to-end serving driver (the paper's deployment scenario): BERT_BASE
(110M params) answering batched requests through the block-sparse runtime,
driven entirely by the unified ``repro.serving`` API (docs/API.md).

One ``ServingSpec`` declares the whole co-design -- 80% block pruning at the
backend-optimal (128,128) tile (docs/PERF.md), tied masks, fused QKV (one
block-sparse dispatch per attention layer), cross-layer union packing (all
12 encoder layers share ONE specialization per projection group; the paper's
§2.2 task-buffer collapse) -- and ``prepare_servable`` runs prune -> BSR
export -> RowPackPlan -> registry caching in one call. The servable is then
saved and re-loaded (``load_servable``) to show that export cost is paid
once per model, and dense vs sparse serving is timed side by side. Results
are merged into BENCH_kernels.json (section "serving").

Tied masks (the default prune recipe) emulate the high inter-layer pattern
overlap the paper's small-block regularization produces -- that is what
keeps the cross-layer union tight (union overhead 1.0). Pass --no-tied to
prune each layer independently and watch the union fill in.

Run:  PYTHONPATH=src python examples/serve_bert_sparse.py [--requests 6]
          [--no-fused] [--no-union] [--no-tied] [--no-json] [--save DIR]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.core.pruner import oneshot_prune, tied_prune
from repro.models import init_model, model_forward
from repro.runtime.bench_io import update_bench_json
from repro.serving import ServingSpec, load_servable, prepare_servable

SEQ, BATCH = 384, 1


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--sparsity", type=float, default=0.8)
    ap.add_argument("--tile", type=int, default=128)
    ap.add_argument("--no-fused", action="store_true",
                    help="three q/k/v dispatches per layer instead of one")
    ap.add_argument("--no-union", action="store_true",
                    help="one specialization per layer instead of one shared")
    ap.add_argument("--no-tied", action="store_true",
                    help="independent per-layer masks (loose union)")
    ap.add_argument("--no-json", action="store_true",
                    help="skip the BENCH_kernels.json serving section")
    ap.add_argument("--save", default=None, metavar="DIR",
                    help="persist the servable and re-serve via load_servable")
    args = ap.parse_args()

    print("initializing BERT_BASE (110M)...")
    cfg = get_config("bert_base")
    params = init_model(jax.random.PRNGKey(0), cfg)

    spec = ServingSpec(
        tile=(args.tile, args.tile), sparsity=args.sparsity,
        prune="oneshot" if args.no_tied else "tied",
        fuse_qkv=not args.no_fused, cross_layer_union=not args.no_union)
    # prune once here (the dense negative-control baseline below needs the
    # pruned dense tree too) and hand the facade pre-pruned weights
    prune = oneshot_prune if args.no_tied else tied_prune
    pruned, _ = prune(params, spec.sparsity_config())
    servable = prepare_servable(pruned, cfg,
                                dataclasses.replace(spec, prune="none"))
    st = servable.stats()
    print(f"pruned {args.sparsity:.0%} @ {args.tile}x{args.tile} "
          f"({spec.prune} masks); packed tile density {st['density']:.2f}")
    print(f"export: {st['packed_projections']} packed projections "
          f"({'fused QKV' if spec.fuse_qkv else 'unfused'}, "
          f"{'cross-layer union' if spec.cross_layer_union else 'per-layer'})")
    reg = st["registry"]
    print(f"pattern reuse: {reg['hits']} hits / {reg['misses']} misses "
          f"(reuse rate {reg['reuse_rate']:.0%}), {st['unique_patterns']} "
          f"unique patterns serve {st['packed_projections']} projections "
          f"across {cfg.n_layers} layers")
    if st["union_overhead"] is not None:
        print(f"cross-layer union overhead: {st['union_overhead']:.2f}x "
              f"(union tiles / mean per-layer tiles; 1.0 = perfectly tied)")

    if args.save:
        servable.save(args.save)
        servable = load_servable(args.save)
        print(f"saved + reloaded servable from {args.save} "
              f"(no re-export: registry_at_save="
              f"{servable.stats()['registry_at_save']})")

    # the dense baseline serves the SAME pruned weights without BSR support
    # (the paper's negative control)
    dense_fn = jax.jit(lambda p, t: model_forward(p, cfg, {"tokens": t})[0])
    rng = np.random.RandomState(0)
    reqs = [jnp.asarray(rng.randint(0, cfg.vocab_size, (BATCH, SEQ)))
            for _ in range(args.requests)]
    # warmup/compile
    jax.block_until_ready(dense_fn(pruned, reqs[0]))
    jax.block_until_ready(servable.forward(reqs[0]))

    times = {}
    for name, fn in (("dense", lambda r: dense_fn(pruned, r)),
                     ("BSR", servable.forward)):
        t0 = time.perf_counter()
        for r in reqs:
            out = fn(r)
        jax.block_until_ready(out)
        dt = (time.perf_counter() - t0) / args.requests
        times[name] = dt
        print(f"{name:6s} serving: {dt*1e3:8.1f} ms/request")

    d = dense_fn(pruned, reqs[0])
    s = servable.forward(reqs[0])
    delta = float(jnp.max(jnp.abs(d - s)))
    print(f"parity: max |delta logits| = {delta:.2e}")

    if not args.no_json:
        path = update_bench_json("serving", {
            "model": cfg.arch, "seq": SEQ, "batch": BATCH,
            "requests": args.requests, "sparsity": args.sparsity,
            "tile": list(spec.tile), "fused_qkv": spec.fuse_qkv,
            "cross_layer_union": spec.cross_layer_union,
            "tied_masks": spec.prune == "tied",
            "dense_ms_per_request": round(times["dense"] * 1e3, 2),
            "sparse_ms_per_request": round(times["BSR"] * 1e3, 2),
            "speedup_vs_dense": round(times["dense"] / times["BSR"], 3),
            "max_abs_logit_delta": delta,
            "packed_tile_density": round(st["density"], 4),
            "union_overhead": (round(st["union_overhead"], 3)
                               if st["union_overhead"] is not None else None),
            "pattern_reuse": {**reg,
                              "unique_patterns": st["unique_patterns"],
                              "packed_projections":
                                  st["packed_projections"]},
        })
        print(f"wrote serving section to {path}")


if __name__ == "__main__":
    main()
