"""End-to-end serving driver (the paper's deployment scenario): BERT_BASE
(110M params) answering batched requests through the block-sparse runtime.

Pipeline: init 110M model -> 80% block pruning at the backend-optimal
(128,128) tile (see docs/PERF.md for how that shape was found) -> BSR export
with the full exec-plan stack -- precomputed RowPackPlans, fused QKV (one
block-sparse dispatch per attention layer), and cross-layer union packing so
all 12 encoder layers share ONE specialization per projection group (the
paper's §2.2 task-buffer collapse, visible in the printed PatternRegistry
reuse stats) -> jit'd batched serving loop, dense vs sparse timed side by
side. Results are merged into BENCH_kernels.json (section "serving").

By default layers are pruned with a *tied* block mask (scores = mean block
norm across layers), emulating the high inter-layer pattern overlap the
paper's small-block regularization produces -- that is what keeps the
cross-layer union tight (union overhead 1.0). Pass --no-tied to prune each
layer independently and watch the union fill in.

Run:  PYTHONPATH=src python examples/serve_bert_sparse.py [--requests 6]
          [--no-fused] [--no-union] [--no-tied] [--no-json]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.core import PatternRegistry, SparsityConfig
from repro.core.pruner import oneshot_prune
from repro.models import bert as bert_mod
from repro.models import init_model
from repro.models.sparse_exec import export_bert_sparse
from repro.runtime.bench_io import update_bench_json

TARGETS = ("attn/wq", "attn/wk", "attn/wv", "attn/wo", "ffn/wi", "ffn/wo")
SEQ, BATCH = 384, 1


def tied_prune(params, tile, sparsity, targets=TARGETS):
    """Prune every encoder layer with ONE shared block mask per projection
    (block scores = mean block norm across layers). This is the serving-side
    stand-in for the inter-layer overlap that small-block regularized
    training yields (paper §2.2): the cross-layer union adds zero padding."""
    layers = params["layers"]
    new_layers = [{**lp, "attn": dict(lp["attn"]), "ffn": dict(lp["ffn"])}
                  for lp in layers]
    bh, bw = tile
    for target in targets:
        group, proj = target.split("/")
        ws = np.stack([np.asarray(jax.device_get(lp[group][proj]["w"]),
                                  np.float32) for lp in layers])
        l, n, k = ws.shape
        norms = np.sqrt((ws.reshape(l, n // bh, bh, k // bw, bw) ** 2)
                        .sum(axis=(2, 4))).mean(axis=0)
        keep = max(1, int(round(norms.size * (1.0 - sparsity))))
        thresh = np.partition(norms.ravel(), -keep)[-keep]
        expand = np.kron((norms >= thresh).astype(np.float32),
                         np.ones(tile, np.float32))
        for i, lp in enumerate(layers):
            dtype = lp[group][proj]["w"].dtype
            new_layers[i][group][proj] = {
                "w": jnp.asarray(ws[i] * expand).astype(dtype)}
    new = dict(params)
    new["layers"] = tuple(new_layers)
    return new


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--sparsity", type=float, default=0.8)
    ap.add_argument("--tile", type=int, default=128)
    ap.add_argument("--no-fused", action="store_true",
                    help="three q/k/v dispatches per layer instead of one")
    ap.add_argument("--no-union", action="store_true",
                    help="one specialization per layer instead of one shared")
    ap.add_argument("--no-tied", action="store_true",
                    help="independent per-layer masks (loose union)")
    ap.add_argument("--no-json", action="store_true",
                    help="skip the BENCH_kernels.json serving section")
    args = ap.parse_args()
    tile = (args.tile, args.tile)

    print("initializing BERT_BASE (110M)...")
    cfg = get_config("bert_base")
    params = init_model(jax.random.PRNGKey(0), cfg)

    if args.no_tied:
        sp = SparsityConfig(block_shape=tile, sparsity=args.sparsity,
                            targets=TARGETS)
        pruned, _ = oneshot_prune(params, sp)
    else:
        pruned = tied_prune(params, tile, args.sparsity)

    registry = PatternRegistry()
    union_stats = {}
    sparse_params, packs = export_bert_sparse(
        pruned, cfg, tile=tile, fuse_qkv=not args.no_fused,
        cross_layer_union=not args.no_union, registry=registry,
        stats_out=union_stats)
    density = float(np.mean([p.density for p in packs.values()]))
    n_unique = len({p.fingerprint if hasattr(p, "fingerprint") else id(p)
                    for p in packs.values()})
    st = registry.stats
    print(f"pruned {args.sparsity:.0%} @ {args.tile}x{args.tile} "
          f"({'tied' if not args.no_tied else 'independent'} masks); "
          f"packed tile density {density:.2f}")
    print(f"export: {len(packs)} packed projections "
          f"({'fused QKV' if not args.no_fused else 'unfused'}, "
          f"{'cross-layer union' if not args.no_union else 'per-layer'})")
    print(f"pattern reuse: {st.hits} hits / {st.misses} misses "
          f"(reuse rate {st.reuse_rate:.0%}), {n_unique} unique patterns "
          f"serve {len(packs)} projections across {cfg.n_layers} layers")
    union_overhead = None
    if union_stats:
        union_overhead = float(np.mean(
            [s["union_overhead"] for s in union_stats.values()]))
        print(f"cross-layer union overhead: {union_overhead:.2f}x "
              f"(union tiles / mean per-layer tiles; 1.0 = perfectly tied)")

    dense_fn = jax.jit(lambda p, t: bert_mod.forward(p, cfg, t))
    sparse_fn = jax.jit(lambda p, t: bert_mod.forward(p, cfg, t,
                                                      packs=packs))
    rng = np.random.RandomState(0)
    reqs = [jnp.asarray(rng.randint(0, cfg.vocab_size, (BATCH, SEQ)))
            for _ in range(args.requests)]
    # warmup/compile
    jax.block_until_ready(dense_fn(pruned, reqs[0]))
    jax.block_until_ready(sparse_fn(sparse_params, reqs[0]))

    times = {}
    for name, fn, p in (("dense", dense_fn, pruned),
                        ("BSR", sparse_fn, sparse_params)):
        t0 = time.perf_counter()
        for r in reqs:
            out = fn(p, r)
        jax.block_until_ready(out)
        dt = (time.perf_counter() - t0) / args.requests
        times[name] = dt
        print(f"{name:6s} serving: {dt*1e3:8.1f} ms/request")

    d = dense_fn(pruned, reqs[0])
    s = sparse_fn(sparse_params, reqs[0])
    delta = float(jnp.max(jnp.abs(d - s)))
    print(f"parity: max |delta logits| = {delta:.2e}")

    if not args.no_json:
        path = update_bench_json("serving", {
            "model": cfg.arch, "seq": SEQ, "batch": BATCH,
            "requests": args.requests, "sparsity": args.sparsity,
            "tile": list(tile), "fused_qkv": not args.no_fused,
            "cross_layer_union": not args.no_union,
            "tied_masks": not args.no_tied,
            "dense_ms_per_request": round(times["dense"] * 1e3, 2),
            "sparse_ms_per_request": round(times["BSR"] * 1e3, 2),
            "speedup_vs_dense": round(times["dense"] / times["BSR"], 3),
            "max_abs_logit_delta": delta,
            "packed_tile_density": round(density, 4),
            "union_overhead": (round(union_overhead, 3)
                               if union_overhead is not None else None),
            "pattern_reuse": {"hits": st.hits, "misses": st.misses,
                              "unique_patterns": n_unique,
                              "packed_projections": len(packs)},
        })
        print(f"wrote serving section to {path}")


if __name__ == "__main__":
    main()
