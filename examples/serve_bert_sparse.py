"""End-to-end serving driver (the paper's deployment scenario): BERT_BASE
(110M params) answering batched requests through the block-sparse runtime.

Pipeline: init 110M model -> 80% block pruning at the backend-optimal
(128,128) tile (see EXPERIMENTS.md §Perf for how that shape was found) ->
BSR export -> jit'd batched serving loop, dense vs sparse timed side by side.

Run:  PYTHONPATH=src python examples/serve_bert_sparse.py [--requests 6]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.core import SparsityConfig
from repro.core.pruner import oneshot_prune
from repro.models import bert as bert_mod
from repro.models import init_model
from repro.models.sparse_exec import export_bert_sparse

TARGETS = ("attn/wq", "attn/wk", "attn/wv", "attn/wo", "ffn/wi", "ffn/wo")
SEQ, BATCH = 384, 1


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--sparsity", type=float, default=0.8)
    ap.add_argument("--tile", type=int, default=128)
    args = ap.parse_args()

    print("initializing BERT_BASE (110M)...")
    cfg = get_config("bert_base")
    params = init_model(jax.random.PRNGKey(0), cfg)

    sp = SparsityConfig(block_shape=(args.tile, args.tile),
                        sparsity=args.sparsity, targets=TARGETS)
    pruned, _ = oneshot_prune(params, sp)
    sparse_params, packs = export_bert_sparse(pruned, cfg,
                                              tile=(args.tile, args.tile))
    density = float(np.mean([p.density for p in packs.values()]))
    print(f"pruned {args.sparsity:.0%} @ {args.tile}x{args.tile}; "
          f"packed tile density {density:.2f}")

    dense_fn = jax.jit(lambda p, t: bert_mod.forward(p, cfg, t))
    sparse_fn = jax.jit(lambda p, t: bert_mod.forward(p, cfg, t,
                                                      packs=packs))
    rng = np.random.RandomState(0)
    reqs = [jnp.asarray(rng.randint(0, cfg.vocab_size, (BATCH, SEQ)))
            for _ in range(args.requests)]
    # warmup/compile
    jax.block_until_ready(dense_fn(pruned, reqs[0]))
    jax.block_until_ready(sparse_fn(sparse_params, reqs[0]))

    for name, fn, p in (("dense", dense_fn, pruned),
                        ("BSR", sparse_fn, sparse_params)):
        t0 = time.perf_counter()
        for r in reqs:
            out = fn(p, r)
        jax.block_until_ready(out)
        dt = (time.perf_counter() - t0) / args.requests
        print(f"{name:6s} serving: {dt*1e3:8.1f} ms/request")

    d = dense_fn(pruned, reqs[0])
    s = sparse_fn(sparse_params, reqs[0])
    print(f"parity: max |delta logits| = {float(jnp.max(jnp.abs(d-s))):.2e}")


if __name__ == "__main__":
    main()
