"""Quickstart: the paper's algorithm->compilation co-design flow in 50 lines.

  1. take a BERT encoder, declare the co-design as ONE ServingSpec
     (block pruning recipe + tile + fusion/union + backend)
  2. prepare_servable runs prune -> BSR export -> exec plans -> registry
  3. serve through the block-sparse kernels; verify parity with dense
  4. inspect stats(): density, union overhead, pattern reuse
  5. save / load_servable: export cost is paid once per model

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.core.pruner import sparsity_report, tied_prune
from repro.models import init_model, model_forward
from repro.serving import ServingSpec, load_servable, prepare_servable


def main():
    cfg = get_config("bert_base", smoke=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(np.random.RandomState(0).randint(
        0, cfg.vocab_size, (4, 48)))

    # 1. + 2. one spec, one call (paper Eq. 3 pruning + TVM-analogue export).
    # We prune outside the facade (prune='none') only because step 3's dense
    # parity check needs the pruned dense tree too; prune='tied' would run
    # the same recipe inside prepare_servable.
    spec = ServingSpec(tile=(16, 16), sparsity=0.8, prune="none")
    pruned, _ = tied_prune(params, spec.sparsity_config())
    servable = prepare_servable(pruned, cfg, spec)

    print("per-weight block sparsity:",
          {k.split('/')[-2]: round(v, 2) for k, v in
           list(sparsity_report(pruned, spec.sparsity_config()).items())[:4]})

    # 3. sparse serving parity vs dense execution of the same pruned weights
    dense_out, _ = model_forward(pruned, cfg, {"tokens": toks})
    sparse_out = servable.forward(toks)
    err = float(jnp.max(jnp.abs(dense_out - sparse_out)))
    print(f"dense-vs-BSR max |delta logits| = {err:.2e}")

    # 4. the co-design scorecard
    st = servable.stats()
    print(f"stats: density {st['density']:.2f}, union overhead "
          f"{st['union_overhead']:.2f}x, {st['unique_patterns']} unique "
          f"patterns for {st['packed_projections']} projections, registry "
          f"{st['registry']['hits']} hits / {st['registry']['misses']} misses")

    # 5. persistence: serve again without re-running the export
    with tempfile.TemporaryDirectory() as ckpt:
        servable.save(ckpt)
        reloaded = load_servable(ckpt)
        err = float(jnp.max(jnp.abs(reloaded.forward(toks) - sparse_out)))
        print(f"save -> load_servable round-trip delta = {err:.2e}")


if __name__ == "__main__":
    main()
