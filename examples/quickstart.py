"""Quickstart: the paper's algorithm->compilation co-design flow in 60 lines.

  1. take a BERT encoder, block-prune its attention + FC weights (80%)
  2. export to BSR (SciPy-style data/indices/indptr, tile-packed)
  3. serve through the block-sparse kernels; verify parity with dense
  4. inspect the pattern-reuse ("task scheduler") statistics

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.core import PatternRegistry, SparsityConfig
from repro.core.bsr import dense_to_bsr
from repro.core.pruner import oneshot_prune, sparsity_report
from repro.models import bert as bert_mod
from repro.models import init_model
from repro.models.sparse_exec import export_bert_sparse

TARGETS = ("attn/wq", "attn/wk", "attn/wv", "attn/wo", "ffn/wi", "ffn/wo")


def main():
    cfg = get_config("bert_base", smoke=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(np.random.RandomState(0).randint(
        0, cfg.vocab_size, (4, 48)))

    # 1. structured pruning (paper Eq. 3: block-grouped norm, magnitude rule)
    sp = SparsityConfig(block_shape=(16, 16), sparsity=0.8, targets=TARGETS)
    pruned, masks = oneshot_prune(params, sp)
    print("per-weight block sparsity:",
          {k.split('/')[-2]: round(v, 2)
           for k, v in list(sparsity_report(pruned, sp).items())[:4]})

    # 2. BSR export (the TVM-relay-conversion analogue)
    sparse_params, packs = export_bert_sparse(pruned, cfg, tile=(16, 16))
    print(f"exported {len(packs)} BSR weights, "
          f"mean tile density {np.mean([p.density for p in packs.values()]):.2f}")

    # 3. sparse serving parity
    dense_out = bert_mod.forward(pruned, cfg, toks)
    sparse_out = bert_mod.forward(sparse_params, cfg, toks, packs=packs)
    err = float(jnp.max(jnp.abs(dense_out - sparse_out)))
    print(f"dense-vs-BSR max |delta logits| = {err:.2e}")

    # 4. pattern reuse: identical layer patterns compile once
    reg = PatternRegistry()
    fn = lambda m: m.data.sum()
    for lp in pruned["layers"]:
        w = np.asarray(lp["attn"]["wq"]["w"], np.float32)
        reg.specialize(fn, dense_to_bsr(w, (16, 16)))
    print(f"task buffer: {reg.stats.misses} compilations, "
          f"{reg.stats.hits} reuses across {len(pruned['layers'])} layers")


if __name__ == "__main__":
    main()
