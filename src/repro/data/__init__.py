from repro.data.pipeline import DataConfig, DataPipeline
