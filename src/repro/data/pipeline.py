"""Deterministic, shard-aware token pipeline with background prefetch.

Sources: synthetic (seeded zipfian tokens -- offline-safe) or a binary token
file (memory-mapped uint16/uint32). Every host pulls only its own slice of
the global batch (host-local sharding); the iterator is stateless given
(seed, step), so restart-after-failure resumes at the exact batch without
data loss or duplication -- required for fault-tolerant training.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int = 1024
    global_batch: int = 8
    vocab_size: int = 32000
    seed: int = 0
    source: str = "synthetic"        # synthetic | file
    path: Optional[str] = None
    n_hosts: int = 1
    host_id: int = 0
    prefetch: int = 2

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts


def _synthetic_batch(cfg: DataConfig, step: int) -> dict:
    """Zipf-ish tokens, deterministic in (seed, step, host)."""
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, cfg.host_id]))
    z = rng.zipf(1.3, size=(cfg.host_batch, cfg.seq_len + 1))
    toks = (z % cfg.vocab_size).astype(np.int32)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class _FileSource:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self.arr = np.memmap(cfg.path, dtype=np.uint32, mode="r")
        self.n_windows = (len(self.arr) - 1) // cfg.seq_len

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, step]))
        # one global permutation draw; hosts take disjoint strides
        starts = rng.integers(0, self.n_windows, size=cfg.global_batch)
        mine = starts[cfg.host_id::cfg.n_hosts][: cfg.host_batch]
        toks = np.stack([self.arr[s * cfg.seq_len:(s + 1) * cfg.seq_len + 1]
                         for s in mine]).astype(np.int32)
        toks %= cfg.vocab_size
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class DataPipeline:
    """Background-prefetching iterator, resumable at any step."""

    def __init__(self, cfg: DataConfig, start_step: int = 0):
        self.cfg = cfg
        self._file = _FileSource(cfg) if cfg.source == "file" else None
        self._step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=cfg.prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def batch_at(self, step: int) -> dict:
        if self._file is not None:
            return self._file.batch(step)
        return _synthetic_batch(self.cfg, step)

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            b = self.batch_at(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, b), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        step, b = self._q.get()
        self._step = step
        return b

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2.0)
