"""Unified spec-driven serving API (docs/API.md).

    from repro.serving import ServingSpec, prepare_servable, load_servable

    servable = prepare_servable(params, cfg, ServingSpec(sparsity=0.8))
    logits = servable.forward(batch)
    servable.save("ckpt/")            # export cost paid once per model
    servable = load_servable("ckpt/")

    engine = servable.engine(max_slots=16, cache_len=512)   # continuous
    h = engine.submit(prompt_tokens, max_new_tokens=32)     # batching
    engine.run(); print(h.tokens)
"""
from repro.serving.engine import (EngineRequest, EngineStats, FailureReason,
                                  ServingEngine, TERMINAL_STATES)
from repro.serving.export import (export_bert_sparse, export_lm_sparse,
                                  export_params, pack_single, pack_stacked,
                                  shard_axis_for)
from repro.serving.serialize import ServableLoadError
from repro.serving.servable import (SERVABLE_STEP, Servable, load_servable,
                                    make_serving_mesh, prepare_servable)
from repro.serving.spec import (DEFAULT_TARGETS, OVERFLOW_POLICIES,
                                SchedSpec, ServingSpec)

__all__ = [n for n in dir() if not n.startswith("_")]
