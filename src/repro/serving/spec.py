"""ServingSpec: one declarative description of the prune->export->plan->serve
co-design (docs/API.md).

The paper's thesis is that sparsity wins only materialize when the algorithm
side (pruning shape/recipe) and the execution side (BSR packing, plan
specialization) are chosen together. A ``ServingSpec`` is that joint choice
as data: :func:`repro.serving.prepare_servable` consumes it and owns every
layout/fusion/reuse decision, the way a production sparse-serving compiler
owns them behind a single entry point.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

from repro.core.sparsity import SparsityConfig

#: default prunable projections (attention + FC, the paper's BERT targets)
DEFAULT_TARGETS = ("attn/wq", "attn/wk", "attn/wv", "attn/wo",
                   "ffn/wi", "ffn/wo")

PRUNE_RECIPES = ("none", "oneshot", "tied")
BACKENDS = ("plan", "bsr", "dense", "auto")


@dataclasses.dataclass(frozen=True)
class ServingSpec:
    """Declarative spec for :func:`repro.serving.prepare_servable`.

    Attributes:
      tile: kernel tile == pruning block shape. Small *sparsity* blocks from
        training are aggregated into this tile at export (docs/PERF.md).
      sparsity: block-sparsity target for the prune step (ignored when
        ``prune='none'``).
      prune: weight-preparation recipe --
        ``'none'``    params are already pruned (e.g. by training);
        ``'oneshot'`` independent per-layer magnitude masks
        (:func:`repro.core.pruner.oneshot_prune`);
        ``'tied'``    one mask shared across layers per projection group
        (:func:`repro.core.pruner.tied_prune`) -- keeps the cross-layer
        union tight, emulating small-block regularized training.
      targets: substrings selecting prunable projections.
      fuse_qkv: concatenate wq/wk/wv into one pack -> one block-sparse
        dispatch per attention layer.
      cross_layer_union: union the per-layer patterns of unrolled encoders so
        all layers share ONE specialization (scan-stacked LM groups always
        union). The paper's §2.2 task-buffer collapse.
      backend: ``'plan'`` stores weights row-grouped offline and serves
        through the precomputed-RowPackPlan path (the serving optimum);
        ``'bsr'`` keeps packed ``(nnzt, bn, bk)`` values and dispatches via
        ``bsr_linear``'s runtime backends (rowpack on CPU, pallas on TPU);
        ``'dense'`` skips BSR export entirely -- the (possibly pruned)
        weights serve through plain dense matmuls, the paper's negative
        control and the benchmark baseline; ``'auto'`` micro-benchmarks
        {dense, gather, rowpack, plan, pallas, masked} per pattern
        fingerprint on the current device (``kernels/autotune.py``) and
        pins each projection group to the measured winner -- winners are
        persisted on disk and reused across processes, and ``stats()``
        reports the chosen backend per layer group.
      autotune_m: batch-rows the ``'auto'`` micro-benchmark measures at
        (part of the winner-cache key; other backends ignore it).
      dtype: optional dtype override ('float32' | 'bfloat16') applied to the
        exported packed values; None keeps the model dtype.
      include_ffn: export FFN projections too. For bert this is
        unconditional; lm-family exports pack a dense-MLP projection only
        when it is actually block-sparse at the kernel tile (packing an
        unpruned projection is pure loss), so attention-only prune recipes
        keep serving their FFN dense.
    """

    tile: Tuple[int, int] = (128, 128)
    sparsity: float = 0.8
    prune: str = "tied"
    targets: Sequence[str] = DEFAULT_TARGETS
    fuse_qkv: bool = True
    cross_layer_union: bool = True
    backend: str = "plan"
    dtype: Optional[str] = None
    include_ffn: bool = True
    autotune_m: int = 256

    def __post_init__(self):
        if self.prune not in PRUNE_RECIPES:
            raise ValueError(f"prune={self.prune!r} not in {PRUNE_RECIPES}")
        if self.backend not in BACKENDS:
            raise ValueError(f"backend={self.backend!r} not in {BACKENDS}")
        if self.dtype not in (None, "float32", "bfloat16"):
            raise ValueError(f"unsupported dtype {self.dtype!r}")

    @property
    def use_plans(self) -> bool:
        return self.backend == "plan"

    def sparsity_config(self) -> SparsityConfig:
        """The prune step's config (kernel tile == pruning block here; a
        finer training-time block is aggregated at export by pack_bsr)."""
        return SparsityConfig(block_shape=tuple(self.tile),
                              sparsity=self.sparsity,
                              targets=tuple(self.targets))

    # -- (de)serialization -------------------------------------------------
    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["tile"] = list(self.tile)
        d["targets"] = list(self.targets)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "ServingSpec":
        d = dict(d)
        d["tile"] = tuple(d["tile"])
        d["targets"] = tuple(d["targets"])
        return cls(**d)
