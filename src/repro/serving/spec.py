"""ServingSpec: one declarative description of the prune->export->plan->serve
co-design (docs/API.md).

The paper's thesis is that sparsity wins only materialize when the algorithm
side (pruning shape/recipe) and the execution side (BSR packing, plan
specialization) are chosen together. A ``ServingSpec`` is that joint choice
as data: :func:`repro.serving.prepare_servable` consumes it and owns every
layout/fusion/reuse decision, the way a production sparse-serving compiler
owns them behind a single entry point.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

from repro.core.sparsity import SparsityConfig

#: default prunable projections (attention + FC, the paper's BERT targets)
DEFAULT_TARGETS = ("attn/wq", "attn/wk", "attn/wv", "attn/wo",
                   "ffn/wi", "ffn/wo")

PRUNE_RECIPES = ("none", "oneshot", "tied")
BACKENDS = ("plan", "plan_pallas", "bsr", "dense", "auto")
#: attention decode-step kernel: 'xla' (materialized softmax), 'flash'
#: (split-K online-softmax Pallas kernel, kernels/flash_decode.py), or
#: 'auto' (choose_decode_kernel measures/stubs per shape+device; the
#: REPRO_DECODE_KERNEL env var overrides all of these at trace time).
DECODE_KERNELS = ("auto", "xla", "flash")
PARTITIONS = ("tp", "dp", "tp+dp")
#: admission-queue backpressure policies (ServingEngine(overflow=...),
#: docs/API.md §Engine robustness). With a bounded queue (max_queue):
#:   'reject'     -- the NEW submission is shed (structured FailureReason,
#:                   never enqueued) -- the load-balancer-friendly default;
#:   'shed-oldest'-- the oldest queued request is shed to make room (fresh
#:                   traffic beats stale traffic whose client likely gave
#:                   up);
#:   'block'      -- submit() drives engine steps until the queue drains
#:                   below the bound (single-process ingest throttling).
OVERFLOW_POLICIES = ("reject", "shed-oldest", "block")
#: KV-cache layouts (docs/API.md §Paged KV + prefix cache):
#:   'dense' -- per-slot (max_slots, max_seq, ...) slot caches, the parity
#:              oracle; 'paged' -- page-pool storage for linear attention/MLA
#:              KV with per-slot page tables, a host-side refcounting
#:              allocator and a radix prefix cache (serving/paging.py,
#:              serving/prefix_cache.py).
KV_LAYOUTS = ("dense", "paged")
#: pack-sharding mesh support: the plan path shards by construction
#: (ShardedPlan), dense serves through GSPMD param sharding, and 'auto'
#: chooses between exactly those two; 'bsr' has no sharded layout.
SHARDED_BACKENDS = ("plan", "dense", "auto")
#: pack-value quantization (docs/API.md §Quantized sparse packs):
#:   'none' -- fp32/bf16 values, the parity oracle;
#:   'int8' -- symmetric int8 with one fp32 scale per BSR block (per
#:             row group for skinny tiles), dequant fused into the plan
#:             matmul accumulation;
#:   'fp8'  -- float8_e4m3fn values, same scale layout (requires a jax
#:             with float8 dtypes; raises a clear error otherwise).
#: Only plan-layout packs quantize ('plan' / 'plan_pallas' / the plan
#: verdicts of 'auto'); bsr/dense/masked packs serve full precision.
PACK_QUANTS = ("none", "int8", "fp8")
#: backends whose packs carry quantized values when pack_quant != 'none'
QUANTIZABLE_BACKENDS = ("plan", "plan_pallas", "auto")


@dataclasses.dataclass(frozen=True)
class SchedSpec:
    """SLO-aware scheduler knobs for the serving engine (docs/API.md §SLO
    scheduling). A default-constructed ``SchedSpec()`` (all knobs off) is
    behaviorally identical to an engine without one.

    Attributes:
      max_chunk: > 0 enables **chunked prefill**: prompts prefill in slices
        of at most ``max_chunk`` tokens, one slice per window-sync point,
        interleaved with running decodes -- a long prompt no longer
        head-of-line blocks the decode batch. 0 = one-shot prefill (the
        legacy path). Chunking silently falls back to one-shot for configs
        it cannot serve exactly (MoE FFN capacity routing, int8 KV
        quantization, the audio family).
      token_budget: > 0 caps the tokens each window-sync point may spend
        across prefill chunks + new admissions (decode tokens are reserved
        first under ``decode_priority``). 0 = unlimited (admit-everything,
        the legacy behavior). Only meaningful with ``max_chunk`` > 0.
      decode_priority: reserve ``n_decoding * sync_every`` tokens of the
        budget for the running decodes before spending any of it on
        prefill work, so prefill pressure cannot starve token streaming.
      fast_fail: arm the admission-time deadline estimator: a queued
        request whose deadline provably cannot be met at the engine's
        *measured* prefill/decode rates (EngineStats) fails with
        ``FailureReason.DEADLINE`` before consuming a prefill slot.
        Already-expired deadlines fast-fail regardless of this knob.
      max_queue_delay_s: > 0 arms SLO-aware overload shedding: when the
        estimated backlog drain time exceeds this bound, queued requests
        are shed lowest-priority-first (newest-first within a class) with
        ``FailureReason.OVERLOAD`` until the backlog fits. 0 = never shed
        on load (the bounded-queue ``overflow`` policies still apply).
    """

    max_chunk: int = 0
    token_budget: int = 0
    decode_priority: bool = True
    fast_fail: bool = False
    max_queue_delay_s: float = 0.0

    def __post_init__(self):
        if self.max_chunk < 0:
            raise ValueError(f"max_chunk={self.max_chunk} must be >= 0")
        if self.token_budget < 0:
            raise ValueError(
                f"token_budget={self.token_budget} must be >= 0")
        if self.max_queue_delay_s < 0:
            raise ValueError(
                f"max_queue_delay_s={self.max_queue_delay_s} must be >= 0")

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "SchedSpec":
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class ServingSpec:
    """Declarative spec for :func:`repro.serving.prepare_servable`.

    Attributes:
      tile: kernel tile == pruning block shape. Small *sparsity* blocks from
        training are aggregated into this tile at export (docs/PERF.md).
      sparsity: block-sparsity target for the prune step (ignored when
        ``prune='none'``).
      prune: weight-preparation recipe --
        ``'none'``    params are already pruned (e.g. by training);
        ``'oneshot'`` independent per-layer magnitude masks
        (:func:`repro.core.pruner.oneshot_prune`);
        ``'tied'``    one mask shared across layers per projection group
        (:func:`repro.core.pruner.tied_prune`) -- keeps the cross-layer
        union tight, emulating small-block regularized training.
      targets: substrings selecting prunable projections.
      fuse_qkv: concatenate wq/wk/wv into one pack -> one block-sparse
        dispatch per attention layer.
      cross_layer_union: union the per-layer patterns of unrolled encoders so
        all layers share ONE specialization (scan-stacked LM groups always
        union). The paper's §2.2 task-buffer collapse.
      backend: ``'plan'`` stores weights row-grouped offline and serves
        through the precomputed-RowPackPlan path (the serving optimum);
        ``'plan_pallas'`` stores the same row-grouped layout but pins every
        pack to the compiled plan-consuming Pallas kernel (the plan's spill
        schedule drives the grid -- TPU-native, interpret-mode oracle
        elsewhere); ``'bsr'`` keeps packed ``(nnzt, bn, bk)`` values and
        dispatches via
        ``bsr_linear``'s runtime backends (rowpack on CPU, pallas on TPU);
        ``'dense'`` skips BSR export entirely -- the (possibly pruned)
        weights serve through plain dense matmuls, the paper's negative
        control and the benchmark baseline; ``'auto'`` micro-benchmarks
        {dense, gather, rowpack, plan, pallas, masked} per pattern
        fingerprint on the current device (``kernels/autotune.py``) and
        pins each projection group to the measured winner -- winners are
        persisted on disk and reused across processes, and ``stats()``
        reports the chosen backend per layer group.
      autotune_m: batch-rows the ``'auto'`` micro-benchmark measures at
        (part of the winner-cache key; other backends ignore it).
      dtype: optional dtype override ('float32' | 'bfloat16') applied to the
        exported packed values; None keeps the model dtype.
      include_ffn: export FFN projections too. For bert this is
        unconditional; lm-family exports pack a dense-MLP projection only
        when it is actually block-sparse at the kernel tile (packing an
        unpruned projection is pure loss), so attention-only prune recipes
        keep serving their FFN dense.
      mesh_shape: optional ``(data, model)`` device-mesh shape. When set,
        the whole serving path becomes mesh-first: export shards every
        plan pack by output block rows (column-parallel) / input block
        cols (row-parallel wo) over the "model" axis, params and packs are
        placed with NamedSharding at load, engine caches shard batch over
        "data" and heads over "model", and ``stats()`` reports per-shard
        accounting (docs/API.md §Sharded serving). The product must not
        exceed ``jax.device_count()``.
      partition: which parallelism the mesh expresses -- ``'tp'`` (model
        axis only: tensor-parallel packs + caches), ``'dp'`` (data axis
        only: request slots sharded over devices), ``'tp+dp'`` (both).
        Must be consistent with ``mesh_shape`` (a 'tp' mesh needs
        data == 1, etc.). Ignored when ``mesh_shape`` is None.
      kv_layout: ``'dense'`` (per-slot slot caches, the parity oracle) or
        ``'paged'`` (page-pool KV with per-slot page tables, refcounting
        allocator and radix prefix sharing -- docs/API.md §Paged KV).
        Requires ``data_shards == 1``.
      kv_page_size: tokens per physical KV page (paged layout only). Also
        the prefix-sharing granularity: only whole pages are shared, so
        smaller pages share more but gather/scatter more page rows.
      decode_kernel: attention decode-step kernel. ``'xla'`` is the
        materialized-softmax reference, ``'flash'`` the split-K
        online-softmax Pallas kernel (paged caches gather KV pages in
        place -- no dense-view reassembly), ``'auto'`` asks
        ``kernels.autotune.choose_decode_kernel`` per shape+device. The
        ``REPRO_DECODE_KERNEL`` env var overrides any spec value.
      pack_quant: pack-value quantization (docs/API.md §Quantized sparse
        packs). ``'int8'`` stores plan-pack block values as symmetric int8
        with one fp32 scale per BSR block -- per row group when the tile
        is too skinny for a stable block scale -- and serves them through
        the dequant-fused plan matmul (the fp32 values never land in the
        params tree); ``'fp8'`` is the same layout with float8_e4m3fn
        values. Only plan-layout packs quantize: ``backend='plan'`` /
        ``'plan_pallas'`` quantize every pack, ``'auto'`` adds the
        ``plan_q8`` / ``plan_pallas_q8`` candidates so quantization only
        lands where the tuner scores it a win; bsr/dense/masked packs are
        unaffected. ``'none'`` (default) keeps full-precision packs.
      sched: optional :class:`SchedSpec` arming SLO-aware scheduling on
        engines built over this servable (chunked prefill, per-window token
        budget, deadline fast-fail, overload shedding -- docs/API.md §SLO
        scheduling). None (or a default ``SchedSpec()``) keeps the legacy
        admit-everything one-shot-prefill scheduler. The engine's ``sched=``
        kwarg overrides the spec value, mirroring ``kv_layout``.
    """

    tile: Tuple[int, int] = (128, 128)
    sparsity: float = 0.8
    prune: str = "tied"
    targets: Sequence[str] = DEFAULT_TARGETS
    fuse_qkv: bool = True
    cross_layer_union: bool = True
    backend: str = "plan"
    dtype: Optional[str] = None
    include_ffn: bool = True
    autotune_m: int = 256
    mesh_shape: Optional[Tuple[int, int]] = None
    partition: str = "tp"
    kv_layout: str = "dense"
    kv_page_size: int = 16
    decode_kernel: str = "auto"
    pack_quant: str = "none"
    sched: Optional[SchedSpec] = None

    def __post_init__(self):
        if self.kv_layout not in KV_LAYOUTS:
            raise ValueError(
                f"kv_layout={self.kv_layout!r} not in {KV_LAYOUTS}")
        if self.kv_page_size < 1:
            raise ValueError(f"kv_page_size={self.kv_page_size} must be >= 1")
        if self.kv_layout == "paged" and self.data_shards > 1:
            raise ValueError(
                "kv_layout='paged' requires data_shards == 1: the page pool "
                "is a shared id space, so its page axis cannot shard over "
                "'data' (tensor-parallel 'tp' meshes shard the head dims)")
        if self.prune not in PRUNE_RECIPES:
            raise ValueError(f"prune={self.prune!r} not in {PRUNE_RECIPES}")
        if self.backend not in BACKENDS:
            raise ValueError(f"backend={self.backend!r} not in {BACKENDS}")
        if self.decode_kernel not in DECODE_KERNELS:
            raise ValueError(
                f"decode_kernel={self.decode_kernel!r} not in {DECODE_KERNELS}")
        if self.dtype not in (None, "float32", "bfloat16"):
            raise ValueError(f"unsupported dtype {self.dtype!r}")
        if self.pack_quant not in PACK_QUANTS:
            raise ValueError(
                f"pack_quant={self.pack_quant!r} not in {PACK_QUANTS}")
        if (self.pack_quant != "none"
                and self.backend not in QUANTIZABLE_BACKENDS):
            raise ValueError(
                f"pack_quant={self.pack_quant!r} needs a plan-layout "
                f"backend (one of {QUANTIZABLE_BACKENDS}); "
                f"backend={self.backend!r} packs have no per-block scale "
                f"granularity to quantize at")
        if self.sched is not None and not isinstance(self.sched, SchedSpec):
            raise ValueError(
                f"sched must be a SchedSpec or None, got {self.sched!r}")
        if self.partition not in PARTITIONS:
            raise ValueError(
                f"partition={self.partition!r} not in {PARTITIONS}")
        if self.mesh_shape is not None:
            d, m = (int(v) for v in self.mesh_shape)
            if d < 1 or m < 1:
                raise ValueError(f"bad mesh_shape {self.mesh_shape}")
            want = {"tp": m > 1 and d == 1, "dp": d > 1 and m == 1,
                    "tp+dp": d > 1 and m > 1}[self.partition]
            if (d * m > 1) and not want:
                raise ValueError(
                    f"partition={self.partition!r} inconsistent with "
                    f"mesh_shape={self.mesh_shape} (data={d}, model={m})")
            if m > 1 and self.backend not in SHARDED_BACKENDS:
                raise ValueError(
                    f"backend={self.backend!r} has no sharded pack layout; "
                    f"tensor-parallel serving needs one of "
                    f"{SHARDED_BACKENDS}")

    @property
    def use_plans(self) -> bool:
        return self.backend == "plan"

    @property
    def model_shards(self) -> int:
        """Size of the mesh "model" axis (1 = unsharded packs)."""
        return int(self.mesh_shape[1]) if self.mesh_shape is not None else 1

    @property
    def data_shards(self) -> int:
        return int(self.mesh_shape[0]) if self.mesh_shape is not None else 1

    def sparsity_config(self) -> SparsityConfig:
        """The prune step's config (kernel tile == pruning block here; a
        finer training-time block is aggregated at export by pack_bsr)."""
        return SparsityConfig(block_shape=tuple(self.tile),
                              sparsity=self.sparsity,
                              targets=tuple(self.targets))

    # -- (de)serialization -------------------------------------------------
    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["tile"] = list(self.tile)
        d["targets"] = list(self.targets)
        if self.mesh_shape is not None:
            d["mesh_shape"] = list(self.mesh_shape)
        # dataclasses.asdict already lowered the nested SchedSpec to a dict
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "ServingSpec":
        d = dict(d)
        d["tile"] = tuple(d["tile"])
        d["targets"] = tuple(d["targets"])
        if d.get("mesh_shape") is not None:
            d["mesh_shape"] = tuple(d["mesh_shape"])
        if d.get("sched") is not None:
            d["sched"] = SchedSpec.from_dict(d["sched"])
        return cls(**d)
