"""Family-agnostic BSR export passes (the TVM relay-conversion analogue).

Training keeps dense weights + block masks (core.pruner). Serving packs the
pruned projections into tile-granular BSR and -- by default -- lowers each
pattern to a precomputed :class:`~repro.kernels.exec_plan.RowPackPlan`: the
pattern arrays become static plan metadata (cached through
``core.pattern_reuse.PatternRegistry``) and the servable param tree stores
the tile values *already row-grouped*, so the per-call path is pure compute
(docs/PERF.md).

Three pattern-level optimizations happen here, offline:

  * **plans** (``use_plans=True``): weight data is re-laid-out once at export
    instead of on every forward call;
  * **fused QKV** (``fuse_qkv=True``): the wq/wk/wv patterns are concatenated
    along N into a single pack, so attention issues ONE block-sparse matmul
    (one gather of x, one dispatch) per layer instead of three;
  * **cross-layer union** (``export_bert_sparse(cross_layer_union=True)``):
    the per-layer patterns of all encoder layers are unioned so a single
    specialization serves every layer with per-layer data -- the paper's §2.2
    task-buffer mechanism, collapsing 12 compilations to 1. For scan-stacked
    LM layer groups the same union machinery has always applied
    (``pack_stacked``). High inter-layer pattern overlap -- which the paper's
    small-block regularization promotes -- keeps the union tight;
    ``union_overhead`` quantifies the waste.

These passes are consumed by the :func:`repro.serving.prepare_servable`
facade (docs/API.md), which dispatches on ``cfg.family`` via
:func:`export_params`; the per-family entry points remain available for
callers that need one pass in isolation.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.pattern_reuse import PatternRegistry
from repro.kernels.bsr_matmul import KernelBSR, pack_bsr
from repro.kernels.exec_plan import (QuantPlan, build_sharded_plan,
                                     dequantize_plan_values, pack_plan_data,
                                     plan_for_pack, quantize_for_plan,
                                     shard_divisible)

# projection names exported per mixer/ffn kind
_ATTN_PROJS = ("wq", "wk", "wv", "wo")
_QKV = ("wq", "wk", "wv")
_FFN_PROJS = ("wi", "wg", "wo")


def shard_axis_for(proj: str) -> str:
    """Tensor-parallel axis per projection, mirroring the dense rules of
    ``launch/sharding.spec_for_param``: ``wo`` (attention out-proj AND MLP
    down-proj) is row-parallel -- sharded by input block cols, partials
    psum'd -- everything else (wq/wk/wv/wqkv/wi/wg) is column-parallel,
    sharded by output block rows."""
    return "in" if proj == "wo" else "out"

# families whose param tree follows the lm.py prefix/blocks/suffix layout
LM_FAMILIES = ("dense", "moe", "ssm", "hybrid", "vlm")


def _tile_mask(w: np.ndarray, tile) -> np.ndarray:
    n, k = w.shape
    bn, bk = tile
    return np.any(w.reshape(n // bn, bn, k // bk, bk) != 0, axis=(1, 3))


def pack_stacked(w_stacked: np.ndarray, tile) -> Tuple[KernelBSR, jax.Array, Dict]:
    """(L, N, K) -> (pattern pack, per-layer data (L, nnzt, bn, bk), stats)."""
    l, n, k = w_stacked.shape
    bn, bk = tile
    masks = np.stack([_tile_mask(w_stacked[i], tile) for i in range(l)])
    union = masks.any(axis=0)
    # build the pattern from a dense "ones at union" stand-in
    proto = np.kron(union.astype(np.float32), np.ones(tile, np.float32))
    pack = pack_bsr(proto, tile)
    rows = pack.row_id[: pack.nnzt]
    cols = pack.col_id
    blocks = w_stacked.reshape(l, n // bn, bn, k // bk, bk).transpose(0, 1, 3, 2, 4)
    data = blocks[:, rows, cols]                      # (L, nnzt, bn, bk)
    per_layer_nnz = masks.sum(axis=(1, 2))
    stats = {
        "union_nnzt": int(union.sum()),
        "mean_layer_nnzt": float(per_layer_nnz.mean()),
        "union_overhead": float(union.sum() / max(per_layer_nnz.mean(), 1.0)),
    }
    return pack, jnp.asarray(data), stats


def pack_single(w: np.ndarray, tile) -> Tuple[KernelBSR, jax.Array]:
    pack = pack_bsr(w, tile)
    return pack, pack.data


# --------------------------------------------------------------------------
# serving-form helpers (KernelBSR pattern -> plan + row-grouped values)
# --------------------------------------------------------------------------

def _realize_backend(pack, data, backend: str,
                     registry: Optional[PatternRegistry],
                     shard=None, shard_stats=None, quant: str = "none"):
    """(pattern, packed values, chosen backend) -> (static pack stored in
    ``packs``, values stored in the params tree). ``data`` is
    ``(nnzt, bn, bk)`` or layer-stacked ``(L, nnzt, bn, bk)``.

      * ``plan``    -> RowPackPlan + row-grouped values (the default path);
        with ``shard = (n_shards, axis)`` and a divisible pattern, a
        :class:`~repro.kernels.exec_plan.ShardedPlan` whose vrow axis is
        mesh-"model"-shardable (indivisible patterns fall back to the
        replicated plan, the ``spec_for_param`` divisibility rule);
      * ``plan_pallas`` -> the same row-grouped layout wrapped in a
        :class:`~repro.kernels.exec_plan.PlanChoice` pinning every call to
        the compiled plan-consuming Pallas kernel (no sharded form --
        ShardedPlan stays on the XLA 'plan' path);
      * ``bsr``     -> bare KernelBSR (runtime ``default_backend()``);
      * ``gather``/``rowpack``/``pallas`` -> the pattern pinned to that
        ``bsr_linear`` backend (``autotune.BackendChoice``);
      * ``masked``  -> dense-layout values + static tile mask
        (``autotune.MaskedPack``);
      * ``dense``   -> ``(None, None)``: the caller keeps the original
        dense weight and stores no pack (measurement said format support
        does not pay here).

    ``quant != 'none'`` (spec ``pack_quant``) quantizes the plan-layout
    backends: the values come back as a ``{"w": qvalues, "scale": scales}``
    dict wrapped in a :class:`~repro.kernels.exec_plan.QuantPlan` (other
    backends ignore it -- no per-block scale granularity to quantize at).
    The explicit ``plan_q8`` / ``plan_pallas_q8`` backends are the autotune
    verdict names for the same layouts.
    """
    if backend in ("plan", "plan_q8"):
        if shard is not None and shard[0] > 1 \
                and shard_divisible(pack, shard[0], shard[1]):
            # built (not combined-cached) per call so identical layers
            # still count per-shard registry hits; plans stay shared
            # downstream via fingerprint hash/eq
            plan = build_sharded_plan(pack, shard[0], shard[1],
                                      registry=registry,
                                      shard_stats=shard_stats)
        else:
            plan = plan_for_pack(pack, registry)
        if backend == "plan_q8" or (backend == "plan" and quant != "none"):
            return quantize_for_plan(plan, data,
                                     quant if quant != "none" else "int8",
                                     backend="plan")
        return plan, pack_plan_data(plan, data)
    if backend in ("plan_pallas", "plan_pallas_q8"):
        from repro.kernels.exec_plan import PlanChoice
        plan = plan_for_pack(pack, registry)
        if backend == "plan_pallas_q8" or quant != "none":
            return quantize_for_plan(plan, data,
                                     quant if quant != "none" else "int8",
                                     backend="plan_pallas")
        return PlanChoice(plan), pack_plan_data(plan, data)
    if backend == "bsr":
        return pack, data
    if backend == "dense":
        return None, None
    from repro.kernels.autotune import (BackendChoice, dense_from_pack,
                                        masked_pack_from)
    if backend == "masked":
        data = np.asarray(jax.device_get(jnp.asarray(data)))
        if data.ndim == 4:      # (L, nnzt, bn, bk) -> (L, N, K)
            vals = np.stack([dense_from_pack(pack, d) for d in data])
        else:
            vals = dense_from_pack(pack, data)
        return masked_pack_from(pack), jnp.asarray(vals)
    if backend in ("gather", "rowpack", "pallas"):
        return BackendChoice(pack, backend), data
    raise ValueError(f"unknown serving backend {backend!r}")


def _effective_shard(pack, shard):
    """The shard config this pack will ACTUALLY serve under: None unless a
    mesh is active and the pattern divides -- keeps the autotune cache key
    (and candidate restriction) honest for replicated-fallback packs, and
    keeps single-argument ``backend_chooser`` callbacks working unsharded."""
    if shard is not None and shard[0] > 1 and shard_divisible(pack, *shard):
        return shard
    return None


def _choose(chooser, pack, shard):
    """Invoke a backend chooser, passing ``shard=`` only when this pack
    really shards (pre-mesh choosers take a single argument)."""
    return chooser(pack) if shard is None else chooser(pack, shard=shard)


def _quant_meta(pk, vals, data) -> Optional[Dict]:
    """Export-time quantization round-trip accounting for a QuantPlan pack:
    max abs dequant error over the stored tiles, absolute and relative to
    the pack's value range. Recorded in the export stats (and surfaced by
    ``Servable.stats()`` / ``stats_dict()``) so precision loss is visible
    where the byte savings are."""
    if not isinstance(pk, QuantPlan):
        return None
    ref = pack_plan_data(pk.plan, data)
    deq = dequantize_plan_values(vals["w"], vals["scale"])
    err = float(jnp.max(jnp.abs(deq - ref)))
    amax = float(jnp.max(jnp.abs(ref)))
    return {"qdtype": pk.qdtype, "granularity": pk.granularity,
            "max_abs_err": err,
            "rel_err": err / amax if amax > 0 else 0.0}


def _serving_pack(w: np.ndarray, tile, use_plans: bool,
                  registry: Optional[PatternRegistry], chooser=None,
                  shard=None, shard_stats=None, quant: str = "none"):
    """(N, K) weight -> (static pattern, values, autotune meta). With plans,
    the values are row-grouped once here -- the scatter the seed backend
    paid per call. A ``chooser`` (kernels/autotune.py) overrides the
    plan/bsr default with the measured winner for this pattern."""
    pack = pack_bsr(w, tile)
    shard = _effective_shard(pack, shard)
    if chooser is None:
        pk, vals = _realize_backend(pack, pack.data,
                                    "plan" if use_plans else "bsr", registry,
                                    shard, shard_stats, quant)
        qmeta = _quant_meta(pk, vals, pack.data)
        return pk, vals, {"quant": qmeta} if qmeta else None
    choice = _choose(chooser, pack, shard)
    pk, vals = _realize_backend(pack, pack.data, choice.backend, registry,
                                shard, shard_stats, quant)
    meta = {"backend": choice.backend,
            "cache_hit": choice.cache_hit, "mode": choice.mode}
    qmeta = _quant_meta(pk, vals, pack.data)
    if qmeta:
        meta["quant"] = qmeta
    return pk, vals, meta


def _serving_pack_stacked(w_stacked: np.ndarray, tile, use_plans: bool,
                          registry: Optional[PatternRegistry], chooser=None,
                          shard=None, shard_stats=None, quant: str = "none"):
    pack, data, stats = pack_stacked(w_stacked, tile)
    shard = _effective_shard(pack, shard)
    if chooser is None:
        pk, vals = _realize_backend(pack, data,
                                    "plan" if use_plans else "bsr", registry,
                                    shard, shard_stats, quant)
        qmeta = _quant_meta(pk, vals, data)
        if qmeta:
            stats = dict(stats, quant=qmeta)
        return pk, vals, stats
    choice = _choose(chooser, pack, shard)
    pk, vals = _realize_backend(pack, data, choice.backend, registry,
                                shard, shard_stats, quant)
    stats = dict(stats)
    stats["autotune"] = {"backend": choice.backend,
                         "cache_hit": choice.cache_hit, "mode": choice.mode}
    qmeta = _quant_meta(pk, vals, data)
    if qmeta:
        stats["quant"] = qmeta
    return pk, vals, stats


def _param_entry(vals, dtype) -> Dict:
    """Params-tree entry for a pack's serving values. Quantized packs come
    back as a ``{"w", "scale"}`` dict whose leaves keep their own dtypes
    (int8/fp8 values, fp32 scales -- the spec ``dtype`` cast must not touch
    them); everything else stores ``{"w": values}`` cast to the model
    dtype."""
    if isinstance(vals, dict):
        return dict(vals)
    return {"w": vals.astype(dtype)}


def _param_entry_layer(vals, i: int, dtype) -> Dict:
    """Per-layer slice of stacked serving values (bert unrolled-encoder
    path): index the leading layer axis of each leaf."""
    if isinstance(vals, dict):
        return {k: v[i] for k, v in vals.items()}
    return {"w": vals[i].astype(dtype)}


def _get_w(p) -> np.ndarray:
    return np.asarray(jax.device_get(p["w"]), np.float32)


def _divisible(shape, tile) -> bool:
    return shape[-2] % tile[0] == 0 and shape[-1] % tile[1] == 0


def _fused_qkv_weight(ap, tile, stacked: bool) -> Optional[np.ndarray]:
    """Concatenate wq/wk/wv along N (one pack, one dispatch); None when a
    projection is missing or a segment boundary would not land on a block
    row (each segment's N must divide the kernel tile's bn)."""
    if not all(proj in ap for proj in _QKV):
        return None
    ws = [_get_w(ap[proj]) for proj in _QKV]
    if not all(_divisible(w.shape, tile) for w in ws):
        return None
    return np.concatenate(ws, axis=1 if stacked else 0)


# --------------------------------------------------------------------------
# per-family export passes
# --------------------------------------------------------------------------

def _pack_nnzt(pk) -> Optional[int]:
    """Stored-tile count of any static pack kind (plan / bsr / choice /
    masked), for the per-scope export stats."""
    if pk is None:
        return None
    inner = getattr(pk, "pack", pk)             # BackendChoice wraps a BSR
    inner = getattr(inner, "plan", inner)       # PlanChoice wraps a plan
    if hasattr(inner, "real_nnzt"):
        return int(inner.real_nnzt)
    if hasattr(inner, "tile_mask"):
        return int(np.sum(inner.tile_mask))
    return None


def export_lm_sparse(params, cfg: ModelConfig, tile=(128, 128), *,
                     fuse_qkv: bool = True, use_plans: bool = True,
                     include_ffn: bool = True,
                     registry: Optional[PatternRegistry] = None,
                     backend_chooser=None, n_shards: int = 1,
                     pack_quant: str = "none"):
    """Replace attention (and pruned FFN) projections of an LM param tree
    with packed values.

    Returns (sparse_params, packs, stats): ``packs`` maps layer scopes
    ('blocks/<i>/<proj>', 'prefix/<i>/<proj>', ...) to static patterns
    (RowPackPlan by default, KernelBSR with ``use_plans=False``); forward()
    consumes them via the ``packs=`` argument. Scan-stacked layer groups are
    union-packed (one specialization, per-layer data); with ``fuse_qkv`` the
    q/k/v projections additionally share one fused pack per layer group.

    With ``include_ffn`` the dense-MLP projections (wi/wg/wo -- the paper's
    FC targets, where most decode FLOPs live) are exported too, but ONLY
    when actually block-sparse at the kernel tile: packing an unpruned
    (100%-density) projection is pure loss, so attention-only prune
    recipes serve their FFN dense exactly as before. MoE FFNs are skipped
    (expert routing has no packs route).

    ``backend_chooser`` (spec ``backend='auto'``, kernels/autotune.py)
    overrides the representation per pattern with the measured winner; a
    ``dense`` verdict keeps the original weight (no pack) and is recorded
    in ``stats`` like every other choice.

    ``n_shards > 1`` (spec ``mesh_shape``) exports every plan pack in
    tensor-parallel sharded form (:func:`shard_axis_for` per projection;
    indivisible patterns fall back to replicated) and records per-shard
    registry accounting under ``stats['__sharding__']``.
    """
    packs: Dict[str, object] = {}
    stats: Dict[str, Dict] = {}
    shard_stats: Dict[int, Dict] = {}
    new = jax.tree_util.tree_map(lambda x: x, params)  # shallow copy-ish

    def _export_one(w, scope, stacked, proj):
        """Pack one weight (single or layer-stacked), record its stats
        under ``scope``, and register the pack. Returns the serving values,
        or None when the pattern serves dense (autotune verdict) -- the
        caller then keeps the original weight."""
        shard = (n_shards, shard_axis_for(proj)) if n_shards > 1 else None
        if stacked:
            pk, data, st = _serving_pack_stacked(
                w, tile, use_plans, registry, backend_chooser,
                shard, shard_stats, pack_quant)
        else:
            pk, data, meta = _serving_pack(
                w, tile, use_plans, registry, backend_chooser,
                shard, shard_stats, pack_quant)
            st = {"union_nnzt": _pack_nnzt(pk)}
            if meta:
                qmeta = meta.pop("quant", None)
                if qmeta:
                    st["quant"] = qmeta
                if meta:
                    st["autotune"] = meta
        stats[scope] = st
        if pk is None:
            return None
        packs[scope] = pk
        return data

    def export_attn(layer_params, scope, stacked):
        if "attn" not in layer_params:
            return layer_params
        ap = dict(layer_params["attn"])
        projs = list(_ATTN_PROJS)
        if fuse_qkv:
            w_qkv = _fused_qkv_weight(ap, tile, stacked)
            if w_qkv is not None:
                dtype = ap["wq"]["w"].dtype
                data = _export_one(w_qkv, f"{scope}/wqkv", stacked, "wqkv")
                if data is not None:
                    ap["wqkv"] = _param_entry(data, dtype)
                    for proj in _QKV:
                        del ap[proj]
                # measured dense: wq/wk/wv stay, unfused
                projs = ["wo"]
        for proj in projs:
            if proj not in ap:
                continue
            w = _get_w(ap[proj])
            if not _divisible(w.shape, tile):
                continue
            data = _export_one(w, f"{scope}/{proj}", stacked, proj)
            if data is not None:
                ap[proj] = _param_entry(
                    data, layer_params["attn"][proj]["w"].dtype)
        out = dict(layer_params)
        out["attn"] = ap
        return out

    def _is_sparse(w: np.ndarray, stacked: bool) -> bool:
        """True iff the (stacked-union) tile occupancy is < 100%: packing a
        dense projection only adds padding and gather overhead."""
        if stacked:
            occ = np.stack([_tile_mask(w[i], tile) for i in range(w.shape[0])]
                           ).any(axis=0)
        else:
            occ = _tile_mask(w, tile)
        return bool(occ.mean() < 1.0)

    def export_ffn(layer_params, scope, stacked):
        # dense-MLP layers only ({'wi': {'w': ...}, ...}): MoE expert trees
        # keep raw (E, d, f) arrays under the same names and have no packs
        # route
        if ("ffn" not in layer_params
                or not isinstance(layer_params["ffn"].get("wi"), dict)):
            return layer_params
        fp = dict(layer_params["ffn"])
        for proj in _FFN_PROJS:
            if proj not in fp:
                continue
            w = _get_w(fp[proj])
            if not _divisible(w.shape, tile) or not _is_sparse(w, stacked):
                continue
            data = _export_one(w, f"{scope}/{proj}", stacked, proj)
            if data is not None:
                fp[proj] = _param_entry(
                    data, layer_params["ffn"][proj]["w"].dtype)
        out = dict(layer_params)
        out["ffn"] = fp
        return out

    def export_layer(lp, scope, stacked):
        lp = export_attn(lp, f"{scope}/attn", stacked)
        if include_ffn:
            lp = export_ffn(lp, f"{scope}/ffn", stacked)
        return lp

    new["prefix"] = tuple(export_layer(lp, f"prefix/{i}", False)
                          for i, lp in enumerate(params["prefix"]))
    new["blocks"] = tuple(export_layer(lp, f"blocks/{i}", True)
                          for i, lp in enumerate(params["blocks"]))
    new["suffix"] = tuple(export_layer(lp, f"suffix/{i}", False)
                          for i, lp in enumerate(params["suffix"]))
    if n_shards > 1:
        stats["__sharding__"] = {"n_shards": n_shards,
                                 "per_shard": shard_stats}
    return new, packs, stats


def export_bert_sparse(params, cfg: ModelConfig, tile=(64, 64),
                       include_ffn=True, *, fuse_qkv: bool = True,
                       cross_layer_union: bool = False,
                       use_plans: bool = True,
                       registry: Optional[PatternRegistry] = None,
                       stats_out: Optional[Dict] = None,
                       backend_chooser=None, n_shards: int = 1,
                       pack_quant: str = "none"):
    """BSR export for the (unrolled) BERT encoder.

    Default: one pattern per layer and projection group (fused QKV). With
    ``cross_layer_union=True`` each projection group is union-packed ACROSS
    the encoder layers, so all L layers share one specialization driven by
    per-layer data -- the 12->1 compilation collapse of the paper's task
    buffer; pass a ``registry`` to read the hit/miss instrumentation
    (L-1 hits per group when the union is active).

    ``stats_out``, if given, is filled with the per-group union stats
    (``union_nnzt`` / ``mean_layer_nnzt`` / ``union_overhead``, keyed by
    '<group>/<name>') -- the union-waste instrumentation the paper proposes
    as follow-up. (Kept out of the return value for caller compatibility.)
    """
    layers = params["layers"]
    n_layers = len(layers)
    packs: Dict[str, object] = {}
    shard_stats: Dict[int, Dict] = {}
    attn_new = [dict(lp["attn"]) for lp in layers]
    ffn_new = [dict(lp["ffn"]) for lp in layers]

    # (group, exported name, per-layer weight extractor, source param name)
    specs = []
    fused_ws = [_fused_qkv_weight(lp["attn"], tile, False) for lp in layers] \
        if fuse_qkv else []
    fuse_now = fuse_qkv and all(w is not None for w in fused_ws)
    if fuse_now:
        by_id = {id(lp): w for lp, w in zip(layers, fused_ws)}
        specs.append(("attn", "wqkv", lambda lp: by_id[id(lp)], "wq"))
    else:
        specs += [("attn", proj, (lambda lp, _p=proj: _get_w(lp["attn"][_p])),
                   proj) for proj in _QKV]
    specs.append(("attn", "wo", lambda lp: _get_w(lp["attn"]["wo"]), "wo"))
    if include_ffn:
        specs += [("ffn", proj, (lambda lp, _p=proj: _get_w(lp["ffn"][_p])),
                   proj) for proj in ("wi", "wo")]

    for group, name, getw, src in specs:
        tgt = attn_new if group == "attn" else ffn_new
        dtypes = [lp[group][src]["w"].dtype for lp in layers]
        shard = (n_shards, shard_axis_for(name)) if n_shards > 1 else None
        if cross_layer_union:
            stacked = np.stack([getw(lp) for lp in layers])
            pack, data, union_st = pack_stacked(stacked, tile)
            shard_eff = _effective_shard(pack, shard)
            if backend_chooser is not None:
                choice = _choose(backend_chooser, pack, shard_eff)
                union_st = dict(union_st)
                union_st["autotune"] = {"backend": choice.backend,
                                        "cache_hit": choice.cache_hit,
                                        "mode": choice.mode}
                pk, vals = _realize_backend(pack, data, choice.backend,
                                            registry, shard_eff, shard_stats,
                                            pack_quant)
                qmeta = _quant_meta(pk, vals, data)
                if qmeta:
                    union_st["quant"] = qmeta
                shared = [pk] * n_layers
            elif use_plans and pack_quant != "none":
                pk, vals = _realize_backend(pack, data, "plan", registry,
                                            shard_eff, shard_stats,
                                            pack_quant)
                qmeta = _quant_meta(pk, vals, data)
                if qmeta:
                    union_st = dict(union_st, quant=qmeta)
                shared = [pk] * n_layers
            elif use_plans:
                # one lookup per layer: the registry's hit counters (global
                # AND per-shard) then show the (L-1)-fold reuse of the
                # single unioned specialization
                if shard_eff is not None:
                    shared = [build_sharded_plan(pack, *shard_eff,
                                                 registry=registry,
                                                 shard_stats=shard_stats)
                              for _ in range(n_layers)]
                else:
                    shared = [plan_for_pack(pack, registry)
                              for _ in range(n_layers)]
                vals = pack_plan_data(shared[0], data)
            else:
                shared = [pack] * n_layers
                vals = data
            if stats_out is not None:
                stats_out[f"{group}/{name}"] = union_st
            if shared[0] is None:       # measured dense: weights untouched
                continue
            for i in range(n_layers):
                packs[f"layers/{i}/{group}/{name}"] = shared[i]
                tgt[i][name] = _param_entry_layer(vals, i, dtypes[i])
        else:
            for i, lp in enumerate(layers):
                pk, vals, meta = _serving_pack(getw(lp), tile, use_plans,
                                               registry, backend_chooser,
                                               shard, shard_stats,
                                               pack_quant)
                if stats_out is not None and meta:
                    st = {"union_nnzt": _pack_nnzt(pk)}
                    qmeta = meta.pop("quant", None)
                    if qmeta:
                        st["quant"] = qmeta
                    if meta:
                        st["autotune"] = meta
                    stats_out[f"layers/{i}/{group}/{name}"] = st
                if pk is None:          # measured dense: weight untouched
                    continue
                packs[f"layers/{i}/{group}/{name}"] = pk
                tgt[i][name] = _param_entry(vals, dtypes[i])

    if fuse_now:
        # only drop the per-projection weights of layers whose fused pack
        # was actually exported (an autotune 'dense' verdict keeps them)
        for i, ap in enumerate(attn_new):
            if f"layers/{i}/attn/wqkv" in packs:
                for proj in _QKV:
                    del ap[proj]

    new_layers = []
    for i, lp in enumerate(layers):
        nlp = dict(lp)
        nlp["attn"] = attn_new[i]
        if include_ffn:
            nlp["ffn"] = ffn_new[i]
        new_layers.append(nlp)
    if n_shards > 1 and stats_out is not None:
        stats_out["__sharding__"] = {"n_shards": n_shards,
                                     "per_shard": shard_stats}
    new = dict(params)
    new["layers"] = tuple(new_layers)
    return new, packs


# --------------------------------------------------------------------------
# the family dispatch consumed by repro.serving.prepare_servable
# --------------------------------------------------------------------------

def export_params(params, cfg: ModelConfig, tile=(128, 128), *,
                  fuse_qkv: bool = True, cross_layer_union: bool = True,
                  include_ffn: bool = True, use_plans: bool = True,
                  registry: Optional[PatternRegistry] = None,
                  backend_chooser=None, n_shards: int = 1,
                  pack_quant: str = "none"):
    """Export any model family's param tree to serving form.

    Returns ``(sparse_params, packs, stats)``. Dispatch mirrors
    ``models/api.py``:

      * ``bert``           -> :func:`export_bert_sparse` (cross-layer union
        applies to the unrolled encoder);
      * lm-like families (``dense``/``moe``/``ssm``/``hybrid``/``vlm``)
        -> :func:`export_lm_sparse` (scan-stacked groups are always
        union-packed; ``cross_layer_union`` is implicit);
      * ``audio``          -> no export (the enc-dec forward takes no
        ``packs``); the model serves dense and ``stats`` records the gap.

    ``n_shards`` (the mesh "model" axis size) selects tensor-parallel
    sharded export; see :func:`export_lm_sparse`.
    """
    if cfg.family == "bert":
        stats: Dict[str, Dict] = {}
        sparse_params, packs = export_bert_sparse(
            params, cfg, tile=tile, include_ffn=include_ffn,
            fuse_qkv=fuse_qkv, cross_layer_union=cross_layer_union,
            use_plans=use_plans, registry=registry, stats_out=stats,
            backend_chooser=backend_chooser, n_shards=n_shards,
            pack_quant=pack_quant)
        return sparse_params, packs, stats
    if cfg.family in LM_FAMILIES:
        return export_lm_sparse(params, cfg, tile=tile, fuse_qkv=fuse_qkv,
                                use_plans=use_plans, include_ffn=include_ffn,
                                registry=registry,
                                backend_chooser=backend_chooser,
                                n_shards=n_shards, pack_quant=pack_quant)
    if cfg.family == "audio":
        return params, {}, {"__unsupported__": {
            "family": cfg.family,
            "reason": "enc-dec forward has no packs route; serving dense"}}
    raise ValueError(f"unknown model family {cfg.family!r}")
