"""Family-agnostic BSR export passes (the TVM relay-conversion analogue).

Training keeps dense weights + block masks (core.pruner). Serving packs the
pruned projections into tile-granular BSR and -- by default -- lowers each
pattern to a precomputed :class:`~repro.kernels.exec_plan.RowPackPlan`: the
pattern arrays become static plan metadata (cached through
``core.pattern_reuse.PatternRegistry``) and the servable param tree stores
the tile values *already row-grouped*, so the per-call path is pure compute
(docs/PERF.md).

Three pattern-level optimizations happen here, offline:

  * **plans** (``use_plans=True``): weight data is re-laid-out once at export
    instead of on every forward call;
  * **fused QKV** (``fuse_qkv=True``): the wq/wk/wv patterns are concatenated
    along N into a single pack, so attention issues ONE block-sparse matmul
    (one gather of x, one dispatch) per layer instead of three;
  * **cross-layer union** (``export_bert_sparse(cross_layer_union=True)``):
    the per-layer patterns of all encoder layers are unioned so a single
    specialization serves every layer with per-layer data -- the paper's §2.2
    task-buffer mechanism, collapsing 12 compilations to 1. For scan-stacked
    LM layer groups the same union machinery has always applied
    (``pack_stacked``). High inter-layer pattern overlap -- which the paper's
    small-block regularization promotes -- keeps the union tight;
    ``union_overhead`` quantifies the waste.

These passes are consumed by the :func:`repro.serving.prepare_servable`
facade (docs/API.md), which dispatches on ``cfg.family`` via
:func:`export_params`; the per-family entry points remain available for
callers that need one pass in isolation.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.pattern_reuse import PatternRegistry
from repro.kernels.bsr_matmul import KernelBSR, pack_bsr
from repro.kernels.exec_plan import pack_plan_data, plan_for_pack

# projection names exported per mixer/ffn kind
_ATTN_PROJS = ("wq", "wk", "wv", "wo")
_QKV = ("wq", "wk", "wv")
_FFN_PROJS = ("wi", "wg", "wo")

# families whose param tree follows the lm.py prefix/blocks/suffix layout
LM_FAMILIES = ("dense", "moe", "ssm", "hybrid", "vlm")


def _tile_mask(w: np.ndarray, tile) -> np.ndarray:
    n, k = w.shape
    bn, bk = tile
    return np.any(w.reshape(n // bn, bn, k // bk, bk) != 0, axis=(1, 3))


def pack_stacked(w_stacked: np.ndarray, tile) -> Tuple[KernelBSR, jax.Array, Dict]:
    """(L, N, K) -> (pattern pack, per-layer data (L, nnzt, bn, bk), stats)."""
    l, n, k = w_stacked.shape
    bn, bk = tile
    masks = np.stack([_tile_mask(w_stacked[i], tile) for i in range(l)])
    union = masks.any(axis=0)
    # build the pattern from a dense "ones at union" stand-in
    proto = np.kron(union.astype(np.float32), np.ones(tile, np.float32))
    pack = pack_bsr(proto, tile)
    rows = pack.row_id[: pack.nnzt]
    cols = pack.col_id
    blocks = w_stacked.reshape(l, n // bn, bn, k // bk, bk).transpose(0, 1, 3, 2, 4)
    data = blocks[:, rows, cols]                      # (L, nnzt, bn, bk)
    per_layer_nnz = masks.sum(axis=(1, 2))
    stats = {
        "union_nnzt": int(union.sum()),
        "mean_layer_nnzt": float(per_layer_nnz.mean()),
        "union_overhead": float(union.sum() / max(per_layer_nnz.mean(), 1.0)),
    }
    return pack, jnp.asarray(data), stats


def pack_single(w: np.ndarray, tile) -> Tuple[KernelBSR, jax.Array]:
    pack = pack_bsr(w, tile)
    return pack, pack.data


# --------------------------------------------------------------------------
# serving-form helpers (KernelBSR pattern -> plan + row-grouped values)
# --------------------------------------------------------------------------

def _serving_pack(w: np.ndarray, tile, use_plans: bool,
                  registry: Optional[PatternRegistry]):
    """(N, K) weight -> (static pattern, values). With plans, the values are
    row-grouped once here -- the scatter the seed backend paid per call."""
    pack = pack_bsr(w, tile)
    if not use_plans:
        return pack, pack.data
    plan = plan_for_pack(pack, registry)
    return plan, pack_plan_data(plan, pack.data)


def _serving_pack_stacked(w_stacked: np.ndarray, tile, use_plans: bool,
                          registry: Optional[PatternRegistry]):
    pack, data, stats = pack_stacked(w_stacked, tile)
    if not use_plans:
        return pack, data, stats
    plan = plan_for_pack(pack, registry)
    return plan, pack_plan_data(plan, data), stats


def _get_w(p) -> np.ndarray:
    return np.asarray(jax.device_get(p["w"]), np.float32)


def _divisible(shape, tile) -> bool:
    return shape[-2] % tile[0] == 0 and shape[-1] % tile[1] == 0


def _fused_qkv_weight(ap, tile, stacked: bool) -> Optional[np.ndarray]:
    """Concatenate wq/wk/wv along N (one pack, one dispatch); None when a
    projection is missing or a segment boundary would not land on a block
    row (each segment's N must divide the kernel tile's bn)."""
    if not all(proj in ap for proj in _QKV):
        return None
    ws = [_get_w(ap[proj]) for proj in _QKV]
    if not all(_divisible(w.shape, tile) for w in ws):
        return None
    return np.concatenate(ws, axis=1 if stacked else 0)


# --------------------------------------------------------------------------
# per-family export passes
# --------------------------------------------------------------------------

def export_lm_sparse(params, cfg: ModelConfig, tile=(128, 128), *,
                     fuse_qkv: bool = True, use_plans: bool = True,
                     registry: Optional[PatternRegistry] = None):
    """Replace attention projections of an LM param tree with packed values.

    Returns (sparse_params, packs, stats): ``packs`` maps layer scopes
    ('blocks/<i>/<proj>', 'prefix/<i>/<proj>', ...) to static patterns
    (RowPackPlan by default, KernelBSR with ``use_plans=False``); forward()
    consumes them via the ``packs=`` argument. Scan-stacked layer groups are
    union-packed (one specialization, per-layer data); with ``fuse_qkv`` the
    q/k/v projections additionally share one fused pack per layer group.
    """
    packs: Dict[str, object] = {}
    stats: Dict[str, Dict] = {}
    new = jax.tree_util.tree_map(lambda x: x, params)  # shallow copy-ish

    def export_attn(layer_params, scope, stacked):
        if "attn" not in layer_params:
            return layer_params
        ap = dict(layer_params["attn"])
        projs = list(_ATTN_PROJS)
        if fuse_qkv:
            w_qkv = _fused_qkv_weight(ap, tile, stacked)
            if w_qkv is not None:
                dtype = ap["wq"]["w"].dtype
                if stacked:
                    pk, data, st = _serving_pack_stacked(
                        w_qkv, tile, use_plans, registry)
                else:
                    pk, data = _serving_pack(w_qkv, tile, use_plans, registry)
                    st = {"union_nnzt": pk.real_nnzt if use_plans else pk.nnzt}
                packs[f"{scope}/wqkv"] = pk
                stats[f"{scope}/wqkv"] = st
                ap["wqkv"] = {"w": data.astype(dtype)}
                for proj in _QKV:
                    del ap[proj]
                projs = ["wo"]
        for proj in projs:
            if proj not in ap:
                continue
            w = _get_w(ap[proj])
            if not _divisible(w.shape, tile):
                continue
            if stacked:
                pk, data, st = _serving_pack_stacked(w, tile, use_plans,
                                                     registry)
            else:
                pk, data = _serving_pack(w, tile, use_plans, registry)
                st = {"union_nnzt": pk.real_nnzt if use_plans else pk.nnzt}
            packs[f"{scope}/{proj}"] = pk
            stats[f"{scope}/{proj}"] = st
            ap[proj] = {"w": data.astype(layer_params["attn"][proj]["w"].dtype)}
        out = dict(layer_params)
        out["attn"] = ap
        return out

    new["prefix"] = tuple(export_attn(lp, f"prefix/{i}/attn", False)
                          for i, lp in enumerate(params["prefix"]))
    new["blocks"] = tuple(export_attn(lp, f"blocks/{i}/attn", True)
                          for i, lp in enumerate(params["blocks"]))
    new["suffix"] = tuple(export_attn(lp, f"suffix/{i}/attn", False)
                          for i, lp in enumerate(params["suffix"]))
    return new, packs, stats


def export_bert_sparse(params, cfg: ModelConfig, tile=(64, 64),
                       include_ffn=True, *, fuse_qkv: bool = True,
                       cross_layer_union: bool = False,
                       use_plans: bool = True,
                       registry: Optional[PatternRegistry] = None,
                       stats_out: Optional[Dict] = None):
    """BSR export for the (unrolled) BERT encoder.

    Default: one pattern per layer and projection group (fused QKV). With
    ``cross_layer_union=True`` each projection group is union-packed ACROSS
    the encoder layers, so all L layers share one specialization driven by
    per-layer data -- the 12->1 compilation collapse of the paper's task
    buffer; pass a ``registry`` to read the hit/miss instrumentation
    (L-1 hits per group when the union is active).

    ``stats_out``, if given, is filled with the per-group union stats
    (``union_nnzt`` / ``mean_layer_nnzt`` / ``union_overhead``, keyed by
    '<group>/<name>') -- the union-waste instrumentation the paper proposes
    as follow-up. (Kept out of the return value for caller compatibility.)
    """
    layers = params["layers"]
    n_layers = len(layers)
    packs: Dict[str, object] = {}
    attn_new = [dict(lp["attn"]) for lp in layers]
    ffn_new = [dict(lp["ffn"]) for lp in layers]

    # (group, exported name, per-layer weight extractor, source param name)
    specs = []
    fused_ws = [_fused_qkv_weight(lp["attn"], tile, False) for lp in layers] \
        if fuse_qkv else []
    fuse_now = fuse_qkv and all(w is not None for w in fused_ws)
    if fuse_now:
        by_id = {id(lp): w for lp, w in zip(layers, fused_ws)}
        specs.append(("attn", "wqkv", lambda lp: by_id[id(lp)], "wq"))
    else:
        specs += [("attn", proj, (lambda lp, _p=proj: _get_w(lp["attn"][_p])),
                   proj) for proj in _QKV]
    specs.append(("attn", "wo", lambda lp: _get_w(lp["attn"]["wo"]), "wo"))
    if include_ffn:
        specs += [("ffn", proj, (lambda lp, _p=proj: _get_w(lp["ffn"][_p])),
                   proj) for proj in ("wi", "wo")]

    for group, name, getw, src in specs:
        tgt = attn_new if group == "attn" else ffn_new
        dtypes = [lp[group][src]["w"].dtype for lp in layers]
        if cross_layer_union:
            stacked = np.stack([getw(lp) for lp in layers])
            pack, data, union_st = pack_stacked(stacked, tile)
            if stats_out is not None:
                stats_out[f"{group}/{name}"] = union_st
            if use_plans:
                # one lookup per layer: the registry's hit counter then shows
                # the (L-1)-fold reuse of the single unioned specialization
                shared = [plan_for_pack(pack, registry)
                          for _ in range(n_layers)]
                vals = pack_plan_data(shared[0], data)
            else:
                shared = [pack] * n_layers
                vals = data
            for i in range(n_layers):
                packs[f"layers/{i}/{group}/{name}"] = shared[i]
                tgt[i][name] = {"w": vals[i].astype(dtypes[i])}
        else:
            for i, lp in enumerate(layers):
                pk, vals = _serving_pack(getw(lp), tile, use_plans, registry)
                packs[f"layers/{i}/{group}/{name}"] = pk
                tgt[i][name] = {"w": vals.astype(dtypes[i])}

    if fuse_now:
        for ap in attn_new:
            for proj in _QKV:
                del ap[proj]

    new_layers = []
    for i, lp in enumerate(layers):
        nlp = dict(lp)
        nlp["attn"] = attn_new[i]
        if include_ffn:
            nlp["ffn"] = ffn_new[i]
        new_layers.append(nlp)
    new = dict(params)
    new["layers"] = tuple(new_layers)
    return new, packs


# --------------------------------------------------------------------------
# the family dispatch consumed by repro.serving.prepare_servable
# --------------------------------------------------------------------------

def export_params(params, cfg: ModelConfig, tile=(128, 128), *,
                  fuse_qkv: bool = True, cross_layer_union: bool = True,
                  include_ffn: bool = True, use_plans: bool = True,
                  registry: Optional[PatternRegistry] = None):
    """Export any model family's param tree to serving form.

    Returns ``(sparse_params, packs, stats)``. Dispatch mirrors
    ``models/api.py``:

      * ``bert``           -> :func:`export_bert_sparse` (cross-layer union
        applies to the unrolled encoder);
      * lm-like families (``dense``/``moe``/``ssm``/``hybrid``/``vlm``)
        -> :func:`export_lm_sparse` (scan-stacked groups are always
        union-packed; ``cross_layer_union`` is implicit);
      * ``audio``          -> no export (the enc-dec forward takes no
        ``packs``); the model serves dense and ``stats`` records the gap.
    """
    if cfg.family == "bert":
        stats: Dict[str, Dict] = {}
        sparse_params, packs = export_bert_sparse(
            params, cfg, tile=tile, include_ffn=include_ffn,
            fuse_qkv=fuse_qkv, cross_layer_union=cross_layer_union,
            use_plans=use_plans, registry=registry, stats_out=stats)
        return sparse_params, packs, stats
    if cfg.family in LM_FAMILIES:
        return export_lm_sparse(params, cfg, tile=tile, fuse_qkv=fuse_qkv,
                                use_plans=use_plans, registry=registry)
    if cfg.family == "audio":
        return params, {}, {"__unsupported__": {
            "family": cfg.family,
            "reason": "enc-dec forward has no packs route; serving dense"}}
    raise ValueError(f"unknown model family {cfg.family!r}")
