"""prepare_servable / Servable / load_servable: the serving facade.

One spec-driven entry point owns the whole algorithm->compilation pipeline
the paper argues must be co-designed:

    prune (core.pruner recipe)            -- algorithm side
    -> BSR export (serving.export)        -- layout
    -> RowPackPlan construction           -- execution schedule
    -> PatternRegistry caching            -- cross-layer/task reuse

for every model family, dispatched through ``models/api.py``. The returned
:class:`Servable` is a self-contained handle: ``forward`` / ``decode_step``
serve through the packed weights, ``stats`` surfaces the co-design
instrumentation (density, union overhead, registry hits, padded-FLOP
ratio), and ``save`` / :func:`load_servable` persist the artifact through
``checkpoint/store.py`` so export cost is paid once per model, not once per
process.
"""
from __future__ import annotations

import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import CheckpointStore
from repro.configs.base import ModelConfig
from repro.models.sampling import sample_tokens
from repro.core.pattern_reuse import PatternRegistry
from repro.core.pruner import _path_name, oneshot_prune, tied_prune
from repro.kernels.exec_plan import QuantPlan, RowPackPlan, ShardedPlan
from repro.kernels.flash_decode import decode_kernel_override
from repro.models import api as model_api
from repro.serving.export import export_params
from repro.serving.serialize import (LeafReader, ServableLoadError,
                                     build_like, config_from_dict,
                                     config_to_dict, packs_from_arrays,
                                     packs_to_arrays, pattern_key, tree_spec)
from repro.serving.spec import ServingSpec

#: the single checkpoint slot a Servable occupies in its store directory
SERVABLE_STEP = 0
_PACKS_FILE = "packs.npz"


def _norm_path(name: str) -> str:
    """'layers/[0]/attn/wqkv/w' (tree-path rendering, core.pruner._path_name)
    -> 'layers/0/attn/wqkv/w' (the pack-key convention)."""
    return "/".join(tok.strip("[]") for tok in name.split("/"))


def _cast_packed(params, packs, jdtype):
    """Cast only the packed projection values to the spec dtype (embeddings,
    norms, heads keep the model dtype). Quantized packs are exempt: their
    int8/fp8 values and fp32 scales ARE the storage format -- casting either
    to the model dtype would silently dequantize or lose scale precision."""
    targets = {key + "/w" for key, pk in packs.items()
               if not isinstance(pk, QuantPlan)}

    def one(path, leaf):
        name = _norm_path(_path_name(path))
        return leaf.astype(jdtype) if name in targets else leaf
    return jax.tree_util.tree_map_with_path(one, params)


# --------------------------------------------------------------------------
# mesh placement (spec.mesh_shape: the tensor/data-parallel serving path)
# --------------------------------------------------------------------------

def make_serving_mesh(spec) -> "jax.sharding.Mesh":
    """Build the ``("data", "model")`` mesh a spec asks for, with an
    actionable error when the process doesn't expose enough devices
    (host-platform runs need ``XLA_FLAGS=--xla_force_host_platform_
    device_count=N`` set before jax initializes)."""
    from repro.launch.mesh import make_mesh
    need = spec.data_shards * spec.model_shards
    have = jax.device_count()
    if need > have:
        raise ValueError(
            f"spec.mesh_shape={tuple(spec.mesh_shape)} needs {need} devices "
            f"but only {have} are visible; on CPU set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={need} before importing"
            f" jax")
    return make_mesh(tuple(spec.mesh_shape), ("data", "model"))


def attach_mesh(packs, mesh):
    """Attach ``mesh`` to every ShardedPlan pack (static metadata consumed
    by the models/common.linear sharding hook), including ShardedPlans
    wrapped in a QuantPlan. Identical patterns keep sharing one underlying
    layout -- with_mesh is a shallow replace."""
    out, seen = {}, {}
    for key, pk in packs.items():
        inner = pk.plan if isinstance(pk, QuantPlan) else pk
        if isinstance(inner, ShardedPlan) and inner.mesh is not mesh:
            if id(pk) not in seen:
                seen[id(pk)] = pk.with_mesh(mesh)
            pk = seen[id(pk)]
        out[key] = pk
    return out


def serving_param_shardings(params, packs, mesh):
    """NamedSharding tree for a serving param tree:

      * ShardedPlan-packed values ``(..., V, P, bn, bk)`` shard their vrow
        axis over "model" -- shard ``s`` of the plan lands on device column
        ``s``, per-device pack bytes drop ~n_shards-fold;
      * unsharded pack values replicate (their pattern did not divide);
      * every dense leaf follows ``launch/sharding.spec_for_param`` in
        inference mode (TP-only: no per-layer weight all-gathers).
    """
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.sharding import spec_for_param
    packed = {}
    for key, pk in packs.items():
        packed[key + "/w"] = pk
        if isinstance(pk, QuantPlan):
            packed[key + "/scale"] = pk

    def one(path, leaf):
        name = _norm_path(_path_name(path))
        pk = packed.get(name)
        inner = pk.plan if isinstance(pk, QuantPlan) else pk
        if isinstance(inner, ShardedPlan):
            spec = [None] * leaf.ndim
            # qvalues (..., V, P, bn, bk) and scales (..., V, P|1) both
            # shard their vrow axis over "model"
            vrow_axis = leaf.ndim - (2 if name.endswith("/scale") else 4)
            spec[vrow_axis] = "model"
            return NamedSharding(mesh, P(*spec))
        if pk is not None:                      # packed but not shardable
            return NamedSharding(mesh, P())
        return NamedSharding(
            mesh, spec_for_param(name, leaf.shape, mesh, mode="inference"))
    return jax.tree_util.tree_map_with_path(one, params)


class Servable:
    """Handle over (packed params, static patterns, config, spec).

    Not constructed directly -- use :func:`prepare_servable` or
    :func:`load_servable`.
    """

    def __init__(self, params, cfg: ModelConfig, spec: ServingSpec,
                 packs: Dict[str, object], registry: PatternRegistry,
                 export_stats: Optional[Dict] = None,
                 stats_at_save: Optional[Dict] = None, mesh=None):
        self.params = params
        self.cfg = cfg
        self.spec = spec
        self.packs = packs
        self.registry = registry
        self.mesh = mesh                 # jax.sharding.Mesh | None
        self.export_stats = export_stats or {}
        self.stats_at_save = stats_at_save
        self._fwd_fn = None
        self._decode_kind = None
        self._decode_fn = None
        self._decode_many_fn = None
        self._engine_decode = None
        self._engine_decode_many = None
        self._engine_prefill = None
        self._engine_write = None
        self._engine_free = None
        self._engine_paged = None
        self._engine_suffix = None
        self._mesh_paged_fns: Dict[Any, tuple] = {}
        self._mesh_suffix_fns: Dict[Any, Any] = {}
        # mesh engines: (decode, decode_many, write, free) jits cached per
        # cache-sharding tree, so engines over the same placement share
        # executables exactly like the unsharded path
        self._mesh_engine_fns: Dict[Any, tuple] = {}

    # -- serving ----------------------------------------------------------
    def _as_batch(self, batch) -> Dict[str, Any]:
        if isinstance(batch, dict):
            return batch
        return {"tokens": jnp.asarray(batch)}

    def forward(self, batch):
        """batch dict (models/api.py schema) or raw tokens -> logits f32.

        The callable is jit'd once per Servable with the packs held static;
        identical patterns across layers share one specialization (plans
        hash by pattern fingerprint)."""
        if self._fwd_fn is None:
            cfg, packs = self.cfg, self.packs
            self._fwd_fn = jax.jit(
                lambda p, b: model_api.model_forward(p, cfg, b, packs=packs))
        logits, _aux = self._fwd_fn(self.params, self._as_batch(batch))
        return logits

    def init_cache(self, batch_size: int, cache_len: int, frames=None,
                   paged=None):
        cache = model_api.init_cache(self.params, self.cfg, batch_size,
                                     cache_len, frames=frames, paged=paged)
        if self.mesh is not None:
            # slots over "data", heads/state over "model"; lifecycle ops
            # stay sharding-preserving device scatters from here on
            cache = model_api.shard_cache(cache, self.cfg, self.mesh)
        return cache

    def decode_kernel_kind(self) -> str:
        """Resolve the attention decode kernel every jitted decode closure
        of this servable pins at trace time ('xla' | 'flash' | 'auto'):
        the ``REPRO_DECODE_KERNEL`` env var wins when set to a non-'auto'
        value, then a non-'auto' ``spec.decode_kernel``, then -- for
        'auto' -- :func:`repro.kernels.autotune.choose_decode_kernel` over
        this config's decode shape (so the choice is measured/stubbed per
        device and persisted like every other autotune winner)."""
        if self._decode_kind is None:
            env = os.environ.get("REPRO_DECODE_KERNEL", "").strip()
            if env and env != "auto":
                self._decode_kind = env
            elif self.spec.decode_kernel != "auto":
                self._decode_kind = self.spec.decode_kernel
            else:
                cfg = self.cfg
                if not getattr(cfg, "n_kv_heads", 0):
                    # attention-free families (pure SSM) never reach the
                    # decode-attention kernel -- nothing to tune
                    self._decode_kind = "xla"
                else:
                    from repro.kernels.autotune import choose_decode_kernel
                    self._decode_kind = choose_decode_kernel(
                        b=8, t=512, hq=cfg.n_heads, hkv=cfg.n_kv_heads,
                        d=cfg.head_dim).backend
        return self._decode_kind

    def decode_step(self, cache, token, pos):
        """(cache, token (B,1), pos) -> (logits, new_cache); encoder-only
        families raise (models/api.py contract). ``pos`` is a scalar or a
        ragged int32 (B,) vector of per-slot positions (-1 = inactive row,
        cache untouched) -- the continuous-batching calling convention."""
        if self._decode_fn is None:
            cfg, packs = self.cfg, self.packs
            kind = self.decode_kernel_kind()

            def step(p, c, t, s):
                with decode_kernel_override(kind):
                    return model_api.decode_step(p, c, cfg, t, s,
                                                 packs=packs)
            self._decode_fn = jax.jit(step)
        return self._decode_fn(self.params, cache, token, pos)

    def decode_many(self, cache, token, pos, n_steps, *, remaining=None,
                    eos_id=None, key=None, temperature: float = 0.0,
                    top_k: int = 0):
        """Fused K-step decode (``models.api.decode_many``): K decode steps,
        sampling and per-slot EOS/stop masking inside ONE jitted
        ``lax.scan`` -- one host round-trip per window instead of per
        token. Returns ``(tokens (K, B), valid (K, B), state)``; this is
        the non-donating API (the engine hot loop uses the donated
        executable, ``_engine_decode_many_fn``). Retraces per distinct
        ``(K, temperature, top_k)``."""
        if self._decode_many_fn is None:
            cfg, packs = self.cfg, self.packs
            kind = self.decode_kernel_kind()

            def fused(p, c, t, s, rem, eos, k, n, temp, tk):
                with decode_kernel_override(kind):
                    return model_api.decode_many(
                        p, c, cfg, t, s, n, packs=packs, remaining=rem,
                        eos_id=eos, key=k, temperature=temp, top_k=tk)

            self._decode_many_fn = jax.jit(fused, static_argnums=(7, 8, 9))
        b = jnp.shape(token)[0]
        if remaining is None:
            remaining = jnp.full((b,), jnp.iinfo(jnp.int32).max // 2,
                                 jnp.int32)
        if eos_id is None:
            eos_id = jnp.full((b,), -1, jnp.int32)
        else:
            eos_id = jnp.broadcast_to(jnp.asarray(eos_id, jnp.int32), (b,))
        if key is None:
            key = jax.random.PRNGKey(0)
        return self._decode_many_fn(self.params, cache, token, pos,
                                    jnp.asarray(remaining, jnp.int32),
                                    eos_id, key, int(n_steps),
                                    float(temperature), int(top_k))

    def engine(self, max_slots: int = 8, cache_len: int = 256, **kw):
        """Construct a continuous-batching :class:`~repro.serving.engine.
        ServingEngine` over this servable: request slots, admission queue,
        bucketed prefill, one batched decode per step (docs/API.md)."""
        from repro.serving.engine import ServingEngine
        return ServingEngine(self, max_slots=max_slots, cache_len=cache_len,
                             **kw)

    def _engine_decode_fn(self, cache_shardings=None):
        """Jitted batched decode shared by every engine of this servable
        (jit retraces per (max_slots, cache) shape and per static
        (temperature, top_k); executables persist across engine
        instances). Returns ``(sampled_tokens (B,), ok (B,) bool, logits,
        cache)`` -- sampling (greedy argmax, or temperature/top-k with the
        slot+position-keyed PRNG of models/sampling.py) runs on device so
        the hot loop only moves B int32s + B bools to host; ``ok`` is the
        per-slot non-finite guard (False = that slot's logits row went
        NaN/inf and the engine must quarantine it), and the full logits
        land on host only when an engine collects them. The cache argument
        is DONATED -- engine hot-loop use only; :meth:`decode_step` is the
        non-donating API.

        ``cache_shardings`` (mesh engines) pins the output cache to the
        engine cache's placement, so the donated buffers stay reusable
        step over step instead of XLA re-deciding (and copying) per
        leaf; cached per sharding tree by :meth:`engine_fns`."""
        if self._engine_decode is None or cache_shardings is not None:
            cfg, packs = self.cfg, self.packs
            kind = self.decode_kernel_kind()

            def decode(p, c, t, s, key, temperature, top_k):
                with decode_kernel_override(kind):
                    logits, c = model_api.decode_step(p, c, cfg, t, s,
                                                      packs=packs)
                rows = logits[:, 0, :]
                ok = jnp.isfinite(rows).all(axis=-1)
                nxt = sample_tokens(rows, key, s,
                                    temperature=temperature, top_k=top_k)
                return nxt, ok, logits, c

            kw = {} if cache_shardings is None else \
                {"out_shardings": (None, None, None, cache_shardings)}
            fn = jax.jit(decode, donate_argnums=(1,),
                         static_argnums=(5, 6), **kw)
            if cache_shardings is not None:
                return fn
            self._engine_decode = fn
        return self._engine_decode

    def _engine_decode_many_fn(self, cache_shardings=None):
        """Jitted fused K-step decode for the engine hot loop: K decode
        steps + sampling + per-slot EOS/budget masking inside one
        ``lax.scan`` (``models.api.decode_many``), cache DONATED. One
        executable per static (K, temperature, top_k) -- the engine bounds
        K by ``sync_every``, so the trace count stays small and every
        window after the first reuses a warm executable.
        ``cache_shardings`` as in :meth:`_engine_decode_fn`."""
        if self._engine_decode_many is None or cache_shardings is not None:
            cfg, packs = self.cfg, self.packs
            kind = self.decode_kernel_kind()

            def fused(p, c, t, s, rem, eos, key, n_steps, temperature,
                      top_k):
                with decode_kernel_override(kind):
                    return model_api.decode_many(
                        p, c, cfg, t, s, n_steps, packs=packs,
                        remaining=rem, eos_id=eos, key=key,
                        temperature=temperature, top_k=top_k)

            kw = {}
            if cache_shardings is not None:
                kw["out_shardings"] = (
                    None, None, {"token": None, "pos": None,
                                 "remaining": None, "failed": None,
                                 "cache": cache_shardings})
            fn = jax.jit(fused, donate_argnums=(1,),
                         static_argnums=(7, 8, 9), **kw)
            if cache_shardings is not None:
                return fn
            self._engine_decode_many = fn
        return self._engine_decode_many

    def _engine_prefill_fn(self):
        """Jitted prompt prefill shared by every engine of this servable.
        Uniform signature ``(params, cache1, tokens (bucket,), pos_seq
        (bucket,), length) -> (cache1, logits (bucket, V))``; one trace per
        bucket length serves every admission (``length`` is traced).

        lm-family models run the ONE-PASS forward prefill
        (``models.api.prefill_cache``): the whole prompt streams the weights
        once, instead of once per token. Audio (enc-dec) scans the
        single-token decode path -- its decoder prompts are BOS-sized, and
        padding steps carry pos = -1 so they write nothing."""
        if self._engine_prefill is None:
            cfg, packs = self.cfg, self.packs

            if cfg.family == "audio":
                def prefill(params, cache, tokens, pos_seq, length):
                    def step(c, tp):
                        tok, p = tp
                        logits, c = model_api.decode_step(
                            params, c, cfg, tok[None, None], p[None])
                        return c, logits[0, 0]
                    return jax.lax.scan(step, cache, (tokens, pos_seq))
            else:
                def prefill(params, cache, tokens, pos_seq, length):
                    logits, cache = model_api.prefill_cache(
                        params, cache, cfg, tokens[None], length, packs=packs)
                    return cache, logits[0]

            self._engine_prefill = jax.jit(prefill)
        return self._engine_prefill

    def engine_fns(self, cache_shardings=None):
        """The engine's four cache-carrying jits ``(decode, decode_many,
        write_slot, free_slot)``. Unsharded engines share the
        Servable-cached executables; mesh engines share them per
        cache-sharding tree (NamedSharding is hashable), so constructing
        a second engine over the same placement retraces nothing."""
        if cache_shardings is None:
            return (self._engine_decode_fn(), self._engine_decode_many_fn(),
                    *self._engine_slot_fns())
        leaves, treedef = jax.tree_util.tree_flatten(cache_shardings)
        key = (treedef, tuple(leaves))
        if key not in self._mesh_engine_fns:
            self._mesh_engine_fns[key] = (
                self._engine_decode_fn(cache_shardings),
                self._engine_decode_many_fn(cache_shardings),
                *self._engine_slot_fns(cache_shardings))
        return self._mesh_engine_fns[key]

    def _engine_slot_fns(self, out_shardings=None):
        """Jitted ``(write_slot, free_slot)`` with the batched cache DONATED:
        slot insertion and retirement become in-place scatters instead of
        whole-cache copies (the slot index is traced, so one executable per
        cache shape serves every slot).

        ``out_shardings`` (a NamedSharding tree matching the cache, mesh
        engines only) pins the outputs to the engine cache's placement so
        lifecycle ops never regather it; sharded pairs are cached per
        sharding tree by :meth:`engine_fns`, the unsharded pair directly
        on the Servable."""
        cfg = self.cfg
        kw = {} if out_shardings is None else \
            {"out_shardings": out_shardings}

        def build():
            return (jax.jit(
                        lambda c, i, sub: model_api.write_slot(c, cfg, i,
                                                               sub),
                        donate_argnums=(0,), **kw),
                    jax.jit(lambda c, i: model_api.free_slot(c, cfg, i),
                            donate_argnums=(0,), **kw))
        if out_shardings is not None:
            return build()
        if self._engine_write is None:
            self._engine_write, self._engine_free = build()
        return self._engine_write, self._engine_free

    def suffix_prefill_fn(self, cache_shardings=None):
        """Jitted suffix/chunk prefill over the BATCHED engine cache:
        ``suffix_prefill(params, cache, tokens (S,), slot, start, length)
        -> (cache, logits (S, V))``. One trace per bucketed chunk length S
        serves every chunk (``slot``/``start``/``length`` are traced). The
        cache is DONATED, like the decode and write-slot jits: at serving
        scale the batched cache is tens of MB and an un-donated copy per
        chunk (~35 ms observed at 8x512 slots) would dwarf the chunk's own
        compute. Fault containment is unchanged -- the chaos site
        ``engine.prefill_chunk`` fires BEFORE dispatch, where the buffer
        has not yet been consumed (tests/test_chaos.py).

        Shared by the paged shared-prefix path (PR 7,
        :meth:`paged_engine_fns`) and the dense-KV chunked-prefill
        scheduler (docs/API.md §SLO scheduling) -- the model-layer entry
        point is the same ``models.api.prefill_suffix`` either way.
        Cached like the other engine jits: once on the Servable when
        unsharded, per cache-sharding tree for mesh engines."""
        cfg, packs = self.cfg, self.packs

        def build():
            def suffix(params, cache, tokens, slot, start, length):
                logits, cache = model_api.prefill_suffix(
                    params, cache, cfg, tokens[None], slot, start, length,
                    packs=packs)
                return cache, logits[0]
            skw = {} if cache_shardings is None else \
                {"out_shardings": (cache_shardings, None)}
            return jax.jit(suffix, donate_argnums=(1,), **skw)

        if cache_shardings is None:
            if self._engine_suffix is None:
                self._engine_suffix = build()
            return self._engine_suffix
        leaves, treedef = jax.tree_util.tree_flatten(cache_shardings)
        key = (treedef, tuple(leaves))
        if key not in self._mesh_suffix_fns:
            self._mesh_suffix_fns[key] = build()
        return self._mesh_suffix_fns[key]

    def paged_engine_fns(self, cache_shardings=None):
        """The paged engine's three extra cache-carrying jits
        ``(write_paged, restore_paged, suffix_prefill)``:

        - ``write_paged(cache, slot, sub, page_row)`` -- scatter a dense
          batch-1 prefill result into the slot's pages (cache DONATED, the
          paged analogue of ``write_slot``);
        - ``restore_paged(cache, slot, page_row, resume_len)`` -- re-attach
          retained pages after preemption, NOT donated: restore runs inside
          admission's failure envelope, and a donated cache would be
          invalidated even when the op is abandoned;
        - ``suffix_prefill(params, cache, tokens (S,), slot, start,
          length)`` -- prefill only the uncached prompt suffix against a
          shared resident prefix; :meth:`suffix_prefill_fn`, shared with
          the dense chunked-prefill path. Returns ``(cache, logits
          (S, V))``.

        Cached like :meth:`engine_fns`: unsharded engines share the
        Servable-held trio, mesh engines share per cache-sharding tree."""
        cfg, packs = self.cfg, self.packs
        kw = {} if cache_shardings is None else \
            {"out_shardings": cache_shardings}

        def build():
            write = jax.jit(
                lambda c, i, sub, row: model_api.write_slot_paged(
                    c, cfg, i, sub, row),
                donate_argnums=(0,), **kw)
            restore = jax.jit(
                lambda c, i, row, n: model_api.restore_slot_paged(
                    c, cfg, i, row, n), **kw)
            return write, restore, self.suffix_prefill_fn(cache_shardings)

        if cache_shardings is None:
            if self._engine_paged is None:
                self._engine_paged = build()
            return self._engine_paged
        leaves, treedef = jax.tree_util.tree_flatten(cache_shardings)
        key = (treedef, tuple(leaves))
        if key not in self._mesh_paged_fns:
            self._mesh_paged_fns[key] = build()
        return self._mesh_paged_fns[key]

    # -- instrumentation --------------------------------------------------
    def stats(self) -> Dict[str, Any]:
        """The co-design scorecard: how sparse, how shared, how padded."""
        plans = [p.plan if isinstance(p, QuantPlan) else p
                 for p in self.packs.values()
                 if isinstance(p, (RowPackPlan, QuantPlan))]
        unique = {pattern_key(p) for p in self.packs.values()}
        union = [s["union_overhead"] for s in self.export_stats.values()
                 if isinstance(s, dict) and "union_overhead" in s]
        st = self.registry.stats
        out = {
            "family": self.cfg.family,
            "arch": self.cfg.arch,
            "backend": self.spec.backend,
            "tile": list(self.spec.tile),
            "packed_projections": len(self.packs),
            "unique_patterns": len(unique),
            "density": (float(np.mean([p.density
                                       for p in self.packs.values()]))
                        if self.packs else None),
            "union_overhead": float(np.mean(union)) if union else None,
            "padded_flop_ratio": (float(np.mean([p.padding_waste
                                                 for p in plans]))
                                  if plans else None),
            "registry": {"hits": st.hits, "misses": st.misses,
                         "reuse_rate": st.reuse_rate},
        }
        # autotune verdicts (backend='auto'): measured winner per layer
        # group + how often the on-disk winner cache answered
        auto = {k: s["autotune"] for k, s in self.export_stats.items()
                if isinstance(s, dict) and "autotune" in s}
        if auto:
            out["autotune"] = {
                "backends": {k: a["backend"] for k, a in auto.items()},
                "cache_hits": sum(1 for a in auto.values()
                                  if a.get("cache_hit")),
                "cache_misses": sum(1 for a in auto.values()
                                    if not a.get("cache_hit")),
                "mode": next(iter(auto.values())).get("mode"),
            }
        qs = self.quant_stats()
        if qs:
            out["quant"] = qs
        if self.mesh is not None or self.spec.mesh_shape is not None:
            out["sharding"] = self._sharding_stats()
        if self.stats_at_save is not None:
            out["registry_at_save"] = self.stats_at_save.get("registry")
        return out

    def pack_bytes(self) -> Tuple[int, int]:
        """(total, per-device) bytes of the packed projection values in the
        params tree. Per-device accounting follows each leaf's placement
        (``sharding.shard_shape``); unplaced trees count fully on one
        device. Quantized packs count both their qvalues AND their scale
        arrays -- the scales are real pack traffic. Shared by ``stats()``
        and benchmarks/serving_bench.py."""
        targets = set()
        for key, pk in self.packs.items():
            targets.add(key + "/w")
            if isinstance(pk, QuantPlan):
                targets.add(key + "/scale")
        total = per_dev = 0

        def visit(path, leaf):
            nonlocal total, per_dev
            if _norm_path(_path_name(path)) not in targets:
                return leaf
            nbytes = int(np.prod(leaf.shape)) * leaf.dtype.itemsize
            total += nbytes
            shard_shape = (leaf.sharding.shard_shape(leaf.shape)
                           if hasattr(leaf, "sharding") else leaf.shape)
            per_dev += int(np.prod(shard_shape)) * leaf.dtype.itemsize
            return leaf
        jax.tree_util.tree_map_with_path(visit, self.params)
        return total, per_dev

    def quant_stats(self) -> Optional[Dict[str, Any]]:
        """Quantized-pack accounting: bytes actually stored (qvalues +
        scales, total and per-device) vs the fp32-equivalent footprint of
        the same packs, plus the worst export-time round-trip error. None
        when nothing is quantized (the common case; engine ``stats_dict()``
        forwards this section only when it exists)."""
        qpacks = {k: p for k, p in self.packs.items()
                  if isinstance(p, QuantPlan)}
        if not qpacks:
            return None
        wkeys = {k + "/w" for k in qpacks}
        skeys = {k + "/scale" for k in qpacks}
        acc = {"w": 0, "w_dev": 0, "scale": 0, "scale_dev": 0,
               "fp32": 0, "fp32_dev": 0}

        def visit(path, leaf):
            name = _norm_path(_path_name(path))
            if name not in wkeys and name not in skeys:
                return leaf
            n = int(np.prod(leaf.shape))
            shard_shape = (leaf.sharding.shard_shape(leaf.shape)
                           if hasattr(leaf, "sharding") else leaf.shape)
            nd = int(np.prod(shard_shape))
            if name in wkeys:
                acc["w"] += n * leaf.dtype.itemsize
                acc["w_dev"] += nd * leaf.dtype.itemsize
                acc["fp32"] += n * 4          # the same values stored fp32
                acc["fp32_dev"] += nd * 4
            else:
                acc["scale"] += n * leaf.dtype.itemsize
                acc["scale_dev"] += nd * leaf.dtype.itemsize
            return leaf
        jax.tree_util.tree_map_with_path(visit, self.params)
        qbytes = acc["w"] + acc["scale"]
        qdev = acc["w_dev"] + acc["scale_dev"]
        errs = [s["quant"] for s in self.export_stats.values()
                if isinstance(s, dict) and "quant" in s]
        out = {
            "pack_quant": self.spec.pack_quant,
            "quantized_packs": len(qpacks),
            "total_packs": len(self.packs),
            "qdtype": next(iter(qpacks.values())).qdtype,
            "granularities": sorted({p.granularity
                                     for p in qpacks.values()}),
            "quant_bytes_total": qbytes,
            "quant_bytes_per_device": qdev,
            "scale_bytes_total": acc["scale"],
            "fp32_equiv_bytes_total": acc["fp32"],
            "fp32_equiv_bytes_per_device": acc["fp32_dev"],
            "compression_ratio": (acc["fp32"] / qbytes if qbytes else None),
        }
        if errs:
            out["max_abs_err"] = max(e["max_abs_err"] for e in errs)
            out["max_rel_err"] = max(e["rel_err"] for e in errs)
        return out

    def _sharding_stats(self) -> Dict[str, Any]:
        """Per-shard accounting of the mesh path: how the pack bytes split
        across devices, which packs actually sharded, and the per-shard
        registry hit/miss counts collected at export."""
        total, per_dev = self.pack_bytes()
        sharded = {k: (p.plan if isinstance(p, QuantPlan) else p)
                   for k, p in self.packs.items()
                   if isinstance(p.plan if isinstance(p, QuantPlan) else p,
                                 ShardedPlan)}
        shard_meta = self.export_stats.get("__sharding__") or {}
        out = {
            "mesh_shape": (list(self.spec.mesh_shape)
                           if self.spec.mesh_shape else None),
            "partition": self.spec.partition,
            "n_shards": self.spec.model_shards,
            "sharded_packs": len(sharded),
            "replicated_packs": len(self.packs) - len(sharded),
            "pack_bytes_total": total,
            "pack_bytes_per_device": per_dev,
            "per_shard_registry": {
                str(s): dict(v)
                for s, v in (shard_meta.get("per_shard") or {}).items()},
            "axes": {k: p.shard_axis for k, p in sharded.items()},
        }
        if sharded:
            uniq = {p.fingerprint for p in sharded.values()}
            out["unique_sharded_patterns"] = len(uniq)
        return out

    # -- persistence ------------------------------------------------------
    def save(self, path: str) -> str:
        """Persist params + static patterns + spec/config under ``path``
        (a CheckpointStore directory). Export never re-runs on load."""
        store = CheckpointStore(path, keep=1)
        arrays, pack_meta = packs_to_arrays(self.packs)
        meta = {
            "spec": self.spec.to_dict(),
            "cfg": config_to_dict(self.cfg),
            "tree": tree_spec(self.params),
            "packs": pack_meta,
            "export_stats": self.export_stats,
            "stats": self.stats(),
        }
        store.save(SERVABLE_STEP, self.params, blocking=True,
                   extra={"servable": meta})
        step_dir = os.path.join(path, f"step_{SERVABLE_STEP:09d}")
        np.savez(os.path.join(step_dir, _PACKS_FILE), **arrays)
        return path


def prepare_servable(params, cfg: ModelConfig, spec: ServingSpec = None, *,
                     registry: Optional[PatternRegistry] = None) -> Servable:
    """Run the full prune -> export -> plan -> cache pipeline for any family.

    ``params`` are dense training-form weights (already-pruned weights with
    ``spec.prune='none'``). The returned Servable's weights are in packed
    serving form; the original tree is not modified.
    """
    spec = spec or ServingSpec()
    registry = registry if registry is not None else PatternRegistry()
    mesh = make_serving_mesh(spec) if spec.mesh_shape is not None else None

    if spec.prune == "oneshot":
        pruned, _ = oneshot_prune(params, spec.sparsity_config())
    elif spec.prune == "tied":
        pruned, _ = tied_prune(params, spec.sparsity_config())
    else:
        pruned = params

    if spec.backend == "dense":     # negative control: no BSR support
        if mesh is not None:
            pruned = jax.device_put(
                pruned, serving_param_shardings(pruned, {}, mesh))
        return Servable(pruned, cfg, spec, {}, registry, export_stats={},
                        mesh=mesh)

    chooser = None
    if spec.backend == "plan_pallas":
        # pinned, not measured: every pack serves through the compiled
        # plan-consuming kernel (export wraps each plan in a PlanChoice);
        # the chooser protocol only needs backend/cache_hit/mode
        import types

        def chooser(pack, shard=None):
            return types.SimpleNamespace(backend="plan_pallas",
                                         cache_hit=False, mode="pinned")
    elif spec.backend == "auto":
        from repro.kernels.autotune import choose_backend

        def chooser(pack, shard=None):
            # sharded serving has exactly two layouts with a mesh story
            # (ShardedPlan and dense-via-GSPMD, plus the quantized plan
            # when pack_quant asks for it); the winner is still keyed per
            # (pattern, shard, device count, quant, value dtype) on disk
            if shard and shard[0] > 1:
                cands = ("dense", "plan")
                if spec.pack_quant != "none":
                    cands = cands + ("plan_q8",)
            else:
                cands = None    # choose_backend adds the q8 arms per quant
            return choose_backend(pack, m=spec.autotune_m,
                                  candidates=cands, shard=shard,
                                  quant=spec.pack_quant)

    sparse_params, packs, stats = export_params(
        pruned, cfg, tile=spec.tile, fuse_qkv=spec.fuse_qkv,
        cross_layer_union=spec.cross_layer_union,
        include_ffn=spec.include_ffn, use_plans=spec.use_plans,
        registry=registry, backend_chooser=chooser,
        n_shards=spec.model_shards, pack_quant=spec.pack_quant)
    if spec.dtype is not None and packs:
        jdtype = jnp.bfloat16 if spec.dtype == "bfloat16" else jnp.float32
        sparse_params = _cast_packed(sparse_params, packs, jdtype)
    if mesh is not None:
        packs = attach_mesh(packs, mesh)
        sparse_params = jax.device_put(
            sparse_params, serving_param_shardings(sparse_params, packs,
                                                   mesh))
    return Servable(sparse_params, cfg, spec, packs, registry,
                    export_stats=stats, mesh=mesh)


def load_servable(path: str, *, registry: Optional[PatternRegistry] = None,
                  chaos=None) -> Servable:
    """Restore a saved Servable: params via ``CheckpointStore.restore``,
    patterns via the fingerprint-keyed pack codec. No pruning, packing, or
    plan construction re-runs; the load-time registry only pays one build
    per unique pattern (the saved reuse counters stay readable under
    ``stats()['registry_at_save']``).

    A truncated / corrupt / incomplete artifact raises
    :class:`~repro.serving.serialize.ServableLoadError` naming the
    offending piece (the npz leaf when one is identifiable) instead of
    surfacing a zlib/zip/KeyError traceback from deep inside the codec.
    ``chaos`` (a ``repro.runtime.chaos.ChaosInjector``) fires the
    ``servable.load_packs`` site just before the archive is read."""
    store = CheckpointStore(path)
    try:
        meta = store.meta(SERVABLE_STEP)["servable"]
    except Exception as e:
        raise ServableLoadError(
            f"servable meta unreadable under {path} "
            f"({type(e).__name__}: {e})") from e
    cfg = config_from_dict(meta["cfg"])
    spec = ServingSpec.from_dict(meta["spec"])
    params = store.restore(build_like(meta["tree"]), step=SERVABLE_STEP)
    step_dir = os.path.join(path, f"step_{SERVABLE_STEP:09d}")
    registry = registry if registry is not None else PatternRegistry()
    packs_path = os.path.join(step_dir, _PACKS_FILE)
    if chaos is not None:
        from repro.runtime.chaos import SITE_LOAD_PACKS
        chaos.fire(SITE_LOAD_PACKS, path=packs_path)
    try:
        npz = np.load(packs_path)
    except Exception as e:
        raise ServableLoadError(
            f"pack archive {packs_path} unreadable "
            f"({type(e).__name__}: {e})") from e
    with npz:
        packs = packs_from_arrays(meta["packs"], LeafReader(npz, packs_path),
                                  registry)
    mesh = None
    if spec.mesh_shape is not None:
        # the artifact stores shard-partitioned packs; re-placement (and
        # the mesh the linear hook pins shardings to) is rebuilt per
        # process from the spec
        mesh = make_serving_mesh(spec)
        packs = attach_mesh(packs, mesh)
        params = jax.device_put(
            params, serving_param_shardings(params, packs, mesh))
    return Servable(params, cfg, spec, packs, registry,
                    export_stats=meta.get("export_stats"),
                    stats_at_save=meta.get("stats"), mesh=mesh)
