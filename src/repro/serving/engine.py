"""Continuous-batching serving engine: request slots over one batched decode.

The model layer's decode path takes a ragged ``pos: (B,)`` vector (one
absolute position per batch row, -1 = inactive; models/api.py), which turns
the batch dimension into *request slots*. This module adds the request-level
machinery on top:

  * an **admission queue** -- ``submit()`` enqueues requests; each ``step()``
    admits as many as there are free slots;
  * **prefill-into-cache** -- an admitted prompt runs ONE forward pass on a
    batch-1 cache (``models.api.prefill_cache``: the full prompt streams the
    weights once, with bulk KV/recurrent-state writes; audio scans the
    decode path instead, its prompts being BOS-sized). Prompt lengths are
    padded to power-of-two *buckets* so the per-bucket jit executables stay
    warm -- padding tokens leave no trace in the cache -- and the result is
    inserted into the engine cache with ``write_slot``;
  * **one jitted batched decode per step** over all ``max_slots`` rows --
    mixed-progress requests share the call via per-slot causal/window masks;
    the engine cache is donated to the step, so decode is copy-free;
  * **slot lifecycle** -- completion fires the request's callbacks and
    ``free_slot``-zeroes the slot (attention KV *and* SSM/RgLRU recurrent
    state), so a recycled slot cannot leak its previous request.

Construct via :meth:`repro.serving.Servable.engine`::

    engine = servable.engine(max_slots=16, cache_len=512)
    h = engine.submit([1, 2, 3], max_new_tokens=32,
                      on_token=lambda rid, tok: print(rid, tok))
    engine.run()                      # drain queue + active slots
    print(h.tokens)                   # greedy continuation

Known batching caveat: MoE layers route over the whole batch with a
capacity limit, so token drops can depend on which slots are co-resident --
for MoE configs the engine is still correct serving-wise but not bitwise
equal to sequential decode (all other families are; tests/test_engine.py).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api as model_api

__all__ = ["EngineRequest", "EngineStats", "ServingEngine"]


@dataclasses.dataclass
class EngineRequest:
    """One submitted request; doubles as the caller's result handle."""

    req_id: int
    prompt: np.ndarray                      # (L,) int32
    max_new_tokens: int
    eos_id: Optional[int] = None
    frames: Optional[np.ndarray] = None     # audio family: encoder input
    on_token: Optional[Callable[[int, int], None]] = None
    on_done: Optional[Callable[[int, List[int]], None]] = None

    # engine-owned state
    slot: int = -1
    pos: int = -1                           # next decode position
    tokens: List[int] = dataclasses.field(default_factory=list)
    step_logits: List[np.ndarray] = dataclasses.field(default_factory=list)
    done: bool = False

    @property
    def n_generated(self) -> int:
        return len(self.tokens)


@dataclasses.dataclass
class EngineStats:
    steps: int = 0                  # batched decode calls
    prefills: int = 0
    tokens_generated: int = 0
    occupancy_sum: int = 0          # sum over steps of active slots
    completed: int = 0
    bucket_hits: Dict[int, int] = dataclasses.field(
        default_factory=lambda: collections.defaultdict(int))

    @property
    def mean_occupancy(self) -> float:
        return self.occupancy_sum / self.steps if self.steps else 0.0

    def as_dict(self) -> Dict:
        return {"steps": self.steps, "prefills": self.prefills,
                "tokens_generated": self.tokens_generated,
                "completed": self.completed,
                "mean_occupancy": round(self.mean_occupancy, 3),
                "prefill_buckets": dict(self.bucket_hits)}


class ServingEngine:
    """Slot-addressable continuous-batching engine over a Servable.

    ``max_slots`` bounds request concurrency (the static batch of the one
    jitted decode executable); ``cache_len`` bounds prompt + generation
    length per slot (windowed/recurrent layers keep their own tighter
    state bounds).
    """

    def __init__(self, servable, max_slots: int = 8, cache_len: int = 256,
                 *, min_bucket: int = 8, collect_logits: bool = False):
        if servable.cfg.family == "bert":
            raise ValueError("encoder-only arch has no decode step")
        self.servable = servable
        self.cfg = servable.cfg
        self.max_slots = int(max_slots)
        self.cache_len = int(cache_len)
        # floor of 2: a length-1 "prefill" would hit the single-token decode
        # path (s == 1), which expects a pos argument
        self.min_bucket = max(2, int(min_bucket))
        self.collect_logits = collect_logits
        self.stats = EngineStats()

        self._sub_template = None
        if self.cfg.family == "audio":
            # structure-only cache: encode batch-1 zero frames and broadcast
            # the slot axis (axis 1; every leaf is layer-stacked) -- the real
            # cross K/V arrives per request via write_slot at admission
            one = model_api.init_cache(
                servable.params, self.cfg, 1, self.cache_len,
                frames=jnp.zeros((1, self.cfg.n_audio_ctx, self.cfg.d_model),
                                 self.cfg.jdtype))
            self.cache = jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(
                    x, x.shape[:1] + (self.max_slots,) + x.shape[2:]), one)
        else:
            self.cache = model_api.init_cache(servable.params, self.cfg,
                                              self.max_slots, self.cache_len)
            # single-request cache template reused by every prefill (the
            # prefill is functional; audio rebuilds per request from frames)
            self._sub_template = model_api.init_cache(
                servable.params, self.cfg, 1, self.cache_len)

        self._tokens = np.zeros((self.max_slots, 1), np.int32)
        self._pos = np.full((self.max_slots,), -1, np.int32)
        self._free: List[int] = list(range(self.max_slots))
        self._active: Dict[int, EngineRequest] = {}
        self._queue: "collections.deque[EngineRequest]" = collections.deque()
        self._requests: List[EngineRequest] = []
        self._next_id = 0

        # jitted functions are owned by the Servable and shared across its
        # engines: one decode executable per max_slots shape, one prefill
        # trace per bucket length, warm for the engine's whole lifetime (and
        # the next engine's). The decode cache argument is donated, so the
        # hot loop never copies the slot caches.
        self._decode = servable._engine_decode_fn()
        self._prefill = servable._engine_prefill_fn()
        self._write_slot, self._free_slot = servable._engine_slot_fns()

    # -- submission -------------------------------------------------------
    def submit(self, prompt: Sequence[int], max_new_tokens: int = 16, *,
               eos_id: Optional[int] = None, frames=None,
               on_token: Optional[Callable[[int, int], None]] = None,
               on_done: Optional[Callable[[int, List[int]], None]] = None
               ) -> EngineRequest:
        """Enqueue a request; returns its handle (``.tokens`` fills as the
        engine runs, ``.done`` flips on completion)."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError("empty prompt")
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1 (the prefill "
                             "already samples the first token)")
        if prompt.size + max_new_tokens > self.cache_len:
            raise ValueError(
                f"prompt ({prompt.size}) + max_new_tokens ({max_new_tokens}) "
                f"exceeds cache_len ({self.cache_len})")
        if self.cfg.family == "audio" and frames is None:
            raise ValueError("audio requests need encoder frames")
        req = EngineRequest(req_id=self._next_id, prompt=prompt,
                            max_new_tokens=int(max_new_tokens), eos_id=eos_id,
                            frames=frames, on_token=on_token, on_done=on_done)
        self._next_id += 1
        self._queue.append(req)
        self._requests.append(req)
        return req

    # -- prefill ----------------------------------------------------------
    def _bucket(self, length: int) -> int:
        b = max(self.min_bucket, 1 << (length - 1).bit_length())
        return min(b, self.cache_len)

    def _admit(self, req: EngineRequest) -> None:
        slot = self._free.pop(0)
        length = int(req.prompt.size)
        bucket = self._bucket(length)
        self.stats.prefills += 1
        self.stats.bucket_hits[bucket] += 1

        if self.cfg.family == "audio":
            sub = model_api.init_cache(
                self.servable.params, self.cfg, 1, self.cache_len,
                frames=jnp.asarray(req.frames)[None]
                if np.ndim(req.frames) == 2 else jnp.asarray(req.frames))
        else:
            sub = self._sub_template
        toks = np.zeros((bucket,), np.int32)
        toks[:length] = req.prompt
        pos_seq = np.full((bucket,), -1, np.int32)
        pos_seq[:length] = np.arange(length)
        sub, logits = self._prefill(self.servable.params, sub,
                                    jnp.asarray(toks), jnp.asarray(pos_seq),
                                    jnp.int32(length))
        self.cache = self._write_slot(self.cache, jnp.int32(slot), sub)

        req.slot, req.pos = slot, length
        self._active[slot] = req
        row = np.asarray(logits[length - 1])    # once per admission: fine
        self._emit(req, int(np.argmax(row)), row)

    # -- stepping ---------------------------------------------------------
    def _emit(self, req: EngineRequest, tok: int, logits_row=None) -> None:
        """Record one greedily sampled token and retire the request if it
        just completed. ``logits_row`` (V,) is only materialized on host
        when the engine collects logits."""
        req.tokens.append(tok)
        if self.collect_logits and logits_row is not None:
            req.step_logits.append(np.asarray(logits_row, np.float32))
        self.stats.tokens_generated += 1
        if req.on_token is not None:
            req.on_token(req.req_id, tok)
        if (req.n_generated >= req.max_new_tokens
                or (req.eos_id is not None and tok == req.eos_id)):
            self._finish(req)
        else:
            self._tokens[req.slot, 0] = tok
            self._pos[req.slot] = req.pos

    def _finish(self, req: EngineRequest) -> None:
        slot = req.slot
        req.done = True
        self.stats.completed += 1
        # zero attention KV and recurrent state: recycled slots start fresh
        self.cache = self._free_slot(self.cache, jnp.int32(slot))
        self._pos[slot] = -1
        self._tokens[slot, 0] = 0
        del self._active[slot]
        self._free.append(slot)
        self._free.sort()
        req.slot = -1
        if req.on_done is not None:
            req.on_done(req.req_id, list(req.tokens))

    def step(self) -> bool:
        """Admit what fits, then run ONE batched decode over all active
        slots. Returns True while there is (or may be) work left."""
        while self._free and self._queue:
            self._admit(self._queue.popleft())
        if not self._active:
            return bool(self._queue)

        self.stats.steps += 1
        self.stats.occupancy_sum += len(self._active)
        next_tok, logits, self.cache = self._decode(
            self.servable.params, self.cache, jnp.asarray(self._tokens),
            jnp.asarray(self._pos))
        toks = np.asarray(next_tok)             # (max_slots,) int32 only
        rows = np.asarray(logits[:, 0, :]) if self.collect_logits else None
        for slot in sorted(self._active):
            req = self._active[slot]
            req.pos += 1
            self._emit(req, int(toks[slot]),
                       rows[slot] if rows is not None else None)
        return bool(self._active or self._queue)

    def run(self, max_steps: Optional[int] = None) -> List[EngineRequest]:
        """Drain the queue and all active slots; returns completed requests
        in submission order."""
        steps = 0
        while self.step():
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break
        return [r for r in self._requests if r.done]

    # -- introspection ----------------------------------------------------
    @property
    def n_active(self) -> int:
        return len(self._active)

    @property
    def n_queued(self) -> int:
        return len(self._queue)
