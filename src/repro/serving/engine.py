"""Continuous-batching serving engine: request slots over one batched decode.

The model layer's decode path takes a ragged ``pos: (B,)`` vector (one
absolute position per batch row, -1 = inactive; models/api.py), which turns
the batch dimension into *request slots*. This module adds the request-level
machinery on top:

  * an **admission queue** -- ``submit()`` enqueues requests; each ``step()``
    admits as many as there are free slots (highest ``priority`` first, FIFO
    within a priority class). The queue is optionally bounded
    (``max_queue``) with a pluggable backpressure policy (``overflow`` =
    ``'reject'`` / ``'shed-oldest'`` / ``'block'``, spec.OVERFLOW_POLICIES);
  * **prefill-into-cache** -- an admitted prompt runs ONE forward pass on a
    batch-1 cache (``models.api.prefill_cache``; audio scans the decode
    path instead). Prompt lengths are padded to power-of-two *buckets* so
    the per-bucket jit executables stay warm, and the result is inserted
    into the engine cache with ``write_slot``;
  * **fused decode windows** -- with ``sync_every = K > 1`` each ``step()``
    runs up to K decode steps inside ONE jitted ``lax.scan``
    (``models.api.decode_many``); the host syncs once per window to drain
    emitted tokens, fire callbacks, recycle finished slots and admit
    queued requests. ``sync_every=1`` (or ``collect_logits=True``) keeps
    the one-decode-per-step loop;
  * **SLO-aware scheduling** (``sched=SchedSpec(...)``, docs/API.md §SLO
    scheduling) -- chunked prefill splits long prompts into
    ``max_chunk``-sized slices run through the masked suffix-prefill path
    between decode windows (a partially-prefilled request holds its slot
    as a pos -1 no-op row; chunked == one-shot bit-exact), a per-window
    ``token_budget`` with ``decode_priority`` reserve eliminates
    head-of-line blocking, and graceful overload degradation fast-fails
    un-meetable deadlines at admission and sheds the newest low-priority
    queued traffic once the estimated queue delay exceeds
    ``max_queue_delay_s`` (both from MEASURED prefill/decode rates);
  * **request lifecycle robustness** (docs/API.md §Engine robustness) --
    every submitted request ends in EXACTLY ONE terminal status (``done``
    / ``failed`` / ``cancelled`` / ``shed``), with a structured
    :class:`FailureReason` on the non-success paths:
      - **deadlines** (``submit(deadline_s=...)``) and **cancellation**
        (:meth:`ServingEngine.cancel`) are enforced at window-sync points,
        so the fused decode stays one jitted scan between checks;
      - **preemption**: under slot pressure a queued request of strictly
        higher priority evicts the lowest-priority in-flight request --
        the victim's slot is freed with the usual recycle hygiene and the
        victim requeued; on re-admission it resumes via ``prefill_cache``
        over prompt + already-generated tokens (greedy streams continue
        exactly; sampled streams may re-key if the slot changed);
      - **non-finite quarantine**: decode logits are finite-checked on
        device (per-step and inside the fused scan,
        ``models.api.decode_many``); a poisoned slot fails with a
        structured reason while co-resident slots finish bit-identically
        to an uninjected run;
      - **failure isolation**: admission errors fail only their request
        (slot restored -- the try/except hygiene paths); a decode-window
        error fails the active requests, rebuilds the (donated, possibly
        invalidated) cache, and leaves the engine serving;
      - an optional **watchdog** (``watchdog_timeout_s``) detects stuck
        windows/syncs from a background thread (detection-only: a hung
        XLA dispatch cannot be cancelled, but it can be seen);
      - **chaos hooks** (``chaos=repro.runtime.chaos.ChaosInjector()``)
        fire at alloc/prefill/window/sync so the fault paths above are
        testable deterministically (tests/test_chaos.py).
  * **slot lifecycle** -- any retirement (completion, failure, cancel,
    preemption) ``free_slot``-zeroes the slot (attention KV *and*
    SSM/RgLRU recurrent state), so a recycled slot cannot leak its
    previous request.

Construct via :meth:`repro.serving.Servable.engine`::

    engine = servable.engine(max_slots=16, cache_len=512, sync_every=8,
                             max_queue=64, overflow="reject")
    h = engine.submit([1, 2, 3], max_new_tokens=32, priority=1,
                      deadline_s=30.0)
    engine.run()                      # drain queue + active slots
    print(h.status, h.tokens)         # 'done' + greedy continuation

Sampling is configured per engine (``temperature`` / ``top_k`` / ``seed``);
the PRNG key is folded by (slot, position), so fused and per-step decoding
emit identical tokens for the same seed (models/sampling.py).

Known batching caveat: MoE layers route over the whole batch with a
capacity limit, so token drops can depend on which slots are co-resident --
for MoE configs the engine is still correct serving-wise but not bitwise
equal to sequential decode (all other families are; tests/test_engine.py).
"""
from __future__ import annotations

import collections
import dataclasses
import logging
import os
import time
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api as model_api
from repro.models.common import PagedLayout
from repro.models.sampling import sample_token_row
from repro.runtime import chaos as chaos_mod
from repro.serving.paging import PagePool, PagePoolExhausted, pages_needed
from repro.serving.prefix_cache import PrefixCache
from repro.serving.spec import KV_LAYOUTS, OVERFLOW_POLICIES, SchedSpec

__all__ = ["EngineRequest", "EngineStats", "FailureReason", "ServingEngine",
           "TERMINAL_STATES"]

log = logging.getLogger("repro.serving")

#: the exactly-once terminal accounting: every submit() ends in ONE of these
TERMINAL_STATES = frozenset({"done", "failed", "cancelled", "shed"})


@dataclasses.dataclass(frozen=True)
class FailureReason:
    """Structured reason attached to every non-success terminal request.

    ``code`` is one of the class constants below (the machine-readable
    taxonomy, stable across releases); ``message`` carries the
    human-readable detail (offending sizes, exception text, ...).
    """

    code: str
    message: str = ""

    REJECTED = "rejected"                # invalid at submission
    QUEUE_FULL = "queue_full"            # shed by backpressure policy
    OVERLOAD = "overload_shed"           # SLO shedding (SchedSpec knobs)
    DEADLINE = "deadline"                # deadline_s expired (sync point)
    CANCELLED = "cancelled"              # engine.cancel(handle)
    PREFILL_ERROR = "prefill_error"      # admission/prefill raised
    NONFINITE_LOGITS = "nonfinite_logits"  # NaN/inf quarantine
    ENGINE_ERROR = "engine_error"        # decode window raised
    KV_PAGES = "kv_pages_exhausted"      # page pool dry with no way to drain

    def __str__(self):
        return f"{self.code}: {self.message}" if self.message else self.code


@dataclasses.dataclass
class EngineRequest:
    """One submitted request; doubles as the caller's result handle."""

    req_id: int
    prompt: np.ndarray                      # (L,) int32
    max_new_tokens: int
    eos_id: Optional[int] = None
    frames: Optional[np.ndarray] = None     # audio family: encoder input
    on_token: Optional[Callable[[int, int], None]] = None
    on_done: Optional[Callable[[int, List[int]], None]] = None
    priority: int = 0                       # higher preempts lower
    deadline_at: Optional[float] = None     # absolute time.monotonic()

    # engine-owned state
    status: str = "queued"          # queued|active|done|failed|cancelled|shed
    failure: Optional[FailureReason] = None
    slot: int = -1
    pos: int = -1                           # next decode position
    tokens: List[int] = dataclasses.field(default_factory=list)
    step_logits: List[np.ndarray] = dataclasses.field(default_factory=list)
    done: bool = False                      # status == 'done' (back-compat)
    cancel_requested: bool = False
    n_preempted: int = 0
    admit_seq: int = -1                     # monotonic admission counter
    # chunked prefill (docs/API.md §SLO scheduling): prompt tokens already
    # resident in the slot vs the full prefill length; pos == target (or
    # target == 0) = the request is decodable
    prefill_pos: int = 0
    prefill_target: int = 0
    # SLO timestamps (time.monotonic): submission, first emitted token and
    # terminal transition -- the open-loop bench derives TTFT and
    # per-token latency from these (benchmarks/serving_bench.py)
    submitted_at: float = 0.0
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None

    @property
    def n_generated(self) -> int:
        return len(self.tokens)

    @property
    def finished(self) -> bool:
        """True once the request reached ANY terminal state (``done`` stays
        success-only)."""
        return self.status in TERMINAL_STATES


@dataclasses.dataclass
class EngineStats:
    steps: int = 0                  # decode steps (fused windows count K)
    windows: int = 0                # device dispatches (fused or per-step)
    prefills: int = 0
    tokens_generated: int = 0
    occupancy_sum: int = 0          # sum over steps of active slots
    completed: int = 0
    # lifecycle accounting (completed + failed + cancelled + shed covers
    # every request that ever reached a terminal state)
    failed: int = 0
    cancelled: int = 0
    shed: int = 0
    rejected: int = 0               # failed at submission (subset of failed)
    preemptions: int = 0
    deadline_misses: int = 0        # subset of failed/cancelled-by-deadline
    watchdog_stalls: int = 0
    # paged-KV accounting (dense engines fill prefilled_tokens only):
    # tokens actually run through a prefill forward (full or suffix),
    # prompt tokens served from shared prefix pages instead, and
    # preemption resumes that re-attached retained pages with NO prefill
    prefilled_tokens: int = 0
    prefix_hit_tokens: int = 0
    page_resumes: int = 0
    prefill_chunks: int = 0         # chunk dispatches (SLO scheduler)
    bucket_hits: Dict[int, int] = dataclasses.field(
        default_factory=lambda: collections.defaultdict(int))
    # wall-clock breakdown of the serving loop (seconds): prompt prefill
    # (compute + slot insert), decode windows (device call until outputs
    # materialize on host), and host-side sync work (token drain,
    # callbacks, slot recycling) -- benchmarks/serving_bench.py reports it
    prefill_s: float = 0.0
    decode_s: float = 0.0
    sync_s: float = 0.0

    @property
    def mean_occupancy(self) -> float:
        return self.occupancy_sum / self.steps if self.steps else 0.0

    def as_dict(self) -> Dict:
        return {"steps": self.steps, "windows": self.windows,
                "prefills": self.prefills,
                "tokens_generated": self.tokens_generated,
                "completed": self.completed,
                "failed": self.failed, "cancelled": self.cancelled,
                "shed": self.shed, "rejected": self.rejected,
                "preemptions": self.preemptions,
                "deadline_misses": self.deadline_misses,
                "watchdog_stalls": self.watchdog_stalls,
                "prefilled_tokens": self.prefilled_tokens,
                "prefix_hit_tokens": self.prefix_hit_tokens,
                "page_resumes": self.page_resumes,
                "prefill_chunks": self.prefill_chunks,
                "mean_occupancy": round(self.mean_occupancy, 3),
                "prefill_buckets": dict(self.bucket_hits),
                "prefill_s": round(self.prefill_s, 4),
                "decode_s": round(self.decode_s, 4),
                "sync_s": round(self.sync_s, 4)}


class ServingEngine:
    """Slot-addressable continuous-batching engine over a Servable.

    ``max_slots`` bounds request concurrency (the static batch of the one
    jitted decode executable); ``cache_len`` bounds prompt + generation
    length per slot. ``sync_every = K`` fuses up to K decode steps into one
    on-device window between host syncs (``collect_logits`` forces K = 1).

    Robustness knobs (docs/API.md §Engine robustness): ``max_queue`` +
    ``overflow`` bound the admission queue (policies in
    ``spec.OVERFLOW_POLICIES``); ``watchdog_timeout_s`` arms a stuck-window
    detector (``on_stall(label, elapsed)`` optional callback; stalls also
    snapshot into ``stats_dict()['watchdog']``); ``chaos`` attaches a
    :class:`repro.runtime.chaos.ChaosInjector` whose
    alloc/prefill/window/sync/arrival/chunk sites this engine fires;
    ``sched`` (:class:`repro.serving.SchedSpec`; kwarg > spec.sched)
    enables the SLO scheduler -- chunked prefill, per-window token
    budget, deadline fast-fail and overload shedding (module docstring).
    """

    def __init__(self, servable, max_slots: int = 8, cache_len: int = 256,
                 *, min_bucket: int = 8, collect_logits: bool = False,
                 sync_every: int = 8, temperature: float = 0.0,
                 top_k: int = 0, seed: int = 0,
                 max_queue: Optional[int] = None, overflow: str = "reject",
                 watchdog_timeout_s: Optional[float] = None,
                 on_stall: Optional[Callable[[str, float], None]] = None,
                 chaos: Optional["chaos_mod.ChaosInjector"] = None,
                 kv_layout: Optional[str] = None,
                 kv_pool_pages: Optional[int] = None,
                 sched: Optional[SchedSpec] = None):
        if servable.cfg.family == "bert":
            raise ValueError("encoder-only arch has no decode step")
        if overflow not in OVERFLOW_POLICIES:
            raise ValueError(
                f"overflow={overflow!r} not in {OVERFLOW_POLICIES}")
        if max_queue is not None and int(max_queue) < 1:
            raise ValueError("max_queue must be >= 1 (or None = unbounded)")
        self.servable = servable
        self.cfg = servable.cfg
        self.max_slots = int(max_slots)
        self.cache_len = int(cache_len)
        # floor of 2: a length-1 "prefill" would hit the single-token decode
        # path (s == 1), which expects a pos argument
        self.min_bucket = max(2, int(min_bucket))
        self.collect_logits = collect_logits
        self.sync_every = 1 if collect_logits else max(1, int(sync_every))
        self.temperature = float(temperature)
        self.top_k = int(top_k)
        self._key = jax.random.PRNGKey(int(seed))
        self.stats = EngineStats()
        self.mesh = servable.mesh               # None = single-device path
        self.max_queue = None if max_queue is None else int(max_queue)
        self.overflow = overflow
        self._chaos = chaos
        self._watchdog = None
        self._user_on_stall = on_stall
        self._watchdog_snapshot: Optional[Dict] = None
        if watchdog_timeout_s is not None:
            # the engine interposes on the stall callback to snapshot its
            # queue/active/chunk state for stats_dict()['watchdog'];
            # detection semantics are the Watchdog's, unchanged
            self._watchdog = chaos_mod.Watchdog(watchdog_timeout_s,
                                                on_stall=self._on_stall)

        self._sub_template = None
        if self.cfg.family != "audio":
            # single-request cache template reused by every prefill (the
            # prefill is functional; audio rebuilds per request from frames)
            self._sub_template = model_api.init_cache(
                servable.params, self.cfg, 1, self.cache_len)
            if self.mesh is not None:
                from repro.launch.sharding import replicated
                self._sub_template = jax.device_put(
                    self._sub_template, replicated(self.mesh))

        # -- KV layout resolution: kwarg > REPRO_KV_LAYOUT env > spec ------
        layout = kv_layout or os.environ.get("REPRO_KV_LAYOUT") \
            or servable.spec.kv_layout
        if layout not in KV_LAYOUTS:
            raise ValueError(f"kv_layout={layout!r} not in {KV_LAYOUTS}")
        prefix_k, pattern_k, n_per, suffix_k = self.cfg.layer_plan()
        kinds = list(prefix_k) + (list(pattern_k) if n_per > 0 else []) \
            + list(suffix_k)
        pageable = [k for k in kinds
                    if k.mixer in ("attn", "mla") and k.window == 0]
        if layout == "paged":
            blocker = None
            if self.cfg.family == "audio":
                blocker = "family 'audio' (cross-attn caches are per-request)"
            elif self.cfg.kv_cache_quant:
                blocker = "kv_cache_quant (int8 page pools are future work)"
            elif servable.spec.data_shards > 1:
                blocker = "data-parallel mesh (page ids are a shared space)"
            elif not pageable:
                blocker = "no linear attention/MLA layers to page"
            if blocker is not None:
                log.info("kv_layout='paged' unavailable for this config "
                         "(%s); serving dense", blocker)
                layout = "dense"
        self.kv_layout = layout
        self._pool = None
        self._prefix_cache = None
        self._slot_pages: Dict[int, List[int]] = {}
        self._saved_pages: Dict[int, tuple] = {}
        self._layout = None
        if layout == "paged":
            # largest page size <= spec.kv_page_size dividing cache_len (the
            # table must tile the cache exactly); default pool capacity
            # matches the dense worst case so parity runs are apples to
            # apples -- kv_pool_pages shrinks it to create real pressure
            ps = min(int(servable.spec.kv_page_size), self.cache_len)
            while self.cache_len % ps:
                ps -= 1
            self.kv_page_size = ps
            self._table_width = self.cache_len // ps
            n_pages = int(kv_pool_pages) if kv_pool_pages is not None \
                else self.max_slots * self._table_width
            self._layout = PagedLayout(page_size=ps, n_pages=n_pages)
            self._pool = PagePool(n_pages, ps)
            self._prefix_cache = PrefixCache(self._pool, ps)
            # prefix sharing needs the masked suffix-prefill path (pure
            # global attention); preempt-resume page retention additionally
            # admits MLA (restore is layout-only, no recompute)
            self._can_share = all(k.mixer == "attn" and k.window == 0
                                  for k in kinds)
            self._can_retain = all(k.mixer in ("attn", "mla")
                                   and k.window == 0 for k in kinds)

        # -- SLO scheduling: kwarg > spec (docs/API.md §SLO scheduling) ---
        # sched arms deadline fast-fail and overload shedding regardless;
        # chunked prefill (max_chunk > 0) additionally needs the masked
        # chunk path every layer supports -- ineligible configs fall back
        # to one-shot admission with the other knobs still live
        self.sched = sched if sched is not None else servable.spec.sched
        self._chunking = False
        if self.sched is not None and self.sched.max_chunk > 0:
            blocker = None
            if self.cfg.family == "audio":
                blocker = "family 'audio' prefills through the decode path"
            elif self.cfg.kv_cache_quant:
                blocker = "kv_cache_quant (int8 KV has no masked chunk path)"
            elif any(k.ffn == "moe" for k in kinds):
                blocker = "MoE ffn (expert routing is batch-global)"
            if blocker is not None:
                log.info("chunked prefill unavailable for this config "
                         "(%s); scheduling runs without it", blocker)
            else:
                self._chunking = True
        # chunk lengths are QUANTIZED: every dispatched chunk is exactly
        # _chunk_len tokens or the prompt tail, never a budget-truncated
        # remainder -- each novel chunk length is a fresh suffix-jit shape
        # (an on-clock compile), so max_chunk is clamped to the window
        # budget and a chunk that no longer fits waits for the next window
        self._chunk_len = 0
        if self._chunking:
            self._chunk_len = self.sched.max_chunk
            if self.sched.token_budget > 0:
                self._chunk_len = min(self._chunk_len,
                                      self.sched.token_budget)
        #: req_ids whose fresh full-prompt pages publish to the prefix
        #: cache once their (chunked) prefill completes
        self._pending_publish: set = set()

        self.cache = self._build_cache()
        # host-side byte accounting from the real device leaves
        self._kv_bytes_total = sum(
            x.nbytes for x in jax.tree_util.tree_leaves(self.cache))
        if self._pool is not None:
            pool_bytes = 0
            def _acc(path, x):
                nonlocal pool_bytes
                name = getattr(path[-1], "key", None)
                if isinstance(name, str) and name.endswith("_pages"):
                    pool_bytes += x.nbytes
                return x
            jax.tree_util.tree_map_with_path(_acc, self.cache)
            self._pool.bytes_per_page = pool_bytes // self._pool.n_pages

        self._tokens = np.zeros((self.max_slots, 1), np.int32)
        self._pos = np.full((self.max_slots,), -1, np.int32)
        self._remaining = np.zeros((self.max_slots,), np.int32)
        self._eos = np.full((self.max_slots,), -1, np.int32)
        self._free: List[int] = list(range(self.max_slots))
        self._active: Dict[int, EngineRequest] = {}
        self._queue: "collections.deque[EngineRequest]" = collections.deque()
        # completed since the last run() drain -- the engine does NOT
        # retain request history beyond that (a long-lived engine would
        # otherwise hold every prompt/generation ever served); callers
        # keep their own handles
        self._done: List[EngineRequest] = []
        self._next_id = 0
        self._admit_counter = 0

        # jitted functions are owned by the Servable and shared across its
        # engines: one decode executable per max_slots shape (and per fused
        # window length K), one prefill trace per bucket length, warm for
        # the engine's whole lifetime (and the next engine's). The decode
        # cache argument is donated, so the hot loop never copies the slot
        # caches.
        # under a mesh, every jit the cache flows through pins its output
        # to the engine cache's placement: decode windows, insertion and
        # retirement then keep ONE canonical sharded layout end to end --
        # donation stays usable (no per-step copies) and the cache never
        # gathers to one device (let alone host) across a request's
        # lifetime. engine_fns shares executables across engines in both
        # modes (per cache-sharding tree under a mesh).
        out_sh = None if self.mesh is None else \
            jax.tree_util.tree_map(lambda x: x.sharding, self.cache)
        (self._decode, self._decode_many, self._write_slot,
         self._free_slot) = servable.engine_fns(out_sh)
        self._prefill = servable._engine_prefill_fn()
        if self.kv_layout == "paged":
            (self._write_paged, self._restore_paged,
             self._suffix_prefill) = servable.paged_engine_fns(out_sh)
        elif self._chunking:
            # dense chunked prefill rides the same suffix entry point the
            # paged prefix-hit path uses (servable.suffix_prefill_fn)
            self._suffix_prefill = servable.suffix_prefill_fn(out_sh)

    def _build_cache(self):
        """A fresh all-slots-free engine cache (constructor AND the
        recovery path after a decode-window failure invalidated the donated
        buffers)."""
        if self.cfg.family == "audio":
            # structure-only cache: encode batch-1 zero frames and broadcast
            # the slot axis (axis 1; every leaf is layer-stacked) -- the real
            # cross K/V arrives per request via write_slot at admission
            one = model_api.init_cache(
                self.servable.params, self.cfg, 1, self.cache_len,
                frames=jnp.zeros((1, self.cfg.n_audio_ctx, self.cfg.d_model),
                                 self.cfg.jdtype))
            cache = jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(
                    x, x.shape[:1] + (self.max_slots,) + x.shape[2:]), one)
        else:
            cache = model_api.init_cache(self.servable.params, self.cfg,
                                         self.max_slots, self.cache_len,
                                         paged=self._layout)
        if self.mesh is not None:
            # mesh-first cache: slots over "data", heads/state over "model".
            # Lifecycle ops below are pinned to these shardings, so alloc/
            # free/reset/write never regather the cache (tested:
            # tests/test_sharded_serving.py)
            cache = model_api.shard_cache(cache, self.cfg, self.mesh)
        return cache

    # -- submission -------------------------------------------------------
    def submit(self, prompt: Sequence[int], max_new_tokens: int = 16, *,
               eos_id: Optional[int] = None, frames=None, priority: int = 0,
               deadline_s: Optional[float] = None,
               on_token: Optional[Callable[[int, int], None]] = None,
               on_done: Optional[Callable[[int, List[int]], None]] = None
               ) -> EngineRequest:
        """Enqueue a request; returns its handle (``.tokens`` fills as the
        engine runs, ``.status`` reaches exactly one terminal state).

        Invalid requests are REJECTED AT SUBMISSION with a structured
        reason (``status == 'failed'``, ``failure.code == 'rejected'``)
        instead of failing late inside prefill/decode -- submit() never
        raises for request-level problems. ``deadline_s`` is a relative
        wall-clock budget enforced at window-sync points (and, with a
        ``SchedSpec``, fast-failed at admission when the engine's measured
        rates already rule the deadline out); ``priority`` orders admission
        and arms preemption (higher wins)."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        req = EngineRequest(req_id=self._next_id, prompt=prompt,
                            max_new_tokens=int(max_new_tokens), eos_id=eos_id,
                            frames=frames, on_token=on_token, on_done=on_done,
                            priority=int(priority))
        req.submitted_at = time.monotonic()
        self._next_id += 1
        if deadline_s is not None:
            req.deadline_at = req.submitted_at + float(deadline_s)

        reject = None
        if prompt.size == 0:
            reject = "empty prompt"
        elif max_new_tokens < 1:
            reject = ("max_new_tokens must be >= 1 (the prefill already "
                      "samples the first token)")
        elif prompt.size + max_new_tokens > self.cache_len:
            reject = (f"prompt ({prompt.size}) + max_new_tokens "
                      f"({max_new_tokens}) exceeds cache_len "
                      f"({self.cache_len})")
        elif self.cfg.family == "audio" and frames is None:
            reject = "audio requests need encoder frames"
        if reject is not None:
            self.stats.rejected += 1
            self._finalize(req, "failed",
                           FailureReason(FailureReason.REJECTED, reject))
            return req

        # deadline fast-fail AT ADMISSION (docs/API.md §SLO scheduling): an
        # already-expired deadline always fails here; with sched.fast_fail,
        # a completion projected past the deadline from the engine's
        # MEASURED prefill/decode rates fails too -- either way before the
        # request consumes a prefill slot. Both count as deadline_misses.
        if req.deadline_at is not None:
            now = time.monotonic()
            if now > req.deadline_at:
                self._finalize(req, "failed", FailureReason(
                    FailureReason.DEADLINE, "deadline expired at submission"))
                return req
            if self.sched is not None and self.sched.fast_fail:
                est = self._service_estimate_s(req)
                if est is not None and now + est > req.deadline_at:
                    self._finalize(req, "failed", FailureReason(
                        FailureReason.DEADLINE,
                        f"projected completion in {est:.3f}s exceeds the "
                        f"deadline (measured prefill/decode rates)"))
                    return req

        if self._chaos is not None:
            # open-loop ingest chaos: an action may re-entrantly submit a
            # burst through this engine; an exception sheds ONLY this
            # submission with a structured reason (never a crash)
            try:
                self._chaos.fire(chaos_mod.SITE_ARRIVAL_BURST, engine=self,
                                 request=req)
            except Exception as e:  # noqa: BLE001 -- shed, keep serving
                self._finalize(req, "shed", FailureReason(
                    FailureReason.OVERLOAD,
                    f"shed at ingest: {type(e).__name__}: {e}"))
                return req

        if self.max_queue is not None and len(self._queue) >= self.max_queue:
            if self.overflow == "block":
                # drive the engine until the queue drains below the bound
                while len(self._queue) >= self.max_queue and self.step():
                    pass
            if len(self._queue) >= self.max_queue:
                if self.overflow == "shed-oldest":
                    victim = self._queue.popleft()
                    self._finalize(victim, "shed", FailureReason(
                        FailureReason.QUEUE_FULL,
                        "shed by newer submission (shed-oldest)"))
                else:                   # 'reject' (or a block that stalled)
                    self._finalize(req, "shed", FailureReason(
                        FailureReason.QUEUE_FULL,
                        f"queue full ({self.max_queue}), policy "
                        f"{self.overflow!r}"))
                    return req
        self._queue.append(req)
        self._shed_overload()
        return req

    def cancel(self, req: EngineRequest) -> bool:
        """Request cancellation of ``req``. Queued requests cancel
        immediately; active ones at the next window-sync point (already
        generated tokens stay on the handle). Returns False when the
        request is already terminal."""
        if req.status in TERMINAL_STATES:
            return False
        req.cancel_requested = True
        if req.status == "queued":
            try:
                self._queue.remove(req)
            except ValueError:      # pragma: no cover - defensive
                return False
            self._finalize(req, "cancelled", FailureReason(
                FailureReason.CANCELLED, "cancelled while queued"))
        return True

    # -- SLO estimation + overload degradation ----------------------------
    def _service_estimate_s(self, req: EngineRequest) -> Optional[float]:
        """Projected seconds to finish ``req``, from the engine's MEASURED
        prefill/decode rates (the EngineStats wall-clock buckets). Returns
        None until both rates have real samples -- estimation never
        guesses, so a cold engine neither fast-fails nor sheds."""
        st = self.stats
        if (st.prefill_s <= 0 or st.prefilled_tokens <= 0
                or st.decode_s <= 0 or st.steps <= 0):
            return None
        pre_tokens = req.prompt.size + req.n_generated - req.prefill_pos
        pre = pre_tokens / (st.prefilled_tokens / st.prefill_s)
        dec = (req.max_new_tokens - req.n_generated) \
            / (st.steps / st.decode_s)
        return max(pre, 0.0) + max(dec, 0.0)

    def _shed_overload(self) -> None:
        """Graceful overload degradation (``sched.max_queue_delay_s > 0``):
        when the estimated time to drain the queue exceeds the bound, shed
        queued requests -- lowest priority first, newest first within a
        class -- with the structured OVERLOAD reason until the backlog
        fits. Shedding the newest lowest-priority traffic keeps requests
        that already waited (and higher SLO tiers) on track instead of
        letting every request miss a little."""
        if (self.sched is None or self.sched.max_queue_delay_s <= 0
                or not self._queue):
            return
        ests: Dict[int, float] = {}
        for r in self._queue:
            est = self._service_estimate_s(r)
            if est is None:         # rates not measured yet: never shed
                return
            ests[r.req_id] = est
        bound = self.sched.max_queue_delay_s
        slots = max(1, self.max_slots)
        backlog = sum(ests.values()) / slots
        while backlog > bound and self._queue:
            victim = min(self._queue,
                         key=lambda r: (r.priority, -r.submitted_at))
            self._queue.remove(victim)
            backlog -= ests[victim.req_id] / slots
            self._finalize(victim, "shed", FailureReason(
                FailureReason.OVERLOAD,
                f"estimated queue delay exceeds "
                f"max_queue_delay_s={bound}"))

    def _on_stall(self, label: str, elapsed: float) -> None:
        """Watchdog callback (daemon thread): snapshot queue/active/chunk
        state into ``stats_dict()['watchdog']`` -- best-effort shallow
        reads, since the serving thread keeps mutating -- then forward to
        the user's ``on_stall``. Detection-only semantics unchanged."""
        try:
            now = time.monotonic()

            def row(r):
                return {"req_id": r.req_id, "status": r.status,
                        "pos": int(r.pos),
                        "prefill_pos": int(r.prefill_pos),
                        "prefill_target": int(r.prefill_target),
                        "n_generated": r.n_generated,
                        "age_s": round(now - r.submitted_at, 4)}

            self._watchdog_snapshot = {
                "site": label, "elapsed_s": round(elapsed, 4),
                "n_queued": len(self._queue),
                "n_active": len(self._active),
                "queued": [row(r) for r in list(self._queue)[:8]],
                "active": [row(r) for r in list(self._active.values())[:8]]}
        except Exception:  # pragma: no cover -- racing the serving thread
            self._watchdog_snapshot = {"site": label,
                                       "elapsed_s": round(elapsed, 4)}
        if self._user_on_stall is not None:
            self._user_on_stall(label, elapsed)

    # -- prefill ----------------------------------------------------------
    def _bucket(self, length: int) -> int:
        b = max(self.min_bucket, 1 << (length - 1).bit_length())
        return min(b, self.cache_len)

    def _admit(self, req: EngineRequest) -> bool:
        """Admit ``req`` into a free slot. Returns True when the request was
        CONSUMED (now active, or terminally failed) and False when it was
        PARKED back at the queue front by paged backpressure -- the
        scheduler must stop admitting for this sync point, or it would spin
        on the same exhausted pool."""
        if self.kv_layout == "paged":
            return self._admit_paged(req)
        return self._admit_dense(req)

    def _activate(self, req: EngineRequest, slot: int, pos: int,
                  pages: Optional[List[int]] = None) -> None:
        """Common admission bookkeeping (dense and paged paths)."""
        req.slot, req.pos = slot, pos
        req.status = "active"
        req.admit_seq = self._admit_counter
        self._admit_counter += 1
        self._active[slot] = req
        self._eos[slot] = -1 if req.eos_id is None else int(req.eos_id)
        if pages is not None:
            self._slot_pages[slot] = pages

    def _admit_dense(self, req: EngineRequest) -> bool:
        """Prefill ``req`` into a free slot. A resumed (preempted) request
        prefills over prompt + already-generated tokens, continuing exactly
        where it stopped. Any failure here fails ONLY this request: the
        slot is restored and the engine keeps serving."""
        t0 = time.perf_counter()
        slot = None
        try:
            if self._chaos is not None:
                self._chaos.fire(chaos_mod.SITE_ALLOC, engine=self,
                                 request=req)
            slot = self._free.pop(0)
            seq = req.prompt if not req.tokens else np.concatenate(
                [req.prompt, np.asarray(req.tokens, np.int32)])
            length = int(seq.size)
            bucket = self._bucket(length)

            if self._chaos is not None:
                self._chaos.fire(chaos_mod.SITE_PREFILL, engine=self,
                                 request=req)
            if self.cfg.family == "audio":
                sub = model_api.init_cache(
                    self.servable.params, self.cfg, 1, self.cache_len,
                    frames=jnp.asarray(req.frames)[None]
                    if np.ndim(req.frames) == 2 else jnp.asarray(req.frames))
            else:
                sub = self._sub_template
            toks = np.zeros((bucket,), np.int32)
            toks[:length] = seq
            pos_seq = np.full((bucket,), -1, np.int32)
            pos_seq[:length] = np.arange(length)
            sub, logits = self._prefill(self.servable.params, sub,
                                        jnp.asarray(toks),
                                        jnp.asarray(pos_seq),
                                        jnp.int32(length))
            self.cache = self._write_slot(self.cache, jnp.int32(slot), sub)
            row = np.asarray(logits[length - 1])    # once per admission
        except Exception as e:  # noqa: BLE001 -- isolate to this request
            self._restore_slot(slot)
            self.stats.prefill_s += time.perf_counter() - t0
            log.warning("admission of request %d failed (%s: %s)",
                        req.req_id, type(e).__name__, e)
            self._finalize(req, "failed", FailureReason(
                FailureReason.PREFILL_ERROR, f"{type(e).__name__}: {e}"))
            return True

        self.stats.prefills += 1
        self.stats.prefilled_tokens += length
        self.stats.bucket_hits[bucket] += 1
        if not np.all(np.isfinite(row)):
            # poisoned before the first decode: quarantine at admission
            self.cache = self._free_slot(self.cache, jnp.int32(slot))
            self._restore_slot(slot)
            self.stats.prefill_s += time.perf_counter() - t0
            self._finalize(req, "failed", FailureReason(
                FailureReason.NONFINITE_LOGITS,
                f"non-finite prefill logits at position {length - 1}"))
            return True

        self._activate(req, slot, length)
        tok = sample_token_row(row, self._key, slot, length - 1,
                               temperature=self.temperature,
                               top_k=self.top_k)
        self.stats.prefill_s += time.perf_counter() - t0
        self._emit(req, int(tok), row)
        return True

    def _page_row(self, pages: List[int]):
        """A slot's page-table row: ``pages`` padded to table width with -1
        (-1 = unmapped; device scatters drop writes to unmapped pages)."""
        row = np.full((self._table_width,), -1, np.int32)
        row[:len(pages)] = pages
        return jnp.asarray(row)

    def _reserve_pages(self, n: int) -> List[int]:
        """Claim ``n`` fresh pages, evicting LRU prefix-cache references
        when the free list runs short (forfeits future hits, never touches
        an active slot's pages). Raises PagePoolExhausted -- the paged
        backpressure signal -- when eviction cannot cover the request."""
        if self._chaos is not None:
            self._chaos.fire(chaos_mod.SITE_PAGE_ALLOC, engine=self, want=n)
        while self._pool.free_count < n and self._prefix_cache.evict(1):
            pass
        return self._pool.alloc(n)

    def _admit_paged(self, req: EngineRequest) -> bool:
        """Paged admission: reserve ceil((len + max_new) / page_size) pages
        up front (the page table is static across decode windows), serve
        the longest cached prefix from shared pages, prefill only the
        remainder, and publish the fresh prompt's full pages for future
        sharers. A preempted request whose pages were retained re-attaches
        them with NO prefill at all. Pool exhaustion is backpressure (park
        at the queue front / structured shed), never a crash."""
        t0 = time.perf_counter()
        slot = None
        held: List[int] = []            # pages owned by THIS admission
        try:
            if self._chaos is not None:
                self._chaos.fire(chaos_mod.SITE_ALLOC, engine=self,
                                 request=req)
            slot = self._free.pop(0)

            saved = self._saved_pages.pop(req.req_id, None)
            if saved is not None:
                # preempt-resume via page retention: the victim's pages
                # were never released, so restoring the page table + pos
                # map resumes it bit-exactly with zero prefill work
                pages, resume_len = saved
                held = pages
                self.cache = self._restore_paged(
                    self.cache, jnp.int32(slot), self._page_row(pages),
                    jnp.int32(resume_len))
                self._activate(req, slot, resume_len, pages)
                self._tokens[slot, 0] = req.tokens[-1]
                self._pos[slot] = resume_len
                self._remaining[slot] = \
                    req.max_new_tokens - req.n_generated
                self.stats.page_resumes += 1
                self.stats.prefill_s += time.perf_counter() - t0
                return True

            seq = req.prompt if not req.tokens else np.concatenate(
                [req.prompt, np.asarray(req.tokens, np.int32)])
            length = int(seq.size)
            need = pages_needed(
                min(length + req.max_new_tokens, self.cache_len),
                self.kv_page_size)
            shared: List[int] = []
            if self._can_share and not req.tokens:
                # cap the match at length-1 so a fully-cached prompt still
                # prefills >= 1 suffix token (the forward pass must have a
                # position to produce next-token logits from)
                shared = self._prefix_cache.match(seq, limit=length - 1)
                held = held + shared
            start = len(shared) * self.kv_page_size
            fresh = self._reserve_pages(need - len(shared))
            held = held + fresh
            pages = shared + fresh

            if self._chaos is not None:
                self._chaos.fire(chaos_mod.SITE_PREFILL, engine=self,
                                 request=req)
            if start > 0:
                # prefix hit: attach the pages, then prefill ONLY the
                # suffix against the resident shared prefix (masked
                # attention; write positions never land in shared full
                # pages, so sharing is copy-on-write by construction)
                suffix = seq[start:]
                slen = int(suffix.size)
                bucket = self._bucket(slen)
                toks = np.zeros((bucket,), np.int32)
                toks[:slen] = suffix
                self.cache = self._restore_paged(
                    self.cache, jnp.int32(slot), self._page_row(pages),
                    jnp.int32(start))
                self.cache, logits = self._suffix_prefill(
                    self.servable.params, self.cache, jnp.asarray(toks),
                    jnp.int32(slot), jnp.int32(start), jnp.int32(slen))
                row = np.asarray(logits[slen - 1])
                self.stats.prefix_hit_tokens += start
                self.stats.prefilled_tokens += slen
            else:
                bucket = self._bucket(length)
                toks = np.zeros((bucket,), np.int32)
                toks[:length] = seq
                pos_seq = np.full((bucket,), -1, np.int32)
                pos_seq[:length] = np.arange(length)
                sub, logits = self._prefill(
                    self.servable.params, self._sub_template,
                    jnp.asarray(toks), jnp.asarray(pos_seq),
                    jnp.int32(length))
                self.cache = self._write_paged(
                    self.cache, jnp.int32(slot), sub, self._page_row(pages))
                row = np.asarray(logits[length - 1])
                self.stats.prefilled_tokens += length
                if self._can_share and not req.tokens:
                    # publish the prompt's FULL pages (strictly below the
                    # prompt length -- the partial tail page is mutable)
                    self._prefix_cache.insert(
                        seq, pages[:length // self.kv_page_size])
        except PagePoolExhausted as e:
            if held:
                self._pool.release(held)
            self._restore_slot(slot)
            self.stats.prefill_s += time.perf_counter() - t0
            if self._active:
                # actives will release pages as they finish: park at the
                # queue FRONT and let the scheduler retry next sync point
                req.status = "queued"
                self._queue.appendleft(req)
                log.info("parking request %d on page pressure (%s)",
                         req.req_id, e)
                return False
            self._finalize(req, "failed", FailureReason(
                FailureReason.KV_PAGES,
                f"{e} with no active requests to drain"))
            return True
        except Exception as e:  # noqa: BLE001 -- isolate to this request
            if held:
                self._pool.release(held)
            self._restore_slot(slot)
            self.stats.prefill_s += time.perf_counter() - t0
            log.warning("admission of request %d failed (%s: %s)",
                        req.req_id, type(e).__name__, e)
            self._finalize(req, "failed", FailureReason(
                FailureReason.PREFILL_ERROR, f"{type(e).__name__}: {e}"))
            return True

        self.stats.prefills += 1
        self.stats.bucket_hits[bucket] += 1
        if not np.all(np.isfinite(row)):
            self._pool.release(held)
            self.cache = self._free_slot(self.cache, jnp.int32(slot))
            self._restore_slot(slot)
            self.stats.prefill_s += time.perf_counter() - t0
            self._finalize(req, "failed", FailureReason(
                FailureReason.NONFINITE_LOGITS,
                f"non-finite prefill logits at position {length - 1}"))
            return True

        self._activate(req, slot, length, pages)
        tok = sample_token_row(row, self._key, slot, length - 1,
                               temperature=self.temperature,
                               top_k=self.top_k)
        self.stats.prefill_s += time.perf_counter() - t0
        self._emit(req, int(tok), row)
        return True

    # -- chunked prefill (docs/API.md §SLO scheduling) --------------------
    def _begin_chunked(self, req: EngineRequest) -> bool:
        """Claim a slot (and, paged, the request's full page reservation +
        prefix match) WITHOUT running prefill compute -- chunk dispatch is
        metered separately by the token budget (``_prefill_chunk``). The
        request becomes active with ``_pos[slot]`` still -1: it holds its
        slot across windows but is a device no-op row until the final
        chunk samples its first token. Returns False when paged
        backpressure parked it at the queue front (the ``_admit``
        contract)."""
        t0 = time.perf_counter()
        slot = None
        held: List[int] = []
        try:
            if self._chaos is not None:
                self._chaos.fire(chaos_mod.SITE_ALLOC, engine=self,
                                 request=req)
            slot = self._free.pop(0)

            if self.kv_layout == "paged":
                saved = self._saved_pages.pop(req.req_id, None)
                if saved is not None:
                    # preempt-resume page retention: instant, no prefill
                    pages, resume_len = saved
                    held = pages
                    self.cache = self._restore_paged(
                        self.cache, jnp.int32(slot), self._page_row(pages),
                        jnp.int32(resume_len))
                    self._activate(req, slot, resume_len, pages)
                    req.prefill_pos = req.prefill_target = 0
                    self._tokens[slot, 0] = req.tokens[-1]
                    self._pos[slot] = resume_len
                    self._remaining[slot] = \
                        req.max_new_tokens - req.n_generated
                    self.stats.page_resumes += 1
                    self.stats.prefill_s += time.perf_counter() - t0
                    return True

            seq = req.prompt if not req.tokens else np.concatenate(
                [req.prompt, np.asarray(req.tokens, np.int32)])
            length = int(seq.size)
            start = 0
            pages = None
            if self.kv_layout == "paged":
                need = pages_needed(
                    min(length + req.max_new_tokens, self.cache_len),
                    self.kv_page_size)
                shared: List[int] = []
                if self._can_share and not req.tokens:
                    shared = self._prefix_cache.match(seq, limit=length - 1)
                    held = held + shared
                start = len(shared) * self.kv_page_size
                fresh = self._reserve_pages(need - len(shared))
                held = held + fresh
                pages = shared + fresh
                # install the page table up front: every chunk scatters
                # through it, and the pos map starts at the shared prefix
                self.cache = self._restore_paged(
                    self.cache, jnp.int32(slot), self._page_row(pages),
                    jnp.int32(start))
                if start > 0:
                    self.stats.prefix_hit_tokens += start
                elif self._can_share and not req.tokens:
                    self._pending_publish.add(req.req_id)
        except PagePoolExhausted as e:
            if held:
                self._pool.release(held)
            self._restore_slot(slot)
            self.stats.prefill_s += time.perf_counter() - t0
            if self._active:
                req.status = "queued"
                self._queue.appendleft(req)
                log.info("parking request %d on page pressure (%s)",
                         req.req_id, e)
                return False
            self._finalize(req, "failed", FailureReason(
                FailureReason.KV_PAGES,
                f"{e} with no active requests to drain"))
            return True
        except Exception as e:  # noqa: BLE001 -- isolate to this request
            if held:
                self._pool.release(held)
            self._restore_slot(slot)
            self.stats.prefill_s += time.perf_counter() - t0
            log.warning("admission of request %d failed (%s: %s)",
                        req.req_id, type(e).__name__, e)
            self._finalize(req, "failed", FailureReason(
                FailureReason.PREFILL_ERROR, f"{type(e).__name__}: {e}"))
            return True

        self._activate(req, slot, length, pages)
        req.prefill_pos = start
        req.prefill_target = length
        self.stats.prefill_s += time.perf_counter() - t0
        return True

    def _prefill_chunk(self, req: EngineRequest, budget: int) -> int:
        """Run prefill chunks for an admitted, partially-prefilled request
        until its prompt is resident or ``budget`` tokens are spent;
        returns the tokens dispatched. Chunk lengths are quantized to
        ``_chunk_len`` (or the prompt tail) and bucketed like one-shot
        prefills, so the suffix jit set stays small and warm. The final chunk
        samples the first token (the request decodes next window). A
        failure fails ONLY this request: the chaos site fires before the
        (cache-donating) suffix dispatch, so ``engine.cache`` survives an
        injected chunk fault intact (tests/test_chaos.py)."""
        if budget <= 0 or req.prefill_pos >= req.prefill_target:
            return 0
        seq = req.prompt if not req.tokens else np.concatenate(
            [req.prompt, np.asarray(req.tokens, np.int32)])
        used = 0
        t0 = time.perf_counter()
        try:
            while req.prefill_pos < req.prefill_target:
                start = req.prefill_pos
                c = min(self._chunk_len, req.prefill_target - start)
                if c > budget - used:
                    break               # whole-chunk budget gating: defer
                if self._chaos is not None:
                    self._chaos.fire(chaos_mod.SITE_PREFILL_CHUNK,
                                     engine=self, request=req,
                                     start=start, size=c)
                bucket = self._bucket(c)
                toks = np.zeros((bucket,), np.int32)
                toks[:c] = seq[start:start + c]
                if self._watchdog is not None:
                    self._watchdog.arm("prefill-chunk")
                try:
                    self.cache, logits = self._suffix_prefill(
                        self.servable.params, self.cache,
                        jnp.asarray(toks), jnp.int32(req.slot),
                        jnp.int32(start), jnp.int32(c))
                finally:
                    if self._watchdog is not None:
                        self._watchdog.disarm()
                        self.stats.watchdog_stalls = \
                            len(self._watchdog.stalls)
                req.prefill_pos += c
                used += c
                self.stats.prefilled_tokens += c
                self.stats.prefill_chunks += 1
                self.stats.bucket_hits[bucket] += 1
            if req.prefill_pos < req.prefill_target:
                return used                 # budget spent mid-prompt
            row = np.asarray(logits[c - 1])
        except Exception as e:  # noqa: BLE001 -- isolate to this request
            log.warning("chunked prefill of request %d failed (%s: %s)",
                        req.req_id, type(e).__name__, e)
            self._finalize(req, "failed", FailureReason(
                FailureReason.PREFILL_ERROR, f"{type(e).__name__}: {e}"))
            return used
        finally:
            self.stats.prefill_s += time.perf_counter() - t0

        self.stats.prefills += 1
        if not np.all(np.isfinite(row)):
            self._finalize(req, "failed", FailureReason(
                FailureReason.NONFINITE_LOGITS,
                f"non-finite prefill logits at position "
                f"{req.prefill_target - 1}"))
            return used
        if req.req_id in self._pending_publish:
            self._pending_publish.discard(req.req_id)
            pages = self._slot_pages.get(req.slot, [])
            self._prefix_cache.insert(
                seq, pages[:req.prefill_target // self.kv_page_size])
        tok = sample_token_row(row, self._key, req.slot,
                               req.prefill_target - 1,
                               temperature=self.temperature,
                               top_k=self.top_k)
        self._emit(req, int(tok), row)
        return used

    def _admit_budgeted(self, req: EngineRequest, budget: int):
        """Admission dispatch for the chunked scheduler: prompts that fit
        in ONE chunk (the short/interactive population an SLO protects)
        take the LEGACY one-shot path -- donated slot write, paged prefix
        match, no full-cache chunk attention -- because slicing only pays
        off when a prompt spans windows. Multi-chunk prompts go through
        ``_admit_chunked``. Returns ``(consumed, tokens_used)``."""
        need = req.prompt.size + req.n_generated
        if need <= self._chunk_len:
            return self._admit(req), need
        return self._admit_chunked(req, budget)

    def _admit_chunked(self, req: EngineRequest, budget: int):
        """Chunked admission: claim slot + pages, then spend up to
        ``budget`` prefill tokens. Returns ``(consumed, tokens_used)``;
        ``consumed`` False = paged backpressure parked the request (the
        scheduler must stop admitting this sync point)."""
        if not self._begin_chunked(req):
            return False, 0
        if req.status != "active":          # begin failed terminally
            return True, 0
        if req.prefill_pos >= req.prefill_target:   # page-retention resume
            return True, 0
        return True, self._prefill_chunk(req, budget)

    def _restore_slot(self, slot: Optional[int]) -> None:
        """Return a popped-but-unoccupied slot to the free list."""
        if slot is not None and slot not in self._free:
            self._free.append(slot)
            self._free.sort()

    # -- lifecycle --------------------------------------------------------
    def _emit(self, req: EngineRequest, tok: int, logits_row=None) -> None:
        """Record one sampled token and retire the request if it just
        completed. ``logits_row`` (V,) is only materialized on host when
        the engine collects logits."""
        req.tokens.append(tok)
        if req.first_token_at is None:
            req.first_token_at = time.monotonic()
        if self.collect_logits and logits_row is not None:
            req.step_logits.append(np.asarray(logits_row, np.float32))
        self.stats.tokens_generated += 1
        if req.on_token is not None:
            req.on_token(req.req_id, tok)
        if (req.n_generated >= req.max_new_tokens
                or (req.eos_id is not None and tok == req.eos_id)):
            self._finalize(req, "done")
        else:
            self._tokens[req.slot, 0] = tok
            self._pos[req.slot] = req.pos
            self._remaining[req.slot] = req.max_new_tokens - req.n_generated

    def _release_slot(self, req: EngineRequest, *,
                      keep_pages: bool = False) -> None:
        """Free ``req``'s slot with full recycle hygiene: zero attention KV
        and recurrent state on device, reset the host mirrors, return the
        slot to the free list. Paged engines also settle the slot's page
        references -- released back to the pool, or (``keep_pages``,
        preemption retention) parked in ``_saved_pages`` with the resume
        position so re-admission can re-attach them prefill-free."""
        slot = req.slot
        if self.kv_layout == "paged":
            pages = self._slot_pages.pop(slot, [])
            if keep_pages and pages:
                # resume point: KV holds positions 0..req.pos-1 (the
                # current token's KV is written by its NEXT decode step)
                self._saved_pages[req.req_id] = (pages, req.pos)
            elif pages:
                self._pool.release(pages)
        self.cache = self._free_slot(self.cache, jnp.int32(slot))
        self._pos[slot] = -1
        self._tokens[slot, 0] = 0
        self._remaining[slot] = 0
        self._eos[slot] = -1
        del self._active[slot]
        self._free.append(slot)
        self._free.sort()
        req.slot = -1

    def _finalize(self, req: EngineRequest, status: str,
                  reason: Optional[FailureReason] = None) -> None:
        """Move ``req`` to its (single) terminal state, releasing its slot
        if it holds one."""
        if req.slot >= 0:
            self._release_slot(req)
        if self.kv_layout == "paged":
            # a retained (preempted) request dying while queued must give
            # its saved pages back -- cancel/deadline/shed paths
            saved = self._saved_pages.pop(req.req_id, None)
            if saved is not None:
                self._pool.release(saved[0])
        self._pending_publish.discard(req.req_id)
        req.status = status
        req.failure = reason
        req.done = status == "done"
        req.finished_at = time.monotonic()
        if status == "done":
            self.stats.completed += 1
        elif status == "failed":
            self.stats.failed += 1
        elif status == "cancelled":
            self.stats.cancelled += 1
        elif status == "shed":
            self.stats.shed += 1
        if reason is not None and reason.code == FailureReason.DEADLINE:
            self.stats.deadline_misses += 1
        self._done.append(req)
        if status == "done" and req.on_done is not None:
            req.on_done(req.req_id, list(req.tokens))

    def _preempt(self, req: EngineRequest) -> None:
        """Evict an in-flight request: free its slot (recycle hygiene) and
        requeue it at the FRONT of its priority class. Re-admission resumes
        it via page retention when the layout allows (paged + every layer's
        state lives in pages: pure linear attn/MLA) -- bit-exact and
        prefill-free -- and otherwise via prefill over prompt + generated
        tokens."""
        keep = (self.kv_layout == "paged" and self._can_retain
                and req.n_generated > 0)
        self._release_slot(req, keep_pages=keep)
        # a half-prefilled victim (chunk scheduling) restarts its prefill
        # from scratch on re-admission -- its slot state is gone (retention
        # requires n_generated > 0, so it never kept pages either)
        self._pending_publish.discard(req.req_id)
        req.prefill_pos = req.prefill_target = 0
        req.status = "queued"
        req.n_preempted += 1
        self.stats.preemptions += 1
        self._queue.appendleft(req)

    def _sweep_control(self) -> None:
        """The window-sync control sweep: apply pending cancellations,
        expire deadlines for queued AND active requests, fast-fail queued
        requests whose projected completion already rules their deadline
        out (``sched.fast_fail``, measured rates only) and run overload
        shedding. Runs at the top of every step(), so lifecycle
        enforcement costs nothing between sync points (the fused window
        stays one jitted scan)."""
        now = time.monotonic()

        def expired(r):
            return r.deadline_at is not None and now > r.deadline_at

        fast = self.sched is not None and self.sched.fast_fail

        def doomed(r):
            if not fast or r.deadline_at is None:
                return False
            est = self._service_estimate_s(r)
            return est is not None and now + est > r.deadline_at

        for req in [r for r in self._queue
                    if r.cancel_requested or expired(r) or doomed(r)]:
            self._queue.remove(req)
            if req.cancel_requested:
                self._finalize(req, "cancelled", FailureReason(
                    FailureReason.CANCELLED, "cancelled while queued"))
            elif expired(req):
                self._finalize(req, "failed", FailureReason(
                    FailureReason.DEADLINE,
                    "deadline expired before admission"))
            else:
                self._finalize(req, "failed", FailureReason(
                    FailureReason.DEADLINE,
                    "projected completion exceeds deadline while queued "
                    "(fast-fail before consuming a prefill slot)"))
        for req in [r for r in self._active.values()
                    if r.cancel_requested or expired(r)]:
            if req.cancel_requested:
                self._finalize(req, "cancelled", FailureReason(
                    FailureReason.CANCELLED,
                    f"cancelled after {req.n_generated} tokens"))
            else:
                self._finalize(req, "failed", FailureReason(
                    FailureReason.DEADLINE,
                    f"deadline expired after {req.n_generated}/"
                    f"{req.max_new_tokens} tokens"))
        self._shed_overload()

    def _pop_next(self) -> EngineRequest:
        """Highest-priority queued request, FIFO within a priority class."""
        best_i, best = 0, self._queue[0]
        for i, req in enumerate(self._queue):
            if req.priority > best.priority:
                best_i, best = i, req
        del self._queue[best_i]
        return best

    def _schedule(self) -> None:
        """Admissions + priority preemption (a window-sync point action).
        A False from ``_admit`` means paged backpressure parked the request
        at the queue front -- stop admitting until the next sync point (the
        pool cannot satisfy it now; retrying in this loop would spin).
        Chunk-scheduling engines route through ``_schedule_chunked``."""
        if self._chunking:
            self._schedule_chunked()
            return
        while self._free and self._queue:
            if not self._admit(self._pop_next()):
                return
        # under slot pressure: strictly-higher-priority queued traffic
        # evicts the lowest-priority (latest-admitted on ties) active
        # request; the victim resumes later (page retention or re-prefill)
        while self._queue and not self._free and self._active:
            best_p = max(r.priority for r in self._queue)
            victim = min(self._active.values(),
                         key=lambda r: (r.priority, -r.admit_seq))
            if best_p <= victim.priority:
                break
            self._preempt(victim)
            if not self._admit(self._pop_next()):
                return

    def _schedule_chunked(self) -> None:
        """The token-budget scheduler (docs/API.md §SLO scheduling): each
        window-sync point spends at most ``sched.token_budget`` prefill
        tokens, in ``sched.max_chunk``-sized chunks, so one long prompt
        can never head-of-line-block running decodes behind a monolithic
        prefill. ``decode_priority`` reserves ``n_decoding * sync_every``
        of the budget for the decode window that follows; with nothing
        decoding the budget clamps to >= 1 token so prefill always makes
        progress (liveness). Partially-prefilled residents continue in
        admission order before new requests are admitted; priority
        preemption matches the legacy scheduler."""
        sched = self.sched
        budget = sched.token_budget if sched.token_budget > 0 else (1 << 30)
        n_dec = sum(1 for s in self._active if self._pos[s] >= 0)
        if sched.decode_priority:
            budget -= n_dec * self.sync_every
        if n_dec == 0:
            budget = max(budget, 1)

        # 1. priority preemption FIRST (the legacy policy): a high-SLO
        # arrival must not wait out a low-priority resident's chunked
        # prefill -- the continuation pass below would otherwise spend
        # every window's budget on the victim it is about to evict. The
        # preemptor claims its slot even at budget 0 (its chunks then run
        # in later windows).
        while self._queue and not self._free and self._active:
            best_p = max(r.priority for r in self._queue)
            victim = min(self._active.values(),
                         key=lambda r: (r.priority, -r.admit_seq))
            if best_p <= victim.priority:
                break
            self._preempt(victim)
            consumed, used = self._admit_budgeted(self._pop_next(), budget)
            budget -= used
            if not consumed:
                return

        # 2. admit new requests into free slots BEFORE continuing resident
        # prefills: a short arrival starts (and finishes) its prefill out
        # of the same budget a long resident would otherwise monopolize --
        # this is what kills head-of-line blocking. But admissions must
        # not STARVE the residents either (under sustained arrivals a long
        # prompt would otherwise never finish prefilling while holding its
        # slot): when a continuation is pending, admissions may spend at
        # most half the window budget, so the oldest resident keeps
        # making whole-chunk progress (set token_budget >= 2 * max_chunk
        # for both halves to fit a chunk).
        pending = any(r.prefill_pos < r.prefill_target
                      for r in self._active.values())
        adm_budget = budget // 2 if pending else budget
        while adm_budget > 0 and self._free and self._queue:
            consumed, used = self._admit_budgeted(self._pop_next(),
                                                  adm_budget)
            adm_budget -= used
            budget -= used
            if not consumed:
                return

        # 3. continue partially-prefilled residents, oldest admission first
        for req in sorted((r for r in self._active.values()
                           if r.prefill_pos < r.prefill_target),
                          key=lambda r: r.admit_seq):
            if budget <= 0:
                break
            budget -= self._prefill_chunk(req, budget)

    # -- stepping ---------------------------------------------------------
    def step(self) -> bool:
        """One window-sync cycle: control sweep (cancel/deadline/overload),
        schedule (chunk continuation + admit + preempt), then ONE batched
        decode window (up to ``sync_every`` fused steps) over the DECODING
        slots -- a mid-prefill request (chunk scheduling) holds its slot
        as a device no-op row (pos -1) and rides along untouched. Returns
        True while there is (or may be) work left."""
        self._sweep_control()
        self._schedule()
        decoding = sorted(s for s in self._active if self._pos[s] >= 0)
        if not decoding:
            return bool(self._active or self._queue)
        if self._watchdog is not None:
            self._watchdog.arm("decode-window")
        try:
            if self._chaos is not None:
                self._chaos.fire(chaos_mod.SITE_WINDOW, engine=self)
            k = min(self.sync_every,
                    max(int(self._remaining[s]) for s in decoding))
            if k <= 1:
                self._step_single(decoding)
            else:
                self._step_fused(k, decoding)
            if self._chaos is not None:
                self._chaos.fire(chaos_mod.SITE_SYNC, engine=self)
        except Exception as e:  # noqa: BLE001 -- keep the engine serving
            self._recover_window_failure(e)
        finally:
            if self._watchdog is not None:
                self._watchdog.disarm()
                self.stats.watchdog_stalls = len(self._watchdog.stalls)
        return bool(self._active or self._queue)

    def _recover_window_failure(self, err: Exception) -> None:
        """A decode window raised: the donated engine cache may be
        invalidated, so fail every active request with a structured reason,
        rebuild a fresh cache, and leave the engine usable (queued
        requests are admitted on the next step)."""
        log.warning("decode window failed (%s: %s); failing %d active "
                    "request(s) and rebuilding the engine cache",
                    type(err).__name__, err, len(self._active))
        reason = FailureReason(
            FailureReason.ENGINE_ERROR,
            f"decode window failed: {type(err).__name__}: {err}")
        reqs = list(self._active.values())
        self._active.clear()
        self._free = list(range(self.max_slots))
        self._pos[:] = -1
        self._tokens[:] = 0
        self._remaining[:] = 0
        self._eos[:] = -1
        if self.kv_layout == "paged":
            # the rebuilt cache has fresh (zeroed) pools: restart the host
            # allocator and drop every prefix/retention reference with it
            self._slot_pages.clear()
            self._saved_pages.clear()
            self._pool.reset()
            self._prefix_cache = PrefixCache(self._pool, self.kv_page_size)
        self.cache = self._build_cache()
        for req in reqs:
            req.slot = -1
            self._finalize(req, "failed", reason)

    def _step_single(self, decoding: List[int]) -> None:
        """The unfused loop: one decode, one host sync per token. Kept for
        ``sync_every=1`` and ``collect_logits`` (per-step logits only exist
        on host here). ``decoding`` is the slot set this window actually
        decodes -- mid-prefill slots are skipped at the drain (their rows
        are device no-ops and must not be quarantined or emitted)."""
        t0 = time.perf_counter()
        self.stats.steps += 1
        self.stats.windows += 1
        self.stats.occupancy_sum += len(decoding)
        next_tok, ok, logits, self.cache = self._decode(
            self.servable.params, self.cache, jnp.asarray(self._tokens),
            jnp.asarray(self._pos), self._key, self.temperature, self.top_k)
        toks = np.asarray(next_tok)             # (max_slots,) int32 only
        ok_h = np.asarray(ok)                   # (max_slots,) bool
        rows = np.asarray(logits[:, 0, :]) if self.collect_logits else None
        self.stats.decode_s += time.perf_counter() - t0
        t0 = time.perf_counter()
        for slot in decoding:
            req = self._active[slot]
            if not ok_h[slot]:
                # non-finite logits: quarantine only this slot
                self._finalize(req, "failed", FailureReason(
                    FailureReason.NONFINITE_LOGITS,
                    f"non-finite decode logits at position {req.pos}"))
                continue
            req.pos += 1
            self._emit(req, int(toks[slot]),
                       rows[slot] if rows is not None else None)
        self.stats.sync_s += time.perf_counter() - t0

    def _step_fused(self, k: int, decoding: List[int]) -> None:
        """The fused hot loop: K decode steps inside one jitted scan
        (sampling, EOS, non-finite guard and position bookkeeping on
        device), then ONE host sync that drains the emitted tokens, fires
        callbacks in step order and recycles finished slots. ``k`` never
        exceeds the largest remaining budget, so a window cannot overshoot
        ``max_new_tokens``; slots that hit EOS (or their budget, or
        non-finite logits) mid-window deactivate themselves on device and
        ride along as no-ops until the sync."""
        t0 = time.perf_counter()
        self.stats.steps += k
        self.stats.windows += 1
        toks, valid, state = self._decode_many(
            self.servable.params, self.cache, jnp.asarray(self._tokens),
            jnp.asarray(self._pos), jnp.asarray(self._remaining),
            jnp.asarray(self._eos), self._key, k, self.temperature,
            self.top_k)
        self.cache = state["cache"]
        toks_h = np.asarray(toks)               # (K, B) int32
        valid_h = np.asarray(valid)             # (K, B) bool
        failed_h = np.asarray(state["failed"])  # (B,) bool
        # writable host mirrors (np.asarray of a jax array is read-only)
        self._tokens = np.array(state["token"], np.int32)
        self._pos = np.array(state["pos"], np.int32)
        self._remaining = np.array(state["remaining"], np.int32)
        self.stats.decode_s += time.perf_counter() - t0

        t0 = time.perf_counter()
        self.stats.occupancy_sum += int(valid_h.sum())
        window = decoding
        for step in range(k):
            for slot in window:
                if not valid_h[step, slot]:
                    continue
                req = self._active[slot]
                req.pos += 1
                tok = int(toks_h[step, slot])
                req.tokens.append(tok)
                self.stats.tokens_generated += 1
                if req.on_token is not None:
                    req.on_token(req.req_id, tok)
        for slot in window:
            req = self._active[slot]
            if failed_h[slot]:                  # device quarantined it
                self._finalize(req, "failed", FailureReason(
                    FailureReason.NONFINITE_LOGITS,
                    f"non-finite decode logits in fused window at "
                    f"position {req.pos}"))
            elif self._pos[slot] < 0:           # device marked it finished
                # _finalize re-zeroes the host mirrors; cache hygiene via
                # free_slot as in the per-step path
                self._finalize(req, "done")
        self.stats.sync_s += time.perf_counter() - t0

    def run(self, max_steps: Optional[int] = None) -> List[EngineRequest]:
        """Drain the queue and all active slots; returns every request that
        reached a terminal state since the last drain (done / failed /
        cancelled / shed), in submission order, and releases them from
        engine tracking (callers keep their handles -- the engine itself
        retains no request history, so a long-lived engine's memory is
        bounded by its live requests)."""
        steps = 0
        while self.step():
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break
        done, self._done = self._done, []
        return sorted(done, key=lambda r: r.req_id)

    def close(self) -> None:
        """Stop the watchdog thread (idempotent; engines without one are
        no-ops)."""
        if self._watchdog is not None:
            self._watchdog.close()

    # -- chaos / test hooks ----------------------------------------------
    def corrupt_slot(self, slot: int) -> None:
        """Chaos hook: NaN-fill every float leaf of one slot's cache state
        (``repro.runtime.chaos.poison_slot``). The slot's next decode
        logits go non-finite and the engine's quarantine path must contain
        the damage to exactly this slot. Paged engines NaN-fill the slot's
        OWN pages instead (pool rows are not slot-addressable; co-resident
        slots never reference another slot's pages, so containment holds by
        the same argument). Only the slot's PRIVATE pages (refcount 1) are
        filled: shared prefix pages are other requests' state too, and
        poisoning them would break the containment the test asserts."""
        if self.kv_layout == "paged":
            own = [p for p in self._slot_pages.get(int(slot), [])
                   if self._pool.refcount(p) == 1]
            rows = jnp.asarray(own, jnp.int32)
            if rows.size == 0:
                return

            def poison(path, x):
                name = getattr(path[-1], "key", None)
                if not (isinstance(name, str) and name.endswith("_pages")
                        and jnp.issubdtype(x.dtype, jnp.floating)):
                    return x
                lead = getattr(path[0], "key", None) == "blocks"
                nan = jnp.nan
                if lead:
                    return x.at[:, rows].set(nan)
                return x.at[rows].set(nan)
            self.cache = jax.tree_util.tree_map_with_path(poison, self.cache)
            return
        sub = model_api.read_slot(self.cache, self.cfg, int(slot))
        sub = jax.tree_util.tree_map(
            lambda x: jnp.full_like(x, jnp.nan)
            if jnp.issubdtype(x.dtype, jnp.floating) else x, sub)
        self.cache = self._write_slot(self.cache, jnp.int32(int(slot)), sub)

    def verify_invariants(self) -> None:
        """Assert the engine's internal bookkeeping is consistent (chaos
        suite: called after every injected fault). Raises AssertionError
        on violation; cheap enough for tests, not run on the hot path."""
        slots = sorted(self._free) + sorted(self._active)
        assert sorted(slots) == list(range(self.max_slots)), (
            f"slot leak: free={sorted(self._free)} "
            f"active={sorted(self._active)} of {self.max_slots}")
        assert len(set(self._free)) == len(self._free), (
            f"duplicate free slots: {self._free}")
        for slot, req in self._active.items():
            assert req.slot == slot and req.status == "active", (
                f"slot {slot} holds request {req.req_id} with "
                f"slot={req.slot} status={req.status}")
            assert (self._pos[slot] >= 0 or req.n_generated > 0
                    or 0 <= req.prefill_pos < req.prefill_target), (
                f"active slot {slot} has no progress")
        for slot in self._free:
            assert self._pos[slot] == -1, (
                f"free slot {slot} has live pos {self._pos[slot]}")
        for req in self._queue:
            assert req.status == "queued" and req.slot == -1, (
                f"queued request {req.req_id} has slot={req.slot} "
                f"status={req.status}")
        for req in self._done:
            assert req.status in TERMINAL_STATES and req.slot == -1, (
                f"drained request {req.req_id} non-terminal: {req.status}")
        if self.kv_layout == "paged":
            self._pool.check()
            assert set(self._slot_pages) == set(self._active), (
                f"page ownership out of sync with active slots: "
                f"{sorted(self._slot_pages)} vs {sorted(self._active)}")
            for slot, pages in self._slot_pages.items():
                for p in pages:
                    assert self._pool.refcount(p) >= 1, (
                        f"slot {slot} holds unreferenced page {p}")
            for req_id, (pages, _len) in self._saved_pages.items():
                for p in pages:
                    assert self._pool.refcount(p) >= 1, (
                        f"retained request {req_id} holds unreferenced "
                        f"page {p}")

    # -- introspection ----------------------------------------------------
    def kv_stats(self) -> Dict:
        """KV-memory scorecard (``stats_dict()['kv']``): layout, pool
        utilization and the prefix-sharing/retention counters. Byte figures
        come from the real device leaves at construction time."""
        if self.kv_layout != "paged":
            return {"layout": "dense",
                    "kv_bytes_total": int(self._kv_bytes_total),
                    "kv_bytes_per_slot":
                        int(self._kv_bytes_total) // self.max_slots,
                    "prefilled_tokens": self.stats.prefilled_tokens,
                    "prefix_hit_tokens": 0}
        pool = self._pool
        return {"layout": "paged",
                "page_size": self.kv_page_size,
                "n_pages": pool.n_pages,
                "pages_used": pool.used_count,
                "pages_free": pool.free_count,
                "peak_pages_used": pool.peak_used,
                "bytes_per_page": pool.bytes_per_page,
                "kv_bytes_total": pool.total_bytes(),
                "kv_bytes_used": pool.used_bytes(),
                "utilization": round(pool.used_count / pool.n_pages, 4),
                "prefix_cached_pages": self._prefix_cache.cached_pages,
                "prefix_hit_tokens": self.stats.prefix_hit_tokens,
                "prefilled_tokens": self.stats.prefilled_tokens,
                "page_resumes": self.stats.page_resumes}

    def stats_dict(self) -> Dict:
        """``EngineStats.as_dict()`` plus the ``'kv'`` section, the
        ``'quant'`` section when the servable carries quantized packs
        (pack bytes, compression ratio vs fp32, worst quantization
        error), and, after a watchdog stall, the ``'watchdog'`` snapshot
        of queue/active/chunk state taken at detection time -- last
        stall wins."""
        d = self.stats.as_dict()
        d["kv"] = self.kv_stats()
        qs = getattr(self.servable, "quant_stats", lambda: None)()
        if qs:
            d["quant"] = qs
        if self._watchdog_snapshot is not None:
            d["watchdog"] = dict(self._watchdog_snapshot)
        return d

    @property
    def n_active(self) -> int:
        return len(self._active)

    @property
    def n_queued(self) -> int:
        return len(self._queue)

    @property
    def n_free(self) -> int:
        return len(self._free)
