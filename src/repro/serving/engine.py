"""Continuous-batching serving engine: request slots over one batched decode.

The model layer's decode path takes a ragged ``pos: (B,)`` vector (one
absolute position per batch row, -1 = inactive; models/api.py), which turns
the batch dimension into *request slots*. This module adds the request-level
machinery on top:

  * an **admission queue** -- ``submit()`` enqueues requests; each ``step()``
    admits as many as there are free slots;
  * **prefill-into-cache** -- an admitted prompt runs ONE forward pass on a
    batch-1 cache (``models.api.prefill_cache``: the full prompt streams the
    weights once, with bulk KV/recurrent-state writes; audio scans the
    decode path instead, its prompts being BOS-sized). Prompt lengths are
    padded to power-of-two *buckets* so the per-bucket jit executables stay
    warm -- padding tokens leave no trace in the cache -- and the result is
    inserted into the engine cache with ``write_slot``;
  * **fused decode windows** -- with ``sync_every = K > 1`` each ``step()``
    runs up to K decode steps inside ONE jitted ``lax.scan``
    (``models.api.decode_many``): sampling (greedy or temperature/top-k,
    PRNG keys threaded on device), per-slot EOS/stop handling and position
    bookkeeping all stay on device, and the host syncs once per window to
    drain emitted tokens, fire callbacks, recycle finished slots and admit
    queued requests. This removes the per-token host dispatch that
    dominated the per-step loop (docs/PERF.md); ``sync_every=1`` (or
    ``collect_logits=True``, which needs per-step logits on host) keeps
    the one-decode-per-step loop;
  * **one jitted batched decode (window) per step** over all ``max_slots``
    rows -- mixed-progress requests share the call via per-slot
    causal/window masks; the engine cache is donated, so decode is
    copy-free;
  * **slot lifecycle** -- completion fires the request's callbacks and
    ``free_slot``-zeroes the slot (attention KV *and* SSM/RgLRU recurrent
    state), so a recycled slot cannot leak its previous request. Slots
    that finish mid-window become device-side no-ops until the sync point
    recycles them.

Construct via :meth:`repro.serving.Servable.engine`::

    engine = servable.engine(max_slots=16, cache_len=512, sync_every=8)
    h = engine.submit([1, 2, 3], max_new_tokens=32,
                      on_token=lambda rid, tok: print(rid, tok))
    engine.run()                      # drain queue + active slots
    print(h.tokens)                   # greedy continuation

Sampling is configured per engine (``temperature`` / ``top_k`` / ``seed``);
the PRNG key is folded by (slot, position), so fused and per-step decoding
emit identical tokens for the same seed (models/sampling.py).

Known batching caveat: MoE layers route over the whole batch with a
capacity limit, so token drops can depend on which slots are co-resident --
for MoE configs the engine is still correct serving-wise but not bitwise
equal to sequential decode (all other families are; tests/test_engine.py).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api as model_api
from repro.models.sampling import sample_token_row

__all__ = ["EngineRequest", "EngineStats", "ServingEngine"]


@dataclasses.dataclass
class EngineRequest:
    """One submitted request; doubles as the caller's result handle."""

    req_id: int
    prompt: np.ndarray                      # (L,) int32
    max_new_tokens: int
    eos_id: Optional[int] = None
    frames: Optional[np.ndarray] = None     # audio family: encoder input
    on_token: Optional[Callable[[int, int], None]] = None
    on_done: Optional[Callable[[int, List[int]], None]] = None

    # engine-owned state
    slot: int = -1
    pos: int = -1                           # next decode position
    tokens: List[int] = dataclasses.field(default_factory=list)
    step_logits: List[np.ndarray] = dataclasses.field(default_factory=list)
    done: bool = False

    @property
    def n_generated(self) -> int:
        return len(self.tokens)


@dataclasses.dataclass
class EngineStats:
    steps: int = 0                  # decode steps (fused windows count K)
    windows: int = 0                # device dispatches (fused or per-step)
    prefills: int = 0
    tokens_generated: int = 0
    occupancy_sum: int = 0          # sum over steps of active slots
    completed: int = 0
    bucket_hits: Dict[int, int] = dataclasses.field(
        default_factory=lambda: collections.defaultdict(int))
    # wall-clock breakdown of the serving loop (seconds): prompt prefill
    # (compute + slot insert), decode windows (device call until outputs
    # materialize on host), and host-side sync work (token drain,
    # callbacks, slot recycling) -- benchmarks/serving_bench.py reports it
    prefill_s: float = 0.0
    decode_s: float = 0.0
    sync_s: float = 0.0

    @property
    def mean_occupancy(self) -> float:
        return self.occupancy_sum / self.steps if self.steps else 0.0

    def as_dict(self) -> Dict:
        return {"steps": self.steps, "windows": self.windows,
                "prefills": self.prefills,
                "tokens_generated": self.tokens_generated,
                "completed": self.completed,
                "mean_occupancy": round(self.mean_occupancy, 3),
                "prefill_buckets": dict(self.bucket_hits),
                "prefill_s": round(self.prefill_s, 4),
                "decode_s": round(self.decode_s, 4),
                "sync_s": round(self.sync_s, 4)}


class ServingEngine:
    """Slot-addressable continuous-batching engine over a Servable.

    ``max_slots`` bounds request concurrency (the static batch of the one
    jitted decode executable); ``cache_len`` bounds prompt + generation
    length per slot (windowed/recurrent layers keep their own tighter
    state bounds). ``sync_every = K`` fuses up to K decode steps into one
    on-device window between host syncs (``collect_logits`` forces K = 1:
    per-step logits only exist on host in the unfused loop).
    """

    def __init__(self, servable, max_slots: int = 8, cache_len: int = 256,
                 *, min_bucket: int = 8, collect_logits: bool = False,
                 sync_every: int = 8, temperature: float = 0.0,
                 top_k: int = 0, seed: int = 0):
        if servable.cfg.family == "bert":
            raise ValueError("encoder-only arch has no decode step")
        self.servable = servable
        self.cfg = servable.cfg
        self.max_slots = int(max_slots)
        self.cache_len = int(cache_len)
        # floor of 2: a length-1 "prefill" would hit the single-token decode
        # path (s == 1), which expects a pos argument
        self.min_bucket = max(2, int(min_bucket))
        self.collect_logits = collect_logits
        self.sync_every = 1 if collect_logits else max(1, int(sync_every))
        self.temperature = float(temperature)
        self.top_k = int(top_k)
        self._key = jax.random.PRNGKey(int(seed))
        self.stats = EngineStats()
        self.mesh = servable.mesh               # None = single-device path

        self._sub_template = None
        if self.cfg.family == "audio":
            # structure-only cache: encode batch-1 zero frames and broadcast
            # the slot axis (axis 1; every leaf is layer-stacked) -- the real
            # cross K/V arrives per request via write_slot at admission
            one = model_api.init_cache(
                servable.params, self.cfg, 1, self.cache_len,
                frames=jnp.zeros((1, self.cfg.n_audio_ctx, self.cfg.d_model),
                                 self.cfg.jdtype))
            self.cache = jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(
                    x, x.shape[:1] + (self.max_slots,) + x.shape[2:]), one)
        else:
            self.cache = model_api.init_cache(servable.params, self.cfg,
                                              self.max_slots, self.cache_len)
            # single-request cache template reused by every prefill (the
            # prefill is functional; audio rebuilds per request from frames)
            self._sub_template = model_api.init_cache(
                servable.params, self.cfg, 1, self.cache_len)

        if self.mesh is not None:
            # mesh-first cache: slots over "data", heads/state over "model".
            # Lifecycle ops below are pinned to these shardings, so alloc/
            # free/reset/write never regather the cache (tested:
            # tests/test_sharded_serving.py)
            self.cache = model_api.shard_cache(self.cache, self.cfg,
                                               self.mesh)
            if self._sub_template is not None:
                from repro.launch.sharding import replicated
                self._sub_template = jax.device_put(
                    self._sub_template, replicated(self.mesh))

        self._tokens = np.zeros((self.max_slots, 1), np.int32)
        self._pos = np.full((self.max_slots,), -1, np.int32)
        self._remaining = np.zeros((self.max_slots,), np.int32)
        self._eos = np.full((self.max_slots,), -1, np.int32)
        self._free: List[int] = list(range(self.max_slots))
        self._active: Dict[int, EngineRequest] = {}
        self._queue: "collections.deque[EngineRequest]" = collections.deque()
        # completed since the last run() drain -- the engine does NOT
        # retain request history beyond that (a long-lived engine would
        # otherwise hold every prompt/generation ever served); callers
        # keep their own handles
        self._done: List[EngineRequest] = []
        self._next_id = 0

        # jitted functions are owned by the Servable and shared across its
        # engines: one decode executable per max_slots shape (and per fused
        # window length K), one prefill trace per bucket length, warm for
        # the engine's whole lifetime (and the next engine's). The decode
        # cache argument is donated, so the hot loop never copies the slot
        # caches.
        # under a mesh, every jit the cache flows through pins its output
        # to the engine cache's placement: decode windows, insertion and
        # retirement then keep ONE canonical sharded layout end to end --
        # donation stays usable (no per-step copies) and the cache never
        # gathers to one device (let alone host) across a request's
        # lifetime. engine_fns shares executables across engines in both
        # modes (per cache-sharding tree under a mesh).
        out_sh = None if self.mesh is None else \
            jax.tree_util.tree_map(lambda x: x.sharding, self.cache)
        (self._decode, self._decode_many, self._write_slot,
         self._free_slot) = servable.engine_fns(out_sh)
        self._prefill = servable._engine_prefill_fn()

    # -- submission -------------------------------------------------------
    def submit(self, prompt: Sequence[int], max_new_tokens: int = 16, *,
               eos_id: Optional[int] = None, frames=None,
               on_token: Optional[Callable[[int, int], None]] = None,
               on_done: Optional[Callable[[int, List[int]], None]] = None
               ) -> EngineRequest:
        """Enqueue a request; returns its handle (``.tokens`` fills as the
        engine runs, ``.done`` flips on completion)."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError("empty prompt")
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1 (the prefill "
                             "already samples the first token)")
        if prompt.size + max_new_tokens > self.cache_len:
            raise ValueError(
                f"prompt ({prompt.size}) + max_new_tokens ({max_new_tokens}) "
                f"exceeds cache_len ({self.cache_len})")
        if self.cfg.family == "audio" and frames is None:
            raise ValueError("audio requests need encoder frames")
        req = EngineRequest(req_id=self._next_id, prompt=prompt,
                            max_new_tokens=int(max_new_tokens), eos_id=eos_id,
                            frames=frames, on_token=on_token, on_done=on_done)
        self._next_id += 1
        self._queue.append(req)
        return req

    # -- prefill ----------------------------------------------------------
    def _bucket(self, length: int) -> int:
        b = max(self.min_bucket, 1 << (length - 1).bit_length())
        return min(b, self.cache_len)

    def _admit(self, req: EngineRequest) -> None:
        t0 = time.perf_counter()
        slot = self._free.pop(0)
        length = int(req.prompt.size)
        bucket = self._bucket(length)
        self.stats.prefills += 1
        self.stats.bucket_hits[bucket] += 1

        if self.cfg.family == "audio":
            sub = model_api.init_cache(
                self.servable.params, self.cfg, 1, self.cache_len,
                frames=jnp.asarray(req.frames)[None]
                if np.ndim(req.frames) == 2 else jnp.asarray(req.frames))
        else:
            sub = self._sub_template
        toks = np.zeros((bucket,), np.int32)
        toks[:length] = req.prompt
        pos_seq = np.full((bucket,), -1, np.int32)
        pos_seq[:length] = np.arange(length)
        sub, logits = self._prefill(self.servable.params, sub,
                                    jnp.asarray(toks), jnp.asarray(pos_seq),
                                    jnp.int32(length))
        self.cache = self._write_slot(self.cache, jnp.int32(slot), sub)

        req.slot, req.pos = slot, length
        self._active[slot] = req
        self._eos[slot] = -1 if req.eos_id is None else int(req.eos_id)
        row = np.asarray(logits[length - 1])    # once per admission: fine
        tok = sample_token_row(row, self._key, slot, length - 1,
                               temperature=self.temperature,
                               top_k=self.top_k)
        self.stats.prefill_s += time.perf_counter() - t0
        self._emit(req, int(tok), row)

    # -- stepping ---------------------------------------------------------
    def _emit(self, req: EngineRequest, tok: int, logits_row=None) -> None:
        """Record one sampled token and retire the request if it just
        completed. ``logits_row`` (V,) is only materialized on host when
        the engine collects logits."""
        req.tokens.append(tok)
        if self.collect_logits and logits_row is not None:
            req.step_logits.append(np.asarray(logits_row, np.float32))
        self.stats.tokens_generated += 1
        if req.on_token is not None:
            req.on_token(req.req_id, tok)
        if (req.n_generated >= req.max_new_tokens
                or (req.eos_id is not None and tok == req.eos_id)):
            self._finish(req)
        else:
            self._tokens[req.slot, 0] = tok
            self._pos[req.slot] = req.pos
            self._remaining[req.slot] = req.max_new_tokens - req.n_generated

    def _finish(self, req: EngineRequest) -> None:
        slot = req.slot
        req.done = True
        self.stats.completed += 1
        # zero attention KV and recurrent state: recycled slots start fresh
        self.cache = self._free_slot(self.cache, jnp.int32(slot))
        self._pos[slot] = -1
        self._tokens[slot, 0] = 0
        self._remaining[slot] = 0
        self._eos[slot] = -1
        del self._active[slot]
        self._free.append(slot)
        self._free.sort()
        req.slot = -1
        self._done.append(req)
        if req.on_done is not None:
            req.on_done(req.req_id, list(req.tokens))

    def step(self) -> bool:
        """Admit what fits, then run ONE batched decode window (up to
        ``sync_every`` fused steps) over all active slots. Returns True
        while there is (or may be) work left."""
        while self._free and self._queue:
            self._admit(self._queue.popleft())
        if not self._active:
            return bool(self._queue)
        k = min(self.sync_every,
                max(int(self._remaining[s]) for s in self._active))
        if k <= 1:
            self._step_single()
        else:
            self._step_fused(k)
        return bool(self._active or self._queue)

    def _step_single(self) -> None:
        """The unfused loop: one decode, one host sync per token. Kept for
        ``sync_every=1`` and ``collect_logits`` (per-step logits only exist
        on host here)."""
        t0 = time.perf_counter()
        self.stats.steps += 1
        self.stats.windows += 1
        self.stats.occupancy_sum += len(self._active)
        next_tok, logits, self.cache = self._decode(
            self.servable.params, self.cache, jnp.asarray(self._tokens),
            jnp.asarray(self._pos), self._key, self.temperature, self.top_k)
        toks = np.asarray(next_tok)             # (max_slots,) int32 only
        rows = np.asarray(logits[:, 0, :]) if self.collect_logits else None
        self.stats.decode_s += time.perf_counter() - t0
        t0 = time.perf_counter()
        for slot in sorted(self._active):
            req = self._active[slot]
            req.pos += 1
            self._emit(req, int(toks[slot]),
                       rows[slot] if rows is not None else None)
        self.stats.sync_s += time.perf_counter() - t0

    def _step_fused(self, k: int) -> None:
        """The fused hot loop: K decode steps inside one jitted scan
        (sampling, EOS and position bookkeeping on device), then ONE host
        sync that drains the emitted tokens, fires callbacks in step order
        and recycles finished slots. ``k`` never exceeds the largest
        remaining budget, so a window cannot overshoot ``max_new_tokens``;
        slots that hit EOS (or their budget) mid-window deactivate
        themselves on device and ride along as no-ops until the sync."""
        t0 = time.perf_counter()
        self.stats.steps += k
        self.stats.windows += 1
        toks, valid, state = self._decode_many(
            self.servable.params, self.cache, jnp.asarray(self._tokens),
            jnp.asarray(self._pos), jnp.asarray(self._remaining),
            jnp.asarray(self._eos), self._key, k, self.temperature,
            self.top_k)
        self.cache = state["cache"]
        toks_h = np.asarray(toks)               # (K, B) int32
        valid_h = np.asarray(valid)             # (K, B) bool
        # writable host mirrors (np.asarray of a jax array is read-only)
        self._tokens = np.array(state["token"], np.int32)
        self._pos = np.array(state["pos"], np.int32)
        self._remaining = np.array(state["remaining"], np.int32)
        self.stats.decode_s += time.perf_counter() - t0

        t0 = time.perf_counter()
        self.stats.occupancy_sum += int(valid_h.sum())
        window = sorted(self._active)
        for step in range(k):
            for slot in window:
                if not valid_h[step, slot]:
                    continue
                req = self._active[slot]
                req.pos += 1
                tok = int(toks_h[step, slot])
                req.tokens.append(tok)
                self.stats.tokens_generated += 1
                if req.on_token is not None:
                    req.on_token(req.req_id, tok)
        for slot in window:
            req = self._active[slot]
            if self._pos[slot] < 0:             # device marked it finished
                # _finish re-zeroes the host mirrors; cache hygiene via
                # free_slot as in the per-step path
                self._finish(req)
        self.stats.sync_s += time.perf_counter() - t0

    def run(self, max_steps: Optional[int] = None) -> List[EngineRequest]:
        """Drain the queue and all active slots; returns the requests that
        completed since the last drain, in submission order, and releases
        them from engine tracking (callers keep their handles -- the
        engine itself retains no request history, so a long-lived engine's
        memory is bounded by its live requests)."""
        steps = 0
        while self.step():
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break
        done, self._done = self._done, []
        return sorted(done, key=lambda r: r.req_id)

    # -- introspection ----------------------------------------------------
    @property
    def n_active(self) -> int:
        return len(self._active)

    @property
    def n_queued(self) -> int:
        return len(self._queue)
