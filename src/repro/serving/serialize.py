"""(De)serialization helpers for Servable artifacts.

Three JSON/npz-safe codecs used by ``Servable.save`` / ``load_servable``
(serving/servable.py), layered on top of ``checkpoint/store.py``:

  * **tree spec** -- a JSON description of a param pytree's structure with
    per-leaf dtypes, so a ``like`` tree can be rebuilt at load time and
    handed to ``CheckpointStore.restore`` (which only needs structure +
    dtype, not values);
  * **pack codec** -- RowPackPlan / KernelBSR static patterns flattened into
    npz arrays + JSON meta, deduplicated by pattern fingerprint so the
    cross-layer-union sharing (12 layer scopes -> 1 plan object) survives a
    round-trip and the loaded servable keeps one specialization per group;
  * **config codec** -- ModelConfig (with nested LayerKind / SparsityConfig)
    to plain dicts and back.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax.numpy as jnp
import numpy as np

from repro.configs.base import LayerKind, ModelConfig
from repro.core.pattern_reuse import PatternRegistry
from repro.core.sparsity import SparsityConfig
from repro.kernels.autotune import BackendChoice, MaskedPack
from repro.kernels.bsr_matmul import KernelBSR
from repro.kernels.exec_plan import (PlanChoice, QuantPlan, RowPackPlan,
                                     ShardedPlan,
                                     kernel_pattern_fingerprint)

_PLAN_FIELDS = ("col_idx", "slot_mask", "row_of_vrow", "vrow", "slot")
_BSR_FIELDS = ("row_id", "col_id", "t_perm")


class ServableLoadError(RuntimeError):
    """A Servable artifact failed to load: missing, truncated or corrupt
    metadata / pack archive. The message names the offending piece (the
    archive member = "leaf" when one is identifiable), so a bad artifact
    reads as "leaf 'p0_col_idx' is unreadable", not a zlib traceback."""


class LeafReader:
    """Mapping shim over an ``np.load`` NpzFile that converts per-member
    failures into :class:`ServableLoadError` naming the offending leaf.

    npz members decompress lazily, so a truncated or bit-flipped
    ``packs.npz`` loads fine and only fails when a specific member is
    read -- deep inside the pack codec. Routing every read through this
    shim pins the error to the artifact and leaf instead."""

    def __init__(self, npz, path: str):
        self._npz = npz
        self._path = path

    def __getitem__(self, name: str) -> np.ndarray:
        try:
            return self._npz[name]
        except KeyError:
            raise ServableLoadError(
                f"pack archive {self._path} is missing leaf {name!r} "
                f"(truncated or incompatible artifact)") from None
        except Exception as e:  # zlib.error / BadZipFile / ValueError ...
            raise ServableLoadError(
                f"pack archive {self._path}: leaf {name!r} is unreadable "
                f"({type(e).__name__}: {e})") from e

    def __contains__(self, name: str) -> bool:
        return name in self._npz


def pattern_key(pack) -> bytes:
    """Fingerprint of a static pattern, uniform across the pack kinds
    (plan / bsr / autotuned choice / masked) -- the dedupe key here and the
    uniqueness key of ``Servable.stats()``. Choice/masked packs embed the
    backend in their fingerprint, so the same pattern pinned to two
    different backends is (correctly) two keys."""
    if isinstance(pack, (RowPackPlan, PlanChoice, QuantPlan, BackendChoice,
                         MaskedPack)):
        return pack.fingerprint
    return kernel_pattern_fingerprint(pack)


# --------------------------------------------------------------------------
# tree spec
# --------------------------------------------------------------------------

def tree_spec(tree) -> dict:
    """JSON-safe structure descriptor of a pytree of arrays (dict / tuple /
    list containers, array or None leaves)."""
    if isinstance(tree, dict):
        return {"kind": "dict",
                "items": {str(k): tree_spec(v) for k, v in tree.items()}}
    if isinstance(tree, (tuple, list)):
        return {"kind": "tuple" if isinstance(tree, tuple) else "list",
                "items": [tree_spec(v) for v in tree]}
    if tree is None:
        return {"kind": "none"}
    return {"kind": "leaf", "dtype": str(jnp.asarray(tree).dtype)}


def build_like(spec: dict):
    """Rebuild a placeholder tree from :func:`tree_spec` output -- same
    structure, scalar zero leaves carrying the recorded dtype (all
    ``CheckpointStore.restore`` consults)."""
    kind = spec["kind"]
    if kind == "dict":
        return {k: build_like(v) for k, v in spec["items"].items()}
    if kind in ("tuple", "list"):
        items = [build_like(v) for v in spec["items"]]
        return tuple(items) if kind == "tuple" else items
    if kind == "none":
        return None
    return np.zeros((), np.dtype(spec["dtype"]))


# --------------------------------------------------------------------------
# pack codec
# --------------------------------------------------------------------------

def packs_to_arrays(packs: Dict[str, object]) -> Tuple[dict, dict]:
    """-> (npz arrays, JSON meta). Unique patterns stored once (fingerprint
    dedupe); ``meta['keys']`` fans each layer scope back out to its ref."""
    arrays: Dict[str, np.ndarray] = {}
    metas: List[dict] = []
    index_of: Dict[bytes, int] = {}
    keys = []
    for key, pk in packs.items():
        fp = pattern_key(pk)
        idx = index_of.get(fp)
        if idx is None:
            idx = len(metas)
            index_of[fp] = idx
            arrays[f"p{idx}_fingerprint"] = np.frombuffer(fp, np.uint8)
            if isinstance(pk, QuantPlan):
                # quantized wrapper: quant meta + the inner plan's fields
                # and fingerprint (registry-shared with any unquantized
                # packs of the same pattern). ``codec: 1`` versions the
                # quant entry itself; files written before this kind
                # existed simply never contain it, so they load unchanged.
                plan = pk.plan
                m = {"kind": "quant_plan", "codec": 1,
                     "qdtype": pk.qdtype, "granularity": pk.granularity,
                     "backend": pk.backend, "shape": list(plan.shape),
                     "tile": list(plan.tile), "nnzt": plan.nnzt,
                     "real_nnzt": plan.real_nnzt,
                     "sharded": isinstance(plan, ShardedPlan)}
                arrays[f"p{idx}_plan_fingerprint"] = np.frombuffer(
                    plan.fingerprint, np.uint8)
                for f in _PLAN_FIELDS:
                    arrays[f"p{idx}_{f}"] = np.asarray(getattr(plan, f))
                if isinstance(plan, ShardedPlan):
                    m["n_shards"] = plan.n_shards
                    m["shard_axis"] = plan.shard_axis
                    sfps = list(plan.shard_fingerprints)
                    arrays[f"p{idx}_shard_fp_lens"] = np.array(
                        [len(s) for s in sfps], np.int64)
                    arrays[f"p{idx}_shard_fps"] = np.frombuffer(
                        b"".join(sfps), np.uint8)
                metas.append(m)
            elif isinstance(pk, ShardedPlan):
                # shard-partitioned plan: plan fields + shard layout meta +
                # per-shard sub-pattern fingerprints (the registry/autotune
                # keys survive the round-trip; the mesh itself does NOT --
                # load_servable rebuilds it from the spec)
                metas.append({"kind": "sharded_plan",
                              "shape": list(pk.shape),
                              "tile": list(pk.tile), "nnzt": pk.nnzt,
                              "real_nnzt": pk.real_nnzt,
                              "n_shards": pk.n_shards,
                              "shard_axis": pk.shard_axis})
                for f in _PLAN_FIELDS:
                    arrays[f"p{idx}_{f}"] = np.asarray(getattr(pk, f))
                sfps = list(pk.shard_fingerprints)
                arrays[f"p{idx}_shard_fp_lens"] = np.array(
                    [len(s) for s in sfps], np.int64)
                arrays[f"p{idx}_shard_fps"] = np.frombuffer(
                    b"".join(sfps), np.uint8)
            elif isinstance(pk, RowPackPlan):
                metas.append({"kind": "plan", "shape": list(pk.shape),
                              "tile": list(pk.tile), "nnzt": pk.nnzt,
                              "real_nnzt": pk.real_nnzt})
                for f in _PLAN_FIELDS:
                    arrays[f"p{idx}_{f}"] = np.asarray(getattr(pk, f))
            elif isinstance(pk, PlanChoice):
                # plan fields + the pinned backend; the inner plan's own
                # fingerprint is stored too so the registry-cached rebuild
                # shares the plan with any bare-'plan' packs of the same
                # pattern
                plan = pk.plan
                metas.append({"kind": "plan_choice", "backend": pk.backend,
                              "shape": list(plan.shape),
                              "tile": list(plan.tile), "nnzt": plan.nnzt,
                              "real_nnzt": plan.real_nnzt})
                arrays[f"p{idx}_plan_fingerprint"] = np.frombuffer(
                    plan.fingerprint, np.uint8)
                for f in _PLAN_FIELDS:
                    arrays[f"p{idx}_{f}"] = np.asarray(getattr(plan, f))
            elif isinstance(pk, MaskedPack):
                metas.append({"kind": "masked", "shape": list(pk.shape),
                              "tile": list(pk.tile)})
                arrays[f"p{idx}_tile_mask"] = np.asarray(pk.tile_mask, bool)
            elif isinstance(pk, BackendChoice):
                inner = pk.pack
                metas.append({"kind": "choice", "backend": pk.backend,
                              "shape": list(inner.shape),
                              "tile": list(inner.tile),
                              "real_nnzt": inner.real_nnzt})
                for f in _BSR_FIELDS:
                    arrays[f"p{idx}_{f}"] = np.asarray(getattr(inner, f))
            else:
                # structural fields only: serving rebuilds KernelBSR around
                # the values held in the params tree (models/common.linear),
                # so pk.data is never read back -- storing it would duplicate
                # every packed weight in the artifact
                metas.append({"kind": "bsr", "shape": list(pk.shape),
                              "tile": list(pk.tile),
                              "real_nnzt": pk.real_nnzt})
                for f in _BSR_FIELDS:
                    arrays[f"p{idx}_{f}"] = np.asarray(getattr(pk, f))
        keys.append({"key": key, "ref": idx})
    return arrays, {"patterns": metas, "keys": keys}


def packs_from_arrays(meta: dict, arrays, registry: PatternRegistry = None
                      ) -> Dict[str, object]:
    """Inverse of :func:`packs_to_arrays`. Plans are rebuilt through the
    registry's fingerprint-keyed cache so the loaded servable shares one
    object (and downstream one jit specialization) per unique pattern."""
    built = []
    for idx, m in enumerate(meta["patterns"]):
        fp = bytes(np.asarray(arrays[f"p{idx}_fingerprint"], np.uint8))
        if m["kind"] == "quant_plan":
            plan_fp = bytes(np.asarray(arrays[f"p{idx}_plan_fingerprint"],
                                       np.uint8))

            def build_inner(idx=idx, m=m, plan_fp=plan_fp):
                fields = dict(
                    col_idx=np.asarray(arrays[f"p{idx}_col_idx"], np.int32),
                    slot_mask=np.asarray(arrays[f"p{idx}_slot_mask"], bool),
                    row_of_vrow=np.asarray(arrays[f"p{idx}_row_of_vrow"],
                                           np.int32),
                    vrow=np.asarray(arrays[f"p{idx}_vrow"], np.int32),
                    slot=np.asarray(arrays[f"p{idx}_slot"], np.int32),
                    shape=tuple(m["shape"]), tile=tuple(m["tile"]),
                    nnzt=int(m["nnzt"]), real_nnzt=int(m["real_nnzt"]),
                    fingerprint=plan_fp)
                if not m.get("sharded"):
                    return RowPackPlan(**fields)
                lens = np.asarray(arrays[f"p{idx}_shard_fp_lens"], np.int64)
                blob = bytes(np.asarray(arrays[f"p{idx}_shard_fps"],
                                        np.uint8))
                offs = np.concatenate([[0], np.cumsum(lens)])
                sfps = tuple(blob[offs[i]: offs[i + 1]]
                             for i in range(len(lens)))
                return ShardedPlan(**fields, n_shards=int(m["n_shards"]),
                                   shard_axis=m["shard_axis"],
                                   shard_fingerprints=sfps)
            cache_key = (("sharded_plan_codec", plan_fp) if m.get("sharded")
                         else ("rowpack_plan", plan_fp))
            plan = (registry.cached(cache_key, build_inner)
                    if registry is not None else build_inner())
            built.append(QuantPlan(plan, qdtype=m["qdtype"],
                                   granularity=m["granularity"],
                                   backend=m["backend"]))
        elif m["kind"] == "sharded_plan":
            def build_sharded(idx=idx, m=m, fp=fp):
                lens = np.asarray(arrays[f"p{idx}_shard_fp_lens"], np.int64)
                blob = bytes(np.asarray(arrays[f"p{idx}_shard_fps"],
                                        np.uint8))
                offs = np.concatenate([[0], np.cumsum(lens)])
                sfps = tuple(blob[offs[i]: offs[i + 1]]
                             for i in range(len(lens)))
                return ShardedPlan(
                    col_idx=np.asarray(arrays[f"p{idx}_col_idx"], np.int32),
                    slot_mask=np.asarray(arrays[f"p{idx}_slot_mask"], bool),
                    row_of_vrow=np.asarray(arrays[f"p{idx}_row_of_vrow"],
                                           np.int32),
                    vrow=np.asarray(arrays[f"p{idx}_vrow"], np.int32),
                    slot=np.asarray(arrays[f"p{idx}_slot"], np.int32),
                    shape=tuple(m["shape"]), tile=tuple(m["tile"]),
                    nnzt=int(m["nnzt"]), real_nnzt=int(m["real_nnzt"]),
                    fingerprint=fp, n_shards=int(m["n_shards"]),
                    shard_axis=m["shard_axis"], shard_fingerprints=sfps)
            if registry is not None:
                built.append(registry.cached(("sharded_plan_codec", fp),
                                             build_sharded))
            else:
                built.append(build_sharded())
        elif m["kind"] == "plan":
            def build(idx=idx, m=m, fp=fp):
                return RowPackPlan(
                    col_idx=np.asarray(arrays[f"p{idx}_col_idx"], np.int32),
                    slot_mask=np.asarray(arrays[f"p{idx}_slot_mask"], bool),
                    row_of_vrow=np.asarray(arrays[f"p{idx}_row_of_vrow"],
                                           np.int32),
                    vrow=np.asarray(arrays[f"p{idx}_vrow"], np.int32),
                    slot=np.asarray(arrays[f"p{idx}_slot"], np.int32),
                    shape=tuple(m["shape"]), tile=tuple(m["tile"]),
                    nnzt=int(m["nnzt"]), real_nnzt=int(m["real_nnzt"]),
                    fingerprint=fp)
            if registry is not None:
                built.append(registry.cached(("rowpack_plan", fp), build))
            else:
                built.append(build())
        elif m["kind"] == "plan_choice":
            plan_fp = bytes(np.asarray(arrays[f"p{idx}_plan_fingerprint"],
                                       np.uint8))
            def build_plan_obj(idx=idx, m=m, plan_fp=plan_fp):
                return RowPackPlan(
                    col_idx=np.asarray(arrays[f"p{idx}_col_idx"], np.int32),
                    slot_mask=np.asarray(arrays[f"p{idx}_slot_mask"], bool),
                    row_of_vrow=np.asarray(arrays[f"p{idx}_row_of_vrow"],
                                           np.int32),
                    vrow=np.asarray(arrays[f"p{idx}_vrow"], np.int32),
                    slot=np.asarray(arrays[f"p{idx}_slot"], np.int32),
                    shape=tuple(m["shape"]), tile=tuple(m["tile"]),
                    nnzt=int(m["nnzt"]), real_nnzt=int(m["real_nnzt"]),
                    fingerprint=plan_fp)
            if registry is not None:
                plan = registry.cached(("rowpack_plan", plan_fp),
                                       build_plan_obj)
            else:
                plan = build_plan_obj()
            built.append(PlanChoice(plan, m["backend"]))
        elif m["kind"] == "masked":
            built.append(MaskedPack(
                tile_mask=np.asarray(arrays[f"p{idx}_tile_mask"], bool),
                shape=tuple(m["shape"]), tile=tuple(m["tile"])))
        else:
            col_id = np.asarray(arrays[f"p{idx}_col_id"], np.int32)
            bn, bk = (int(t) for t in m["tile"])
            bsr = KernelBSR(
                # zeros placeholder: serve-time data comes from the params
                # tree, never from the pack (models/common.linear)
                data=jnp.zeros((len(col_id), bn, bk), jnp.float32),
                row_id=np.asarray(arrays[f"p{idx}_row_id"], np.int32),
                col_id=col_id,
                t_perm=np.asarray(arrays[f"p{idx}_t_perm"], np.int32),
                real_nnzt=int(m["real_nnzt"]), shape=tuple(m["shape"]),
                tile=(bn, bk))
            built.append(BackendChoice(bsr, m["backend"])
                         if m["kind"] == "choice" else bsr)
    return {e["key"]: built[e["ref"]] for e in meta["keys"]}


# --------------------------------------------------------------------------
# config codec
# --------------------------------------------------------------------------

def config_to_dict(cfg: ModelConfig) -> dict:
    return dataclasses.asdict(cfg)


def config_from_dict(d: dict) -> ModelConfig:
    d = dict(d)
    d["pattern"] = tuple(LayerKind(**k) for k in d.get("pattern", ()))
    d["prefix"] = tuple(LayerKind(**k) for k in d.get("prefix", ()))
    if d.get("sparsity"):
        sp = dict(d["sparsity"])
        sp["block_shape"] = tuple(sp["block_shape"])
        sp["targets"] = tuple(sp["targets"])
        d["sparsity"] = SparsityConfig(**sp)
    return ModelConfig(**d)
