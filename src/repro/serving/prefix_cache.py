"""Radix prefix cache over page-granular token chunks (ISSUE 7 tentpole).

Admissions whose prompts share a token prefix (system prompts, few-shot
headers) should reuse the pages already holding that prefix instead of
re-prefilling and re-storing it. The cache is a radix tree whose edges are
``page_size``-token tuples: node depth d holds the physical page storing
prompt tokens [d*ps, (d+1)*ps). Only *immutable* pages are ever inserted --
full pages strictly inside the prompt -- so sharing is copy-on-write by
construction: decode writes always land at positions >= prompt length, which
live in pages the sharer allocated privately. No page copy ever happens.

The tree holds its own reference on every inserted page (via the pool), so
a cached prefix survives its original request's retirement; ``evict`` drops
least-recently-used leaves when the pool runs dry, which only forfeits
future hits -- active slots keep their own references.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple


class _Node:
    __slots__ = ("children", "page", "stamp")

    def __init__(self):
        self.children: Dict[Tuple[int, ...], _Node] = {}
        self.page: Optional[int] = None     # physical page id (root: None)
        self.stamp = 0                      # LRU clock at last touch


class PrefixCache:
    """Radix tree mapping page-aligned token prefixes to physical pages.

    The cache cooperates with a :class:`repro.serving.paging.PagePool`:
    ``insert`` retains inserted pages (the tree's own reference), ``match``
    retains matched pages on behalf of the caller (the new request's
    reference), and ``evict``/``clear`` release the tree's references.
    """

    def __init__(self, pool, page_size: int):
        self.pool = pool
        self.page_size = page_size
        self._root = _Node()
        self._clock = 0
        # stats
        self.hit_tokens = 0
        self.miss_tokens = 0
        self.inserted_pages = 0
        self.evicted_pages = 0

    # -- internals --------------------------------------------------------

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def _chunks(self, tokens: Sequence[int]):
        ps = self.page_size
        for i in range(0, len(tokens) - len(tokens) % ps, ps):
            yield tuple(int(t) for t in tokens[i:i + ps])

    # -- queries ----------------------------------------------------------

    def match(self, tokens: Sequence[int], limit: Optional[int] = None
              ) -> List[int]:
        """Longest page-aligned cached prefix of ``tokens``.

        Returns the physical pages holding it (possibly empty) with ONE
        reference per page retained for the caller -- the caller owns
        releasing them (normally folded into the slot's page list).
        ``limit`` caps the match length in tokens; the engine passes
        ``len(prompt) - 1`` so a hit still leaves >= 1 suffix token to
        prefill (the model needs at least one forward position to produce
        next-token logits).
        """
        cap = len(tokens) if limit is None else min(limit, len(tokens))
        stamp = self._tick()
        node, pages = self._root, []
        for chunk in self._chunks(tokens[:cap]):
            nxt = node.children.get(chunk)
            if nxt is None:
                break
            nxt.stamp = stamp
            pages.append(nxt.page)
            node = nxt
        self.hit_tokens += len(pages) * self.page_size
        self.miss_tokens += len(tokens) - len(pages) * self.page_size
        if pages:
            self.pool.retain(pages)
        return pages

    # -- lifecycle --------------------------------------------------------

    def insert(self, tokens: Sequence[int], pages: Sequence[int]) -> int:
        """Publish the full pages of a freshly-prefilled prompt: chunk k of
        ``tokens`` is stored in ``pages[k]``. Only complete chunks are
        walked (a trailing partial page is mutable -- never shared). New
        nodes retain their page; existing nodes are refreshed, not retained
        again. Returns the number of newly published pages."""
        stamp = self._tick()
        node, new = self._root, 0
        for k, chunk in enumerate(self._chunks(tokens)):
            if k >= len(pages):
                break
            nxt = node.children.get(chunk)
            if nxt is None:
                nxt = _Node()
                nxt.page = int(pages[k])
                node.children[chunk] = nxt
                self.pool.retain([nxt.page])
                new += 1
            nxt.stamp = stamp
            node = nxt
        self.inserted_pages += new
        return new

    def evict(self, n_pages: int) -> int:
        """Release up to ``n_pages`` tree references, least-recently-used
        leaves first (leaves only: an inner node's page is a prefix of a
        live cached path). Returns pages actually released -- note a
        released reference frees HBM only when no active slot still holds
        the page."""
        dropped = 0
        while dropped < n_pages:
            leaf = self._lru_leaf()
            if leaf is None:
                break
            parent, key, node = leaf
            del parent.children[key]
            self.pool.release([node.page])
            dropped += 1
        self.evicted_pages += dropped
        return dropped

    def _lru_leaf(self):
        best = None
        stack = [self._root]
        while stack:
            node = stack.pop()
            for key, child in node.children.items():
                if child.children:
                    stack.append(child)
                elif best is None or child.stamp < best[2].stamp:
                    best = (node, key, child)
        return best

    def clear(self) -> None:
        """Release every tree reference (engine recovery path)."""
        stack = [self._root]
        while stack:
            node = stack.pop()
            for child in node.children.values():
                self.pool.release([child.page])
                stack.append(child)
        self._root = _Node()

    @property
    def cached_pages(self) -> int:
        n, stack = 0, [self._root]
        while stack:
            node = stack.pop()
            n += len(node.children)
            stack.extend(node.children.values())
        return n
