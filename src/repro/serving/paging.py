"""Page-pool allocator for the paged KV cache (ISSUE 7 tentpole).

The device side (models/common.py paged_* primitives) only understands two
things: per-layer page pools whose axis 0 is *physical page ids*, and int32
page tables mapping each request slot's logical pages to those ids. This
module owns everything else -- which ids are free, which are shared, and how
many bytes the pool pins -- entirely on the host, in plain Python, so the
engine can make admission decisions without a device sync.

Design notes:
  - ONE logical id space serves every layer: each layer has its own pool
    arrays (k_pages/v_pages or c_kv_pages/k_rope_pages), but page id ``p``
    means row ``p`` in all of them. The allocator therefore tracks ids once,
    not per layer.
  - Refcounts, not ownership: the prefix cache (prefix_cache.py) retains
    pages for future sharers and preemption retains a victim's pages across
    slot loss. A page returns to the free list only when its count hits 0.
  - Deterministic: ``alloc`` hands out the lowest free ids (a heap) so runs
    are reproducible and tests can assert exact tables.
"""
from __future__ import annotations

import heapq
from typing import Dict, List


class PagePoolExhausted(RuntimeError):
    """Raised by :meth:`PagePool.alloc` when the request cannot be satisfied;
    the engine translates this into its backpressure policy (evict prefix
    pages -> park the admission -> shed) instead of crashing."""

    def __init__(self, want: int, free: int):
        super().__init__(f"page pool exhausted: want {want} pages, "
                         f"{free} free")
        self.want = want
        self.free = free


def pages_needed(n_tokens: int, page_size: int) -> int:
    """ceil(n_tokens / page_size); 0 tokens needs 0 pages."""
    return -(-max(n_tokens, 0) // page_size)


class PagePool:
    """Host-side free-list allocator with refcounts over ``n_pages`` physical
    pages of ``page_size`` tokens each.

    ``bytes_per_page`` is the summed on-device footprint of one page id
    across every paged layer (so ``used_bytes()`` is real HBM, not a
    per-layer slice); pass 0 if accounting is not needed.
    """

    def __init__(self, n_pages: int, page_size: int, bytes_per_page: int = 0):
        if n_pages < 1 or page_size < 1:
            raise ValueError("n_pages and page_size must be >= 1")
        self.n_pages = n_pages
        self.page_size = page_size
        self.bytes_per_page = bytes_per_page
        self._free: List[int] = list(range(n_pages))
        heapq.heapify(self._free)
        self._refs: Dict[int, int] = {}
        # high-water mark of pages simultaneously in use (bench reporting)
        self.peak_used = 0

    # -- queries ----------------------------------------------------------

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def used_count(self) -> int:
        return self.n_pages - len(self._free)

    def refcount(self, page: int) -> int:
        return self._refs.get(page, 0)

    def used_bytes(self) -> int:
        return self.used_count * self.bytes_per_page

    def total_bytes(self) -> int:
        return self.n_pages * self.bytes_per_page

    # -- lifecycle --------------------------------------------------------

    def alloc(self, n: int) -> List[int]:
        """Claim ``n`` pages (refcount 1 each), lowest ids first. Raises
        :class:`PagePoolExhausted` without side effects if short."""
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > len(self._free):
            raise PagePoolExhausted(n, len(self._free))
        out = [heapq.heappop(self._free) for _ in range(n)]
        for p in out:
            self._refs[p] = 1
        self.peak_used = max(self.peak_used, self.used_count)
        return out

    def retain(self, pages) -> None:
        """Add one reference to each page (sharing / retention)."""
        for p in pages:
            if p not in self._refs:
                raise ValueError(f"retain of free page {p}")
            self._refs[p] += 1

    def release(self, pages) -> None:
        """Drop one reference from each page; pages reaching 0 return to the
        free list. Double-release raises (refcount bugs must be loud)."""
        for p in pages:
            c = self._refs.get(p, 0)
            if c <= 0:
                raise ValueError(f"release of free page {p}")
            if c == 1:
                del self._refs[p]
                heapq.heappush(self._free, p)
            else:
                self._refs[p] = c - 1

    def reset(self) -> None:
        """Forget everything (engine window-failure recovery: the device
        cache is re-initialized, so host bookkeeping restarts too)."""
        self._free = list(range(self.n_pages))
        heapq.heapify(self._free)
        self._refs.clear()

    def check(self) -> None:
        """Invariant sweep: free + referenced partitions [0, n_pages)."""
        free = set(self._free)
        held = set(self._refs)
        if free & held:
            raise AssertionError(f"pages both free and held: {free & held}")
        if len(free) + len(held) != self.n_pages:
            raise AssertionError(
                f"page accounting leak: {len(free)} free + {len(held)} held "
                f"!= {self.n_pages}")
        if any(c <= 0 for c in self._refs.values()):
            raise AssertionError("non-positive refcount")
