"""Gemma-3 4B [hf:google/gemma-3-1b-pt family; unverified tier].

34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144; 5 local (window
1024) : 1 global interleave; embedding scaled by sqrt(d). long_500k is
SKIPPED for this arch (global layers are full attention).
"""
from repro.configs.base import LayerKind, ModelConfig

_PATTERN = tuple([LayerKind("local", "dense", window=1024)] * 5
                 + [LayerKind("attn", "dense")])


def full():
    return ModelConfig(
        arch="gemma3-4b", family="dense",
        n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4, head_dim=256,
        d_ff=10240, vocab_size=262144,
        pattern=_PATTERN, scale_embedding=True, tie_embeddings=True,
        act="geglu", rope_theta=1e6,
    )


def smoke():
    return ModelConfig(
        arch="gemma3-smoke", family="dense",
        n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512,
        pattern=tuple([LayerKind("local", "dense", window=32)] * 2
                      + [LayerKind("attn", "dense")]),
        scale_embedding=True, tie_embeddings=True, act="geglu",
        dtype="float32", q_chunk=64, kv_chunk=64,
    )
