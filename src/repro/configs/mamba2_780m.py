"""Mamba2 780M [arXiv:2405.21060; unverified tier]. Attention-free SSD.

48L d_model=1536, ssm_state=128, expand=2, head_dim=64, vocab=50280.
long_500k RUNS for this arch (O(1) decode state).
"""
from repro.configs.base import LayerKind, ModelConfig


def full():
    return ModelConfig(
        arch="mamba2-780m", family="ssm",
        n_layers=48, d_model=1536, n_heads=0, n_kv_heads=0, head_dim=0,
        d_ff=0, vocab_size=50280,
        ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_chunk=128,
        pattern=(LayerKind("ssm", "none"),), tie_embeddings=True,
    )


def smoke():
    return ModelConfig(
        arch="mamba2-smoke", family="ssm",
        n_layers=2, d_model=64, n_heads=0, n_kv_heads=0, head_dim=0,
        d_ff=0, vocab_size=512,
        ssm_state=16, ssm_expand=2, ssm_head_dim=16, ssm_chunk=32,
        pattern=(LayerKind("ssm", "none"),), tie_embeddings=True,
        dtype="float32",
    )
