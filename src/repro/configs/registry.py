"""Architecture registry: ``get_config(arch, smoke=False)``.

Each arch module exposes ``full()`` (the assigned published config) and
``smoke()`` (a reduced same-family config for CPU tests).
"""
from __future__ import annotations

import importlib

ARCHS = (
    "qwen3_moe_235b_a22b",
    "deepseek_v2_lite_16b",
    "gemma3_4b",
    "internlm2_20b",
    "deepseek_7b",
    "chatglm3_6b",
    "whisper_base",
    "mamba2_780m",
    "recurrentgemma_9b",
    "pixtral_12b",
    "bert_base",            # the paper's own model (not in the 40-cell grid)
)

ASSIGNED = ARCHS[:10]


def canon(arch: str) -> str:
    return arch.replace("-", "_")


def get_config(arch: str, smoke: bool = False):
    name = canon(arch)
    if name not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCHS}")
    mod = importlib.import_module(f"repro.configs.{name}")
    return mod.smoke() if smoke else mod.full()
