"""Qwen3-MoE 235B-A22B [hf:Qwen/Qwen3-30B-A3B family; hf-verified].

94L d_model=4096 64H (GQA kv=4) expert d_ff=1536 vocab=151936,
128 experts top-8, QK-RMSNorm, no shared experts, untied head.
"""
from repro.configs.base import LayerKind, ModelConfig


def full():
    return ModelConfig(
        arch="qwen3-moe-235b-a22b", family="moe",
        n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, head_dim=128,
        d_ff=1536, d_ff_expert=1536, vocab_size=151936,
        n_experts=128, top_k=8, qk_norm=True, rope_theta=1e6,
        pattern=(LayerKind("attn", "moe"),),
    )


def smoke():
    return ModelConfig(
        arch="qwen3-moe-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=96, d_ff_expert=96, vocab_size=512,
        n_experts=8, top_k=2, qk_norm=True,
        pattern=(LayerKind("attn", "moe"),), dtype="float32",
        q_chunk=64, kv_chunk=64,
    )
