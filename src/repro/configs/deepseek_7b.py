"""DeepSeek-LLM 7B [arXiv:2401.02954; hf-verified]. Llama-arch MHA.

30L d_model=4096 32H (kv=32) d_ff=11008 vocab=102400.
"""
from repro.configs.base import LayerKind, ModelConfig


def full():
    return ModelConfig(
        arch="deepseek-7b", family="dense",
        n_layers=30, d_model=4096, n_heads=32, n_kv_heads=32, head_dim=128,
        d_ff=11008, vocab_size=102400,
        pattern=(LayerKind("attn", "dense"),),
    )


def smoke():
    return ModelConfig(
        arch="deepseek-7b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=512, pattern=(LayerKind("attn", "dense"),),
        dtype="float32", q_chunk=64, kv_chunk=64,
    )
