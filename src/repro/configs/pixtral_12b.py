"""Pixtral 12B [hf:mistralai/Pixtral-12B-2409; unverified tier].

Mistral-Nemo-style decoder: 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072. The Pixtral-ViT frontend is STUBBED: input_specs feeds
(B, n_patches=256, d_model) patch embeddings merged into the prefix slots.
"""
from repro.configs.base import LayerKind, ModelConfig


def full():
    return ModelConfig(
        arch="pixtral-12b", family="vlm",
        n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=14336, vocab_size=131072, n_patches=256, rope_theta=1e6,
        pattern=(LayerKind("attn", "dense"),),
    )


def smoke():
    return ModelConfig(
        arch="pixtral-smoke", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, n_patches=8,
        pattern=(LayerKind("attn", "dense"),), dtype="float32",
        q_chunk=64, kv_chunk=64,
    )
