"""DeepSeek-V2-Lite 16B [arXiv:2405.04434; hf-verified].

27L d_model=2048 16H, MLA kv_lora=512 (nope 128 / rope 64 / v 128),
expert d_ff=1408, 64 routed top-6 + 2 shared experts, first layer dense FFN.
(The assignment line lists both "64e" and "160 routed"; we follow the real
V2-Lite: 64 routed + 2 shared -- noted in DESIGN.md.)
"""
from repro.configs.base import LayerKind, ModelConfig


def full():
    return ModelConfig(
        arch="deepseek-v2-lite-16b", family="moe",
        n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
        d_ff=10944,                      # dense first layer (real V2-Lite)
        d_ff_expert=1408, vocab_size=102400,
        n_experts=64, n_shared_experts=2, top_k=6,
        kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
        prefix=(LayerKind("mla", "dense"),),
        pattern=(LayerKind("mla", "moe"),),
    )


def smoke():
    return ModelConfig(
        arch="deepseek-v2-lite-smoke", family="moe",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, d_ff_expert=48, vocab_size=512,
        n_experts=8, n_shared_experts=1, top_k=2,
        kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
        prefix=(LayerKind("mla", "dense"),),
        pattern=(LayerKind("mla", "moe"),), dtype="float32",
        q_chunk=64, kv_chunk=64,
    )
