"""BERT_BASE -- the paper's own pruning target (L=12, H=768, A=12, 110M).

Not part of the 40-cell assigned grid; used by the paper-validation
benchmarks (Table 1 / Table 2 analogues) and the sparse-serving example.
"""
from repro.configs.base import LayerKind, ModelConfig


def full():
    return ModelConfig(
        arch="bert-base", family="bert",
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
        d_ff=3072, vocab_size=30522, norm="ln", act="gelu",
        rotary_fraction=0.0,  # learned absolute positions
        pattern=(LayerKind("attn", "dense"),), dtype="float32",
    )


def smoke():
    return ModelConfig(
        arch="bert-smoke", family="bert",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=512, norm="ln", act="gelu",
        rotary_fraction=0.0,
        pattern=(LayerKind("attn", "dense"),), dtype="float32",
    )
