from repro.configs.base import SHAPES, LayerKind, ModelConfig, ShapeSpec
from repro.configs.registry import ARCHS, ASSIGNED, get_config
