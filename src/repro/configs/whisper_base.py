"""Whisper-base [arXiv:2212.04356; unverified tier]. Enc-dec audio backbone.

6L enc + 6L dec, d_model=512 8H d_ff=2048 vocab=51865; conv frontend STUBBED
(input_specs feeds (B, 1500, d) frame embeddings). Decoder uses RoPE instead
of learned positions (adaptation for 32k-decode stress cells; DESIGN.md).
"""
from repro.configs.base import LayerKind, ModelConfig


def full():
    return ModelConfig(
        arch="whisper-base", family="audio",
        n_layers=6, n_enc_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
        head_dim=64, d_ff=2048, vocab_size=51865, n_audio_ctx=1500,
        norm="ln", act="gelu", pattern=(LayerKind("attn", "dense"),),
    )


def smoke():
    return ModelConfig(
        arch="whisper-smoke", family="audio",
        n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=128, vocab_size=512, n_audio_ctx=64,
        norm="ln", act="gelu", pattern=(LayerKind("attn", "dense"),),
        dtype="float32", q_chunk=64, kv_chunk=64,
    )
