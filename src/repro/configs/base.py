"""Model/run configuration schema shared by all architectures.

A config fully determines parameter shapes, the layer plan (how heterogeneous
layer stacks are decomposed into a scannable repeating pattern + unrolled
prefix/suffix), and the input specs for every assigned input shape.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

from repro.core.sparsity import SparsityConfig

# Mixer kinds: 'attn' (global), 'local' (windowed), 'mla', 'ssm', 'rglru'
# FFN kinds:   'dense', 'moe', 'none'
@dataclasses.dataclass(frozen=True)
class LayerKind:
    mixer: str = "attn"
    ffn: str = "dense"
    window: int = 0          # 0 = global attention; >0 = local window


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch: str
    family: str                      # dense|moe|ssm|hybrid|audio|vlm|bert
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128

    # layer heterogeneity: the per-layer kinds, cycled; overridden per arch
    pattern: Tuple[LayerKind, ...] = (LayerKind(),)
    prefix: Tuple[LayerKind, ...] = ()     # unrolled leading layers

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # MLA (DeepSeek-V2)
    kv_lora_rank: int = 0
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128

    # SSM (Mamba2 SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    conv_width: int = 4

    # RG-LRU (RecurrentGemma)
    rnn_width: int = 0

    # attention details
    rope_theta: float = 10000.0
    rotary_fraction: float = 1.0    # chatglm "2d" rope = 0.5
    qk_norm: bool = False           # qwen3
    scale_embedding: bool = False   # gemma
    attn_logit_softcap: float = 0.0
    tie_embeddings: bool = False

    # enc-dec (whisper)
    n_enc_layers: int = 0
    n_audio_ctx: int = 0

    # vlm (pixtral)
    n_patches: int = 0

    # norms / activation
    norm: str = "rms"               # rms|ln
    act: str = "swiglu"             # swiglu|gelu|geglu
    # numeric
    dtype: str = "bfloat16"
    # paper technique
    sparsity: Optional[SparsityConfig] = None
    # flash-attention chunking
    q_chunk: int = 1024
    kv_chunk: int = 1024
    # decode KV cache quantization (int8 + per-(slot,head) scales): halves
    # cache HBM residency -- the capacity fix for few-kv-head GQA archs at
    # batch 128 x 32k (DESIGN.md §8, EXPERIMENTS.md §Perf iter 5)
    kv_cache_quant: bool = False

    # ------------------------------------------------------------------
    @property
    def jdtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def layer_plan(self):
        """(prefix, pattern, n_periods, suffix): layers = prefix
        + n_periods * pattern + suffix, with the middle lax.scan'ed."""
        body = self.n_layers - len(self.prefix)
        n_periods, rem = divmod(body, len(self.pattern))
        suffix = self.pattern[:rem]
        return self.prefix, self.pattern, n_periods, suffix

    def supports_long_context(self) -> bool:
        """True iff no layer kind requires global full attention
        (=> 500k decode has bounded per-step state)."""
        kinds = self.prefix + self.pattern
        return all(k.mixer in ("ssm", "rglru") or
                   (k.mixer in ("attn", "local") and k.window > 0)
                   for k in kinds)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}
