"""InternLM2 20B [arXiv:2403.17297; hf-verified].

48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92544, SwiGLU.
"""
from repro.configs.base import LayerKind, ModelConfig


def full():
    return ModelConfig(
        arch="internlm2-20b", family="dense",
        n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
        d_ff=16384, vocab_size=92544, rope_theta=1e6,
        pattern=(LayerKind("attn", "dense"),),
    )


def smoke():
    return ModelConfig(
        arch="internlm2-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, pattern=(LayerKind("attn", "dense"),),
        dtype="float32", q_chunk=64, kv_chunk=64,
    )
