"""ChatGLM3 6B [arXiv:2406.12793; hf-verified].

28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024; 2d-RoPE realized as
partial rotary over half the head dim (rotary_fraction=0.5).
"""
from repro.configs.base import LayerKind, ModelConfig


def full():
    return ModelConfig(
        arch="chatglm3-6b", family="dense",
        n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2, head_dim=128,
        d_ff=13696, vocab_size=65024, rotary_fraction=0.5,
        pattern=(LayerKind("attn", "dense"),),
    )


def smoke():
    return ModelConfig(
        arch="chatglm3-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, rotary_fraction=0.5,
        pattern=(LayerKind("attn", "dense"),), dtype="float32",
        q_chunk=64, kv_chunk=64,
    )
