"""RecurrentGemma 9B [arXiv:2402.19427; unverified tier]. Griffin hybrid.

38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000; repeating
(RG-LRU, RG-LRU, local-attn window 2048) blocks; rnn_width=4096.
long_500k RUNS (bounded window + recurrent state).
"""
from repro.configs.base import LayerKind, ModelConfig

_PATTERN = (LayerKind("rglru", "dense"), LayerKind("rglru", "dense"),
            LayerKind("local", "dense", window=2048))


def full():
    return ModelConfig(
        arch="recurrentgemma-9b", family="hybrid",
        n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, head_dim=256,
        d_ff=12288, vocab_size=256000, rnn_width=4096,
        pattern=_PATTERN, scale_embedding=True, tie_embeddings=True,
        act="geglu",
    )


def smoke():
    return ModelConfig(
        arch="recurrentgemma-smoke", family="hybrid",
        n_layers=6, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
        d_ff=128, vocab_size=512, rnn_width=64,
        pattern=(LayerKind("rglru", "dense"), LayerKind("rglru", "dense"),
                 LayerKind("local", "dense", window=32)),
        scale_embedding=True, tie_embeddings=True, act="geglu",
        dtype="float32", q_chunk=64, kv_chunk=64,
    )
