"""Fault-tolerant training runtime: restore-on-failure, straggler monitoring,
elastic re-meshing.

The train driver wraps every step in the supervisor; on a device/runtime
failure (XlaRuntimeError, injected faults in tests) it restores the latest
checkpoint and replays from there. Because the data pipeline is stateless in
(seed, step), replay is exactly-once w.r.t. the optimizer trajectory.

Straggler mitigation: per-host step-time EWMA; hosts slower than
``threshold``x the fleet median get flagged, and the grad-accumulation
rebalancer shifts microbatches away from them (simulated timers in tests; on
real fleets the timings come from the per-host profiler).
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable, Dict, Optional

import numpy as np

log = logging.getLogger("repro.runtime")


@dataclasses.dataclass
class FaultToleranceConfig:
    max_restarts: int = 5
    checkpoint_every: int = 50
    straggler_threshold: float = 1.5
    straggler_ewma: float = 0.9


class StragglerMonitor:
    """Tracks per-host step-time EWMAs and proposes microbatch rebalancing."""

    def __init__(self, n_hosts: int, cfg: FaultToleranceConfig):
        self.cfg = cfg
        self.ewma = np.zeros(n_hosts)
        self.seen = np.zeros(n_hosts, bool)

    def observe(self, host_times: Dict[int, float]):
        a = self.cfg.straggler_ewma
        for h, t in host_times.items():
            self.ewma[h] = t if not self.seen[h] else a * self.ewma[h] + (1 - a) * t
            self.seen[h] = True

    def stragglers(self):
        if not self.seen.any():
            return []
        med = np.median(self.ewma[self.seen])
        return [int(h) for h in np.nonzero(
            self.seen & (self.ewma > self.cfg.straggler_threshold * med))[0]]

    def rebalance(self, microbatches_per_host: np.ndarray) -> np.ndarray:
        """Shift one microbatch from each straggler to the fastest host,
        preserving the global batch (deterministic given timings)."""
        mb = microbatches_per_host.copy()
        slow = self.stragglers()
        if not slow or not self.seen.any():
            return mb
        order = np.argsort(self.ewma)
        for s in slow:
            if mb[s] > 1:
                fastest = next(int(h) for h in order if h != s)
                mb[s] -= 1
                mb[fastest] += 1
        return mb


class Supervisor:
    """run() drives step_fn with restore-on-failure semantics."""

    def __init__(self, cfg: FaultToleranceConfig, store, save_state_fn,
                 restore_state_fn):
        self.cfg = cfg
        self.store = store
        self.save_state = save_state_fn
        self.restore_state = restore_state_fn
        self.restarts = 0

    def run(self, state, start_step: int, n_steps: int,
            step_fn: Callable, on_step: Optional[Callable] = None):
        step = start_step
        while step < start_step + n_steps:
            try:
                state, metrics = step_fn(state, step)
                if on_step:
                    on_step(step, metrics)
                step += 1
                if step % self.cfg.checkpoint_every == 0:
                    self.save_state(self.store, step, state)
            except Exception as e:  # noqa: BLE001 -- device loss is generic
                self.restarts += 1
                log.warning("step %d failed (%s); restart %d/%d",
                            step, type(e).__name__, self.restarts,
                            self.cfg.max_restarts)
                if self.restarts > self.cfg.max_restarts:
                    raise
                # drain any in-flight async save before reading the directory:
                # without this, a failure shortly after a checkpoint step races
                # the background writer's atomic rename and restore sees a
                # stale (or empty) step list -- the flake seen under full-suite
                # load, where the writer thread lags the train loop.
                wait = getattr(self.store, "wait", None)
                if wait is not None:
                    wait()
                latest = self.store.latest_step()
                if latest is None:
                    raise
                state = self.restore_state(self.store, latest, state)
                step = latest
        return state, step


# Deterministic failure injection now lives in the shared chaos registry
# (repro/runtime/chaos.py) alongside the serving-engine hook points and the
# watchdog; re-exported here for the train driver and existing importers.
from repro.runtime.chaos import FaultInjector  # noqa: E402,F401
