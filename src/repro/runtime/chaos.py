"""Shared fault-injection registry + watchdog for runtime robustness tests.

Production traffic brings failure modes the happy-path benches never see:
prefill blow-ups, NaN-poisoned numerics, allocation failures, stragglers,
corrupt artifacts. This module is the ONE place those faults are injected
from, so every layer (training supervisor, serving engine, servable
loader) exercises its failure path against the same deterministic
machinery:

  * :class:`ChaosInjector` -- a registry of named *sites*. Code under test
    calls ``chaos.fire(site, **ctx)`` at its hook points; tests arm faults
    with ``chaos.inject(site, at=N, exc=...)`` (raise into the caller) or
    ``action=fn`` (mutate state through the ctx -- e.g. NaN-poison an
    engine slot, sleep to fake a straggler). Unarmed sites are free:
    ``fire`` on a site with no faults is a dict lookup + counter bump.
  * serving hook points (``repro/serving/engine.py``):
      - ``engine.alloc``   -- slot allocation at admission
      - ``engine.prefill`` -- prompt prefill of an admitted request
      - ``engine.window``  -- before each batched decode window (ctx
        carries the engine: poison a slot here to test NaN quarantine)
      - ``engine.sync``    -- host-side sync after a window (sleep here to
        fake a straggler and trip the watchdog)
      - ``engine.arrival_burst`` -- inside submit(), before enqueue (an
        action may recursively submit a burst; raise sheds the submission)
      - ``engine.prefill_chunk`` -- before each chunked-prefill dispatch
        (raise fails that request; sleep fakes a straggling chunk)
    and ``servable.load_packs`` (``repro/serving/servable.py``) -- fired
    with the pack-archive path before it is read, so a fault can corrupt
    the bytes a load is about to trust.
  * :class:`Watchdog` -- wall-clock stall detector for device calls the
    host cannot interrupt: ``arm()`` before a dispatch, ``disarm()`` after;
    a background thread records a stall event (and fires an optional
    callback) when an armed section exceeds its timeout. Detection-only by
    design -- a stuck XLA call cannot be cancelled, but a serving loop
    that *knows* it is stuck can be drained, alerted on, or killed by its
    supervisor.
  * :class:`FaultInjector` -- the train-loop step injector (previously in
    ``runtime/fault_tolerance.py``; re-exported there), kept as a thin
    shim over the same registry so train and serving faults share one
    accounting surface.

Everything here is deterministic: faults fire on the Nth ``fire()`` of
their site, never on wall clocks or RNG, so chaos tests replay exactly
(tests/test_chaos.py asserts engine invariants after every fault class).
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Callable, Dict, List, Optional

__all__ = ["ChaosEvent", "ChaosInjector", "FaultInjector", "Watchdog",
           "poison_slot", "straggle",
           "SITE_ALLOC", "SITE_PREFILL", "SITE_WINDOW", "SITE_SYNC",
           "SITE_PAGE_ALLOC", "SITE_LOAD_PACKS", "SITE_TRAIN_STEP",
           "SITE_ARRIVAL_BURST", "SITE_PREFILL_CHUNK"]

#: serving-engine hook points (repro/serving/engine.py)
SITE_ALLOC = "engine.alloc"
SITE_PREFILL = "engine.prefill"
SITE_WINDOW = "engine.window"
SITE_SYNC = "engine.sync"
#: open-loop ingest hook: fires inside submit() after validation, before the
#: request is enqueued (ctx: engine, request). An action may submit a burst
#: of extra requests through the same engine (re-entrant: the nested
#: submits re-fire this site); 'raise' sheds THIS submission with a
#: structured failure, never a crash
SITE_ARRIVAL_BURST = "engine.arrival_burst"
#: chunked-prefill hook: fires before each prefill chunk dispatch (ctx:
#: engine, request, start, size). 'raise' fails the request with
#: FailureReason.PREFILL_ERROR and releases its slot; straggle() here fakes
#: a slow chunk so the watchdog's prefill-chunk label trips
SITE_PREFILL_CHUNK = "engine.prefill_chunk"
#: paged-KV page allocation (fires before each admission's page reservation;
#: 'raise' simulates pool exhaustion -> backpressure, never a crash)
SITE_PAGE_ALLOC = "engine.page_alloc"
#: servable-loader hook point (repro/serving/servable.py)
SITE_LOAD_PACKS = "servable.load_packs"
#: train-loop hook point (FaultInjector shim)
SITE_TRAIN_STEP = "train.step"


@dataclasses.dataclass
class ChaosEvent:
    """One fault firing, recorded on ``ChaosInjector.log``."""

    site: str
    occurrence: int             # the site's fire() count when it fired
    kind: str                   # 'raise' | 'action'


class _Fault:
    """One armed fault: fires on hits ``at .. at+times-1`` of its site."""

    def __init__(self, site: str, at: int, times: int,
                 exc: Optional[BaseException],
                 action: Optional[Callable[[dict], None]]):
        if exc is None and action is None:
            raise ValueError("fault needs exc= or action=")
        self.site, self.at, self.times = site, int(at), int(times)
        self.exc, self.action = exc, action
        self.fired = 0

    def should_fire(self, n: int) -> bool:
        return self.at <= n < self.at + self.times


class ChaosInjector:
    """Deterministic, site-keyed fault registry (module docstring).

    ``inject(site, at=N)`` arms a fault for the Nth ``fire(site)`` (1-based;
    ``times=K`` keeps it armed for K consecutive hits). ``exc=`` raises the
    exception into the firing code path; ``action=`` calls ``fn(ctx)`` with
    the keyword context the hook point passed to ``fire`` (both together
    run the action first, then raise). Every firing is appended to ``log``
    for test assertions.
    """

    def __init__(self):
        self._counts: Dict[str, int] = collections.Counter()
        self._faults: Dict[str, List[_Fault]] = collections.defaultdict(list)
        self.log: List[ChaosEvent] = []

    def inject(self, site: str, *, at: int = 1, times: int = 1,
               exc: Optional[BaseException] = None,
               action: Optional[Callable[[dict], None]] = None) -> "_Fault":
        fault = _Fault(site, at, times, exc, action)
        self._faults[site].append(fault)
        return fault

    def fire(self, site: str, **ctx) -> None:
        """Hook point: count this hit of ``site`` and trigger any armed
        fault. Actions run (and may mutate state through ``ctx``) before an
        exception is raised into the caller."""
        self._counts[site] += 1
        n = self._counts[site]
        for fault in self._faults.get(site, ()):
            if fault.should_fire(n):
                fault.fired += 1
                self.log.append(ChaosEvent(
                    site, n, "raise" if fault.exc is not None else "action"))
                if fault.action is not None:
                    fault.action(ctx)
                if fault.exc is not None:
                    raise fault.exc

    def count(self, site: str) -> int:
        """How many times ``site`` has fired (armed or not)."""
        return self._counts.get(site, 0)

    def fired(self, site: Optional[str] = None) -> int:
        """How many faults actually triggered (optionally per site)."""
        return sum(1 for e in self.log if site is None or e.site == site)


# --------------------------------------------------------------------------
# canned actions for the serving hook points
# --------------------------------------------------------------------------

def poison_slot(slot: Optional[int] = None) -> Callable[[dict], None]:
    """Action for ``engine.window``: NaN-fill one active slot's cache
    (``slot=None`` = the lowest-numbered active slot), so that slot's next
    decode logits go non-finite and the engine's quarantine path runs."""

    def action(ctx: dict) -> None:
        eng = ctx["engine"]
        target = slot
        if target is None:
            if not eng._active:
                return
            target = min(eng._active)
        eng.corrupt_slot(target)
    return action


def straggle(seconds: float) -> Callable[[dict], None]:
    """Action for ``engine.sync``: stall the host loop -- an artificial
    straggler sync that a configured watchdog must detect."""

    def action(ctx: dict) -> None:
        time.sleep(seconds)
    return action


# --------------------------------------------------------------------------
# watchdog
# --------------------------------------------------------------------------

class Watchdog:
    """Background wall-clock monitor for host-uninterruptible sections.

    ``arm(label)`` starts a timed section, ``disarm()`` ends it (returning
    the elapsed seconds). A daemon thread polls the armed section; once it
    exceeds ``timeout_s`` a stall event ``(label, elapsed_at_detection)``
    is appended to ``stalls`` and ``on_stall(label, elapsed)`` fires --
    once per armed section, even if it stays stuck. ``close()`` stops the
    thread (idempotent; also called by ``__del__``)."""

    def __init__(self, timeout_s: float,
                 on_stall: Optional[Callable[[str, float], None]] = None,
                 poll_s: Optional[float] = None):
        self.timeout_s = float(timeout_s)
        self.on_stall = on_stall
        self.stalls: List[tuple] = []
        self._lock = threading.Lock()
        self._armed: Optional[list] = None      # [label, t0, fired]
        self._stop = threading.Event()
        self._poll = poll_s if poll_s is not None else \
            max(min(self.timeout_s / 4.0, 0.05), 0.001)
        self._thread = threading.Thread(target=self._watch, daemon=True,
                                        name="repro-watchdog")
        self._thread.start()

    def arm(self, label: str = "window") -> None:
        with self._lock:
            self._armed = [label, time.monotonic(), False]

    def disarm(self) -> float:
        with self._lock:
            if self._armed is None:
                return 0.0
            elapsed = time.monotonic() - self._armed[1]
            self._armed = None
            return elapsed

    def _watch(self) -> None:
        while not self._stop.wait(self._poll):
            cb = None
            with self._lock:
                if self._armed is not None and not self._armed[2]:
                    label, t0, _ = self._armed
                    elapsed = time.monotonic() - t0
                    if elapsed > self.timeout_s:
                        self._armed[2] = True
                        self.stalls.append((label, elapsed))
                        cb = (label, elapsed)
            if cb is not None and self.on_stall is not None:
                self.on_stall(*cb)

    def close(self) -> None:
        self._stop.set()

    def __del__(self):  # pragma: no cover - GC timing
        self.close()


# --------------------------------------------------------------------------
# the train-loop step injector (formerly runtime/fault_tolerance.py)
# --------------------------------------------------------------------------

class FaultInjector:
    """Deterministic train-step failure injection: ``maybe_fail(step)``
    raises once per step listed in ``fail_at_steps``. Historically lived in
    ``runtime/fault_tolerance.py`` (still re-exported there); now a shim
    over the shared registry so its firings land on the same ``log``."""

    def __init__(self, fail_at_steps=(), chaos: Optional[ChaosInjector] = None):
        self.fail_at = set(fail_at_steps)
        self.fired = set()
        self.chaos = chaos if chaos is not None else ChaosInjector()

    def maybe_fail(self, step: int):
        self.chaos.fire(SITE_TRAIN_STEP, step=step)
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            self.chaos.log.append(ChaosEvent(
                SITE_TRAIN_STEP, self.chaos.count(SITE_TRAIN_STEP), "raise"))
            raise RuntimeError(f"injected device failure at step {step}")
