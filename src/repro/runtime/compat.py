"""Version-portable wrappers for JAX APIs that moved between releases.

``shard_map`` graduated from ``jax.experimental.shard_map`` (where the
replication-check kwarg is ``check_rep``) to top-level ``jax.shard_map``
(where it is ``check_vma``). Callers here always use the modern spelling;
the wrapper translates for older installs so the repo runs unmodified on
both sides of the move.
"""
from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` with the modern signature on every supported JAX."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)
