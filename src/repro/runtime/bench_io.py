"""Machine-readable benchmark persistence (BENCH_kernels.json).

Benchmarks and serving demos merge their sections into one JSON file at the
repo root so successive PRs have a perf trajectory to compare against
(docs/PERF.md documents the schema). Sections are replaced wholesale by the
producer that owns them; unrelated sections are preserved.
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Dict


def repo_root() -> str:
    """Repo root inferred from this file's location (src/repro/runtime/..)."""
    return os.path.abspath(os.path.join(os.path.dirname(__file__),
                                        "..", "..", ".."))


def default_bench_path() -> str:
    return os.path.join(repo_root(), "BENCH_kernels.json")


def update_bench_json(section: str, payload: Any,
                      path: str | None = None) -> str:
    """Merge ``{section: payload}`` into the bench JSON file; returns path."""
    path = path or default_bench_path()
    data: Dict[str, Any] = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                loaded = json.load(f)
            if isinstance(loaded, dict):
                data = loaded
        except (json.JSONDecodeError, OSError):
            data = {}
    data[section] = payload
    meta = data.setdefault("meta", {})
    meta["updated"] = time.strftime("%Y-%m-%dT%H:%M:%S")
    meta.setdefault("schema", 1)
    with open(path, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
        f.write("\n")
    return path
