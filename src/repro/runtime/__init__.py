from repro.runtime.fault_tolerance import (FaultInjector, FaultToleranceConfig,
                                           StragglerMonitor, Supervisor)
