from repro.runtime.chaos import (ChaosEvent, ChaosInjector, Watchdog,
                                 poison_slot, straggle)
from repro.runtime.fault_tolerance import (FaultInjector, FaultToleranceConfig,
                                           StragglerMonitor, Supervisor)
