"""Roofline terms from compiled artifacts (no real hardware needed).

  compute     = HLO_FLOPs / (chips * peak)          [cost_analysis]
  memory      = HLO_bytes / (chips * hbm_bw)        [cost_analysis]
  collective  = sum(output bytes of all-gather/all-reduce/reduce-scatter/
                all-to-all/collective-permute) / (chips * link_bw)
                [parsed from compiled HLO text]

Conventions: cost_analysis flops/bytes on an SPMD module are per-partition
in recent jax (we multiply back to fleet totals where needed -- the ratios
reported divide out); collective volume counts each op's *output* tensor
bytes once per op (documented approximation; ring-algorithm factors (P-1)/P
are ~1 at P=256).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

# TPU v5e-class constants (assignment-specified)
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.:  %all-gather.5 = bf16[16,1024,512]{2,1,0} all-gather(...)
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^\s]*\s+(" +
    "|".join(_COLLECTIVES) + r")[\.( ]")
# tuple-result collectives:  = (f32[8,4]{...}, f32[8,4]{...}) all-reduce(
_TUPLE_RE = re.compile(
    r"=\s*\(([^)]+)\)\s+(" + "|".join(_COLLECTIVES) + r")[\.( ]")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind output bytes summed over the module."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if m:
            dtype, dims, kind = m.groups()
            out[kind] += _shape_bytes(dtype, dims)
            continue
        m = _TUPLE_RE.search(line)
        if m:
            parts, kind = m.groups()
            for sm in _SHAPE_RE.finditer(parts):
                out[kind] += _shape_bytes(*sm.groups())
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


@dataclasses.dataclass
class Roofline:
    flops: float                 # per-device HLO flops
    hbm_bytes: float             # per-device HLO bytes accessed
    coll_bytes: float            # per-device collective bytes
    n_devices: int
    model_flops: float = 0.0     # 6*N*D-style useful flops (fleet-wide)

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flop_ratio(self) -> float:
        total = self.flops * self.n_devices
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of peak sustained if the dominant term were the wall:
        useful_flops / (chips * peak * t_dominant)."""
        t = max(self.t_compute, self.t_memory, self.t_collective)
        if t <= 0:
            return 0.0
        return self.model_flops / (self.n_devices * PEAK_FLOPS * t)

    def to_dict(self) -> dict:
        return {
            "flops_per_dev": self.flops, "hbm_bytes_per_dev": self.hbm_bytes,
            "coll_bytes_per_dev": self.coll_bytes, "n_devices": self.n_devices,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective, "bottleneck": self.bottleneck,
            "useful_flop_ratio": self.useful_flop_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def count_params(shape_tree) -> int:
    import jax
    return sum(int(l.size) for l in jax.tree_util.tree_leaves(shape_tree)
               if hasattr(l, "size"))


def count_expert_params(shape_tree) -> int:
    """Routed-expert weights only. Expert leaves are raw (E, a, b) arrays
    named .../ffn/{wi,wg,wo}; DENSE mlp weights live one level deeper
    (.../ffn/wi/w) and must NOT be counted even when scan-stacking makes
    them 3-D."""
    import jax
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shape_tree)[0]:
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        if ("ffn" in name and not name.endswith("/w")
                and getattr(leaf, "ndim", 0) >= 3 and "shared" not in name):
            total += int(leaf.size)
    return total


def model_flops_estimate(cfg, shape, n_params: int, n_expert_params: int,
                         kind: str) -> float:
    """6*N_active*D for train, 2*N_active*D for inference-prefill,
    2*N_active*B per decoded token."""
    active = n_params - n_expert_params
    if cfg.n_experts:
        active += n_expert_params * (cfg.top_k + cfg.n_shared_experts) / cfg.n_experts
    # embedding rows aren't multiplied per token; subtract one embed table
    active -= cfg.vocab_size * cfg.d_model
    tokens = shape.global_batch * (1 if kind == "decode" else shape.seq_len)
    mult = 6.0 if kind == "train" else 2.0
    return mult * active * tokens
