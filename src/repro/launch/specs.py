"""ShapeDtypeStruct input specs for every (arch x shape) cell -- the dry-run's
stand-ins (weak-type-correct, shardable, zero allocation).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, ModelConfig, ShapeSpec
from repro.models import init_cache, init_model


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def batch_specs(cfg: ModelConfig, shape: ShapeSpec, with_labels: bool):
    b, s = shape.global_batch, shape.seq_len
    out = {"tokens": sds((b, s), jnp.int32)}
    if with_labels:
        out["labels"] = sds((b, s), jnp.int32)
    if cfg.family == "audio":
        out["frames"] = sds((b, cfg.n_audio_ctx, cfg.d_model), cfg.jdtype)
    if cfg.family == "vlm":
        out["mm_embeds"] = sds((b, cfg.n_patches, cfg.d_model), cfg.jdtype)
    return out


def params_specs(cfg: ModelConfig):
    return jax.eval_shape(lambda: init_model(jax.random.PRNGKey(0), cfg))


def cache_specs(cfg: ModelConfig, shape: ShapeSpec, params_shapes):
    b, s = shape.global_batch, shape.seq_len
    frames = (sds((b, cfg.n_audio_ctx, cfg.d_model), cfg.jdtype)
              if cfg.family == "audio" else None)
    return jax.eval_shape(
        lambda p, f: init_cache(p, cfg, b, s, frames=f),
        params_shapes, frames)


def input_specs(cfg: ModelConfig, shape_name: str):
    """Returns a dict describing every jit input for the cell's step fn."""
    shape = SHAPES[shape_name]
    p = params_specs(cfg)
    if shape.kind == "train":
        return {"params": p, "batch": batch_specs(cfg, shape, True)}
    if shape.kind == "prefill":
        return {"params": p, "batch": batch_specs(cfg, shape, False)}
    # decode
    b = shape.global_batch
    return {"params": p,
            "cache": cache_specs(cfg, shape, p),
            "token": sds((b, 1), jnp.int32),
            "pos": sds((), jnp.int32)}


def cell_is_supported(cfg: ModelConfig, shape_name: str):
    """(supported, reason). long_500k only for bounded-state archs; decode
    shapes skipped for encoder-only families."""
    shape = SHAPES[shape_name]
    if cfg.family == "bert" and shape.kind in ("decode",):
        return False, "encoder-only: no decode step"
    if shape_name == "long_500k" and not cfg.supports_long_context():
        return False, "full-attention arch: 500k ctx needs sub-quadratic attention"
    return True, ""
