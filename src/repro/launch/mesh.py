"""Production mesh construction (function, not constant: importing this module
never touches jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests, elastic re-meshing)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def dp_axes(mesh) -> tuple:
    """The data-parallel axes of a mesh ('pod' folds into DP)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def axis_size(mesh, names) -> int:
    s = 1
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for n in (names if isinstance(names, (tuple, list)) else (names,)):
        s *= sizes.get(n, 1)
    return s
