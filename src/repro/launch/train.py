"""Fault-tolerant training driver (example entry: examples/train_bert_sparse.py).

Composes: data pipeline -> pjit train_step (remat'd scan model) -> AdamW(+prox)
-> gradual block pruner -> async checkpointing -> Supervisor restart loop ->
straggler monitor. Single-process CPU here; the same code drives a TPU fleet
(device count and mesh shape come from the environment).

Optional distributed-optimization extras (flags):
  * grad_compression: block-sparse error-feedback DP all-reduce
    (optim/compression.py) via shard_map on the dp axes.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import CheckpointStore
from repro.configs.base import ModelConfig
from repro.core import pruner as pruner_mod
from repro.data.pipeline import DataConfig, DataPipeline
from repro.launch.sharding import (batch_shardings, opt_shardings,
                                   param_shardings, replicated)
from repro.launch.steps import make_train_step
from repro.models import init_model
from repro.optim.adamw import AdamWConfig, init_opt_state
from repro.runtime.fault_tolerance import (FaultInjector, FaultToleranceConfig,
                                           StragglerMonitor, Supervisor)

log = logging.getLogger("repro.train")


@dataclasses.dataclass
class TrainConfig:
    n_steps: int = 100
    ckpt_dir: str = "/tmp/repro_ckpt"
    seed: int = 0
    grad_accum: int = 1
    log_every: int = 10
    opt: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)
    ft: FaultToleranceConfig = dataclasses.field(
        default_factory=FaultToleranceConfig)
    prune: bool = False       # gradual block pruning during training


class Trainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainConfig, mesh,
                 data_cfg: Optional[DataConfig] = None,
                 fault_injector: Optional[FaultInjector] = None):
        self.cfg, self.tcfg, self.mesh = cfg, tcfg, mesh
        self.data_cfg = data_cfg or DataConfig(vocab_size=cfg.vocab_size)
        self.store = CheckpointStore(tcfg.ckpt_dir, keep=3)
        self.injector = fault_injector
        self.monitor = StragglerMonitor(self.data_cfg.n_hosts, tcfg.ft)

        with mesh:
            p_shapes = jax.eval_shape(
                lambda: init_model(jax.random.PRNGKey(tcfg.seed), cfg))
            self.p_sh = param_shardings(p_shapes, mesh)
            o_shapes = jax.eval_shape(
                lambda: init_opt_state(p_shapes, tcfg.opt))
            self.o_sh = opt_shardings(o_shapes, mesh)
            self.step_fn = jax.jit(
                make_train_step(cfg, tcfg.opt, tcfg.grad_accum),
                in_shardings=(self.p_sh, self.o_sh, None),
                out_shardings=(self.p_sh, self.o_sh, replicated(mesh)),
                donate_argnums=(0, 1))

    # -- state management --------------------------------------------------
    def init_state(self):
        with self.mesh:
            params = jax.jit(
                lambda: init_model(jax.random.PRNGKey(self.tcfg.seed),
                                   self.cfg),
                out_shardings=self.p_sh)()
            opt = jax.jit(lambda p: init_opt_state(p, self.tcfg.opt),
                          out_shardings=self.o_sh)(params)
        masks = (pruner_mod.init_masks(params, self.cfg.sparsity)
                 if self.tcfg.prune and self.cfg.sparsity else None)
        return {"params": params, "opt": opt, "masks": masks}

    @staticmethod
    def save_state(store, step, state):
        store.save(step, {"params": state["params"], "opt": state["opt"],
                          "masks": state["masks"]})

    def restore_state(self, store, step, like):
        shardings = {"params": self.p_sh, "opt": self.o_sh,
                     "masks": None if like["masks"] is None else
                     jax.tree_util.tree_map(lambda _: None, like["masks"])}
        tree = store.restore({"params": like["params"], "opt": like["opt"],
                              "masks": like["masks"]}, step=step,
                             shardings=None)
        with self.mesh:
            tree["params"] = jax.device_put(tree["params"], self.p_sh)
            tree["opt"] = jax.device_put(tree["opt"], self.o_sh)
        return tree

    # -- step --------------------------------------------------------------
    def _one_step(self, state, step: int):
        if self.injector is not None:
            self.injector.maybe_fail(step)
        pipe_batch = self.pipeline.batch_at(step)
        batch = {k: jnp.asarray(v) for k, v in pipe_batch.items()}
        t0 = time.time()
        params, opt, metrics = self.step_fn(state["params"], state["opt"],
                                            batch)
        metrics = jax.device_get(metrics)
        self.monitor.observe({self.data_cfg.host_id: time.time() - t0})
        if state["masks"] is not None:
            sp = self.cfg.sparsity
            masks = pruner_mod.update_masks(params, state["masks"], step, sp)
            params = pruner_mod.apply_masks(params, masks, sp)
            state = {"params": params, "opt": opt, "masks": masks}
        else:
            state = {"params": params, "opt": opt, "masks": None}
        return state, metrics

    # -- driver ------------------------------------------------------------
    def fit(self, resume: bool = True):
        self.pipeline = DataPipeline(self.data_cfg)
        state = self.init_state()
        start = 0
        if resume and self.store.latest_step() is not None:
            start = self.store.latest_step()
            state = self.restore_state(self.store, start, state)
            log.info("resumed from step %d", start)

        sup = Supervisor(self.tcfg.ft, self.store, self.save_state,
                         self.restore_state)
        history = []

        def on_step(step, metrics):
            if step % self.tcfg.log_every == 0:
                history.append((step, float(metrics["loss"])))
                log.info("step %d loss %.4f", step, float(metrics["loss"]))

        state, end = sup.run(state, start, self.tcfg.n_steps - start,
                             self._one_step, on_step)
        self.save_state(self.store, end, state)
        self.store.wait()
        self.pipeline.close()
        return state, history
