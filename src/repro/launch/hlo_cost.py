"""Loop-aware HLO cost model (text-based).

XLA's built-in ``compiled.cost_analysis()`` counts every computation ONCE --
while-loop bodies are NOT multiplied by trip count (verified empirically, see
EXPERIMENTS.md §Dry-run methodology). Our models are scan-based (layers,
flash-attention chunks, SSD chunks), so raw cost_analysis undercounts by
orders of magnitude. This module recomputes flops / HBM bytes / collective
bytes by walking the optimized HLO text:

  * computations are parsed into op lists; the call graph is traversed from
    ENTRY; ``while`` bodies+conds are weighted by their trip count (XLA:CPU
    emits ``backend_config={"known_trip_count":{"n":...}}``; fallback: the
    largest integer constant in the condition computation);
  * ``dot`` flops = 2 * prod(output dims) * prod(contracting dims);
  * bytes per op = operand bytes + output bytes (HloCostAnalysis convention;
    fusions are costed at the fusion boundary, their internals contribute
    flops only);
  * collective bytes = output bytes of all-gather / all-reduce /
    reduce-scatter(max of in/out) / all-to-all / collective-permute.

Validated against cost_analysis on loop-free graphs (tests/test_hlo_cost.py).
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_ZERO_COST = {"parameter", "constant", "tuple", "get-tuple-element",
              "bitcast", "after-all", "custom-call", "partition-id",
              "replica-id", "opt-barrier", "domain", "iota"}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*")
_KIND_RE = re.compile(r"^\s*([\w\-]+)\(")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\(.*\)\s*->")
_TRIP_RE = re.compile(r'known_trip_count[":{\\]+n[":\\]+(\d+)')
_CALLED_RE = re.compile(r"(?:body|condition|to_apply|calls)=%([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _parse_types(type_str: str) -> List[Tuple[str, List[int]]]:
    """'(s32[], bf16[64,64]{1,0})' -> [('s32', []), ('bf16', [64, 64])]."""
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dtype, dims = m.groups()
        out.append((dtype, [int(d) for d in dims.split(",")] if dims else []))
    return out


def _nbytes(types) -> int:
    total = 0
    for dtype, dims in types:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES.get(dtype, 4)
    return total


def _nelems(types) -> int:
    total = 0
    for _, dims in types:
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    types: list                      # result types
    operands: List[str]
    line: str


def parse_module(text: str):
    """-> (computations: {name: [Op]}, entry_name)."""
    comps: Dict[str, List[Op]] = {}
    entry = None
    cur: Optional[str] = None
    for line in text.splitlines():
        if not line.strip():
            continue
        if not line.startswith(" "):          # computation header or '}'
            m = _COMP_RE.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = m.group(1)
                comps[cur] = []
                if line.lstrip().startswith("ENTRY"):
                    entry = cur
            elif line.strip() == "}":
                cur = None
            continue
        if cur is None:
            continue
        m = _NAME_RE.match(line)
        if not m:
            continue
        name = m.group(1)
        rest = line[m.end():]
        # result type: either '(tuple, ...)' (may contain /*index=N*/
        # comments) or 'dtype[dims]{layout}' -- scan to its end manually.
        if rest.startswith("("):
            depth = 0
            for i, ch in enumerate(rest):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        break
            type_str, rest = rest[: i + 1], rest[i + 1:]
        else:
            i = rest.find(" ")
            if i < 0:
                continue
            type_str, rest = rest[:i], rest[i:]
        km = _KIND_RE.match(rest)
        if not km:
            continue
        kind = km.group(1)
        paren = rest[km.end() - 1:]
        depth, i = 0, 0
        for i, ch in enumerate(paren):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        operand_str = paren[: i + 1]
        operands = re.findall(r"%([\w.\-]+)", operand_str)
        comps[cur].append(Op(name, kind, _parse_types(type_str), operands,
                             line))
    return comps, entry


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})

    def __iadd__(self, other):
        self.flops += other.flops
        self.bytes += other.bytes
        for k in self.coll:
            self.coll[k] += other.coll[k]
        return self

    def scaled(self, m: float) -> "Cost":
        return Cost(self.flops * m, self.bytes * m,
                    {k: v * m for k, v in self.coll.items()})

    @property
    def coll_total(self) -> float:
        return sum(self.coll.values())


class HloCostModel:
    def __init__(self, text: str):
        self.comps, self.entry = parse_module(text)
        # symbol table: op name -> result types (module-wide; names unique
        # enough in practice, last-write-wins is harmless for shapes)
        self.symbols: Dict[str, list] = {}
        for ops in self.comps.values():
            for op in ops:
                self.symbols[op.name] = op.types
        self._memo: Dict[str, Cost] = {}
        self._fusion_flops_memo: Dict[str, float] = {}

    # -- helpers -----------------------------------------------------------
    def _operand_bytes(self, op: Op) -> int:
        return sum(_nbytes(self.symbols.get(o, [])) for o in op.operands)

    def _trip_count(self, op: Op) -> int:
        m = _TRIP_RE.search(op.line)
        if m:
            return int(m.group(1))
        cm = _CALLED_RE.findall(op.line)
        # fallback: largest s32 constant in the condition computation
        for comp_name in cm:
            if "cond" in comp_name or "region_1" in comp_name:
                best = 1
                for o in self.comps.get(comp_name, []):
                    if o.kind == "constant":
                        c = re.search(r"constant\((\d+)\)", o.line)
                        if c:
                            best = max(best, int(c.group(1)))
                return best
        return 1

    def _dot_flops(self, op: Op) -> float:
        out_elems = _nelems(op.types)
        lhs = self.symbols.get(op.operands[0], [])
        contract = 1
        m = _LHS_CONTRACT_RE.search(op.line)
        if m and lhs:
            dims = lhs[0][1]
            idxs = [int(x) for x in m.group(1).split(",") if x != ""]
            for ix in idxs:
                if ix < len(dims):
                    contract *= dims[ix]
        return 2.0 * out_elems * contract

    def _fusion_flops(self, comp_name: str) -> float:
        """Elementwise flops inside a fusion computation (1 flop/elem/op)."""
        if comp_name in self._fusion_flops_memo:
            return self._fusion_flops_memo[comp_name]
        total = 0.0
        for op in self.comps.get(comp_name, []):
            if op.kind == "dot":
                total += self._dot_flops(op)
            elif op.kind == "fusion":
                called = _CALLED_RE.findall(op.line)
                total += sum(self._fusion_flops(c) for c in called)
            elif op.kind not in _ZERO_COST and op.kind not in (
                    "copy", "broadcast", "reshape", "transpose", "slice",
                    "concatenate", "pad", "reverse", "gather", "scatter",
                    "dynamic-slice", "dynamic-update-slice", "convert"):
                total += _nelems(op.types)
        self._fusion_flops_memo[comp_name] = total
        return total

    # -- main traversal ------------------------------------------------------
    def comp_cost(self, comp_name: str) -> Cost:
        if comp_name in self._memo:
            return self._memo[comp_name]
        total = Cost()
        for op in self.comps.get(comp_name, []):
            total += self.op_cost(op)
        self._memo[comp_name] = total
        return total

    def op_cost(self, op: Op) -> Cost:
        c = Cost()
        k = op.kind
        if k == "while":
            trip = self._trip_count(op)
            called = _CALLED_RE.findall(op.line)
            inner = Cost()
            for cn in called:
                inner += self.comp_cost(cn)
            return inner.scaled(trip)
        if k == "conditional":
            m = _BRANCHES_RE.search(op.line)
            branches = re.findall(r"%([\w.\-]+)", m.group(1)) if m else []
            costs = [self.comp_cost(b) for b in branches]
            if costs:   # one branch executes: take the max-flops branch
                return max(costs, key=lambda x: x.flops)
            return c
        if k == "call":
            called = _CALLED_RE.findall(op.line)
            for cn in called:
                c += self.comp_cost(cn)
            return c

        out_bytes = _nbytes(op.types)
        if k in _COLLECTIVES:
            vol = out_bytes
            if k == "reduce-scatter":
                vol = max(out_bytes, self._operand_bytes(op))
            c.coll[k] += vol
            c.bytes += out_bytes + self._operand_bytes(op)
            return c
        if k in _ZERO_COST:
            return c
        if k == "fusion":
            called = _CALLED_RE.findall(op.line)
            c.flops += sum(self._fusion_flops(cn) for cn in called)
            c.bytes += out_bytes + self._operand_bytes(op)
            return c
        if k == "dot":
            c.flops += self._dot_flops(op)
            c.bytes += out_bytes + self._operand_bytes(op)
            return c
        if k in ("convolution",):
            # not used by our models; approximate as output elems
            c.flops += _nelems(op.types)
            c.bytes += out_bytes + self._operand_bytes(op)
            return c
        if k in ("reduce", "reduce-window", "sort", "map", "scatter",
                 "select-and-scatter"):
            c.flops += self._operand_bytes(op) / 4.0   # ~1 flop per element
            c.bytes += out_bytes + self._operand_bytes(op)
            return c
        # elementwise / data movement
        if k in ("copy", "broadcast", "reshape", "transpose", "slice",
                 "concatenate", "pad", "gather", "dynamic-slice",
                 "dynamic-update-slice", "convert", "reverse", "copy-start",
                 "copy-done"):
            c.bytes += out_bytes + self._operand_bytes(op)
            return c
        c.flops += _nelems(op.types)
        c.bytes += out_bytes + self._operand_bytes(op)
        return c

    def total(self) -> Cost:
        return self.comp_cost(self.entry)


def analyze(text: str) -> dict:
    cost = HloCostModel(text).total()
    return {"flops": cost.flops, "bytes": cost.bytes,
            "coll": {**cost.coll, "total": cost.coll_total}}
