"""GPipe-style pipeline parallelism over shard_map + collective_permute.

Optional parallelism mode for very deep models (adds a "pipe" mesh axis).
Stages hold contiguous layer groups; microbatches stream through with
ppermute handoffs; bubbles = (S-1)/(S-1+M) as usual. Off by default on the
2-axis production mesh (the assigned models fit TP x DP comfortably); the
test exercises a 4-stage pipeline on fake devices via subprocess.

The implementation is deliberately minimal-but-real: it runs the SAME layer
body the LM uses, and the schedule is the classic fill-drain loop expressed
with lax.fori_loop + ppermute so it lowers to static HLO.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.runtime.compat import shard_map


def pipeline_apply(layer_fn, stage_params, x_microbatches, mesh,
                   axis: str = "pipe"):
    """Run ``layer_fn(stage_params, x)`` across pipeline stages.

    stage_params: pytree stacked over stages on axis ``pipe``;
    x_microbatches: (M, mb, ...) microbatched inputs, resident on stage 0.
    Returns outputs (M, mb, ...) resident on the last stage (replicated out).
    """
    n_stages = dict(mesh.shape)[axis]
    m = x_microbatches.shape[0]
    total_ticks = m + n_stages - 1

    @partial(shard_map, mesh=mesh,
             in_specs=(P(axis), P(None)), out_specs=P(None),
             check_vma=False)
    def run(params_stage, xs):
        stage = jax.lax.axis_index(axis)
        params_local = jax.tree_util.tree_map(lambda p: p[0], params_stage)
        buf = jnp.zeros_like(xs[0])          # current activation
        outs = jnp.zeros_like(xs)

        def tick(t, carry):
            buf, outs = carry
            # stage 0 ingests microbatch t (if any remain)
            mb_idx = jnp.clip(t, 0, m - 1)
            incoming = jnp.where((stage == 0) & (t < m), 1.0, 0.0)
            buf = buf * (1 - incoming) + xs[mb_idx] * incoming
            # all stages compute
            buf = layer_fn(params_local, buf)
            # last stage emits microbatch (t - n_stages + 1)
            out_idx = jnp.clip(t - n_stages + 1, 0, m - 1)
            emit = (stage == n_stages - 1) & (t >= n_stages - 1)
            outs = jax.lax.dynamic_update_index_in_dim(
                outs, jnp.where(emit, buf, outs[out_idx]), out_idx, 0)
            # hand off downstream (ring; stage S-1 -> 0 wraps harmlessly)
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            buf = jax.lax.ppermute(buf, axis, perm)
            return buf, outs

        _, outs = jax.lax.fori_loop(0, total_ticks, tick, (buf, outs))
        # replicate result (last stage holds it)
        outs = jax.lax.psum(
            jnp.where(stage == n_stages - 1, outs, jnp.zeros_like(outs)),
            axis)
        return outs

    return run(stage_params, x_microbatches)
