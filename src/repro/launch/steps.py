"""Step builders: train_step (CE + aux + group-ℓ1, AdamW, prox) and
serve_step / prefill_step. Pure functions suitable for jax.jit AOT lowering.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.regularizer import tree_group_penalty
from repro.models import decode_step as model_decode_step
from repro.models import model_forward
from repro.optim.adamw import AdamWConfig, adamw_update


def cross_entropy(logits, labels):
    """logits (B,S,V) f32, labels (B,S) int32; mean over all tokens."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def make_loss_fn(cfg: ModelConfig, packs=None):
    def loss_fn(params, batch):
        logits, aux = model_forward(params, cfg, batch, packs=packs)
        ce = cross_entropy(logits, batch["labels"])
        loss = ce + aux
        if cfg.sparsity is not None and cfg.sparsity.lambda_reg > 0:
            # proximal-gradient: the group-lasso term is handled EXACTLY by
            # the blockwise soft-threshold in the optimizer (adamw_update);
            # the penalty is reported in the loss but must not flow
            # gradients (d||w||/dw is NaN at the zero blocks prox creates)
            reg = tree_group_penalty(params, cfg.sparsity.block_shape, 2,
                                     cfg.sparsity.applies_to)
            loss = loss + cfg.sparsity.lambda_reg * jax.lax.stop_gradient(reg)
        return loss, {"ce": ce, "aux": aux}
    return loss_fn


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    grad_accum: int = 1, packs=None):
    loss_fn = make_loss_fn(cfg, packs)

    def train_step(params, opt_state, batch):
        if grad_accum == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            # microbatched accumulation: batch dims reshaped (A, B/A, ...)
            def micro(carry, mb):
                gsum, lsum = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                return (jax.tree_util.tree_map(jnp.add, gsum, g),
                        lsum + l), None
            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            mbs = jax.tree_util.tree_map(
                lambda x: x.reshape(grad_accum, x.shape[0] // grad_accum,
                                    *x.shape[1:]), batch)
            (gsum, lsum), _ = jax.lax.scan(micro, (zeros, 0.0), mbs)
            grads = jax.tree_util.tree_map(lambda g: g / grad_accum, gsum)
            loss = lsum / grad_accum
            metrics = {"ce": loss, "aux": jnp.zeros((), jnp.float32)}
        new_params, new_opt, om = adamw_update(grads, opt_state, params,
                                               opt_cfg, cfg.sparsity)
        return new_params, new_opt, {"loss": loss, **metrics, **om}

    return train_step


def make_prefill_step(cfg: ModelConfig, packs=None):
    def prefill_step(params, batch):
        logits, _ = model_forward(params, cfg, batch, packs=packs)
        return jnp.argmax(logits[:, -1], axis=-1)
    return prefill_step


def make_serve_step(cfg: ModelConfig, packs=None):
    def serve_step(params, cache, token, pos):
        logits, new_cache = model_decode_step(params, cache, cfg, token, pos,
                                              packs=packs)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return nxt[:, None], new_cache
    return serve_step
