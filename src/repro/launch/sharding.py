"""Divisibility-aware sharding rules: DP + FSDP + TP + EP (+ SP constraint).

Conventions (single pod mesh ("data","model"); multi-pod prepends "pod" which
folds into DP):
  * column-parallel 2-D weights (out, in): out -> "model", in -> "data" (FSDP)
  * row-parallel    2-D weights (wo/out*): out -> "data",  in -> "model"
  * expert 3-D weights (E, a, b):          E   -> "model" (EP), a -> "data"
  * embeddings (V, d): V -> "model", d -> "data"
  * 1-D (norm scales, biases, gates): replicated
  * a dim is sharded over an axis only when divisible, else replicated --
    this is what lets kv_heads=1 (MQA) or tiny projections coexist with a
    16-wide model axis.

Caches/batches shard batch over DP and heads/state over "model".
Stacked (scan) leaves get leading None specs automatically.
"""
from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import dp_axes

_ROW_PARALLEL = ("wo", "out", "out_proj")
# router: small, replicated keeps top-k local. MLA latent down/up
# projections (w_dkv/w_uk/w_uv/w_krope): rank-sized, consumed via per-head
# reshapes in the absorbed decode path -- sharding them buys little and the
# reshard churn compounds float noise through the softmax chain.
_REPLICATE = ("router", "w_dkv", "w_uk", "w_uv", "w_krope")


def _sizes(mesh):
    return dict(mesh.shape)   # works for Mesh and AbstractMesh alike


def _div(shape, dim, ax, sizes):
    return ax is not None and shape[dim] % sizes.get(ax, 1) == 0 and \
        shape[dim] >= sizes.get(ax, 1)


def _leaf_name(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def spec_for_param(name: str, shape, mesh, mode: str = "train") -> P:
    """mode="train": TP + FSDP (weights gathered per layer; optimizer state
    must fit). mode="inference": TP-only — no data-axis weight sharding, so
    prefill/decode never pay per-layer weight all-gathers (§Perf cell B);
    experts use 2-D (E x f) sharding so 100B+ MoE weights still fit."""
    sizes = _sizes(mesh)
    toks = name.split("/")
    short = toks[-2] if toks[-1] == "w" and len(toks) >= 2 else toks[-1]
    ndim = len(shape)

    if any(t in short for t in _REPLICATE):
        return P(*([None] * ndim))

    # expert weights: trailing 3 dims (E, a, b)
    if ndim >= 3 and short in ("wi", "wg", "wo") and "ffn" in name:
        lead = ndim - 3
        e_ax = "model" if _div(shape, lead, "model", sizes) else None
        if mode == "inference":
            # 2-D EP x TP: shard the f dim over data (wi/wg: f is dim 2;
            # wo: f is dim 1) -- no gather; partial sums all-reduce instead
            if short == "wo":
                f_ax = "data" if _div(shape, lead + 1, "data", sizes) else None
                return P(*([None] * lead), e_ax, f_ax, None)
            f_ax = "data" if _div(shape, lead + 2, "data", sizes) else None
            return P(*([None] * lead), e_ax, None, f_ax)
        a_ax = "data" if _div(shape, lead + 1, "data", sizes) else None
        return P(*([None] * lead), e_ax, a_ax, None)

    if ndim == 1 or np.prod(shape) < 4096:
        return P(*([None] * ndim))

    # generic 2-D (possibly stacked): trailing (out, in)
    lead = ndim - 2
    row = any(short.startswith(t) or short == t for t in _ROW_PARALLEL)
    fsdp = "data" if mode == "train" else None
    if row:
        out_ax = fsdp if _div(shape, lead, "data", sizes) else None
        in_ax = "model" if _div(shape, lead + 1, "model", sizes) else None
    else:
        out_ax = "model" if _div(shape, lead, "model", sizes) else None
        in_ax = fsdp if _div(shape, lead + 1, "data", sizes) else None
    return P(*([None] * lead), out_ax, in_ax)


def param_shardings(shape_tree: Any, mesh, mode: str = "train"):
    def one(path, leaf):
        return NamedSharding(mesh, spec_for_param(_leaf_name(path),
                                                  leaf.shape, mesh, mode))
    return jax.tree_util.tree_map_with_path(one, shape_tree)


def opt_shardings(opt_shape_tree: Any, mesh):
    """m/v mirror the params rules; scalars replicated."""
    return param_shardings(opt_shape_tree, mesh)


# model-axis candidate dim per cache leaf kind, relative to the unstacked
# layout (never the head_dim / time dims -- sharding those forces SPMD
# resharding in the attention einsums, observed as "involuntary full
# rematerialization" warnings in the dry-run).
_CACHE_MODEL_DIM = {
    "k": 2, "v": 2,          # (B, T, Hkv, D) -> kv heads
    "k_scale": 2, "v_scale": 2,  # int8-cache scales (B, T, Hkv)
    "c_kv": 2, "k_rope": None,   # MLA latent (B, T, r) -> rank
    "state": 1,              # SSD (B, H, P, N) -> heads
    "conv": 2,               # (B, W, C) -> channels
    "h": 1,                  # RG-LRU (B, W) -> width
    "cross_k": 3, "cross_v": 3,  # stacked (L, B, T, Hkv, D) handled by lead
}

#: paged-KV pool leaves: axis 0 (+lead) is PHYSICAL PAGES -- one shared id
#: space across the pool, never sharded (and never the dp batch dim); only
#: the kv-head / latent-rank dim goes over "model", mirroring the dense
#: rules above. page_table / pos_map stay replicated int32 bookkeeping.
_PAGE_POOL_MODEL_DIM = {
    "k_pages": 2, "v_pages": 2,      # (N, ps, Hkv, D) -> kv heads
    "c_kv_pages": 2,                 # (N, ps, r) -> latent rank
    "k_rope_pages": None,            # (N, ps, dr) shared rope key: replicated
}


def spec_for_cache(name: str, shape, mesh) -> P:
    sizes = _sizes(mesh)
    dp = dp_axes(mesh)
    dp_size = int(np.prod([sizes[a] for a in dp])) if dp else 1
    ndim = len(shape)
    toks = name.split("/")
    short = toks[-1]
    if ndim <= 1:
        return P(*([None] * ndim))
    # caches carry a leading stack dim when scanned: detect 'blocks'
    lead = 1 if ("blocks" in toks or short.startswith("cross")
                 or "self" in toks) else 0
    if short.startswith("cross"):
        lead = 1
    if short == "page_table":
        return P(*([None] * ndim))
    if short.endswith("_pages"):
        spec = [None] * ndim
        mdim = _PAGE_POOL_MODEL_DIM.get(short)
        if mdim is not None:
            d = mdim + lead
            if d < ndim and _div(shape, d, "model", sizes):
                spec[d] = "model"
        return P(*spec)
    spec = [None] * ndim
    bdim = lead
    if bdim < ndim and shape[bdim] % dp_size == 0 and shape[bdim] >= dp_size:
        spec[bdim] = dp if len(dp) > 1 else (dp[0] if dp else None)
    mdim = _CACHE_MODEL_DIM.get(short)
    if mdim is not None:
        d = mdim + (lead if not short.startswith("cross") else 0)
        if d < ndim and d > bdim and _div(shape, d, "model", sizes):
            spec[d] = "model"
    return P(*spec)


def cache_shardings(cache_shape_tree: Any, mesh):
    def one(path, leaf):
        return NamedSharding(mesh, spec_for_cache(_leaf_name(path),
                                                  leaf.shape, mesh))
    return jax.tree_util.tree_map_with_path(one, cache_shape_tree)


def batch_shardings(batch_shape_tree: Any, mesh):
    sizes = _sizes(mesh)
    dp = dp_axes(mesh)
    dp_size = int(np.prod([sizes[a] for a in dp])) if dp else 1

    def one(path, leaf):
        spec = [None] * len(leaf.shape)
        if leaf.ndim >= 1 and leaf.shape[0] % dp_size == 0 and \
                leaf.shape[0] >= dp_size:
            spec[0] = dp if len(dp) > 1 else (dp[0] if dp else None)
        elif leaf.ndim >= 2 and leaf.shape[0] == 1 and \
                leaf.shape[1] % dp_size == 0:
            spec[1] = dp if len(dp) > 1 else (dp[0] if dp else None)  # SP
        return NamedSharding(mesh, P(*spec))
    return jax.tree_util.tree_map_with_path(one, batch_shape_tree)


def replicated(mesh):
    return NamedSharding(mesh, P())
