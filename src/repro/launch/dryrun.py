import os
os.environ["XLA_FLAGS"] = (os.environ.get("DRYRUN_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=" +
                           os.environ.get("DRYRUN_DEVICES", "512")).strip()

"""Multi-pod dry-run: AOT lower + compile every (arch x shape) cell on the
production mesh, record memory/cost/collective analysis.

MUST be run as its own process (the XLA_FLAGS line above executes before any
jax import; jax locks device count on first init).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-moe-235b-a22b \
      --shape train_4k [--multipod] [--out results/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multipod]
"""
import argparse
import json
import time
import traceback

import jax
import numpy as np

from repro.configs.base import SHAPES
from repro.configs.registry import ASSIGNED, get_config
from repro.launch import hlo_analysis as ha
from repro.launch import hlo_cost
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import (batch_shardings, cache_shardings,
                                   opt_shardings, param_shardings, replicated)
from repro.launch.specs import cache_specs, cell_is_supported, input_specs
from repro.launch.steps import make_prefill_step, make_serve_step, make_train_step
from repro.optim.adamw import AdamWConfig, init_opt_state


def lower_cell(cfg, shape_name, mesh, opt_cfg=None):
    """Returns (lowered, in_info) for the cell's step function."""
    shape = SHAPES[shape_name]
    specs = input_specs(cfg, shape_name)
    p_sh = param_shardings(specs["params"], mesh)

    if shape.kind == "train":
        opt_cfg = opt_cfg or AdamWConfig()
        opt_shapes = jax.eval_shape(
            lambda p: init_opt_state(p, opt_cfg), specs["params"])
        o_sh = opt_shardings(opt_shapes, mesh)
        b_sh = batch_shardings(specs["batch"], mesh)
        step = make_train_step(cfg, opt_cfg)
        with mesh:
            lowered = jax.jit(
                step,
                in_shardings=(p_sh, o_sh, b_sh),
                out_shardings=(p_sh, o_sh, replicated(mesh)),
                donate_argnums=(0, 1),
            ).lower(specs["params"], opt_shapes, specs["batch"])
        return lowered

    # inference cells: TP-only params (no per-layer weight all-gathers);
    # beyond-paper distribution optimization, §Perf cell B
    p_sh = param_shardings(specs["params"], mesh, mode="inference")

    if shape.kind == "prefill":
        b_sh = batch_shardings(specs["batch"], mesh)
        step = make_prefill_step(cfg)
        with mesh:
            lowered = jax.jit(
                step, in_shardings=(p_sh, b_sh),
            ).lower(specs["params"], specs["batch"])
        return lowered

    # decode
    c_sh = cache_shardings(specs["cache"], mesh)
    t_sh = batch_shardings({"t": specs["token"]}, mesh)["t"]
    step = make_serve_step(cfg)
    with mesh:
        lowered = jax.jit(
            step,
            in_shardings=(p_sh, c_sh, t_sh, replicated(mesh)),
            out_shardings=(t_sh, c_sh),
            donate_argnums=(1,),
        ).lower(specs["params"], specs["cache"], specs["token"], specs["pos"])
    return lowered


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = int(np.prod(mesh.devices.shape))
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    cell = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
            "n_devices": n_dev}

    ok, reason = cell_is_supported(cfg, shape_name)
    if not ok:
        cell.update(status="SKIP", reason=reason)
        return cell

    t0 = time.time()
    try:
        lowered = lower_cell(cfg, shape_name, mesh)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()          # loop-UNAWARE (reference)
        hlo = compiled.as_text()
        loop_aware = hlo_cost.analyze(hlo)       # loop-aware cost model
        coll = loop_aware["coll"]

        p_specs = input_specs(cfg, shape_name)["params"]
        n_params = ha.count_params(p_specs)
        n_expert = ha.count_expert_params(p_specs)
        model_fl = ha.model_flops_estimate(cfg, shape, n_params, n_expert,
                                           shape.kind)
        roof = ha.Roofline(
            flops=loop_aware["flops"],
            hbm_bytes=loop_aware["bytes"],
            coll_bytes=coll["total"],
            n_devices=n_dev, model_flops=model_fl)

        cell.update(
            status="OK", lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            n_params=n_params, n_expert_params=n_expert,
            memory={
                "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
                "output_bytes": getattr(mem, "output_size_in_bytes", 0),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
                "peak_bytes": getattr(mem, "temp_size_in_bytes", 0)
                + getattr(mem, "argument_size_in_bytes", 0),
            },
            collectives={k: int(v) for k, v in coll.items()},
            xla_cost_raw={"flops": float(cost.get("flops", 0.0)),
                          "bytes": float(cost.get("bytes accessed", 0.0))},
            roofline=roof.to_dict(),
        )
    except Exception as e:  # noqa: BLE001
        cell.update(status="FAIL", error=f"{type(e).__name__}: {e}",
                    trace=traceback.format_exc()[-2000:])
    return cell


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    cells = ([(a, s) for a in ASSIGNED for s in SHAPES] if args.all
             else [(args.arch, args.shape)])
    for arch, shape in cells:
        tag = "multipod" if args.multipod else "pod"
        res = run_cell(arch, shape, args.multipod, args.out)
        path = os.path.join(args.out,
                            f"{arch.replace('-', '_')}__{shape}__{tag}.json")
        with open(path, "w") as f:
            json.dump(res, f, indent=1)
        print(json.dumps({k: res[k] for k in
                          ("arch", "shape", "mesh", "status")}
                         | ({"bottleneck": res["roofline"]["bottleneck"],
                             "compile_s": res["compile_s"]}
                            if res["status"] == "OK" else
                            {"why": res.get("reason", res.get("error", ""))}),
                         ), flush=True)


if __name__ == "__main__":
    main()
