"""Mixture-of-Experts FFN with capacity-based sort dispatch (EP-shardable).

Dispatch avoids the O(T*E) one-hot matmul: assignments are argsorted by
expert id, positioned within their expert segment, and scattered into a
(E, capacity, d) buffer. All heavy ops are O(T*k*d) gathers/scatters plus the
expert einsums, and the expert dimension shards cleanly over the "model"
mesh axis (expert parallelism). Aux load-balancing loss follows Switch.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import init_linear, init_mlp, normal_init


def init_moe(key, cfg):
    d, f, e = cfg.d_model, cfg.d_ff_expert or cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {"router": normal_init(ks[0], (d, e), 0.02, jnp.float32),
         "wi": normal_init(ks[1], (e, d, f), 0.02, cfg.jdtype),
         "wg": normal_init(ks[2], (e, d, f), 0.02, cfg.jdtype),
         "wo": normal_init(ks[3], (e, f, d), 0.02, cfg.jdtype)}
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(ks[4], d, f * cfg.n_shared_experts,
                               cfg.act, cfg.jdtype)
    return p


def apply_moe(p, x, cfg):
    """x: (B, S, d) -> (y, aux_loss)."""
    b, s, d = x.shape
    x2 = x.reshape(b * s, d)
    t, k, e = b * s, cfg.top_k, cfg.n_experts
    cap = max(1, int(t * k / e * cfg.capacity_factor))

    logits = jnp.einsum("td,de->te", x2.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)                       # (T, k)
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)

    # ---- sort-based dispatch -------------------------------------------
    flat_e = idx.reshape(-1)                                   # (T*k,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(e))
    pos_in_seg = jnp.arange(t * k) - seg_start[sorted_e]       # (T*k,)
    token_src = order // k

    buf = jnp.zeros((e, cap, d), x2.dtype)
    buf = buf.at[sorted_e, pos_in_seg].set(x2[token_src], mode="drop")

    # ---- expert computation (shards over E) ----------------------------
    act = jax.nn.silu if cfg.act == "swiglu" else jax.nn.gelu
    h = act(jnp.einsum("ecd,edf->ecf", buf, p["wg"])) * \
        jnp.einsum("ecd,edf->ecf", buf, p["wi"])
    out = jnp.einsum("ecf,efd->ecd", h, p["wo"])

    # ---- combine --------------------------------------------------------
    y_flat = out.at[sorted_e, pos_in_seg].get(mode="fill", fill_value=0)
    w_flat = gate.reshape(-1)[order]
    y = jnp.zeros((t, d), jnp.float32).at[token_src].add(
        y_flat.astype(jnp.float32) * w_flat[:, None])

    if "shared" in p:
        from repro.models.common import apply_mlp
        y = y + apply_mlp(p["shared"], x2, cfg.act).astype(jnp.float32)

    # Switch-style aux loss: E * sum(frac_tokens_e * mean_prob_e)
    frac = jnp.mean(jax.nn.one_hot(idx[:, 0], e, dtype=jnp.float32), axis=0)
    mean_prob = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(frac * mean_prob) * cfg.router_aux_coef
    return y.reshape(b, s, d).astype(x.dtype), aux
