"""RG-LRU recurrent block (RecurrentGemma / Griffin).

Real-Gated Linear Recurrent Unit:
    r_t = sigmoid(W_a x_t)            (recurrence gate)
    i_t = sigmoid(W_x x_t)            (input gate)
    log a_t = -c * softplus(Lambda) * r_t          (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Train/prefill uses jax.lax.associative_scan (parallel over sequence); decode
carries h. The block wraps the LRU with the Griffin recipe: dual linear
branches (gelu gate), depthwise causal conv width 4 on the recurrent branch.
Linear in sequence length -> long_500k-capable.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.attention import as_slot_positions
from repro.models.common import (init_linear, linear, normal_init,
                                 prefill_conv_history)

_C = 8.0


def init_rglru(key, cfg):
    d = cfg.d_model
    w = cfg.rnn_width or d
    ks = jax.random.split(key, 6)
    return {
        "in_x": init_linear(ks[0], d, w, cfg.jdtype),
        "in_gate": init_linear(ks[1], d, w, cfg.jdtype),
        "conv_w": normal_init(ks[2], (cfg.conv_width, w), 0.1, cfg.jdtype),
        "conv_b": jnp.zeros((w,), cfg.jdtype),
        "w_a": init_linear(ks[3], w, w, cfg.jdtype),
        "w_i": init_linear(ks[4], w, w, cfg.jdtype),
        # Lambda init so a^c in (0.9, 0.999) at r=0.5, griffin-style
        "lam": normal_init(jax.random.fold_in(key, 7), (w,), 0.5,
                           jnp.float32) + 4.0,
        "out": init_linear(ks[5], w, d, cfg.jdtype),
    }


def init_cache_rglru(cfg, batch, dtype=None):
    w = cfg.rnn_width or cfg.d_model
    dtype = dtype or cfg.jdtype
    return {"h": jnp.zeros((batch, w), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_width - 1, w), dtype)}


def _conv(x, w, b):
    out = jnp.zeros(x.shape, jnp.float32)
    width = w.shape[0]
    for i in range(width):
        sh = width - 1 - i
        xi = jnp.pad(x, ((0, 0), (sh, 0), (0, 0)))[:, :x.shape[1]]
        out = out + xi.astype(jnp.float32) * w[i].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def _lru_gates(p, xr):
    r = jax.nn.sigmoid(linear(p["w_a"], xr).astype(jnp.float32))
    i = jax.nn.sigmoid(linear(p["w_i"], xr).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"])[None] * r      # broadcast over (b,s,w)
    a = jnp.exp(log_a)
    gated_in = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * \
        (i * xr.astype(jnp.float32))
    return a, gated_in


def apply_rglru(p, x, cfg, *, cache=None, pos=None, packs=None,
                prefill_len=None, page_slot=None, **_):
    b, s, _ = x.shape
    gate = jax.nn.gelu(linear(p["in_gate"], x,
                              packs and packs.get("in_gate")).astype(jnp.float32))
    xr = linear(p["in_x"], x, packs and packs.get("in_x"))

    prefill = cache is not None and s > 1
    # chunk/suffix prefill: x holds ONE slot's next prompt slice against the
    # BATCHED engine cache -- continue from the slot's carried h and real
    # conv history instead of zeros (docs/API.md §SLO scheduling)
    chunked = prefill and page_slot is not None
    if cache is None or prefill:
        w1 = cfg.conv_width - 1
        xr_raw = xr
        if chunked:
            assert b == 1
            hist_row = cache["conv"][page_slot].astype(xr.dtype)  # (W-1,w)
            hist_stream = jnp.concatenate([hist_row[None], xr], axis=1)
            xr = _conv(hist_stream, p["conv_w"], p["conv_b"])[:, w1:]
        else:
            xr = _conv(xr, p["conv_w"], p["conv_b"])
        a, u = _lru_gates(p, xr)
        if prefill:
            # padding steps (>= prefill_len) become identity: a = 1, u = 0,
            # so the scan's value at length-1 persists to the last slot
            length = s if prefill_len is None else prefill_len
            valid = (jnp.arange(s) < length)[None, :, None]
            a = jnp.where(valid, a, 1.0)
            u = jnp.where(valid, u, 0.0)
        # parallel linear recurrence: h_t = a_t h_{t-1} + u_t
        def combine(c1, c2):
            a1, u1 = c1
            a2, u2 = c2
            return a1 * a2, u1 * a2 + u2
        aa, hh = jax.lax.associative_scan(combine, (a, u), axis=1)
        h = hh
        new_cache = None
        if chunked:
            # inject the carried state: h_t = (prod a_1..t) h_prev + hh_t
            h = aa * cache["h"][page_slot][None, None] + hh
            validp = jnp.concatenate(
                [jnp.ones((1, w1, 1), bool),
                 jnp.broadcast_to(valid, (1, s, 1))], axis=1)
            hist_in = jnp.concatenate([hist_row[None], xr_raw], axis=1)
            new_hist = prefill_conv_history(
                hist_in, validp, w1 + jnp.asarray(length, jnp.int32), w1,
                cache["conv"].dtype)
            new_cache = {
                "h": cache["h"].at[page_slot].set(h[0, -1]),
                "conv": cache["conv"].at[page_slot].set(new_hist[0])}
        elif prefill:
            new_cache = {
                "h": hh[:, -1],                 # padding holds h at length-1
                "conv": prefill_conv_history(xr_raw, valid, length,
                                             cfg.conv_width - 1,
                                             cache["conv"].dtype),
            }
    else:
        # inactive slots (ragged pos < 0) keep h and the conv history
        # untouched -- see attention.as_slot_positions
        active = (as_slot_positions(pos, b) >= 0) if pos is not None \
            else jnp.ones((b,), bool)
        hist = jnp.concatenate([cache["conv"], xr], axis=1)
        xr = _conv(hist, p["conv_w"], p["conv_b"])[:, -1:]
        a, u = _lru_gates(p, xr)
        h = jnp.where(active[:, None], a[:, 0] * cache["h"] + u[:, 0],
                      cache["h"])
        new_conv = jnp.where(active[:, None, None], hist[:, 1:],
                             cache["conv"])
        new_cache = {"h": h, "conv": new_conv}
        h = h[:, None]

    y = (h * gate).astype(x.dtype)
    return linear(p["out"], y, packs and packs.get("out")), new_cache
