"""Shared layer primitives: norms, activations, RoPE, linear init/apply.

Everything is a pure function over explicit param dicts (no module framework
dependency); params are plain pytrees so they shard, scan, and checkpoint
uniformly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def normal_init(key, shape, scale=0.02, dtype=jnp.float32):
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


# -- norms -------------------------------------------------------------------

def rms_norm(x, scale, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))
            ).astype(x.dtype)


def layer_norm(x, scale, bias, eps=1e-6):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def init_norm(key, d, kind="rms", dtype=jnp.float32):
    if kind == "rms":
        return {"scale": jnp.zeros((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def apply_norm(p, x, kind="rms"):
    if kind == "rms":
        return rms_norm(x, p["scale"])
    return layer_norm(x, p["scale"], p["bias"])


# -- activations --------------------------------------------------------------

def act_fn(name):
    return {"gelu": jax.nn.gelu, "silu": jax.nn.silu, "relu": jax.nn.relu}[name]


# -- RoPE ---------------------------------------------------------------------

def rope_freqs(head_dim, rotary_fraction=1.0, theta=10000.0):
    rot = int(head_dim * rotary_fraction)
    rot -= rot % 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    return inv, rot


def apply_rope(x, positions, *, theta=10000.0, rotary_fraction=1.0):
    """x: (..., S, H, D); positions: (..., S) int32. Pairs (x_i, x_{i+rot/2})
    rotated; trailing (1-fraction) dims pass through (chatglm-style partial)."""
    d = x.shape[-1]
    inv, rot = rope_freqs(d, rotary_fraction, theta)
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    ang = positions[..., None].astype(jnp.float32) * inv          # (..., S, rot/2)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x_rot.astype(jnp.float32), 2, axis=-1)
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2, x_pass.astype(jnp.float32)],
                           axis=-1).astype(x.dtype)


# -- linear (dense or block-sparse) -------------------------------------------

def init_linear(key, d_in, d_out, dtype=jnp.float32, scale=0.02):
    """Weight stored (d_out, d_in): y = x @ w.T -- matches the BSR layout."""
    return {"w": normal_init(key, (d_out, d_in), scale, dtype)}


def tp_constrain(y, pack):
    """The tensor-parallel sharding hook for plan-backed projections
    (kernels/exec_plan.ShardedPlan with a mesh attached by
    ``prepare_servable``):

      * column-parallel (``shard_axis='out'``): pin the output feature dim
        to the mesh "model" axis -- activations stay sharded into the next
        (row-parallel) projection, no gather between them;
      * row-parallel (``shard_axis='in'``): pin the feature dim replicated
        -- THE single psum per layer that folds the per-device partial
        products (the plan's segment-sum) back together.

    The leading (batch/slot) dim keeps its "data" sharding when the mesh
    has one -- a None there would constrain it REPLICATED and force a
    per-layer all-gather of activations under partition='tp+dp'.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P
    spec = [None] * y.ndim
    dp = dict(pack.mesh.shape).get("data", 1)
    if y.ndim >= 2 and dp > 1 and y.shape[0] % dp == 0:
        spec[0] = "data"    # batch-1 prefill sub-caches stay replicated
    if pack.shard_axis == "out":
        spec[-1] = "model"
    return jax.lax.with_sharding_constraint(
        y, NamedSharding(pack.mesh, P(*spec)))


def linear(p, x, pack=None, backend=None):
    """Dense or block-sparse projection.

    ``pack`` is static pattern metadata (from repro.serving.export), one of:
      * a ``RowPackPlan`` -- ``p['w']`` holds row-grouped values
        (R, P, bn, bk) and the precomputed-plan fast path executes
        (kernels/exec_plan.py; no per-call pattern work at all); its
        ``ShardedPlan`` subclass additionally carries the tensor-parallel
        vrow partitioning and (when a mesh is attached) pins the output
        sharding via :func:`tp_constrain`;
      * a ``PlanChoice`` -- the same row-grouped layout pinned to a
        plan-consuming execution backend (``'plan_pallas'`` = the compiled
        Pallas kernel driven by the plan's spill schedule);
      * a ``QuantPlan`` -- ``p['w']`` holds int8/fp8 row-grouped values and
        ``p['scale']`` the per-block (or per-row-group) fp32 scales; the
        dequant-fused plan matmul executes (``pack.backend`` picks the XLA
        composition vs the compiled kernel), and a ShardedPlan inner keeps
        the tensor-parallel constraint;
      * a ``KernelBSR`` -- ``p['w']`` holds packed tile values (nnzt, bn, bk)
        and the matmul dispatches through ``bsr_linear``'s backends;
      * an ``autotune.BackendChoice`` -- a KernelBSR pattern pinned to the
        backend the autotuner measured fastest for it (backend='auto');
      * an ``autotune.MaskedPack`` -- ``p['w']`` stays a DENSE (N, K)
        weight and the tile-skipping ``masked`` kernel executes.
    """
    if pack is not None:
        from repro.kernels.exec_plan import (PlanChoice, QuantPlan,
                                             RowPackPlan, ShardedPlan,
                                             plan_matmul)
        if isinstance(pack, QuantPlan):
            from repro.kernels.ops import plan_q_dispatch
            y = plan_q_dispatch(x, p["w"], p["scale"], pack.plan,
                                backend=pack.backend)
            if (isinstance(pack.plan, ShardedPlan)
                    and pack.plan.mesh is not None):
                y = tp_constrain(y, pack.plan)
            return y
        if isinstance(pack, PlanChoice):
            from repro.kernels.ops import plan_dispatch
            return plan_dispatch(x, p["w"], pack.plan, backend=pack.backend)
        if isinstance(pack, RowPackPlan):
            y = plan_matmul(x, p["w"], pack)
            if isinstance(pack, ShardedPlan) and pack.mesh is not None:
                y = tp_constrain(y, pack)
            return y
        from repro.kernels.autotune import BackendChoice, MaskedPack
        if isinstance(pack, BackendChoice):
            backend, pack = pack.backend, pack.pack
        if isinstance(pack, MaskedPack):
            from repro.kernels.bsr_matmul import masked_matmul
            lead = x.shape[:-1]
            y = masked_matmul(x.reshape(-1, x.shape[-1]), p["w"],
                              jnp.asarray(pack.tile_mask), tile=pack.tile,
                              interpret=jax.default_backend() != "tpu")
            return y.reshape(*lead, pack.shape[0])
        from repro.kernels.ops import bsr_matmul  # local import, cycle-free
        from repro.kernels.bsr_matmul import KernelBSR
        kb = KernelBSR(p["w"], pack.row_id, pack.col_id, pack.t_perm,
                       pack.real_nnzt, pack.shape, pack.tile)
        return bsr_matmul(x, kb, backend)
    return jnp.einsum("...k,nk->...n", x, p["w"])


def prefill_conv_history(x, valid, length, width, dtype):
    """Conv-cache state after a one-pass prompt prefill: the last ``width``
    pre-conv inputs of the real prompt. ``x``: (B, S, C) bucket-padded
    inputs, ``valid``: (1, S, 1) real-token mask, ``length`` (traced OK) the
    prompt length. Masking then left-padding ``width`` zeros makes prompts
    shorter than the conv window zero-fill exactly like a fresh decode
    cache. Shared by the SSM and RG-LRU prefill paths."""
    b = x.shape[0]
    padded = jnp.concatenate(
        [jnp.zeros((b, width) + x.shape[2:], x.dtype),
         jnp.where(valid, x, 0)], axis=1)
    return jax.lax.dynamic_slice_in_dim(
        padded, jnp.asarray(length, jnp.int32), width, axis=1).astype(dtype)


# -- paged KV primitives ------------------------------------------------------
#
# A paged cache replaces per-slot contiguous KV storage (B, T, ...) with a
# pool of fixed-size pages (n_pages, page_size, ...) plus a per-slot page
# table (B, T // page_size) of physical page ids (-1 = unmapped). The
# serving engine owns allocation (repro/serving/paging.py); the model layer
# only needs the three pure device ops below. Index discipline: -1 must
# never reach a device gather/scatter directly (JAX wraps negative indices);
# gathers clip into range and mask by pos_map, scatters map invalid rows to
# n_pages (out of bounds HIGH), which jit scatter semantics DROP.

import dataclasses as _dataclasses


@_dataclasses.dataclass(frozen=True)
class PagedLayout:
    """Static paging geometry threaded into ``init_cache``: a pool of
    ``n_pages`` pages of ``page_size`` positions each, addressed through
    per-slot page tables covering ``cache_len // page_size`` entries."""

    page_size: int
    n_pages: int

    def __post_init__(self):
        if self.page_size < 1 or self.n_pages < 1:
            raise ValueError(f"bad paged layout: page_size={self.page_size} "
                             f"n_pages={self.n_pages}")

    def table_width(self, cache_len: int) -> int:
        if cache_len % self.page_size:
            raise ValueError(
                f"cache_len={cache_len} not divisible by "
                f"page_size={self.page_size}")
        return cache_len // self.page_size


def paged_view(pages, page_table, pos_map):
    """Gather a slot-contiguous (B, T, ...) view out of a page pool.

    ``pages``: (N, ps, ...); ``page_table``: (B, NP) int32 page ids (-1 =
    unmapped); ``pos_map``: (B, T = NP*ps) absolute positions (-1 = empty).
    Unmapped/unwritten positions read as EXACT zeros -- the view is then
    elementwise identical to the dense slot cache the same writes would
    have produced (dense caches zero-init and zero-reset), which is what
    makes the paged decode path bit-exact against the dense oracle."""
    b, npg = page_table.shape
    n, ps = pages.shape[0], pages.shape[1]
    safe = jnp.clip(page_table, 0, n - 1)            # gather: clip, mask below
    view = pages[safe]                               # (B, NP, ps, ...)
    view = view.reshape((b, npg * ps) + pages.shape[2:])
    keep = (pos_map >= 0).reshape((b, npg * ps) + (1,) * (pages.ndim - 2))
    return jnp.where(keep, view, jnp.zeros_like(view))


def paged_row_write(pages, page_table, positions, val, active):
    """Scatter one new position per batch row into the pool: row ``i``'s
    value lands in page ``page_table[i, positions[i] // ps]`` at offset
    ``positions[i] % ps``. Inactive/unmapped rows are redirected to page id
    ``n_pages`` -- out of bounds, so the jit scatter DROPS them (the paged
    analogue of attention._masked_row_write)."""
    n, ps = pages.shape[0], pages.shape[1]
    npg = page_table.shape[1]
    posv = jnp.maximum(positions, 0)
    rows = jnp.arange(page_table.shape[0])
    pp = page_table[rows, jnp.clip(posv // ps, 0, npg - 1)]
    pp = jnp.where(active & (pp >= 0), pp, n)        # OOB-high => dropped
    return pages.at[pp, posv % ps].set(val)


def paged_bulk_write(pages, page_row, vals):
    """Scatter a slot-contiguous tensor into the pool pages of ONE slot:
    ``vals`` (NP*ps, ...) reshaped to (NP, ps, ...) lands page-wise at the
    ids in ``page_row`` (NP,); entries < 0 (unallocated table slots) are
    redirected out of bounds and dropped. Used to insert a dense batch-1
    prefill result into a slot's pages -- every allocated page is fully
    (re)written, so recycled pages cannot leak stale state."""
    n, ps = pages.shape[0], pages.shape[1]
    npg = page_row.shape[0]
    dst = jnp.where(page_row >= 0, page_row, n)
    return pages.at[dst].set(vals.reshape((npg, ps) + pages.shape[2:]))


def init_mlp(key, d_model, d_ff, act="swiglu", dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    if act in ("swiglu", "geglu"):
        return {"wi": init_linear(k1, d_model, d_ff, dtype),
                "wg": init_linear(k2, d_model, d_ff, dtype),
                "wo": init_linear(k3, d_ff, d_model, dtype)}
    return {"wi": init_linear(k1, d_model, d_ff, dtype),
            "wo": init_linear(k3, d_ff, d_model, dtype)}


def apply_mlp(p, x, act="swiglu", packs=None, backend=None):
    def pk(name):
        return None if packs is None else packs.get(name)
    if act in ("swiglu", "geglu"):
        g = jax.nn.silu if act == "swiglu" else jax.nn.gelu
        h = g(linear(p["wg"], x, pk("wg"), backend)) * linear(p["wi"], x, pk("wi"), backend)
    else:
        h = act_fn(act)(linear(p["wi"], x, pk("wi"), backend))
    return linear(p["wo"], h, pk("wo"), backend)
