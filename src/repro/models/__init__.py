"""Model zoo: unified LM (dense/MoE/MLA/SSM/RG-LRU/VLM), enc-dec, BERT."""
from repro.models.api import (alloc_slot, decode_step, free_slot, init_cache,
                              init_model, model_forward, read_slot,
                              reset_slot, write_slot)
