"""Model zoo: unified LM (dense/MoE/MLA/SSM/RG-LRU/VLM), enc-dec, BERT."""
from repro.models.api import decode_step, init_cache, init_model, model_forward
