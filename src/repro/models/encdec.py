"""Encoder-decoder transformer backbone (whisper-base shape).

The audio conv frontend is a STUB per the assignment: ``input_specs`` feeds
precomputed frame embeddings (B, n_audio_ctx, d_model). Encoder layers are
bidirectional; decoder layers are causal self-attention + cross-attention.
Decode caches: ring self-KV + cross-K/V computed once at encode time.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models.common import (apply_mlp, apply_norm, init_mlp, init_norm,
                                 linear, normal_init)


def _init_enc_layer(key, cfg):
    ks = jax.random.split(key, 4)
    return {"norm1": init_norm(ks[0], cfg.d_model, cfg.norm, cfg.jdtype),
            "attn": attn.init_attention(ks[1], cfg),
            "norm2": init_norm(ks[2], cfg.d_model, cfg.norm, cfg.jdtype),
            "ffn": init_mlp(ks[3], cfg.d_model, cfg.d_ff, cfg.act, cfg.jdtype)}


def _init_dec_layer(key, cfg):
    ks = jax.random.split(key, 6)
    return {"norm1": init_norm(ks[0], cfg.d_model, cfg.norm, cfg.jdtype),
            "attn": attn.init_attention(ks[1], cfg),
            "norm_x": init_norm(ks[2], cfg.d_model, cfg.norm, cfg.jdtype),
            "xattn": attn.init_attention(ks[3], cfg),
            "norm2": init_norm(ks[4], cfg.d_model, cfg.norm, cfg.jdtype),
            "ffn": init_mlp(ks[5], cfg.d_model, cfg.d_ff, cfg.act, cfg.jdtype)}


def init_encdec(key, cfg: ModelConfig):
    ke, kd, ko = jax.random.split(key, 3)
    enc_keys = jax.random.split(ke, max(cfg.n_enc_layers, 1))
    dec_keys = jax.random.split(kd, max(cfg.n_layers, 1))
    ks = jax.random.split(ko, 4)
    return {
        "enc_pos": normal_init(ks[0], (cfg.n_audio_ctx, cfg.d_model), 0.02,
                               cfg.jdtype),
        "enc_layers": jax.vmap(lambda k: _init_enc_layer(k, cfg))(enc_keys),
        "enc_norm": init_norm(ks[1], cfg.d_model, cfg.norm, cfg.jdtype),
        "embed": {"w": normal_init(ks[2], (cfg.vocab_size, cfg.d_model), 0.02,
                                   cfg.jdtype)},
        "dec_layers": jax.vmap(lambda k: _init_dec_layer(k, cfg))(dec_keys),
        "dec_norm": init_norm(ks[3], cfg.d_model, cfg.norm, cfg.jdtype),
    }


def encode(params, cfg: ModelConfig, frames):
    """frames: (B, T_audio, d) stub embeddings -> encoder output."""
    h = frames.astype(cfg.jdtype) + params["enc_pos"][None]
    positions = jnp.arange(h.shape[1])[None]

    def body(h, lp):
        hn = apply_norm(lp["norm1"], h, cfg.norm)
        out, _ = attn.apply_attention(lp["attn"], hn, cfg,
                                      positions=positions, causal=False)
        h = h + out
        hn = apply_norm(lp["norm2"], h, cfg.norm)
        return h + apply_mlp(lp["ffn"], hn, cfg.act), None

    h, _ = jax.lax.scan(body, h, params["enc_layers"])
    return apply_norm(params["enc_norm"], h, cfg.norm)


def _cross_kv(lp, cfg, enc_out):
    b, t, _ = enc_out.shape
    k = linear(lp["xattn"]["wk"], enc_out).reshape(b, t, cfg.n_kv_heads,
                                                   cfg.head_dim)
    v = linear(lp["xattn"]["wv"], enc_out).reshape(b, t, cfg.n_kv_heads,
                                                   cfg.head_dim)
    return k, v


def _dec_layer(lp, h, cfg, *, positions, enc_out=None, cross_kv=None,
               cache=None, pos=None):
    hn = apply_norm(lp["norm1"], h, cfg.norm)
    out, new_self = attn.apply_attention(
        lp["attn"], hn, cfg, positions=positions,
        cache=cache.get("self") if cache else None, pos=pos)
    h = h + out
    hn = apply_norm(lp["norm_x"], h, cfg.norm)
    kv = cross_kv if cross_kv is not None else _cross_kv(lp, cfg, enc_out)
    out, _ = attn.apply_attention(
        lp["xattn"], hn, cfg, positions=positions, kv_override=kv,
        causal=False, cache={} if cache is not None else None, pos=pos)
    h = h + out
    hn = apply_norm(lp["norm2"], h, cfg.norm)
    h = h + apply_mlp(lp["ffn"], hn, cfg.act)
    return h, new_self


def forward(params, cfg: ModelConfig, frames, tokens):
    """Training forward: (frames, decoder tokens) -> logits."""
    enc_out = encode(params, cfg, frames)
    b, s = tokens.shape
    h = jnp.take(params["embed"]["w"], tokens, axis=0)
    positions = jnp.arange(s)[None]

    def body(h, lp):
        h, _ = _dec_layer(lp, h, cfg, positions=positions, enc_out=enc_out)
        return h, None

    h, _ = jax.lax.scan(jax.checkpoint(body, prevent_cse=False), h,
                        params["dec_layers"])
    h = apply_norm(params["dec_norm"], h, cfg.norm)
    return jnp.einsum("bsd,vd->bsv", h, params["embed"]["w"],
                      preferred_element_type=jnp.float32), jnp.zeros((), jnp.float32)


def init_cache(params, cfg: ModelConfig, frames, cache_len):
    """Run the encoder once; build per-layer self caches + cross K/V."""
    enc_out = encode(params, cfg, frames)
    b = frames.shape[0]
    self_cache = attn.init_cache_attn(cfg, b, cache_len)
    n_dec = jax.tree_util.tree_leaves(params["dec_layers"])[0].shape[0]
    stacked_self = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (n_dec,) + x.shape), self_cache)
    cross = jax.vmap(lambda lp: _cross_kv(lp, cfg, enc_out))(params["dec_layers"])
    return {"self": stacked_self, "cross_k": cross[0], "cross_v": cross[1]}


def decode_step(params, cache, cfg: ModelConfig, token, pos):
    """``pos``: scalar or ragged (B,) per-slot positions (lm.decode_step
    convention; rows with pos < 0 are inactive and leave their cache
    untouched)."""
    b = token.shape[0]
    h = jnp.take(params["embed"]["w"], token, axis=0)
    pos = attn.as_slot_positions(pos, b)
    positions = jnp.maximum(pos, 0)[:, None]

    def body(h, xs):
        lp, self_c, ck, cv = xs
        h, new_self = _dec_layer(lp, h, cfg, positions=positions,
                                 cross_kv=(ck, cv),
                                 cache={"self": self_c}, pos=pos)
        return h, new_self

    h, new_self = jax.lax.scan(
        body, h, (params["dec_layers"], cache["self"],
                  cache["cross_k"], cache["cross_v"]))
    h = apply_norm(params["dec_norm"], h, cfg.norm)
    logits = jnp.einsum("bsd,vd->bsv", h, params["embed"]["w"],
                        preferred_element_type=jnp.float32)
    return logits, {"self": new_self, "cross_k": cache["cross_k"],
                    "cross_v": cache["cross_v"]}


# ---------------------------------------------------------------------------
# slot lifecycle (every cache leaf is layer-stacked: slot dim at axis 1)
# ---------------------------------------------------------------------------

def reset_slot(cache, slot):
    """Zero request slot ``slot``: ring self-KV (+pos_map -> -1) AND the
    per-slot cross K/V, so a recycled slot cannot leak its previous
    request's audio context."""
    return jax.tree_util.tree_map_with_path(
        lambda p, x: x.at[:, slot].set(attn.slot_reset_value(p, x[:, slot])),
        cache)


def write_slot(cache, slot, sub):
    """Insert a batch-1 cache (init_cache over one request's frames) into
    slot ``slot`` -- admission writes both the fresh self cache and the
    request's encoder cross K/V."""
    return jax.tree_util.tree_map(lambda x, y: x.at[:, slot].set(y[:, 0]),
                                  cache, sub)


def read_slot(cache, slot):
    return jax.tree_util.tree_map(lambda x: x[:, slot:slot + 1], cache)
