"""BERT encoder (the paper's pruning target) with MLM head.

Post-LN transformer, learned positional embeddings, GELU FFN, tied MLM
decoder -- the classical BERT_BASE recipe (biases omitted; immaterial for the
systems study, noted in DESIGN.md). Layers are *unrolled* (12 at base scale)
so each layer can carry its own BSR pattern for sparse serving, matching the
paper's per-layer pruning of attention weights.

``packs`` routes attention/FC projections through the block-sparse kernels --
this is the TVM+ execution mode; ``packs=None`` is the dense baseline. The
pack entries are whatever repro/serving/export.py exported: per-layer patterns,
fused-QKV patterns (one dispatch per attention layer), or -- with cross-layer
union -- one shared RowPackPlan per projection group referenced by all 12
layer scopes, so the unrolled loop still compiles a single specialization
per group (docs/PERF.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models.common import (apply_mlp, apply_norm, init_mlp, init_norm,
                                 linear, normal_init)

MAX_POSITIONS = 512


def init_bert(key, cfg: ModelConfig):
    ks = jax.random.split(key, 6)
    layers = []
    for i in range(cfg.n_layers):
        lk = jax.random.split(jax.random.fold_in(ks[0], i), 4)
        layers.append({
            "attn": attn.init_attention(lk[0], cfg),
            "norm1": init_norm(lk[1], cfg.d_model, "ln", cfg.jdtype),
            "ffn": init_mlp(lk[2], cfg.d_model, cfg.d_ff, "gelu", cfg.jdtype),
            "norm2": init_norm(lk[3], cfg.d_model, "ln", cfg.jdtype),
        })
    return {
        "embed": {"w": normal_init(ks[1], (cfg.vocab_size, cfg.d_model), 0.02,
                                   cfg.jdtype)},
        "pos": normal_init(ks[2], (MAX_POSITIONS, cfg.d_model), 0.02, cfg.jdtype),
        "embed_norm": init_norm(ks[3], cfg.d_model, "ln", cfg.jdtype),
        "layers": tuple(layers),
        "mlm_dense": {"w": normal_init(ks[4], (cfg.d_model, cfg.d_model), 0.02,
                                       cfg.jdtype)},
        "mlm_norm": init_norm(ks[5], cfg.d_model, "ln", cfg.jdtype),
    }


def forward(params, cfg: ModelConfig, tokens, *, packs=None):
    """tokens (B, S) -> MLM logits (B, S, V) f32."""
    b, s = tokens.shape
    h = jnp.take(params["embed"]["w"], tokens, axis=0) + params["pos"][None, :s]
    h = apply_norm(params["embed_norm"], h, "ln")
    positions = jnp.arange(s)[None]
    for i, lp in enumerate(params["layers"]):
        lpacks = _sel(packs, f"layers/{i}")
        out, _ = attn.apply_attention(lp["attn"], h, cfg, positions=positions,
                                      causal=False,
                                      packs=_sel(lpacks, "attn"))
        h = apply_norm(lp["norm1"], h + out, "ln")           # post-LN
        out = apply_mlp(lp["ffn"], h, "gelu", packs=_sel(lpacks, "ffn"))
        h = apply_norm(lp["norm2"], h + out, "ln")
    t = jax.nn.gelu(linear(params["mlm_dense"], h))
    t = apply_norm(params["mlm_norm"], t, "ln")
    return jnp.einsum("bsd,vd->bsv", t, params["embed"]["w"],
                      preferred_element_type=jnp.float32)


def _sel(packs, scope):
    if not packs:
        return None
    pre = scope + "/"
    sel = {k[len(pre):]: v for k, v in packs.items() if k.startswith(pre)}
    return sel or None
