"""Unified decoder-only LM covering dense / MoE / MLA / SSM / hybrid / VLM.

Layer stacks follow the config's ``layer_plan()``: an unrolled prefix, a
lax.scan over ``n_periods`` repetitions of the (possibly heterogeneous)
``pattern``, and an unrolled suffix. This keeps HLO size O(len(pattern)) no
matter how deep the model -- required for tractable 512-device compiles --
while still expressing per-layer heterogeneity (gemma3 5:1 local:global,
recurrentgemma 1:2 attn:recurrent) with static layer kinds.

The paper's technique is first-class: when serving params are exported via
the repro.serving facade (prepare_servable / serving.export), attention and
mixer projections route through the BSR kernels (pattern static + per-layer
packed values scanned).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import LayerKind, ModelConfig
from repro.models import attention as attn
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.common import (apply_mlp, apply_norm, init_mlp, init_norm,
                                 normal_init, paged_bulk_write)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_layer(key, cfg: ModelConfig, kind: LayerKind):
    ks = jax.random.split(key, 4)
    p = {"norm1": init_norm(ks[0], cfg.d_model, cfg.norm, cfg.jdtype)}
    if kind.mixer in ("attn", "local"):
        p["attn"] = attn.init_attention(ks[1], cfg)
    elif kind.mixer == "mla":
        p["attn"] = mla_mod.init_mla(ks[1], cfg)
    elif kind.mixer == "ssm":
        p["mixer"] = ssm_mod.init_ssm(ks[1], cfg)
    elif kind.mixer == "rglru":
        p["mixer"] = rglru_mod.init_rglru(ks[1], cfg)
    else:
        raise ValueError(kind.mixer)
    if kind.ffn == "dense":
        p["norm2"] = init_norm(ks[2], cfg.d_model, cfg.norm, cfg.jdtype)
        p["ffn"] = init_mlp(ks[3], cfg.d_model, cfg.d_ff, cfg.act, cfg.jdtype)
    elif kind.ffn == "moe":
        p["norm2"] = init_norm(ks[2], cfg.d_model, cfg.norm, cfg.jdtype)
        p["ffn"] = moe_mod.init_moe(ks[3], cfg)
    return p


def init_lm(key, cfg: ModelConfig):
    prefix, pattern, n_periods, suffix = cfg.layer_plan()
    k_embed, k_head, k_rest = jax.random.split(key, 3)
    params = {"embed": {"w": normal_init(k_embed, (cfg.vocab_size, cfg.d_model),
                                         0.02, cfg.jdtype)},
              "final_norm": init_norm(k_head, cfg.d_model, cfg.norm, cfg.jdtype)}
    if not cfg.tie_embeddings:
        params["lm_head"] = {"w": normal_init(
            jax.random.fold_in(k_head, 1), (cfg.vocab_size, cfg.d_model),
            0.02, cfg.jdtype)}

    keys = jax.random.split(k_rest, 3)
    params["prefix"] = tuple(
        _init_layer(jax.random.fold_in(keys[0], i), cfg, kind)
        for i, kind in enumerate(prefix))
    params["blocks"] = tuple(
        jax.vmap(lambda k, i=i, kind=kind: _init_layer(k, cfg, kind))(
            jax.random.split(jax.random.fold_in(keys[1], i), max(n_periods, 1)))
        for i, kind in enumerate(pattern)) if n_periods > 0 else ()
    params["suffix"] = tuple(
        _init_layer(jax.random.fold_in(keys[2], i), cfg, kind)
        for i, kind in enumerate(suffix))
    return params


# ---------------------------------------------------------------------------
# layer application
# ---------------------------------------------------------------------------

def _apply_layer(p, h, cfg, kind: LayerKind, *, positions, cache=None,
                 pos=None, packs=None, prefill_len=None, page_slot=None,
                 page_start=None):
    hn = apply_norm(p["norm1"], h, cfg.norm)
    aux = jnp.zeros((), jnp.float32)
    mix_packs = _layer_packs(packs, "attn") or _layer_packs(packs, "mixer")
    if kind.mixer in ("attn", "local"):
        out, new_mix_cache = attn.apply_attention(
            p["attn"], hn, cfg, positions=positions, window=kind.window,
            cache=cache.get("mix") if cache else None, pos=pos,
            packs=mix_packs, prefill_len=prefill_len, page_slot=page_slot,
            page_start=page_start)
    elif kind.mixer == "mla":
        out, new_mix_cache = mla_mod.apply_mla(
            p["attn"], hn, cfg, positions=positions,
            cache=cache.get("mix") if cache else None, pos=pos,
            packs=mix_packs, prefill_len=prefill_len, page_slot=page_slot,
            page_start=page_start)
    elif kind.mixer == "ssm":
        out, new_mix_cache = ssm_mod.apply_ssm(
            p["mixer"], hn, cfg, cache=cache.get("mix") if cache else None,
            pos=pos, packs=mix_packs, prefill_len=prefill_len,
            page_slot=page_slot)
    elif kind.mixer == "rglru":
        out, new_mix_cache = rglru_mod.apply_rglru(
            p["mixer"], hn, cfg, cache=cache.get("mix") if cache else None,
            pos=pos, packs=mix_packs, prefill_len=prefill_len,
            page_slot=page_slot)
    # name the mixer output so the remat policy can pin it: the layer-body
    # recompute then skips re-running attention forward (saves ~2 of the 9
    # O(S^2) passes per layer; §Perf iter 4)
    from jax.ad_checkpoint import checkpoint_name
    out = checkpoint_name(out, "mixer_out")
    h = h + out

    if kind.ffn != "none" and "ffn" in p:
        hn = apply_norm(p["norm2"], h, cfg.norm)
        if kind.ffn == "moe":
            out, aux = moe_mod.apply_moe(p["ffn"], hn, cfg)
        else:
            out = apply_mlp(p["ffn"], hn, cfg.act,
                            packs=_layer_packs(packs, "ffn"))
        h = h + out
    new_cache = {"mix": new_mix_cache} if cache is not None else None
    return h, new_cache, aux


def _layer_packs(packs, scope):
    """Select this layer's packs: keys '<scope>/<name>' -> {'<name>': pack}."""
    if not packs:
        return None
    pre = scope + "/"
    sel = {k[len(pre):]: v for k, v in packs.items() if k.startswith(pre)}
    return sel or None


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def forward(params, cfg: ModelConfig, tokens, *, mm_embeds=None, packs=None):
    """tokens (B, S) -> logits (B, S, V) f32, aux loss."""
    prefix, pattern, n_periods, suffix = cfg.layer_plan()
    b, s = tokens.shape
    h = jnp.take(params["embed"]["w"], tokens, axis=0)
    if cfg.scale_embedding:
        h = h * jnp.asarray(cfg.d_model ** 0.5, h.dtype)
    if mm_embeds is not None:   # vlm: patch embeddings occupy the prefix slots
        p = mm_embeds.shape[1]
        h = jnp.concatenate([mm_embeds.astype(h.dtype), h[:, p:]], axis=1)
    positions = jnp.arange(s)[None, :]
    aux = jnp.zeros((), jnp.float32)

    for i, kind in enumerate(prefix):
        h, _, a = _apply_layer(params["prefix"][i], h, cfg, kind,
                               positions=positions,
                               packs=_layer_packs(packs, f"prefix/{i}"))
        aux += a

    if n_periods > 0:
        def body(carry, xs):
            h, aux = carry
            for i, kind in enumerate(pattern):
                h, _, a = _apply_layer(xs[i], h, cfg, kind,
                                       positions=positions,
                                       packs=_layer_packs(packs, f"blocks/{i}"))
                aux += a
            return (h, aux), None
        # NOTE §Perf iter 4 (refuted): a save_only_these_names("mixer_out")
        # remat policy was tried to skip attention-forward recompute; the
        # custom-vjp residuals must be rebuilt either way, so flops stayed
        # flat (-0.8%) while temp memory rose 42%. Full-recompute remat wins.
        body = jax.checkpoint(body, prevent_cse=False)
        (h, aux), _ = jax.lax.scan(body, (h, aux), params["blocks"])

    for i, kind in enumerate(suffix):
        h, _, a = _apply_layer(params["suffix"][i], h, cfg, kind,
                               positions=positions,
                               packs=_layer_packs(packs, f"suffix/{i}"))
        aux += a

    h = apply_norm(params["final_norm"], h, cfg.norm)
    head = params["embed"]["w"] if cfg.tie_embeddings else params["lm_head"]["w"]
    logits = jnp.einsum("bsd,vd->bsv", h, head,
                        preferred_element_type=jnp.float32)
    return logits, aux


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def _init_layer_cache(cfg, kind: LayerKind, batch, cache_len, paged=None):
    if kind.mixer in ("attn", "local"):
        return {"mix": attn.init_cache_attn(cfg, batch, cache_len, kind.window,
                                            paged=paged)}
    if kind.mixer == "mla":
        return {"mix": mla_mod.init_cache_mla(cfg, batch, cache_len,
                                              paged=paged)}
    if kind.mixer == "ssm":
        return {"mix": ssm_mod.init_cache_ssm(cfg, batch)}
    if kind.mixer == "rglru":
        return {"mix": rglru_mod.init_cache_rglru(cfg, batch)}
    raise ValueError(kind.mixer)


def init_cache(cfg: ModelConfig, batch, cache_len, paged=None):
    """``paged`` (models.common.PagedLayout or None) switches every linear
    (window == 0) attention/MLA layer onto page-pool storage; ring caches and
    SSM/RgLRU state stay slot-dense regardless (their footprint is O(window)
    or O(1) per slot, so paging them buys nothing)."""
    prefix, pattern, n_periods, suffix = cfg.layer_plan()
    def stack(kind):
        one = _init_layer_cache(cfg, kind, batch, cache_len, paged)
        return jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (n_periods,) + x.shape), one)
    return {
        "prefix": tuple(_init_layer_cache(cfg, k, batch, cache_len, paged)
                        for k in prefix),
        "blocks": tuple(stack(k) for k in pattern) if n_periods > 0 else (),
        "suffix": tuple(_init_layer_cache(cfg, k, batch, cache_len, paged)
                        for k in suffix),
    }


# ---------------------------------------------------------------------------
# slot lifecycle (continuous batching: the batch dim is request slots)
#
# Cache leaves carry the slot dim at axis 0 in the unrolled prefix/suffix
# sections and at axis 1 in the scan-stacked ``blocks`` groups (leading dim =
# layer period), so the slot ops are defined here where that layout is known.
# ---------------------------------------------------------------------------

def _map_slot_sections(fn0, fn1, *caches):
    """Apply ``fn0`` (slot axis 0) / ``fn1`` (slot axis 1) leafwise across
    one or more structurally identical caches."""
    tmap = jax.tree_util.tree_map
    return {
        "prefix": tuple(tmap(fn0, *cs)
                        for cs in zip(*(c["prefix"] for c in caches))),
        "blocks": tuple(tmap(fn1, *cs)
                        for cs in zip(*(c["blocks"] for c in caches))),
        "suffix": tuple(tmap(fn0, *cs)
                        for cs in zip(*(c["suffix"] for c in caches))),
    }


def _is_pool_leaf(path):
    """True for ``*_pages`` leaves, whose axis 0 is *physical pages*, not
    request slots -- a slot-indexed op on them would corrupt page ``slot``."""
    name = getattr(path[-1], "key", None)
    return isinstance(name, str) and name.endswith("_pages")


def reset_slot(cache, slot):
    """Zero request slot ``slot``: attention KV + pos_map AND the SSM/RgLRU
    recurrent and conv state, so a recycled slot cannot leak its previous
    request. Page pools are skipped (page hygiene is the allocator's job:
    ``pos_map``/``page_table`` reset to -1 here makes stale page content
    unreachable). Returns the updated cache (functional)."""
    reset = attn.slot_reset_value
    mp = jax.tree_util.tree_map_with_path
    f0 = lambda c: mp(lambda p, x: x if _is_pool_leaf(p)
                      else x.at[slot].set(reset(p, x[slot])), c)
    f1 = lambda c: mp(lambda p, x: x if _is_pool_leaf(p)
                      else x.at[:, slot].set(reset(p, x[:, slot])), c)
    return {"prefix": tuple(f0(c) for c in cache["prefix"]),
            "blocks": tuple(f1(c) for c in cache["blocks"]),
            "suffix": tuple(f0(c) for c in cache["suffix"])}


def write_slot(cache, slot, sub):
    """Insert single-request cache ``sub`` (batch == 1, e.g. a prefill
    result) into slot ``slot`` of the batched ``cache``."""
    return _map_slot_sections(lambda x, y: x.at[slot].set(y[0]),
                              lambda x, y: x.at[:, slot].set(y[:, 0]),
                              cache, sub)


def read_slot(cache, slot):
    """Extract slot ``slot`` as a batch-1 cache (the write_slot inverse)."""
    return _map_slot_sections(lambda x: x[slot:slot + 1],
                              lambda x: x[:, slot:slot + 1], cache)


# pool leaf -> the dense batch-1 sub-cache leaf that feeds it
_POOL_SRC = {"k_pages": "k", "v_pages": "v",
             "c_kv_pages": "c_kv", "k_rope_pages": "k_rope"}


def write_slot_paged(cache, slot, sub, page_row):
    """Insert a *dense* batch-1 prefill result ``sub`` into paged slot
    ``slot``: each pool leaf scatters the sub-cache rows page-by-page into
    the physical pages named by ``page_row`` (int32 (n_pages_per_slot,),
    -1 = unallocated -> dropped), the slot's ``page_table`` row becomes
    ``page_row`` and ``pos_map`` copies over. Every *allocated* page is
    fully written (sub content beyond the prompt is zeros), so recycled
    pages cannot leak stale or poisoned values. Non-paged leaves (rings,
    SSM/RgLRU state) take the ordinary dense slot write."""
    def ins(c, s, axis):
        m, ms = c["mix"], s["mix"]
        if "page_table" not in m:
            if axis == 0:
                return jax.tree_util.tree_map(
                    lambda x, y: x.at[slot].set(y[0]), c, s)
            return jax.tree_util.tree_map(
                lambda x, y: x.at[:, slot].set(y[:, 0]), c, s)
        out = {}
        for name, x in m.items():
            if name in _POOL_SRC:
                y = ms[_POOL_SRC[name]]
                if axis == 0:
                    out[name] = paged_bulk_write(x, page_row, y[0])
                else:                       # (P, n_pages, ps, ...) pools
                    out[name] = jax.vmap(
                        lambda pg, vl: paged_bulk_write(pg, page_row, vl)
                    )(x, y[:, 0])
            elif name == "page_table":
                if axis == 0:
                    out[name] = x.at[slot].set(page_row)
                else:
                    out[name] = x.at[:, slot].set(jnp.broadcast_to(
                        page_row, (x.shape[0],) + page_row.shape))
            else:                           # pos_map: plain dense insert
                out[name] = (x.at[slot].set(ms[name][0]) if axis == 0
                             else x.at[:, slot].set(ms[name][:, 0]))
        return {"mix": out}
    return {"prefix": tuple(ins(c, s, 0) for c, s in
                            zip(cache["prefix"], sub["prefix"])),
            "blocks": tuple(ins(c, s, 1) for c, s in
                            zip(cache["blocks"], sub["blocks"])),
            "suffix": tuple(ins(c, s, 0) for c, s in
                            zip(cache["suffix"], sub["suffix"]))}


def restore_slot_paged(cache, slot, page_row, resume_len):
    """Re-attach retained pages to slot ``slot`` after a preemption: write
    ``page_row`` back into the slot's page table and mark positions
    0..resume_len-1 live in ``pos_map``. Page *content* was never touched
    (refcounts held the pages out of the free list), so this restores the
    victim bit-exactly with zero prefill work. Paged layers only -- the
    engine gates retention to configs where every layer is paged."""
    def rst(c, axis):
        m = c["mix"]
        if "page_table" not in m:
            return c
        t = m["pos_map"].shape[-1]
        ar = jnp.arange(t)
        pm_row = jnp.where(ar < resume_len, ar, -1).astype(jnp.int32)
        out = dict(m)
        if axis == 0:
            out["page_table"] = m["page_table"].at[slot].set(page_row)
            out["pos_map"] = m["pos_map"].at[slot].set(pm_row)
        else:
            p = m["page_table"].shape[0]
            out["page_table"] = m["page_table"].at[:, slot].set(
                jnp.broadcast_to(page_row, (p,) + page_row.shape))
            out["pos_map"] = m["pos_map"].at[:, slot].set(
                jnp.broadcast_to(pm_row, (p, t)))
        return {"mix": out}
    return {"prefix": tuple(rst(c, 0) for c in cache["prefix"]),
            "blocks": tuple(rst(c, 1) for c in cache["blocks"]),
            "suffix": tuple(rst(c, 0) for c in cache["suffix"])}


def decode_step(params, cache, cfg: ModelConfig, token, pos, *, packs=None):
    """token (B, 1) + caches at absolute position ``pos`` -> (logits, cache).

    ``pos`` is a scalar (every row at the same position -- the single-request
    convention) or an int32 (B,) vector of ragged per-slot positions: each
    batch row is an independent request slot with its own causal/window mask
    and cache write slot. Rows with ``pos < 0`` are inactive -- their cache
    state is left untouched and their logits are meaningless.
    """
    prefix, pattern, n_periods, suffix = cfg.layer_plan()
    b = token.shape[0]
    h = jnp.take(params["embed"]["w"], token, axis=0)
    if cfg.scale_embedding:
        h = h * jnp.asarray(cfg.d_model ** 0.5, h.dtype)
    pos = attn.as_slot_positions(pos, b)
    positions = jnp.maximum(pos, 0)[:, None]          # (B, 1), rope-safe

    new_prefix = []
    for i, kind in enumerate(prefix):
        h, c, _ = _apply_layer(params["prefix"][i], h, cfg, kind,
                               positions=positions, cache=cache["prefix"][i],
                               pos=pos, packs=_layer_packs(packs, f"prefix/{i}"))
        new_prefix.append(c)

    new_blocks = cache["blocks"]
    if n_periods > 0:
        def body(h, xs):
            layer_ps, layer_cs = xs
            new_cs = []
            for i, kind in enumerate(pattern):
                h, c, _ = _apply_layer(layer_ps[i], h, cfg, kind,
                                       positions=positions, cache=layer_cs[i],
                                       pos=pos,
                                       packs=_layer_packs(packs, f"blocks/{i}"))
                new_cs.append(c)
            return h, tuple(new_cs)
        h, new_blocks = jax.lax.scan(body, h,
                                     (params["blocks"], cache["blocks"]))

    new_suffix = []
    for i, kind in enumerate(suffix):
        h, c, _ = _apply_layer(params["suffix"][i], h, cfg, kind,
                               positions=positions, cache=cache["suffix"][i],
                               pos=pos, packs=_layer_packs(packs, f"suffix/{i}"))
        new_suffix.append(c)

    h = apply_norm(params["final_norm"], h, cfg.norm)
    head = params["embed"]["w"] if cfg.tie_embeddings else params["lm_head"]["w"]
    logits = jnp.einsum("bsd,vd->bsv", h, head,
                        preferred_element_type=jnp.float32)
    new_cache = {"prefix": tuple(new_prefix), "blocks": new_blocks,
                 "suffix": tuple(new_suffix)}
    return logits, new_cache


def prefill_suffix(params, cache, cfg: ModelConfig, tokens, slot, start,
                   length=None, *, packs=None):
    """Prefill only the *suffix* ``tokens`` (1, S) of a prompt whose first
    ``start`` tokens are already resident in slot ``slot`` of the batched
    ``cache``: each layer writes the suffix KV (or carries recurrent state)
    at absolute positions start..start+length-1 for that slot and attends
    over resident-prefix + suffix with an explicit mask. Serves both the
    paged shared-prefix path (prefix-cache hit; PR 7) and dense-KV
    *chunked prefill* (docs/API.md §SLO scheduling), across every decode-
    capable mixer: global/windowed attention (dense rings + paged pools),
    MLA latents, SSM state carry, RG-LRU state carry. Sample the next
    token from ``logits[0, length - 1]`` after the final chunk."""
    prefix, pattern, n_periods, suffix = cfg.layer_plan()
    b, s = tokens.shape
    length = s if length is None else length
    h = jnp.take(params["embed"]["w"], tokens, axis=0)
    if cfg.scale_embedding:
        h = h * jnp.asarray(cfg.d_model ** 0.5, h.dtype)
    start = jnp.asarray(start, jnp.int32)
    positions = start + jnp.arange(s)[None, :]

    new_prefix = []
    for i, kind in enumerate(prefix):
        h, c, _ = _apply_layer(params["prefix"][i], h, cfg, kind,
                               positions=positions, cache=cache["prefix"][i],
                               prefill_len=length, page_slot=slot,
                               page_start=start,
                               packs=_layer_packs(packs, f"prefix/{i}"))
        new_prefix.append(c)

    new_blocks = cache["blocks"]
    if n_periods > 0:
        def body(h, xs):
            layer_ps, layer_cs = xs
            new_cs = []
            for i, kind in enumerate(pattern):
                h, c, _ = _apply_layer(layer_ps[i], h, cfg, kind,
                                       positions=positions, cache=layer_cs[i],
                                       prefill_len=length, page_slot=slot,
                                       page_start=start,
                                       packs=_layer_packs(packs, f"blocks/{i}"))
                new_cs.append(c)
            return h, tuple(new_cs)
        h, new_blocks = jax.lax.scan(body, h,
                                     (params["blocks"], cache["blocks"]))

    new_suffix = []
    for i, kind in enumerate(suffix):
        h, c, _ = _apply_layer(params["suffix"][i], h, cfg, kind,
                               positions=positions, cache=cache["suffix"][i],
                               prefill_len=length, page_slot=slot,
                               page_start=start,
                               packs=_layer_packs(packs, f"suffix/{i}"))
        new_suffix.append(c)

    h = apply_norm(params["final_norm"], h, cfg.norm)
    head = params["embed"]["w"] if cfg.tie_embeddings else params["lm_head"]["w"]
    logits = jnp.einsum("bsd,vd->bsv", h, head,
                        preferred_element_type=jnp.float32)
    new_cache = {"prefix": tuple(new_prefix), "blocks": new_blocks,
                 "suffix": tuple(new_suffix)}
    return logits, new_cache


def prefill_cache(params, cache, cfg: ModelConfig, tokens, length=None, *,
                  packs=None):
    """One-pass prompt prefill: ``tokens`` (B, S) starting at position 0 run
    through the *forward* attention/SSD/LRU paths (one weight stream for the
    whole prompt, not one per token), while every layer bulk-writes the
    state of positions 0..length-1 into ``cache``. ``length`` (<= S, traced
    OK) marks the real prompt; the tail is bucket padding and leaves no
    trace. Returns (logits (B, S, V) f32, cache) -- sample the next token
    from ``logits[:, length - 1]``.
    """
    prefix, pattern, n_periods, suffix = cfg.layer_plan()
    b, s = tokens.shape
    length = s if length is None else length
    h = jnp.take(params["embed"]["w"], tokens, axis=0)
    if cfg.scale_embedding:
        h = h * jnp.asarray(cfg.d_model ** 0.5, h.dtype)
    positions = jnp.arange(s)[None, :]

    new_prefix = []
    for i, kind in enumerate(prefix):
        h, c, _ = _apply_layer(params["prefix"][i], h, cfg, kind,
                               positions=positions, cache=cache["prefix"][i],
                               prefill_len=length,
                               packs=_layer_packs(packs, f"prefix/{i}"))
        new_prefix.append(c)

    new_blocks = cache["blocks"]
    if n_periods > 0:
        def body(h, xs):
            layer_ps, layer_cs = xs
            new_cs = []
            for i, kind in enumerate(pattern):
                h, c, _ = _apply_layer(layer_ps[i], h, cfg, kind,
                                       positions=positions, cache=layer_cs[i],
                                       prefill_len=length,
                                       packs=_layer_packs(packs, f"blocks/{i}"))
                new_cs.append(c)
            return h, tuple(new_cs)
        h, new_blocks = jax.lax.scan(body, h,
                                     (params["blocks"], cache["blocks"]))

    new_suffix = []
    for i, kind in enumerate(suffix):
        h, c, _ = _apply_layer(params["suffix"][i], h, cfg, kind,
                               positions=positions, cache=cache["suffix"][i],
                               prefill_len=length,
                               packs=_layer_packs(packs, f"suffix/{i}"))
        new_suffix.append(c)

    h = apply_norm(params["final_norm"], h, cfg.norm)
    head = params["embed"]["w"] if cfg.tie_embeddings else params["lm_head"]["w"]
    logits = jnp.einsum("bsd,vd->bsv", h, head,
                        preferred_element_type=jnp.float32)
    new_cache = {"prefix": tuple(new_prefix), "blocks": new_blocks,
                 "suffix": tuple(new_suffix)}
    return logits, new_cache
