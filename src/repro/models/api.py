"""Family-dispatching model API used by the launcher, tests and benchmarks.

batch keys by family:
  lm-like ('dense','moe','ssm','hybrid'): tokens (B,S) [, labels]
  'vlm':   tokens + mm_embeds (B,P,d)
  'audio': frames (B,T_audio,d) + tokens (B,S)
  'bert':  tokens
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import bert as bert_mod
from repro.models import encdec as encdec_mod
from repro.models import lm as lm_mod
from repro.models.attention import as_slot_positions
from repro.models.sampling import sample_tokens


def init_model(key, cfg: ModelConfig):
    if cfg.family == "bert":
        return bert_mod.init_bert(key, cfg)
    if cfg.family == "audio":
        return encdec_mod.init_encdec(key, cfg)
    return lm_mod.init_lm(key, cfg)


def model_forward(params, cfg: ModelConfig, batch, packs=None):
    """-> (logits f32, aux)."""
    if cfg.family == "bert":
        return bert_mod.forward(params, cfg, batch["tokens"], packs=packs), \
            jnp.zeros((), jnp.float32)
    if cfg.family == "audio":
        return encdec_mod.forward(params, cfg, batch["frames"], batch["tokens"])
    if cfg.family == "vlm":
        return lm_mod.forward(params, cfg, batch["tokens"],
                              mm_embeds=batch.get("mm_embeds"), packs=packs)
    return lm_mod.forward(params, cfg, batch["tokens"], packs=packs)


def init_cache(params, cfg: ModelConfig, batch_size, cache_len, frames=None,
               paged=None):
    """``paged`` (models.common.PagedLayout or None): page-pool storage for
    linear attention/MLA KV (lm-family only; serving/paging.py owns the
    allocator that hands out page ids)."""
    if cfg.family == "audio":
        if paged is not None:
            raise ValueError("paged KV is not supported for family 'audio'")
        return encdec_mod.init_cache(params, cfg, frames, cache_len)
    if cfg.family == "bert":
        raise ValueError("encoder-only arch has no decode step")
    return lm_mod.init_cache(cfg, batch_size, cache_len, paged=paged)


def cache_shardings(cache, mesh):
    """NamedSharding tree for a decode cache on ``mesh``: request slots
    (the batch dim) shard over "data", KV heads / recurrent state over
    "model" -- the divisibility-aware rules of
    ``launch/sharding.spec_for_cache``. ``cache`` may be real arrays or
    ShapeDtypeStructs."""
    from repro.launch.sharding import cache_shardings as _cache_shardings
    return _cache_shardings(cache, mesh)


def shard_cache(cache, cfg: ModelConfig, mesh):
    """Place a decode cache on ``mesh`` (see :func:`cache_shardings`).
    Slot lifecycle ops (:func:`write_slot` / :func:`free_slot` /
    :func:`reset_slot`) are sharding-preserving device scatters, so a
    placed cache never gathers back to host across its lifetime."""
    return jax.device_put(cache, cache_shardings(cache, mesh))


def decode_step(params, cache, cfg: ModelConfig, token, pos, packs=None):
    """``pos``: scalar (single-request convention, broadcast) or int32 (B,)
    ragged per-slot positions; rows with pos < 0 are inactive slots whose
    cache state is left untouched (continuous batching, docs/API.md)."""
    if cfg.family == "audio":
        return encdec_mod.decode_step(params, cache, cfg, token, pos)
    if cfg.family == "bert":
        raise ValueError("encoder-only arch has no decode step")
    return lm_mod.decode_step(params, cache, cfg, token, pos, packs=packs)


def decode_many(params, cache, cfg: ModelConfig, token, pos, n_steps, *,
                packs=None, remaining=None, eos_id=None, key=None,
                temperature: float = 0.0, top_k: int = 0):
    """Fused multi-token decode: ``n_steps`` decode steps inside ONE
    ``lax.scan``, with sampling, per-slot EOS/stop handling and position
    bookkeeping all on device -- the host only syncs once per window
    (repro/serving/engine.py drains the emitted tokens at sync points).

    Args:
      token: (B, 1) int32 -- the current token of each request slot.
      pos: scalar or ragged (B,) int32 slot positions (the ``decode_step``
        convention; pos < 0 = inactive slot, a device-side no-op).
      n_steps: static window length K.
      remaining: optional (B,) int32 token budget per slot; a slot that
        exhausts it mid-window deactivates itself (pos -> -1) and emits
        nothing further. None = unbounded within the window.
      eos_id: optional scalar or (B,) int32 stop token per slot (-1 =
        none); sampling it deactivates the slot *after* emitting it.
      key / temperature / top_k: sampling config (models/sampling.py);
        temperature 0 = greedy, and the PRNG key is folded by (slot,
        position) so fused and per-step decoding sample identically.

    Non-finite guard: a slot whose logits go non-finite (NaN/inf anywhere
    in its row) is quarantined ON DEVICE mid-window -- the poisoned token
    is never emitted (``valid`` False), the slot deactivates (pos -> -1)
    and rides the rest of the window as a no-op, and ``state['failed']``
    flags it at the sync point. Other slots are untouched: per-slot
    compute is batch-row independent, so they finish bit-identically to a
    window with no poisoned co-resident (tests/test_chaos.py).

    Returns ``(tokens (K, B) int32, valid (K, B) bool, state)`` where
    ``valid[k, b]`` marks tokens actually emitted by live slots and
    ``state`` is the carry to continue from:
    ``{'token', 'pos', 'remaining', 'failed', 'cache'}``.
    """
    b = token.shape[0]
    pos = as_slot_positions(pos, b)
    if remaining is None:
        remaining = jnp.full((b,), jnp.iinfo(jnp.int32).max // 2, jnp.int32)
    else:
        remaining = jnp.asarray(remaining, jnp.int32)
    if eos_id is None:
        eos = jnp.full((b,), -1, jnp.int32)
    else:
        eos = jnp.broadcast_to(jnp.asarray(eos_id, jnp.int32), (b,))
    if key is None:
        key = jax.random.PRNGKey(0)

    failed0 = jnp.zeros((b,), bool)

    def body(carry, _):
        tok, p, rem, bad, c = carry
        logits, c = decode_step(params, c, cfg, tok, p, packs=packs)
        rows = logits[:, 0, :]
        finite = jnp.isfinite(rows).all(axis=-1)
        nxt = sample_tokens(rows, key, p, temperature=temperature,
                            top_k=top_k)
        active = p >= 0
        poisoned = active & ~finite
        emit = active & finite
        nxt = jnp.where(emit, nxt, 0)
        rem = jnp.where(emit, rem - 1, rem)
        done = emit & ((rem <= 0) | ((eos >= 0) & (nxt == eos)))
        new_pos = jnp.where(done | poisoned, -1,
                            jnp.where(active, p + 1, p))
        new_tok = jnp.where(emit, nxt, tok[:, 0])[:, None]
        return (new_tok, new_pos, rem, bad | poisoned, c), (nxt, emit)

    (token, pos, remaining, failed, cache), (toks, valid) = jax.lax.scan(
        body, (token, pos, remaining, failed0, cache), None, length=n_steps)
    state = {"token": token, "pos": pos, "remaining": remaining,
             "failed": failed, "cache": cache}
    return toks, valid, state


def prefill_cache(params, cache, cfg: ModelConfig, tokens, length=None,
                  packs=None):
    """One-pass prompt prefill into a decode cache (lm-family layouts):
    forward-path compute for tokens (B, S), bulk cache writes for positions
    0..length-1 (length <= S; the tail is bucket padding). Returns
    (logits (B, S, V), cache). Audio prefills through the scanned decode
    path instead (its decoder prompts are BOS-sized)."""
    if cfg.family in ("audio", "bert"):
        raise ValueError(f"no one-pass prefill for family {cfg.family!r}")
    return lm_mod.prefill_cache(params, cache, cfg, tokens, length,
                                packs=packs)


# ---------------------------------------------------------------------------
# slot lifecycle: the batch dimension of a decode cache is request slots
# (continuous batching, repro/serving/engine.py)
# ---------------------------------------------------------------------------

def _slot_mod(cfg: ModelConfig):
    if cfg.family == "bert":
        raise ValueError("encoder-only arch has no decode cache")
    return encdec_mod if cfg.family == "audio" else lm_mod


def reset_slot(cache, cfg: ModelConfig, slot):
    """Zero one request slot: attention KV (pos_map -> empty) and SSM/RgLRU
    recurrent + conv state, so a recycled slot cannot leak its previous
    request. Returns the updated cache."""
    return _slot_mod(cfg).reset_slot(cache, slot)


def alloc_slot(cache, cfg: ModelConfig, slot):
    """Claim ``slot`` for a new request: identical state-wise to
    :func:`reset_slot` (a fresh slot IS a zeroed slot); named separately so
    admission and retirement read as a lifecycle."""
    return _slot_mod(cfg).reset_slot(cache, slot)


def free_slot(cache, cfg: ModelConfig, slot):
    """Retire ``slot`` after request completion (state hygiene: the zeroing
    is what guarantees recycled slots start from a fresh cache)."""
    return _slot_mod(cfg).reset_slot(cache, slot)


def write_slot(cache, cfg: ModelConfig, slot, sub):
    """Insert a batch-1 cache (e.g. a prefill result) into ``slot``."""
    return _slot_mod(cfg).write_slot(cache, slot, sub)


def read_slot(cache, cfg: ModelConfig, slot):
    """Extract ``slot`` as a batch-1 cache (write_slot's inverse)."""
    return _slot_mod(cfg).read_slot(cache, slot)


def write_slot_paged(cache, cfg: ModelConfig, slot, sub, page_row):
    """Insert a dense batch-1 prefill result into paged slot ``slot``,
    scattering KV rows into the physical pages named by ``page_row``
    (lm-family paged caches only; see lm.write_slot_paged)."""
    if cfg.family in ("audio", "bert"):
        raise ValueError(f"no paged slots for family {cfg.family!r}")
    return lm_mod.write_slot_paged(cache, slot, sub, page_row)


def restore_slot_paged(cache, cfg: ModelConfig, slot, page_row, resume_len):
    """Re-attach retained pages to ``slot`` after preemption (bit-exact,
    zero prefill; see lm.restore_slot_paged)."""
    if cfg.family in ("audio", "bert"):
        raise ValueError(f"no paged slots for family {cfg.family!r}")
    return lm_mod.restore_slot_paged(cache, slot, page_row, resume_len)


def prefill_suffix(params, cache, cfg: ModelConfig, tokens, slot, start,
                   length=None, packs=None):
    """Prefill only the suffix of a prompt whose first ``start`` tokens are
    already resident in slot ``slot`` of the batched engine cache: the
    paged shared-prefix path (prefix-cache hit) and the dense-KV chunked-
    prefill path share this entry point (see lm.prefill_suffix)."""
    if cfg.family in ("audio", "bert"):
        raise ValueError(f"no one-pass prefill for family {cfg.family!r}")
    return lm_mod.prefill_suffix(params, cache, cfg, tokens, slot, start,
                                 length, packs=packs)
