"""Family-dispatching model API used by the launcher, tests and benchmarks.

batch keys by family:
  lm-like ('dense','moe','ssm','hybrid'): tokens (B,S) [, labels]
  'vlm':   tokens + mm_embeds (B,P,d)
  'audio': frames (B,T_audio,d) + tokens (B,S)
  'bert':  tokens
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import bert as bert_mod
from repro.models import encdec as encdec_mod
from repro.models import lm as lm_mod


def init_model(key, cfg: ModelConfig):
    if cfg.family == "bert":
        return bert_mod.init_bert(key, cfg)
    if cfg.family == "audio":
        return encdec_mod.init_encdec(key, cfg)
    return lm_mod.init_lm(key, cfg)


def model_forward(params, cfg: ModelConfig, batch, packs=None):
    """-> (logits f32, aux)."""
    if cfg.family == "bert":
        return bert_mod.forward(params, cfg, batch["tokens"], packs=packs), \
            jnp.zeros((), jnp.float32)
    if cfg.family == "audio":
        return encdec_mod.forward(params, cfg, batch["frames"], batch["tokens"])
    if cfg.family == "vlm":
        return lm_mod.forward(params, cfg, batch["tokens"],
                              mm_embeds=batch.get("mm_embeds"), packs=packs)
    return lm_mod.forward(params, cfg, batch["tokens"], packs=packs)


def init_cache(params, cfg: ModelConfig, batch_size, cache_len, frames=None):
    if cfg.family == "audio":
        return encdec_mod.init_cache(params, cfg, frames, cache_len)
    if cfg.family == "bert":
        raise ValueError("encoder-only arch has no decode step")
    return lm_mod.init_cache(cfg, batch_size, cache_len)


def decode_step(params, cache, cfg: ModelConfig, token, pos, packs=None):
    """``pos``: scalar (single-request convention, broadcast) or int32 (B,)
    ragged per-slot positions; rows with pos < 0 are inactive slots whose
    cache state is left untouched (continuous batching, docs/API.md)."""
    if cfg.family == "audio":
        return encdec_mod.decode_step(params, cache, cfg, token, pos)
    if cfg.family == "bert":
        raise ValueError("encoder-only arch has no decode step")
    return lm_mod.decode_step(params, cache, cfg, token, pos, packs=packs)


def prefill_cache(params, cache, cfg: ModelConfig, tokens, length=None,
                  packs=None):
    """One-pass prompt prefill into a decode cache (lm-family layouts):
    forward-path compute for tokens (B, S), bulk cache writes for positions
    0..length-1 (length <= S; the tail is bucket padding). Returns
    (logits (B, S, V), cache). Audio prefills through the scanned decode
    path instead (its decoder prompts are BOS-sized)."""
    if cfg.family in ("audio", "bert"):
        raise ValueError(f"no one-pass prefill for family {cfg.family!r}")
    return lm_mod.prefill_cache(params, cache, cfg, tokens, length,
                                packs=packs)


# ---------------------------------------------------------------------------
# slot lifecycle: the batch dimension of a decode cache is request slots
# (continuous batching, repro/serving/engine.py)
# ---------------------------------------------------------------------------

def _slot_mod(cfg: ModelConfig):
    if cfg.family == "bert":
        raise ValueError("encoder-only arch has no decode cache")
    return encdec_mod if cfg.family == "audio" else lm_mod


def reset_slot(cache, cfg: ModelConfig, slot):
    """Zero one request slot: attention KV (pos_map -> empty) and SSM/RgLRU
    recurrent + conv state, so a recycled slot cannot leak its previous
    request. Returns the updated cache."""
    return _slot_mod(cfg).reset_slot(cache, slot)


def alloc_slot(cache, cfg: ModelConfig, slot):
    """Claim ``slot`` for a new request: identical state-wise to
    :func:`reset_slot` (a fresh slot IS a zeroed slot); named separately so
    admission and retirement read as a lifecycle."""
    return _slot_mod(cfg).reset_slot(cache, slot)


def free_slot(cache, cfg: ModelConfig, slot):
    """Retire ``slot`` after request completion (state hygiene: the zeroing
    is what guarantees recycled slots start from a fresh cache)."""
    return _slot_mod(cfg).reset_slot(cache, slot)


def write_slot(cache, cfg: ModelConfig, slot, sub):
    """Insert a batch-1 cache (e.g. a prefill result) into ``slot``."""
    return _slot_mod(cfg).write_slot(cache, slot, sub)


def read_slot(cache, cfg: ModelConfig, slot):
    """Extract ``slot`` as a batch-1 cache (write_slot's inverse)."""
    return _slot_mod(cfg).read_slot(cache, slot)
