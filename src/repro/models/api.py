"""Family-dispatching model API used by the launcher, tests and benchmarks.

batch keys by family:
  lm-like ('dense','moe','ssm','hybrid'): tokens (B,S) [, labels]
  'vlm':   tokens + mm_embeds (B,P,d)
  'audio': frames (B,T_audio,d) + tokens (B,S)
  'bert':  tokens
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import bert as bert_mod
from repro.models import encdec as encdec_mod
from repro.models import lm as lm_mod


def init_model(key, cfg: ModelConfig):
    if cfg.family == "bert":
        return bert_mod.init_bert(key, cfg)
    if cfg.family == "audio":
        return encdec_mod.init_encdec(key, cfg)
    return lm_mod.init_lm(key, cfg)


def model_forward(params, cfg: ModelConfig, batch, packs=None):
    """-> (logits f32, aux)."""
    if cfg.family == "bert":
        return bert_mod.forward(params, cfg, batch["tokens"], packs=packs), \
            jnp.zeros((), jnp.float32)
    if cfg.family == "audio":
        return encdec_mod.forward(params, cfg, batch["frames"], batch["tokens"])
    if cfg.family == "vlm":
        return lm_mod.forward(params, cfg, batch["tokens"],
                              mm_embeds=batch.get("mm_embeds"), packs=packs)
    return lm_mod.forward(params, cfg, batch["tokens"], packs=packs)


def init_cache(params, cfg: ModelConfig, batch_size, cache_len, frames=None):
    if cfg.family == "audio":
        return encdec_mod.init_cache(params, cfg, frames, cache_len)
    if cfg.family == "bert":
        raise ValueError("encoder-only arch has no decode step")
    return lm_mod.init_cache(cfg, batch_size, cache_len)


def decode_step(params, cache, cfg: ModelConfig, token, pos, packs=None):
    if cfg.family == "audio":
        return encdec_mod.decode_step(params, cache, cfg, token, pos)
    if cfg.family == "bert":
        raise ValueError("encoder-only arch has no decode step")
    return lm_mod.decode_step(params, cache, cfg, token, pos, packs=packs)
