"""Multi-head Latent Attention (DeepSeek-V2): compressed KV cache via low-rank
joint projection.

Train/prefill path expands K/V from the latent c_kv per token. Decode path
uses the *absorbed* formulation: W_uk is folded into the query so attention
scores are taken directly against the (T, kv_lora_rank) latent cache --
the cache is rank*T instead of 2*H*D*T, which is the technique's point.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.attention import decode_attention, flash_attention, full_attention
from repro.models.common import apply_rope, init_linear, linear, rms_norm


def init_mla(key, cfg):
    d, h = cfg.d_model, cfg.n_heads
    r = cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq": init_linear(ks[0], d, h * (dn + dr), cfg.jdtype),
        "w_dkv": init_linear(ks[1], d, r, cfg.jdtype),       # down: x -> c_kv
        "w_krope": init_linear(ks[2], d, dr, cfg.jdtype),    # shared rope key
        "w_uk": init_linear(ks[3], r, h * dn, cfg.jdtype),   # up: c_kv -> k_nope
        "w_uv": init_linear(ks[4], r, h * dv, cfg.jdtype),   # up: c_kv -> v
        "wo": init_linear(ks[5], h * dv, d, cfg.jdtype),
        "kv_norm": {"scale": jnp.zeros((r,), cfg.jdtype)},
    }


def init_cache_mla(cfg, batch, cache_len, dtype=None):
    dtype = dtype or cfg.jdtype
    return {"c_kv": jnp.zeros((batch, cache_len, cfg.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, cache_len, cfg.qk_rope_dim), dtype),
            "pos_map": jnp.full((cache_len,), -1, jnp.int32)}


def _project_q(p, x, cfg):
    b, s, _ = x.shape
    h, dn, dr = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim
    q = linear(p["wq"], x).reshape(b, s, h, dn + dr)
    return q[..., :dn], q[..., dn:]


def apply_mla(p, x, cfg, *, positions, cache=None, pos=None, packs=None):
    b, s, d = x.shape
    h, dn, dr, dv = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    q_nope, q_rope = _project_q(p, x, cfg)
    q_rope = apply_rope(q_rope, positions, theta=cfg.rope_theta)

    c_kv = rms_norm(linear(p["w_dkv"], x), p["kv_norm"]["scale"])
    k_rope = apply_rope(linear(p["w_krope"], x)[:, :, None, :],
                        positions, theta=cfg.rope_theta)       # (b,s,1,dr)

    if cache is None:
        # expanded path: materialize per-head K/V from latents
        k_nope = linear(p["w_uk"], c_kv).reshape(b, s, h, dn)
        v = linear(p["w_uv"], c_kv).reshape(b, s, h, dv)
        k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (b, s, h, dr))],
                            axis=-1)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        # pad v to qk head dim so the shared attention kernels apply
        attn = full_attention if s <= 1024 else flash_attention
        vp = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, dn + dr - dv)))
        o = attn(q, k, vp, causal=True)[..., :dv]
        out = linear(p["wo"], o.reshape(b, s, h * dv),
                     packs and packs.get("wo"))
        return out, None

    # ---- absorbed decode: score against the latent cache ----------------
    assert s == 1 and pos is not None
    t = cache["c_kv"].shape[1]
    slot = pos % t
    c_cache = jax.lax.dynamic_update_slice(cache["c_kv"], c_kv, (0, slot, 0))
    r_cache = jax.lax.dynamic_update_slice(cache["k_rope"], k_rope[:, :, 0, :],
                                           (0, slot, 0))
    pm = cache["pos_map"].at[slot].set(pos)
    new_cache = {"c_kv": c_cache, "k_rope": r_cache, "pos_map": pm}

    w_uk = p["w_uk"]["w"].reshape(h, dn, cfg.kv_lora_rank)    # (h, dn, r)
    q_abs = jnp.einsum("bqhd,hdr->bqhr", q_nope, w_uk)        # (b,1,h,r)
    s_lat = jnp.einsum("bqhr,btr->bhqt", q_abs.astype(jnp.float32),
                       c_cache.astype(jnp.float32))
    s_rope = jnp.einsum("bqhd,btd->bhqt", q_rope.astype(jnp.float32),
                        r_cache.astype(jnp.float32))
    scores = (s_lat + s_rope) * ((dn + dr) ** -0.5)
    ok = (pm >= 0) & (pm <= pos)
    scores = jnp.where(ok[None, None, None, :], scores, -1e30)
    pr = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhqt,btr->bqhr", pr, c_cache.astype(jnp.float32))
    w_uv = p["w_uv"]["w"].reshape(h, dv, cfg.kv_lora_rank)
    o = jnp.einsum("bqhr,hvr->bqhv", ctx, w_uv).astype(x.dtype)
    out = linear(p["wo"], o.reshape(b, 1, h * dv), packs and packs.get("wo"))
    return out, new_cache
