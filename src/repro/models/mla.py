"""Multi-head Latent Attention (DeepSeek-V2): compressed KV cache via low-rank
joint projection.

Train/prefill path expands K/V from the latent c_kv per token. Decode path
uses the *absorbed* formulation: W_uk is folded into the query so attention
scores are taken directly against the (T, kv_lora_rank) latent cache --
the cache is rank*T instead of 2*H*D*T, which is the technique's point.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.attention import (_masked_row_write, as_slot_positions,
                                    decode_attention, flash_attention,
                                    full_attention, masked_attention,
                                    paged_suffix_positions,
                                    prefill_slot_sources)
from repro.models.common import (apply_rope, init_linear, linear,
                                 paged_row_write, paged_view, rms_norm)


def init_mla(key, cfg):
    d, h = cfg.d_model, cfg.n_heads
    r = cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq": init_linear(ks[0], d, h * (dn + dr), cfg.jdtype),
        "w_dkv": init_linear(ks[1], d, r, cfg.jdtype),       # down: x -> c_kv
        "w_krope": init_linear(ks[2], d, dr, cfg.jdtype),    # shared rope key
        "w_uk": init_linear(ks[3], r, h * dn, cfg.jdtype),   # up: c_kv -> k_nope
        "w_uv": init_linear(ks[4], r, h * dv, cfg.jdtype),   # up: c_kv -> v
        "wo": init_linear(ks[5], h * dv, d, cfg.jdtype),
        "kv_norm": {"scale": jnp.zeros((r,), cfg.jdtype)},
    }


def init_cache_mla(cfg, batch, cache_len, dtype=None, paged=None):
    """Latent decode cache; ``paged`` (models.common.PagedLayout) stores the
    latents in page pools (n_pages, page_size, r) addressed through a
    per-slot page table, sharing ids with the attention pools (one logical
    page serves every layer). ``pos_map`` stays dense (batch, T) so the
    absorbed-decode masking is unchanged."""
    dtype = dtype or cfg.jdtype
    if paged is not None:
        npg = paged.table_width(cache_len)
        return {"c_kv_pages": jnp.zeros(
                    (paged.n_pages, paged.page_size, cfg.kv_lora_rank),
                    dtype),
                "k_rope_pages": jnp.zeros(
                    (paged.n_pages, paged.page_size, cfg.qk_rope_dim),
                    dtype),
                "page_table": jnp.full((batch, npg), -1, jnp.int32),
                "pos_map": jnp.full((batch, cache_len), -1, jnp.int32)}
    return {"c_kv": jnp.zeros((batch, cache_len, cfg.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, cache_len, cfg.qk_rope_dim), dtype),
            "pos_map": jnp.full((batch, cache_len), -1, jnp.int32)}


def _project_q(p, x, cfg, packs=None):
    b, s, _ = x.shape
    h, dn, dr = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim
    q = linear(p["wq"], x, packs and packs.get("wq")).reshape(b, s, h, dn + dr)
    return q[..., :dn], q[..., dn:]


def apply_mla(p, x, cfg, *, positions, cache=None, pos=None, packs=None,
              prefill_len=None, page_slot=None, page_start=None):
    b, s, d = x.shape
    h, dn, dr, dv = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    q_nope, q_rope = _project_q(p, x, cfg, packs)
    q_rope = apply_rope(q_rope, positions, theta=cfg.rope_theta)

    c_kv = rms_norm(linear(p["w_dkv"], x), p["kv_norm"]["scale"])
    k_rope = apply_rope(linear(p["w_krope"], x)[:, :, None, :],
                        positions, theta=cfg.rope_theta)       # (b,s,1,dr)

    if cache is not None and s > 1 and page_slot is not None:
        # chunk/suffix prefill: x holds ONE slot's next prompt slice at
        # absolute positions page_start.. . The latent cache is linear
        # (slot == position, no ring), so the chunk's latents write first
        # and the queries attend EXPANDED K/V materialized from the updated
        # latent view -- the same per-token expansion the one-shot prefill
        # runs, just against cached latents for positions < page_start.
        assert b == 1
        length = s if prefill_len is None else prefill_len
        start = jnp.asarray(page_start, jnp.int32)
        pos_i = start + jnp.arange(s)
        validw = jnp.arange(s) < length
        if "c_kv_pages" in cache:
            n, psz = (cache["c_kv_pages"].shape[0],
                      cache["c_kv_pages"].shape[1])
            npg = cache["page_table"].shape[1]
            pt_row = cache["page_table"][page_slot]              # (NP,)
            pp = pt_row[jnp.clip(pos_i // psz, 0, npg - 1)]
            pp = jnp.where(validw & (pp >= 0), pp, n)            # OOB: drop
            cp = cache["c_kv_pages"].at[pp, pos_i % psz].set(c_kv[0])
            rp = cache["k_rope_pages"].at[pp, pos_i % psz].set(
                k_rope[0, :, 0, :])
            pm_row = paged_suffix_positions(npg * psz, start, length)
            new_cache = {"c_kv_pages": cp, "k_rope_pages": rp,
                         "pos_map": cache["pos_map"].at[page_slot].set(
                             pm_row),
                         "page_table": cache["page_table"]}
            c_view = paged_view(cp, pt_row[None], pm_row[None])  # (1,T,r)
            r_view = paged_view(rp, pt_row[None], pm_row[None])  # (1,T,dr)
        else:
            t = cache["c_kv"].shape[1]
            nslots = cache["c_kv"].shape[0]
            dst = jnp.where(validw, pos_i, t)           # OOB: drop padding
            c_row = cache["c_kv"][page_slot].at[dst].set(
                c_kv[0].astype(cache["c_kv"].dtype))
            r_row = cache["k_rope"][page_slot].at[dst].set(
                k_rope[0, :, 0, :].astype(cache["k_rope"].dtype))
            pm = cache["pos_map"]
            if pm.ndim == 1:                            # legacy shared map
                pm = jnp.broadcast_to(pm, (nslots, t))
            pm_row = paged_suffix_positions(t, start, length)
            new_cache = {"c_kv": cache["c_kv"].at[page_slot].set(c_row),
                         "k_rope": cache["k_rope"].at[page_slot].set(r_row),
                         "pos_map": pm.at[page_slot].set(pm_row)}
            c_view, r_view = c_row[None], r_row[None]
        tv = c_view.shape[1]
        k_nope_all = linear(p["w_uk"], c_view).reshape(1, tv, h, dn)
        v_all = linear(p["w_uv"], c_view).reshape(1, tv, h, dv)
        k_all = jnp.concatenate(
            [k_nope_all,
             jnp.broadcast_to(r_view[:, :, None, :], (1, tv, h, dr))],
            axis=-1)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        vp = jnp.pad(v_all, ((0, 0), (0, 0), (0, 0), (0, dn + dr - dv)))
        qpos = pos_i[None, :, None]                              # (1,S,1)
        ok = (pm_row[None, None, :] >= 0) & (pm_row[None, None, :] <= qpos)
        o = masked_attention(q, k_all, vp, ok)[..., :dv]
        out = linear(p["wo"], o.reshape(1, s, h * dv),
                     packs and packs.get("wo"))
        return out, new_cache

    if cache is None or s > 1:
        # expanded path: materialize per-head K/V from latents
        k_nope = linear(p["w_uk"], c_kv).reshape(b, s, h, dn)
        v = linear(p["w_uv"], c_kv).reshape(b, s, h, dv)
        k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (b, s, h, dr))],
                            axis=-1)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        # pad v to qk head dim so the shared attention kernels apply
        attn = full_attention if s <= 1024 else flash_attention
        vp = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, dn + dr - dv)))
        o = attn(q, k, vp, causal=True)[..., :dv]
        out = linear(p["wo"], o.reshape(b, s, h * dv),
                     packs and packs.get("wo"))
        if cache is None:
            return out, None
        if "c_kv_pages" in cache:
            raise NotImplementedError(
                "whole-cache prompt prefill is undefined for a paged MLA "
                "layout; prefill a dense batch-1 sub-cache and insert it "
                "with write_slot_paged")
        # prompt prefill: bulk-write the latent cache (linear, T >= prompt)
        t = cache["c_kv"].shape[1]
        src, slot_pos = prefill_slot_sources(
            t, s if prefill_len is None else prefill_len, s)
        keep2 = (slot_pos >= 0)[None, :, None]
        new_cache = {
            "c_kv": jnp.where(keep2, jnp.take(c_kv, src, axis=1), 0.0
                              ).astype(cache["c_kv"].dtype),
            "k_rope": jnp.where(keep2, jnp.take(k_rope[:, :, 0, :], src,
                                                axis=1), 0.0
                                ).astype(cache["k_rope"].dtype),
            "pos_map": jnp.broadcast_to(slot_pos[None], (b, t)),
        }
        return out, new_cache

    # ---- absorbed decode: score against the latent cache ----------------
    assert s == 1 and pos is not None
    posv = as_slot_positions(pos, b)                    # ragged per-slot pos
    active = posv >= 0
    rows = jnp.arange(b)
    if "c_kv_pages" in cache:
        # paged latents: scatter the new row into the slot's current page,
        # score against a gathered slot-contiguous view -- elementwise
        # identical to the dense latent cache, so decode stays bit-exact
        pt = cache["page_table"]
        cp = paged_row_write(cache["c_kv_pages"], pt, posv, c_kv[:, 0],
                             active)
        rp = paged_row_write(cache["k_rope_pages"], pt, posv,
                             k_rope[:, 0, 0, :], active)
        pm = _masked_row_write(cache["pos_map"], rows,
                               jnp.maximum(posv, 0), jnp.maximum(posv, 0),
                               active)
        c_cache = paged_view(cp, pt, pm)
        r_cache = paged_view(rp, pt, pm)
        new_cache = {"c_kv_pages": cp, "k_rope_pages": rp, "pos_map": pm,
                     "page_table": pt}
    else:
        t = cache["c_kv"].shape[1]
        slot = jnp.maximum(posv, 0) % t
        c_cache = _masked_row_write(cache["c_kv"], rows, slot, c_kv[:, 0],
                                    active)
        r_cache = _masked_row_write(cache["k_rope"], rows, slot,
                                    k_rope[:, 0, 0, :], active)
        pm = cache["pos_map"]
        if pm.ndim == 1:                                # legacy shared map
            pm = jnp.broadcast_to(pm, (b, t))
        pm = _masked_row_write(pm, rows, slot, jnp.maximum(posv, 0), active)
        new_cache = {"c_kv": c_cache, "k_rope": r_cache, "pos_map": pm}

    w_uk = p["w_uk"]["w"].reshape(h, dn, cfg.kv_lora_rank)    # (h, dn, r)
    q_abs = jnp.einsum("bqhd,hdr->bqhr", q_nope, w_uk)        # (b,1,h,r)
    s_lat = jnp.einsum("bqhr,btr->bhqt", q_abs.astype(jnp.float32),
                       c_cache.astype(jnp.float32))
    s_rope = jnp.einsum("bqhd,btd->bhqt", q_rope.astype(jnp.float32),
                        r_cache.astype(jnp.float32))
    scores = (s_lat + s_rope) * ((dn + dr) ** -0.5)
    ok = (pm >= 0) & (pm <= posv[:, None])              # per-row causal mask
    scores = jnp.where(ok[:, None, None, :], scores, -1e30)
    pr = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhqt,btr->bqhr", pr, c_cache.astype(jnp.float32))
    w_uv = p["w_uv"]["w"].reshape(h, dv, cfg.kv_lora_rank)
    o = jnp.einsum("bqhr,hvr->bqhv", ctx, w_uv).astype(x.dtype)
    out = linear(p["wo"], o.reshape(b, 1, h * dv), packs and packs.get("wo"))
    return out, new_cache
