"""Attention: GQA with RoPE variants, flash-chunked global, banded local,
and single-token decode against (ring-)KV caches.

Memory discipline matters at the assigned shapes (32k prefill): global
attention never materializes an (S, T) score matrix -- it runs a chunked
online-softmax (flash) loop under lax.scan. Local attention gathers only the
window-adjacent KV chunks, so its FLOPs are O(S * window) -- this is what
makes recurrentgemma/mamba runnable at 500k.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_decode import (flash_decode, paged_flash_decode,
                                        resolved_decode_kernel)
from repro.models.common import (apply_rope, init_linear, linear, normal_init,
                                 paged_bulk_write, paged_row_write, paged_view)

NEG_INF = -1e30


def as_slot_positions(pos, batch):
    """Normalize ``pos`` to the ragged per-slot form: an int32 (B,) vector.

    Serving runs request *slots* through the batch dimension, each at its own
    absolute position (repro/serving/engine.py). A scalar ``pos`` -- the
    single-request calling convention -- broadcasts to every row. Negative
    entries mark inactive slots: their cache writes are suppressed and their
    outputs are garbage (finite, but meaningless).
    """
    return jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (batch,))


def _masked_row_write(buf, rows, slot, val, active):
    """Write ``val[i]`` into ``buf[i, slot[i]]`` where ``active[i]``; inactive
    rows keep their previous value (the write happens but stores the old
    content back, so one scatter serves both cases under jit)."""
    keep = jnp.expand_dims(active, tuple(range(1, val.ndim)))
    old = buf[rows, slot]
    return buf.at[rows, slot].set(jnp.where(keep, val, old))


def slot_reset_value(path, x_slice):
    """Reset value for one cache leaf's slot slice (tree_map_with_path
    callback): ``pos_map`` and ``page_table`` slots empty out to -1,
    everything else -- attention KV, quant scales, SSM state, RG-LRU h,
    conv history -- to 0. Shared by every family's ``reset_slot`` (lm.py,
    encdec.py). Page-pool leaves (``*_pages``) never reach this callback:
    their leading axis is physical pages, not slots, so the slot ops skip
    them (lm.reset_slot)."""
    name = getattr(path[-1], "key", None)
    return jnp.full_like(
        x_slice, -1 if name in ("pos_map", "page_table") else 0)


def prefill_slot_sources(t, length, s):
    """Cache-slot gather plan for a one-pass prompt prefill.

    A prompt of ``length`` tokens (padded to ``s``, positions 0..length-1)
    lands in a T-slot ring cache at slot = pos % T; slot j ends up holding
    the LATEST position congruent to j. Returns ``(src, pos)``: per-slot
    source index into the (B, S, ...) prefill tensors (clipped; gather, so
    no duplicate-scatter ordering hazards) and the per-slot absolute
    position (-1 = empty). ``length`` may be a traced scalar -- one compiled
    prefill serves every prompt length in a bucket. Linear caches (T >=
    prompt) are the ring's trivial case: slot j <- position j.
    """
    j = jnp.arange(t)
    last = jnp.asarray(length, jnp.int32) - 1
    src = j + t * ((last - j) // t)         # latest p <= last with p%T == j
    ok = (src >= 0) & (src <= last)
    return jnp.clip(src, 0, s - 1), jnp.where(ok, src, -1)


def _split_heads(x, n_heads, head_dim):
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, head_dim)


def _merge_heads(x):
    b, s, h, d = x.shape
    return x.reshape(b, s, h * d)


# ---------------------------------------------------------------------------
# core attention math (all paths share the grouped-heads convention)
# ---------------------------------------------------------------------------

def full_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                   softcap=0.0):
    """Materialized-scores path for short sequences (smoke tests, decode prefill
    of small models). q: (B,S,Hq,D), k/v: (B,T,Hkv,D)."""
    b, s, hq, d = q.shape
    t, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, s, hkv, g, d)
    scores = jnp.einsum("bshgd,bthd->bhgst", qg, k,
                        preferred_element_type=jnp.float32) * (d ** -0.5)
    if softcap > 0:
        scores = jnp.tanh(scores / softcap) * softcap
    qpos = q_offset + jnp.arange(s)[:, None]
    kpos = jnp.arange(t)[None, :]
    ok = jnp.ones((s, t), bool)
    if causal:
        ok &= kpos <= qpos
    if window > 0:
        ok &= (qpos - kpos) < window
    scores = jnp.where(ok[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgst,bthd->bshgd", p.astype(v.dtype), v)
    return out.reshape(b, s, hq, d)


def flash_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                    q_chunk=1024, kv_chunk=1024, softcap=0.0):
    """Chunked online-softmax attention with a flash-style custom VJP.

    Forward keeps only O(Cq*Ckv) scores live and saves O(S*d) residuals
    (out + per-position logsumexp); backward recomputes attention blockwise
    (the FA2 schedule). Without the custom VJP, scan autodiff stacks
    per-chunk probability tensors -- O(S^2) residual memory, which the
    dry-run showed dominating the HBM roofline term (docs/PERF.md).
    """
    return _flash(q, k, v, causal, window, q_offset, q_chunk, kv_chunk,
                  softcap)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _flash(q, k, v, causal, window, q_offset, q_chunk, kv_chunk, softcap):
    out, _ = _flash_fwd_impl(q, k, v, causal, window, q_offset, q_chunk,
                             kv_chunk, softcap)
    return out


def _blocks(q, k, v, q_chunk, kv_chunk):
    b, s, hq, d = q.shape
    t, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    cq, ck = min(q_chunk, s), min(kv_chunk, t)
    pad_q, pad_k = (-s) % cq, (-t) % ck
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    nq, nk = (s + pad_q) // cq, (t + pad_k) // ck
    qb = q.reshape(b, nq, cq, hkv, g, d).transpose(1, 0, 3, 4, 2, 5)
    kb = k.reshape(b, nk, ck, hkv, d).transpose(1, 0, 3, 2, 4)
    vb = v.reshape(b, nk, ck, hkv, d).transpose(1, 0, 3, 2, 4)
    return qb, kb, vb, (b, s, t, hq, hkv, g, d, cq, ck, nq, nk)


def _tile_ok(qi, ki, cq, ck, t_valid, causal, window, q_offset):
    qpos = q_offset + qi * cq + jnp.arange(cq)[:, None]
    kpos = ki * ck + jnp.arange(ck)[None, :]
    ok = kpos < t_valid
    if causal:
        ok &= kpos <= qpos
    if window > 0:
        ok &= (qpos - kpos) < window
    return ok


def _flash_fwd_impl(q, k, v, causal, window, q_offset, q_chunk, kv_chunk,
                    softcap):
    qb, kb, vb, dims = _blocks(q, k, v, q_chunk, kv_chunk)
    b, s, t, hq, hkv, g, d, cq, ck, nq, nk = dims
    scale = d ** -0.5

    def q_step(_, qi_qblk):
        qi, qblk = qi_qblk
        m0 = jnp.full((b, hkv, g, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, cq), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, cq, d), jnp.float32)

        def kv_step(carry, ki_kv):
            m, l, acc = carry
            ki, kblk, vblk = ki_kv
            s_ = jnp.einsum("bhgqd,bhkd->bhgqk", qblk, kblk,
                            preferred_element_type=jnp.float32) * scale
            if softcap > 0:
                s_ = jnp.tanh(s_ / softcap) * softcap
            ok = _tile_ok(qi, ki, cq, ck, t, causal, window, q_offset)
            m_new = jnp.maximum(m, jnp.max(
                jnp.where(ok[None, None, None], s_, NEG_INF), axis=-1))
            # store the probability tile in the model dtype: for bf16 models
            # this halves the dominant HBM term (§Perf iter 3); f32 models
            # keep full precision
            p = jnp.where(ok[None, None, None],
                          jnp.exp(s_ - m_new[..., None]), 0.0).astype(q.dtype)
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1,
                                   dtype=jnp.float32)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p, vblk,
                preferred_element_type=jnp.float32)
            return (m_new, l, acc), None

        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nk), kb, vb))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        lse = m + jnp.log(jnp.maximum(l, 1e-30))        # (b,hkv,g,cq)
        return None, (out, lse)

    _, (ob, lseb) = jax.lax.scan(q_step, None, (jnp.arange(nq), qb))
    out = ob.transpose(1, 0, 4, 2, 3, 5).reshape(b, nq * cq, hq, d)
    return out[:, :s].astype(q.dtype), lseb             # lseb (nq,b,hkv,g,cq)


def _flash_vjp_fwd(q, k, v, causal, window, q_offset, q_chunk, kv_chunk,
                   softcap):
    out, lse = _flash_fwd_impl(q, k, v, causal, window, q_offset, q_chunk,
                               kv_chunk, softcap)
    return out, (q, k, v, out, lse)


def _flash_vjp_bwd(causal, window, q_offset, q_chunk, kv_chunk, softcap,
                   res, dout):
    # softcap>0 bwd falls back to autodiff at the call site (not used by the
    # assigned archs); here softcap is always 0.
    q, k, v, out, lse = res
    qb, kb, vb, dims = _blocks(q, k, v, q_chunk, kv_chunk)
    b, s, t, hq, hkv, g, d, cq, ck, nq, nk = dims
    scale = d ** -0.5
    pad_q = nq * cq - s
    do = dout.astype(q.dtype)
    outp = out.astype(q.dtype)
    if pad_q:
        do = jnp.pad(do, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        outp = jnp.pad(outp, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    dob = do.reshape(b, nq, cq, hkv, g, d).transpose(1, 0, 3, 4, 2, 5)
    outb = outp.reshape(b, nq, cq, hkv, g, d).transpose(1, 0, 3, 4, 2, 5)
    delta = jnp.einsum("nbhgqd,nbhgqd->nbhgq", dob, outb,
                       preferred_element_type=jnp.float32)

    def kv_step(dq_acc, ki_kv):
        ki, kblk, vblk = ki_kv

        def q_step(carry, qi_stuff):
            dk_j, dv_j = carry
            qi, qblk, doq, lseq, dlt = qi_stuff
            s_ = jnp.einsum("bhgqd,bhkd->bhgqk", qblk, kblk,
                            preferred_element_type=jnp.float32) * scale
            ok = _tile_ok(qi, ki, cq, ck, t, causal, window, q_offset)
            # p/ds tiles stored in the model dtype (see fwd note)
            p = jnp.where(ok[None, None, None],
                          jnp.exp(s_ - lseq[..., None]), 0.0).astype(q.dtype)
            dv_j = dv_j + jnp.einsum("bhgqk,bhgqd->bhkd", p, doq,
                                     preferred_element_type=jnp.float32)
            dp = jnp.einsum("bhgqd,bhkd->bhgqk", doq, vblk,
                            preferred_element_type=jnp.float32)
            ds = (p.astype(jnp.float32) * (dp - dlt[..., None]) *
                  scale).astype(q.dtype)
            dk_j = dk_j + jnp.einsum("bhgqk,bhgqd->bhkd", ds, qblk,
                                     preferred_element_type=jnp.float32)
            dq_i = jnp.einsum("bhgqk,bhkd->bhgqd", ds, kblk,
                              preferred_element_type=jnp.float32)
            return (dk_j, dv_j), dq_i

        zk = jnp.zeros((b, hkv, ck, d), jnp.float32)
        (dk_j, dv_j), dq_parts = jax.lax.scan(
            q_step, (zk, zk), (jnp.arange(nq), qb, dob, lse, delta))
        return dq_acc + dq_parts, (dk_j, dv_j)

    dq0 = jnp.zeros((nq, b, hkv, g, cq, d), jnp.float32)
    dq_acc, (dkb, dvb) = jax.lax.scan(kv_step, dq0, (jnp.arange(nk), kb, vb))

    dq = dq_acc.transpose(1, 0, 4, 2, 3, 5).reshape(b, nq * cq, hq, d)[:, :s]
    dk = dkb.transpose(1, 0, 3, 2, 4).reshape(b, nk * ck, hkv, d)[:, :t]
    dv = dvb.transpose(1, 0, 3, 2, 4).reshape(b, nk * ck, hkv, d)[:, :t]
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def local_attention(q, k, v, *, window, q_offset=0):
    """Banded causal attention: FLOPs O(S * window), not O(S^2).

    Chunk size C divides the window; each query chunk gathers the previous
    ``window//C`` key chunks plus its own, so out-of-band tiles are never
    computed (true sub-quadratic cost, visible in cost_analysis).
    """
    b, s, hq, d = q.shape
    t, hkv = k.shape[1], k.shape[2]
    assert s == t, "local_attention is a self-attention prefill/train path"
    g = hq // hkv
    c = min(window, 1024)
    assert window % c == 0
    n_prev = window // c
    pad = (-s) % c
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n = (s + pad) // c
    qb = q.reshape(b, n, c, hkv, g, d)
    kc = k.reshape(b, n, c, hkv, d)
    vc = v.reshape(b, n, c, hkv, d)

    def shifted(x, sh):  # chunk i -> chunk i-sh (zero for i<sh)
        return jnp.pad(x, ((0, 0), (sh, 0)) + ((0, 0),) * (x.ndim - 2))[:, :n]

    k_ext = jnp.concatenate([shifted(kc, p) for p in range(n_prev, 0, -1)]
                            + [kc], axis=2)            # (b, n, (n_prev+1)c, hkv, d)
    v_ext = jnp.concatenate([shifted(vc, p) for p in range(n_prev, 0, -1)]
                            + [vc], axis=2)
    scores = jnp.einsum("bnchgd,bnkhd->bnhgck", qb, k_ext,
                        preferred_element_type=jnp.float32) * (d ** -0.5)
    ci = jnp.arange(n)[:, None, None]
    a = jnp.arange(c)[None, :, None]
    bcol = jnp.arange((n_prev + 1) * c)[None, None, :]
    qpos = ci * c + a
    kpos = (ci - n_prev) * c + bcol
    ok = (kpos >= 0) & (kpos <= qpos) & (qpos - kpos < window) & (kpos < s)
    scores = jnp.where(ok[:, None, None][None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bnhgck,bnkhd->bnchgd", p.astype(v_ext.dtype), v_ext)
    out = out.reshape(b, n * c, hq, d)
    return out[:, :s]


def decode_attention(q, k_cache, v_cache, kv_positions, pos, *, window=0):
    """One-step decode: q (B,1,Hq,D) vs caches (B,T,Hkv,D).

    ``kv_positions`` holds the absolute position stored in each cache slot
    (-1 = empty) -- this supports both linear caches (slot == position) and
    ring caches for windowed layers (slot == position % window). It is
    either (T,), shared by every batch row, or (B, T) with one map per
    request slot; ``pos`` is correspondingly a scalar or a (B,) vector of
    ragged per-slot positions, so mixed-progress requests share one batched
    decode call with per-row causal/window masks.
    """
    b, _, hq, d = q.shape
    t, hkv = k_cache.shape[1], k_cache.shape[2]
    g = hq // hkv
    qg = q.reshape(b, 1, hkv, g, d)
    scores = jnp.einsum("bqhgd,bthd->bhgqt", qg, k_cache,
                        preferred_element_type=jnp.float32) * (d ** -0.5)
    kvp = kv_positions if kv_positions.ndim == 2 else kv_positions[None, :]
    posv = jnp.asarray(pos, jnp.int32)
    posv = posv[:, None] if posv.ndim else posv[None, None]     # (B|1, 1)
    ok = (kvp >= 0) & (kvp <= posv)
    if window > 0:
        ok &= kvp > posv - window
    scores = jnp.where(ok[:, None, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqt,bthd->bqhgd", p.astype(v_cache.dtype), v_cache)
    return out.reshape(b, 1, hq, d)


def masked_attention(q, k, v, ok):
    """Materialized-scores attention under an explicit boolean mask ``ok``
    (B, S, T): q (B,S,Hq,D) vs k/v (B,T,Hkv,D). The paged *suffix prefill*
    path uses this to attend new prompt tokens against a gathered page view
    holding a shared (radix-cache) prefix -- ``ok[b, s, t]`` encodes the
    per-position causal mask ``0 <= kv_pos[t] <= q_pos[s]`` that
    ``decode_attention`` applies for S == 1."""
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, s, hkv, g, d)
    scores = jnp.einsum("bshgd,bthd->bhgst", qg, k,
                        preferred_element_type=jnp.float32) * (d ** -0.5)
    scores = jnp.where(ok[:, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgst,bthd->bshgd", p.astype(v.dtype), v)
    return out.reshape(b, s, hq, d)


def paged_suffix_positions(pos_map_len, start, length):
    """pos_map row after a suffix prefill of ``length`` real tokens starting
    at absolute position ``start`` on a slot whose shared-prefix pages
    already cover positions 0..start-1: every position below start + length
    is occupied (slot == position in a linear paged cache)."""
    ar = jnp.arange(pos_map_len)
    return jnp.where(ar < start + length, ar, -1)


# ---------------------------------------------------------------------------
# the GQA attention layer (projections + rope + cache plumbing)
# ---------------------------------------------------------------------------

def init_attention(key, cfg):
    ks = jax.random.split(key, 4)
    d, hd = cfg.d_model, cfg.head_dim
    p = {"wq": init_linear(ks[0], d, cfg.n_heads * hd, cfg.jdtype),
         "wk": init_linear(ks[1], d, cfg.n_kv_heads * hd, cfg.jdtype),
         "wv": init_linear(ks[2], d, cfg.n_kv_heads * hd, cfg.jdtype),
         "wo": init_linear(ks[3], cfg.n_heads * hd, d, cfg.jdtype)}
    if cfg.qk_norm:
        p["q_norm"] = {"scale": jnp.zeros((hd,), cfg.jdtype)}
        p["k_norm"] = {"scale": jnp.zeros((hd,), cfg.jdtype)}
    return p


def init_cache_attn(cfg, batch, cache_len, window=0, dtype=None, paged=None):
    """Linear cache for global layers, ring cache (len=window) for local.
    ``pos_map`` is (batch, T): each request slot tracks its own occupancy so
    slots at different positions batch into one decode call. With
    cfg.kv_cache_quant, K/V are stored int8 with per-(slot, head) scales
    (dequantized tile-wise inside attention).

    ``paged`` (a ``models.common.PagedLayout``) switches GLOBAL layers to
    the pooled layout: K/V live in (n_pages, page_size, Hkv, D) page pools
    addressed through a per-slot ``page_table`` (batch, T // page_size) of
    physical page ids (-1 = unmapped); ``pos_map`` keeps its dense (batch,
    T) form, so the decode masking -- and therefore the attention math --
    is unchanged. Ring caches (window > 0) stay slot-dense: their per-slot
    footprint is already bounded by the window, and ring content depends on
    total sequence length, which breaks prefix-granular page sharing."""
    t = min(cache_len, window) if window > 0 else cache_len
    dtype = dtype or cfg.jdtype
    if paged is not None and window == 0:
        if cfg.kv_cache_quant:
            raise NotImplementedError(
                "kv_layout='paged' does not compose with kv_cache_quant yet"
                " (int8 page pools + per-page scales are future work)")
        npg = paged.table_width(cache_len)
        pshape = (paged.n_pages, paged.page_size, cfg.n_kv_heads,
                  cfg.head_dim)
        return {"k_pages": jnp.zeros(pshape, dtype),
                "v_pages": jnp.zeros(pshape, dtype),
                "page_table": jnp.full((batch, npg), -1, jnp.int32),
                "pos_map": jnp.full((batch, t), -1, jnp.int32)}
    shape = (batch, t, cfg.n_kv_heads, cfg.head_dim)
    if cfg.kv_cache_quant:
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(shape[:3], jnp.bfloat16),
                "v_scale": jnp.zeros(shape[:3], jnp.bfloat16),
                "pos_map": jnp.full((batch, t), -1, jnp.int32)}
    return {"k": jnp.zeros(shape, dtype),
            "v": jnp.zeros(shape, dtype),
            "pos_map": jnp.full((batch, t), -1, jnp.int32)}


def _quantize_kv(x):
    """(B,S,H,D) -> int8 values + per-(B,S,H) scales."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def _dequantize_kv(q, scale, dtype):
    return (q.astype(jnp.float32) *
            scale[..., None].astype(jnp.float32)).astype(dtype)


def _write_prefill_kv(cache, k, v, length):
    """One-pass prompt prefill: replace the slot cache's contents with the
    K/V of positions 0..length-1 (k/v: (B, S>=length, Hkv, D)). Ring caches
    keep the window-latest positions; padding slots read as empty."""
    b, s = k.shape[0], k.shape[1]
    t = cache["k"].shape[1]
    src, slot_pos = prefill_slot_sources(t, length, s)

    def take(vals):
        g = jnp.take(vals, src, axis=1)
        keep = (slot_pos >= 0).reshape((1, t) + (1,) * (g.ndim - 2))
        return jnp.where(keep, g, jnp.zeros_like(g))

    pm = jnp.broadcast_to(slot_pos[None], (b, t))
    if "k_scale" in cache:          # int8 quantized cache
        kq, ks = _quantize_kv(k)
        vq, vs = _quantize_kv(v)
        return {"k": take(kq), "v": take(vq), "k_scale": take(ks),
                "v_scale": take(vs), "pos_map": pm}
    return {"k": take(k), "v": take(v), "pos_map": pm}


def apply_attention(p, x, cfg, *, positions, window=0, cache=None, pos=None,
                    packs=None, causal=True, kv_override=None,
                    prefill_len=None, page_slot=None, page_start=None):
    """x: (B,S,d). Returns (out, new_cache). Train/prefill when cache is None.
    With a cache and S > 1, the call is a *prompt prefill*: normal causal
    attention over the S tokens plus a bulk cache write of positions
    0..prefill_len-1 (prefill_len defaults to S; tokens past it are padding
    and leave no trace -- serving/engine.py buckets prompt lengths).

    A PAGED cache (leaf carries ``k_pages``/``v_pages``/``page_table``;
    init_cache_attn(paged=...)) serves two extra modes:
      * decode (S == 1): the new K/V row scatters into the slot's current
        page (jit OOB-drop masking) and attention runs over a gathered
        slot-contiguous page view -- elementwise identical to the dense
        cache array, so decode stays bit-exact vs the dense oracle;
      * suffix prefill (S > 1 with ``page_slot``/``page_start``): x holds
        ONE slot's new prompt tokens at absolute positions page_start..;
        their K/V scatter into the slot's (already-installed) pages and
        the queries attend over the page view, whose low pages hold a
        shared radix-cache prefix that was never re-prefilled. Whole-cache
        prefill on a paged layout is not defined -- the engine prefills
        into a dense batch-1 sub-cache and page-scatters it instead
        (lm.write_slot_paged).

    A DENSE cache with ``page_slot``/``page_start`` and S > 1 is the same
    suffix/chunk-prefill contract on slot-dense storage (chunked prefill,
    docs/API.md §SLO scheduling): x holds one slot's next prompt slice, the
    queries attend over the slot's current ring content concatenated with
    the fresh chunk K/V (attend-before-write -- see the branch comment),
    and the chunk then ring-writes latest-wins into the slot row. Works for
    global (T = cache_len) and windowed (T = window) layers; int8-quantized
    caches are excluded (the engine gates chunking off for them).

    kv_override: (k, v) tensors for cross-attention (enc-dec).

    When the sparse export fused the q/k/v projections (``packs['wqkv']``,
    repro/serving/export.py), one block-sparse matmul produces all three --
    one gather of x and one dispatch per layer instead of three -- and the
    output is split at the (Hq*D, Hkv*D, Hkv*D) boundaries."""
    from repro.models.common import rms_norm
    b, s, _ = x.shape
    hd = cfg.head_dim
    fused = packs.get("wqkv") if packs else None
    if fused is not None:
        assert kv_override is None, "fused QKV export is self-attention only"
        qkv = linear(p["wqkv"], x, fused)
        dq, dkv = cfg.n_heads * hd, cfg.n_kv_heads * hd
        q = _split_heads(qkv[..., :dq], cfg.n_heads, hd)
        k = _split_heads(qkv[..., dq:dq + dkv], cfg.n_kv_heads, hd)
        v = _split_heads(qkv[..., dq + dkv:], cfg.n_kv_heads, hd)
    else:
        q = _split_heads(linear(p["wq"], x, packs and packs.get("wq")),
                         cfg.n_heads, hd)
    if kv_override is None:
        if fused is None:
            k = _split_heads(linear(p["wk"], x, packs and packs.get("wk")),
                             cfg.n_kv_heads, hd)
            v = _split_heads(linear(p["wv"], x, packs and packs.get("wv")),
                             cfg.n_kv_heads, hd)
    else:
        k, v = kv_override
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"]["scale"])
        k = rms_norm(k, p["k_norm"]["scale"]) if kv_override is None else k
    if cfg.rotary_fraction > 0 and kv_override is None:
        q = apply_rope(q, positions, theta=cfg.rope_theta,
                       rotary_fraction=cfg.rotary_fraction)
        k = apply_rope(k, positions, theta=cfg.rope_theta,
                       rotary_fraction=cfg.rotary_fraction)

    new_cache = cache
    paged = cache is not None and "k_pages" in cache
    if paged and s > 1:
        if page_slot is None:
            raise NotImplementedError(
                "whole-cache prompt prefill is undefined for a paged KV "
                "layout; prefill a dense batch-1 sub-cache and insert it "
                "with write_slot_paged, or pass page_slot/page_start for a "
                "shared-prefix suffix prefill")
        assert kv_override is None and b == 1
        n, ps = cache["k_pages"].shape[0], cache["k_pages"].shape[1]
        npg = cache["page_table"].shape[1]
        length = s if prefill_len is None else prefill_len
        start = jnp.asarray(page_start, jnp.int32)
        pt_row = cache["page_table"][page_slot]                  # (NP,)
        pos_i = start + jnp.arange(s)
        validw = jnp.arange(s) < length
        pp = pt_row[jnp.clip(pos_i // ps, 0, npg - 1)]
        pp = jnp.where(validw & (pp >= 0), pp, n)                # OOB: drop
        kp = cache["k_pages"].at[pp, pos_i % ps].set(k[0])
        vp = cache["v_pages"].at[pp, pos_i % ps].set(v[0])
        pm_row = paged_suffix_positions(npg * ps, start, length)
        pm = cache["pos_map"].at[page_slot].set(pm_row)
        new_cache = {"k_pages": kp, "v_pages": vp, "pos_map": pm,
                     "page_table": cache["page_table"]}
        k_view = paged_view(kp, pt_row[None], pm_row[None])      # (1,T,H,D)
        v_view = paged_view(vp, pt_row[None], pm_row[None])
        qpos = pos_i[None, :, None]                              # (1,S,1)
        ok = (pm_row[None, None, :] >= 0) & (pm_row[None, None, :] <= qpos)
        out = masked_attention(q, k_view, v_view, ok)
        out = linear(p["wo"], _merge_heads(out), packs and packs.get("wo"))
        return out, new_cache
    if cache is not None and s > 1 and page_slot is not None:
        # DENSE chunk/suffix prefill: x holds ONE slot's next prompt slice
        # at absolute positions page_start.. against the BATCHED engine
        # cache. Attention runs BEFORE the cache write over a concat of the
        # slot's current ring content and the fresh chunk K/V -- a write-
        # then-view order would let the chunk's own tail overwrite ring
        # slots (slot = pos % window) that earlier chunk queries still need.
        assert kv_override is None and b == 1
        if "k_scale" in cache:
            raise NotImplementedError(
                "chunked prefill does not compose with kv_cache_quant: the "
                "one-shot path attends unquantized chunk K/V, so a chunked "
                "run could not be token-exact against it")
        t = cache["k"].shape[1]
        nslots = cache["k"].shape[0]
        length = s if prefill_len is None else prefill_len
        start = jnp.asarray(page_start, jnp.int32)
        pos_i = start + jnp.arange(s)
        validw = jnp.arange(s) < length
        ck_row = cache["k"][page_slot]                           # (T,H,D)
        cv_row = cache["v"][page_slot]
        pm = cache["pos_map"]
        if pm.ndim == 1:                                # legacy shared map
            pm = jnp.broadcast_to(pm, (nslots, t))
        pm_row = pm[page_slot]                                   # (T,)
        k_eff = jnp.concatenate([ck_row[None], k], axis=1)       # (1,T+S,..)
        v_eff = jnp.concatenate([cv_row[None], v], axis=1)
        kvpos = jnp.concatenate([pm_row, jnp.where(validw, pos_i, -1)])
        qpos = pos_i[None, :, None]                              # (1,S,1)
        ok = (kvpos[None, None, :] >= 0) & (kvpos[None, None, :] <= qpos)
        if window > 0:
            ok &= (qpos - kvpos[None, None, :]) < window
        out = masked_attention(q, k_eff, v_eff, ok)
        # latest-wins ring write of the chunk: prefill_slot_sources' gather
        # plan shifted to absolute positions start..start+length-1; ring
        # slots whose latest congruent position predates the chunk keep
        # their old content
        j = jnp.arange(t)
        last = start + jnp.asarray(length, jnp.int32) - 1
        src_abs = j + t * ((last - j) // t)
        okw = (src_abs >= start) & (src_abs <= last)
        src_rel = jnp.clip(src_abs - start, 0, s - 1)

        def ring_merge(row, chunk):
            keep = okw.reshape((t,) + (1,) * (row.ndim - 1))
            return jnp.where(keep, chunk[0][src_rel].astype(row.dtype), row)

        new_cache = {
            "k": cache["k"].at[page_slot].set(ring_merge(ck_row, k)),
            "v": cache["v"].at[page_slot].set(ring_merge(cv_row, v)),
            "pos_map": pm.at[page_slot].set(
                jnp.where(okw, src_abs, pm_row))}
        out = linear(p["wo"], _merge_heads(out), packs and packs.get("wo"))
        return out, new_cache
    if cache is None or s > 1:
        if not causal:
            out = full_attention(q, k, v, causal=False) if s <= 2048 else \
                flash_attention(q, k, v, causal=False,
                                q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
        elif window > 0 and s > window:
            out = local_attention(q, k, v, window=window)
        elif s <= 1024:
            out = full_attention(q, k, v, causal=True, window=window)
        else:
            out = flash_attention(q, k, v, causal=True, window=window,
                                  q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
                                  softcap=cfg.attn_logit_softcap)
        if cache is not None:       # prompt prefill: bulk-write the KV
            assert kv_override is None, "prefill is self-attention only"
            new_cache = _write_prefill_kv(
                cache, k, v, s if prefill_len is None else prefill_len)
    elif paged:
        assert s == 1 and pos is not None and kv_override is None
        posv = as_slot_positions(pos, b)
        active = posv >= 0
        pt = cache["page_table"]
        kp = paged_row_write(cache["k_pages"], pt, posv, k[:, 0], active)
        vp = paged_row_write(cache["v_pages"], pt, posv, v[:, 0], active)
        pm = _masked_row_write(cache["pos_map"], jnp.arange(b),
                               jnp.maximum(posv, 0), jnp.maximum(posv, 0),
                               active)
        new_cache = {"k_pages": kp, "v_pages": vp, "pos_map": pm,
                     "page_table": pt}
        if resolved_decode_kernel() == "flash":
            # the split-K kernel reads pages in place via the prefetched
            # table -- no per-step dense-view gather
            out = paged_flash_decode(q, kp, vp, pt, pm, posv, window=window)
        else:
            k_view = paged_view(kp, pt, pm)
            v_view = paged_view(vp, pt, pm)
            out = decode_attention(q, k_view, v_view, pm, posv,
                                   window=window)
    else:
        assert s == 1 and pos is not None
        if kv_override is None:
            t = cache["k"].shape[1]
            posv = as_slot_positions(pos, b)
            active = posv >= 0
            slot = jnp.maximum(posv, 0) % t                 # (B,) ring slots
            rows = jnp.arange(b)
            pm = cache["pos_map"]
            if pm.ndim == 1:                                # legacy shared map
                pm = jnp.broadcast_to(pm, (b, t))
            pm = _masked_row_write(pm, rows, slot, jnp.maximum(posv, 0),
                                   active)
            if "k_scale" in cache:   # int8 quantized cache
                kq, ks = _quantize_kv(k)
                vq, vs = _quantize_kv(v)
                ck = _masked_row_write(cache["k"], rows, slot, kq[:, 0],
                                       active)
                cv = _masked_row_write(cache["v"], rows, slot, vq[:, 0],
                                       active)
                cks = _masked_row_write(cache["k_scale"], rows, slot,
                                        ks[:, 0], active)
                cvs = _masked_row_write(cache["v_scale"], rows, slot,
                                        vs[:, 0], active)
                new_cache = {"k": ck, "v": cv, "k_scale": cks,
                             "v_scale": cvs, "pos_map": pm}
                kd = _dequantize_kv(ck, cks, q.dtype)
                vd = _dequantize_kv(cv, cvs, q.dtype)
                out = decode_attention(q, kd, vd, pm, posv, window=window)
            else:
                ck = _masked_row_write(cache["k"], rows, slot, k[:, 0],
                                       active)
                cv = _masked_row_write(cache["v"], rows, slot, v[:, 0],
                                       active)
                new_cache = {"k": ck, "v": cv, "pos_map": pm}
                if resolved_decode_kernel() == "flash":
                    out = flash_decode(q, ck, cv, pm, posv, window=window)
                else:
                    out = decode_attention(q, ck, cv, pm, posv,
                                           window=window)
        else:
            # cross-attn decode: every encoder position is visible
            t = k.shape[1]
            out = decode_attention(q, k, v, jnp.arange(t), t - 1, window=0)
    out = linear(p["wo"], _merge_heads(out), packs and packs.get("wo"))
    return out, new_cache
