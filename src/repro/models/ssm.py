"""Mamba-2 SSD (state-space duality) mixer, chunked, attention-free.

Train/prefill: the standard chunked SSD algorithm -- intra-chunk quadratic
term + inter-chunk state recurrence via lax.scan, O(S * chunk * (P + N))
instead of O(S^2). Decode: O(1) recurrent state update, which is what makes
long_500k a bounded-memory cell for this family.

Layout: heads H with head dim P, state size N, one B/C group broadcast to all
heads (n_groups=1), scalar decay A per head, depthwise causal conv (width 4)
on the x/B/C stream, z-gated output with D skip -- matching the mamba2 block.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.attention import as_slot_positions
from repro.models.common import (init_linear, linear, normal_init,
                                 prefill_conv_history, rms_norm)


def _dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    return d_inner, n_heads, cfg.ssm_head_dim, cfg.ssm_state


def init_ssm(key, cfg):
    d = cfg.d_model
    d_inner, h, p_dim, n = _dims(cfg)
    conv_dim = d_inner + 2 * n                     # x stream + B + C
    ks = jax.random.split(key, 6)
    return {
        # fused input projection: [z, x, B, C, dt]
        "in_proj": init_linear(ks[0], d, 2 * d_inner + 2 * n + h, cfg.jdtype),
        "conv_w": normal_init(ks[1], (cfg.conv_width, conv_dim), 0.1, cfg.jdtype),
        "conv_b": jnp.zeros((conv_dim,), cfg.jdtype),
        "A_log": jnp.zeros((h,), jnp.float32),      # A = -exp(A_log)
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": {"scale": jnp.zeros((d_inner,), cfg.jdtype)},
        "out_proj": init_linear(ks[2], d_inner, d, cfg.jdtype),
    }


def init_cache_ssm(cfg, batch, dtype=None):
    d_inner, h, p_dim, n = _dims(cfg)
    conv_dim = d_inner + 2 * n
    dtype = dtype or cfg.jdtype
    return {"state": jnp.zeros((batch, h, p_dim, n), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_dim), dtype)}


def _causal_conv(x, w, b):
    """Depthwise causal conv via shifts. x: (B,S,C), w: (W,C)."""
    wdt = x.dtype
    out = jnp.zeros_like(x, dtype=jnp.float32)
    width = w.shape[0]
    for i in range(width):
        sh = width - 1 - i
        xi = jnp.pad(x, ((0, 0), (sh, 0), (0, 0)))[:, :x.shape[1]]
        out = out + xi.astype(jnp.float32) * w[i].astype(jnp.float32)
    return jax.nn.silu(out + b.astype(jnp.float32)).astype(wdt)


def _segsum(x):
    """(..., q) log-decays -> (..., q, q) lower-tri cumulative segment sums."""
    q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    ss = cs[..., :, None] - cs[..., None, :]
    # ss[i, j] = sum_{j < t <= i} x[t]; realized as cs[i] - cs[j]
    mask = jnp.tril(jnp.ones((q, q), bool), 0)
    return jnp.where(mask, ss, -jnp.inf)


def _split_proj(zxbcdt, cfg):
    d_inner, h, p_dim, n = _dims(cfg)
    z, x, bmat, cmat, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + n, 2 * d_inner + 2 * n],
        axis=-1)
    return z, x, bmat, cmat, dt


def apply_ssm(p, xin, cfg, *, cache=None, pos=None, packs=None,
              prefill_len=None, page_slot=None, **_):
    b, s, _ = xin.shape
    d_inner, h, p_dim, n = _dims(cfg)
    zxbcdt = linear(p["in_proj"], xin, packs and packs.get("in_proj"))
    z, x, bmat, cmat, dt = _split_proj(zxbcdt, cfg)

    prefill = cache is not None and s > 1
    # chunk/suffix prefill: xin holds ONE slot's next prompt slice against
    # the BATCHED engine cache -- continue from the slot's recurrent state
    # and real conv history instead of zeros (docs/API.md §SLO scheduling)
    chunked = prefill and page_slot is not None
    conv_in = jnp.concatenate([x, bmat, cmat], axis=-1)
    if chunked:
        assert b == 1
        w1 = cfg.conv_width - 1
        hist_row = cache["conv"][page_slot].astype(conv_in.dtype)  # (W-1,C)
        hist_stream = jnp.concatenate([hist_row[None], conv_in], axis=1)
        conv_out = _causal_conv(hist_stream, p["conv_w"],
                                p["conv_b"])[:, w1:]
    elif cache is None or prefill:
        conv_out = _causal_conv(conv_in, p["conv_w"], p["conv_b"])
    else:
        hist = jnp.concatenate([cache["conv"], conv_in], axis=1)
        conv_out = _causal_conv(hist, p["conv_w"], p["conv_b"])[:, -1:]
        new_conv = hist[:, 1:]
    x, bmat, cmat = jnp.split(conv_out, [d_inner, d_inner + n], axis=-1)

    xh = x.reshape(b, -1, h, p_dim).astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         p["dt_bias"][None, None, :])       # (b,s,h)
    a_neg = -jnp.exp(p["A_log"])                             # (h,)
    if prefill:
        # prompt prefill: padding tokens (>= prefill_len) must be identity
        # steps -- dt = 0 zeroes both their decay (exp(0) = 1) and their
        # state contribution, so the scan's final carry IS the state after
        # the real prompt
        length = s if prefill_len is None else prefill_len
        valid = (jnp.arange(s) < length)[None, :, None]
        dt = jnp.where(valid, dt, 0.0)
    da = dt * a_neg[None, None, :]                           # log-decay (b,s,h)
    bmat = bmat.astype(jnp.float32)                          # (b,s,n)
    cmat = cmat.astype(jnp.float32)

    if cache is None or prefill:
        init_state = cache["state"][page_slot][None] if chunked else None
        y, state = _ssd_chunked(xh, dt, da, bmat, cmat, cfg.ssm_chunk,
                                return_state=True,
                                initial_state=init_state)
        new_cache = None
        if chunked:
            validp = jnp.concatenate(
                [jnp.ones((1, w1, 1), bool),
                 jnp.broadcast_to(valid, (1, s, 1))], axis=1)
            new_hist = prefill_conv_history(
                hist_stream, validp, w1 + jnp.asarray(length, jnp.int32),
                w1, cache["conv"].dtype)
            new_cache = {
                "state": cache["state"].at[page_slot].set(state[0]),
                "conv": cache["conv"].at[page_slot].set(new_hist[0])}
        elif prefill:
            new_cache = {"state": state,
                         "conv": prefill_conv_history(
                             conv_in, valid, length, cfg.conv_width - 1,
                             cache["conv"].dtype)}
    else:
        # O(1) recurrent decode step; inactive slots (ragged pos < 0) keep
        # their recurrent + conv state untouched so a shared batched decode
        # call cannot corrupt a paused or free request slot
        active = (as_slot_positions(pos, b) >= 0) if pos is not None \
            else jnp.ones((b,), bool)
        state = cache["state"]                               # (b,h,p,n)
        decay = jnp.exp(da[:, 0, :])[..., None, None]        # (b,h,1,1)
        upd = jnp.einsum("bh,bhp,bn->bhpn", dt[:, 0], xh[:, 0], bmat[:, 0])
        state = jnp.where(active[:, None, None, None],
                          state * decay + upd, cache["state"])
        new_conv = jnp.where(active[:, None, None], new_conv, cache["conv"])
        y = jnp.einsum("bhpn,bn->bhp", state, cmat[:, 0])
        y = y.reshape(b, 1, h, p_dim)
        new_cache = {"state": state, "conv": new_conv}

    y = y + xh * p["D"][None, None, :, None]
    y = y.reshape(b, -1, d_inner)
    y = rms_norm(y.astype(cfg.jdtype), p["norm"]["scale"])
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    out = linear(p["out_proj"], y, packs and packs.get("out_proj"))
    return out, new_cache


def _ssd_chunked(x, dt, da, bmat, cmat, chunk, return_state=False,
                 initial_state=None):
    """Chunked SSD. x:(b,s,h,p) f32, dt/da:(b,s,h), B/C:(b,s,n).
    With ``return_state`` also returns the final recurrent state (b,h,p,n)
    -- the carry a one-pass prompt prefill hands to the decode path.
    ``initial_state`` (b,h,p,n) seeds the inter-chunk recurrence -- the
    chunked-prefill continuation passes the slot's current state so a
    prompt split across windows matches the one-pass result."""
    b, s, h, p_dim = x.shape
    n = bmat.shape[-1]
    q = min(chunk, s)
    pad = (-s) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        da = jnp.pad(da, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
    nc = (s + pad) // q
    xc = x.reshape(b, nc, q, h, p_dim)
    dtc = dt.reshape(b, nc, q, h)
    dac = da.reshape(b, nc, q, h)
    bc = bmat.reshape(b, nc, q, n)
    cc = cmat.reshape(b, nc, q, n)

    da_cum = jnp.cumsum(dac, axis=2)                          # (b,nc,q,h)
    # intra-chunk (diagonal) term
    lmat = jnp.exp(_segsum(dac.transpose(0, 1, 3, 2)))        # (b,nc,h,q,q)
    scores = jnp.einsum("bcqn,bckn->bcqk", cc, bc)            # (b,nc,q,k)
    xdt = xc * dtc[..., None]                                 # (b,nc,q,h,p)
    y_diag = jnp.einsum("bcqk,bchqk,bckhp->bcqhp",
                        scores, lmat, xdt)

    # per-chunk final states
    decay_to_end = jnp.exp(da_cum[:, :, -1:, :] - da_cum)     # (b,nc,q,h)
    states = jnp.einsum("bckn,bckh,bckhp->bchpn", bc, decay_to_end, xdt)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(da_cum[:, :, -1, :])                # (b,nc,h)

    def step(carry, inp):
        st, dec = inp                                          # (b,h,p,n),(b,h)
        out = carry
        carry = carry * dec[..., None, None] + st
        return carry, out
    init = (jnp.zeros((b, h, p_dim, n), jnp.float32)
            if initial_state is None else
            initial_state.astype(jnp.float32))
    final_state, prev_states = jax.lax.scan(
        step, init, (states.transpose(1, 0, 2, 3, 4),
                     chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)        # (b,nc,h,p,n)

    # off-diagonal (cross-chunk) contribution
    decay_from_start = jnp.exp(da_cum)                        # (b,nc,q,h)
    y_off = jnp.einsum("bcqn,bchpn,bcqh->bcqhp",
                       cc, prev_states, decay_from_start)
    y = (y_diag + y_off).reshape(b, nc * q, h, p_dim)
    if return_state:
        return y[:, :s], final_state
    return y[:, :s]
