"""On-device token sampling for the serving decode paths.

Greedy, temperature, and top-k sampling over a batch of logit rows with
*slot- and position-keyed* PRNG: the key used by slot ``b`` to sample the
token that follows position ``p`` is ``fold_in(fold_in(base_key, b), p)``.
Because the key depends only on (base_key, slot, position) -- never on how
many decode calls the host issued, how steps were fused, or which other
requests were co-resident -- the fused K-step loop (``models.api.
decode_many``) and the per-step loop produce bit-identical samples for the
same base key (tests/test_decode_many.py::test_seeded_sampling_parity).

``temperature`` and ``top_k`` are compile-time constants (the serving
engine fixes them per engine), so the greedy path stays a pure argmax with
no PRNG work at all.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["sample_tokens", "sample_token_row", "slot_keys"]


def slot_keys(key, pos):
    """Per-row sampling keys for a batch of slots: ``(B,)`` keys derived as
    ``fold_in(fold_in(key, row), max(pos, 0))``."""
    b = pos.shape[0]

    def one(i, p):
        return jax.random.fold_in(jax.random.fold_in(key, i), p)

    return jax.vmap(one)(jnp.arange(b, dtype=jnp.int32),
                         jnp.maximum(jnp.asarray(pos, jnp.int32), 0))


def sample_tokens(logits, key, pos, *, temperature: float = 0.0,
                  top_k: int = 0):
    """``logits (B, V)`` -> sampled token ids ``(B,)`` int32.

    ``temperature == 0`` is greedy argmax (``key``/``pos`` unused, no PRNG
    in the trace). Otherwise logits are scaled by ``1/temperature`` and
    sampled categorically, optionally restricted to the ``top_k`` largest
    entries per row. ``pos`` is the per-slot absolute position the sample
    *follows* (the engine's ragged ``pos`` vector); inactive rows
    (pos < 0) still produce a (meaningless) token -- callers mask them.
    """
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    keys = slot_keys(key, pos)
    scaled = logits.astype(jnp.float32) / float(temperature)
    if top_k and top_k > 0:
        vals, idx = jax.lax.top_k(scaled, int(top_k))
        choice = jax.vmap(jax.random.categorical)(keys, vals)
        return jnp.take_along_axis(
            idx, choice[:, None], axis=1)[:, 0].astype(jnp.int32)
    return jax.vmap(jax.random.categorical)(keys, scaled).astype(jnp.int32)


def sample_token_row(logits_row, key, slot: int, position: int, *,
                     temperature: float = 0.0, top_k: int = 0) -> int:
    """Single-row variant with the SAME key derivation as
    :func:`sample_tokens`, for host-side call sites that hold one logits
    row for a known slot (the engine's prefill-sampled first token). The
    row's key is ``fold_in(fold_in(key, slot), max(position, 0))`` --
    identical to what the batched decode would use for that slot."""
    if temperature <= 0.0:
        return int(np.argmax(np.asarray(logits_row)))
    k = jax.random.fold_in(jax.random.fold_in(key, int(slot)),
                           max(int(position), 0))
    scaled = jnp.asarray(logits_row, jnp.float32) / float(temperature)
    if top_k and top_k > 0:
        vals, idx = jax.lax.top_k(scaled, int(top_k))
        return int(idx[jax.random.categorical(k, vals)])
    return int(jax.random.categorical(k, scaled))
