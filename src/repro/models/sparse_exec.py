"""Export pruned models to BSR serving form (the TVM relay-conversion analogue).

Training keeps dense weights + block masks (core.pruner). Serving packs the
pruned projections into tile-granular BSR: pattern arrays become static
(kernel specializations, cached by core.pattern_reuse) and only the tile
values live in the servable param tree.

For scan-stacked layer groups the per-layer patterns are UNIONED so a single
specialization serves all periods (values are per-layer, zeros where a layer
lacks a block). High inter-layer pattern overlap -- which the paper's small-
block regularization promotes -- keeps the union tight; `union_overhead`
quantifies the waste, the instrumentation the paper proposes as follow-up.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.kernels.bsr_matmul import KernelBSR, pack_bsr

# projection names exported per mixer/ffn kind
_ATTN_PROJS = ("wq", "wk", "wv", "wo")
_FFN_PROJS = ("wi", "wg", "wo")


def _tile_mask(w: np.ndarray, tile) -> np.ndarray:
    n, k = w.shape
    bn, bk = tile
    return np.any(w.reshape(n // bn, bn, k // bk, bk) != 0, axis=(1, 3))


def pack_stacked(w_stacked: np.ndarray, tile) -> Tuple[KernelBSR, jax.Array, Dict]:
    """(L, N, K) -> (pattern pack, per-layer data (L, nnzt, bn, bk), stats)."""
    l, n, k = w_stacked.shape
    bn, bk = tile
    masks = np.stack([_tile_mask(w_stacked[i], tile) for i in range(l)])
    union = masks.any(axis=0)
    # build the pattern from a dense "ones at union" stand-in
    proto = np.kron(union.astype(np.float32), np.ones(tile, np.float32))
    pack = pack_bsr(proto, tile)
    rows = pack.row_id[: pack.nnzt]
    cols = pack.col_id
    blocks = w_stacked.reshape(l, n // bn, bn, k // bk, bk).transpose(0, 1, 3, 2, 4)
    data = blocks[:, rows, cols]                      # (L, nnzt, bn, bk)
    per_layer_nnz = masks.sum(axis=(1, 2))
    stats = {
        "union_nnzt": int(union.sum()),
        "mean_layer_nnzt": float(per_layer_nnz.mean()),
        "union_overhead": float(union.sum() / max(per_layer_nnz.mean(), 1.0)),
    }
    return pack, jnp.asarray(data), stats


def pack_single(w: np.ndarray, tile) -> Tuple[KernelBSR, jax.Array]:
    pack = pack_bsr(w, tile)
    return pack, pack.data


def export_lm_sparse(params, cfg: ModelConfig, tile=(128, 128)):
    """Replace attention projections of an LM param tree with packed values.

    Returns (sparse_params, packs, stats): ``packs`` maps layer scopes
    ('blocks/<i>/<proj>', 'prefix/<i>/<proj>', ...) to static KernelBSR
    patterns; forward() consumes them via the ``packs=`` argument.
    """
    packs: Dict[str, KernelBSR] = {}
    stats: Dict[str, Dict] = {}
    new = jax.tree_util.tree_map(lambda x: x, params)  # shallow copy-ish

    def export_attn(layer_params, scope, stacked):
        if "attn" not in layer_params:
            return layer_params
        ap = dict(layer_params["attn"])
        for proj in _ATTN_PROJS:
            if proj not in ap:
                continue
            w = np.asarray(jax.device_get(ap[proj]["w"]), np.float32)
            if stacked:
                if w.shape[1] % tile[0] or w.shape[2] % tile[1]:
                    continue
                pack, data, st = pack_stacked(w, tile)
            else:
                if w.shape[0] % tile[0] or w.shape[1] % tile[1]:
                    continue
                pack, data = pack_single(w, tile)
                st = {"union_nnzt": pack.nnzt}
            packs[f"{scope}/{proj}"] = pack
            stats[f"{scope}/{proj}"] = st
            ap[proj] = {"w": data.astype(ap[proj]["w"].dtype)}
        out = dict(layer_params)
        out["attn"] = ap
        return out

    new["prefix"] = tuple(export_attn(lp, f"prefix/{i}/attn", False)
                          for i, lp in enumerate(params["prefix"]))
    new["blocks"] = tuple(export_attn(lp, f"blocks/{i}/attn", True)
                          for i, lp in enumerate(params["blocks"]))
    new["suffix"] = tuple(export_attn(lp, f"suffix/{i}/attn", False)
                          for i, lp in enumerate(params["suffix"]))
    return new, packs, stats


def export_bert_sparse(params, cfg: ModelConfig, tile=(64, 64),
                       include_ffn=True):
    """Per-layer BSR export for the (unrolled) BERT encoder."""
    packs: Dict[str, KernelBSR] = {}
    new_layers = []
    for i, lp in enumerate(params["layers"]):
        nlp = dict(lp)
        ap = dict(lp["attn"])
        for proj in _ATTN_PROJS:
            w = np.asarray(jax.device_get(ap[proj]["w"]), np.float32)
            pack, data = pack_single(w, tile)
            packs[f"layers/{i}/attn/{proj}"] = pack
            ap[proj] = {"w": data.astype(lp["attn"][proj]["w"].dtype)}
        nlp["attn"] = ap
        if include_ffn:
            fp = dict(lp["ffn"])
            for proj in ("wi", "wo"):
                w = np.asarray(jax.device_get(fp[proj]["w"]), np.float32)
                pack, data = pack_single(w, tile)
                packs[f"layers/{i}/ffn/{proj}"] = pack
                fp[proj] = {"w": data.astype(lp["ffn"][proj]["w"].dtype)}
            nlp["ffn"] = fp
        new_layers.append(nlp)
    new = dict(params)
    new["layers"] = tuple(new_layers)
    return new, packs
