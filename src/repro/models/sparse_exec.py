"""DEPRECATED shim -- the export passes moved to ``repro.serving.export``.

This module remains import-compatible (``export_bert_sparse`` /
``export_lm_sparse`` / ``pack_stacked`` / ``pack_single`` keep their exact
signatures) but new code should go through the serving facade instead:

    from repro.serving import ServingSpec, prepare_servable

``prepare_servable`` runs the whole prune -> BSR export -> RowPackPlan ->
registry pipeline for every model family and returns a Servable handle with
``forward`` / ``decode_step`` / ``stats`` / ``save`` (docs/API.md).
"""
from __future__ import annotations

import warnings

from repro.serving.export import (  # noqa: F401  (re-exported API)
    export_bert_sparse, export_lm_sparse, pack_single, pack_stacked)

warnings.warn(
    "repro.models.sparse_exec is deprecated; import from repro.serving "
    "(prepare_servable) or repro.serving.export instead",
    DeprecationWarning, stacklevel=2)
