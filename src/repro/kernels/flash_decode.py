"""Split-K flash-decode attention kernels (Pallas TPU).

One decode step attends a single query token per request slot against that
slot's KV cache. The XLA reference (models/attention.decode_attention)
materializes the (B, Hkv, G, 1, T) score tensor and softmaxes it -- two HBM
round-trips over a tensor that grows with context length. The kernels here
run the flash-style online softmax on chip instead:

  * ``flash_decode``        -- dense (B, T, Hkv, D) slot caches. The grid is
      (B, Hkv, num_split): the KV axis is cut into ``num_split`` chunks
      (split-K) and each grid step folds one chunk into running
      (max, sum, acc) VMEM scratch; TPU grids iterate sequentially, so the
      scratch IS the split-K reduction and the normalized output is written
      by the last split -- no inter-step HBM traffic.
  * ``paged_flash_decode``  -- page-pool caches (serving/paging.py). One KV
      split == one page: the scalar-prefetched page table drives the
      BlockSpec index map, so each grid step DMAs its page from the pool
      *in place*. This kills the dense-view reassembly tax: the PR-7 paged
      decode gathered a (B, T, Hkv, D) contiguous view per step per layer
      before attending; here no view is ever materialized.

Masking follows decode_attention exactly: ``pos_map`` holds the absolute
position stored in each cache slot (-1 = empty), queries see positions
``0 <= kvp <= pos`` (minus the window cut for ring caches). Because masked
lanes are zeroed *before* the exp (never ``exp(-inf - -inf)``), a fully
masked split -- an unmapped page, an empty ring region, an inactive slot --
contributes exact zeros, which is what makes ``paged_flash_decode``
bit-exact vs ``flash_decode`` over the gathered dense view with matching
split boundaries (tests/test_pallas_serving.py).

Kernel selection: ``resolved_decode_kernel()`` reads an explicit
``decode_kernel_override`` context (set by Servable from the ServingSpec at
trace time), else the ``REPRO_DECODE_KERNEL`` env (auto|xla|flash), else
picks flash on TPU and the XLA path everywhere else (interpret mode stays a
correctness oracle, not a serving path -- docs/PERF.md).
"""
from __future__ import annotations

import contextlib
import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

_ENV = "REPRO_DECODE_KERNEL"
DECODE_KERNELS = ("auto", "xla", "flash")
_OVERRIDE: list = []


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def resolved_decode_kernel() -> str:
    """'xla' or 'flash': innermost override context > env > platform."""
    if _OVERRIDE:
        return _OVERRIDE[-1]
    kind = os.environ.get(_ENV, "").strip() or "auto"
    if kind not in DECODE_KERNELS:
        raise ValueError(f"{_ENV}={kind!r}; expected one of {DECODE_KERNELS}")
    if kind == "auto":
        return "flash" if jax.default_backend() == "tpu" else "xla"
    return kind


@contextlib.contextmanager
def decode_kernel_override(kind):
    """Pin the decode kernel inside this context ('xla'/'flash'). The
    attention decode branch consults it at TRACE time, so wrapping a jit
    closure's body bakes the choice into that executable. None/'auto' is a
    no-op (fall through to env/platform)."""
    if kind in (None, "auto"):
        yield
        return
    assert kind in ("xla", "flash"), kind
    _OVERRIDE.append(kind)
    try:
        yield
    finally:
        _OVERRIDE.pop()


def default_kv_split(t: int) -> int:
    """Split count keeping ~128-position chunks, capped at 8 -- past that
    the per-split (m, l, acc) reduce traffic outweighs the DMA overlap."""
    return max(1, min(8, t // 128))


# --------------------------------------------------------------------------
# shared online-softmax split step
# --------------------------------------------------------------------------

def _flash_decode_kernel(*refs, n_prefetch, num_split, window, scale):
    """One KV split: fold (k, v, kvp) into running (m, l, acc) scratch.

    refs = (*scalar_prefetch, q, k, v, kvp, o, m_scratch, l_scratch, acc).
    prefetch[0] is the per-slot position vector; the paged variant adds the
    flattened page table (consumed only by the BlockSpec index maps).
    """
    pos_ref = refs[0]
    q_ref, k_ref, v_ref, kvp_ref, o_ref, m_ref, l_ref, acc_ref = \
        refs[n_prefetch:]
    b = pl.program_id(0)
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                                   # (G, D)
    k = k_ref[0, :, 0, :]                             # (ck, D)
    v = v_ref[0, :, 0, :]
    s_ = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale   # (G, ck)
    kvp = kvp_ref[...]                                # (1, ck)
    pos = pos_ref[b]
    ok = (kvp >= 0) & (kvp <= pos)
    if window > 0:
        ok &= kvp > pos - window
    s_ = jnp.where(ok, s_, NEG_INF)
    m_prev = m_ref[:, :1]                             # (G, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s_, axis=-1, keepdims=True))
    # masked lanes zero BEFORE exp: a fully masked split keeps m at NEG_INF
    # and must contribute exactly nothing (exp(NEG_INF - NEG_INF) == 1)
    p = jnp.where(ok, jnp.exp(s_ - m_new), 0.0)
    corr = jnp.exp(m_prev - m_new)                    # (G, 1)
    l_new = l_ref[:, :1] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)           # (G, D)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(si == num_split - 1)
    def _():
        l = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("window", "num_split", "interpret"))
def _flash_decode_call(q4, k, v, kvp, pos, *, window, num_split, interpret):
    b, hkv, g, d = q4.shape
    t = k.shape[1]
    ck = t // num_split
    grid = (b, hkv, num_split)
    return pl.pallas_call(
        functools.partial(_flash_decode_kernel, n_prefetch=1,
                          num_split=num_split, window=window,
                          scale=d ** -0.5),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, g, d), lambda b, h, s, pos: (b, h, 0, 0)),
                pl.BlockSpec((1, ck, 1, d), lambda b, h, s, pos: (b, s, h, 0)),
                pl.BlockSpec((1, ck, 1, d), lambda b, h, s, pos: (b, s, h, 0)),
                pl.BlockSpec((1, ck), lambda b, h, s, pos: (b, s)),
            ],
            out_specs=pl.BlockSpec((1, 1, g, d),
                                   lambda b, h, s, pos: (b, h, 0, 0)),
            scratch_shapes=[pltpu.VMEM((g, 128), jnp.float32),
                            pltpu.VMEM((g, 128), jnp.float32),
                            pltpu.VMEM((g, d), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q4.dtype),
        interpret=interpret,
    )(pos, q4, k, v, kvp)


def flash_decode(q, k_cache, v_cache, kv_positions, pos, *, window=0,
                 kv_split=None, interpret=None):
    """Split-K one-step decode: q (B,1,Hq,D) vs dense caches (B,T,Hkv,D).

    Same contract as decode_attention (ragged per-slot ``pos``, shared or
    per-slot ``kv_positions``, ring-cache ``window``). ``kv_split`` chunks
    the KV axis (T is padded with masked slots to a multiple); matching
    split boundaries make two runs of this kernel -- e.g. over a paged
    cache's gathered view vs ``paged_flash_decode`` -- bit-exact.
    """
    b, _, hq, d = q.shape
    t, hkv = k_cache.shape[1], k_cache.shape[2]
    g = hq // hkv
    num_split = min(kv_split or default_kv_split(t), t)
    posv = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    kvp = jnp.asarray(kv_positions, jnp.int32)
    kvp = jnp.broadcast_to(kvp[None, :] if kvp.ndim == 1 else kvp, (b, t))
    pad = (-t) % num_split
    if pad:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kvp = jnp.pad(kvp, ((0, 0), (0, pad)), constant_values=-1)
    if interpret is None:
        interpret = _interpret_default()
    out = _flash_decode_call(q[:, 0].reshape(b, hkv, g, d), k_cache, v_cache,
                             kvp, posv, window=window, num_split=num_split,
                             interpret=interpret)
    return out.reshape(b, 1, hq, d)


# --------------------------------------------------------------------------
# paged variant: one split == one page, gathered in place via the table
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("window", "npg", "interpret"))
def _paged_flash_call(q4, k_pages, v_pages, pt_flat, pm, pos, *, window, npg,
                      interpret):
    b, hkv, g, d = q4.shape
    ps = k_pages.shape[1]
    grid = (b, hkv, npg)

    def page_map(b, h, s, pos, pt):
        # unmapped (-1) pages clip to page 0; their pos_map slots are -1 so
        # every lane of the split is masked before the exp
        return (jnp.maximum(pt[b * npg + s], 0), 0, h, 0)

    return pl.pallas_call(
        functools.partial(_flash_decode_kernel, n_prefetch=2, num_split=npg,
                          window=window, scale=d ** -0.5),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, g, d),
                             lambda b, h, s, pos, pt: (b, h, 0, 0)),
                pl.BlockSpec((1, ps, 1, d), page_map),
                pl.BlockSpec((1, ps, 1, d), page_map),
                pl.BlockSpec((1, ps), lambda b, h, s, pos, pt: (b, s)),
            ],
            out_specs=pl.BlockSpec((1, 1, g, d),
                                   lambda b, h, s, pos, pt: (b, h, 0, 0)),
            scratch_shapes=[pltpu.VMEM((g, 128), jnp.float32),
                            pltpu.VMEM((g, 128), jnp.float32),
                            pltpu.VMEM((g, d), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q4.dtype),
        interpret=interpret,
    )(pos, pt_flat, q4, k_pages, v_pages, pm)


def paged_flash_decode(q, k_pages, v_pages, page_table, pos_map, pos, *,
                       window=0, interpret=None):
    """One-step decode straight off the page pools.

    q (B,1,Hq,D); pools (n_pages, page_size, Hkv, D); ``page_table``
    (B, NP) physical page per logical page (-1 = unmapped); ``pos_map``
    (B, NP*page_size) per-slot occupancy as in the dense layout. Each grid
    step DMAs one page via the prefetched table -- the per-step dense-view
    gather of the XLA paged path never happens.
    """
    b, _, hq, d = q.shape
    _, ps, hkv, _ = k_pages.shape
    npg = page_table.shape[1]
    g = hq // hkv
    assert pos_map.shape == (b, npg * ps), (pos_map.shape, npg, ps)
    posv = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    if interpret is None:
        interpret = _interpret_default()
    out = _paged_flash_call(q[:, 0].reshape(b, hkv, g, d), k_pages, v_pages,
                            page_table.reshape(-1).astype(jnp.int32),
                            jnp.asarray(pos_map, jnp.int32), posv,
                            window=window, npg=npg, interpret=interpret)
    return out.reshape(b, 1, hq, d)
