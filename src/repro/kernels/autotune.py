"""Measured backend selection for block-sparse serving (``backend='auto'``).

The Sparsity Roofline argument (arXiv:2310.00496) -- and this repo's own
``BENCH_kernels.json`` -- say the profitable (backend, tile, density)
region must be *measured on the target device*, not assumed: on the CPU
reference box dense wins most cells outright, ``gather`` overtakes it only
below ~10% density, and ``plan`` only at the paper's 32x1 linear tile.
A hardcoded ``default_backend()`` cannot express any of that. This module
micro-benchmarks the candidate execution paths

    dense       -- plain ``x @ w.T`` (the negative control / usual CPU winner)
    gather      -- one gather per stored tile (``bsr_linear`` backend)
    rowpack     -- row-grouped batched matmul, per-call scatter
    plan        -- precomputed RowPackPlan, data row-grouped offline
    pallas      -- flat-stream TPU kernel (native on TPU; interpret elsewhere)
    masked      -- dense-layout tile-skipping kernel (TPU)
    plan_pallas -- compiled plan-consuming kernel: the RowPackPlan's spill
                   schedule drives the Pallas grid (exec_plan, TPU)

Decode-side, :func:`choose_decode_kernel` runs the same machinery over the
attention decode step ('xla' materialized softmax vs the split-K 'flash'
kernel, kernels/flash_decode.py); its stub proxy charges the flash arm the
split-K reduce traffic (per-split on-chip (m, l, acc) state) so the
crossover moves with context length and split count.

per *pattern fingerprint* on the current device, picks the fastest, and
persists the winner so the cost is paid once per (pattern, device) --
across processes, not just per process.

Cache location and invalidation
-------------------------------
Winners live in ONE json file: ``$REPRO_AUTOTUNE_CACHE`` if set, else
``~/.cache/repro/autotune.json``. Each entry is keyed by
``sha1(pattern fingerprint) : m<batch rows> : <device kind> :
d<device count> : [shard tag :] <mode> : c<candidate-set digest>``, so a
different sparsity pattern, measurement batch size, device kind, *visible
device count*, shard partitioning, timing mode, or candidate set never
reuses a stale winner -- there is nothing else to invalidate. (The device
count and shard tag matter under mesh serving: a winner measured on one
device must not answer for an 8-way-sharded pack whose per-device shard
is an 8x smaller problem.) Delete the file (or point the env var
elsewhere) to force re-tuning.

The file carries a format ``version``; loading an older version silently
discards its entries (they were keyed without the device/shard fields) and
the next ``put`` rewrites the file at the current version -- stale caches
migrate by invalidation, never by crash.

Stub mode (CI determinism)
--------------------------
With ``REPRO_AUTOTUNE_STUB=1`` (or ``stub=True``) no wall-clock timing
runs: backends are ranked by a deterministic FLOP/traffic proxy, so
``backend='auto'`` paths are exercised reproducibly in CI. Tests can also
inject a frozen ``timer`` to exercise the wall-clock code path without
real clocks (tests/test_autotune.py).

Interpret-mode honesty: off-TPU, ``pallas`` and ``masked`` execute in
Pallas interpret mode -- a correctness vehicle thousands of times slower
than any serving path -- so wall-clock mode drops them from the candidate
set off-TPU rather than spending minutes proving they lose (docs/PERF.md).
The stub proxy still ranks them (with an interpret penalty), so their
dispatch path stays exercised.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import exec_plan as xp
from repro.kernels.bsr_matmul import KernelBSR, masked_matmul
from repro.kernels.flash_decode import default_kv_split

CANDIDATES = ("dense", "gather", "rowpack", "plan", "pallas", "masked",
              "plan_pallas")
#: quantized-pack arms, appended to the default candidate set only when
#: the caller serves quantized packs (choose_backend(quant=...)): int8
#: values + per-block scales through the dequant-fused plan matmul (XLA
#: composition / compiled Pallas kernel). Their stub costs price the
#: 4x-smaller value stream plus the scale stream, so 'auto' only picks
#: them where the reduced traffic actually pays.
QUANT_CANDIDATES = ("plan_q8", "plan_pallas_q8")
#: interpret-mode-only off TPU: excluded from wall-clock candidate sets
#: there (docs/PERF.md); the stub proxy still ranks them
INTERPRET_ONLY = ("pallas", "masked", "plan_pallas", "plan_pallas_q8")

#: attention decode-step kernels ranked by choose_decode_kernel
DECODE_CANDIDATES = ("xla", "flash")
#: decode kernels that run in interpret mode off-TPU
DECODE_INTERPRET_ONLY = ("flash",)

_ENV_CACHE = "REPRO_AUTOTUNE_CACHE"
_ENV_STUB = "REPRO_AUTOTUNE_STUB"

#: on-disk winner-cache format. v1 keys lacked the device-count and shard
#: fields (a winner measured on 1 device would answer for 8); v1 files are
#: read as empty and rewritten at the current version on the next put.
CACHE_VERSION = 2


def stub_mode() -> bool:
    return os.environ.get(_ENV_STUB, "").strip() not in ("", "0", "false")


def device_kind() -> str:
    d = jax.devices()[0]
    return f"{d.platform}:{getattr(d, 'device_kind', '?')}".replace(" ", "_")


def pattern_digest(pack: KernelBSR) -> str:
    return hashlib.sha1(xp.kernel_pattern_fingerprint(pack)).hexdigest()[:16]


# --------------------------------------------------------------------------
# pack wrappers consumed by models/common.linear
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, eq=False)
class BackendChoice:
    """A KernelBSR pattern pinned to a measured ``bsr_linear`` backend.

    ``prepare_servable(spec.backend='auto')`` stores these in ``packs``
    when the winner is a runtime-dispatch backend (gather / rowpack /
    pallas); the params tree keeps the packed ``(nnzt, bn, bk)`` values
    and ``models/common.linear`` routes through ``bsr_matmul`` with this
    backend instead of ``default_backend()``."""

    pack: KernelBSR
    backend: str

    @property
    def shape(self):
        return self.pack.shape

    @property
    def tile(self):
        return self.pack.tile

    @property
    def density(self) -> float:
        return self.pack.density

    @property
    def fingerprint(self) -> bytes:
        return (b"choice:" + self.backend.encode()
                + xp.kernel_pattern_fingerprint(self.pack))

    def __hash__(self):
        return hash(self.fingerprint)

    def __eq__(self, other):
        return (isinstance(other, BackendChoice)
                and self.fingerprint == other.fingerprint)


@dataclasses.dataclass(frozen=True, eq=False)
class MaskedPack:
    """Dense-layout serving through the tile-skipping ``masked`` kernel:
    the params tree keeps the DENSE ``(N, K)`` weight and only this static
    tile occupancy mask rides in ``packs`` (compute skipped, weight
    traffic paid -- the paper's format-support negative control)."""

    tile_mask: np.ndarray     # (R, C) bool, True = stored tile
    shape: Tuple[int, int]
    tile: Tuple[int, int]

    @property
    def density(self) -> float:
        return float(np.mean(self.tile_mask))

    @property
    def fingerprint(self) -> bytes:
        header = np.array([*self.shape, *self.tile], np.int64)
        return (b"masked:" + header.tobytes()
                + np.packbits(np.asarray(self.tile_mask, bool)).tobytes())

    def __hash__(self):
        return hash(self.fingerprint)

    def __eq__(self, other):
        return (isinstance(other, MaskedPack)
                and self.fingerprint == other.fingerprint)


def masked_pack_from(pack: KernelBSR) -> MaskedPack:
    mask = np.zeros((pack.n_brows, pack.n_bcols), bool)
    rows = np.asarray(pack.row_id[: pack.real_nnzt])
    cols = np.asarray(pack.col_id[: pack.real_nnzt])
    mask[rows, cols] = True
    return MaskedPack(tile_mask=mask, shape=pack.shape, tile=pack.tile)


def dense_from_pack(pack: KernelBSR, data=None) -> np.ndarray:
    """Densify a KernelBSR back to (N, K) -- the dense / masked candidate's
    weight. ``data`` defaults to the pack's stored values."""
    data = np.asarray(jax.device_get(pack.data if data is None else data))
    n, k = pack.shape
    bn, bk = pack.tile
    w = np.zeros((n // bn, bn, k // bk, bk), data.dtype)
    rows = np.asarray(pack.row_id[: pack.real_nnzt])
    cols = np.asarray(pack.col_id[: pack.real_nnzt])
    w[rows, :, cols, :] = data[: pack.real_nnzt]
    return w.reshape(n, k)


def shard_subpack(pack: KernelBSR, n_shards: int, axis: str) -> KernelBSR:
    """The measurement proxy for a tensor-parallel shard: the sub-pattern
    of the MOST occupied shard (the per-device straggler that sets the
    layer's critical path), as its own KernelBSR over the per-device
    sub-shape. ``axis='out'`` slices output block rows, ``'in'`` input
    block cols (serving/export.shard_axis_for conventions)."""
    from repro.kernels.bsr_matmul import pack_bsr
    rows = np.asarray(pack.row_id[: pack.real_nnzt], np.int64)
    cols = np.asarray(pack.col_id[: pack.real_nnzt], np.int64)
    per = (pack.n_brows if axis == "out" else pack.n_bcols) // n_shards
    shard_of = (rows if axis == "out" else cols) // per
    s = int(np.bincount(shard_of, minlength=n_shards).argmax())
    w = dense_from_pack(pack)
    bn, bk = pack.tile
    if axis == "out":
        sub = w[s * per * bn: (s + 1) * per * bn, :]
    else:
        sub = w[:, s * per * bk: (s + 1) * per * bk]
    return pack_bsr(sub, pack.tile)


# --------------------------------------------------------------------------
# the on-disk winner cache
# --------------------------------------------------------------------------

def default_cache_path() -> str:
    env = os.environ.get(_ENV_CACHE)
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "autotune.json")


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0


def _valid_entries(entries) -> Dict[str, dict]:
    """Keep only well-formed (str key -> dict record) winner entries; a
    hand-edited or bit-rotted file degrades to fewer cached winners, never
    to a crash in ``get``'s consumers."""
    if not isinstance(entries, dict):
        return {}
    return {k: v for k, v in entries.items()
            if isinstance(k, str) and isinstance(v, dict)}


class AutotuneCache:
    """Winner cache persisted as one JSON file (see module docstring for
    the key scheme / invalidation rules). Reads merge-on-write, so
    concurrent processes at worst re-measure -- they never corrupt; an
    unreadable/corrupt file reads as empty and is rewritten by the next
    ``put`` (tests/test_autotune.py)."""

    def __init__(self, path: Optional[str] = None):
        self.path = path or default_cache_path()
        self.stats = CacheStats()
        self._entries: Optional[Dict[str, dict]] = None

    def _load(self) -> Dict[str, dict]:
        if self._entries is None:
            # any unreadable file -- missing, truncated mid-write, binary
            # garbage, wrong JSON shape -- reads as an EMPTY cache (worst
            # case: re-measure) and is replaced wholesale by the next
            # put(); a corrupt winner cache must never crash serving.
            # ValueError covers JSONDecodeError and UnicodeDecodeError.
            self._entries = {}
            try:
                with open(self.path) as f:
                    doc = json.load(f)
                # migration-by-invalidation: entries written under an older
                # key scheme (no device count / shard tag) are dropped, not
                # crashed on; the file is rewritten at CACHE_VERSION by the
                # next put()
                if isinstance(doc, dict) \
                        and doc.get("version") == CACHE_VERSION:
                    self._entries = _valid_entries(doc.get("entries"))
            except (OSError, ValueError):
                pass
        return self._entries

    def get(self, key: str) -> Optional[dict]:
        rec = self._load().get(key)
        if rec is None:
            self.stats.misses += 1
        else:
            self.stats.hits += 1
        return rec

    def put(self, key: str, record: dict) -> None:
        entries = self._load()
        entries[key] = record
        # merge-on-write: pick up entries other processes added meanwhile
        # (same-version files only: stale-format entries stay invalidated)
        on_disk: Dict[str, dict] = {}
        try:
            with open(self.path) as f:
                doc = json.load(f)
            if isinstance(doc, dict) and doc.get("version") == CACHE_VERSION:
                on_disk = _valid_entries(doc.get("entries"))
        except (OSError, ValueError):
            pass
        on_disk.update(entries)
        self._entries = on_disk
        os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
        tmp = f"{self.path}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump({"version": CACHE_VERSION, "entries": on_disk}, f,
                      indent=1, sort_keys=True)
        os.replace(tmp, self.path)


_DEFAULT_CACHE: Optional[AutotuneCache] = None


def default_cache() -> AutotuneCache:
    """Process-wide cache over :func:`default_cache_path` (re-resolved if
    the env var changed, so tests can repoint it)."""
    global _DEFAULT_CACHE
    path = default_cache_path()
    if _DEFAULT_CACHE is None or _DEFAULT_CACHE.path != path:
        _DEFAULT_CACHE = AutotuneCache(path)
    return _DEFAULT_CACHE


# --------------------------------------------------------------------------
# candidate executors + measurement
# --------------------------------------------------------------------------

def _candidate_fn(pack: KernelBSR, name: str):
    """-> (jitted fn, data arg) executing this backend for ``pack``."""
    from repro.kernels.ops import bsr_linear  # local: ops imports exec_plan
    if name == "dense":
        w = jnp.asarray(dense_from_pack(pack))
        return jax.jit(lambda x, w_: x @ w_.T), w
    if name == "plan":
        plan = xp.plan_for_pack(pack)
        data = xp.pack_plan_data(plan, pack.data)
        return jax.jit(lambda x, d, _p=plan: xp.plan_linear(x, d, _p)), data
    if name == "masked":
        mp = masked_pack_from(pack)
        w = jnp.asarray(dense_from_pack(pack))
        mask = jnp.asarray(mp.tile_mask)
        tile = pack.tile
        return (jax.jit(lambda x, w_: masked_matmul(
            x, w_, mask, tile=tile,
            interpret=jax.default_backend() != "tpu")), w)
    if name == "plan_pallas":
        plan = xp.plan_for_pack(pack)
        data = xp.pack_plan_data(plan, pack.data)
        return (jax.jit(lambda x, d, _p=plan:
                        xp.plan_linear_pallas(x, d, _p)), data)
    if name in ("plan_q8", "plan_pallas_q8"):
        # quantize the measurement data exactly like export would: the
        # timed op consumes int8 values + fp32 scales, dequant fused
        plan = xp.plan_for_pack(pack)
        data_rp = xp.pack_plan_data(plan, pack.data)
        q, s = xp.quantize_plan_values(
            data_rp, "int8", xp.quant_granularity(pack.tile))
        if name == "plan_q8":
            return (jax.jit(lambda x, d, _s=s, _p=plan:
                            xp.plan_q_linear(x, d, _s, _p)), q)
        return (jax.jit(lambda x, d, _s=s, _p=plan:
                        xp.plan_q_linear_pallas(x, d, _s, _p)), q)
    if name in ("gather", "rowpack", "pallas"):
        return (jax.jit(lambda x, d, _pk=pack, _b=name:
                        bsr_linear(x, d, _pk, _b)), pack.data)
    raise ValueError(f"unknown autotune candidate {name!r}")


def measure(pack: KernelBSR, m: int, candidates: Sequence[str], *,
            reps: int = 5, timer: Optional[Callable] = None
            ) -> Tuple[Dict[str, float], Dict[str, float]]:
    """Paired wall-clock micro-benchmark: interleave the reps of all
    candidates round-robin (machine drift hits every arm equally, the
    kernel_bench discipline). Returns ``(times, scores)``:

      * ``times`` -- min-of-reps seconds per candidate (reporting);
      * ``scores`` -- the RANKING statistic: per round, each arm's time is
        divided by the round's first-candidate time (arms in one round see
        the same machine state), and the median of those paired ratios is
        taken. On a shared box whose speed drifts between rounds this
        orders near-ties far more reliably than comparing each arm's
        luckiest absolute rep.

    ``timer(name, fn, args)`` substitutes the measurement -- the
    frozen-clock hook for tests (scores == times there)."""
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(m, pack.shape[1]).astype(np.float32))
    arms = [(name,) + _candidate_fn(pack, name) for name in candidates]
    if timer is not None:
        times = {name: float(timer(name, fn, (x, data)))
                 for name, fn, data in arms}
        return times, dict(times)
    for _, fn, data in arms:
        jax.block_until_ready(fn(x, data))          # compile + warm
    ts: Dict[str, list] = {name: [] for name, _, _ in arms}
    for _ in range(reps):
        for name, fn, data in arms:
            t0 = time.perf_counter()
            jax.block_until_ready(fn(x, data))
            ts[name].append(time.perf_counter() - t0)
    anchor = np.asarray(ts[arms[0][0]], np.float64)
    scores = {name: float(np.median(np.asarray(t, np.float64) / anchor))
              for name, t in ts.items()}
    return {name: float(np.min(t)) for name, t in ts.items()}, scores


def stub_costs(pack: KernelBSR, m: int,
               candidates: Sequence[str]) -> Dict[str, float]:
    """Deterministic FLOP/traffic proxy (pseudo-seconds) used instead of
    wall clocks in stub mode. Not calibrated -- its only contracts are
    determinism and roughly-roofline-shaped ordering (dense wins dense-ish
    cells, the sparse paths win only when density actually pays, interpret
    mode never wins off-TPU)."""
    n, k = pack.shape
    bn, bk = pack.tile
    nnzt = pack.real_nnzt
    rows = np.asarray(pack.row_id[: nnzt], np.int64)
    counts = np.bincount(rows, minlength=pack.n_brows)
    p_max = max(1, int(counts.max()))
    plan = xp.plan_for_pack(pack)
    on_tpu = jax.default_backend() == "tpu"
    interp = 0.0 if on_tpu else 1e6 * nnzt          # interpret-mode penalty
    traffic = 8.0                                   # weight-stream weight
    out = {}
    for name in candidates:
        if name == "dense":
            c = m * n * k + traffic * n * k
        elif name == "gather":
            c = 2.5 * m * nnzt * bn * bk + traffic * nnzt * bn * bk
        elif name == "rowpack":
            # per-call scatter of every stored tile + padded batched matmul
            c = (4 * traffic * nnzt * bn * bk
                 + m * pack.n_brows * p_max * bn * bk)
        elif name == "plan":
            c = (m * plan.n_vrows * plan.p_max * bn * bk
                 + traffic * nnzt * bn * bk)
            if plan.spilled:
                c += m * plan.n_vrows * bn
        elif name == "pallas":
            c = m * nnzt * bn * bk + traffic * nnzt * bn * bk + interp
        elif name == "plan_pallas":
            # same real-tile FLOPs and weight stream as 'pallas', minus the
            # padded-slot work 'plan' pays, with spills + epilogue folded
            # into the row-change write -- a small scheduling edge that
            # breaks the tie toward the plan-consuming kernel on TPU
            c = (0.97 * m * nnzt * bn * bk + traffic * nnzt * bn * bk
                 + interp)
        elif name in ("plan_q8", "plan_pallas_q8"):
            # int8 values cut the weight stream 4x vs fp32, but add a
            # per-block (or per-row-group) fp32 scale stream; FLOPs match
            # the fp32 arm (dequant fuses into the accumulate)
            gran = xp.quant_granularity(pack.tile)
            scale_elems = plan.n_vrows * (plan.p_max if gran == "block"
                                          else 1)
            qtraffic = traffic * nnzt * bn * bk / 4.0 + traffic * scale_elems
            if name == "plan_q8":
                c = m * plan.n_vrows * plan.p_max * bn * bk + qtraffic
                if plan.spilled:
                    c += m * plan.n_vrows * bn
            else:
                c = 0.97 * m * nnzt * bn * bk + qtraffic + interp
        elif name == "masked":
            c = m * nnzt * bn * bk + traffic * n * k + interp
        else:
            raise ValueError(f"unknown autotune candidate {name!r}")
        out[name] = float(c)
    return out


# --------------------------------------------------------------------------
# the chooser
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Choice:
    backend: str
    costs: Dict[str, float]     # seconds (wallclock) or proxy (stub)
    cache_hit: bool
    mode: str                   # 'wallclock' | 'stub'
    key: str


def choose_backend(pack: KernelBSR, m: int = 256, *,
                   candidates: Optional[Sequence[str]] = None,
                   cache: Optional[AutotuneCache] = None,
                   stub: Optional[bool] = None, reps: int = 5,
                   timer: Optional[Callable] = None,
                   shard: Optional[Tuple[int, str]] = None,
                   quant: str = "none") -> Choice:
    """Pick the fastest execution path for ``pack`` on this device.

    Consults the on-disk winner cache first (one measurement per
    (pattern, shard, m, device kind, device count, mode, quant, value
    dtype) EVER, across processes); on a miss it measures (or, in stub
    mode, ranks by the deterministic proxy) and persists the winner.

    ``quant`` is the serving pack quantization ('none' | 'int8' | 'fp8').
    When set and ``candidates`` is None, the quantized arms
    (:data:`QUANT_CANDIDATES`) join the default set so 'auto' can pick
    between fp32 and quantized plans. It is always folded into the cache
    key -- alongside the value dtype -- so a winner measured for fp32
    packs never answers for quantized ones (and vice versa); entries
    written before this keying are simply never matched again.

    ``shard = (n_shards, axis)`` tags the key with the tensor-parallel
    partitioning AND the per-shard sub-problem shape, and the measurement
    itself runs on the per-shard sub-problem (:func:`shard_subpack`, the
    most occupied shard): an 8-way-sharded pack runs 8 per-device problems
    an 8th the size, so a winner measured unsharded (or at a different
    shard count) is neither keyed nor measured for it.
    """
    stub = stub_mode() if stub is None else bool(stub)
    cache = cache if cache is not None else default_cache()
    if candidates is None:
        candidates = list(CANDIDATES)
        if quant != "none":
            candidates += list(QUANT_CANDIDATES)
        if not stub and timer is None and jax.default_backend() != "tpu":
            candidates = [c for c in candidates if c not in INTERPRET_ONLY]
    mode = "stub" if stub else "wallclock"
    # the candidate set is part of the key: a winner measured over a
    # narrow set must not answer for a broader one (the extra backends
    # were never measured)
    cand_tag = hashlib.sha1(
        ",".join(sorted(candidates)).encode()).hexdigest()[:8]
    shard_tag = ""
    measure_pack = pack
    if shard is not None and int(shard[0]) > 1:
        from repro.kernels.exec_plan import shard_divisible
        n_shards, axis = int(shard[0]), shard[1]
        if not shard_divisible(pack, n_shards, axis):
            # an indivisible pattern serves through the replicated
            # fallback, i.e. unsharded -- key and measure it as such
            # (serving/export guards this too; this covers direct callers)
            n_shards = 0
        else:
            n, k = pack.shape
            sn = n // n_shards if axis == "out" else n
            sk = k // n_shards if axis == "in" else k
            shard_tag = f":s{axis}{n_shards}x{sn}x{sk}"
            # measure the per-device problem, not the full matrix: under
            # TP each device runs a 1/n_shards-sized matmul, whose winner
            # can differ (smaller problems lean dense)
            measure_pack = shard_subpack(pack, n_shards, axis)
    key = (f"{pattern_digest(pack)}:m{int(m)}:{device_kind()}"
           f":d{jax.device_count()}{shard_tag}:{mode}"
           f":q{quant}:w{np.dtype(pack.data.dtype).name}:c{cand_tag}")
    rec = cache.get(key)
    if rec is not None and rec.get("backend") in candidates:
        return Choice(rec["backend"], dict(rec.get("costs", {})), True,
                      mode, key)
    if stub:
        costs = stub_costs(measure_pack, m, candidates)
        scores = costs
    else:
        costs, scores = measure(measure_pack, m, candidates, reps=reps,
                                timer=timer)
    backend = min(scores, key=scores.get)
    cache.put(key, {"backend": backend, "costs": costs, "mode": mode,
                    "m": int(m), "device": device_kind(),
                    "devices": jax.device_count(),
                    "shard": shard_tag.lstrip(":") or None,
                    "quant": quant,
                    "created": time.strftime("%Y-%m-%dT%H:%M:%S")})
    return Choice(backend, costs, False, mode, key)


# --------------------------------------------------------------------------
# decode-kernel selection (attention decode step: 'xla' vs split-K 'flash')
# --------------------------------------------------------------------------

def decode_stub_costs(*, b: int, t: int, hq: int, hkv: int, d: int,
                      kv_split: int) -> Dict[str, float]:
    """Deterministic proxy for the one-token decode step (pseudo-seconds).

    Both arms stream the full KV cache once (the roofline floor). On top of
    that, 'xla' pays the materialized (B, Hq, T) scores + probs HBM
    round-trip; 'flash' pays the split-K reduce: per split, the on-chip
    (m, l, acc) running state -- (G, d + 2) floats per (slot, kv head) --
    is corrected and re-written, so cost grows with ``kv_split`` while the
    score tensor never touches HBM. Off-TPU the interpret penalty keeps
    'flash' from ever winning (same contract as INTERPRET_ONLY)."""
    g = max(1, hq // hkv)
    on_tpu = jax.default_backend() == "tpu"
    interp = 0.0 if on_tpu else 1e6 * t
    traffic = 8.0
    flops = 2.0 * b * hq * t * d
    kv_read = traffic * b * hkv * t * d
    return {
        "xla": flops + kv_read + traffic * 2.0 * b * hq * t,
        "flash": (flops + kv_read
                  + traffic * b * hkv * g * (d + 2) * kv_split + interp),
    }


def _measure_decode(b, t, hq, hkv, d, window, kv_split, candidates, *,
                    reps=3, timer=None):
    """Paired wall-clock micro-benchmark of the decode arms (same
    round-robin + paired-ratio discipline as :func:`measure`)."""
    from repro.kernels.flash_decode import flash_decode
    from repro.models.attention import decode_attention
    rng = np.random.RandomState(0)
    q = jnp.asarray(rng.randn(b, 1, hq, d).astype(np.float32))
    k = jnp.asarray(rng.randn(b, t, hkv, d).astype(np.float32))
    v = jnp.asarray(rng.randn(b, t, hkv, d).astype(np.float32))
    pm = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))
    pos = jnp.full((b,), t - 1, jnp.int32)
    fns = {
        "xla": jax.jit(lambda q, k, v, pm, pos: decode_attention(
            q, k, v, pm, pos, window=window)),
        "flash": jax.jit(lambda q, k, v, pm, pos: flash_decode(
            q, k, v, pm, pos, window=window, kv_split=kv_split)),
    }
    arms = [(name, fns[name]) for name in candidates]
    if timer is not None:
        times = {name: float(timer(name, fn, (q, k, v, pm, pos)))
                 for name, fn in arms}
        return times, dict(times)
    for _, fn in arms:
        jax.block_until_ready(fn(q, k, v, pm, pos))
    ts: Dict[str, list] = {name: [] for name, _ in arms}
    for _ in range(reps):
        for name, fn in arms:
            t0 = time.perf_counter()
            jax.block_until_ready(fn(q, k, v, pm, pos))
            ts[name].append(time.perf_counter() - t0)
    anchor = np.asarray(ts[arms[0][0]], np.float64)
    scores = {name: float(np.median(np.asarray(v_, np.float64) / anchor))
              for name, v_ in ts.items()}
    return {name: float(np.min(v_)) for name, v_ in ts.items()}, scores


def choose_decode_kernel(b: int = 8, t: int = 512, hq: int = 8,
                         hkv: int = 8, d: int = 64, *, window: int = 0,
                         kv_split: Optional[int] = None,
                         cache: Optional[AutotuneCache] = None,
                         stub: Optional[bool] = None, reps: int = 3,
                         timer: Optional[Callable] = None) -> Choice:
    """Pick the attention decode kernel ('xla' | 'flash') for this shape on
    this device, with the same cache / stub / frozen-timer contract as
    :func:`choose_backend`. ``Servable`` consults this when
    ``spec.decode_kernel='auto'`` and no env override pins the choice."""
    if hq < 1 or hkv < 1 or d < 1:
        raise ValueError(f"attention-free decode shape (hq={hq}, hkv={hkv}, "
                         f"d={d}); pin decode kernel instead of tuning")
    stub = stub_mode() if stub is None else bool(stub)
    cache = cache if cache is not None else default_cache()
    split = int(kv_split) if kv_split else default_kv_split(t)
    candidates = list(DECODE_CANDIDATES)
    if not stub and timer is None and jax.default_backend() != "tpu":
        candidates = [c for c in candidates
                      if c not in DECODE_INTERPRET_ONLY]
    mode = "stub" if stub else "wallclock"
    cand_tag = hashlib.sha1(
        ",".join(sorted(candidates)).encode()).hexdigest()[:8]
    key = (f"decode:b{int(b)}t{int(t)}h{int(hq)}g{int(hkv)}d{int(d)}"
           f"w{int(window)}s{split}:{device_kind()}"
           f":d{jax.device_count()}:{mode}:c{cand_tag}")
    rec = cache.get(key)
    if rec is not None and rec.get("backend") in candidates:
        return Choice(rec["backend"], dict(rec.get("costs", {})), True,
                      mode, key)
    if stub:
        all_costs = decode_stub_costs(b=b, t=t, hq=hq, hkv=hkv, d=d,
                                      kv_split=split)
        costs = {name: all_costs[name] for name in candidates}
        scores = costs
    else:
        costs, scores = _measure_decode(b, t, hq, hkv, d, window, split,
                                        candidates, reps=reps, timer=timer)
    backend = min(scores, key=scores.get)
    cache.put(key, {"backend": backend, "costs": costs, "mode": mode,
                    "device": device_kind(),
                    "devices": jax.device_count(),
                    "created": time.strftime("%Y-%m-%dT%H:%M:%S")})
    return Choice(backend, costs, False, mode, key)
