"""Pallas TPU kernels for block-sparse linear layers (the paper's TVM+ ops).

TPU adaptation of the paper's BSR operators: the *sparsity* blocks chosen by
the regularizer (e.g. 32x1) are aggregated on the host into *kernel tiles*
sized for the MXU/VMEM (default 128x128; a tile is stored iff it contains any
nonzero sparsity block). The kernels then skip whole tiles:

  * ``dds``    -- Y(M,N) = X(M,K) @ W^T, W an (N,K) tile-BSR. Scalar-prefetched
                  ``row_id/col_id`` (SMEM) drive the BlockSpec index maps, so
                  only stored tiles are DMA'd into VMEM and MXU time scales
                  with density. This is the serving hot path.
  * ``sddmm``  -- dW.data[j] = dY[:,row_j]^T @ X[:,col_j]: gradient w.r.t.
                  stored tiles only (sparse training backward).
  * ``masked`` -- dense-layout matmul that skips MXU work on zero tiles via a
                  prefetched tile mask, but still pays the full weight DMA.
                  It is the "sparsity without format support" middle ground --
                  the measurable analogue of the paper's negative control
                  (stock TVM: sparse model, no BSR support, no win).

All kernels accumulate in fp32 VMEM scratch and are validated against
ref.py oracles in interpret mode (CPU) across shape/dtype sweeps.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.bsr import BSR, bsr_to_dense


# --------------------------------------------------------------------------
# Host-side packing
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class KernelBSR:
    """Tile-granular BSR pack for the Pallas kernels.

    The pattern arrays are *host numpy* and are treated as static: every
    distinct pattern is its own specialization, which is exactly the TVM
    task-buffer model (see core/pattern_reuse.py for the reuse cache).

    row_id has one sentinel entry appended (== n_brows) so the kernel can
    detect the last tile of each block row without branching on bounds.
    """

    data: jax.Array          # (nnzt, bn, bk) stored tile values
    row_id: np.ndarray       # (nnzt + 1,) int32, sorted, sentinel-terminated
    col_id: np.ndarray       # (nnzt,) int32
    t_perm: np.ndarray       # (nnzt,) permutation sorting tiles by (col, row)
    real_nnzt: int           # stored tiles that are not padding
    shape: Tuple[int, int]   # (N, K)
    tile: Tuple[int, int]    # (bn, bk)

    @property
    def nnzt(self) -> int:
        return int(self.col_id.shape[0])

    @property
    def n_brows(self) -> int:
        return self.shape[0] // self.tile[0]

    @property
    def n_bcols(self) -> int:
        return self.shape[1] // self.tile[1]

    @property
    def density(self) -> float:
        return self.real_nnzt / max(1, self.n_brows * self.n_bcols)

    def pad_mask(self) -> np.ndarray:
        m = np.zeros((self.nnzt,), bool)
        m[: self.real_nnzt] = True
        return m

    # transpose-pattern views (for dX = dY @ W)
    def t_row_id(self) -> np.ndarray:
        t = self.col_id[self.t_perm]
        return np.concatenate([t, [self.n_bcols]]).astype(np.int32)

    def t_col_id(self) -> np.ndarray:
        return self.row_id[:-1][self.t_perm].astype(np.int32)


def pack_bsr(w, tile: Tuple[int, int], nnzt: int | None = None) -> KernelBSR:
    """Pack a dense (or core.BSR) weight into tile-granular KernelBSR.

    Guarantees every block row stores >= 1 tile (zero-valued if the row is
    empty) so the kernel's write-on-row-change protocol covers all outputs.
    Runs on host; this is the offline "model packing" step, mirroring TVM's
    relay transformation of dense weights into BSR params.
    """
    if isinstance(w, BSR):
        w = np.asarray(jax.device_get(bsr_to_dense(w)))
    w = np.asarray(w)
    n, k = w.shape
    bn, bk = tile
    assert n % bn == 0 and k % bk == 0, (w.shape, tile)
    nbr, nbc = n // bn, k // bk

    blocks = w.reshape(nbr, bn, nbc, bk).transpose(0, 2, 1, 3)
    mask = np.any(blocks != 0, axis=(2, 3))
    # Every row AND column must store >= 1 tile (zero-valued if needed) so the
    # write-on-row-change protocol covers all outputs in both the forward and
    # the transposed (dds_t) orientation.
    for r in np.nonzero(~mask.any(axis=1))[0]:
        mask[r, 0] = True
    for c in np.nonzero(~mask.any(axis=0))[0]:
        mask[0, c] = True
    rows, cols = np.nonzero(mask)
    real = len(rows)
    if nnzt is None:
        nnzt = real
    if real > nnzt:
        raise ValueError(f"nnzt={nnzt} < required tiles {real}")

    data = np.zeros((nnzt, bn, bk), dtype=w.dtype)
    data[:real] = blocks[rows, cols]
    row_id = np.full((nnzt + 1,), nbr, dtype=np.int32)
    row_id[:real] = rows
    row_id[real:nnzt] = nbr - 1        # padding tiles live in the last row
    col_id = np.zeros((nnzt,), dtype=np.int32)
    col_id[:real] = cols

    t_perm = np.lexsort((row_id[:nnzt], col_id)).astype(np.int32)
    return KernelBSR(jnp.asarray(data), row_id, col_id, t_perm,
                     real, (n, k), tile)


# --------------------------------------------------------------------------
# DDS: Y = X @ W^T   (dense = dense x sparse)
# --------------------------------------------------------------------------

def _dds_kernel(row_ref, col_ref, x_ref, w_ref, o_ref, acc_ref):
    j = pl.program_id(1)
    first = (j == 0) | (row_ref[j] != row_ref[jnp.maximum(j - 1, 0)])

    @pl.when(first)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[0], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(row_ref[j + 1] != row_ref[j])
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("pack_static", "bm", "interpret"))
def _dds_call(x, data, row_id, col_id, *, pack_static, bm, interpret):
    n, k = pack_static[0]
    bn, bk = pack_static[1]
    nnzt = int(col_id.shape[0])
    m = x.shape[0]
    grid = (m // bm, nnzt)
    return pl.pallas_call(
        _dds_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, bk), lambda i, j, row, col: (i, col[j])),
                pl.BlockSpec((1, bn, bk), lambda i, j, row, col: (j, 0, 0)),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j, row, col: (i, row[j])),
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=interpret,
    )(row_id, col_id, x, data)


def dds(x: jax.Array, w: KernelBSR, *, bm: int = 128,
        interpret: bool = True) -> jax.Array:
    """Y(M, N) = X(M, K) @ W^T with tile skipping. Pads M to bm internally."""
    m, k = x.shape
    assert k == w.shape[1], (x.shape, w.shape)
    bm = min(bm, _ceil_mult(m, 8))
    pad = (-m) % bm
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    y = _dds_call(x, w.data, jnp.asarray(w.row_id), jnp.asarray(w.col_id),
                  pack_static=(w.shape, w.tile), bm=bm, interpret=interpret)
    return y[:m] if pad else y


def dds_t(dy: jax.Array, w: KernelBSR, *, bm: int = 128,
          interpret: bool = True) -> jax.Array:
    """dX(M, K) = dY(M, N) @ W, reusing the DDS kernel on the transposed
    pattern (tiles re-sorted by column on host at pack time)."""
    t_data = jnp.transpose(w.data[jnp.asarray(w.t_perm)], (0, 2, 1))
    m = dy.shape[0]
    bm = min(bm, _ceil_mult(m, 8))
    pad = (-m) % bm
    if pad:
        dy = jnp.pad(dy, ((0, pad), (0, 0)))
    x = _dds_call(dy, t_data, jnp.asarray(w.t_row_id()),
                  jnp.asarray(w.t_col_id()),
                  pack_static=((w.shape[1], w.shape[0]),
                               (w.tile[1], w.tile[0])),
                  bm=bm, interpret=interpret)
    return x[:m] if pad else x


# --------------------------------------------------------------------------
# SDDMM: dW.data[j] = dY[:, row_j]^T @ X[:, col_j]
# --------------------------------------------------------------------------

def _sddmm_kernel(row_ref, col_ref, dy_ref, x_ref, o_ref, acc_ref, *, num_m):
    mi = pl.program_id(1)

    @pl.when(mi == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        dy_ref[...], x_ref[...], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(mi == num_m - 1)
    def _():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("pack_static", "bm", "interpret"))
def _sddmm_call(dy, x, row_id, col_id, *, pack_static, bm, interpret):
    (n, k), (bn, bk), out_dtype = pack_static
    nnzt = int(col_id.shape[0])
    m = x.shape[0]
    num_m = m // bm
    grid = (nnzt, num_m)
    return pl.pallas_call(
        functools.partial(_sddmm_kernel, num_m=num_m),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, bn), lambda j, mi, row, col: (mi, row[j])),
                pl.BlockSpec((bm, bk), lambda j, mi, row, col: (mi, col[j])),
            ],
            out_specs=pl.BlockSpec((1, bn, bk), lambda j, mi, row, col: (j, 0, 0)),
            scratch_shapes=[pltpu.VMEM((bn, bk), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((nnzt, bn, bk), out_dtype),
        interpret=interpret,
    )(row_id, col_id, dy, x)


def sddmm(dy: jax.Array, x: jax.Array, w: KernelBSR, *, bm: int = 128,
          interpret: bool = True) -> jax.Array:
    """Gradient w.r.t. stored tiles. Returns (nnzt, bn, bk); padding tiles
    receive garbage and are zeroed here (they must stay dead)."""
    m = x.shape[0]
    bm = min(bm, _ceil_mult(m, 8))
    pad = (-m) % bm
    if pad:
        dy = jnp.pad(dy, ((0, pad), (0, 0)))
        x = jnp.pad(x, ((0, pad), (0, 0)))
    g = _sddmm_call(dy, x, jnp.asarray(w.row_id), jnp.asarray(w.col_id),
                    pack_static=(w.shape, w.tile, w.data.dtype),
                    bm=bm, interpret=interpret)
    return g * jnp.asarray(w.pad_mask())[:, None, None].astype(g.dtype)


# --------------------------------------------------------------------------
# Masked dense-layout matmul (negative-control arm)
# --------------------------------------------------------------------------

def _masked_kernel(mask_ref, x_ref, w_ref, o_ref, acc_ref, *, nk):
    ni, ki = pl.program_id(1), pl.program_id(2)

    @pl.when(ki == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(mask_ref[ni * nk + ki] != 0)
    def _():
        acc_ref[...] += jax.lax.dot_general(
            x_ref[...], w_ref[...], (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile", "bm", "interpret"))
def _masked_call(x, w, tile_mask, *, tile, bm, interpret):
    m, k = x.shape
    n = w.shape[0]
    bn, bk = tile
    nn, nk = n // bn, k // bk
    grid = (m // bm, nn, nk)
    return pl.pallas_call(
        functools.partial(_masked_kernel, nk=nk),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, bk), lambda i, ni, ki, mask: (i, ki)),
                pl.BlockSpec((bn, bk), lambda i, ni, ki, mask: (ni, ki)),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda i, ni, ki, mask: (i, ni)),
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=interpret,
    )(tile_mask.reshape(-1).astype(jnp.int32), x, w)


def masked_matmul(x: jax.Array, w_dense: jax.Array, tile_mask: jax.Array,
                  *, tile: Tuple[int, int] = (128, 128), bm: int = 128,
                  interpret: bool = True) -> jax.Array:
    """Y = X @ W^T skipping MXU work on zero tiles; W stays dense in HBM.

    Saves compute but NOT memory traffic -- quantifying why format support
    (BSR) is required for real wins, the paper's negative-control finding.
    """
    m = x.shape[0]
    bm = min(bm, _ceil_mult(m, 8))
    pad = (-m) % bm
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    y = _masked_call(x, w_dense, tile_mask, tile=tile, bm=bm,
                     interpret=interpret)
    return y[:m] if pad else y


def _ceil_mult(v: int, m: int) -> int:
    return max(m, ((v + m - 1) // m) * m)


# --------------------------------------------------------------------------
# Plan-consuming DDS: the RowPackPlan layout, streamed per row group
# --------------------------------------------------------------------------
#
# The kernels above read the flat KernelBSR (nnzt, bn, bk) stream. The
# serving layout, however, is the RowPackPlan's row-grouped (V, P, bn, bk)
# pack (exec_plan.py): home virtual rows 0..R-1 plus appended spill rows,
# each holding up to P tiles with per-slot column ids. ``plan_dds`` consumes
# that pack *directly* -- no re-layout, no segment-sum epilogue: the block
# loop follows the precomputed spill schedule (tiles stably sorted by output
# row on the host, see exec_plan.plan_kernel_sequence), so home and spill
# tiles of one output row are visited consecutively and accumulate in the
# same VMEM scratch; the row-change write doubles as the spill reduction.
# A bias add + activation can be fused into that final write (epilogue).

def _act_epilogue(y, act):
    if act == "relu":
        return jnp.maximum(y, 0.0)
    if act == "gelu":
        return jax.nn.gelu(y)
    if act == "silu":
        return y * jax.nn.sigmoid(y)
    assert act is None, act
    return y


def _plan_dds_kernel(row_ref, col_ref, vrow_ref, slot_ref, x_ref, w_ref,
                     b_ref, o_ref, acc_ref, *, act, bias):
    j = pl.program_id(1)
    first = (j == 0) | (row_ref[j] != row_ref[jnp.maximum(j - 1, 0)])

    @pl.when(first)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[0, 0], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(row_ref[j + 1] != row_ref[j])
    def _():
        y = acc_ref[...]
        if bias:
            y = y + b_ref[...].astype(jnp.float32)
        o_ref[...] = _act_epilogue(y, act).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n", "tile", "bm", "act",
                                             "bias", "interpret"))
def _plan_dds_call(x, data_rp, b, row_seq, col_seq, vrow_seq, slot_seq, *,
                   n, tile, bm, act, bias, interpret):
    bn, bk = tile
    nnzt = int(col_seq.shape[0])
    m = x.shape[0]
    grid = (m // bm, nnzt)
    return pl.pallas_call(
        functools.partial(_plan_dds_kernel, act=act, bias=bias),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, bk),
                             lambda i, j, row, col, vr, sl: (i, col[j])),
                # stream the (V, P, bn, bk) pack in place: the scalar-
                # prefetched schedule picks (virtual row, slot) per step
                pl.BlockSpec((1, 1, bn, bk),
                             lambda i, j, row, col, vr, sl:
                             (vr[j], sl[j], 0, 0)),
                pl.BlockSpec((1, bn),
                             lambda i, j, row, col, vr, sl: (0, row[j])),
            ],
            out_specs=pl.BlockSpec(
                (bm, bn), lambda i, j, row, col, vr, sl: (i, row[j])),
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=interpret,
    )(row_seq, col_seq, vrow_seq, slot_seq, x, data_rp, b)


def plan_dds(x: jax.Array, data_rp: jax.Array, schedule, *, n: int,
             tile: Tuple[int, int], bias: jax.Array | None = None,
             act: str | None = None, bm: int = 128,
             interpret: bool = True) -> jax.Array:
    """Y(M, N) = X(M, K) @ W^T from the row-grouped (V, P, bn, bk) pack.

    ``schedule`` is the (row_seq, col_seq, vrow_seq, slot_seq) tuple from
    exec_plan.plan_kernel_sequence: real tiles stably sorted by output block
    row, row_seq sentinel-terminated. ``bias`` (N,) and ``act`` fuse into
    the row-change write.
    """
    m = x.shape[0]
    bn, bk = tile
    row_seq, col_seq, vrow_seq, slot_seq = schedule
    bm = min(bm, _ceil_mult(m, 8))
    pad = (-m) % bm
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    has_bias = bias is not None
    b = (bias.reshape(1, n) if has_bias
         else jnp.zeros((1, n), x.dtype))
    y = _plan_dds_call(x, data_rp, b, jnp.asarray(row_seq),
                       jnp.asarray(col_seq), jnp.asarray(vrow_seq),
                       jnp.asarray(slot_seq), n=n, tile=tile, bm=bm,
                       act=act, bias=has_bias, interpret=interpret)
    return y[:m] if pad else y


def _plan_dds_q_kernel(row_ref, col_ref, vrow_ref, slot_ref, x_ref, w_ref,
                       s_ref, b_ref, o_ref, acc_ref, *, act, bias):
    # same schedule/accumulator protocol as _plan_dds_kernel; the block
    # values arrive int8/fp8 and the per-block (or per-row-group) scale
    # rides the scalar-prefetched schedule -- dequant is one scalar
    # multiply on the tile's contribution, inside the accumulation, so
    # fp32 weight values never exist outside VMEM.
    j = pl.program_id(1)
    first = (j == 0) | (row_ref[j] != row_ref[jnp.maximum(j - 1, 0)])

    @pl.when(first)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += s_ref[0, 0] * jax.lax.dot_general(
        x_ref[...].astype(jnp.float32), w_ref[0, 0].astype(jnp.float32),
        (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(row_ref[j + 1] != row_ref[j])
    def _():
        y = acc_ref[...]
        if bias:
            y = y + b_ref[...].astype(jnp.float32)
        o_ref[...] = _act_epilogue(y, act).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n", "tile", "bm",
                                             "granularity", "act", "bias",
                                             "interpret"))
def _plan_dds_q_call(x, qvalues, scales, b, row_seq, col_seq, vrow_seq,
                     slot_seq, *, n, tile, bm, granularity, act, bias,
                     interpret):
    bn, bk = tile
    nnzt = int(col_seq.shape[0])
    m = x.shape[0]
    grid = (m // bm, nnzt)
    # 'block' scales are (V, P): one per schedule step at (vr[j], sl[j]).
    # 'row' scales are (V, 1): every slot of a vrow shares column 0 -- the
    # granularity is a static choice, so the index map is too.
    if granularity == "block":
        s_spec = pl.BlockSpec((1, 1),
                              lambda i, j, row, col, vr, sl: (vr[j], sl[j]))
    else:
        s_spec = pl.BlockSpec((1, 1),
                              lambda i, j, row, col, vr, sl: (vr[j], 0))
    return pl.pallas_call(
        functools.partial(_plan_dds_q_kernel, act=act, bias=bias),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, bk),
                             lambda i, j, row, col, vr, sl: (i, col[j])),
                pl.BlockSpec((1, 1, bn, bk),
                             lambda i, j, row, col, vr, sl:
                             (vr[j], sl[j], 0, 0)),
                s_spec,
                pl.BlockSpec((1, bn),
                             lambda i, j, row, col, vr, sl: (0, row[j])),
            ],
            out_specs=pl.BlockSpec(
                (bm, bn), lambda i, j, row, col, vr, sl: (i, row[j])),
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=interpret,
    )(row_seq, col_seq, vrow_seq, slot_seq, x, qvalues, scales, b)


def plan_dds_q(x: jax.Array, qvalues: jax.Array, scales: jax.Array,
               schedule, *, n: int, tile: Tuple[int, int],
               granularity: str = "block", bias: jax.Array | None = None,
               act: str | None = None, bm: int = 128,
               interpret: bool = True) -> jax.Array:
    """Y(M, N) = X(M, K) @ dequant(Q)^T, dequant fused into the block loop.

    Same contract as :func:`plan_dds` with the (V, P, bn, bk) values stored
    int8/fp8 and ``scales`` (V, P) fp32 ('block' granularity) or (V, 1)
    ('row'). Each tile's partial product is scaled before it joins the VMEM
    accumulator; bias/act fuse into the row-change write as before.
    """
    m = x.shape[0]
    bn, bk = tile
    row_seq, col_seq, vrow_seq, slot_seq = schedule
    bm = min(bm, _ceil_mult(m, 8))
    pad = (-m) % bm
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    has_bias = bias is not None
    b = (bias.reshape(1, n) if has_bias
         else jnp.zeros((1, n), x.dtype))
    y = _plan_dds_q_call(x, qvalues, scales, b, jnp.asarray(row_seq),
                         jnp.asarray(col_seq), jnp.asarray(vrow_seq),
                         jnp.asarray(slot_seq), n=n, tile=tile, bm=bm,
                         granularity=granularity, act=act, bias=has_bias,
                         interpret=interpret)
    return y[:m] if pad else y


def plan_dds_t(dy: jax.Array, data_rp: jax.Array, t_schedule, *, k: int,
               tile: Tuple[int, int], bm: int = 128,
               interpret: bool = True) -> jax.Array:
    """dX(M, K) = dY(M, N) @ W on the transposed schedule (tiles sorted by
    block column); tile values are gathered+transposed per call, like dds_t.
    """
    bn, bk = tile
    t_row_seq, t_col_seq, t_flat = t_schedule
    flat = data_rp.reshape(-1, bn, bk)
    t_data = jnp.transpose(flat[jnp.asarray(t_flat)], (0, 2, 1))
    m, n = dy.shape
    bm = min(bm, _ceil_mult(m, 8))
    pad = (-m) % bm
    if pad:
        dy = jnp.pad(dy, ((0, pad), (0, 0)))
    x = _dds_call(dy, t_data, jnp.asarray(t_row_seq), jnp.asarray(t_col_seq),
                  pack_static=((k, n), (bk, bn)), bm=bm, interpret=interpret)
    return x[:m] if pad else x


def plan_sddmm(dy: jax.Array, x: jax.Array, schedule, *,
               tile: Tuple[int, int], out_dtype, bm: int = 128,
               interpret: bool = True) -> jax.Array:
    """Per-tile gradient dW[j] = dY[:, row_j]^T @ X[:, col_j] over the
    schedule order. Returns (nnzt, bn, bk); all schedule tiles are real
    (the plan keeps no padding tiles in its vrow/slot lists)."""
    row_seq, col_seq, _, _ = schedule
    m = x.shape[0]
    bm = min(bm, _ceil_mult(m, 8))
    pad = (-m) % bm
    if pad:
        dy = jnp.pad(dy, ((0, pad), (0, 0)))
        x = jnp.pad(x, ((0, pad), (0, 0)))
    n = dy.shape[1]
    k = x.shape[1]
    return _sddmm_call(dy, x, jnp.asarray(row_seq), jnp.asarray(col_seq),
                       pack_static=((n, k), tile, out_dtype),
                       bm=bm, interpret=interpret)
