"""Pure-jnp oracles for the block-sparse kernels.

Two reference paths:
  * ``*_ref``     -- densify-then-matmul. The correctness oracle every kernel
                     is allclose-tested against.
  * ``*_gather``  -- an XLA-native sparse-compute path (gather + segment_sum)
                     that actually skips zero blocks. FLOPs scale with density,
                     so on CPU it realizes the paper's TVM+ speedups and is
                     what benchmarks/table1 measures; on TPU the Pallas kernel
                     (bsr_matmul.py) replaces it.

Convention: ``Y(M, N) = X(M, K) @ W^T`` with ``W`` an (N, K) BSR matrix --
the natural layout for a linear layer ``y = x @ W.T`` with output-feature
block rows.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.bsr import BSR, bsr_to_dense


def bsr_matmul_ref(x: jax.Array, w: BSR) -> jax.Array:
    """Oracle: densify W and matmul. x: (M, K) -> (M, N)."""
    dense = bsr_to_dense(w)  # (N, K)
    return jnp.dot(x, dense.T, preferred_element_type=jnp.float32).astype(x.dtype)


def bsr_matmul_gather(x: jax.Array, w: BSR) -> jax.Array:
    """Sparse-compute path: FLOPs = density * dense FLOPs.

    Gathers the K-blocks of ``x`` addressed by ``indices``, multiplies each by
    its stored block, and segment-sums into block rows. Equivalent to the
    TVM+ BSR operator of the paper: only nonzero blocks are touched.
    """
    m, k = x.shape
    n, _ = w.shape
    bn, bk = w.block_shape
    rows = w.block_row_ids()                    # (nnzb,)
    xb = x.reshape(m, k // bk, bk)
    g = jnp.take(xb, w.indices, axis=1)         # (M, nnzb, bk)
    # (M, nnzb, bk) x (nnzb, bn, bk) -> (nnzb, M, bn)
    prod = jnp.einsum("mjk,jnk->jmn", g, w.data,
                      preferred_element_type=jnp.float32)
    y = jax.ops.segment_sum(prod, rows, num_segments=n // bn)  # (R, M, bn)
    return y.transpose(1, 0, 2).reshape(m, n).astype(x.dtype)


def bsr_matmul_t_ref(dy: jax.Array, w: BSR) -> jax.Array:
    """Oracle for the transpose product: dX(M, K) = dY(M, N) @ W."""
    dense = bsr_to_dense(w)
    return jnp.dot(dy, dense, preferred_element_type=jnp.float32).astype(dy.dtype)


def bsr_matmul_t_gather(dy: jax.Array, w: BSR) -> jax.Array:
    """Sparse transpose product via gather/segment-sum (scatter into K blocks)."""
    m, n = dy.shape
    _, k = w.shape
    bn, bk = w.block_shape
    rows = w.block_row_ids()
    dyb = dy.reshape(m, n // bn, bn)
    g = jnp.take(dyb, rows, axis=1)             # (M, nnzb, bn)
    prod = jnp.einsum("mjn,jnk->jmk", g, w.data,
                      preferred_element_type=jnp.float32)  # (nnzb, M, bk)
    x = jax.ops.segment_sum(prod, w.indices, num_segments=k // bk)
    return x.transpose(1, 0, 2).reshape(m, k).astype(dy.dtype)


def sddmm_ref(dy: jax.Array, x: jax.Array, w: BSR) -> jax.Array:
    """Sampled dense-dense matmul: dW.data[j] = dY[:, row_j]^T @ X[:, col_j].

    Gradient of ``bsr_matmul`` w.r.t. the stored blocks; only pattern
    positions are materialized (the whole point of sparse training).
    Returns (nnzb, bn, bk).
    """
    m, n = dy.shape
    _, k = x.shape
    bn, bk = w.block_shape
    rows = w.block_row_ids()
    dyb = dy.reshape(m, n // bn, bn)
    xb = x.reshape(m, k // bk, bk)
    gy = jnp.take(dyb, rows, axis=1)       # (M, nnzb, bn)
    gx = jnp.take(xb, w.indices, axis=1)   # (M, nnzb, bk)
    return jnp.einsum("mjn,mjk->jnk", gy, gx,
                      preferred_element_type=jnp.float32).astype(w.data.dtype)
