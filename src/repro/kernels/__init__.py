"""Pallas TPU kernels for the paper's BSR operators + oracles + wrappers."""
from repro.kernels.bsr_matmul import (KernelBSR, dds, dds_t, masked_matmul,
                                      pack_bsr, sddmm)
from repro.kernels.ops import (bsr_linear, bsr_matmul, default_backend,
                               sparsify_weight)
