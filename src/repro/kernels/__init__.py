"""Pallas TPU kernels for the paper's BSR operators + oracles + wrappers."""
from repro.kernels.autotune import (AutotuneCache, BackendChoice, MaskedPack,
                                    choose_backend, default_cache_path)
from repro.kernels.bsr_matmul import (KernelBSR, dds, dds_t, masked_matmul,
                                      pack_bsr, sddmm)
from repro.kernels.exec_plan import (RowPackPlan, ShardedPlan, build_plan,
                                     build_sharded_plan,
                                     default_plan_registry,
                                     kernel_pattern_fingerprint,
                                     pack_plan_data, plan_for_pack,
                                     plan_linear, plan_matmul,
                                     shard_divisible, unpack_plan_data)
from repro.kernels.ops import (bsr_linear, bsr_matmul, default_backend,
                               sparsify_weight)
