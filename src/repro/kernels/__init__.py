"""Pallas TPU kernels for the paper's BSR operators + oracles + wrappers."""
from repro.kernels.autotune import (DECODE_CANDIDATES, AutotuneCache,
                                    BackendChoice, MaskedPack, choose_backend,
                                    choose_decode_kernel, default_cache_path)
from repro.kernels.bsr_matmul import (KernelBSR, dds, dds_t, masked_matmul,
                                      pack_bsr, plan_dds, sddmm)
from repro.kernels.exec_plan import (PlanChoice, RowPackPlan, ShardedPlan,
                                     build_plan, build_sharded_plan,
                                     default_plan_registry,
                                     kernel_pattern_fingerprint,
                                     pack_plan_data, plan_for_pack,
                                     plan_kernel_sequence, plan_linear,
                                     plan_linear_pallas, plan_matmul,
                                     plan_matmul_pallas, shard_divisible,
                                     unpack_plan_data)
from repro.kernels.flash_decode import (decode_kernel_override, default_kv_split,
                                        flash_decode, paged_flash_decode,
                                        resolved_decode_kernel)
from repro.kernels.ops import (bsr_linear, bsr_matmul, default_backend,
                               plan_dispatch, sparsify_weight)
