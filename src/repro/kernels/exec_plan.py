"""Precomputed execution plans for block-sparse serving (docs/PERF.md).

SparseRT's lesson (and the paper's TVM task-buffer mechanism, §2.2) is that a
sparse op should pay for its pattern exactly once, ahead of time. The seed
``rowpack`` backend violated this twice on the serving hot path:

  * the row-grouped layout (``col_idx``/``slot``) was rebuilt with a Python
    loop at **every trace** of the op, and
  * the stored tile values were re-scattered from the packed ``(nnzt, bn, bk)``
    layout into the row-grouped layout with a ``zeros().at[].set()`` inside
    **every jitted call** -- pure memory traffic on a path the Sparsity
    Roofline says is traffic-bound already.

A :class:`RowPackPlan` moves all pattern-dependent work offline. It is frozen
host metadata (numpy, hashable by pattern fingerprint) computed once at pack
time; weight values are stored *already row-grouped*, so the per-call path is
one gather of ``x``, one batched matmul, and (only when the plan spilled
rows) one segment-sum. Plans are cached through
``core.pattern_reuse.PatternRegistry`` -- identical patterns (e.g. the 12
cross-layer-unioned BERT encoder layers) share one plan and, because the plan
hashes by fingerprint, one compiled executable.

Offline scheduling
------------------
``rowpack`` pads every block row to P = max tiles/row, so a skewed pattern
(binomial row occupancy at serving densities) wastes 1.5-2.5x the real FLOPs
on padding. Because the plan is built ahead of time it instead *chooses* a
row capacity P that minimizes total padded slots (subject to a GEMM-
efficiency floor on the inner dimension P*bk) and spills the overflow tiles
of heavy rows into extra **virtual rows**; a segment-sum folds virtual rows
back into their real output rows. For uniform patterns no row spills and the
schedule degenerates to the seed layout with the scatter removed.

Layout, for a tile-BSR weight ``W (N, K)`` with ``R = N/bn`` block rows,
``V >= R`` virtual rows and ``P`` slots per virtual row:

  * ``col_idx (V, P)``    -- block-column of the tile in each slot
                             (0 for padding slots: they multiply zero data);
  * ``slot_mask (V, P)``  -- True where a real tile lives (grads of padding
                             slots are forced to zero: pruned blocks stay
                             dead);
  * ``row_of_vrow (V,)``  -- owning block row of each virtual row;
  * data ``(V, P, bn, bk)`` -- tile values, already grouped by virtual row.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pattern_reuse import PatternRegistry
from repro.kernels.bsr_matmul import KernelBSR

# GEMM-efficiency floor for the batched matmul's inner dimension P*bk:
# below this, small-P schedules degenerate into gather-style batch-1 work.
_MIN_INNER = 128


def kernel_pattern_fingerprint(pack: KernelBSR) -> bytes:
    """Hashable fingerprint of a KernelBSR *structure* (not values) -- the
    task-identity key for plan reuse, mirroring core.bsr.pattern_fingerprint."""
    header = np.array([*pack.shape, *pack.tile, pack.nnzt, pack.real_nnzt],
                      dtype=np.int64)
    return (header.tobytes()
            + np.asarray(pack.row_id, np.int32).tobytes()
            + np.asarray(pack.col_id, np.int32).tobytes())


@dataclasses.dataclass(frozen=True, eq=False)
class RowPackPlan:
    """Frozen row-grouped execution plan for one sparsity pattern.

    All fields are host numpy / python scalars: the plan is static metadata
    baked into specializations. Hash/eq go through ``fingerprint`` so plans
    can key jit caches -- two layers with identical patterns share one
    executable even if their plan objects differ.
    """

    col_idx: np.ndarray       # (V, P) int32 block-col per slot
    slot_mask: np.ndarray     # (V, P) bool, True where a real tile lives
    row_of_vrow: np.ndarray   # (V,) int32 owning block row of each vrow
    vrow: np.ndarray          # (real_nnzt,) int32 virtual row of each tile
    slot: np.ndarray          # (real_nnzt,) int32 slot of each tile
    shape: Tuple[int, int]    # (N, K)
    tile: Tuple[int, int]     # (bn, bk)
    nnzt: int                 # packed tile count incl. padding tiles
    real_nnzt: int            # stored tiles that are not padding
    fingerprint: bytes        # kernel_pattern_fingerprint of the source pack

    @property
    def n_brows(self) -> int:
        return self.shape[0] // self.tile[0]

    @property
    def n_bcols(self) -> int:
        return self.shape[1] // self.tile[1]

    @property
    def n_vrows(self) -> int:
        return int(self.col_idx.shape[0])

    @property
    def p_max(self) -> int:
        return int(self.col_idx.shape[1])

    @property
    def spilled(self) -> bool:
        """True when heavy rows overflowed into virtual rows (the per-call
        path then folds them back with one segment-sum)."""
        return self.n_vrows != self.n_brows

    @property
    def density(self) -> float:
        return self.real_nnzt / max(1, self.n_brows * self.n_bcols)

    @property
    def padding_waste(self) -> float:
        """Total slots / real tiles (1.0 = zero padding) -- the FLOP
        overhead factor of the schedule (rowpack's fixed max-P layout sits
        at R*max(c)/nnzt)."""
        return self.n_vrows * self.p_max / max(1, self.real_nnzt)

    def __hash__(self):
        return hash(self.fingerprint)

    def __eq__(self, other):
        return (isinstance(other, RowPackPlan)
                and self.fingerprint == other.fingerprint)


@dataclasses.dataclass(frozen=True, eq=False)
class ShardedPlan(RowPackPlan):
    """A RowPackPlan whose virtual-row axis is partitioned into ``n_shards``
    contiguous, equal-size groups -- one per device of a tensor-parallel
    "model" mesh axis (launch/sharding.py conventions).

    ``shard_axis`` selects the TP layout:

      * ``'out'`` (column-parallel: wq/wk/wv/wqkv/wi/wg): shard ``s`` owns
        output block rows ``[s*R/S, (s+1)*R/S)``; its vrows reference only
        those rows, so the row-grouped values ``(V, P, bn, bk)`` sharded
        over vrows place each shard's tiles on exactly one device and the
        output feature dim comes out model-sharded;
      * ``'in'`` (row-parallel: wo): shard ``s`` owns input block columns
        ``[s*C/S, (s+1)*C/S)``; every shard's vrows map to *global* output
        rows, so the plan's segment-sum doubles as the per-layer psum that
        folds the partial products back together.

    Either way the per-call math is exactly :func:`plan_linear` -- the
    shard structure lives entirely in how vrows/values are laid out, which
    is why a sharded plan is *also* a valid single-device plan (exact
    fallback when no mesh is active). ``spilled`` is forced True: the
    segment-sum is what reassembles (or psums) the per-shard partials.

    ``shard_fingerprints`` identify each shard's sub-pattern -- the
    per-shard registry / autotune cache keys (a winner measured for one
    shard's pattern never answers for a different shard or device count).
    ``mesh`` is attached by ``prepare_servable`` (never serialized, never
    part of the fingerprint): when set, ``models/common.linear`` pins the
    output sharding (column-parallel) or the psum point (row-parallel).
    """

    n_shards: int = 1
    shard_axis: str = "out"            # 'out' = column-parallel, 'in' = row
    shard_fingerprints: Tuple[bytes, ...] = ()
    mesh: Optional[object] = None      # jax.sharding.Mesh, attached late

    @property
    def spilled(self) -> bool:
        # per-shard partials always fold through the segment-sum (for
        # 'in'-sharding it IS the psum), even if vrow/row counts collide
        return True

    @property
    def vrows_per_shard(self) -> int:
        return self.n_vrows // max(1, self.n_shards)

    def with_mesh(self, mesh) -> "ShardedPlan":
        return dataclasses.replace(self, mesh=mesh)

    def __hash__(self):
        return hash(self.fingerprint)

    def __eq__(self, other):
        return (isinstance(other, ShardedPlan)
                and self.fingerprint == other.fingerprint)


# a spill schedule reassociates row sums and adds segment-sum + batch-count
# overhead, so it must buy a decisive FLOP reduction to be worth it; below
# this saving the no-spill layout (strictly cheaper than rowpack: same
# matmul, no per-call scatter) is kept.
_SPILL_MIN_SAVING = 0.25


def _choose_capacity(counts: np.ndarray, bk: int) -> int:
    """Pick the per-vrow slot capacity P minimizing total padded slots
    ``(R + spill_rows(P)) * P``, subject to the inner-dimension floor
    P*bk >= _MIN_INNER (ties -> larger P: fewer vrows, fewer segment adds)
    and to the spill schedule saving at least ``_SPILL_MIN_SAVING`` of the
    no-spill slots.

    Fully offline -- this is the schedule choice SparseRT makes at codegen
    time and rowpack (fixed P = max(counts)) cannot make at all.
    """
    cmax = max(1, int(counts.max()))
    p_lo = min(cmax, max(1, -(-_MIN_INNER // bk)))
    cand = np.arange(p_lo, cmax + 1, dtype=np.int64)
    extra = np.ceil(np.maximum(counts[None, :] - cand[:, None], 0)
                    / cand[:, None]).sum(axis=1)
    slots = (len(counts) + extra) * cand
    best = slots.min()
    if best > (1.0 - _SPILL_MIN_SAVING) * len(counts) * cmax:
        return cmax
    return int(cand[np.nonzero(slots <= best * 1.02)[0][-1]])


def build_plan(pack: KernelBSR) -> RowPackPlan:
    """Derive the spill-scheduled row-grouped layout on host, once.

    Padding tiles (``real_nnzt <= j < nnzt``) are dropped: their data is zero
    by the pack_bsr contract, so they only wasted a row slot in the seed
    layout. Replaces the per-trace Python loop of the old ``_rowpack_static``
    with vectorized numpy.
    """
    rows = np.asarray(pack.row_id[: pack.real_nnzt], dtype=np.int64)
    cols = np.asarray(pack.col_id[: pack.real_nnzt], dtype=np.int64)
    r = pack.n_brows
    counts = np.bincount(rows, minlength=r)
    p = _choose_capacity(counts, pack.tile[1])
    # rank of each tile within its row (stable, preserves column order)
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    order = np.argsort(rows, kind="stable")
    rank = np.empty(rows.shape[0], np.int64)
    rank[order] = np.arange(rows.shape[0]) - starts[rows[order]]
    # spill layout: row r owns vrow r plus ceil((c_r - P)+ / P) extra vrows
    n_spill = np.ceil(np.maximum(counts - p, 0) / p).astype(np.int64)
    spill_base = r + np.concatenate([[0], np.cumsum(n_spill)[:-1]])
    v = int(r + n_spill.sum())
    chunk = rank // p                      # 0 = home vrow, >=1 = spill chunk
    vrow = np.where(chunk == 0, rows, spill_base[rows] + chunk - 1)
    slot = rank % p
    col_idx = np.zeros((v, p), np.int32)
    col_idx[vrow, slot] = cols
    slot_mask = np.zeros((v, p), bool)
    slot_mask[vrow, slot] = True
    row_of_vrow = np.empty((v,), np.int64)
    row_of_vrow[:r] = np.arange(r)
    for rr in np.nonzero(n_spill)[0]:
        row_of_vrow[spill_base[rr]: spill_base[rr] + n_spill[rr]] = rr
    return RowPackPlan(col_idx=col_idx, slot_mask=slot_mask,
                       row_of_vrow=row_of_vrow.astype(np.int32),
                       vrow=vrow.astype(np.int32), slot=slot.astype(np.int32),
                       shape=pack.shape, tile=pack.tile, nnzt=pack.nnzt,
                       real_nnzt=pack.real_nnzt,
                       fingerprint=kernel_pattern_fingerprint(pack))


# --------------------------------------------------------------------------
# sharded plans (tensor-parallel serving: launch/sharding.py conventions)
# --------------------------------------------------------------------------

def shard_divisible(pack: KernelBSR, n_shards: int, shard_axis: str) -> bool:
    """True when this pack can be partitioned into ``n_shards`` equal groups
    along ``shard_axis`` ('out' = output block rows, 'in' = input block
    cols) -- the same divisibility rule launch/sharding.spec_for_param
    applies to dense weights (indivisible dims replicate)."""
    dim = pack.n_brows if shard_axis == "out" else pack.n_bcols
    return n_shards >= 1 and dim % n_shards == 0 and dim >= n_shards

def _shard_layout(rows: np.ndarray, cols: np.ndarray, p: int):
    """Compressed row-grouped layout for ONE shard's tiles (pack order).

    ``rows`` are the *global* output block rows of this shard's tiles.
    Unlike :func:`build_plan` (one vrow per block row, empty rows padded),
    only rows actually present get vrows: at serving densities a shard owns
    a small fraction of each row's tiles, and empty-row slots would multiply
    the padding waste by ``n_shards``. Returns
    ``(col_idx (v, p), slot_mask, row_of_vrow (v,) global, vrow, slot)``.
    """
    if rows.size == 0:
        return (np.zeros((0, p), np.int32), np.zeros((0, p), bool),
                np.zeros((0,), np.int64), np.zeros((0,), np.int64),
                np.zeros((0,), np.int64))
    uniq, inv = np.unique(rows, return_inverse=True)
    counts = np.bincount(inv, minlength=len(uniq))
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    order = np.argsort(inv, kind="stable")
    rank = np.empty(rows.shape[0], np.int64)
    rank[order] = np.arange(rows.shape[0]) - starts[inv[order]]
    n_spill = np.ceil(np.maximum(counts - p, 0) / p).astype(np.int64)
    spill_base = len(uniq) + np.concatenate([[0], np.cumsum(n_spill)[:-1]])
    v = int(len(uniq) + n_spill.sum())
    chunk = rank // p
    vrow = np.where(chunk == 0, inv, spill_base[inv] + chunk - 1)
    slot = rank % p
    col_idx = np.zeros((v, p), np.int32)
    col_idx[vrow, slot] = cols
    slot_mask = np.zeros((v, p), bool)
    slot_mask[vrow, slot] = True
    row_of_vrow = np.empty((v,), np.int64)
    row_of_vrow[: len(uniq)] = uniq
    for rr in np.nonzero(n_spill)[0]:
        row_of_vrow[spill_base[rr]: spill_base[rr] + n_spill[rr]] = uniq[rr]
    return col_idx, slot_mask, row_of_vrow, vrow, slot


def shard_pattern_fingerprint(pack: KernelBSR, n_shards: int,
                              shard_axis: str, shard: int) -> bytes:
    """Fingerprint of ONE shard's sub-pattern -- the per-shard registry and
    autotune key (kernels/autotune.py keys winners on (digest, shard, m,
    device); two shards with identical sub-patterns share one key)."""
    rows = np.asarray(pack.row_id[: pack.real_nnzt], np.int64)
    cols = np.asarray(pack.col_id[: pack.real_nnzt], np.int64)
    if shard_axis == "out":
        per = pack.n_brows // n_shards
        sel = (rows // per) == shard
        lrows, lcols = rows[sel] % per, cols[sel]
        shape = (pack.shape[0] // n_shards, pack.shape[1])
    else:
        per = pack.n_bcols // n_shards
        sel = (cols // per) == shard
        lrows, lcols = rows[sel], cols[sel] % per
        shape = (pack.shape[0], pack.shape[1] // n_shards)
    header = np.array([*shape, *pack.tile, int(shard_axis == "in")], np.int64)
    return (b"shard:" + header.tobytes()
            + lrows.astype(np.int32).tobytes()
            + lcols.astype(np.int32).tobytes())


def build_sharded_plan(pack: KernelBSR, n_shards: int,
                       shard_axis: str = "out", *,
                       registry: Optional[PatternRegistry] = None,
                       shard_stats: Optional[dict] = None) -> ShardedPlan:
    """Partition ``pack`` into ``n_shards`` equal vrow groups (see
    :class:`ShardedPlan`). All shards share one slot capacity P and are
    padded to the max per-shard vrow count, so the combined vrow axis is
    exactly ``n_shards``-divisible -- the property that lets the values
    array shard over the mesh "model" axis with zero cross-device tiles.

    ``registry`` (optional) caches each shard's layout under its sub-pattern
    fingerprint -- identical layers (cross-layer union, scan-stacked groups)
    then reuse per-shard layouts, and ``shard_stats`` (dict, optional) is
    filled with per-shard hit/miss counts for ``Servable.stats()``.
    """
    if not shard_divisible(pack, n_shards, shard_axis):
        raise ValueError(
            f"pattern {pack.shape} @ tile {pack.tile} not divisible into "
            f"{n_shards} shards along {shard_axis!r}")
    rows = np.asarray(pack.row_id[: pack.real_nnzt], np.int64)
    cols = np.asarray(pack.col_id[: pack.real_nnzt], np.int64)
    bn, bk = pack.tile
    if shard_axis == "out":
        per = pack.n_brows // n_shards
        shard_of = rows // per
    else:
        per = pack.n_bcols // n_shards
        shard_of = cols // per
    # one capacity for every shard (uniform P = uniform padded layout)
    p = 1
    for s in range(n_shards):
        srows = rows[shard_of == s]
        if srows.size:
            counts = np.bincount(np.unique(srows, return_inverse=True)[1])
            p = max(p, _choose_capacity(counts, bk))

    layouts, fps = [], []
    for s in range(n_shards):
        idx = np.nonzero(shard_of == s)[0]
        fp = shard_pattern_fingerprint(pack, n_shards, shard_axis, s)
        fps.append(fp)
        # layouts are built (and registry-cached) in SHARD-LOCAL
        # coordinates -- the fingerprint describes the local sub-pattern,
        # so two shards with identical local structure must share a
        # position-independent layout; global offsets are re-applied at
        # assembly below
        lrows = rows[idx] - s * per if shard_axis == "out" else rows[idx]
        lcols = cols[idx] - s * per if shard_axis == "in" else cols[idx]

        def build(lrows=lrows, lcols=lcols):
            return _shard_layout(lrows, lcols, p)
        if registry is not None:
            key = ("plan_shard", shard_axis, p, fp)
            if shard_stats is not None:
                st = shard_stats.setdefault(s, {"hits": 0, "misses": 0})
                st["hits" if registry.peek(key) else "misses"] += 1
            layouts.append((idx, registry.cached(key, build)))
        else:
            layouts.append((idx, build()))

    v_max = max(1, max(lay[1][0].shape[0] for lay in layouts))
    col_idx = np.zeros((n_shards * v_max, p), np.int32)
    slot_mask = np.zeros((n_shards * v_max, p), bool)
    row_of_vrow = np.zeros((n_shards * v_max,), np.int64)
    vrow = np.zeros((pack.real_nnzt,), np.int64)
    slot = np.zeros((pack.real_nnzt,), np.int64)
    for s, (idx, (ci, sm, rov, vr, sl)) in enumerate(layouts):
        v = ci.shape[0]
        lo = s * v_max
        # globalize: 'out' shards own rows [s*per, (s+1)*per); 'in' shards
        # gather x block-cols [s*per, (s+1)*per). Padding vrows (>= v) keep
        # the shard's base row/col: they multiply zero data either way.
        if shard_axis == "out":
            col_idx[lo: lo + v] = ci
            row_of_vrow[lo: lo + v_max] = s * per
            row_of_vrow[lo: lo + v] = rov + s * per
        else:
            col_idx[lo: lo + v_max] = s * per
            col_idx[lo: lo + v] = ci + s * per
            row_of_vrow[lo: lo + v] = rov
        slot_mask[lo: lo + v] = sm
        vrow[idx] = lo + vr
        slot[idx] = sl
    header = np.array([n_shards, int(shard_axis == "in")], np.int64)
    fingerprint = (b"sharded:" + header.tobytes()
                   + kernel_pattern_fingerprint(pack))
    return ShardedPlan(
        col_idx=col_idx, slot_mask=slot_mask,
        row_of_vrow=row_of_vrow.astype(np.int32),
        vrow=vrow.astype(np.int32), slot=slot.astype(np.int32),
        shape=pack.shape, tile=pack.tile, nnzt=pack.nnzt,
        real_nnzt=pack.real_nnzt, fingerprint=fingerprint,
        n_shards=n_shards, shard_axis=shard_axis,
        shard_fingerprints=tuple(fps))


# --------------------------------------------------------------------------
# plan-keyed registry (the task buffer for execution plans)
# --------------------------------------------------------------------------

_PLAN_REGISTRY = PatternRegistry()


def default_plan_registry() -> PatternRegistry:
    """Process-wide plan task buffer (hit/miss stats included)."""
    return _PLAN_REGISTRY


def plan_for_pack(pack: KernelBSR,
                  registry: Optional[PatternRegistry] = None) -> RowPackPlan:
    """Cached plan lookup: identical patterns share one RowPackPlan (and via
    its fingerprint-hash, one compiled executable downstream)."""
    reg = registry if registry is not None else _PLAN_REGISTRY
    fp = kernel_pattern_fingerprint(pack)
    return reg.cached(("rowpack_plan", fp), lambda: build_plan(pack))


# --------------------------------------------------------------------------
# offline data re-layout (pack time, not call time)
# --------------------------------------------------------------------------

def pack_plan_data(plan: RowPackPlan, data) -> jax.Array:
    """(..., nnzt, bn, bk) packed tile values -> (..., V, P, bn, bk)
    row-grouped values. This is the scatter the seed backend paid on every
    forward call; here it runs once at export/pack time."""
    data = jnp.asarray(data)
    lead = data.shape[:-3]
    bn, bk = plan.tile
    d = data.reshape((-1,) + data.shape[-3:])[:, : plan.real_nnzt]
    out = jnp.zeros((d.shape[0], plan.n_vrows, plan.p_max, bn, bk), d.dtype)
    out = out.at[:, jnp.asarray(plan.vrow), jnp.asarray(plan.slot)].set(d)
    return out.reshape(lead + (plan.n_vrows, plan.p_max, bn, bk))


def unpack_plan_data(plan: RowPackPlan, data_rp) -> jax.Array:
    """Inverse re-layout: (..., V, P, bn, bk) -> (..., real_nnzt, bn, bk)."""
    data_rp = jnp.asarray(data_rp)
    return data_rp[..., jnp.asarray(plan.vrow), jnp.asarray(plan.slot), :, :]


# --------------------------------------------------------------------------
# the differentiable plan-backed op
# --------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def plan_linear(x, data_rp, plan: RowPackPlan):
    """Y(M, N) = X(M, K) @ W^T with W given as a plan + row-grouped values.

    The per-call path is pattern-free compute: one gather of ``x`` at static
    indices, one batched matmul, and a segment-sum only when the plan
    spilled rows. Differentiable in ``x`` and ``data_rp`` (padding-slot
    gradients are exactly zero)."""
    return _plan_fwd_impl(x, data_rp, plan)


def _gather_x(x, plan: RowPackPlan):
    m = x.shape[0]
    bk = plan.tile[1]
    return x.reshape(m, plan.shape[1] // bk, bk)[:, jnp.asarray(plan.col_idx)]


def _plan_fwd_impl(x, data_rp, plan):
    m = x.shape[0]
    xg = _gather_x(x, plan)                               # (M, V, P, bk)
    y = jnp.einsum("mvpk,vpnk->vmn", xg, data_rp,
                   preferred_element_type=jnp.float32)    # (V, M, bn)
    if plan.spilled:
        y = jax.ops.segment_sum(y, jnp.asarray(plan.row_of_vrow),
                                num_segments=plan.n_brows)  # (R, M, bn)
    return y.transpose(1, 0, 2).reshape(m, plan.shape[0]).astype(x.dtype)


def _plan_fwd(x, data_rp, plan):
    return _plan_fwd_impl(x, data_rp, plan), (x, data_rp)


def _plan_bwd(plan, res, dy):
    x, data_rp = res
    m = x.shape[0]
    bn, bk = plan.tile
    dy_v = dy.reshape(m, plan.n_brows, bn)
    if plan.spilled:
        dy_v = dy_v[:, jnp.asarray(plan.row_of_vrow)]     # (M, V, bn)
    xg = _gather_x(x, plan)
    ddata = jnp.einsum("mvn,mvpk->vpnk", dy_v, xg,
                       preferred_element_type=jnp.float32)
    ddata = ddata * jnp.asarray(plan.slot_mask)[:, :, None, None].astype(
        ddata.dtype)
    dxg = jnp.einsum("mvn,vpnk->mvpk", dy_v, data_rp,
                     preferred_element_type=jnp.float32)
    dx = jnp.zeros((m, plan.shape[1] // bk, bk), dxg.dtype)
    dx = dx.at[:, jnp.asarray(plan.col_idx)].add(dxg)
    return (dx.reshape(m, plan.shape[1]).astype(x.dtype),
            ddata.astype(data_rp.dtype))


plan_linear.defvjp(_plan_fwd, _plan_bwd)


def plan_matmul(x: jax.Array, data_rp: jax.Array, plan: RowPackPlan):
    """Batched-x entry point: x (..., K) -> (..., N)."""
    lead = x.shape[:-1]
    y = plan_linear(x.reshape(-1, x.shape[-1]), data_rp, plan)
    return y.reshape(*lead, plan.shape[0])


# --------------------------------------------------------------------------
# compiled Pallas backend: the plan's spill schedule drives the kernel grid
# --------------------------------------------------------------------------
#
# plan_linear composes the row-grouped layout out of XLA ops (gather /
# batched matmul / segment-sum). plan_linear_pallas hands the SAME layout to
# a Pallas kernel (bsr_matmul.plan_dds): the (V, P, bn, bk) values are
# streamed in place -- the scalar-prefetched schedule below picks one
# (vrow, slot) tile per grid step -- and because tiles are visited in output-
# row order, spill vrows accumulate into the same VMEM scratch as their home
# row and the segment-sum disappears into the row-change write.

def pallas_interpret_default() -> bool:
    """Kernels compile on TPU; everywhere else interpret mode is the
    correctness oracle (docs/PERF.md: orders of magnitude slower)."""
    return jax.default_backend() != "tpu"


@functools.lru_cache(maxsize=None)
def plan_kernel_sequence(plan: RowPackPlan):
    """Forward tile visitation schedule for the plan-consuming kernel.

    Real tiles stably sorted by owning output block row, so home and spill
    tiles of one row are consecutive (one accumulator lifetime per row).
    Returns ``(row_seq, col_seq, vrow_seq, slot_seq)`` int32 numpy arrays;
    ``row_seq`` carries the usual write-on-row-change sentinel. Cached per
    plan fingerprint (the plan hashes by it) -- host work runs once.
    """
    vrow = np.asarray(plan.vrow, np.int64)
    slot = np.asarray(plan.slot, np.int64)
    t_row = np.asarray(plan.row_of_vrow, np.int64)[vrow]
    # pack_bsr guarantees >= 1 real tile per block row, which build_plan
    # preserves -- the write-on-row-change protocol needs full coverage
    assert np.array_equal(np.unique(t_row), np.arange(plan.n_brows)), \
        "plan does not cover every output block row"
    order = np.argsort(t_row, kind="stable")
    row_seq = np.concatenate([t_row[order], [plan.n_brows]]).astype(np.int32)
    col_seq = np.asarray(plan.col_idx, np.int64)[vrow, slot][order]
    return (row_seq, col_seq.astype(np.int32),
            vrow[order].astype(np.int32), slot[order].astype(np.int32))


@functools.lru_cache(maxsize=None)
def plan_t_sequence(plan: RowPackPlan):
    """Transposed schedule (tiles sorted by block column) for dX = dY @ W.

    Returns ``(t_row_seq, t_col_seq, t_flat)`` where ``t_flat`` indexes the
    flattened (V*P, bn, bk) values (gathered + transposed per call, like
    the KernelBSR dds_t path)."""
    vrow = np.asarray(plan.vrow, np.int64)
    slot = np.asarray(plan.slot, np.int64)
    t_row = np.asarray(plan.row_of_vrow, np.int64)[vrow]
    t_col = np.asarray(plan.col_idx, np.int64)[vrow, slot]
    assert np.array_equal(np.unique(t_col), np.arange(plan.n_bcols)), \
        "plan does not cover every input block column"
    order = np.lexsort((t_row, t_col))
    t_row_seq = np.concatenate(
        [t_col[order], [plan.n_bcols]]).astype(np.int32)
    t_col_seq = t_row[order].astype(np.int32)
    t_flat = (vrow[order] * plan.p_max + slot[order]).astype(np.int32)
    return t_row_seq, t_col_seq, t_flat


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def plan_linear_pallas(x, data_rp, plan: RowPackPlan):
    """Y(M, N) = X(M, K) @ W^T via the compiled plan-consuming Pallas kernel.

    Same layout contract as :func:`plan_linear` (row-grouped (V, P, bn, bk)
    values), same gradients (padding-slot grads exactly zero); the execution
    is one Pallas grid over the spill schedule instead of gather + einsum +
    segment-sum. Interpret mode (off-TPU) is the correctness oracle.
    """
    return _plan_pallas_fwd_impl(x, data_rp, plan)


def _plan_pallas_fwd_impl(x, data_rp, plan, bias=None, act=None):
    from repro.kernels.bsr_matmul import plan_dds
    return plan_dds(x, data_rp, plan_kernel_sequence(plan),
                    n=plan.shape[0], tile=plan.tile, bias=bias, act=act,
                    interpret=pallas_interpret_default())


def _plan_pallas_fwd(x, data_rp, plan):
    return _plan_pallas_fwd_impl(x, data_rp, plan), (x, data_rp)


def _plan_pallas_bwd(plan, res, dy):
    from repro.kernels.bsr_matmul import plan_dds_t, plan_sddmm
    x, data_rp = res
    interpret = pallas_interpret_default()
    dx = plan_dds_t(dy, data_rp, plan_t_sequence(plan),
                    k=plan.shape[1], tile=plan.tile, interpret=interpret)
    seq = plan_kernel_sequence(plan)
    g_seq = plan_sddmm(dy, x, seq, tile=plan.tile,
                       out_dtype=jnp.float32, interpret=interpret)
    # scatter schedule-ordered tile grads back into the row-grouped layout;
    # untouched (padding) slots stay exactly zero, matching slot_mask
    ddata = jnp.zeros(data_rp.shape, jnp.float32)
    ddata = ddata.at[jnp.asarray(seq[2]), jnp.asarray(seq[3])].set(g_seq)
    return dx.astype(x.dtype), ddata.astype(data_rp.dtype)


plan_linear_pallas.defvjp(_plan_pallas_fwd, _plan_pallas_bwd)


def plan_fused_linear(x, data_rp, plan: RowPackPlan, *, bias=None,
                      act: str | None = None):
    """Forward-only fused epilogue entry: bias add + activation ('relu' /
    'gelu' / 'silu') folded into the kernel's row-change write -- the
    serving-path shape of the op (no extra HBM round-trip for the
    activation between wi and wo)."""
    return _plan_pallas_fwd_impl(x, data_rp, plan, bias=bias, act=act)


def plan_matmul_pallas(x: jax.Array, data_rp: jax.Array, plan: RowPackPlan):
    """Batched-x entry point for the Pallas plan backend."""
    lead = x.shape[:-1]
    y = plan_linear_pallas(x.reshape(-1, x.shape[-1]), data_rp, plan)
    return y.reshape(*lead, plan.shape[0])


@dataclasses.dataclass(frozen=True, eq=False)
class PlanChoice:
    """A RowPackPlan pinned to a specific plan-consuming execution backend.

    ``backend='plan_pallas'`` routes models/common.linear through
    :func:`plan_linear_pallas`; the wrapper (rather than a bare plan) keeps
    the choice serializable and the pattern key distinct from the XLA plan
    path, mirroring autotune.BackendChoice for flat KernelBSR packs.
    """

    plan: RowPackPlan
    backend: str = "plan_pallas"

    @property
    def shape(self) -> Tuple[int, int]:
        return self.plan.shape

    @property
    def tile(self) -> Tuple[int, int]:
        return self.plan.tile

    @property
    def density(self) -> float:
        return self.plan.density

    @property
    def fingerprint(self) -> bytes:
        return (b"plan_choice:" + self.backend.encode() + b":"
                + self.plan.fingerprint)

    def __hash__(self):
        return hash(self.fingerprint)

    def __eq__(self, other):
        return (isinstance(other, PlanChoice)
                and self.fingerprint == other.fingerprint)


# --------------------------------------------------------------------------
# quantized packs: int8/fp8 block values, one fp32 scale per block
# --------------------------------------------------------------------------
#
# The BSR block is the natural quantization unit (Intel's sparse CPU
# accelerator, arxiv 2306.16601): one scale per (bn, bk) tile keeps the
# dequant inside the block matmul, so the XLA path folds it into the
# gathered activations (xg * scale before the einsum -- exactly equivalent,
# fp32 weight values never land in the params tree) and the Pallas path
# multiplies the accumulator contribution by the scalar-prefetched scale.
# Skinny tiles (bn*bk below _QUANT_BLOCK_MIN_ELEMS, e.g. the paper's 32x1
# column blocks) would spend one fp32 scale per <=32 values; they fall back
# to one scale per virtual row (the row group), bounding scale overhead.

QUANT_DTYPES = ("int8", "fp8")
#: per-block scales need bn*bk elements to amortize their 4 bytes; below
#: this the scale granularity falls back to one per row group (vrow)
_QUANT_BLOCK_MIN_ELEMS = 128
_FP8_MAX = 448.0                       # float8_e4m3fn finite max


def fp8_dtype():
    """jnp.float8_e4m3fn when this jax build has float8, else None."""
    return getattr(jnp, "float8_e4m3fn", None)


def quant_granularity(tile: Tuple[int, int]) -> str:
    """'block' (one scale per (bn, bk) tile) for tiles that amortize the
    fp32 scale; 'row' (one per virtual row group) for skinny tiles."""
    return "block" if tile[0] * tile[1] >= _QUANT_BLOCK_MIN_ELEMS else "row"


def _qparams(qdtype: str):
    if qdtype == "int8":
        return 127.0, jnp.int8
    if qdtype == "fp8":
        ft = fp8_dtype()
        if ft is None:
            raise NotImplementedError(
                "pack_quant='fp8' needs a jax build with float8_e4m3fn; "
                "this one has none (use 'int8')")
        return _FP8_MAX, ft
    raise ValueError(f"qdtype={qdtype!r} not in {QUANT_DTYPES}")


def quantize_plan_values(data_rp, qdtype: str, granularity: str):
    """Row-grouped values (..., V, P, bn, bk) -> (qvalues, scales).

    Symmetric absmax quantization: ``scales`` is (..., V, P) fp32 for
    'block' granularity, (..., V, 1) for 'row' (the trailing 1 broadcasts
    over slots, and gives the Pallas kernel a static slot-0 index map).
    All-zero groups (pruned padding slots) get scale 1.0 so dequant stays
    exact zero."""
    d = jnp.asarray(data_rp, jnp.float32)
    if granularity == "block":
        amax = jnp.max(jnp.abs(d), axis=(-2, -1))          # (..., V, P)
    elif granularity == "row":
        amax = jnp.max(jnp.abs(d), axis=(-3, -2, -1))[..., None]
    else:
        raise ValueError(f"granularity={granularity!r}")
    qmax, qt = _qparams(qdtype)
    scales = jnp.where(amax > 0, amax / qmax, 1.0).astype(jnp.float32)
    s = scales[..., None, None]                # broadcast over (bn, bk)
    if qdtype == "int8":
        q = jnp.clip(jnp.round(d / s), -qmax, qmax).astype(qt)
    else:
        q = (d / s).astype(qt)
    return q, scales


def dequantize_plan_values(qvalues, scales) -> jax.Array:
    """(qvalues, scales) -> fp32 row-grouped values (the export-time
    round-trip check and the serialize-compat path; serving never calls
    this -- dequant stays fused in the matmul)."""
    q = jnp.asarray(qvalues).astype(jnp.float32)
    return q * jnp.asarray(scales)[..., None, None]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def plan_q_linear(x, qvalues, scales, plan: RowPackPlan):
    """Y(M, N) = X(M, K) @ dequant(Q)^T with the dequant fused.

    Scaling the *gathered activations* (xg[m, v, p, :] * scales[v, p]) is
    exactly the per-block weight dequant re-associated onto the small
    operand, so the einsum contracts int8/fp8 values directly (XLA fuses
    the widening convert into the contraction) and a full fp32 weight
    tensor never materializes. Differentiable in ``x`` (serving + probe
    path); quantized values are constants, their grad is zero."""
    return _plan_q_fwd_impl(x, qvalues, scales, plan)


def _scale_xg(xg, scales):
    # xg (M, V, P, bk); scales (V, P) or (V, 1) -> broadcast over bk (and
    # over slots for row granularity)
    return xg.astype(jnp.float32) * scales[..., None]


def _plan_q_fwd_impl(x, qvalues, scales, plan):
    m = x.shape[0]
    xs = _scale_xg(_gather_x(x, plan), scales)            # (M, V, P, bk)
    y = jnp.einsum("mvpk,vpnk->vmn", xs, qvalues.astype(jnp.float32),
                   preferred_element_type=jnp.float32)    # (V, M, bn)
    if plan.spilled:
        y = jax.ops.segment_sum(y, jnp.asarray(plan.row_of_vrow),
                                num_segments=plan.n_brows)
    return y.transpose(1, 0, 2).reshape(m, plan.shape[0]).astype(x.dtype)


def _plan_q_fwd(x, qvalues, scales, plan):
    return _plan_q_fwd_impl(x, qvalues, scales, plan), (x, qvalues, scales)


def _plan_q_bwd(plan, res, dy):
    x, qvalues, scales = res
    m = x.shape[0]
    bn, bk = plan.tile
    dy_v = dy.reshape(m, plan.n_brows, bn)
    if plan.spilled:
        dy_v = dy_v[:, jnp.asarray(plan.row_of_vrow)]     # (M, V, bn)
    dxg = jnp.einsum("mvn,vpnk->mvpk", dy_v, qvalues.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    dxg = _scale_xg(dxg, scales)       # re-associate the dequant onto dX
    dx = jnp.zeros((m, plan.shape[1] // bk, bk), dxg.dtype)
    dx = dx.at[:, jnp.asarray(plan.col_idx)].add(dxg)
    return (dx.reshape(m, plan.shape[1]).astype(x.dtype),
            jnp.zeros_like(qvalues), jnp.zeros_like(scales))


plan_q_linear.defvjp(_plan_q_fwd, _plan_q_bwd)


def plan_q_matmul(x: jax.Array, qvalues, scales, plan: RowPackPlan):
    """Batched-x entry point for the dequant-fused XLA plan backend."""
    lead = x.shape[:-1]
    y = plan_q_linear(x.reshape(-1, x.shape[-1]), qvalues, scales, plan)
    return y.reshape(*lead, plan.shape[0])


def plan_q_linear_pallas(x, qvalues, scales, plan: RowPackPlan, *,
                         bias=None, act: str | None = None):
    """Dequant-fused plan matmul via the compiled Pallas kernel: the
    per-block scale rides the scalar-prefetched schedule and multiplies
    the accumulator contribution in place (bsr_matmul.plan_dds_q), with
    the same fused bias/act epilogue as :func:`plan_fused_linear`.
    Forward-only (serving path)."""
    from repro.kernels.bsr_matmul import plan_dds_q
    granularity = "row" if scales.shape[-1] == 1 else "block"
    return plan_dds_q(x, qvalues, scales, plan_kernel_sequence(plan),
                      n=plan.shape[0], tile=plan.tile,
                      granularity=granularity, bias=bias, act=act,
                      interpret=pallas_interpret_default())


def plan_q_matmul_pallas(x: jax.Array, qvalues, scales,
                         plan: RowPackPlan):
    """Batched-x entry point for the dequant-fused Pallas plan backend."""
    lead = x.shape[:-1]
    y = plan_q_linear_pallas(x.reshape(-1, x.shape[-1]), qvalues, scales,
                             plan)
    return y.reshape(*lead, plan.shape[0])


@dataclasses.dataclass(frozen=True, eq=False)
class QuantPlan:
    """A RowPackPlan whose values are stored quantized (int8/fp8 + fp32
    scales) and served through the dequant-fused plan matmul.

    Wraps the (possibly Sharded) plan rather than subclassing it: the
    pattern, spill schedule and shard layout are untouched -- only the
    value storage and the dispatch change. The params-tree entry for a
    QuantPlan pack is ``{"w": qvalues, "scale": scales}`` (dtype-cast and
    byte accounting treat it specially; serving/servable.py).

    ``backend`` pins the execution path: 'plan' = the XLA composition
    (:func:`plan_q_matmul`), 'plan_pallas' = the compiled kernel
    (:func:`plan_q_matmul_pallas`).
    """

    plan: RowPackPlan
    qdtype: str = "int8"               # 'int8' | 'fp8'
    granularity: str = "block"         # 'block' (V, P) | 'row' (V, 1)
    backend: str = "plan"              # 'plan' | 'plan_pallas'

    @property
    def shape(self) -> Tuple[int, int]:
        return self.plan.shape

    @property
    def tile(self) -> Tuple[int, int]:
        return self.plan.tile

    @property
    def density(self) -> float:
        return self.plan.density

    @property
    def real_nnzt(self) -> int:
        return self.plan.real_nnzt

    @property
    def fingerprint(self) -> bytes:
        return (b"quant:" + self.qdtype.encode() + b":"
                + self.granularity.encode() + b":" + self.backend.encode()
                + b":" + self.plan.fingerprint)

    def with_mesh(self, mesh) -> "QuantPlan":
        """Mesh attachment passthrough for ShardedPlan inners."""
        if isinstance(self.plan, ShardedPlan):
            return dataclasses.replace(self, plan=self.plan.with_mesh(mesh))
        return self

    def __hash__(self):
        return hash(self.fingerprint)

    def __eq__(self, other):
        return (isinstance(other, QuantPlan)
                and self.fingerprint == other.fingerprint)


def quantize_for_plan(plan: RowPackPlan, data, qdtype: str, *,
                      backend: str = "plan"):
    """Packed tile values (..., nnzt, bn, bk) -> (QuantPlan, params dict).

    The export-time quantize pass: row-group the values (pack_plan_data),
    pick the scale granularity from the tile, quantize. Returns the
    QuantPlan wrapper and its ``{"w", "scale"}`` params entry."""
    data_rp = pack_plan_data(plan, data)
    granularity = quant_granularity(plan.tile)
    q, s = quantize_plan_values(data_rp, qdtype, granularity)
    qp = QuantPlan(plan, qdtype=qdtype, granularity=granularity,
                   backend=backend)
    return qp, {"w": q, "scale": s}
